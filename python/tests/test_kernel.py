"""L1 correctness: Pallas kernels vs pure-jnp oracles.

The CORE numeric signal of the stack: if these pass, every GEMM the rust
coordinator dispatches computes the paper's PE datapath exactly.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cim_gemm as ck
from compile.kernels import conv2d as c2
from compile.kernels import ref


def _rand_i8(rng, shape, lo=-128, hi=128):
    return jnp.array(rng.integers(lo, hi, shape, dtype=np.int8))


# ---------------------------------------------------------------- cim_gemm


class TestCimGemm:
    def test_matches_ref_full_range(self):
        rng = np.random.default_rng(1)
        a = _rand_i8(rng, (64, 64))
        w = _rand_i8(rng, (64, 64))
        out = ck.cim_gemm(a, w)
        want = ref.cim_gemm_ref(a, w)
        np.testing.assert_array_equal(out, want)

    def test_exact_regime_matches_ideal_gemm(self):
        # Small magnitudes -> no ADC saturation -> bit-serial == exact GEMM.
        rng = np.random.default_rng(2)
        a = _rand_i8(rng, (64, 16), lo=0, hi=4)
        w = _rand_i8(rng, (16, 8), lo=-2, hi=3)
        out = ck.cim_gemm(a, w, block_b=64)
        want = ref.gemm_exact_ref(a, w)
        np.testing.assert_array_equal(out, want)

    def test_saturating_regime_differs_from_ideal(self):
        # All-max inputs saturate the ADC: the clamp must bite, and the
        # kernel must agree with the clamped oracle, not the ideal GEMM.
        a = jnp.full((64, 64), 127, jnp.int8)
        w = jnp.full((64, 64), 127, jnp.int8)
        out = ck.cim_gemm(a, w)
        want = ref.cim_gemm_ref(a, w)
        ideal = ref.gemm_exact_ref(a, w)
        np.testing.assert_array_equal(out, want)
        assert not np.array_equal(np.asarray(out), np.asarray(ideal))

    def test_zero_activation_is_zero(self):
        rng = np.random.default_rng(3)
        a = jnp.zeros((64, 64), jnp.int8)
        w = _rand_i8(rng, (64, 64))
        np.testing.assert_array_equal(ck.cim_gemm(a, w), 0)

    def test_negative_activations_twos_complement(self):
        # -1 = all bit-planes set; exercises the MSB sign path.
        a = jnp.full((64, 8), -1, jnp.int8)
        w = jnp.eye(8, dtype=jnp.int8)
        out = ck.cim_gemm(a, w)
        np.testing.assert_array_equal(out, -1)

    def test_multiple_batch_blocks(self):
        rng = np.random.default_rng(4)
        a = _rand_i8(rng, (256, 64))
        w = _rand_i8(rng, (64, 64))
        out = ck.cim_gemm(a, w, block_b=64)
        want = ref.cim_gemm_ref(a, w)
        np.testing.assert_array_equal(out, want)

    def test_bad_batch_multiple_rejected(self):
        a = jnp.zeros((65, 64), jnp.int8)
        w = jnp.zeros((64, 64), jnp.int8)
        with pytest.raises(AssertionError):
            ck.cim_gemm(a, w, block_b=64)

    @settings(max_examples=25, deadline=None)
    @given(
        b_blocks=st.integers(1, 3),
        c1=st.sampled_from([8, 16, 32, 64]),
        c2=st.sampled_from([8, 16, 64]),
        adc_bits=st.sampled_from([6, 8, 10]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_prop_matches_ref(self, b_blocks, c1, c2, adc_bits, seed):
        """Hypothesis sweep over shapes and ADC resolutions."""
        rng = np.random.default_rng(seed)
        a = _rand_i8(rng, (32 * b_blocks, c1))
        w = _rand_i8(rng, (c1, c2))
        out = ck.cim_gemm(a, w, adc_bits=adc_bits, block_b=32)
        want = ref.cim_gemm_ref(a, w, adc_bits=adc_bits)
        np.testing.assert_array_equal(out, want)

    @settings(max_examples=10, deadline=None)
    @given(
        input_bits=st.sampled_from([4, 8]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_prop_input_bits(self, input_bits, seed):
        rng = np.random.default_rng(seed)
        hi = 1 << (input_bits - 1)
        a = _rand_i8(rng, (64, 16), lo=-hi, hi=hi)
        w = _rand_i8(rng, (16, 16))
        out = ck.cim_gemm(a, w, input_bits=input_bits)
        want = ref.cim_gemm_ref(a, w, input_bits=input_bits)
        np.testing.assert_array_equal(out, want)


# ------------------------------------------------------------- conv2d_3x3


class TestConv2d:
    def test_matches_dense_conv_no_saturation(self):
        rng = np.random.default_rng(5)
        x = _rand_i8(rng, (1, 8, 8, 16), lo=-4, hi=4)
        w = _rand_i8(rng, (3, 3, 16, 16), lo=-2, hi=3)
        out = c2.conv2d_3x3(x, w)
        want = ref.conv2d_ref(x, w)
        np.testing.assert_array_equal(out, want)

    def test_batch_dim(self):
        rng = np.random.default_rng(6)
        x = _rand_i8(rng, (3, 4, 4, 8), lo=-3, hi=4)
        w = _rand_i8(rng, (3, 3, 8, 8), lo=-2, hi=2)
        out = c2.conv2d_3x3(x, w)
        want = ref.conv2d_ref(x, w)
        np.testing.assert_array_equal(out, want)

    def test_identity_kernel(self):
        # Center-tap identity: output == input (widened).
        rng = np.random.default_rng(7)
        x = _rand_i8(rng, (1, 5, 5, 4), lo=-8, hi=8)
        w = np.zeros((3, 3, 4, 4), np.int8)
        w[1, 1] = np.eye(4, dtype=np.int8)
        out = c2.conv2d_3x3(x, jnp.array(w))
        np.testing.assert_array_equal(out, np.asarray(x, np.int32))

    def test_saturating_matches_bitserial_oracle(self):
        # Build the conv oracle out of the clamped cim_gemm_ref so the ADC
        # path is checked through the conv kernel too.
        rng = np.random.default_rng(8)
        x = _rand_i8(rng, (1, 4, 4, 32))
        w = _rand_i8(rng, (3, 3, 32, 8))
        out = np.asarray(c2.conv2d_3x3(x, w))
        xp = np.pad(np.asarray(x), ((0, 0), (1, 1), (1, 1), (0, 0)))
        want = np.zeros_like(out)
        for ky in range(3):
            for kx in range(3):
                patch = xp[:, ky : ky + 4, kx : kx + 4, :].reshape(-1, 32)
                psum = ref.cim_gemm_ref(
                    jnp.array(patch, jnp.int8), jnp.array(w[ky, kx])
                )
                want += np.asarray(psum).reshape(1, 4, 4, 8)
        np.testing.assert_array_equal(out, want)

    @settings(max_examples=10, deadline=None)
    @given(
        h=st.integers(2, 8),
        w_=st.integers(2, 8),
        c=st.sampled_from([4, 8, 16]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_prop_shapes(self, h, w_, c, seed):
        rng = np.random.default_rng(seed)
        x = _rand_i8(rng, (1, h, w_, c), lo=-3, hi=4)
        wk = _rand_i8(rng, (3, 3, c, c), lo=-2, hi=2)
        out = c2.conv2d_3x3(x, wk)
        want = ref.conv2d_ref(x, wk)
        np.testing.assert_array_equal(out, want)


# ------------------------------------------------------------ perf proxies


class TestPerfModel:
    def test_vmem_footprint_fits_vmem(self):
        # One grid step of the default block must fit a TPU core's ~16 MiB
        # VMEM with generous headroom (DESIGN.md §Perf).
        fp = ck.vmem_footprint_bytes(ck.DEFAULT_BLOCK_B, 64, 64)
        assert fp < 2 * 1024 * 1024

    def test_mxu_utilization_reported(self):
        u = ck.mxu_utilization_estimate(ck.DEFAULT_BLOCK_B, 64, 64)
        assert 0.0 < u <= 1.0
        # Block B=128 fills the MXU rows; 64/128 on each channel dim.
        assert abs(u - 0.25) < 1e-9
        # The C=64 channel tile (CIM sub-matrix fidelity) caps util at
        # 0.25; full fill needs 128-channel tiles.
        assert abs(ck.mxu_utilization_estimate(128, 128, 128) - 1.0) < 1e-9
