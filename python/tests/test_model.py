"""L2 correctness: model-level compositions and the epilogue."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


class TestOffsetGemm:
    def test_fused_equals_per_offset(self):
        rng = np.random.default_rng(10)
        k3, b, c = 27, 64, 64
        a = jnp.array(rng.integers(-128, 128, (k3, b, c), dtype=np.int8))
        w = jnp.array(rng.integers(-128, 128, (k3, c, c), dtype=np.int8))
        fused = model.offset_gemm_fused(a, w)
        for k in range(0, k3, 5):
            want = model.offset_gemm(a[k], w[k])
            np.testing.assert_array_equal(fused[k], want)

    def test_offset_gemm_is_ref(self):
        rng = np.random.default_rng(11)
        a = jnp.array(rng.integers(-128, 128, (64, 64), dtype=np.int8))
        w = jnp.array(rng.integers(-128, 128, (64, 64), dtype=np.int8))
        np.testing.assert_array_equal(
            model.offset_gemm(a, w), ref.cim_gemm_ref(a, w)
        )


class TestVfe:
    def test_mean_simple(self):
        pts = np.zeros((4, 8, 4), np.float32)
        cnt = np.array([1, 2, 4, 8], np.int32)
        for v in range(4):
            pts[v, : cnt[v]] = v + 1.0
        out = model.vfe_mean(jnp.array(pts), jnp.array(cnt))
        want = np.array([[1.0] * 4, [2.0] * 4, [3.0] * 4, [4.0] * 4])
        np.testing.assert_allclose(out, want, rtol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(v=st.integers(1, 16), p=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
    def test_prop_mean_matches_numpy(self, v, p, seed):
        rng = np.random.default_rng(seed)
        cnt = rng.integers(1, p + 1, v).astype(np.int32)
        pts = np.zeros((v, p, 4), np.float32)
        for i in range(v):
            pts[i, : cnt[i]] = rng.normal(size=(cnt[i], 4)).astype(np.float32)
        out = np.asarray(model.vfe_mean(jnp.array(pts), jnp.array(cnt)))
        want = pts.sum(1) / cnt[:, None]
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)


class TestEpilogue:
    def test_relu_clamps_negative(self):
        psum = jnp.array([[-100, 50]], jnp.int32)
        scale = jnp.array([1.0, 1.0], jnp.float32)
        zero = jnp.array([0.0, 0.0], jnp.float32)
        out = model.dequant_relu_quant(psum, scale, zero)
        np.testing.assert_array_equal(out, np.array([[0, 50]], np.int8))

    def test_saturates_to_int8(self):
        psum = jnp.array([[10_000, -10_000]], jnp.int32)
        scale = jnp.array([1.0, 1.0], jnp.float32)
        zero = jnp.array([0.0, 0.0], jnp.float32)
        out = model.dequant_relu_quant(psum, scale, zero)
        np.testing.assert_array_equal(out, np.array([[127, 0]], np.int8))

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_prop_range_and_monotonic(self, seed):
        rng = np.random.default_rng(seed)
        psum = jnp.array(rng.integers(-(2**20), 2**20, (8, 16)), jnp.int32)
        scale = jnp.array(np.abs(rng.normal(0.01, 0.005, 16)) + 1e-4, jnp.float32)
        zero = jnp.array(rng.normal(0, 1, 16), jnp.float32)
        out = np.asarray(model.dequant_relu_quant(psum, scale, zero))
        assert out.dtype == np.int8
        assert (out >= 0).all()  # ReLU then quantize: never negative
