"""AOT path: every artifact lowers to parseable HLO text and the lowered
computation agrees with executing the jitted function directly."""

import os
import subprocess
import sys
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


def test_manifest_covers_all_entries():
    names = [e[0] for e in aot.build_entries()]
    assert len(names) == len(set(names)), "duplicate artifact names"
    kinds = {e[3]["kind"] for e in aot.build_entries()}
    assert {"gemm", "gemm_fused", "conv3x3", "epilogue", "vfe_mean"} <= kinds


@pytest.mark.parametrize("entry", aot.build_entries(), ids=lambda e: e[0])
def test_every_entry_lowers_to_hlo_text(entry):
    name, fn, specs, kv = entry
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), f"{name}: not HLO text"
    assert "ENTRY" in text
    # The interchange constraint: ids must be 32-bit safe after re-parse;
    # the text emitter guarantees this, but assert no obviously huge ids.
    assert "parameter(0)" in text


def test_cli_writes_artifacts_and_manifest():
    with tempfile.TemporaryDirectory() as d:
        env = dict(os.environ)
        r = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", d,
             "--only", "cim_gemm_b64"],
            cwd=os.path.join(os.path.dirname(__file__), ".."),
            capture_output=True, text=True, env=env, timeout=600,
        )
        assert r.returncode == 0, r.stderr
        assert os.path.exists(os.path.join(d, "cim_gemm_b64.hlo.txt"))


def test_gemm_artifact_numerics_roundtrip():
    """Compile the lowered HLO with jax's own client and compare results —
    the same HLO text the rust runtime loads."""
    name, fn, specs, kv = [e for e in aot.build_entries() if e[0] == "cim_gemm_b64"][0]
    rng = np.random.default_rng(42)
    a = jnp.array(rng.integers(-128, 128, specs[0].shape, dtype=np.int8))
    w = jnp.array(rng.integers(-128, 128, specs[1].shape, dtype=np.int8))
    direct = fn(a, w)[0]
    want = model.offset_gemm(a, w)
    np.testing.assert_array_equal(direct, want)
