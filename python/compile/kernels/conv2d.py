"""L1 Pallas kernel: RPN dense Conv2D with the CIM sub-matrix schedule.

The paper maps Conv2D onto the same CIM fabric with K x K sub-matrices
(Fig. 5c): the kernel slides, and the input feature vector gathered for
sub-matrix (ky, kx) this cycle is reused by the neighbouring sub-matrix
next cycle. In our stack the bulk data movement lives in the rust
coordinator (spconv/conv2d.rs builds im2col batches dispatched to the
shared cim_gemm artifact); this module additionally provides a *fused*
Pallas conv used for small RPN feature maps, demonstrating the sub-matrix
schedule inside one kernel: each of the 9 weight slices is a resident
sub-block activated in turn, with the bit-serial ADC datapath of
cim_gemm applied per activation wave.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _conv3x3_kernel(x_ref, w_ref, o_ref, *, input_bits: int, adc_bits: int):
    """One output row of a SAME, stride-1, 3x3 conv, NHWC.

    x_ref : [1, H+2, W+2, C1] the whole padded image of this batch element
    w_ref : [3, 3, C1, C2]    resident weight sub-matrices
    o_ref : [1, 1, W, C2]     output row `pl.program_id(1)`
    """
    hrow = pl.program_id(1)
    _, _, wpad, c1 = x_ref.shape
    w_out = o_ref.shape[2]
    c2 = o_ref.shape[3]
    lo = -(1 << (adc_bits - 1))
    hi = (1 << (adc_bits - 1)) - 1
    acc = jnp.zeros((w_out, c2), jnp.int32)
    # Three padded input rows hrow .. hrow+2 form the halo of output row
    # hrow (padded coordinates).
    halo = jax.lax.dynamic_slice(
        x_ref[...], (0, hrow, 0, 0), (1, 3, wpad, c1)
    )[0].astype(jnp.int32)  # [3, W+2, C1]
    # Sub-matrix schedule: activate each of the 9 weight sub-matrices in
    # turn; the gathered input row is shared between horizontally adjacent
    # sub-matrices (the paper's Conv2D feature-reuse argument).
    for ky in range(3):
        row = halo[ky]  # [W+2, C1]
        for kx in range(3):
            xs = jax.lax.dynamic_slice(row, (kx, 0), (w_out, c1))
            wsub = w_ref[ky, kx, :, :].astype(jnp.int32)  # [C1, C2]
            for b in range(input_bits):
                bit = (xs >> b) & 1
                psum = jax.lax.dot_general(
                    bit,
                    wsub,
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32,
                )
                psum = jnp.clip(psum, lo, hi)  # ADC saturation
                sign = -1 if b == input_bits - 1 else 1
                acc = acc + sign * (psum << b)  # shift-adder
    o_ref[0, 0, :, :] = acc


@functools.partial(jax.jit, static_argnames=("input_bits", "adc_bits"))
def conv2d_3x3(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    input_bits: int = ref.INPUT_BITS,
    adc_bits: int = ref.ADC_BITS,
) -> jnp.ndarray:
    """Fused 3x3 SAME stride-1 conv, int8 NHWC x [3,3,C1,C2] -> int32 NHWC.

    Grid = (N, H): one kernel invocation per output row. The padded image
    block stays resident across the H grid dimension (index map ignores the
    row index), so HBM->VMEM traffic is O(image), not O(image * H).
    """
    n, h, width, c1 = x.shape
    c2 = w.shape[3]
    assert w.shape[:3] == (3, 3, c1), f"bad weight shape {w.shape}"
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    kernel = functools.partial(
        _conv3x3_kernel, input_bits=input_bits, adc_bits=adc_bits
    )
    return pl.pallas_call(
        kernel,
        grid=(n, h),
        in_specs=[
            pl.BlockSpec((1, h + 2, width + 2, c1), lambda ni, hi_: (ni, 0, 0, 0)),
            pl.BlockSpec((3, 3, c1, c2), lambda ni, hi_: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, width, c2), lambda ni, hi_: (ni, hi_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h, width, c2), jnp.int32),
        interpret=True,
    )(xp, w)
