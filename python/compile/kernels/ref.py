"""Pure-jnp correctness oracles for the Pallas kernels.

These are the bit-exact references used by pytest. The CIM PE datapath
(Fig. 7 of the paper) is:

    weights  : int8, resident in the SRAM sub-array (sub-matrix mapping)
    inputs   : int8 activations, fed bit-serially (one bit-plane per cycle)
    per bit-plane b: analog MAC wave -> column partial sum -> ADC (clamped
                     to `adc_bits` of resolution) -> shift-adder adds
                     (psum_b << b) into the digital accumulator

Everything is integer math; the oracle reproduces the clamp exactly so the
Pallas kernel can be checked bit-for-bit.
"""

from __future__ import annotations

import jax.numpy as jnp

# Default datapath parameters (match rust/src/cim/pe.rs PeConfig::default).
INPUT_BITS = 8  # int8 activations, bit-serial
ADC_BITS = 8  # ADC resolution per column read


def adc_range(adc_bits: int = ADC_BITS) -> tuple[int, int]:
    """Signed saturation range of the ADC digital output."""
    lo = -(1 << (adc_bits - 1))
    hi = (1 << (adc_bits - 1)) - 1
    return lo, hi


def cim_gemm_ref(
    acts: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    input_bits: int = INPUT_BITS,
    adc_bits: int = ADC_BITS,
) -> jnp.ndarray:
    """Bit-serial CIM GEMM oracle.

    acts    : [B, C1] int8 activations
    weights : [C1, C2] int8 weights
    returns : [B, C2] int32 partial sums (after shift-add recombination)

    Activations are two's-complement: bit `input_bits-1` has weight
    -(2^(input_bits-1)); lower bits are positive. Each bit-plane MAC result
    is clamped to the ADC range before the shift-add — this is the paper's
    quantization point (partial-sum quantization), NOT a full-precision
    matmul, so the result can differ from `acts @ weights` when a column
    partial sum overflows the ADC range. test_kernel.py exercises both the
    exact regime (small magnitudes) and the saturating regime.
    """
    if acts.dtype != jnp.int8 or weights.dtype != jnp.int8:
        raise TypeError("cim_gemm_ref expects int8 acts/weights")
    a = acts.astype(jnp.int32)
    w = weights.astype(jnp.int32)
    lo, hi = adc_range(adc_bits)
    acc = jnp.zeros((acts.shape[0], weights.shape[1]), jnp.int32)
    for b in range(input_bits):
        bit = (a >> b) & 1  # [B, C1] in {0,1}
        psum = bit @ w  # analog column MAC wave
        psum = jnp.clip(psum, lo, hi)  # ADC saturation
        sign = -1 if b == input_bits - 1 else 1  # two's-complement MSB
        acc = acc + sign * (psum << b)  # shift-adder
    return acc


def gemm_exact_ref(acts: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Full-precision int GEMM (what an ideal, unclamped ADC would give)."""
    return acts.astype(jnp.int32) @ weights.astype(jnp.int32)


def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """Dense 2D convolution oracle, NHWC x HWIO -> NHWC, SAME padding.

    Used for the RPN path. Integer dtypes are accumulated in int32.
    """
    import jax.lax as lax

    acc_t = jnp.int32 if jnp.issubdtype(x.dtype, jnp.integer) else jnp.float32
    return lax.conv_general_dilated(
        x.astype(acc_t),
        w.astype(acc_t),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def vfe_mean_ref(points: jnp.ndarray, counts: jnp.ndarray) -> jnp.ndarray:
    """Mean-VFE oracle: average the (padded) points in each voxel.

    points : [V, P, F] float32, zero-padded along P
    counts : [V] int32 number of valid points per voxel (>= 1)
    returns: [V, F] float32 per-voxel mean feature
    """
    s = points.sum(axis=1)
    return s / counts[:, None].astype(jnp.float32)
