"""L1 Pallas kernel: the CIM macro's bit-serial MAC wave.

This is the compute hot-spot of the whole stack: every Spconv3D offset-GEMM
and every RPN Conv2D (via im2col) in the rust coordinator dispatches to the
HLO lowered from this kernel.

Hardware adaptation (CIM -> Pallas/TPU, see DESIGN.md §Hardware-Adaptation):

  * CIM array (weight-stationary SRAM sub-matrix)  -> the [C1, C2] weight
    block resident in VMEM across the whole batch grid dimension.
  * bit-serial input drivers                       -> loop over `input_bits`
    bit-planes of the int8 activations; each plane is a {0,1} matrix fed to
    the MXU as the LHS of a matmul (the analog MAC wave).
  * per-column ADC with `adc_bits` resolution      -> clamp of the bit-plane
    partial sum.
  * shift-adder                                    -> scaled accumulation
    (psum << b), MSB negative (two's complement).

BlockSpec tiles the batch into `block_b` rows so the weight block is reused
`ceil(B/block_b)` times from VMEM — the Pallas analogue of leaving weights
in the array. `interpret=True` always: the CPU PJRT plugin cannot execute
Mosaic custom-calls; numerics are identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# TPU-friendly default: 8x128 lane multiples; on interpret/CPU any block
# works, but we keep the layout MXU-aligned so the same kernel is TPU-ready.
# 128 fills the MXU's row dimension completely (EXPERIMENTS.md §Perf L1
# iteration 1: halves the grid steps of the B>=128 artifacts; the B=64
# artifact clamps down automatically).
DEFAULT_BLOCK_B = 128


def _cim_gemm_kernel(a_ref, w_ref, o_ref, *, input_bits: int, adc_bits: int):
    """Pallas kernel body: one [block_b, C1] x [C1, C2] bit-serial GEMM."""
    a = a_ref[...].astype(jnp.int32)  # [bB, C1] int8 -> int32
    w = w_ref[...].astype(jnp.int32)  # [C1, C2]
    lo = -(1 << (adc_bits - 1))
    hi = (1 << (adc_bits - 1)) - 1
    acc = jnp.zeros(o_ref.shape, jnp.int32)
    # Python loop (static, input_bits is a compile-time constant): unrolls
    # into `input_bits` MXU waves, exactly like the PE's bit-serial schedule.
    for b in range(input_bits):
        bit = (a >> b) & 1
        # The analog MAC wave: all rows activated by this bit-plane.
        psum = jax.lax.dot_general(
            bit,
            w,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        psum = jnp.clip(psum, lo, hi)  # ADC saturation
        sign = -1 if b == input_bits - 1 else 1
        acc = acc + sign * (psum << b)  # shift-adder
    o_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("input_bits", "adc_bits", "block_b")
)
def cim_gemm(
    acts: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    input_bits: int = ref.INPUT_BITS,
    adc_bits: int = ref.ADC_BITS,
    block_b: int = DEFAULT_BLOCK_B,
) -> jnp.ndarray:
    """Bit-serial CIM GEMM: [B, C1] int8 x [C1, C2] int8 -> [B, C2] int32.

    B must be a multiple of `block_b` (the rust dispatcher always pads to
    the artifact's batch shape, so this holds by construction).
    """
    b_dim, c1 = acts.shape
    c1w, c2 = weights.shape
    assert c1 == c1w, f"contraction mismatch {c1} vs {c1w}"
    block_b = min(block_b, b_dim)  # small batches use one whole-B block
    assert b_dim % block_b == 0, f"B={b_dim} not a multiple of {block_b}"
    grid = (b_dim // block_b,)
    kernel = functools.partial(
        _cim_gemm_kernel, input_bits=input_bits, adc_bits=adc_bits
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, c1), lambda i: (i, 0)),
            # Weight block is the same for every grid step: resident reuse.
            pl.BlockSpec((c1, c2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, c2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_dim, c2), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(acts, weights)


def vmem_footprint_bytes(
    block_b: int, c1: int, c2: int, input_bits: int = ref.INPUT_BITS
) -> int:
    """Estimated VMEM working set of one grid step (for DESIGN.md §Perf).

    acts block (int8) + weight block (int8) + int32 bit-plane psum +
    int32 accumulator + int32 widened activation copy.
    """
    acts = block_b * c1  # int8
    w = c1 * c2  # int8
    a32 = block_b * c1 * 4  # widened copy
    psum = block_b * c2 * 4
    acc = block_b * c2 * 4
    return acts + w + a32 + psum + acc


def mxu_utilization_estimate(block_b: int, c1: int, c2: int) -> float:
    """Fraction of 128x128 MXU lanes used by one bit-plane wave.

    The bit-plane matmul is [block_b, c1] x [c1, c2]; the MXU processes
    128x128 tiles, so utilization is the product of the fill ratios of the
    three dims against their padded-to-128 sizes.
    """

    def fill(n: int) -> float:
        pad = ((n + 127) // 128) * 128
        return n / pad

    return fill(block_b) * fill(c1) * fill(c2)
