"""L2: the JAX compute graph of Voxel-CIM's numerics, calling the L1 kernels.

Each public function here is a fixed-shape, jit-lowerable computation that
`aot.py` exports to HLO text. The rust coordinator (L3) composes them:

  * `offset_gemm`   — one Spconv3D kernel-offset sub-matrix MAC: the
                      gathered activation batch times that offset's C1 x C2
                      weight slice (Fig. 5b). The coordinator calls this once
                      per offset per batch wave and scatter-adds the psums.
  * `offset_gemm_fused` — K^3 offsets in one call: [K3, B, C1] x
                      [K3, C1, C2] -> [K3, B, C2], the whole-tile MAC wave
                      (all sub-matrices of one layer activated in a cycle).
  * `rpn_conv3x3`   — fused dense 3x3 conv for the RPN (Fig. 5c schedule).
  * `vfe_mean`      — simple/mean VFE reduction.
  * `dequant_relu_quant` — the inter-layer requantization: int32 psum ->
                      scale -> ReLU -> int8, the digital epilogue after the
                      shift-adders.

All shapes are static; the rust side pads batches to the artifact shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import cim_gemm as ck
from .kernels import conv2d as c2
from .kernels import ref


def offset_gemm(acts: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """[B, C1] int8 x [C1, C2] int8 -> [B, C2] int32 via the CIM PE kernel."""
    return ck.cim_gemm(acts, weights)


def offset_gemm_fused(acts: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """All kernel offsets in one wave.

    acts    : [K3, B, C1] int8 (gathered batch per offset)
    weights : [K3, C1, C2] int8 (all sub-matrices of the layer)
    returns : [K3, B, C2] int32
    """
    return jax.vmap(ck.cim_gemm)(acts, weights)


def rpn_conv3x3(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Fused RPN conv block step: int8 NHWC x [3,3,C1,C2] -> int32 NHWC."""
    return c2.conv2d_3x3(x, w)


def vfe_mean(points: jnp.ndarray, counts: jnp.ndarray) -> jnp.ndarray:
    """Mean VFE: [V, P, F] f32 zero-padded points, [V] i32 counts -> [V, F]."""
    return ref.vfe_mean_ref(points, counts)


def dequant_relu_quant(
    psum: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray
) -> jnp.ndarray:
    """Inter-layer epilogue: int32 psum -> f32 scale -> ReLU -> int8.

    psum  : [B, C] int32 accumulated partial sums
    scale : [C] f32 per-channel requant scale
    zero  : [C] f32 per-channel bias (already folded to f32)
    """
    y = psum.astype(jnp.float32) * scale[None, :] + zero[None, :]
    y = jnp.maximum(y, 0.0)
    y = jnp.clip(jnp.round(y), -128.0, 127.0)
    return y.astype(jnp.int8)
