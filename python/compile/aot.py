"""AOT export: lower every L2 entry point to HLO *text* artifacts.

Interchange format is HLO text, NOT `lowered.compile().serialize()` and NOT
a serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction
ids which xla_extension 0.5.1 (the version behind the published `xla` crate)
rejects (`proto.id() <= INT_MAX`). The text parser reassigns ids, so text
round-trips cleanly. See /opt/xla-example/README.md.

Every computation is lowered with `return_tuple=True`; the rust runtime
unwraps with `to_tuple1()`.

Output layout:
    artifacts/<name>.hlo.txt      one per entry point x shape variant
    artifacts/manifest.txt        machine-readable index for rust

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# The universal CIM sub-matrix tile: C1 = C2 = 64 int8 weights. Every layer
# of SECOND / MinkUNet is decomposed by the rust coordinator into these
# tiles (channels padded up to a multiple of 64), mirroring how the paper
# maps C1 x C2 weight slices onto PE-sized regions of the 1024x1024 array.
TILE_C = 64
# Batch variants: small for latency-critical tail waves, large for bulk.
GEMM_BATCHES = (64, 256, 1024)
# Fused-wave variant: all 27 offsets of a subm3 layer in one dispatch.
FUSED_K3 = 27
FUSED_B = 64
# RPN fused conv tile (NHWC), one per-row grid kernel.
RPN_H, RPN_W = 32, 32
# VFE shapes.
VFE_V, VFE_P, VFE_F = 512, 32, 4


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_entries():
    """(name, fn, arg_specs, manifest_kv) for every artifact."""
    entries = []
    for b in GEMM_BATCHES:
        entries.append(
            (
                f"cim_gemm_b{b}",
                lambda a, w: (model.offset_gemm(a, w),),
                (_spec((b, TILE_C), jnp.int8), _spec((TILE_C, TILE_C), jnp.int8)),
                {"kind": "gemm", "b": b, "c1": TILE_C, "c2": TILE_C},
            )
        )
    entries.append(
        (
            f"cim_gemm_fused_k{FUSED_K3}_b{FUSED_B}",
            lambda a, w: (model.offset_gemm_fused(a, w),),
            (
                _spec((FUSED_K3, FUSED_B, TILE_C), jnp.int8),
                _spec((FUSED_K3, TILE_C, TILE_C), jnp.int8),
            ),
            {"kind": "gemm_fused", "k3": FUSED_K3, "b": FUSED_B, "c1": TILE_C, "c2": TILE_C},
        )
    )
    entries.append(
        (
            f"rpn_conv3x3_h{RPN_H}_w{RPN_W}",
            lambda x, w: (model.rpn_conv3x3(x, w),),
            (
                _spec((1, RPN_H, RPN_W, TILE_C), jnp.int8),
                _spec((3, 3, TILE_C, TILE_C), jnp.int8),
            ),
            {"kind": "conv3x3", "h": RPN_H, "w": RPN_W, "c1": TILE_C, "c2": TILE_C},
        )
    )
    for b in (64, 256, 1024):
        entries.append(
            (
                f"epilogue_b{b}",
                lambda p, s, z: (model.dequant_relu_quant(p, s, z),),
                (
                    _spec((b, TILE_C), jnp.int32),
                    _spec((TILE_C,), jnp.float32),
                    _spec((TILE_C,), jnp.float32),
                ),
                {"kind": "epilogue", "b": b, "c": TILE_C},
            )
        )
    entries.append(
        (
            f"vfe_mean_v{VFE_V}",
            lambda p, c: (model.vfe_mean(p, c),),
            (
                _spec((VFE_V, VFE_P, VFE_F), jnp.float32),
                _spec((VFE_V,), jnp.int32),
            ),
            {"kind": "vfe_mean", "v": VFE_V, "p": VFE_P, "f": VFE_F},
        )
    )
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", default=None, help="comma-separated artifact name filter"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    manifest_lines = []
    for name, fn, specs, kv in build_entries():
        if only is not None and name not in only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        kvs = " ".join(f"{k}={v}" for k, v in kv.items())
        manifest_lines.append(f"{name} file={fname} {kvs}")
        print(f"wrote {path} ({len(text)} chars)")

    if only is None:
        with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
            f.write("# name file=<hlo file> kind=<kind> <shape params>\n")
            f.write("\n".join(manifest_lines) + "\n")
        print(f"wrote manifest with {len(manifest_lines)} entries")


if __name__ == "__main__":
    main()
