//! Bench E7/E8 (detection side): the full Table 2 / Fig. 11 detection
//! pipeline — accelerator-model FPS for SECOND plus the host-side
//! end-to-end frame through the pipeline facade with real numerics.

use voxel_cim::bench_util::bench;
use voxel_cim::mapsearch::SearcherKind;
use voxel_cim::model::second;
use voxel_cim::pipeline::{EngineKind, Job, Pipeline, PipelineConfig};
use voxel_cim::pointcloud::voxelize::Voxelizer;
use voxel_cim::sim::accelerator::{Accelerator, SimOptions};
use voxel_cim::sim::baselines::{BASELINES, GPU_DET_FPS};
use voxel_cim::sparse::tensor::SparseTensor;
use voxel_cim::util::rng::Pcg64;

fn main() {
    println!("# e2e_detection — SECOND / KITTI-like (Table 2 Det row, Fig. 11)");
    // The engine layer's configured dataflow (paper default: DOMS).
    let searcher = SearcherKind::Doms.build();
    // Accelerator-model simulation at full resolution.
    let net = second::second();
    let g = Voxelizer::synth_clustered(net.extent, 6.0e-4, 10, 0.35, 31);
    let input = SparseTensor::from_coords(net.extent, g.coords(), 1);
    let acc = Accelerator::default();
    println!("input: {} voxels at {:?}", input.len(), net.extent);
    bench("detection/accel_sim_full", 0, 5, || {
        acc.simulate(&net, &input, searcher.as_ref(), &SimOptions::default())
    });
    let rep = acc.simulate(&net, &input, searcher.as_ref(), &SimOptions::default());
    println!(
        "model: {:.1} fps | {:.2} mJ/frame | paper 106 fps | GPU {:.1} fps | best accel {:.1} fps",
        rep.fps(),
        rep.energy_joules * 1e3,
        GPU_DET_FPS,
        BASELINES.iter().filter_map(|b| b.det_fps).fold(0.0, f64::max),
    );

    // Host-side real-numerics frame at the reduced grid, submitted
    // through the owned-engine facade.
    let small = second::second_small();
    let cfg = PipelineConfig {
        engine: EngineKind::Native,
        ..Default::default()
    };
    let mut pipe = Pipeline::builder()
        .config(cfg)
        .network(small.clone())
        .build()
        .expect("pipeline");
    let gs = Voxelizer::synth_occupancy(small.extent, 2500.0 / small.extent.volume() as f64, 32);
    let mut t = SparseTensor::from_coords(small.extent, gs.coords(), 4);
    let mut rng = Pcg64::new(33);
    for v in t.features.iter_mut() {
        *v = rng.next_i8(0, 12);
    }
    let r = bench("detection/host_frame_native", 0, 3, || {
        pipe.run(Job::Frame(t.clone())).unwrap()
    });
    println!("host frame mean: {:.1} ms (CPU-emulated CIM numerics)", r.mean() * 1e3);
}
