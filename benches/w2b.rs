//! Bench E5/E6: the W2B allocator itself plus the Fig. 10 simulation
//! (MinkUNet with and without W2B).

use voxel_cim::bench_util::bench;
use voxel_cim::cim::w2b::w2b_allocate;
use voxel_cim::experiments::w2b_fig10;
use voxel_cim::util::rng::Pcg64;

fn main() {
    println!("# w2b — allocator and Fig. 10 simulation");
    let mut rng = Pcg64::new(12);
    let skewed: Vec<u64> = (0..27)
        .map(|i| if i == 13 { 40_000 } else { rng.next_below(2_000) })
        .collect();
    bench("w2b/allocate/k27_budget54", 10, 50, || {
        w2b_allocate(&skewed, 54)
    });
    let wide: Vec<u64> = (0..125).map(|_| rng.next_below(100_000)).collect();
    bench("w2b/allocate/k125_budget500", 10, 50, || {
        w2b_allocate(&wide, 500)
    });

    let r = bench("w2b/fig10_full_sim", 0, 3, || w2b_fig10::run_fig10(21));
    let _ = r;
    let res = w2b_fig10::run_fig10(21);
    println!(
        "fig10: {:.1} fps with W2B vs {:.1} fps without -> {:.2}x speedup, {:.1}% energy reduction (paper: 2.3x, 6%)",
        res.with_w2b.fps(),
        res.without_w2b.fps(),
        res.speedup(),
        res.energy_reduction() * 100.0
    );
}
