//! Bench E1-E4: map-search dataflows across the paper's two resolutions
//! (Fig. 2d / Fig. 9 regimes) — wall-clock of the behavioral searchers
//! plus the normalized access volumes they model.

use voxel_cim::bench_util::bench;
use voxel_cim::experiments::{sweep_tensor, HIGH_RES, LOW_RES};
use voxel_cim::mapsearch::{MapSearch, SearcherKind};

fn main() {
    println!("# map_search — Fig. 2(d) / Fig. 9 regimes");
    for (label, extent, s) in [
        ("lowres_s0.005", LOW_RES, 0.005),
        ("highres_s0.005", HIGH_RES, 0.005),
    ] {
        let t = sweep_tensor(extent, s, 42);
        let n = t.len() as u64;
        println!("\n## {label}: N = {n} voxels");
        let r = bench(&format!("map_search/hash_oracle/{label}"), 1, 10, || {
            voxel_cim::sparse::hash_map_search(&t, voxel_cim::sparse::rulebook::ConvKind::subm3())
        });
        r.print_throughput(n, "voxels");
        // Every selectable dataflow through the engine layer's dispatch.
        for kind in SearcherKind::ALL {
            let searcher = kind.build();
            let r = bench(&format!("map_search/{kind}/{label}"), 1, 10, || {
                searcher.search_subm(&t, 3)
            });
            r.print_throughput(n, "voxels");
            let (_, st) = searcher.search_subm(&t, 3);
            println!(
                "        access {:.2}x N | {} sorter passes | table {} B",
                st.normalized(t.len()),
                st.sorter_passes,
                st.table_bytes
            );
        }
    }
}
