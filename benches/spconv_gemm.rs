//! Bench: the compute hot path — sub-matrix GEMM waves through the
//! native engine and (when artifacts exist) the PJRT executables, plus a
//! full subm3 layer execution. This is the L3-side measurement for the
//! §Perf pass in EXPERIMENTS.md.

use voxel_cim::bench_util::{bench, black_box};
use voxel_cim::geom::Extent3;
use voxel_cim::pointcloud::voxelize::Voxelizer;
use voxel_cim::runtime::{Runtime, RuntimeConfig};
use voxel_cim::sparse::rulebook::ConvKind;
use voxel_cim::sparse::{hash_map_search, SparseTensor};
use voxel_cim::spconv::layer::{GemmEngine, LayerWeights, NativeEngine, SpconvLayer};
use voxel_cim::util::rng::Pcg64;

fn main() {
    println!("# spconv_gemm — compute hot path");
    let mut rng = Pcg64::new(9);
    let acts: Vec<i8> = (0..1024 * 64).map(|_| rng.next_i8(-128, 127)).collect();
    let w: Vec<i8> = (0..64 * 64).map(|_| rng.next_i8(-128, 127)).collect();

    let mut native = NativeEngine::default();
    for b in [64usize, 256, 1024] {
        let r = bench(&format!("gemm/native/b{b}"), 2, 10, || {
            native.gemm_i8(&acts[..b * 64], &w, b, 64, 64).unwrap()
        });
        let macs = (b * 64 * 64) as u64;
        r.print_throughput(macs, "MAC");
    }

    match Runtime::load(&RuntimeConfig::discover()) {
        Ok(mut rt) => {
            for b in [64usize, 256, 1024] {
                let r = bench(&format!("gemm/pjrt/b{b}"), 2, 10, || {
                    rt.gemm_i8(&acts[..b * 64], &w, b, 64, 64).unwrap()
                });
                let macs = (b * 64 * 64) as u64;
                r.print_throughput(macs, "MAC");
            }
        }
        Err(e) => println!("(PJRT skipped: {e:#})"),
    }

    // Full subm3 layer at realistic sparsity.
    let e = Extent3::new(176, 200, 10);
    let grid = Voxelizer::synth_occupancy(e, 3000.0 / e.volume() as f64, 10);
    let mut t = SparseTensor::from_coords(e, grid.coords(), 16);
    for v in t.features.iter_mut() {
        *v = rng.next_i8(-8, 8);
    }
    let rb = hash_map_search(&t, ConvKind::subm3());
    println!("\nlayer: {} voxels, {} pairs", t.len(), rb.len());
    let layer = SpconvLayer::new(LayerWeights::random(27, 16, 16, 11), 256);
    let r = bench("spconv_layer/native/subm3_c16", 1, 8, || {
        black_box(layer.execute(&t, &rb, &mut NativeEngine::default()).unwrap())
    });
    r.print_throughput(rb.len() as u64 * 16 * 16, "MAC");
}
