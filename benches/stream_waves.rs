//! Bench: batched multi-frame GEMM waves on the stream path — the
//! engine-layer feature that packs rule pairs from all in-flight frames
//! into shared sub-matrix dispatches. Five sweeps plus a CI smoke mode,
//! all submitted through the pipeline facade (`Pipeline::run(Job::..)`,
//! the engine owned by the pipeline):
//!
//! * **inflight sweep** (1/2/4/8): the latency-SLO trade-off curve — p50
//!   and p95 latency vs throughput as more frames share each wave group,
//!   with per-frame bit-identity asserted against inflight = 1 (the
//!   dispatch delta is what a PJRT engine amortizes).
//! * **shard sweep** (1 / 2x2 / 4x4 grids, W2B 2x): oversized scenes as
//!   block-partitioned pseudo-frames, bit-identity across grids.
//! * **profile sweep**: every scenario profile (urban / highway / indoor
//!   / far-field) served through the prefetching dataset layer.
//! * **serving sweep**: a mixed-profile sequence mux (dense urban scenes
//!   that shard, sparse far-field frames that do not) served through
//!   exclusive vs cross-scene lockstep windows — bit-identity and a
//!   strict dispatch reduction asserted — then the SLO admission
//!   frontier (drop-oldest / defer-sharding / reject-over-depth) over
//!   the attributed-latency p95.
//! * **delta sweep**: an ego-motion drift stream served cold, warm
//!   (map-search rung), and warm with compute reuse — per-frame
//!   bit-identity asserted, cold-vs-warm p50/p95 and blocks-searched
//!   vs frame index printed with the stream's reuse ratio — then a
//!   feature-stable coherent stream where the compute rung actually
//!   splices psums: gather rows saved, waves skipped, and a strict
//!   GEMM-dispatch reduction asserted.
//!
//! ```sh
//! cargo bench --bench stream_waves             # full sweeps
//! cargo bench --bench stream_waves -- --smoke  # CI: one tick over the
//!                                              # checked-in KITTI fixture
//!                                              # + serving + warm-cache
//!                                              # + compute-reuse ticks
//! cargo bench --bench stream_waves -- --json BENCH_stream_waves.json
//!     # machine-readable sweep points (fps, p50/p95, dispatches, the
//!     # reuse/skip counters, and per-stage span p50/p95); composes
//!     # with --smoke
//! cargo bench --bench stream_waves -- --smoke --trace-out BENCH_trace.json
//!     # also export the warm delta tick's stage spans as Chrome
//!     # trace-event JSON (loads in Perfetto / chrome://tracing)
//! cargo bench --bench stream_waves -- --smoke --metrics-out BENCH_metrics.json
//!     # also export the warm delta tick's metrics-registry snapshot
//!     # (cost.* counters, per-wave occupancy, per-stage histograms)
//! ```

use voxel_cim::bench_util::bench;
use voxel_cim::coordinator::scheduler::RunnerConfig;
use voxel_cim::coordinator::shard::ShardConfig;
use voxel_cim::coordinator::stream::StreamReport;
use voxel_cim::dataset::{
    ClosureSource, DatasetConfig, FrameSource, KittiSource, PrefetchSource, ProfileSource,
    ScenarioProfile,
};
use voxel_cim::geom::Extent3;
use voxel_cim::mapsearch::DeltaConfig;
use voxel_cim::model::layer::{LayerSpec, NetworkSpec, TaskKind};
use voxel_cim::pipeline::{Job, Pipeline, PipelineConfig};
use voxel_cim::pointcloud::voxelize::Voxelizer;
use voxel_cim::serving::{
    AdmissionConfig, AdmissionPolicy, MuxPolicy, SequenceMux, ServingConfig, WindowPolicy,
};
use voxel_cim::sparse::tensor::SparseTensor;
use voxel_cim::spconv::layer::NativeEngine;
use voxel_cim::util::json::Json;

use std::sync::atomic::{AtomicBool, Ordering};

/// Whether the bench pipelines record stage spans: set in `main` when
/// `--json` or `--trace-out` is given, so the machine-readable report
/// carries per-stage p50/p95 and the trace export has spans to write.
/// Span recording stays off the measured `bench(..)` timing loops'
/// critical claims — the sweeps compare configurations under the *same*
/// recording mode.
static TRACE: AtomicBool = AtomicBool::new(false);

fn net() -> NetworkSpec {
    NetworkSpec {
        name: "stream-bench",
        task: TaskKind::Segmentation,
        extent: Extent3::new(64, 64, 12),
        vfe_channels: 8,
        layers: vec![
            LayerSpec::Subm3 { c_in: 8, c_out: 16 },
            LayerSpec::Subm3 { c_in: 16, c_out: 16 },
            LayerSpec::GConv2 { c_in: 16, c_out: 32 },
            LayerSpec::Subm3 { c_in: 32, c_out: 32 },
        ],
    }
}

fn make_frame(id: u64) -> SparseTensor {
    let e = Extent3::new(64, 64, 12);
    let g = Voxelizer::synth_clustered(e, 0.02, 6, 0.35, 500 + id);
    let mut t = SparseTensor::from_coords(e, g.coords(), 8);
    for (i, v) in t.features.iter_mut().enumerate() {
        *v = ((i as u64 + 3 * id) % 11) as i8;
    }
    t
}

/// One facade per measured serve: the owned `NativeEngine`'s dispatch
/// counter then measures exactly that stream (`pipe.dispatches()`).
fn mk_pipe(net: NetworkSpec, runner: RunnerConfig, serving: ServingConfig, frames: u64) -> Pipeline {
    let mut cfg = PipelineConfig {
        runner,
        serving,
        dataset: DatasetConfig {
            frames,
            ..Default::default()
        },
        ..Default::default()
    };
    cfg.observability.trace = TRACE.load(Ordering::Relaxed);
    // Cost accounting rides the same switch: the JSON report's cost
    // fields come from the pure `cost_summary()` either way, but the
    // metrics snapshot / trace export only carry cost.* counters and
    // counter tracks when the ledger records live.
    cfg.observability.cost = TRACE.load(Ordering::Relaxed);
    Pipeline::builder()
        .config(cfg)
        .network(net)
        .engine(NativeEngine::default())
        .build()
        .expect("bench pipeline")
}

/// The old `serve_closure` producer/consumer split as a stream job: a
/// prefetch thread over a closure source, buffer depth `depth`.
fn prefetched_job<P>(producer: P, depth: usize) -> Job
where
    P: Fn(u64) -> SparseTensor + Send + 'static,
{
    Job::stream(PrefetchSource::spawn(
        Box::new(ClosureSource::new(producer)),
        depth,
    ))
}

/// The shared p50/p95 line every sweep prints (`util::stats::LatencySummary`).
fn latency_line(report: &StreamReport) -> String {
    report
        .latency_summary()
        .map(|s| s.format_ms())
        .unwrap_or_else(|| "no completions".into())
}

/// One sweep point of the machine-readable report (`--json <path>`):
/// throughput, the latency distribution, the engine dispatch count,
/// every delta-reuse counter the stream report carries, and (when span
/// recording is on) per-stage latency summaries.
struct JsonPoint {
    sweep: String,
    label: String,
    fps: f64,
    p50_ms: f64,
    p95_ms: f64,
    dispatches: u64,
    blocks_searched: u64,
    blocks_reused: u64,
    voxels_rebinned: u64,
    waves_skipped: u64,
    rows_gathered_saved: u64,
    /// Modeled cost of the point (`StreamReport::cost_summary`, the
    /// calibrated-constant ledger): DRAM/buffer traffic, energy, MACs,
    /// effective efficiency, and the Fig. 2d/9 normalized access volume.
    cost_dram_bytes: u64,
    cost_buffer_bytes: u64,
    cost_energy_uj: f64,
    cost_macs: u64,
    cost_tops_per_watt: f64,
    cost_normalized_access: f64,
    /// Per-stage `(name, p50 ms, p95 ms)` from `StreamReport::stage_summary`
    /// — empty when span recording is off.
    stages: Vec<(String, f64, f64)>,
}

impl JsonPoint {
    fn of(sweep: &str, label: &str, report: &StreamReport, dispatches: u64) -> Self {
        let (p50_ms, p95_ms) = report
            .latency_summary()
            .map(|s| (s.p50 * 1e3, s.p95 * 1e3))
            .unwrap_or((0.0, 0.0));
        let cost = report.cost_summary();
        Self {
            sweep: sweep.into(),
            label: label.into(),
            fps: report.throughput_fps(),
            p50_ms,
            p95_ms,
            dispatches,
            blocks_searched: report.blocks_searched,
            blocks_reused: report.blocks_reused,
            voxels_rebinned: report.voxels_rebinned,
            waves_skipped: report.waves_skipped,
            rows_gathered_saved: report.rows_gathered_saved,
            cost_dram_bytes: cost.dram_bytes,
            cost_buffer_bytes: cost.buffer_bytes,
            cost_energy_uj: cost.joules * 1e6,
            cost_macs: cost.macs,
            cost_tops_per_watt: cost.tops_per_watt,
            cost_normalized_access: cost.normalized_access,
            stages: report
                .stage_summary()
                .iter()
                .map(|(name, s)| (name.to_string(), s.p50 * 1e3, s.p95 * 1e3))
                .collect(),
        }
    }

    fn json(&self) -> Json {
        let mut obj: Vec<(String, Json)> = vec![
            ("sweep".into(), Json::str(&self.sweep)),
            ("label".into(), Json::str(&self.label)),
            ("fps".into(), Json::Num(self.fps)),
            ("p50_ms".into(), Json::Num(self.p50_ms)),
            ("p95_ms".into(), Json::Num(self.p95_ms)),
            ("dispatches".into(), Json::UInt(self.dispatches)),
            ("blocks_searched".into(), Json::UInt(self.blocks_searched)),
            ("blocks_reused".into(), Json::UInt(self.blocks_reused)),
            ("voxels_rebinned".into(), Json::UInt(self.voxels_rebinned)),
            ("waves_skipped".into(), Json::UInt(self.waves_skipped)),
            (
                "rows_gathered_saved".into(),
                Json::UInt(self.rows_gathered_saved),
            ),
            ("cost_dram_bytes".into(), Json::UInt(self.cost_dram_bytes)),
            (
                "cost_buffer_bytes".into(),
                Json::UInt(self.cost_buffer_bytes),
            ),
            ("cost_energy_uj".into(), Json::Num(self.cost_energy_uj)),
            ("cost_macs".into(), Json::UInt(self.cost_macs)),
            (
                "cost_tops_per_watt".into(),
                Json::Num(self.cost_tops_per_watt),
            ),
            (
                "cost_normalized_access".into(),
                Json::Num(self.cost_normalized_access),
            ),
        ];
        if !self.stages.is_empty() {
            obj.push((
                "stages".into(),
                Json::Obj(
                    self.stages
                        .iter()
                        .map(|(name, p50, p95)| {
                            (
                                name.clone(),
                                Json::obj(vec![
                                    ("p50_ms", Json::Num(*p50)),
                                    ("p95_ms", Json::Num(*p95)),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ));
        }
        Json::Obj(obj)
    }
}

/// `--json <path>`; a bare `--json` falls back to the CI convention,
/// `BENCH_stream_waves.json` in the working directory.
fn json_path() -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter().position(|a| a == "--json").map(|i| {
        args.get(i + 1)
            .filter(|p| !p.starts_with("--"))
            .cloned()
            .unwrap_or_else(|| "BENCH_stream_waves.json".into())
    })
}

/// `--trace-out <path>`; a bare `--trace-out` falls back to the CI
/// convention, `BENCH_trace.json` in the working directory.
fn trace_out_path() -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter().position(|a| a == "--trace-out").map(|i| {
        args.get(i + 1)
            .filter(|p| !p.starts_with("--"))
            .cloned()
            .unwrap_or_else(|| "BENCH_trace.json".into())
    })
}

/// `--metrics-out <path>`; a bare `--metrics-out` falls back to the CI
/// convention, `BENCH_metrics.json` in the working directory.
fn metrics_out_path() -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter().position(|a| a == "--metrics-out").map(|i| {
        args.get(i + 1)
            .filter(|p| !p.starts_with("--"))
            .cloned()
            .unwrap_or_else(|| "BENCH_metrics.json".into())
    })
}

/// Lint counts for the report's metadata block: a bench artifact also
/// records the invariant health of the tree it was built from (the CI
/// smoke gate asserts `unsuppressed == 0`).
fn lint_metadata() -> Json {
    let src = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    match vcim_lint::lint_tree(&src) {
        Ok(report) => {
            let rules: Vec<(String, Json)> = report
                .rule_counts()
                .into_iter()
                .map(|(rule, (total, unsup))| {
                    let counts = Json::obj(vec![
                        ("total", Json::UInt(total as u64)),
                        ("unsuppressed", Json::UInt(unsup as u64)),
                    ]);
                    (rule, counts)
                })
                .collect();
            Json::obj(vec![
                ("files", Json::UInt(report.files as u64)),
                ("total", Json::UInt(report.total() as u64)),
                ("unsuppressed", Json::UInt(report.unsuppressed() as u64)),
                ("suppressed", Json::UInt(report.suppressed() as u64)),
                ("rules", Json::Obj(rules)),
            ])
        }
        Err(e) => Json::obj(vec![("error", Json::str(&e.to_string()))]),
    }
}

fn write_json(path: &str, points: &[JsonPoint]) {
    let doc = Json::obj(vec![
        ("bench", Json::str("stream_waves")),
        ("metadata", Json::obj(vec![("lint", lint_metadata())])),
        ("points", Json::arr(points.iter().map(JsonPoint::json).collect())),
    ]);
    std::fs::write(path, doc.render()).expect("write --json report");
    println!("wrote {path} ({} sweep points)", points.len());
}

/// Export the recorded spans of `pipe` when `--trace-out` was given,
/// and the metrics-registry snapshot (cost.* counters, per-wave
/// occupancy, per-stage histograms) when `--metrics-out` was given.
fn maybe_write_trace(pipe: &Pipeline) {
    if let Some(path) = trace_out_path() {
        pipe.observer()
            .write_chrome_trace(std::path::Path::new(&path))
            .expect("write --trace-out");
        println!("trace written to {path} (load in Perfetto / chrome://tracing)");
    }
    if let Some(path) = metrics_out_path() {
        pipe.observer()
            .write_metrics_json(std::path::Path::new(&path))
            .expect("write --metrics-out");
        println!("metrics snapshot written to {path}");
    }
}

fn main() {
    let json = json_path();
    // Record stage spans whenever a machine-readable artifact is being
    // produced: the JSON report then carries per-stage p50/p95, and the
    // Chrome trace export has spans to write.
    if json.is_some() || trace_out_path().is_some() || metrics_out_path().is_some() {
        TRACE.store(true, Ordering::Relaxed);
    }
    let mut points: Vec<JsonPoint> = Vec::new();
    if std::env::args().any(|a| a == "--smoke") {
        smoke(&mut points);
        if let Some(path) = &json {
            write_json(path, &points);
        }
        return;
    }
    println!("# stream_waves — multi-frame GEMM wave batching");
    const FRAMES: u64 = 8;

    // Inflight sweep: the p50/p95-vs-throughput curve of wave batching
    // (ROADMAP's latency-SLO follow-on).
    let mut reports = Vec::new();
    for inflight in [1usize, 2, 4, 8] {
        let cfg = RunnerConfig {
            inflight,
            // Serial compute so the owned NativeEngine's counter sees
            // every GEMM (forked pool engines keep their own counters).
            compute_workers: 1,
            ..Default::default()
        };
        let mut timed = mk_pipe(net(), cfg, ServingConfig::default(), FRAMES);
        let r = bench(&format!("stream/serve8/inflight{inflight}"), 0, 3, || {
            timed
                .run(prefetched_job(make_frame, FRAMES as usize))
                .unwrap()
        });
        let mut counted = mk_pipe(net(), cfg, ServingConfig::default(), FRAMES);
        let report = counted
            .run(prefetched_job(make_frame, FRAMES as usize))
            .unwrap()
            .into_stream()
            .unwrap();
        let calls = counted.dispatches();
        println!(
            "inflight {inflight}: {:.2} fps | {} | {} engine dispatches | mean {:.1} ms",
            report.throughput_fps(),
            latency_line(&report),
            calls,
            r.mean() * 1e3,
        );
        points.push(JsonPoint::of(
            "inflight",
            &format!("inflight{inflight}"),
            &report,
            calls,
        ));
        reports.push((inflight, calls, report));
    }

    // Bit-identity across wave packing: every inflight level's per-frame
    // checksums match the frame-at-a-time baseline.
    let (_, solo_calls, solo) = &reports[0];
    for (inflight, calls, packed) in &reports[1..] {
        for (a, b) in solo.completions.iter().zip(&packed.completions) {
            assert_eq!(a.id, b.id);
            assert_eq!(
                a.result.checksum, b.result.checksum,
                "frame {} diverged at inflight {inflight}",
                a.id
            );
        }
        println!(
            "inflight {inflight}: bit-identical; {} dispatches vs {} frame-at-a-time",
            calls, solo_calls
        );
    }

    shard_sweep(&mut points);
    profile_sweep(&mut points);
    serving_sweep(&mut points);
    delta_sweep(&mut points);
    if let Some(path) = &json {
        write_json(path, &points);
    }
}

/// Shard-count sweep: one oversized scene per frame, served at 1 / 2x2 /
/// 4x4 block-shard grids — the latency-vs-throughput curve of the shard
/// scheduler, with bit-identity asserted across every grid.
fn shard_sweep(points: &mut Vec<JsonPoint>) {
    const FRAMES: u64 = 3;
    let extent = Extent3::new(192, 192, 10);
    let net = NetworkSpec {
        name: "shard-bench",
        task: TaskKind::Segmentation,
        extent,
        vfe_channels: 8,
        layers: vec![
            LayerSpec::Subm3 { c_in: 8, c_out: 16 },
            LayerSpec::Subm3 { c_in: 16, c_out: 16 },
            LayerSpec::GConv2 { c_in: 16, c_out: 32 },
            LayerSpec::Subm3 { c_in: 32, c_out: 32 },
        ],
    };
    let make_big = move |id: u64| {
        let g = Voxelizer::synth_clustered(extent, 0.012, 10, 0.3, 7000 + id);
        let mut t = SparseTensor::from_coords(extent, g.coords(), 8);
        for (i, v) in t.features.iter_mut().enumerate() {
            *v = ((i as u64 + 7 * id) % 13) as i8;
        }
        t
    };

    println!("\n# shard sweep — block-partitioned pseudo-frames (w2b 2x)");
    let mut baseline: Option<StreamReport> = None;
    for (bx, by) in [(1usize, 1usize), (2, 2), (4, 4)] {
        let cfg = RunnerConfig {
            shard: ShardConfig::grid(bx, by).unwrap(),
            w2b_factor: 2,
            compute_workers: 1,
            ..Default::default()
        };
        let mut pipe = mk_pipe(net.clone(), cfg, ServingConfig::default(), FRAMES);
        let report = pipe
            .run(prefetched_job(make_big, 4))
            .unwrap()
            .into_stream()
            .unwrap();
        let shards: u32 = report.completions.iter().map(|c| c.result.shards).sum();
        println!(
            "shards {bx}x{by}: {:.2} fps | {} | {} pseudo-frames | {} dispatches",
            report.throughput_fps(),
            latency_line(&report),
            shards,
            pipe.dispatches(),
        );
        points.push(JsonPoint::of(
            "shard",
            &format!("{bx}x{by}"),
            &report,
            pipe.dispatches(),
        ));
        match &baseline {
            None => baseline = Some(report),
            Some(base) => {
                for (a, b) in base.completions.iter().zip(&report.completions) {
                    assert_eq!(
                        a.result.checksum, b.result.checksum,
                        "frame {} diverged under {bx}x{by} sharding",
                        a.id
                    );
                }
            }
        }
    }
    println!("shard grids bit-identical across the sweep");
}

/// Scenario-profile sweep: workload diversity through the prefetching
/// dataset layer — same engine config, four density shapes.
fn profile_sweep(points: &mut Vec<JsonPoint>) {
    const FRAMES: u64 = 6;
    let extent = Extent3::new(64, 64, 12);
    println!("\n# profile sweep — dataset ingestion (prefetch depth 2, inflight 2)");
    for profile in ScenarioProfile::ALL {
        let cfg = RunnerConfig {
            inflight: 2,
            compute_workers: 1,
            ..Default::default()
        };
        let mut pipe = mk_pipe(net(), cfg, ServingConfig::default(), FRAMES);
        let inner = ProfileSource::new(profile, extent, 0.02, 0xA11).with_channels(8);
        let source = PrefetchSource::spawn(Box::new(inner), 2);
        let report = pipe
            .run(Job::stream(source))
            .unwrap()
            .into_stream()
            .unwrap();
        let voxels: u64 = report.completions.iter().map(|c| c.result.out_voxels).sum();
        println!(
            "{:<10} {:.2} fps | {} | {} out voxels | {} dispatches",
            profile.key(),
            report.throughput_fps(),
            latency_line(&report),
            voxels,
            pipe.dispatches(),
        );
        assert_eq!(report.completions.len(), FRAMES as usize, "{profile}");
        points.push(JsonPoint::of("profile", profile.key(), &report, pipe.dispatches()));
    }
}

/// The serving sweep's mixed-profile mux: a dense urban sequence whose
/// scenes shard on the 2x2 grid next to a sparse far-field sequence that
/// never does. Synchronous (unprefetched) sources so the two window
/// policies see the identical frame stream.
fn mixed_mux(extent: Extent3) -> SequenceMux {
    SequenceMux::new(
        vec![
            Box::new(
                ProfileSource::new(ScenarioProfile::Urban, extent, 0.03, 0x5E1)
                    .with_channels(8),
            ),
            Box::new(
                ProfileSource::new(ScenarioProfile::FarField, extent, 0.008, 0x5E2)
                    .with_channels(8),
            ),
        ],
        MuxPolicy::RoundRobin,
    )
    .expect("two sequences")
}

fn serving_cfg(extent: Extent3) -> RunnerConfig {
    // Urban frames at sparsity 0.03 carry ~3x the far-field voxel count:
    // the threshold splits exactly the urban scenes.
    let threshold = (extent.volume() as f64 * 0.018) as usize;
    RunnerConfig {
        shard: ShardConfig {
            auto_threshold: threshold,
            ..ShardConfig::grid(2, 2).unwrap()
        },
        inflight: 6,
        compute_workers: 1,
        // One wave per non-empty offset per window: the dispatch counter
        // then directly measures window packing, not batch remainders.
        batch: 4096,
        ..Default::default()
    }
}

/// The serving sweep's `[serving]` view: an explicit window policy plus
/// (optionally) an admission config.
fn serving_with(window: WindowPolicy, admission: AdmissionConfig) -> ServingConfig {
    ServingConfig {
        window: Some(window),
        admission,
        ..Default::default()
    }
}

/// Serving sweep: cross-scene lockstep windows + SLO admission over a
/// mixed-profile sequence mux — the p95-vs-throughput frontier against
/// the exclusive-window baseline.
fn serving_sweep(points: &mut Vec<JsonPoint>) {
    const FRAMES: u64 = 8;
    let extent = Extent3::new(64, 64, 12);
    println!("\n# serving sweep — mixed-profile mux (urban shards next to far-field)");

    // Window-policy comparison at equal frame count: bit-identity and a
    // strict engine-dispatch reduction (the acceptance criterion).
    let mut reports: Vec<(WindowPolicy, u64, StreamReport)> = Vec::new();
    for window in [WindowPolicy::Exclusive, WindowPolicy::CrossScene] {
        let mut pipe = mk_pipe(
            net(),
            serving_cfg(extent),
            serving_with(window, AdmissionConfig::default()),
            FRAMES,
        );
        let report = pipe
            .run(Job::stream(mixed_mux(extent)))
            .unwrap()
            .into_stream()
            .unwrap();
        assert_eq!(report.completions.len(), FRAMES as usize, "{window}");
        let att = report
            .attributed_summary()
            .map(|s| s.format_ms())
            .unwrap_or_default();
        println!(
            "window {:<11} {:.2} fps | {} | own {} | {} windows | {} dispatches",
            window.key(),
            report.throughput_fps(),
            latency_line(&report),
            att,
            report.windows,
            pipe.dispatches(),
        );
        points.push(JsonPoint::of("window", window.key(), &report, pipe.dispatches()));
        reports.push((window, pipe.dispatches(), report));
    }
    let (_, excl_calls, excl) = &reports[0];
    let (_, cross_calls, cross) = &reports[1];
    for (a, b) in excl.completions.iter().zip(&cross.completions) {
        assert_eq!((a.sequence, a.id), (b.sequence, b.id));
        assert_eq!(
            a.result.checksum, b.result.checksum,
            "seq {} frame {} diverged between window policies",
            a.sequence, a.id
        );
    }
    assert!(
        excl.completions.iter().any(|c| c.result.shards > 1),
        "urban scenes should shard in the mixed mux"
    );
    assert!(
        cross_calls < excl_calls,
        "cross-scene windows must dispatch strictly less at equal frames: \
         {cross_calls} vs {excl_calls}"
    );
    println!(
        "cross-scene bit-identical to exclusive; dispatches {cross_calls} vs \
         {excl_calls} ({} vs {} windows)",
        cross.windows, excl.windows
    );

    // Admission frontier: the SLO target set inside the measured band so
    // the policies actually engage; goodput vs attributed p95 per policy.
    // More frames than the effective queue depth (2 x inflight = 12) —
    // with a shallower stream every frame is admitted before the first
    // completion feeds the estimator and drop/reject never fire.
    const ADM_FRAMES: u64 = 16;
    let slo_ms = cross
        .attributed_summary()
        .map(|s| s.p95 * 1e3 * 0.6)
        .unwrap_or(1.0);
    println!("admission frontier @ slo {slo_ms:.2} ms (0.6x the cross-scene p95):");
    for policy in [
        AdmissionPolicy::None,
        AdmissionPolicy::DropOldest,
        AdmissionPolicy::DeferSharding,
        AdmissionPolicy::RejectOverDepth,
    ] {
        let mut pipe = mk_pipe(
            net(),
            serving_cfg(extent),
            serving_with(
                WindowPolicy::CrossScene,
                AdmissionConfig {
                    policy,
                    slo_ms,
                    ..Default::default()
                },
            ),
            ADM_FRAMES,
        );
        let report = pipe
            .run(Job::stream(mixed_mux(extent)))
            .unwrap()
            .into_stream()
            .unwrap();
        let adm = report.admission;
        let att = report
            .attributed_summary()
            .map(|s| s.format_ms())
            .unwrap_or_else(|| "-".into());
        println!(
            "{:<18} {} served | own {} | {:.2} fps | {} dropped | {} rejected | \
             {} deferrals",
            policy.key(),
            report.completions.len(),
            att,
            report.throughput_fps(),
            adm.dropped,
            adm.rejected,
            adm.deferred,
        );
        points.push(JsonPoint::of("admission", policy.key(), &report, pipe.dispatches()));
        // Shedding policies lose frames only to their counters; deferral
        // serves everything. Every pulled frame is served or accounted.
        assert_eq!(
            report.completions.len() as u64 + adm.dropped + adm.rejected,
            ADM_FRAMES,
            "{policy}: completions + shed must cover every pulled frame"
        );
    }
}

/// Delta sweep: the temporal delta cache over an ego-motion drift
/// stream — the same frames served cold, warm (map-search rung), and
/// warm with the compute rung stacked on top — with per-frame
/// bit-identity asserted, the latency distributions printed, and the
/// warm run's blocks-searched curve traced against the frame index.
/// Drift profiles re-randomize per-voxel features every frame, so the
/// compute rung must stay bit-identical there while splicing nothing;
/// a final feature-stable coherent stream shows the rung actually
/// saving gather rows, skipping waves, and dispatching strictly fewer
/// GEMMs.
fn delta_sweep(points: &mut Vec<JsonPoint>) {
    const FRAMES: u64 = 8;
    let extent = Extent3::new(64, 64, 12);
    println!("\n# delta sweep — temporal delta cache over an ego-motion stream");
    let source = || {
        let inner = ProfileSource::new(ScenarioProfile::Urban, extent, 0.02, 0xDE17A)
            .with_drift(1.0)
            .with_channels(8);
        PrefetchSource::spawn(Box::new(inner), 2)
    };
    let mut reports = Vec::new();
    for (label, enabled, compute) in
        [("off", false, false), ("map", true, false), ("map+compute", true, true)]
    {
        let cfg = RunnerConfig {
            // One frame per window so every warm frame plans against its
            // predecessor's committed cache entry.
            inflight: 1,
            compute_workers: 1,
            delta: DeltaConfig {
                enabled,
                compute,
                blocks_x: 16,
                blocks_y: 16,
                ..DeltaConfig::default()
            },
            ..Default::default()
        };
        let mut pipe = mk_pipe(net(), cfg, ServingConfig::default(), FRAMES);
        let report = pipe
            .run(Job::stream(source()))
            .unwrap()
            .into_stream()
            .unwrap();
        assert_eq!(report.completions.len(), FRAMES as usize);
        println!(
            "delta {:<11} {:.2} fps | {} | {} searched | {} reused ({:.1}% reuse) | \
             {} rows saved | {} waves skipped | {} dispatches",
            label,
            report.throughput_fps(),
            latency_line(&report),
            report.blocks_searched,
            report.blocks_reused,
            report.reuse_ratio() * 100.0,
            report.rows_gathered_saved,
            report.waves_skipped,
            pipe.dispatches(),
        );
        points.push(JsonPoint::of("delta", label, &report, pipe.dispatches()));
        if label == "map" {
            // The warm drift stream is the interesting trace: cold frame
            // 0 map-searches everything, warm frames only dirty blocks —
            // visibly shorter map_search spans in Perfetto.
            maybe_write_trace(&pipe);
        }
        reports.push(report);
    }
    let cold = &reports[0];
    for warm in &reports[1..] {
        for (a, b) in cold.completions.iter().zip(&warm.completions) {
            assert_eq!(a.id, b.id);
            assert_eq!(
                a.result.checksum, b.result.checksum,
                "frame {} diverged with the delta cache on",
                a.id
            );
        }
        assert!(
            warm.blocks_reused > 0,
            "the ego-motion stream must reuse blocks once warm"
        );
    }
    for c in &reports[1].completions {
        println!(
            "frame {}: {} blocks searched | {} reused",
            c.id, c.result.blocks_searched, c.result.blocks_reused
        );
    }
    println!(
        "delta sweep bit-identical; stream reuse {:.1}%",
        reports[1].reuse_ratio() * 100.0
    );

    // Compute-rung point: a feature-stable coherent stream (the same
    // scene every frame — the regime where psums are reusable at all).
    println!("\n# delta sweep — compute reuse on a feature-stable coherent stream");
    let coherent = make_frame(9);
    let mut pair = Vec::new();
    for on in [false, true] {
        let cfg = RunnerConfig {
            inflight: 1,
            compute_workers: 1,
            delta: DeltaConfig {
                enabled: on,
                compute: on,
                blocks_x: 16,
                blocks_y: 16,
                ..DeltaConfig::default()
            },
            ..Default::default()
        };
        let mut pipe = mk_pipe(net(), cfg, ServingConfig::default(), FRAMES);
        let t = coherent.clone();
        let report = pipe
            .run(Job::stream(ClosureSource::new(move |_| t.clone())))
            .unwrap()
            .into_stream()
            .unwrap();
        assert_eq!(report.completions.len(), FRAMES as usize);
        println!(
            "compute {:<4} {:.2} fps | {} | {} rows saved | {} waves skipped | \
             {} dispatches",
            if on { "on" } else { "off" },
            report.throughput_fps(),
            latency_line(&report),
            report.rows_gathered_saved,
            report.waves_skipped,
            pipe.dispatches(),
        );
        points.push(JsonPoint::of(
            "delta-compute",
            if on { "warm" } else { "cold" },
            &report,
            pipe.dispatches(),
        ));
        pair.push((pipe.dispatches(), report));
    }
    let (cold_calls, cold) = &pair[0];
    let (warm_calls, warm) = &pair[1];
    for (a, b) in cold.completions.iter().zip(&warm.completions) {
        assert_eq!(
            a.result.checksum, b.result.checksum,
            "frame {} diverged with compute reuse",
            a.id
        );
    }
    assert!(warm.rows_gathered_saved > 0, "coherent stream must splice psums");
    assert!(warm.waves_skipped > 0, "full splices must drop whole waves");
    assert!(
        warm_calls < cold_calls,
        "compute reuse must dispatch strictly fewer GEMMs ({warm_calls} vs {cold_calls})"
    );
    println!(
        "compute reuse bit-identical; dispatches {warm_calls} vs {cold_calls}, \
         {} rows saved, {} waves skipped",
        warm.rows_gathered_saved, warm.waves_skipped
    );
}

/// CI smoke: one serving tick over the checked-in KITTI fixture — the
/// on-disk reader → voxelizer → stream-server path end to end — plus a
/// mixed-profile serving tick exercising the sequence mux and the
/// cross-scene window packer, a warm-cache tick asserting the temporal
/// delta cache reuses blocks without changing a single bit, and a
/// compute-reuse tick asserting a warm coherent frame issues strictly
/// fewer GEMM dispatches than cold. A few hundred milliseconds total.
fn smoke(points: &mut Vec<JsonPoint>) {
    println!("# stream_waves --smoke — KITTI fixture, one tick");
    let fixture = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/kitti");
    let extent = Extent3::new(16, 16, 8);
    let vx = Voxelizer::new((16.0, 16.0, 8.0), extent, 8);
    let source = KittiSource::open(fixture, vx).expect("fixture dir");
    let net = NetworkSpec {
        name: "smoke",
        task: TaskKind::Segmentation,
        extent,
        vfe_channels: 4,
        layers: vec![
            LayerSpec::Subm3 { c_in: 4, c_out: 8 },
            LayerSpec::Subm3 { c_in: 8, c_out: 8 },
        ],
    };
    let mut pipe = mk_pipe(
        net.clone(),
        RunnerConfig {
            inflight: 2,
            compute_workers: 1,
            ..Default::default()
        },
        ServingConfig::default(),
        8,
    );
    let report = pipe
        .run(Job::stream(source))
        .unwrap()
        .into_stream()
        .unwrap();
    assert_eq!(report.completions.len(), 2, "fixture holds two frames");
    for c in &report.completions {
        assert!(c.result.out_voxels > 0, "frame {}", c.id);
        println!(
            "frame {}: {} out voxels | checksum {:#018x}",
            c.id, c.result.out_voxels, c.result.checksum
        );
    }
    println!("smoke ok: {} frames served", report.completions.len());
    points.push(JsonPoint::of("smoke", "kitti", &report, pipe.dispatches()));
    serving_smoke(net.clone(), points);
    delta_smoke(net, points);
}

/// The serving-scheduler smoke: a two-sequence mux served through
/// exclusive and cross-scene windows with sharding forced on — per-frame
/// bit-identity and a strict dispatch reduction asserted on every push.
fn serving_smoke(net: NetworkSpec, points: &mut Vec<JsonPoint>) {
    println!("\n# --smoke serving tick — mixed-profile mux, 2x2 shards");
    let extent = net.extent;
    let cfg = RunnerConfig {
        shard: ShardConfig {
            auto_threshold: 1,
            ..ShardConfig::grid(2, 2).unwrap()
        },
        inflight: 8,
        compute_workers: 1,
        ..Default::default()
    };
    let mux = || {
        SequenceMux::new(
            vec![
                Box::new(
                    ProfileSource::new(ScenarioProfile::Urban, extent, 0.05, 0x51)
                        .with_frames(2),
                ) as Box<dyn FrameSource>,
                Box::new(
                    ProfileSource::new(ScenarioProfile::Highway, extent, 0.05, 0x52)
                        .with_frames(2),
                ),
            ],
            MuxPolicy::RoundRobin,
        )
        .expect("two sequences")
    };
    let mut results = Vec::new();
    for window in [WindowPolicy::Exclusive, WindowPolicy::CrossScene] {
        let mut pipe = mk_pipe(
            net.clone(),
            cfg,
            serving_with(window, AdmissionConfig::default()),
            4,
        );
        let report = pipe
            .run(Job::stream(mux()))
            .unwrap()
            .into_stream()
            .unwrap();
        assert_eq!(report.completions.len(), 4, "{window}");
        println!(
            "window {:<11} {} windows | {} dispatches | {}",
            window.key(),
            report.windows,
            pipe.dispatches(),
            latency_line(&report),
        );
        points.push(JsonPoint::of(
            "smoke-serving",
            window.key(),
            &report,
            pipe.dispatches(),
        ));
        results.push((pipe.dispatches(), report));
    }
    let (excl_calls, excl) = &results[0];
    let (cross_calls, cross) = &results[1];
    for (a, b) in excl.completions.iter().zip(&cross.completions) {
        assert_eq!((a.sequence, a.id), (b.sequence, b.id));
        assert_eq!(
            a.result.checksum, b.result.checksum,
            "seq {} frame {} diverged in the serving smoke",
            a.sequence, a.id
        );
    }
    assert!(
        cross_calls < excl_calls,
        "serving smoke: cross-scene must dispatch strictly less \
         ({cross_calls} vs {excl_calls})"
    );
    println!("serving smoke ok: bit-identical, {cross_calls} vs {excl_calls} dispatches");
}

/// The warm-cache smoke: a short ego-motion drift stream served cold and
/// warm — per-frame checksum equality against the cold pass plus a
/// nonzero reuse ratio asserted on every push — followed by the
/// compute-reuse tick: a feature-stable coherent stream where the warm
/// pass must save gather rows, skip waves, and issue strictly fewer
/// GEMM dispatches than the cold pass, bit-identically.
fn delta_smoke(net: NetworkSpec, points: &mut Vec<JsonPoint>) {
    println!("\n# --smoke delta tick — warm temporal cache vs cold, drift stream");
    let extent = net.extent;
    let source = || {
        ProfileSource::new(ScenarioProfile::Urban, extent, 0.08, 0xD3)
            .with_drift(1.0)
            .with_frames(4)
    };
    let mut reports = Vec::new();
    for enabled in [false, true] {
        let cfg = RunnerConfig {
            inflight: 1,
            compute_workers: 1,
            delta: DeltaConfig {
                enabled,
                ..DeltaConfig::default()
            },
            ..Default::default()
        };
        let mut pipe = mk_pipe(net.clone(), cfg, ServingConfig::default(), 4);
        let report = pipe
            .run(Job::stream(source()))
            .unwrap()
            .into_stream()
            .unwrap();
        assert_eq!(report.completions.len(), 4);
        points.push(JsonPoint::of(
            "smoke-delta",
            if enabled { "warm" } else { "cold" },
            &report,
            pipe.dispatches(),
        ));
        if enabled {
            // CI validates this export: the warm drift tick's spans as
            // Chrome trace-event JSON.
            maybe_write_trace(&pipe);
        }
        reports.push(report);
    }
    let (cold, warm) = (&reports[0], &reports[1]);
    for (a, b) in cold.completions.iter().zip(&warm.completions) {
        assert_eq!(a.id, b.id);
        assert_eq!(
            a.result.checksum, b.result.checksum,
            "frame {} diverged with the warm cache",
            a.id
        );
    }
    assert_eq!(cold.blocks_searched + cold.blocks_reused, 0, "cache off is free");
    assert!(warm.blocks_reused > 0, "warm drift stream must reuse blocks");
    assert!(warm.reuse_ratio() > 0.0);
    println!(
        "delta smoke ok: bit-identical, {} searched | {} reused ({:.1}% reuse)",
        warm.blocks_searched,
        warm.blocks_reused,
        warm.reuse_ratio() * 100.0
    );

    // Compute-reuse tick: the same coherent scene every frame (drift
    // profiles re-randomize features, which correctly defeats psum
    // reuse — the dispatch-reduction gate needs a stable-feature
    // stream).
    println!("\n# --smoke compute tick — psum splicing vs cold, coherent stream");
    let coherent = {
        let g = Voxelizer::synth_clustered(extent, 0.08, 4, 0.3, 0xC0);
        let mut t = SparseTensor::from_coords(extent, g.coords(), 4);
        for (i, v) in t.features.iter_mut().enumerate() {
            *v = ((i % 13) as i8) - 6;
        }
        t
    };
    let mut pair = Vec::new();
    for on in [false, true] {
        let cfg = RunnerConfig {
            inflight: 1,
            compute_workers: 1,
            delta: DeltaConfig {
                enabled: on,
                compute: on,
                ..DeltaConfig::default()
            },
            ..Default::default()
        };
        let mut pipe = mk_pipe(net.clone(), cfg, ServingConfig::default(), 4);
        let t = coherent.clone();
        let report = pipe
            .run(Job::stream(ClosureSource::new(move |_| t.clone())))
            .unwrap()
            .into_stream()
            .unwrap();
        assert_eq!(report.completions.len(), 4);
        points.push(JsonPoint::of(
            "smoke-compute",
            if on { "warm" } else { "cold" },
            &report,
            pipe.dispatches(),
        ));
        pair.push((pipe.dispatches(), report));
    }
    let (cold_calls, ccold) = &pair[0];
    let (warm_calls, cwarm) = &pair[1];
    for (a, b) in ccold.completions.iter().zip(&cwarm.completions) {
        assert_eq!(
            a.result.checksum, b.result.checksum,
            "frame {} diverged with compute reuse",
            a.id
        );
    }
    assert!(cwarm.rows_gathered_saved > 0, "compute smoke must splice psums");
    assert!(cwarm.waves_skipped > 0, "full splices must drop whole waves");
    assert!(
        warm_calls < cold_calls,
        "compute smoke: warm must issue strictly fewer GEMM dispatches \
         ({warm_calls} vs {cold_calls})"
    );
    println!(
        "compute smoke ok: bit-identical, dispatches {warm_calls} vs {cold_calls}, \
         {} rows saved, {} waves skipped",
        cwarm.rows_gathered_saved, cwarm.waves_skipped
    );
}
