//! Bench: batched multi-frame GEMM waves on the stream path — the
//! engine-layer feature that packs rule pairs from all in-flight frames
//! into shared sub-matrix dispatches. Serves the same synthetic stream
//! at inflight = 1 (classic frame-at-a-time) and inflight = 4, verifies
//! per-frame results are bit-identical, and reports dispatch counts and
//! throughput for both (the dispatch delta is what a PJRT engine
//! amortizes).
//!
//! A second sweep serves oversized scenes at shard grids 1 / 2x2 / 4x4
//! (with W2B-aware wave packing) and emits the latency-vs-throughput
//! curve of the shard scheduler, asserting bit-identity across grids.
//!
//! ```sh
//! cargo bench --bench stream_waves
//! ```

use voxel_cim::bench_util::bench;
use voxel_cim::coordinator::scheduler::RunnerConfig;
use voxel_cim::coordinator::shard::ShardConfig;
use voxel_cim::coordinator::stream::StreamServer;
use voxel_cim::geom::Extent3;
use voxel_cim::model::layer::{LayerSpec, NetworkSpec, TaskKind};
use voxel_cim::pointcloud::voxelize::Voxelizer;
use voxel_cim::sparse::tensor::SparseTensor;
use voxel_cim::spconv::layer::NativeEngine;

fn net() -> NetworkSpec {
    NetworkSpec {
        name: "stream-bench",
        task: TaskKind::Segmentation,
        extent: Extent3::new(64, 64, 12),
        vfe_channels: 8,
        layers: vec![
            LayerSpec::Subm3 { c_in: 8, c_out: 16 },
            LayerSpec::Subm3 { c_in: 16, c_out: 16 },
            LayerSpec::GConv2 { c_in: 16, c_out: 32 },
            LayerSpec::Subm3 { c_in: 32, c_out: 32 },
        ],
    }
}

fn make_frame(id: u64) -> SparseTensor {
    let e = Extent3::new(64, 64, 12);
    let g = Voxelizer::synth_clustered(e, 0.02, 6, 0.35, 500 + id);
    let mut t = SparseTensor::from_coords(e, g.coords(), 8);
    for (i, v) in t.features.iter_mut().enumerate() {
        *v = ((i as u64 + 3 * id) % 11) as i8;
    }
    t
}

fn main() {
    println!("# stream_waves — multi-frame GEMM wave batching");
    const FRAMES: u64 = 8;

    let mut reports = Vec::new();
    for inflight in [1usize, 4] {
        let cfg = RunnerConfig {
            inflight,
            // Serial compute so the caller's NativeEngine counter sees
            // every GEMM (forked pool engines keep their own counters).
            compute_workers: 1,
            ..Default::default()
        };
        let srv = StreamServer::new(net(), cfg, FRAMES as usize);
        let mut engine = NativeEngine::default();
        let r = bench(&format!("stream/serve8/inflight{inflight}"), 0, 3, || {
            srv.serve(FRAMES, make_frame, &mut engine).unwrap()
        });
        let mut engine = NativeEngine::default();
        let report = srv.serve(FRAMES, make_frame, &mut engine).unwrap();
        println!(
            "inflight {inflight}: {:.2} fps | p50 {:.1} ms | p95 {:.1} ms | {} engine dispatches | mean {:.1} ms",
            report.throughput_fps(),
            report.latency_p50() * 1e3,
            report.latency_p95() * 1e3,
            engine.calls,
            r.mean() * 1e3,
        );
        reports.push((inflight, engine.calls, report));
    }

    // Bit-identity across wave packing: every frame's checksum matches.
    let (_, solo_calls, solo) = &reports[0];
    let (_, packed_calls, packed) = &reports[1];
    for (a, b) in solo.completions.iter().zip(&packed.completions) {
        assert_eq!(a.id, b.id);
        assert_eq!(
            a.result.checksum, b.result.checksum,
            "frame {} diverged under wave batching",
            a.id
        );
    }
    println!(
        "\nper-frame results bit-identical; shared waves used {} dispatches vs {} frame-at-a-time",
        packed_calls, solo_calls
    );

    shard_sweep();
}

/// Shard-count sweep: one oversized scene per frame, served at 1 / 2x2 /
/// 4x4 block-shard grids — the latency-vs-throughput curve of the shard
/// scheduler (ROADMAP's SLO item), with bit-identity asserted across
/// every grid.
fn shard_sweep() {
    const FRAMES: u64 = 3;
    let extent = Extent3::new(192, 192, 10);
    let net = NetworkSpec {
        name: "shard-bench",
        task: TaskKind::Segmentation,
        extent,
        vfe_channels: 8,
        layers: vec![
            LayerSpec::Subm3 { c_in: 8, c_out: 16 },
            LayerSpec::Subm3 { c_in: 16, c_out: 16 },
            LayerSpec::GConv2 { c_in: 16, c_out: 32 },
            LayerSpec::Subm3 { c_in: 32, c_out: 32 },
        ],
    };
    let make_big = move |id: u64| {
        let g = Voxelizer::synth_clustered(extent, 0.012, 10, 0.3, 7000 + id);
        let mut t = SparseTensor::from_coords(extent, g.coords(), 8);
        for (i, v) in t.features.iter_mut().enumerate() {
            *v = ((i as u64 + 7 * id) % 13) as i8;
        }
        t
    };

    println!("\n# shard sweep — block-partitioned pseudo-frames (w2b 2x)");
    let mut baseline: Option<voxel_cim::coordinator::stream::StreamReport> = None;
    for (bx, by) in [(1usize, 1usize), (2, 2), (4, 4)] {
        let cfg = RunnerConfig {
            shard: ShardConfig::grid(bx, by).unwrap(),
            w2b_factor: 2,
            compute_workers: 1,
            ..Default::default()
        };
        let srv = StreamServer::new(net.clone(), cfg, 4);
        let mut engine = NativeEngine::default();
        let report = srv.serve(FRAMES, make_big, &mut engine).unwrap();
        let shards: u32 = report.completions.iter().map(|c| c.result.shards).sum();
        println!(
            "shards {bx}x{by}: {:.2} fps | p50 {:.1} ms | p95 {:.1} ms | {} pseudo-frames | {} dispatches",
            report.throughput_fps(),
            report.latency_p50() * 1e3,
            report.latency_p95() * 1e3,
            shards,
            engine.calls,
        );
        match &baseline {
            None => baseline = Some(report),
            Some(base) => {
                for (a, b) in base.completions.iter().zip(&report.completions) {
                    assert_eq!(
                        a.result.checksum, b.result.checksum,
                        "frame {} diverged under {bx}x{by} sharding",
                        a.id
                    );
                }
            }
        }
    }
    println!("shard grids bit-identical across the sweep");
}
