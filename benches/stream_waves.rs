//! Bench: batched multi-frame GEMM waves on the stream path — the
//! engine-layer feature that packs rule pairs from all in-flight frames
//! into shared sub-matrix dispatches. Five sweeps plus a CI smoke mode,
//! all submitted through the pipeline facade (`Pipeline::run(Job::..)`,
//! the engine owned by the pipeline):
//!
//! * **inflight sweep** (1/2/4/8): the latency-SLO trade-off curve — p50
//!   and p95 latency vs throughput as more frames share each wave group,
//!   with per-frame bit-identity asserted against inflight = 1 (the
//!   dispatch delta is what a PJRT engine amortizes).
//! * **shard sweep** (1 / 2x2 / 4x4 grids, W2B 2x): oversized scenes as
//!   block-partitioned pseudo-frames, bit-identity across grids.
//! * **profile sweep**: every scenario profile (urban / highway / indoor
//!   / far-field) served through the prefetching dataset layer.
//! * **serving sweep**: a mixed-profile sequence mux (dense urban scenes
//!   that shard, sparse far-field frames that do not) served through
//!   exclusive vs cross-scene lockstep windows — bit-identity and a
//!   strict dispatch reduction asserted — then the SLO admission
//!   frontier (drop-oldest / defer-sharding / reject-over-depth) over
//!   the attributed-latency p95.
//! * **delta sweep**: an ego-motion drift stream served cold vs warm
//!   through the temporal delta map-search cache — per-frame
//!   bit-identity asserted, cold-vs-warm p50/p95 and blocks-searched
//!   vs frame index printed with the stream's reuse ratio.
//!
//! ```sh
//! cargo bench --bench stream_waves             # full sweeps
//! cargo bench --bench stream_waves -- --smoke  # CI: one tick over the
//!                                              # checked-in KITTI fixture
//!                                              # + serving + warm-cache
//!                                              # ticks
//! ```

use voxel_cim::bench_util::bench;
use voxel_cim::coordinator::scheduler::RunnerConfig;
use voxel_cim::coordinator::shard::ShardConfig;
use voxel_cim::coordinator::stream::StreamReport;
use voxel_cim::dataset::{
    ClosureSource, DatasetConfig, FrameSource, KittiSource, PrefetchSource, ProfileSource,
    ScenarioProfile,
};
use voxel_cim::geom::Extent3;
use voxel_cim::mapsearch::DeltaConfig;
use voxel_cim::model::layer::{LayerSpec, NetworkSpec, TaskKind};
use voxel_cim::pipeline::{Job, Pipeline, PipelineConfig};
use voxel_cim::pointcloud::voxelize::Voxelizer;
use voxel_cim::serving::{
    AdmissionConfig, AdmissionPolicy, MuxPolicy, SequenceMux, ServingConfig, WindowPolicy,
};
use voxel_cim::sparse::tensor::SparseTensor;
use voxel_cim::spconv::layer::NativeEngine;

fn net() -> NetworkSpec {
    NetworkSpec {
        name: "stream-bench",
        task: TaskKind::Segmentation,
        extent: Extent3::new(64, 64, 12),
        vfe_channels: 8,
        layers: vec![
            LayerSpec::Subm3 { c_in: 8, c_out: 16 },
            LayerSpec::Subm3 { c_in: 16, c_out: 16 },
            LayerSpec::GConv2 { c_in: 16, c_out: 32 },
            LayerSpec::Subm3 { c_in: 32, c_out: 32 },
        ],
    }
}

fn make_frame(id: u64) -> SparseTensor {
    let e = Extent3::new(64, 64, 12);
    let g = Voxelizer::synth_clustered(e, 0.02, 6, 0.35, 500 + id);
    let mut t = SparseTensor::from_coords(e, g.coords(), 8);
    for (i, v) in t.features.iter_mut().enumerate() {
        *v = ((i as u64 + 3 * id) % 11) as i8;
    }
    t
}

/// One facade per measured serve: the owned `NativeEngine`'s dispatch
/// counter then measures exactly that stream (`pipe.dispatches()`).
fn mk_pipe(net: NetworkSpec, runner: RunnerConfig, serving: ServingConfig, frames: u64) -> Pipeline {
    let cfg = PipelineConfig {
        runner,
        serving,
        dataset: DatasetConfig {
            frames,
            ..Default::default()
        },
        ..Default::default()
    };
    Pipeline::builder()
        .config(cfg)
        .network(net)
        .engine(NativeEngine::default())
        .build()
        .expect("bench pipeline")
}

/// The old `serve_closure` producer/consumer split as a stream job: a
/// prefetch thread over a closure source, buffer depth `depth`.
fn prefetched_job<P>(producer: P, depth: usize) -> Job
where
    P: Fn(u64) -> SparseTensor + Send + 'static,
{
    Job::stream(PrefetchSource::spawn(
        Box::new(ClosureSource::new(producer)),
        depth,
    ))
}

/// The shared p50/p95 line every sweep prints (`util::stats::LatencySummary`).
fn latency_line(report: &StreamReport) -> String {
    report
        .latency_summary()
        .map(|s| s.format_ms())
        .unwrap_or_else(|| "no completions".into())
}

fn main() {
    if std::env::args().any(|a| a == "--smoke") {
        smoke();
        return;
    }
    println!("# stream_waves — multi-frame GEMM wave batching");
    const FRAMES: u64 = 8;

    // Inflight sweep: the p50/p95-vs-throughput curve of wave batching
    // (ROADMAP's latency-SLO follow-on).
    let mut reports = Vec::new();
    for inflight in [1usize, 2, 4, 8] {
        let cfg = RunnerConfig {
            inflight,
            // Serial compute so the owned NativeEngine's counter sees
            // every GEMM (forked pool engines keep their own counters).
            compute_workers: 1,
            ..Default::default()
        };
        let mut timed = mk_pipe(net(), cfg, ServingConfig::default(), FRAMES);
        let r = bench(&format!("stream/serve8/inflight{inflight}"), 0, 3, || {
            timed
                .run(prefetched_job(make_frame, FRAMES as usize))
                .unwrap()
        });
        let mut counted = mk_pipe(net(), cfg, ServingConfig::default(), FRAMES);
        let report = counted
            .run(prefetched_job(make_frame, FRAMES as usize))
            .unwrap()
            .into_stream()
            .unwrap();
        let calls = counted.dispatches();
        println!(
            "inflight {inflight}: {:.2} fps | {} | {} engine dispatches | mean {:.1} ms",
            report.throughput_fps(),
            latency_line(&report),
            calls,
            r.mean() * 1e3,
        );
        reports.push((inflight, calls, report));
    }

    // Bit-identity across wave packing: every inflight level's per-frame
    // checksums match the frame-at-a-time baseline.
    let (_, solo_calls, solo) = &reports[0];
    for (inflight, calls, packed) in &reports[1..] {
        for (a, b) in solo.completions.iter().zip(&packed.completions) {
            assert_eq!(a.id, b.id);
            assert_eq!(
                a.result.checksum, b.result.checksum,
                "frame {} diverged at inflight {inflight}",
                a.id
            );
        }
        println!(
            "inflight {inflight}: bit-identical; {} dispatches vs {} frame-at-a-time",
            calls, solo_calls
        );
    }

    shard_sweep();
    profile_sweep();
    serving_sweep();
    delta_sweep();
}

/// Shard-count sweep: one oversized scene per frame, served at 1 / 2x2 /
/// 4x4 block-shard grids — the latency-vs-throughput curve of the shard
/// scheduler, with bit-identity asserted across every grid.
fn shard_sweep() {
    const FRAMES: u64 = 3;
    let extent = Extent3::new(192, 192, 10);
    let net = NetworkSpec {
        name: "shard-bench",
        task: TaskKind::Segmentation,
        extent,
        vfe_channels: 8,
        layers: vec![
            LayerSpec::Subm3 { c_in: 8, c_out: 16 },
            LayerSpec::Subm3 { c_in: 16, c_out: 16 },
            LayerSpec::GConv2 { c_in: 16, c_out: 32 },
            LayerSpec::Subm3 { c_in: 32, c_out: 32 },
        ],
    };
    let make_big = move |id: u64| {
        let g = Voxelizer::synth_clustered(extent, 0.012, 10, 0.3, 7000 + id);
        let mut t = SparseTensor::from_coords(extent, g.coords(), 8);
        for (i, v) in t.features.iter_mut().enumerate() {
            *v = ((i as u64 + 7 * id) % 13) as i8;
        }
        t
    };

    println!("\n# shard sweep — block-partitioned pseudo-frames (w2b 2x)");
    let mut baseline: Option<StreamReport> = None;
    for (bx, by) in [(1usize, 1usize), (2, 2), (4, 4)] {
        let cfg = RunnerConfig {
            shard: ShardConfig::grid(bx, by).unwrap(),
            w2b_factor: 2,
            compute_workers: 1,
            ..Default::default()
        };
        let mut pipe = mk_pipe(net.clone(), cfg, ServingConfig::default(), FRAMES);
        let report = pipe
            .run(prefetched_job(make_big, 4))
            .unwrap()
            .into_stream()
            .unwrap();
        let shards: u32 = report.completions.iter().map(|c| c.result.shards).sum();
        println!(
            "shards {bx}x{by}: {:.2} fps | {} | {} pseudo-frames | {} dispatches",
            report.throughput_fps(),
            latency_line(&report),
            shards,
            pipe.dispatches(),
        );
        match &baseline {
            None => baseline = Some(report),
            Some(base) => {
                for (a, b) in base.completions.iter().zip(&report.completions) {
                    assert_eq!(
                        a.result.checksum, b.result.checksum,
                        "frame {} diverged under {bx}x{by} sharding",
                        a.id
                    );
                }
            }
        }
    }
    println!("shard grids bit-identical across the sweep");
}

/// Scenario-profile sweep: workload diversity through the prefetching
/// dataset layer — same engine config, four density shapes.
fn profile_sweep() {
    const FRAMES: u64 = 6;
    let extent = Extent3::new(64, 64, 12);
    println!("\n# profile sweep — dataset ingestion (prefetch depth 2, inflight 2)");
    for profile in ScenarioProfile::ALL {
        let cfg = RunnerConfig {
            inflight: 2,
            compute_workers: 1,
            ..Default::default()
        };
        let mut pipe = mk_pipe(net(), cfg, ServingConfig::default(), FRAMES);
        let inner = ProfileSource::new(profile, extent, 0.02, 0xA11).with_channels(8);
        let source = PrefetchSource::spawn(Box::new(inner), 2);
        let report = pipe
            .run(Job::stream(source))
            .unwrap()
            .into_stream()
            .unwrap();
        let voxels: u64 = report.completions.iter().map(|c| c.result.out_voxels).sum();
        println!(
            "{:<10} {:.2} fps | {} | {} out voxels | {} dispatches",
            profile.key(),
            report.throughput_fps(),
            latency_line(&report),
            voxels,
            pipe.dispatches(),
        );
        assert_eq!(report.completions.len(), FRAMES as usize, "{profile}");
    }
}

/// The serving sweep's mixed-profile mux: a dense urban sequence whose
/// scenes shard on the 2x2 grid next to a sparse far-field sequence that
/// never does. Synchronous (unprefetched) sources so the two window
/// policies see the identical frame stream.
fn mixed_mux(extent: Extent3) -> SequenceMux {
    SequenceMux::new(
        vec![
            Box::new(
                ProfileSource::new(ScenarioProfile::Urban, extent, 0.03, 0x5E1)
                    .with_channels(8),
            ),
            Box::new(
                ProfileSource::new(ScenarioProfile::FarField, extent, 0.008, 0x5E2)
                    .with_channels(8),
            ),
        ],
        MuxPolicy::RoundRobin,
    )
    .expect("two sequences")
}

fn serving_cfg(extent: Extent3) -> RunnerConfig {
    // Urban frames at sparsity 0.03 carry ~3x the far-field voxel count:
    // the threshold splits exactly the urban scenes.
    let threshold = (extent.volume() as f64 * 0.018) as usize;
    RunnerConfig {
        shard: ShardConfig {
            auto_threshold: threshold,
            ..ShardConfig::grid(2, 2).unwrap()
        },
        inflight: 6,
        compute_workers: 1,
        // One wave per non-empty offset per window: the dispatch counter
        // then directly measures window packing, not batch remainders.
        batch: 4096,
        ..Default::default()
    }
}

/// The serving sweep's `[serving]` view: an explicit window policy plus
/// (optionally) an admission config.
fn serving_with(window: WindowPolicy, admission: AdmissionConfig) -> ServingConfig {
    ServingConfig {
        window: Some(window),
        admission,
        ..Default::default()
    }
}

/// Serving sweep: cross-scene lockstep windows + SLO admission over a
/// mixed-profile sequence mux — the p95-vs-throughput frontier against
/// the exclusive-window baseline.
fn serving_sweep() {
    const FRAMES: u64 = 8;
    let extent = Extent3::new(64, 64, 12);
    println!("\n# serving sweep — mixed-profile mux (urban shards next to far-field)");

    // Window-policy comparison at equal frame count: bit-identity and a
    // strict engine-dispatch reduction (the acceptance criterion).
    let mut reports: Vec<(WindowPolicy, u64, StreamReport)> = Vec::new();
    for window in [WindowPolicy::Exclusive, WindowPolicy::CrossScene] {
        let mut pipe = mk_pipe(
            net(),
            serving_cfg(extent),
            serving_with(window, AdmissionConfig::default()),
            FRAMES,
        );
        let report = pipe
            .run(Job::stream(mixed_mux(extent)))
            .unwrap()
            .into_stream()
            .unwrap();
        assert_eq!(report.completions.len(), FRAMES as usize, "{window}");
        let att = report
            .attributed_summary()
            .map(|s| s.format_ms())
            .unwrap_or_default();
        println!(
            "window {:<11} {:.2} fps | {} | own {} | {} windows | {} dispatches",
            window.key(),
            report.throughput_fps(),
            latency_line(&report),
            att,
            report.windows,
            pipe.dispatches(),
        );
        reports.push((window, pipe.dispatches(), report));
    }
    let (_, excl_calls, excl) = &reports[0];
    let (_, cross_calls, cross) = &reports[1];
    for (a, b) in excl.completions.iter().zip(&cross.completions) {
        assert_eq!((a.sequence, a.id), (b.sequence, b.id));
        assert_eq!(
            a.result.checksum, b.result.checksum,
            "seq {} frame {} diverged between window policies",
            a.sequence, a.id
        );
    }
    assert!(
        excl.completions.iter().any(|c| c.result.shards > 1),
        "urban scenes should shard in the mixed mux"
    );
    assert!(
        cross_calls < excl_calls,
        "cross-scene windows must dispatch strictly less at equal frames: \
         {cross_calls} vs {excl_calls}"
    );
    println!(
        "cross-scene bit-identical to exclusive; dispatches {cross_calls} vs \
         {excl_calls} ({} vs {} windows)",
        cross.windows, excl.windows
    );

    // Admission frontier: the SLO target set inside the measured band so
    // the policies actually engage; goodput vs attributed p95 per policy.
    // More frames than the effective queue depth (2 x inflight = 12) —
    // with a shallower stream every frame is admitted before the first
    // completion feeds the estimator and drop/reject never fire.
    const ADM_FRAMES: u64 = 16;
    let slo_ms = cross
        .attributed_summary()
        .map(|s| s.p95 * 1e3 * 0.6)
        .unwrap_or(1.0);
    println!("admission frontier @ slo {slo_ms:.2} ms (0.6x the cross-scene p95):");
    for policy in [
        AdmissionPolicy::None,
        AdmissionPolicy::DropOldest,
        AdmissionPolicy::DeferSharding,
        AdmissionPolicy::RejectOverDepth,
    ] {
        let mut pipe = mk_pipe(
            net(),
            serving_cfg(extent),
            serving_with(
                WindowPolicy::CrossScene,
                AdmissionConfig {
                    policy,
                    slo_ms,
                    ..Default::default()
                },
            ),
            ADM_FRAMES,
        );
        let report = pipe
            .run(Job::stream(mixed_mux(extent)))
            .unwrap()
            .into_stream()
            .unwrap();
        let adm = report.admission;
        let att = report
            .attributed_summary()
            .map(|s| s.format_ms())
            .unwrap_or_else(|| "-".into());
        println!(
            "{:<18} {} served | own {} | {:.2} fps | {} dropped | {} rejected | \
             {} deferrals",
            policy.key(),
            report.completions.len(),
            att,
            report.throughput_fps(),
            adm.dropped,
            adm.rejected,
            adm.deferred,
        );
        // Shedding policies lose frames only to their counters; deferral
        // serves everything. Every pulled frame is served or accounted.
        assert_eq!(
            report.completions.len() as u64 + adm.dropped + adm.rejected,
            ADM_FRAMES,
            "{policy}: completions + shed must cover every pulled frame"
        );
    }
}

/// Delta sweep: the temporal delta map-search cache over an ego-motion
/// drift stream — the same frames served cold (cache off) and warm,
/// with per-frame bit-identity asserted, the cold-vs-warm latency
/// distributions printed, and the warm run's blocks-searched curve
/// traced against the frame index (the compulsory-cold first frame,
/// then the steady dirty + halo band).
fn delta_sweep() {
    const FRAMES: u64 = 8;
    let extent = Extent3::new(64, 64, 12);
    println!("\n# delta sweep — temporal map-search cache over an ego-motion stream");
    let source = || {
        let inner = ProfileSource::new(ScenarioProfile::Urban, extent, 0.02, 0xDE17A)
            .with_drift(1.0)
            .with_channels(8);
        PrefetchSource::spawn(Box::new(inner), 2)
    };
    let mut reports = Vec::new();
    for enabled in [false, true] {
        let cfg = RunnerConfig {
            // One frame per window so every warm frame plans against its
            // predecessor's committed cache entry.
            inflight: 1,
            compute_workers: 1,
            delta: DeltaConfig {
                enabled,
                blocks_x: 16,
                blocks_y: 16,
                ..DeltaConfig::default()
            },
            ..Default::default()
        };
        let mut pipe = mk_pipe(net(), cfg, ServingConfig::default(), FRAMES);
        let report = pipe
            .run(Job::stream(source()))
            .unwrap()
            .into_stream()
            .unwrap();
        assert_eq!(report.completions.len(), FRAMES as usize);
        println!(
            "delta {:<4} {:.2} fps | {} | {} searched | {} reused ({:.1}% reuse) | \
             {} dispatches",
            if enabled { "on" } else { "off" },
            report.throughput_fps(),
            latency_line(&report),
            report.blocks_searched,
            report.blocks_reused,
            report.reuse_ratio() * 100.0,
            pipe.dispatches(),
        );
        reports.push(report);
    }
    let (cold, warm) = (&reports[0], &reports[1]);
    for (a, b) in cold.completions.iter().zip(&warm.completions) {
        assert_eq!(a.id, b.id);
        assert_eq!(
            a.result.checksum, b.result.checksum,
            "frame {} diverged with the delta cache on",
            a.id
        );
    }
    assert!(
        warm.blocks_reused > 0,
        "the ego-motion stream must reuse blocks once warm"
    );
    for c in &warm.completions {
        println!(
            "frame {}: {} blocks searched | {} reused",
            c.id, c.result.blocks_searched, c.result.blocks_reused
        );
    }
    println!(
        "delta sweep bit-identical; stream reuse {:.1}%",
        warm.reuse_ratio() * 100.0
    );
}

/// CI smoke: one serving tick over the checked-in KITTI fixture — the
/// on-disk reader → voxelizer → stream-server path end to end — plus a
/// mixed-profile serving tick exercising the sequence mux and the
/// cross-scene window packer, and a warm-cache tick asserting the
/// temporal delta cache reuses blocks without changing a single bit.
/// A few hundred milliseconds in total.
fn smoke() {
    println!("# stream_waves --smoke — KITTI fixture, one tick");
    let fixture = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/kitti");
    let extent = Extent3::new(16, 16, 8);
    let vx = Voxelizer::new((16.0, 16.0, 8.0), extent, 8);
    let source = KittiSource::open(fixture, vx).expect("fixture dir");
    let net = NetworkSpec {
        name: "smoke",
        task: TaskKind::Segmentation,
        extent,
        vfe_channels: 4,
        layers: vec![
            LayerSpec::Subm3 { c_in: 4, c_out: 8 },
            LayerSpec::Subm3 { c_in: 8, c_out: 8 },
        ],
    };
    let mut pipe = mk_pipe(
        net.clone(),
        RunnerConfig {
            inflight: 2,
            compute_workers: 1,
            ..Default::default()
        },
        ServingConfig::default(),
        8,
    );
    let report = pipe
        .run(Job::stream(source))
        .unwrap()
        .into_stream()
        .unwrap();
    assert_eq!(report.completions.len(), 2, "fixture holds two frames");
    for c in &report.completions {
        assert!(c.result.out_voxels > 0, "frame {}", c.id);
        println!(
            "frame {}: {} out voxels | checksum {:#018x}",
            c.id, c.result.out_voxels, c.result.checksum
        );
    }
    println!("smoke ok: {} frames served", report.completions.len());
    serving_smoke(net.clone());
    delta_smoke(net);
}

/// The serving-scheduler smoke: a two-sequence mux served through
/// exclusive and cross-scene windows with sharding forced on — per-frame
/// bit-identity and a strict dispatch reduction asserted on every push.
fn serving_smoke(net: NetworkSpec) {
    println!("\n# --smoke serving tick — mixed-profile mux, 2x2 shards");
    let extent = net.extent;
    let cfg = RunnerConfig {
        shard: ShardConfig {
            auto_threshold: 1,
            ..ShardConfig::grid(2, 2).unwrap()
        },
        inflight: 8,
        compute_workers: 1,
        ..Default::default()
    };
    let mux = || {
        SequenceMux::new(
            vec![
                Box::new(
                    ProfileSource::new(ScenarioProfile::Urban, extent, 0.05, 0x51)
                        .with_frames(2),
                ) as Box<dyn FrameSource>,
                Box::new(
                    ProfileSource::new(ScenarioProfile::Highway, extent, 0.05, 0x52)
                        .with_frames(2),
                ),
            ],
            MuxPolicy::RoundRobin,
        )
        .expect("two sequences")
    };
    let mut results = Vec::new();
    for window in [WindowPolicy::Exclusive, WindowPolicy::CrossScene] {
        let mut pipe = mk_pipe(
            net.clone(),
            cfg,
            serving_with(window, AdmissionConfig::default()),
            4,
        );
        let report = pipe
            .run(Job::stream(mux()))
            .unwrap()
            .into_stream()
            .unwrap();
        assert_eq!(report.completions.len(), 4, "{window}");
        println!(
            "window {:<11} {} windows | {} dispatches | {}",
            window.key(),
            report.windows,
            pipe.dispatches(),
            latency_line(&report),
        );
        results.push((pipe.dispatches(), report));
    }
    let (excl_calls, excl) = &results[0];
    let (cross_calls, cross) = &results[1];
    for (a, b) in excl.completions.iter().zip(&cross.completions) {
        assert_eq!((a.sequence, a.id), (b.sequence, b.id));
        assert_eq!(
            a.result.checksum, b.result.checksum,
            "seq {} frame {} diverged in the serving smoke",
            a.sequence, a.id
        );
    }
    assert!(
        cross_calls < excl_calls,
        "serving smoke: cross-scene must dispatch strictly less \
         ({cross_calls} vs {excl_calls})"
    );
    println!("serving smoke ok: bit-identical, {cross_calls} vs {excl_calls} dispatches");
}

/// The warm-cache smoke: a short ego-motion drift stream served cold and
/// warm — per-frame checksum equality against the cold pass plus a
/// nonzero reuse ratio asserted on every push.
fn delta_smoke(net: NetworkSpec) {
    println!("\n# --smoke delta tick — warm temporal cache vs cold, drift stream");
    let extent = net.extent;
    let source = || {
        ProfileSource::new(ScenarioProfile::Urban, extent, 0.08, 0xD3)
            .with_drift(1.0)
            .with_frames(4)
    };
    let mut reports = Vec::new();
    for enabled in [false, true] {
        let cfg = RunnerConfig {
            inflight: 1,
            compute_workers: 1,
            delta: DeltaConfig {
                enabled,
                ..DeltaConfig::default()
            },
            ..Default::default()
        };
        let mut pipe = mk_pipe(net.clone(), cfg, ServingConfig::default(), 4);
        let report = pipe
            .run(Job::stream(source()))
            .unwrap()
            .into_stream()
            .unwrap();
        assert_eq!(report.completions.len(), 4);
        reports.push(report);
    }
    let (cold, warm) = (&reports[0], &reports[1]);
    for (a, b) in cold.completions.iter().zip(&warm.completions) {
        assert_eq!(a.id, b.id);
        assert_eq!(
            a.result.checksum, b.result.checksum,
            "frame {} diverged with the warm cache",
            a.id
        );
    }
    assert_eq!(cold.blocks_searched + cold.blocks_reused, 0, "cache off is free");
    assert!(warm.blocks_reused > 0, "warm drift stream must reuse blocks");
    assert!(warm.reuse_ratio() > 0.0);
    println!(
        "delta smoke ok: bit-identical, {} searched | {} reused ({:.1}% reuse)",
        warm.blocks_searched,
        warm.blocks_reused,
        warm.reuse_ratio() * 100.0
    );
}
