//! Bench: batched multi-frame GEMM waves on the stream path — the
//! engine-layer feature that packs rule pairs from all in-flight frames
//! into shared sub-matrix dispatches. Three sweeps plus a CI smoke mode:
//!
//! * **inflight sweep** (1/2/4/8): the latency-SLO trade-off curve — p50
//!   and p95 latency vs throughput as more frames share each wave group,
//!   with per-frame bit-identity asserted against inflight = 1 (the
//!   dispatch delta is what a PJRT engine amortizes).
//! * **shard sweep** (1 / 2x2 / 4x4 grids, W2B 2x): oversized scenes as
//!   block-partitioned pseudo-frames, bit-identity across grids.
//! * **profile sweep**: every scenario profile (urban / highway / indoor
//!   / far-field) served through the prefetching dataset layer.
//!
//! ```sh
//! cargo bench --bench stream_waves             # full sweeps
//! cargo bench --bench stream_waves -- --smoke  # CI: one tick over the
//!                                              # checked-in KITTI fixture
//! ```

use voxel_cim::bench_util::bench;
use voxel_cim::coordinator::scheduler::RunnerConfig;
use voxel_cim::coordinator::shard::ShardConfig;
use voxel_cim::coordinator::stream::StreamServer;
use voxel_cim::dataset::{KittiSource, PrefetchSource, ProfileSource, ScenarioProfile};
use voxel_cim::geom::Extent3;
use voxel_cim::model::layer::{LayerSpec, NetworkSpec, TaskKind};
use voxel_cim::pointcloud::voxelize::Voxelizer;
use voxel_cim::sparse::tensor::SparseTensor;
use voxel_cim::spconv::layer::NativeEngine;

fn net() -> NetworkSpec {
    NetworkSpec {
        name: "stream-bench",
        task: TaskKind::Segmentation,
        extent: Extent3::new(64, 64, 12),
        vfe_channels: 8,
        layers: vec![
            LayerSpec::Subm3 { c_in: 8, c_out: 16 },
            LayerSpec::Subm3 { c_in: 16, c_out: 16 },
            LayerSpec::GConv2 { c_in: 16, c_out: 32 },
            LayerSpec::Subm3 { c_in: 32, c_out: 32 },
        ],
    }
}

fn make_frame(id: u64) -> SparseTensor {
    let e = Extent3::new(64, 64, 12);
    let g = Voxelizer::synth_clustered(e, 0.02, 6, 0.35, 500 + id);
    let mut t = SparseTensor::from_coords(e, g.coords(), 8);
    for (i, v) in t.features.iter_mut().enumerate() {
        *v = ((i as u64 + 3 * id) % 11) as i8;
    }
    t
}

fn main() {
    if std::env::args().any(|a| a == "--smoke") {
        smoke();
        return;
    }
    println!("# stream_waves — multi-frame GEMM wave batching");
    const FRAMES: u64 = 8;

    // Inflight sweep: the p50/p95-vs-throughput curve of wave batching
    // (ROADMAP's latency-SLO follow-on).
    let mut reports = Vec::new();
    for inflight in [1usize, 2, 4, 8] {
        let cfg = RunnerConfig {
            inflight,
            // Serial compute so the caller's NativeEngine counter sees
            // every GEMM (forked pool engines keep their own counters).
            compute_workers: 1,
            ..Default::default()
        };
        let srv = StreamServer::new(net(), cfg, FRAMES as usize);
        let mut engine = NativeEngine::default();
        let r = bench(&format!("stream/serve8/inflight{inflight}"), 0, 3, || {
            srv.serve_closure(FRAMES, make_frame, &mut engine).unwrap()
        });
        let mut engine = NativeEngine::default();
        let report = srv.serve_closure(FRAMES, make_frame, &mut engine).unwrap();
        println!(
            "inflight {inflight}: {:.2} fps | p50 {:.1} ms | p95 {:.1} ms | {} engine dispatches | mean {:.1} ms",
            report.throughput_fps(),
            report.latency_p50() * 1e3,
            report.latency_p95() * 1e3,
            engine.calls,
            r.mean() * 1e3,
        );
        reports.push((inflight, engine.calls, report));
    }

    // Bit-identity across wave packing: every inflight level's per-frame
    // checksums match the frame-at-a-time baseline.
    let (_, solo_calls, solo) = &reports[0];
    for (inflight, calls, packed) in &reports[1..] {
        for (a, b) in solo.completions.iter().zip(&packed.completions) {
            assert_eq!(a.id, b.id);
            assert_eq!(
                a.result.checksum, b.result.checksum,
                "frame {} diverged at inflight {inflight}",
                a.id
            );
        }
        println!(
            "inflight {inflight}: bit-identical; {} dispatches vs {} frame-at-a-time",
            calls, solo_calls
        );
    }

    shard_sweep();
    profile_sweep();
}

/// Shard-count sweep: one oversized scene per frame, served at 1 / 2x2 /
/// 4x4 block-shard grids — the latency-vs-throughput curve of the shard
/// scheduler, with bit-identity asserted across every grid.
fn shard_sweep() {
    const FRAMES: u64 = 3;
    let extent = Extent3::new(192, 192, 10);
    let net = NetworkSpec {
        name: "shard-bench",
        task: TaskKind::Segmentation,
        extent,
        vfe_channels: 8,
        layers: vec![
            LayerSpec::Subm3 { c_in: 8, c_out: 16 },
            LayerSpec::Subm3 { c_in: 16, c_out: 16 },
            LayerSpec::GConv2 { c_in: 16, c_out: 32 },
            LayerSpec::Subm3 { c_in: 32, c_out: 32 },
        ],
    };
    let make_big = move |id: u64| {
        let g = Voxelizer::synth_clustered(extent, 0.012, 10, 0.3, 7000 + id);
        let mut t = SparseTensor::from_coords(extent, g.coords(), 8);
        for (i, v) in t.features.iter_mut().enumerate() {
            *v = ((i as u64 + 7 * id) % 13) as i8;
        }
        t
    };

    println!("\n# shard sweep — block-partitioned pseudo-frames (w2b 2x)");
    let mut baseline: Option<voxel_cim::coordinator::stream::StreamReport> = None;
    for (bx, by) in [(1usize, 1usize), (2, 2), (4, 4)] {
        let cfg = RunnerConfig {
            shard: ShardConfig::grid(bx, by).unwrap(),
            w2b_factor: 2,
            compute_workers: 1,
            ..Default::default()
        };
        let srv = StreamServer::new(net.clone(), cfg, 4);
        let mut engine = NativeEngine::default();
        let report = srv.serve_closure(FRAMES, make_big, &mut engine).unwrap();
        let shards: u32 = report.completions.iter().map(|c| c.result.shards).sum();
        println!(
            "shards {bx}x{by}: {:.2} fps | p50 {:.1} ms | p95 {:.1} ms | {} pseudo-frames | {} dispatches",
            report.throughput_fps(),
            report.latency_p50() * 1e3,
            report.latency_p95() * 1e3,
            shards,
            engine.calls,
        );
        match &baseline {
            None => baseline = Some(report),
            Some(base) => {
                for (a, b) in base.completions.iter().zip(&report.completions) {
                    assert_eq!(
                        a.result.checksum, b.result.checksum,
                        "frame {} diverged under {bx}x{by} sharding",
                        a.id
                    );
                }
            }
        }
    }
    println!("shard grids bit-identical across the sweep");
}

/// Scenario-profile sweep: workload diversity through the prefetching
/// dataset layer — same engine config, four density shapes.
fn profile_sweep() {
    const FRAMES: u64 = 6;
    let extent = Extent3::new(64, 64, 12);
    println!("\n# profile sweep — dataset ingestion (prefetch depth 2, inflight 2)");
    for profile in ScenarioProfile::ALL {
        let cfg = RunnerConfig {
            inflight: 2,
            compute_workers: 1,
            ..Default::default()
        };
        let srv = StreamServer::new(net(), cfg, 4);
        let inner = ProfileSource::new(profile, extent, 0.02, 0xA11).with_channels(8);
        let mut source = PrefetchSource::spawn(Box::new(inner), 2);
        let mut engine = NativeEngine::default();
        let report = srv.serve(FRAMES, &mut source, &mut engine).unwrap();
        let voxels: u64 = report.completions.iter().map(|c| c.result.out_voxels).sum();
        println!(
            "{:<10} {:.2} fps | p50 {:.1} ms | p95 {:.1} ms | {} out voxels | {} dispatches",
            profile.key(),
            report.throughput_fps(),
            report.latency_p50() * 1e3,
            report.latency_p95() * 1e3,
            voxels,
            engine.calls,
        );
        assert_eq!(report.completions.len(), FRAMES as usize, "{profile}");
    }
}

/// CI smoke: one serving tick over the checked-in KITTI fixture — proves
/// the on-disk reader → voxelizer → stream-server path end to end in a
/// few hundred milliseconds.
fn smoke() {
    println!("# stream_waves --smoke — KITTI fixture, one tick");
    let fixture = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/kitti");
    let extent = Extent3::new(16, 16, 8);
    let vx = Voxelizer::new((16.0, 16.0, 8.0), extent, 8);
    let mut source = KittiSource::open(fixture, vx).expect("fixture dir");
    let net = NetworkSpec {
        name: "smoke",
        task: TaskKind::Segmentation,
        extent,
        vfe_channels: 4,
        layers: vec![
            LayerSpec::Subm3 { c_in: 4, c_out: 8 },
            LayerSpec::Subm3 { c_in: 8, c_out: 8 },
        ],
    };
    let srv = StreamServer::new(
        net,
        RunnerConfig {
            inflight: 2,
            compute_workers: 1,
            ..Default::default()
        },
        2,
    );
    let report = srv
        .serve(8, &mut source, &mut NativeEngine::default())
        .unwrap();
    assert_eq!(report.completions.len(), 2, "fixture holds two frames");
    for c in &report.completions {
        assert!(c.result.out_voxels > 0, "frame {}", c.id);
        println!(
            "frame {}: {} out voxels | checksum {:#018x}",
            c.id, c.result.out_voxels, c.result.checksum
        );
    }
    println!("smoke ok: {} frames served", report.completions.len());
}
