//! Bench E7/E8 (segmentation side): MinkUNet / SemanticKITTI-like — the
//! Table 2 Seg row, Fig. 11 seg bars, and the W2B contribution at the
//! pipeline level.

use voxel_cim::bench_util::bench;
use voxel_cim::mapsearch::SearcherKind;
use voxel_cim::model::minkunet;
use voxel_cim::pipeline::{EngineKind, Job, Pipeline, PipelineConfig};
use voxel_cim::pointcloud::voxelize::Voxelizer;
use voxel_cim::sim::accelerator::{Accelerator, SimOptions};
use voxel_cim::sim::baselines::GPU_SEG_FPS;
use voxel_cim::sparse::tensor::SparseTensor;
use voxel_cim::util::rng::Pcg64;

fn main() {
    println!("# e2e_segmentation — MinkUNet / SemanticKITTI-like (Table 2 Seg row)");
    // The engine layer's configured dataflow (paper default: DOMS).
    let searcher = SearcherKind::Doms.build();
    let net = minkunet::minkunet();
    let g = Voxelizer::synth_clustered(net.extent, 2.3e-4, 14, 0.3, 41);
    let input = SparseTensor::from_coords(net.extent, g.coords(), 1);
    let acc = Accelerator::default();
    println!("input: {} voxels at {:?}", input.len(), net.extent);
    bench("segmentation/accel_sim_full", 0, 3, || {
        acc.simulate(&net, &input, searcher.as_ref(), &SimOptions::default())
    });
    let with = acc.simulate(&net, &input, searcher.as_ref(), &SimOptions::default());
    let without = acc.simulate(
        &net,
        &input,
        searcher.as_ref(),
        &SimOptions { w2b: false, ..Default::default() },
    );
    println!(
        "model: {:.1} fps (W2B) vs {:.1} fps (no W2B) | paper 107 fps | GPU {:.1} fps",
        with.fps(),
        without.fps(),
        GPU_SEG_FPS
    );

    // Host-side real-numerics UNet at the reduced grid, submitted
    // through the owned-engine facade.
    let small = minkunet::minkunet_small();
    let cfg = PipelineConfig {
        engine: EngineKind::Native,
        ..Default::default()
    };
    let mut pipe = Pipeline::builder()
        .config(cfg)
        .network(small.clone())
        .build()
        .expect("pipeline");
    let gs = Voxelizer::synth_clustered(small.extent, 900.0 / small.extent.volume() as f64, 42, 0.3, 43);
    let mut t = SparseTensor::from_coords(small.extent, gs.coords(), 4);
    let mut rng = Pcg64::new(44);
    for v in t.features.iter_mut() {
        *v = rng.next_i8(0, 12);
    }
    let r = bench("segmentation/host_frame_native", 0, 3, || {
        pipe.run(Job::Frame(t.clone())).unwrap()
    });
    println!("host frame mean: {:.1} ms (CPU-emulated CIM numerics)", r.mean() * 1e3);
}
