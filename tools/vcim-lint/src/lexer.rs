//! A comment/string/char-literal-aware Rust tokenizer.
//!
//! This is deliberately *not* a full Rust lexer: the rule engine only
//! needs identifiers, punctuation, literals, and comments, each with an
//! accurate line:col, and it needs the tricky cases that break naive
//! `grep`-style linting handled correctly:
//!
//! - line comments (`//`, `///`, `//!`) and **nested** block comments,
//! - string / raw string / byte string / raw byte string literals
//!   (so `r#"…unwrap()…"#` inside a test fixture never fires a rule),
//! - char literals vs lifetimes (`'a'` vs `<'a>`),
//! - raw identifiers (`r#type`),
//! - `::` folded into a single punct token so rules can match
//!   `Instant :: now` as a three-token sequence.
//!
//! Numeric literals are scanned leniently (one token per literal,
//! including type suffixes like `1.0f32`), which is all the int8-purity
//! rule needs.

/// Token classes surfaced to the rule engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (including raw identifiers, text kept as
    /// written: `r#type`).
    Ident,
    /// Punctuation. Single char, except `::` which is one token.
    Punct,
    /// Numeric literal, suffix included (`0xff`, `1.0f32`, `1_000`).
    Num,
    /// String literal of any flavor (`"…"`, `r#"…"#`, `b"…"`), text
    /// includes the delimiters.
    Str,
    /// Char or byte-char literal (`'a'`, `b'\n'`), delimiters included.
    Char,
    /// Lifetime (`'a`, `'static`), leading quote included.
    Lifetime,
    /// `//…` comment, text includes the slashes, excludes the newline.
    LineComment,
    /// `/* … */` comment (possibly nested), delimiters included.
    BlockComment,
}

/// One token with its 1-based source position.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
    /// 1-based column in *chars* (not bytes).
    pub col: u32,
}

impl Tok {
    pub fn is_comment(&self) -> bool {
        matches!(self.kind, TokKind::LineComment | TokKind::BlockComment)
    }
}

struct Lexer {
    chars: Vec<char>,
    i: usize,
    line: u32,
    col: u32,
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.i + ahead).copied()
    }

    /// Consume one char, tracking line/col.
    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.i).copied()?;
        self.i += 1;
        if c == '\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }

    fn bump_into(&mut self, buf: &mut String) {
        if let Some(c) = self.bump() {
            buf.push(c);
        }
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Tokenize `src`. Never fails: unrecognized bytes become single-char
/// `Punct` tokens, and unterminated literals/comments run to EOF —
/// a linter must degrade gracefully on code it half-understands.
pub fn tokenize(src: &str) -> Vec<Tok> {
    let mut lx = Lexer {
        chars: src.chars().collect(),
        i: 0,
        line: 1,
        col: 1,
    };
    let mut toks = Vec::new();

    while let Some(c) = lx.peek(0) {
        let (line, col) = (lx.line, lx.col);
        if c.is_whitespace() {
            lx.bump();
            continue;
        }

        // Comments.
        if c == '/' && lx.peek(1) == Some('/') {
            let mut text = String::new();
            while let Some(n) = lx.peek(0) {
                if n == '\n' {
                    break;
                }
                lx.bump_into(&mut text);
            }
            toks.push(Tok { kind: TokKind::LineComment, text, line, col });
            continue;
        }
        if c == '/' && lx.peek(1) == Some('*') {
            let mut text = String::new();
            lx.bump_into(&mut text); // '/'
            lx.bump_into(&mut text); // '*'
            let mut depth = 1usize;
            while depth > 0 {
                match (lx.peek(0), lx.peek(1)) {
                    (Some('/'), Some('*')) => {
                        depth += 1;
                        lx.bump_into(&mut text);
                        lx.bump_into(&mut text);
                    }
                    (Some('*'), Some('/')) => {
                        depth -= 1;
                        lx.bump_into(&mut text);
                        lx.bump_into(&mut text);
                    }
                    (Some(_), _) => lx.bump_into(&mut text),
                    (None, _) => break, // unterminated: run to EOF
                }
            }
            toks.push(Tok { kind: TokKind::BlockComment, text, line, col });
            continue;
        }

        // String-ish literals with `r` / `b` prefixes, and raw idents.
        if c == 'r' || c == 'b' {
            if let Some(tok) = scan_prefixed(&mut lx, line, col) {
                toks.push(tok);
                continue;
            }
            // else: fall through to the plain-identifier path below.
        }

        if c == '"' {
            toks.push(scan_string(&mut lx, line, col));
            continue;
        }

        if c == '\'' {
            toks.push(scan_quote(&mut lx, line, col));
            continue;
        }

        if c.is_ascii_digit() {
            toks.push(scan_number(&mut lx, line, col));
            continue;
        }

        if is_ident_start(c) {
            let mut text = String::new();
            while lx.peek(0).is_some_and(is_ident_continue) {
                lx.bump_into(&mut text);
            }
            toks.push(Tok { kind: TokKind::Ident, text, line, col });
            continue;
        }

        // Punctuation: fold `::` into one token, everything else is one
        // char.
        if c == ':' && lx.peek(1) == Some(':') {
            lx.bump();
            lx.bump();
            toks.push(Tok { kind: TokKind::Punct, text: "::".into(), line, col });
            continue;
        }
        let mut text = String::new();
        lx.bump_into(&mut text);
        toks.push(Tok { kind: TokKind::Punct, text, line, col });
    }

    toks
}

/// Handle tokens starting with `r` or `b`: raw strings (`r"`, `r#"`),
/// byte strings (`b"`), raw byte strings (`br"`, `br#"`), byte chars
/// (`b'x'`), and raw identifiers (`r#type`). Returns `None` when the
/// lookahead says this is just a plain identifier starting with r/b.
fn scan_prefixed(lx: &mut Lexer, line: u32, col: u32) -> Option<Tok> {
    let c0 = lx.peek(0)?;
    let c1 = lx.peek(1);
    match (c0, c1) {
        // r"…"  or r#…#"…"#…#
        ('r', Some('"')) => Some(scan_raw_string(lx, line, col, 1)),
        ('r', Some('#')) => {
            // Count hashes; a quote after them means raw string, an
            // ident char means raw identifier (`r#type`).
            let mut hashes = 0usize;
            while lx.peek(1 + hashes) == Some('#') {
                hashes += 1;
            }
            match lx.peek(1 + hashes) {
                Some('"') => Some(scan_raw_string(lx, line, col, 1)),
                Some(c) if is_ident_start(c) && hashes == 1 => {
                    // Raw identifier: consume `r#` + ident.
                    let mut text = String::new();
                    lx.bump_into(&mut text); // r
                    lx.bump_into(&mut text); // #
                    while lx.peek(0).is_some_and(is_ident_continue) {
                        lx.bump_into(&mut text);
                    }
                    Some(Tok { kind: TokKind::Ident, text, line, col })
                }
                _ => None,
            }
        }
        // b"…" — byte string with ordinary escapes.
        ('b', Some('"')) => {
            let mut tok;
            let mut text = String::new();
            lx.bump_into(&mut text); // b
            tok = scan_string(lx, line, col);
            text.push_str(&tok.text);
            tok.text = text;
            Some(tok)
        }
        // b'…' — byte char.
        ('b', Some('\'')) => {
            let mut text = String::new();
            lx.bump_into(&mut text); // b
            let inner = scan_quote(lx, line, col);
            text.push_str(&inner.text);
            Some(Tok { kind: TokKind::Char, text, line, col })
        }
        // br"…" / br#"…"#
        ('b', Some('r')) => {
            let mut hashes = 0usize;
            while lx.peek(2 + hashes) == Some('#') {
                hashes += 1;
            }
            if lx.peek(2 + hashes) == Some('"') {
                Some(scan_raw_string(lx, line, col, 2))
            } else {
                None
            }
        }
        _ => None,
    }
}

/// Scan a raw (byte) string starting at the current position, where
/// `prefix_len` chars of prefix (`r` or `br`) precede the hashes.
fn scan_raw_string(lx: &mut Lexer, line: u32, col: u32, prefix_len: usize) -> Tok {
    let mut text = String::new();
    for _ in 0..prefix_len {
        lx.bump_into(&mut text);
    }
    let mut hashes = 0usize;
    while lx.peek(0) == Some('#') {
        hashes += 1;
        lx.bump_into(&mut text);
    }
    lx.bump_into(&mut text); // opening quote
    loop {
        match lx.peek(0) {
            None => break, // unterminated
            Some('"') => {
                // Check for the closing `"` + `#`*hashes.
                let mut ok = true;
                for k in 0..hashes {
                    if lx.peek(1 + k) != Some('#') {
                        ok = false;
                        break;
                    }
                }
                lx.bump_into(&mut text);
                if ok {
                    for _ in 0..hashes {
                        lx.bump_into(&mut text);
                    }
                    break;
                }
            }
            Some(_) => lx.bump_into(&mut text),
        }
    }
    Tok { kind: TokKind::Str, text, line, col }
}

/// Scan an ordinary `"…"` string with backslash escapes.
fn scan_string(lx: &mut Lexer, line: u32, col: u32) -> Tok {
    let mut text = String::new();
    lx.bump_into(&mut text); // opening quote
    loop {
        match lx.peek(0) {
            None => break, // unterminated
            Some('\\') => {
                lx.bump_into(&mut text);
                lx.bump_into(&mut text); // escaped char (any, incl. `"` and `\`)
            }
            Some('"') => {
                lx.bump_into(&mut text);
                break;
            }
            Some(_) => lx.bump_into(&mut text),
        }
    }
    Tok { kind: TokKind::Str, text, line, col }
}

/// Disambiguate `'…` into a char literal or a lifetime.
///
/// Char literal iff: the quote is followed by an escape (`'\n'`), or
/// the char after the next one is a closing quote (`'a'`, `'('`).
/// Otherwise an ident-start char begins a lifetime (`'a`, `'static`).
fn scan_quote(lx: &mut Lexer, line: u32, col: u32) -> Tok {
    let n1 = lx.peek(1);
    let n2 = lx.peek(2);
    let is_char = match n1 {
        Some('\\') => true,
        Some(_) => n2 == Some('\''),
        None => false,
    };
    let mut text = String::new();
    if is_char {
        lx.bump_into(&mut text); // '
        if lx.peek(0) == Some('\\') {
            lx.bump_into(&mut text); // backslash
            lx.bump_into(&mut text); // escape head (n, u, ', …)
            // `\u{…}` escapes: run to the closing brace.
            if text.ends_with('u') && lx.peek(0) == Some('{') {
                while let Some(c) = lx.peek(0) {
                    lx.bump_into(&mut text);
                    if c == '}' {
                        break;
                    }
                }
            }
        } else {
            lx.bump_into(&mut text); // the char itself
        }
        if lx.peek(0) == Some('\'') {
            lx.bump_into(&mut text); // closing quote
        }
        Tok { kind: TokKind::Char, text, line, col }
    } else if n1.is_some_and(is_ident_start) {
        lx.bump_into(&mut text); // '
        while lx.peek(0).is_some_and(is_ident_continue) {
            lx.bump_into(&mut text);
        }
        Tok { kind: TokKind::Lifetime, text, line, col }
    } else {
        // A lone quote (malformed source): surface as punct and move on.
        lx.bump_into(&mut text);
        Tok { kind: TokKind::Punct, text, line, col }
    }
}

/// Scan a numeric literal leniently: digits, `_`, alphanumerics (hex
/// digits, exponent markers, type suffixes), plus an embedded `.` when
/// followed by a digit — so `0..10` stays two tokens and a range, while
/// `1.5e-3` and `1.0f32` each stay one token.
fn scan_number(lx: &mut Lexer, line: u32, col: u32) -> Tok {
    let mut text = String::new();
    loop {
        match lx.peek(0) {
            Some(c) if is_ident_continue(c) => {
                lx.bump_into(&mut text);
                // Exponent sign: `1e-3`, `2.5E+10`.
                if (c == 'e' || c == 'E')
                    && !text.starts_with("0x")
                    && matches!(lx.peek(0), Some('+') | Some('-'))
                    && lx.peek(1).is_some_and(|d| d.is_ascii_digit())
                {
                    lx.bump_into(&mut text);
                }
            }
            Some('.') if lx.peek(1).is_some_and(|d| d.is_ascii_digit()) => {
                lx.bump_into(&mut text);
            }
            _ => break,
        }
    }
    Tok { kind: TokKind::Num, text, line, col }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        tokenize(src)
            .into_iter()
            .map(|t| (t.kind, t.text))
            .collect()
    }

    fn code_texts(src: &str) -> Vec<String> {
        tokenize(src)
            .into_iter()
            .filter(|t| !t.is_comment())
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn idents_puncts_and_paamayim() {
        let ts = kinds("Instant::now()");
        assert_eq!(
            ts,
            vec![
                (TokKind::Ident, "Instant".to_string()),
                (TokKind::Punct, "::".to_string()),
                (TokKind::Ident, "now".to_string()),
                (TokKind::Punct, "(".to_string()),
                (TokKind::Punct, ")".to_string()),
            ]
        );
    }

    #[test]
    fn single_colon_stays_single() {
        let ts = kinds("x: HashMap<K, V>");
        assert_eq!(ts[1], (TokKind::Punct, ":".to_string()));
        assert_eq!(ts[2], (TokKind::Ident, "HashMap".to_string()));
    }

    #[test]
    fn strings_hide_their_contents() {
        // `.unwrap()` inside a string must not produce ident tokens.
        let ts = code_texts(r#"let s = "call .unwrap() here";"#);
        assert!(!ts.iter().any(|t| t == "unwrap"));
        assert!(ts.iter().any(|t| t.starts_with('"')));
    }

    #[test]
    fn raw_strings_with_hashes_and_embedded_quotes() {
        let src = r####"let s = r#"an "unsafe" Instant::now()"#; x"####;
        let ts = code_texts(src);
        assert!(!ts.iter().any(|t| t == "unsafe" || t == "Instant"));
        // The trailing `x` survives — the raw string closed correctly.
        assert_eq!(ts.last().unwrap(), "x");
    }

    #[test]
    fn raw_identifiers() {
        let ts = kinds("let r#type = 1;");
        assert!(ts.iter().any(|(k, t)| *k == TokKind::Ident && t == "r#type"));
    }

    #[test]
    fn byte_strings_and_byte_chars() {
        let ts = kinds(r#"(b"panic!", b'\n', br"todo!")"#);
        let strs: Vec<_> = ts
            .iter()
            .filter(|(k, _)| *k == TokKind::Str)
            .map(|(_, t)| t.clone())
            .collect();
        assert_eq!(strs.len(), 2, "{ts:?}");
        assert!(ts.iter().any(|(k, t)| *k == TokKind::Char && t == r"b'\n'"));
        assert!(!ts.iter().any(|(k, t)| *k == TokKind::Ident && t == "panic"));
    }

    #[test]
    fn nested_block_comments() {
        let src = "a /* outer /* inner Instant::now() */ still comment */ b";
        let ts = kinds(src);
        let idents: Vec<_> = ts
            .iter()
            .filter(|(k, _)| *k == TokKind::Ident)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(idents, vec!["a", "b"]);
        assert_eq!(
            ts.iter().filter(|(k, _)| *k == TokKind::BlockComment).count(),
            1
        );
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let ts = kinds("fn f<'a>(x: &'a str) -> char { 'x' }");
        assert!(ts
            .iter()
            .any(|(k, t)| *k == TokKind::Lifetime && t == "'a"));
        assert!(ts.iter().any(|(k, t)| *k == TokKind::Char && t == "'x'"));
        // 'static is a lifetime, not a char.
        let ts = kinds("&'static str");
        assert!(ts
            .iter()
            .any(|(k, t)| *k == TokKind::Lifetime && t == "'static"));
    }

    #[test]
    fn escaped_char_literals() {
        let ts = kinds(r"('\n', '\'', '\u{1F600}')");
        let chars: Vec<_> = ts
            .iter()
            .filter(|(k, _)| *k == TokKind::Char)
            .map(|(_, t)| t.clone())
            .collect();
        assert_eq!(chars, vec![r"'\n'", r"'\''", r"'\u{1F600}'"]);
    }

    #[test]
    fn numbers_with_suffixes_ranges_and_exponents() {
        let ts = kinds("0..10");
        let nums: Vec<_> = ts
            .iter()
            .filter(|(k, _)| *k == TokKind::Num)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(nums, vec!["0", "10"]);

        let ts = kinds("let x = 1.0f32 + 0xff + 1.5e-3 + 1_000;");
        let nums: Vec<_> = ts
            .iter()
            .filter(|(k, _)| *k == TokKind::Num)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(nums, vec!["1.0f32", "0xff", "1.5e-3", "1_000"]);
    }

    #[test]
    fn positions_are_one_based_lines_and_cols() {
        let ts = tokenize("ab\n  cd");
        assert_eq!((ts[0].line, ts[0].col), (1, 1));
        assert_eq!((ts[1].line, ts[1].col), (2, 3));
    }

    #[test]
    fn line_comments_capture_text() {
        let ts = tokenize("x // vcim:allow(determinism) pinned seed\ny");
        let c = ts.iter().find(|t| t.kind == TokKind::LineComment).unwrap();
        assert!(c.text.contains("vcim:allow(determinism)"));
        assert_eq!(c.line, 1);
    }
}
