//! CLI for the invariant lint pass.
//!
//! ```text
//! cargo run -p vcim-lint -- [ROOT …] [--json [PATH]] [--show-suppressed]
//! ```
//!
//! Findings print as `path:line:col: rule: message`. Exit code 0 when
//! the tree is clean, 1 on any unsuppressed finding, 2 on usage or IO
//! errors — so CI can gate on it directly.

use std::path::Path;
use std::process::ExitCode;

const USAGE: &str = "\
vcim-lint — invariant lint pass over the voxel-cim source tree

USAGE:
    vcim-lint [ROOT …] [OPTIONS]

ARGS:
    ROOT …              directories to lint (default: rust/src)

OPTIONS:
    --json [PATH]       emit the JSON report; to stdout when PATH is
                        omitted (PATH must end in .json)
    --show-suppressed   also print findings covered by vcim:allow
    --list-rules        print the rule names and exit
    -h, --help          this help
";

fn main() -> ExitCode {
    let mut roots: Vec<String> = Vec::new();
    let mut json_out: Option<Option<String>> = None; // Some(None) = stdout
    let mut show_suppressed = false;

    let mut args = std::env::args().skip(1).peekable();
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "-h" | "--help" => {
                print!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            "--list-rules" => {
                for r in vcim_lint::rules::RULES {
                    println!("{r}");
                }
                return ExitCode::SUCCESS;
            }
            "--show-suppressed" => show_suppressed = true,
            "--json" => {
                // An optional PATH operand: only a following `*.json`
                // argument is taken as the output path, so bare
                // `--json rust/src` keeps rust/src as a root.
                let takes_path = args.peek().is_some_and(|p| p.ends_with(".json"));
                json_out = Some(if takes_path { args.next() } else { None });
            }
            other if other.starts_with('-') => {
                eprintln!("vcim-lint: unknown option `{other}`\n\n{USAGE}");
                return ExitCode::from(2);
            }
            root => roots.push(root.to_string()),
        }
    }
    if roots.is_empty() {
        roots.push("rust/src".to_string());
    }

    let mut report = vcim_lint::Report::default();
    for root in &roots {
        let path = Path::new(root);
        if !path.is_dir() {
            eprintln!("vcim-lint: `{root}` is not a directory (run from the repo root?)");
            return ExitCode::from(2);
        }
        match vcim_lint::lint_tree(path) {
            Ok(mut r) => {
                // Make finding paths root-relative for clickability.
                for f in &mut r.findings {
                    f.file = format!("{}/{}", root.trim_end_matches('/'), f.file);
                }
                report.findings.extend(r.findings);
                report.files += r.files;
            }
            Err(e) => {
                eprintln!("vcim-lint: failed to lint `{root}`: {e}");
                return ExitCode::from(2);
            }
        }
    }

    let json_to_stdout = matches!(json_out, Some(None));
    if !json_to_stdout {
        for f in &report.findings {
            if f.suppressed && !show_suppressed {
                continue;
            }
            let tag = if f.suppressed { " (suppressed)" } else { "" };
            println!("{}:{}:{}: {}: {}{tag}", f.file, f.line, f.col, f.rule, f.message);
        }
        let by_rule: Vec<String> = report
            .rule_counts()
            .iter()
            .filter(|(_, (total, _))| *total > 0)
            .map(|(rule, (total, unsup))| format!("{rule}: {total} ({unsup} unsuppressed)"))
            .collect();
        println!(
            "vcim-lint: {} files, {} findings ({} suppressed, {} unsuppressed){}",
            report.files,
            report.total(),
            report.suppressed(),
            report.unsuppressed(),
            if by_rule.is_empty() {
                String::new()
            } else {
                format!(" — {}", by_rule.join(", "))
            }
        );
    }

    if let Some(path) = &json_out {
        let rendered = report.to_json(&roots).render();
        match path {
            None => println!("{rendered}"),
            Some(p) => {
                if let Err(e) = std::fs::write(p, rendered + "\n") {
                    eprintln!("vcim-lint: failed to write `{p}`: {e}");
                    return ExitCode::from(2);
                }
            }
        }
    }

    if report.unsuppressed() > 0 {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
