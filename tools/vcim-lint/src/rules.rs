//! The six repo-specific invariant rules (see DESIGN.md, "Static
//! analysis", for the rationale and the exact scopes).
//!
//! Rules are token-sequence heuristics over the lexer's output, scoped
//! by path. They are deliberately shallow — no type inference, no name
//! resolution — which keeps the linter dependency-free and fast, at the
//! cost of (a) file-local map tracking for the determinism rule and
//! (b) an identifier allowlist for the int8 quant boundary. Both
//! trade-offs are documented with the rule, and every heuristic miss
//! can be waived in-tree with a justified `// vcim:allow(<rule>)`.

use crate::lexer::{Tok, TokKind};
use std::collections::BTreeSet;

/// The rule registry. `vcim:allow` comments may only name these (plus
/// the engine-internal `lint-allow` meta rule, which is not
/// suppressible — malformed suppressions must be fixed, not waived).
pub const RULES: &[&str] = &[
    "determinism",
    "int8-purity",
    "panic-freedom",
    "safety-comments",
    "strict-config",
    "observer-purity",
];

/// Meta rule for malformed / unused / unjustified `vcim:allow`s.
pub const ALLOW_RULE: &str = "lint-allow";

/// A rule hit before suppression processing.
#[derive(Clone, Debug)]
pub struct RawFinding {
    pub rule: &'static str,
    pub line: u32,
    pub col: u32,
    pub message: String,
}

fn ident(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Ident && t.text == s
}

fn punct(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Punct && t.text == s
}

fn ident_in(t: &Tok, set: &[&str]) -> bool {
    t.kind == TokKind::Ident && set.iter().any(|s| *s == t.text)
}

fn push(out: &mut Vec<RawFinding>, rule: &'static str, t: &Tok, message: String) {
    out.push(RawFinding { rule, line: t.line, col: t.col, message });
}

/// Run every rule over one file. `rel` is the path relative to the
/// lint root (`/`-separated), which is what scopes each rule.
pub fn run_rules(rel: &str, code: &[Tok], comments: &[Tok]) -> Vec<RawFinding> {
    let mut out = Vec::new();
    determinism(rel, code, &mut out);
    int8_purity(rel, code, &mut out);
    panic_freedom(rel, code, &mut out);
    safety_comments(rel, code, comments, &mut out);
    strict_config(rel, code, &mut out);
    observer_purity(rel, code, &mut out);
    out
}

fn has_prefix(rel: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| rel.starts_with(p))
}

// ---------------------------------------------------------------- rules

/// Types whose iteration order is nondeterministic across runs.
const MAP_TYPES: &[&str] = &["HashMap", "HashSet", "FxHashMap", "FxHashSet"];

/// Methods that visit a map/set in hash order.
const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "retain",
    "into_iter",
    "into_keys",
    "into_values",
];

/// **determinism** — the bit-identity modules (`mapsearch/`, `spconv/`,
/// `pointcloud/`, `coordinator/`) must not read wall clocks or iterate
/// hash containers in an order-sensitive way. Keyed lookups
/// (`get`/`contains`/`insert`/`remove`) are fine; `Instant::now`,
/// `SystemTime`, and hash-order iteration are not.
///
/// Map tracking is file-local: a name counts as a hash container when
/// this file declares it with a `HashMap`/`HashSet`/`FxHashMap`/
/// `FxHashSet` type ascription (field, binding, or parameter) or
/// initializes it from one of those types. Iterating a hash container
/// imported from another module therefore needs a reviewer, not this
/// linter — keep such iteration out of the bit-identity modules.
fn determinism(rel: &str, code: &[Tok], out: &mut Vec<RawFinding>) {
    if !has_prefix(rel, &["mapsearch/", "spconv/", "pointcloud/", "coordinator/"]) {
        return;
    }

    // Clock reads.
    for i in 0..code.len() {
        let t = &code[i];
        if ident(t, "Instant")
            && code.get(i + 1).is_some_and(|n| punct(n, "::"))
            && code.get(i + 2).is_some_and(|n| ident(n, "now"))
        {
            push(
                out,
                "determinism",
                t,
                "wall-clock read (Instant::now) in a bit-identity module — route timing \
                 through obs::stopwatch()"
                    .into(),
            );
        }
        if ident(t, "SystemTime") {
            push(
                out,
                "determinism",
                t,
                "wall-clock read (SystemTime) in a bit-identity module".into(),
            );
        }
    }

    // Pass A: collect file-local hash-container names.
    // Matches `name: [&|&mut|std::collections::]HashMap…` (fields,
    // params, struct literals) and `name = [FxHashSet::…]` inits.
    let mut tracked: BTreeSet<String> = BTreeSet::new();
    for i in 0..code.len() {
        if code[i].kind != TokKind::Ident {
            continue;
        }
        let Some(sep) = code.get(i + 1) else { continue };
        if !(punct(sep, ":") || punct(sep, "=")) {
            continue;
        }
        for j in (i + 2)..code.len().min(i + 10) {
            let t = &code[j];
            if ident_in(t, MAP_TYPES) {
                tracked.insert(code[i].text.clone());
                break;
            }
            // Stop at tokens that end the type/init head position —
            // notably `<`, so `x: Vec<HashMap<…>>` does not track `x`
            // (iterating the Vec is deterministic).
            if t.kind == TokKind::Punct
                && matches!(t.text.as_str(), ";" | "," | ")" | "(" | "{" | "}" | "<")
            {
                break;
            }
        }
    }

    // Pass B: flag hash-order iteration over tracked names.
    for i in 0..code.len() {
        let t = &code[i];
        if t.kind == TokKind::Ident && tracked.contains(&t.text) {
            if code.get(i + 1).is_some_and(|n| punct(n, "."))
                && code.get(i + 2).is_some_and(|n| ident_in(n, ITER_METHODS))
                && code.get(i + 3).is_some_and(|n| punct(n, "("))
            {
                let method = &code[i + 2].text;
                push(
                    out,
                    "determinism",
                    t,
                    format!(
                        "hash-order iteration `{}.{}()` in a bit-identity module — iterate \
                         a sorted view or justify order-independence",
                        t.text, method
                    ),
                );
            }
        }
        // `for pat in [&][mut ][self.]tracked {`
        if ident(t, "in") {
            let mut j = i + 1;
            let mut skipped = 0;
            while j < code.len() && skipped < 4 {
                let n = &code[j];
                if punct(n, "&") || ident(n, "mut") || ident(n, "self") || punct(n, ".") {
                    j += 1;
                    skipped += 1;
                } else {
                    break;
                }
            }
            if j < code.len()
                && code[j].kind == TokKind::Ident
                && tracked.contains(&code[j].text)
                && code.get(j + 1).is_some_and(|n| punct(n, "{"))
            {
                push(
                    out,
                    "determinism",
                    &code[j],
                    format!(
                        "hash-order iteration `for … in {}` in a bit-identity module — \
                         iterate a sorted view or justify order-independence",
                        code[j].text
                    ),
                );
            }
        }
    }
}

/// Hot-datapath files for the int8-purity rule: the CIM PE model and
/// the gather/GEMM/scatter modules. The `cim/` analytic cost models
/// (energy, tile, mapping, w2b) model *costs* in floating point and are
/// deliberately out of scope — the rule protects the *datapath*.
const INT8_FILES: &[&str] = &[
    "cim/pe.rs",
    "spconv/quant.rs",
    "spconv/gather.rs",
    "spconv/layer.rs",
    "runtime/gemm.rs",
    "runtime/stub.rs",
];

/// The sanctioned quant boundary: float touches the datapath only in
/// these functions (feature quantization on ingress, the
/// dequant→ReLU→requant epilogue on egress, and the PJRT literal
/// marshals that feed them).
const INT8_ALLOW_FNS: &[&str] = &[
    "quantize_features",
    "dequant_relu_quant",
    "epilogue",
    "vfe_mean",
    "f32_literal",
];

/// **int8-purity** — no `f32`/`f64` (idents, `as` casts, or suffixed
/// literals) in the int8 datapath files outside the allowlisted quant
/// boundary functions. Tracks enclosing functions via brace depth; the
/// allowlist covers a function's whole signature + body.
fn int8_purity(rel: &str, code: &[Tok], out: &mut Vec<RawFinding>) {
    if !INT8_FILES.contains(&rel) {
        return;
    }

    // Attribute each token to its enclosing fn stack.
    let mut depth = 0usize;
    let mut stack: Vec<(String, Option<usize>)> = Vec::new();
    for i in 0..code.len() {
        let allowed = stack
            .iter()
            .any(|(name, _)| INT8_ALLOW_FNS.contains(&name.as_str()));
        let t = &code[i];

        if !allowed {
            let is_float_ident = ident(t, "f32") || ident(t, "f64");
            let is_float_suffix = t.kind == TokKind::Num
                && (t.text.ends_with("f32") || t.text.ends_with("f64"));
            if is_float_ident || is_float_suffix {
                push(
                    out,
                    "int8-purity",
                    t,
                    format!(
                        "`{}` in the int8 datapath — floats may only touch the allowlisted \
                         quant boundary ({})",
                        t.text,
                        INT8_ALLOW_FNS.join(", ")
                    ),
                );
            }
        }

        if ident(t, "fn") && code.get(i + 1).is_some_and(|n| n.kind == TokKind::Ident) {
            stack.push((code[i + 1].text.clone(), None));
        } else if punct(t, "{") {
            depth += 1;
            if let Some(top) = stack.last_mut() {
                if top.1.is_none() {
                    top.1 = Some(depth);
                }
            }
        } else if punct(t, "}") {
            if stack.last().is_some_and(|top| top.1 == Some(depth)) {
                stack.pop();
            }
            depth = depth.saturating_sub(1);
        } else if punct(t, ";") {
            // A `;` before the body closes a signature-only fn (trait
            // method declarations).
            if stack.last().is_some_and(|top| top.1.is_none()) {
                stack.pop();
            }
        }
    }
}

/// **panic-freedom** — the serving path (`serving/`, `coordinator/`,
/// `pipeline/`) returns typed errors; `.unwrap()`, `.expect(…)`,
/// `panic!`, `todo!`, `unimplemented!` are findings. Invariants that
/// genuinely cannot fail get a justified `vcim:allow(panic-freedom)`.
fn panic_freedom(rel: &str, code: &[Tok], out: &mut Vec<RawFinding>) {
    if !has_prefix(rel, &["serving/", "coordinator/", "pipeline/"]) {
        return;
    }
    for i in 0..code.len() {
        let t = &code[i];
        if punct(t, ".")
            && code
                .get(i + 1)
                .is_some_and(|n| ident_in(n, &["unwrap", "expect"]))
            && code.get(i + 2).is_some_and(|n| punct(n, "("))
        {
            let name = &code[i + 1].text;
            push(
                out,
                "panic-freedom",
                &code[i + 1],
                format!(
                    "`.{name}(…)` on the serving path — return a typed error, or justify \
                     the invariant with vcim:allow"
                ),
            );
        }
        if ident_in(t, &["panic", "todo", "unimplemented"])
            && code.get(i + 1).is_some_and(|n| punct(n, "!"))
        {
            push(
                out,
                "panic-freedom",
                t,
                format!("`{}!` on the serving path — return a typed error", t.text),
            );
        }
    }
}

/// **safety-comments** — every `unsafe` keyword (block, fn, impl) needs
/// a comment containing `SAFETY:` on the same line or within the three
/// lines above it. Applies tree-wide.
fn safety_comments(rel: &str, code: &[Tok], comments: &[Tok], out: &mut Vec<RawFinding>) {
    let _ = rel; // tree-wide
    for t in code {
        if !ident(t, "unsafe") {
            continue;
        }
        let covered = comments.iter().any(|c| {
            c.text.contains("SAFETY:") && c.line + 3 >= t.line && c.line <= t.line
        });
        if !covered {
            push(
                out,
                "safety-comments",
                t,
                "`unsafe` without a `// SAFETY:` comment on the preceding lines".into(),
            );
        }
    }
}

/// **strict-config** — raw `.get("dotted.key")` reads bypass the strict
/// typed helpers in `util/config.rs` (`int_or`/`float_or`/`bool_or`/
/// `str_or`/`usize_or`/`parsed_or`/`opt_*`), which is how
/// present-but-mistyped keys silently fall back to defaults. Applies
/// tree-wide except inside the helpers themselves.
fn strict_config(rel: &str, code: &[Tok], out: &mut Vec<RawFinding>) {
    if rel == "util/config.rs" {
        return;
    }
    for i in 0..code.len() {
        if punct(&code[i], ".")
            && code.get(i + 1).is_some_and(|n| ident(n, "get"))
            && code.get(i + 2).is_some_and(|n| punct(n, "("))
            && code.get(i + 3).is_some_and(|n| {
                n.kind == TokKind::Str && n.text.contains('.')
            })
        {
            push(
                out,
                "strict-config",
                &code[i + 1],
                format!(
                    "raw config read {} — use the strict typed helpers in util/config.rs",
                    code[i + 3].text
                ),
            );
        }
    }
}

/// Modules allowed to construct observers and read clocks: the
/// observability layer itself, the pipeline facade that wires it, the
/// CLI/bench/experiment harnesses that *measure*.
const OBSERVER_EXEMPT_PREFIXES: &[&str] = &["obs/", "pipeline/", "experiments/"];
const OBSERVER_EXEMPT_FILES: &[&str] = &["bench_util.rs", "main.rs"];

/// **observer-purity** — outside the exempt modules, nothing constructs
/// a `Recorder`/`MetricsRegistry` or reads a wall clock. Engine code
/// receives its `Recorder` from the facade and takes timestamps via
/// `obs::stopwatch()`, keeping the pure-observer guarantee auditable.
fn observer_purity(rel: &str, code: &[Tok], out: &mut Vec<RawFinding>) {
    if has_prefix(rel, OBSERVER_EXEMPT_PREFIXES) || OBSERVER_EXEMPT_FILES.contains(&rel) {
        return;
    }
    for i in 0..code.len() {
        let t = &code[i];
        let path2 = |a: &str, b: &str| {
            ident(t, a)
                && code.get(i + 1).is_some_and(|n| punct(n, "::"))
                && code.get(i + 2).is_some_and(|n| ident(n, b))
        };
        if path2("Recorder", "from_config") {
            push(
                out,
                "observer-purity",
                t,
                "Recorder construction outside obs/ and the facade — thread the facade's \
                 Recorder through instead"
                    .into(),
            );
        }
        if path2("MetricsRegistry", "new") {
            push(
                out,
                "observer-purity",
                t,
                "MetricsRegistry construction outside obs/ and the facade".into(),
            );
        }
        if path2("Instant", "now") {
            push(
                out,
                "observer-purity",
                t,
                "wall-clock read (Instant::now) outside obs/ — use obs::stopwatch()".into(),
            );
        }
        if ident(t, "SystemTime") {
            push(
                out,
                "observer-purity",
                t,
                "wall-clock read (SystemTime) outside obs/".into(),
            );
        }
    }
}
