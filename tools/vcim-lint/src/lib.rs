//! `vcim-lint` — the repo's zero-dependency invariant lint pass.
//!
//! The pipeline per file: tokenize ([`lexer`]) → locate `#[cfg(test)]`
//! regions (rules do not apply inside test modules) → run the six rules
//! ([`rules`]) → apply inline `// vcim:allow(<rule>) <justification>`
//! suppressions → report.
//!
//! Suppression contract:
//! - an allow comment covers findings of the named rule(s) on **its own
//!   line and the line directly below** it;
//! - a justification string after the closing paren is **mandatory** —
//!   a bare allow does not suppress and is itself a finding;
//! - unknown rule names and allows that match no finding are findings
//!   (`lint-allow`), so stale suppressions can't linger.
//!
//! The JSON writer is the main crate's std-only `util/json.rs`,
//! included by path so the tool stays dependency-free.

pub mod lexer;
pub mod rules;

#[path = "../../../rust/src/util/json.rs"]
pub mod json;

use json::Json;
use lexer::{Tok, TokKind};
use rules::{ALLOW_RULE, RULES};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One lint finding, suppressed or not.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Path relative to the lint root, `/`-separated.
    pub file: String,
    pub line: u32,
    pub col: u32,
    pub rule: String,
    pub message: String,
    /// True when a justified `vcim:allow` covers this finding.
    pub suppressed: bool,
    /// The justification text of the covering allow, if suppressed.
    pub justification: Option<String>,
}

/// The result of linting a tree: every finding plus file count.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub files: usize,
}

impl Report {
    pub fn total(&self) -> usize {
        self.findings.len()
    }

    pub fn unsuppressed(&self) -> usize {
        self.findings.iter().filter(|f| !f.suppressed).count()
    }

    pub fn suppressed(&self) -> usize {
        self.findings.iter().filter(|f| f.suppressed).count()
    }

    /// Per-rule `(total, unsuppressed)` counts, rule-name ordered.
    /// Every registered rule appears even at zero, so downstream
    /// consumers (the bench metadata block) see a stable shape.
    pub fn rule_counts(&self) -> BTreeMap<String, (usize, usize)> {
        let mut counts: BTreeMap<String, (usize, usize)> = BTreeMap::new();
        for rule in RULES {
            counts.insert((*rule).to_string(), (0, 0));
        }
        for f in &self.findings {
            let e = counts.entry(f.rule.clone()).or_insert((0, 0));
            e.0 += 1;
            if !f.suppressed {
                e.1 += 1;
            }
        }
        counts
    }

    /// The machine-readable report (`--json`).
    pub fn to_json(&self, roots: &[String]) -> Json {
        let rules_obj = Json::Obj(
            self.rule_counts()
                .into_iter()
                .map(|(rule, (total, unsup))| {
                    (
                        rule,
                        Json::obj(vec![
                            ("total", Json::UInt(total as u64)),
                            ("unsuppressed", Json::UInt(unsup as u64)),
                        ]),
                    )
                })
                .collect(),
        );
        let findings = Json::Arr(
            self.findings
                .iter()
                .map(|f| {
                    let mut pairs = vec![
                        ("file", Json::str(&f.file)),
                        ("line", Json::UInt(f.line as u64)),
                        ("col", Json::UInt(f.col as u64)),
                        ("rule", Json::str(&f.rule)),
                        ("message", Json::str(&f.message)),
                        ("suppressed", Json::Bool(f.suppressed)),
                    ];
                    if let Some(j) = &f.justification {
                        pairs.push(("justification", Json::str(j)));
                    }
                    Json::obj(pairs)
                })
                .collect(),
        );
        Json::obj(vec![
            ("tool", Json::str("vcim-lint")),
            (
                "roots",
                Json::Arr(roots.iter().map(|r| Json::str(r)).collect()),
            ),
            ("files", Json::UInt(self.files as u64)),
            ("total", Json::UInt(self.total() as u64)),
            ("unsuppressed", Json::UInt(self.unsuppressed() as u64)),
            ("suppressed", Json::UInt(self.suppressed() as u64)),
            ("rules", rules_obj),
            ("findings", findings),
        ])
    }
}

/// An inline suppression comment, parsed from `// vcim:allow(rule[,
/// rule…]) justification`.
#[derive(Debug)]
struct Allow {
    line: u32,
    rules: Vec<String>,
    justification: Option<String>,
    malformed: bool,
    used: bool,
}

fn parse_allows(comments: &[Tok]) -> Vec<Allow> {
    let mut out = Vec::new();
    for c in comments {
        let Some(at) = c.text.find("vcim:allow") else { continue };
        let rest = &c.text[at + "vcim:allow".len()..];
        let (rules_part, tail, malformed) = match (rest.strip_prefix('('), rest.find(')')) {
            (Some(_), Some(close)) => (&rest[1..close], &rest[close + 1..], false),
            _ => ("", "", true),
        };
        let rules: Vec<String> = rules_part
            .split(',')
            .map(|r| r.trim().to_string())
            .filter(|r| !r.is_empty())
            .collect();
        let tail = tail.trim().trim_start_matches([':', '-']).trim();
        let justification = if tail.is_empty() {
            None
        } else {
            Some(tail.to_string())
        };
        out.push(Allow {
            line: c.line,
            rules,
            justification,
            malformed: malformed || rules.is_empty(),
            used: false,
        });
    }
    out
}

/// Line ranges covered by `#[cfg(test)]` items (the trailing unit-test
/// module in each source file). Rules do not fire inside them.
fn test_ranges(code: &[Tok]) -> Vec<(u32, u32)> {
    fn punct(t: &Tok, s: &str) -> bool {
        t.kind == TokKind::Punct && t.text == s
    }
    fn ident(t: &Tok, s: &str) -> bool {
        t.kind == TokKind::Ident && t.text == s
    }

    let mut out = Vec::new();
    let mut i = 0;
    while i < code.len() {
        let is_cfg_attr = punct(&code[i], "#")
            && code.get(i + 1).is_some_and(|t| punct(t, "["))
            && code.get(i + 2).is_some_and(|t| ident(t, "cfg"))
            && code.get(i + 3).is_some_and(|t| punct(t, "("));
        if !is_cfg_attr {
            i += 1;
            continue;
        }
        // Scan the cfg(...) group: it marks a test region when it
        // mentions `test` and is not negated (`cfg(not(test))` is the
        // opposite region — never skip those).
        let mut j = i + 4;
        let mut paren_depth = 1usize;
        let (mut has_test, mut has_not) = (false, false);
        while j < code.len() && paren_depth > 0 {
            let t = &code[j];
            if punct(t, "(") {
                paren_depth += 1;
            } else if punct(t, ")") {
                paren_depth -= 1;
            } else if ident(t, "test") {
                has_test = true;
            } else if ident(t, "not") {
                has_not = true;
            }
            j += 1;
        }
        let closes = code.get(j).is_some_and(|t| punct(t, "]"));
        if !(has_test && !has_not && closes) {
            i = j;
            continue;
        }
        let start_line = code[i].line;

        // Skip any further attributes on the same item.
        let mut k = j + 1;
        while k + 1 < code.len() && punct(&code[k], "#") && punct(&code[k + 1], "[") {
            let mut bracket_depth = 1usize;
            k += 2;
            while k < code.len() && bracket_depth > 0 {
                if punct(&code[k], "[") {
                    bracket_depth += 1;
                } else if punct(&code[k], "]") {
                    bracket_depth -= 1;
                }
                k += 1;
            }
        }

        // The item runs to its `;` (e.g. `#[cfg(test)] use …;`) or to
        // the close of its brace block.
        let mut end_line = u32::MAX; // unterminated → rest of file
        while k < code.len() {
            if punct(&code[k], ";") {
                end_line = code[k].line;
                break;
            }
            if punct(&code[k], "{") {
                let mut brace_depth = 1usize;
                let mut m = k + 1;
                while m < code.len() {
                    if punct(&code[m], "{") {
                        brace_depth += 1;
                    } else if punct(&code[m], "}") {
                        brace_depth -= 1;
                        if brace_depth == 0 {
                            end_line = code[m].line;
                            break;
                        }
                    }
                    m += 1;
                }
                break;
            }
            k += 1;
        }
        out.push((start_line, end_line));
        i = j + 1;
    }
    out
}

/// Lint one file's source. `rel` must be `/`-separated and relative to
/// the lint root (rule scoping keys off it).
pub fn lint_file(rel: &str, src: &str) -> Vec<Finding> {
    let toks = lexer::tokenize(src);
    let comments: Vec<Tok> = toks.iter().filter(|t| t.is_comment()).cloned().collect();
    let code: Vec<Tok> = toks.into_iter().filter(|t| !t.is_comment()).collect();

    let ranges = test_ranges(&code);
    let in_test = |line: u32| ranges.iter().any(|&(a, b)| line >= a && line <= b);

    let raw: Vec<rules::RawFinding> = rules::run_rules(rel, &code, &comments)
        .into_iter()
        .filter(|f| !in_test(f.line))
        .collect();

    // Allows inside test regions are ignored entirely (nothing fires
    // there, so they could only ever be "unused" noise).
    let mut allows: Vec<Allow> = parse_allows(&comments)
        .into_iter()
        .filter(|a| !in_test(a.line))
        .collect();

    let mut findings = Vec::new();
    for rf in raw {
        let mut suppressed = false;
        let mut justification = None;
        for a in allows.iter_mut() {
            let covers_line = a.line == rf.line || a.line + 1 == rf.line;
            if covers_line && !a.malformed && a.rules.iter().any(|r| r == rf.rule) {
                a.used = true;
                if let Some(j) = &a.justification {
                    suppressed = true;
                    justification = Some(j.clone());
                }
                break;
            }
        }
        findings.push(Finding {
            file: rel.to_string(),
            line: rf.line,
            col: rf.col,
            rule: rf.rule.to_string(),
            message: rf.message,
            suppressed,
            justification,
        });
    }

    // Meta findings about the allows themselves. Never suppressible.
    for a in &allows {
        let mut problems: Vec<String> = Vec::new();
        if a.malformed {
            problems.push(
                "malformed vcim:allow — expected `vcim:allow(<rule>) <justification>`".into(),
            );
        }
        for r in &a.rules {
            if !RULES.contains(&r.as_str()) {
                problems.push(format!(
                    "unknown rule `{r}` in vcim:allow (rules: {})",
                    RULES.join(", ")
                ));
            }
        }
        if !a.malformed && a.justification.is_none() {
            problems.push(
                "vcim:allow without a justification — say why the invariant holds".into(),
            );
        }
        if !a.malformed && a.justification.is_some() && !a.used {
            problems.push("unused vcim:allow — no finding on this or the next line".into());
        }
        for message in problems {
            findings.push(Finding {
                file: rel.to_string(),
                line: a.line,
                col: 1,
                rule: ALLOW_RULE.to_string(),
                message,
                suppressed: false,
                justification: None,
            });
        }
    }

    findings.sort_by_key(|f| (f.line, f.col));
    findings
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (recursively, path-sorted).
pub fn lint_tree(root: &Path) -> std::io::Result<Report> {
    let mut files = Vec::new();
    walk(root, &mut files)?;
    let mut report = Report::default();
    for path in &files {
        let bytes = std::fs::read(path)?;
        let src = String::from_utf8_lossy(&bytes);
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        report.findings.extend(lint_file(&rel, &src));
        report.files += 1;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allow_parses_rules_and_justification() {
        let toks = lexer::tokenize("// vcim:allow(determinism, panic-freedom) seed is pinned\n");
        let comments: Vec<Tok> = toks.into_iter().filter(|t| t.is_comment()).collect();
        let allows = parse_allows(&comments);
        assert_eq!(allows.len(), 1);
        assert_eq!(allows[0].rules, vec!["determinism", "panic-freedom"]);
        assert_eq!(allows[0].justification.as_deref(), Some("seed is pinned"));
        assert!(!allows[0].malformed);
    }

    #[test]
    fn bare_allow_does_not_suppress_and_is_flagged() {
        let src = "\
mod coordinator {}
// vcim:allow(observer-purity)
fn f() { let t = std::time::Instant::now(); }
";
        let fs = lint_file("dataset/mod.rs", src);
        // The observer-purity finding stays unsuppressed…
        assert!(fs
            .iter()
            .any(|f| f.rule == "observer-purity" && !f.suppressed));
        // …and the bare allow is itself a finding.
        assert!(fs
            .iter()
            .any(|f| f.rule == ALLOW_RULE && f.message.contains("justification")));
    }

    #[test]
    fn justified_allow_suppresses_same_and_next_line() {
        let src = "\
// vcim:allow(observer-purity) harness-local stopwatch for a self-test
fn f() { let t = std::time::Instant::now(); }
";
        let fs = lint_file("dataset/mod.rs", src);
        let f = fs.iter().find(|f| f.rule == "observer-purity").unwrap();
        assert!(f.suppressed);
        assert_eq!(
            f.justification.as_deref(),
            Some("harness-local stopwatch for a self-test")
        );
        assert!(!fs.iter().any(|f| f.rule == ALLOW_RULE));
    }

    #[test]
    fn unknown_rule_and_unused_allow_are_findings() {
        let src = "\
// vcim:allow(no-such-rule) whatever
fn f() {}
// vcim:allow(determinism) nothing here to suppress
fn g() {}
";
        let fs = lint_file("mapsearch/x.rs", src);
        assert!(fs
            .iter()
            .any(|f| f.rule == ALLOW_RULE && f.message.contains("unknown rule")));
        assert!(fs
            .iter()
            .any(|f| f.rule == ALLOW_RULE && f.message.contains("unused")));
    }

    #[test]
    fn test_modules_are_exempt() {
        let src = "\
fn live() {}
#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let _ = std::time::Instant::now();
        let x: Option<i32> = None;
        x.unwrap();
    }
}
";
        let fs = lint_file("coordinator/stream.rs", src);
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn cfg_not_test_is_not_a_test_region() {
        let src = "\
#[cfg(not(test))]
fn live() { let _ = std::time::Instant::now(); }
";
        let fs = lint_file("coordinator/stream.rs", src);
        assert!(fs.iter().any(|f| f.rule == "determinism"));
    }

    #[test]
    fn rule_counts_have_stable_shape() {
        let report = Report::default();
        let counts = report.rule_counts();
        for rule in RULES {
            assert!(counts.contains_key(*rule));
        }
    }

    #[test]
    fn json_report_renders() {
        let report = Report {
            findings: vec![Finding {
                file: "a.rs".into(),
                line: 3,
                col: 7,
                rule: "determinism".into(),
                message: "m".into(),
                suppressed: false,
                justification: None,
            }],
            files: 1,
        };
        let s = report.to_json(&["rust/src".into()]).render();
        assert!(s.contains("\"tool\":\"vcim-lint\""));
        assert!(s.contains("\"unsuppressed\":1"));
        assert!(s.contains("\"determinism\""));
    }
}
