//! Fixture tests: every rule fires on its seeded-violation tree and
//! stays silent on the clean twin, and the suppression contract holds
//! end to end through `lint_tree` (walking, rel-path scoping, allows).
//!
//! The fixture `.rs` files under `tests/fixtures/` are lint *inputs*,
//! never compiled — some reference types that do not exist.

use std::path::{Path, PathBuf};
use vcim_lint::Report;

fn fixture(rel: &str) -> PathBuf {
    let base = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures");
    base.join(rel)
}

fn lint(rel: &str) -> Report {
    vcim_lint::lint_tree(&fixture(rel)).expect("fixture tree readable")
}

fn unsuppressed_of(r: &Report, rule: &str) -> usize {
    let mut n = 0;
    for f in &r.findings {
        if f.rule == rule && !f.suppressed {
            n += 1;
        }
    }
    n
}

fn has_message(r: &Report, rule: &str, needle: &str) -> bool {
    for f in &r.findings {
        if f.rule == rule && f.message.contains(needle) {
            return true;
        }
    }
    false
}

/// The bad tree has at least `min` unsuppressed findings of `rule`;
/// the clean twin has no findings of any rule at all.
fn assert_fires(dir: &str, rule: &str, min: usize) {
    let bad = lint(&format!("{dir}/bad"));
    let hits = unsuppressed_of(&bad, rule);
    assert!(
        hits >= min,
        "{dir}/bad: expected >= {min} unsuppressed `{rule}` findings, got {hits}: {:?}",
        bad.findings
    );
    let clean = lint(&format!("{dir}/clean"));
    assert!(
        clean.findings.is_empty(),
        "{dir}/clean should be silent, got: {:?}",
        clean.findings
    );
}

#[test]
fn determinism_fires_on_bad_and_not_on_clean() {
    // One clock read + one hash-order iteration.
    assert_fires("determinism", "determinism", 2);
}

#[test]
fn int8_purity_fires_on_bad_and_not_on_clean() {
    // Return type, `as f32` cast, and `0.5f32` suffix.
    assert_fires("int8", "int8-purity", 3);
}

#[test]
fn panic_freedom_fires_on_bad_and_not_on_clean() {
    // `.unwrap()` and `panic!`.
    assert_fires("panic", "panic-freedom", 2);
}

#[test]
fn safety_comments_fire_on_bad_and_not_on_clean() {
    assert_fires("safety", "safety-comments", 1);
}

#[test]
fn strict_config_fires_on_bad_and_not_on_clean() {
    assert_fires("config", "strict-config", 1);
}

#[test]
fn observer_purity_fires_on_bad_and_not_on_clean() {
    // Recorder construction + direct clock read; the clean twin holds
    // the same code inside exempt `obs/` plus a stopwatch() caller.
    assert_fires("observer", "observer-purity", 2);
}

#[test]
fn justified_allow_suppresses_and_counts_stay_consistent() {
    let r = lint("suppression/justified");
    assert_eq!(r.files, 1);
    assert_eq!(r.total(), 1, "{:?}", r.findings);
    assert_eq!(r.unsuppressed(), 0, "{:?}", r.findings);
    let f = &r.findings[0];
    assert_eq!(f.file, "mapsearch/cache.rs");
    assert_eq!(f.rule, "determinism");
    assert!(f.suppressed);
    let just = f.justification.as_deref();
    assert_eq!(just, Some("max over values is order-independent"));
    assert_eq!(r.rule_counts()["determinism"], (1, 0));
}

#[test]
fn bare_allow_does_not_suppress_and_is_itself_flagged() {
    let r = lint("suppression/bare");
    assert_eq!(unsuppressed_of(&r, "determinism"), 1, "{:?}", r.findings);
    assert!(
        has_message(&r, "lint-allow", "justification"),
        "{:?}",
        r.findings
    );
}

#[test]
fn json_report_over_fixtures_has_stable_shape() {
    let r = lint("suppression/justified");
    let roots = vec!["tests/fixtures/suppression/justified".to_string()];
    let json = r.to_json(&roots);
    let s = json.render();
    assert!(s.contains("\"tool\":\"vcim-lint\""));
    assert!(s.contains("\"unsuppressed\":0"));
    // Every rule appears even at zero findings.
    let rules = [
        "determinism",
        "int8-purity",
        "panic-freedom",
        "safety-comments",
        "strict-config",
        "observer-purity",
    ];
    for rule in rules {
        assert!(s.contains(&format!("\"{rule}\"")), "{rule} missing in {s}");
    }
}
