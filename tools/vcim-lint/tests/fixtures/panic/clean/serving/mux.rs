//! Fixture twin: the serving path returns options and typed errors.
//! Never compiled — lint input only.

pub fn pick(xs: &[u32]) -> Option<u32> {
    xs.first().copied()
}
