//! Fixture: panics on the serving path. Never compiled — lint input
//! only.

pub fn pick(xs: &[u32]) -> u32 {
    *xs.first().unwrap()
}

pub fn must(flag: bool) {
    if !flag {
        panic!("flag required");
    }
}
