//! Fixture twin: keyed lookups only — no hash-order iteration, no
//! clock reads. Never compiled — lint input only.

use std::collections::HashMap;

pub fn lookup(entries: &HashMap<u64, u64>, k: u64) -> Option<u64> {
    entries.get(&k).copied()
}

pub fn in_key_order(entries: &HashMap<u64, u64>, keys: &mut Vec<u64>) -> Vec<u64> {
    keys.sort_unstable();
    keys.iter().filter_map(|k| entries.get(k)).copied().collect()
}
