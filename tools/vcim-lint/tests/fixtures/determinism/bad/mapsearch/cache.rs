//! Fixture: hash-order iteration and wall-clock reads in a
//! bit-identity module. Never compiled — lint input only.

use std::collections::HashMap;
use std::time::Instant;

pub fn lru_scan(entries: &HashMap<u64, u64>) -> u64 {
    let t = Instant::now();
    let mut worst = 0;
    for (_, &v) in entries.iter() {
        worst = worst.max(v);
    }
    let _ = t.elapsed();
    worst
}
