//! Fixture twin: the strict typed helper path. Never compiled — lint
//! input only.

pub fn frames(cfg: &Config) -> Result<i64> {
    cfg.int_or("dataset.frames", 0)
}
