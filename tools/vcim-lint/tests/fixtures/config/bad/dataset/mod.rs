//! Fixture: raw dotted config read bypassing the typed helpers.
//! Never compiled — lint input only.

pub fn frames(cfg: &Config) -> i64 {
    match cfg.get("dataset.frames") {
        Some(v) => v.as_int().unwrap_or(0),
        None => 0,
    }
}
