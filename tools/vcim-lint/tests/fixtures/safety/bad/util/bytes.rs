//! Fixture: `unsafe` with no SAFETY comment. Never compiled — lint
//! input only.

pub fn as_bytes(v: &[i8]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len()) }
}
