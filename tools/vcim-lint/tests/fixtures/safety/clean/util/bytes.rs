//! Fixture twin: the same cast with its safety argument written down.
//! Never compiled — lint input only.

pub fn as_bytes(v: &[i8]) -> &[u8] {
    // SAFETY: i8 and u8 share size and alignment; pointer and length
    // come from the borrowed slice and the result inherits its
    // lifetime.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len()) }
}
