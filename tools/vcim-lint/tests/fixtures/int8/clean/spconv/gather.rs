//! Fixture twin: floats appear only inside the allowlisted quant
//! boundary function. Never compiled — lint input only.

pub fn quantize_features(x: &[f32], scale: f32) -> Vec<i8> {
    x.iter().map(|&v| (v / scale) as i8).collect()
}

pub fn gather_rows(rows: &[i8]) -> Vec<i8> {
    rows.to_vec()
}
