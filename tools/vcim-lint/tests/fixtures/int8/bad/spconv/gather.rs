//! Fixture: floats leak into the int8 datapath outside the quant
//! boundary. Never compiled — lint input only.

pub fn scale_row(row: &[i8]) -> Vec<f32> {
    row.iter().map(|&v| v as f32 * 0.5f32).collect()
}
