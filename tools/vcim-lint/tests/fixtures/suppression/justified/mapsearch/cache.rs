//! Fixture: a justified allow suppresses the finding on the next
//! line. Never compiled — lint input only.

use std::collections::HashMap;

pub fn max_val(entries: &HashMap<u64, u64>) -> u64 {
    // vcim:allow(determinism) max over values is order-independent
    entries.values().copied().max().unwrap_or(0)
}
