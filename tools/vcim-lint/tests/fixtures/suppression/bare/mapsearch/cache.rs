//! Fixture: a bare allow suppresses nothing and is itself flagged.
//! Never compiled — lint input only.

use std::collections::HashMap;

pub fn max_val(entries: &HashMap<u64, u64>) -> u64 {
    // vcim:allow(determinism)
    entries.values().copied().max().unwrap_or(0)
}
