//! Fixture: engine-side code constructs an observer and reads the
//! clock directly. Never compiled — lint input only.

pub fn produce(cfg: &Config) -> Frame {
    let rec = Recorder::from_config(cfg);
    let t0 = Instant::now();
    Frame { produced: t0, rec }
}
