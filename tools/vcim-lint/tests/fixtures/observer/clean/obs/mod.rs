//! Fixture twin: the observability layer itself may read clocks and
//! build observers. Never compiled — lint input only.

pub fn stopwatch() -> Instant {
    Instant::now()
}

pub fn build(cfg: &Config) -> Recorder {
    Recorder::from_config(cfg)
}
