//! Fixture twin: engine code takes timestamps through the sanctioned
//! funnel. Never compiled — lint input only.

pub fn produce() -> u128 {
    let t0 = crate::obs::stopwatch();
    t0.elapsed().as_nanos()
}
