//! Map-search explorer: sweep any searcher over any (resolution,
//! sparsity, distribution) point and print the full access breakdown —
//! the tool for reproducing Fig. 2(d)/Fig. 9 style studies beyond the
//! paper's exact configurations.
//!
//! ```sh
//! cargo run --release --example mapsearch_explorer -- \
//!     --extent 1408x1600x41 --sparsity 0.005 --clustered --fifo 64
//! ```

use voxel_cim::experiments::{sweep_tensor, sweep_tensor_clustered};
use voxel_cim::geom::Extent3;
use voxel_cim::mapsearch::{BlockDoms, Doms, MapSearch, OutputMajor, SearcherKind};
use voxel_cim::sparse::hash_search::hash_table_bytes;
use voxel_cim::util::cli::Args;

fn parse_extent(s: &str) -> Extent3 {
    let parts: Vec<usize> = s.split('x').map(|t| t.parse().expect("extent int")).collect();
    assert_eq!(parts.len(), 3, "extent must be XxYxZ");
    Extent3::new(parts[0], parts[1], parts[2])
}

fn main() {
    let args = Args::new("Sweep all map-search dataflows over one configuration")
        .opt("extent", "352x400x10", "voxel grid XxYxZ")
        .opt("sparsity", "0.005", "2.5D sparsity (N = X*Y*s)")
        .opt("fifo", "64", "row-FIFO / sorter-buffer capacity in voxels")
        .opt("bx", "2", "block-DOMS partition in x")
        .opt("by", "8", "block-DOMS partition in y")
        .opt("seed", "3", "occupancy seed")
        .switch("clustered", "use the dense-cluster distribution (Fig. 2b)")
        .parse();

    let extent = parse_extent(args.get("extent"));
    let s = args.get_f64("sparsity");
    let t = if args.get_bool("clustered") {
        sweep_tensor_clustered(extent, s, args.get_u64("seed"))
    } else {
        sweep_tensor(extent, s, args.get_u64("seed"))
    };
    let fifo = args.get_usize("fifo");
    println!(
        "grid {extent:?} | N = {} voxels | table-aided baseline table: {:.1} MiB",
        t.len(),
        hash_table_bytes(extent) as f64 / (1024.0 * 1024.0)
    );
    println!(
        "{:<24} {:>10} {:>12} {:>14} {:>12}",
        "searcher", "reads/N", "writes/N", "sorter passes", "table bytes"
    );

    let run = |name: &str, rb_stats: (voxel_cim::sparse::Rulebook, voxel_cim::mapsearch::AccessStats)| {
        let (rb, st) = rb_stats;
        println!(
            "{:<24} {:>10.2} {:>12.3} {:>14} {:>12}   ({} pairs)",
            name,
            st.voxel_reads as f64 / t.len() as f64,
            st.voxel_writes as f64 / t.len() as f64,
            st.sorter_passes,
            st.table_bytes,
            rb.len()
        );
    };

    // Every selectable dataflow at its paper-default parameters, built
    // through the same SearcherKind dispatch the serving path uses.
    for kind in SearcherKind::ALL {
        let s = kind.build();
        run(kind.key(), s.search_subm(&t, 3));
    }

    // Tuned variants under the CLI's buffer / partition knobs.
    println!("\ntuned (--fifo {fifo}, --bx/--by):");
    run(
        "output-major (tuned)",
        OutputMajor {
            buffer_voxels: fifo,
            sorter_len: 64,
        }
        .search_subm(&t, 3),
    );
    run(
        "doms (tuned)",
        Doms {
            fifo_voxels: fifo,
            sorter_len: 64,
        }
        .search_subm(&t, 3),
    );
    let bd = BlockDoms {
        bx: args.get_usize("bx"),
        by: args.get_usize("by"),
        fifo_voxels: fifo,
        sorter_len: 64,
    };
    run(
        &format!("block-doms ({},{})", bd.bx, bd.by),
        bd.search_subm(&t, 3),
    );
}
