//! Quickstart: the whole stack in ~60 lines.
//!
//! Generates a synthetic LiDAR frame, voxelizes it, builds the IN-OUT map
//! with the searcher named in `examples/configs/default.toml` (DOMS by
//! default — edit `searcher = "..."` to swap the dataflow), and runs one
//! subm3 sparse convolution through the compiled PJRT artifact (falling
//! back to the native engine when `make artifacts` hasn't been run).
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use voxel_cim::geom::Extent3;
use voxel_cim::mapsearch::{MapSearch, SearcherKind};
use voxel_cim::pointcloud::scene::SceneConfig;
use voxel_cim::pointcloud::vfe::{Vfe, VfeKind};
use voxel_cim::pointcloud::voxelize::Voxelizer;
use voxel_cim::runtime::{Runtime, RuntimeConfig};
use voxel_cim::sparse::tensor::SparseTensor;
use voxel_cim::spconv::layer::{GemmEngine, LayerWeights, NativeEngine, SpconvLayer};

fn main() -> voxel_cim::Result<()> {
    // 1. A synthetic urban LiDAR frame (KITTI substitute — see DESIGN.md).
    let points = SceneConfig::default().with_points(20_000).generate();
    println!("scene: {} LiDAR returns", points.len());

    // 2. Voxelize at the paper's low-resolution grid and extract features.
    let extent = Extent3::new(352, 400, 10);
    let vx = Voxelizer::new((70.4, 80.0, 4.0), extent, 32);
    let grid = vx.voxelize(&points);
    let (feats, scale) = Vfe::new(VfeKind::Simple).extract_i8(&grid);
    println!(
        "voxelized: {} occupied voxels (sparsity {:.5}, quant scale {:.4})",
        grid.len(),
        grid.sparsity(),
        scale
    );
    let input = SparseTensor::new(
        extent,
        grid.voxels
            .iter()
            .enumerate()
            .map(|(i, v)| (v.coord, feats[i * 4..(i + 1) * 4].to_vec()))
            .collect(),
        4,
    );

    // 3. Map search through the engine layer's pluggable searcher — any
    // kind from the run config builds a bit-identical rulebook. Only a
    // *missing* config falls back to defaults; a config that fails to
    // parse (or names an unknown searcher) is a real error.
    let cfg_path = "examples/configs/default.toml";
    let cfg = if std::path::Path::new(cfg_path).exists() {
        voxel_cim::util::config::Config::load(cfg_path)?
    } else {
        voxel_cim::util::config::Config::default()
    };
    let kind = cfg.parsed_or("runner.searcher", SearcherKind::Doms)?;
    let searcher = kind.build();
    let (rulebook, stats) =
        searcher.search(&input, voxel_cim::sparse::rulebook::ConvKind::subm3());
    println!(
        "{}: {} IN-OUT pairs | off-chip access {:.2}x N | {} sorter passes | table {} B",
        searcher.name(),
        rulebook.len(),
        stats.normalized(input.len()),
        stats.sorter_passes,
        stats.table_bytes
    );

    // 4. One subm3 layer (4 -> 16 channels) through the CIM GEMM.
    let layer = SpconvLayer::new(LayerWeights::random(27, 4, 16, 7), 256);
    let out = match Runtime::load(&RuntimeConfig::discover()) {
        Ok(mut rt) => {
            println!("engine: PJRT CPU (AOT Pallas artifacts)");
            let out = layer.execute(&input, &rulebook, &mut rt)?;
            println!("PJRT GEMM dispatches: {}", rt.dispatches());
            out
        }
        Err(e) => {
            println!("engine: native fallback ({e:#})");
            layer.execute(&input, &rulebook, &mut NativeEngine::default())?
        }
    };
    println!(
        "spconv3d: {} -> {} voxels, {} channels, {} GEMM tiles",
        input.len(),
        out.tensor.len(),
        out.tensor.channels,
        out.gemm_calls
    );
    let active = out.tensor.features.iter().filter(|&&v| v != 0).count();
    println!(
        "output features: {:.1}% non-zero after ReLU",
        100.0 * active as f64 / out.tensor.features.len() as f64
    );
    Ok(())
}
