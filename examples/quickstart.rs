//! Quickstart: the whole stack in ~60 lines, through the pipeline facade.
//!
//! Generates a synthetic LiDAR frame, voxelizes it, then builds a
//! `Pipeline` from `examples/configs/default.toml` — one owned-engine
//! front door that resolves the map-search dataflow (`[runner]
//! searcher`, DOMS by default — edit it to swap), the GEMM engine
//! (compiled PJRT artifacts when `make artifacts` has run, the bit-exact
//! native fallback otherwise), and the whole runner/serving stack — and
//! submits the frame as one `Job`.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use voxel_cim::geom::Extent3;
use voxel_cim::model::layer::{LayerSpec, NetworkSpec, TaskKind};
use voxel_cim::pipeline::{Job, Pipeline, PipelineConfig};
use voxel_cim::pointcloud::scene::SceneConfig;
use voxel_cim::pointcloud::vfe::{Vfe, VfeKind};
use voxel_cim::pointcloud::voxelize::Voxelizer;
use voxel_cim::sparse::tensor::SparseTensor;

fn main() -> voxel_cim::Result<()> {
    // 1. A synthetic urban LiDAR frame (KITTI substitute — see DESIGN.md).
    let points = SceneConfig::default().with_points(20_000).generate();
    println!("scene: {} LiDAR returns", points.len());

    // 2. Voxelize at the paper's low-resolution grid and extract features.
    let extent = Extent3::new(352, 400, 10);
    let vx = Voxelizer::new((70.4, 80.0, 4.0), extent, 32);
    let grid = vx.voxelize(&points);
    let (feats, scale) = Vfe::new(VfeKind::Simple).extract_i8(&grid);
    println!(
        "voxelized: {} occupied voxels (sparsity {:.5}, quant scale {:.4})",
        grid.len(),
        grid.sparsity(),
        scale
    );
    let input = SparseTensor::new(
        extent,
        grid.voxels
            .iter()
            .enumerate()
            .map(|(i, v)| (v.coord, feats[i * 4..(i + 1) * 4].to_vec()))
            .collect(),
        4,
    );

    // 3. The pipeline facade: one strict config load (only a *missing*
    // config falls back to defaults; a config that fails to parse, or
    // names an unknown searcher, is a real error), one builder, one
    // owned engine. A compact backbone sized to the grid above.
    let cfg_path = "examples/configs/default.toml";
    let cfg = if std::path::Path::new(cfg_path).exists() {
        PipelineConfig::load(cfg_path)?
    } else {
        PipelineConfig::default()
    };
    println!("searcher: {} (from {cfg_path})", cfg.runner.searcher);
    let net = NetworkSpec {
        name: "quickstart",
        task: TaskKind::Segmentation,
        extent,
        vfe_channels: 4,
        layers: vec![
            LayerSpec::Subm3 { c_in: 4, c_out: 16 },
            LayerSpec::Subm3 { c_in: 16, c_out: 16 },
        ],
    };
    let mut pipe = Pipeline::builder().config(cfg).network(net).build()?;
    println!("engine: {}", pipe.engine_desc());

    // 4. Submit the frame as one job; the facade routes it through the
    // same lockstep executor every entry point shares.
    let res = pipe.run(Job::Frame(input))?.into_frame()?;
    for r in &res.records {
        println!(
            "  {:<24} {:>9} IN-OUT pairs -> {:>7} voxels  (ms {:.1} ms, compute {:.1} ms)",
            r.name,
            r.pairs,
            r.out_voxels,
            r.ms_seconds * 1e3,
            r.compute_seconds * 1e3
        );
    }
    println!(
        "done: {} output voxels | {} GEMM dispatches | checksum {:#018x}",
        res.out_voxels,
        pipe.dispatches(),
        res.checksum
    );
    Ok(())
}
