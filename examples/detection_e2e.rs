//! End-to-end detection driver (EXPERIMENTS.md E9): SECOND on a synthetic
//! KITTI-like frame, real numerics through the pipeline facade, full
//! request path — scene → voxelize → VFE → 7 map searches → 11 Spconv3D
//! layers → BEV → 12-layer RPN → detection head — with per-stage timing
//! and the accelerator-model projection next to the host measurement.
//!
//! ```sh
//! make artifacts && cargo run --release --example detection_e2e -- --frames 3
//! ```

use std::time::Instant;

use voxel_cim::model::second;
use voxel_cim::pipeline::{Job, Overrides, Pipeline, PipelineConfig};
use voxel_cim::pointcloud::scene::SceneConfig;
use voxel_cim::pointcloud::vfe::{Vfe, VfeKind};
use voxel_cim::pointcloud::voxelize::Voxelizer;
use voxel_cim::sim::accelerator::{Accelerator, SimOptions};
use voxel_cim::sparse::tensor::SparseTensor;
use voxel_cim::util::cli::Args;

fn main() -> voxel_cim::Result<()> {
    let args = Args::new("SECOND end-to-end detection on synthetic KITTI frames")
        .opt("frames", "2", "number of frames to stream")
        .opt("points", "18000", "LiDAR returns per frame")
        .opt("seed", "7", "scene seed")
        .opt(
            "searcher",
            "doms",
            "map-search engine: hash|weight-major|output-major|octree|doms|block-doms",
        )
        .switch("native", "skip PJRT, use the native engine")
        .parse();

    // The facade resolves the searcher and the engine (PJRT artifacts
    // with native fallback, or native when --native pins it).
    let mut cfg = PipelineConfig::default();
    cfg.apply(&Overrides {
        searcher: Some(args.get("searcher").to_string()),
        native: args.get_bool("native"),
        ..Default::default()
    })?;
    let searcher = cfg.runner.searcher;
    let net = second::second_small();
    println!("=== {} | extent {:?} | searcher {searcher} ===", net.name, net.extent);
    let mut pipe = Pipeline::builder().config(cfg).network(net.clone()).build()?;
    println!("engine: {}", pipe.engine_desc());
    let vx = Voxelizer::new((70.4, 80.0, 4.0), net.extent, 32);
    let vfe = Vfe::new(VfeKind::Simple);

    let frames = args.get_usize("frames");
    let mut host_total = 0.0;
    for f in 0..frames {
        let t0 = Instant::now();
        let pts = SceneConfig::default()
            .with_points(args.get_usize("points"))
            .with_seed(args.get_u64("seed") + f as u64)
            .generate();
        let grid = vx.voxelize(&pts);
        let (feats, _) = vfe.extract_i8(&grid);
        let pre = t0.elapsed().as_secs_f64();
        let input = SparseTensor::new(
            net.extent,
            grid.voxels
                .iter()
                .enumerate()
                .map(|(i, v)| (v.coord, feats[i * 4..(i + 1) * 4].to_vec()))
                .collect(),
            4,
        );
        let n_vox = input.len();

        let res = pipe.run(Job::Frame(input))?.into_frame()?;
        host_total += res.total_seconds + pre;
        let (h, w, c) = res.head_shape.expect("detection head");
        println!(
            "frame {f}: {n_vox} voxels | pre {:.1}ms | MS {:.1}ms | compute {:.1}ms | total {:.1}ms | head {h}x{w}x{c} | {} pairs",
            pre * 1e3,
            res.ms_seconds() * 1e3,
            res.compute_seconds() * 1e3,
            (res.total_seconds + pre) * 1e3,
            res.total_pairs()
        );
    }
    println!(
        "\nhost throughput: {:.2} fps over {frames} frames ({} engine dispatches)",
        frames as f64 / host_total,
        pipe.dispatches(),
    );

    // Accelerator-model projection for the same workload at full scale.
    let full = second::second();
    let gd = voxel_cim::pointcloud::voxelize::Voxelizer::synth_clustered(
        full.extent,
        6.0e-4,
        10,
        0.35,
        args.get_u64("seed"),
    );
    let full_in = SparseTensor::from_coords(full.extent, gd.coords(), 1);
    let acc = Accelerator::default();
    let sim_searcher = searcher.build();
    let rep = acc.simulate(&full, &full_in, sim_searcher.as_ref(), &SimOptions::default());
    println!(
        "accelerator model (full-res SECOND, {} voxels): {:.1} fps | {:.2} mJ/frame | paper: 106 fps",
        full_in.len(),
        rep.fps(),
        rep.energy_joules * 1e3
    );
    Ok(())
}
