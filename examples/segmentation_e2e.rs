//! End-to-end segmentation driver (EXPERIMENTS.md E9): MinkUNet on a
//! synthetic SemanticKITTI-like frame — the Spconv3D-dominated workload
//! the paper runs the W2B study on. Runs the frame through the full UNet
//! (encoder gconv2 downs, decoder tconv2 ups) via the pipeline facade
//! with real numerics, then prints the accelerator-model projection with
//! and without W2B.
//!
//! ```sh
//! make artifacts && cargo run --release --example segmentation_e2e
//! ```

use voxel_cim::model::minkunet;
use voxel_cim::pipeline::{Job, Overrides, Pipeline, PipelineConfig};
use voxel_cim::pointcloud::scene::{SceneConfig, SceneKind};
use voxel_cim::pointcloud::vfe::{Vfe, VfeKind};
use voxel_cim::pointcloud::voxelize::Voxelizer;
use voxel_cim::sim::accelerator::{Accelerator, SimOptions};
use voxel_cim::sparse::tensor::SparseTensor;
use voxel_cim::util::cli::Args;

fn main() -> voxel_cim::Result<()> {
    let args = Args::new("MinkUNet end-to-end segmentation on a synthetic frame")
        .opt("points", "15000", "LiDAR returns")
        .opt("seed", "11", "scene seed")
        .opt(
            "searcher",
            "doms",
            "map-search engine: hash|weight-major|output-major|octree|doms|block-doms",
        )
        .switch("native", "skip PJRT, use the native engine")
        .parse();

    let mut cfg = PipelineConfig::default();
    cfg.apply(&Overrides {
        searcher: Some(args.get("searcher").to_string()),
        native: args.get_bool("native"),
        ..Default::default()
    })?;
    let searcher = cfg.runner.searcher;
    let net = minkunet::minkunet_small();
    println!("=== {} | extent {:?} | searcher {searcher} ===", net.name, net.extent);

    // Clustered scene: segmentation frames have strong local density.
    let pts = SceneConfig {
        kind: SceneKind::Clustered,
        num_points: args.get_usize("points"),
        ..Default::default()
    }
    .with_seed(args.get_u64("seed"))
    .generate();
    let vx = Voxelizer::new((70.4, 80.0, 4.0), net.extent, 32);
    let grid = vx.voxelize(&pts);
    let (feats, _) = Vfe::new(VfeKind::Dynamic).extract_i8(&grid);
    println!("frame: {} points -> {} voxels", pts.len(), grid.len());
    let input = SparseTensor::new(
        net.extent,
        grid.voxels
            .iter()
            .enumerate()
            .map(|(i, v)| (v.coord, feats[i * 4..(i + 1) * 4].to_vec()))
            .collect(),
        4,
    );

    let mut pipe = Pipeline::builder().config(cfg).network(net.clone()).build()?;
    println!("engine: {}", pipe.engine_desc());
    let res = pipe.run(Job::Frame(input))?.into_frame()?;

    println!("\nper-layer (UNet):");
    for r in &res.records {
        println!(
            "  {:<34} pairs {:>9}  out {:>8}  compute {:>8.1}ms{}",
            r.name,
            r.pairs,
            r.out_voxels,
            r.compute_seconds * 1e3,
            if r.ms_seconds == 0.0 && r.pairs > 0 {
                "  (shared MS)"
            } else {
                ""
            }
        );
    }
    println!(
        "\nsegmentation output: {} voxels labeled | host total {:.1} ms | {} dispatches",
        res.out_voxels,
        res.total_seconds * 1e3,
        pipe.dispatches(),
    );

    // Accelerator projection at full scale, W2B on/off (Fig. 10's story).
    let full = minkunet::minkunet();
    let gs = Voxelizer::synth_clustered(full.extent, 2.3e-4, 14, 0.3, args.get_u64("seed"));
    let full_in = SparseTensor::from_coords(full.extent, gs.coords(), 1);
    let acc = Accelerator::default();
    let sim_searcher = searcher.build();
    let with = acc.simulate(&full, &full_in, sim_searcher.as_ref(), &SimOptions::default());
    let without = acc.simulate(
        &full,
        &full_in,
        sim_searcher.as_ref(),
        &SimOptions { w2b: false, ..Default::default() },
    );
    println!(
        "accelerator model (full MinkUNet, {} voxels): {:.1} fps with W2B | {:.1} fps without | {:.2}x (paper: 2.3x, 107 fps)",
        full_in.len(),
        with.fps(),
        without.fps(),
        without.seconds / with.seconds
    );
    Ok(())
}
