//! Integration: the temporal delta map-search cache. Warm stream frames
//! must be bit-identical to a cold full search across every
//! `SearcherKind`, sharded and unsharded, solo and muxed — while
//! performing strictly fewer block map-searches on temporally coherent
//! frames. The cache is off by default, and its per-sequence memory is
//! bounded by `delta_max_entries` (evictions are counted, never wrong).

use std::path::{Path, PathBuf};

use voxel_cim::coordinator::scheduler::RunnerConfig;
use voxel_cim::coordinator::shard::ShardConfig;
use voxel_cim::coordinator::stream::{StreamReport, StreamServer};
use voxel_cim::dataset::{FrameSource, KittiSource, ProfileSource, ScenarioProfile};
use voxel_cim::geom::Extent3;
use voxel_cim::mapsearch::{DeltaConfig, SearcherKind};
use voxel_cim::model::layer::{LayerSpec, NetworkSpec, TaskKind};
use voxel_cim::pointcloud::voxelize::Voxelizer;
use voxel_cim::serving::{MuxPolicy, SequenceMux};
use voxel_cim::spconv::layer::NativeEngine;

const EXTENT: Extent3 = Extent3::new(64, 64, 6);

/// The stream backbone shape: two submanifold layers sharing a rulebook,
/// a downsample, and a fresh submanifold at the coarse scale — both delta
/// slot shapes (fresh full-res, fresh post-downsample) are exercised.
fn stream_net() -> NetworkSpec {
    NetworkSpec {
        name: "delta-stream",
        task: TaskKind::Segmentation,
        extent: EXTENT,
        vfe_channels: 4,
        layers: vec![
            LayerSpec::Subm3 { c_in: 4, c_out: 8 },
            LayerSpec::Subm3 { c_in: 8, c_out: 8 },
            LayerSpec::GConv2 { c_in: 8, c_out: 16 },
            LayerSpec::Subm3 { c_in: 16, c_out: 16 },
        ],
    }
}

fn cfg(kind: SearcherKind, shard: ShardConfig, delta_on: bool) -> RunnerConfig {
    RunnerConfig {
        searcher: kind,
        shard,
        // One frame per window: every warm frame plans against its own
        // predecessor's committed entry.
        inflight: 1,
        compute_workers: 1,
        seed: 33,
        delta: DeltaConfig {
            enabled: delta_on,
            // 4x4-voxel blocks: fine enough that the drift edge and the
            // per-frame dynamic blob leave most of the field clean.
            blocks_x: 16,
            blocks_y: 16,
            ..DeltaConfig::default()
        },
        ..Default::default()
    }
}

/// An ego-motion sequence: world-anchored field drifting one voxel per
/// frame plus a small per-frame dynamic blob — the temporally coherent
/// regime the cache is built for.
fn drift_source(frames: u64, seed: u64) -> Box<dyn FrameSource> {
    Box::new(
        ProfileSource::new(ScenarioProfile::Urban, EXTENT, 0.03, seed)
            .with_drift(1.0)
            .with_frames(frames),
    )
}

fn serve_drift(
    kind: SearcherKind,
    shard: ShardConfig,
    delta_on: bool,
    frames: u64,
    seed: u64,
) -> StreamReport {
    let srv = StreamServer::new(stream_net(), cfg(kind, shard, delta_on), 4);
    let mut src = drift_source(frames, seed);
    srv.serve(frames, src.as_mut(), &mut NativeEngine::default())
        .unwrap()
}

/// The acceptance property: for every searcher kind, sharded and not,
/// warm frames are bit-identical to the cold full search and re-search
/// strictly fewer blocks. A cold pass searches every occupied block of a
/// frame, and occupied = searched + reused on the warm pass, so
/// `blocks_reused > 0` is exactly the strictly-fewer claim.
#[test]
fn warm_serving_is_bit_identical_and_reuses_blocks_for_every_searcher() {
    const FRAMES: u64 = 4;
    let shard_modes = [
        ShardConfig::default(),
        ShardConfig {
            auto_threshold: 1,
            ..ShardConfig::grid(2, 2).unwrap()
        },
    ];
    for kind in SearcherKind::ALL {
        for shard in shard_modes {
            let sharding = shard.num_blocks() > 1;
            let cold = serve_drift(kind, shard, false, FRAMES, 0xD1F7);
            let warm = serve_drift(kind, shard, true, FRAMES, 0xD1F7);
            assert_eq!(cold.completions.len(), FRAMES as usize);
            assert_eq!(warm.completions.len(), FRAMES as usize);
            for (c, w) in cold.completions.iter().zip(&warm.completions) {
                assert_eq!(c.id, w.id);
                assert_eq!(
                    c.result.checksum, w.result.checksum,
                    "{kind} sharding={sharding}: frame {} diverged warm",
                    c.id
                );
                assert_eq!(
                    c.result.total_pairs(),
                    w.result.total_pairs(),
                    "{kind} sharding={sharding}: frame {} pair count",
                    c.id
                );
                assert_eq!(c.result.shards, w.result.shards, "frame {}", c.id);
                // Cold runs never touch the cache or its counters.
                assert_eq!(
                    c.result.blocks_searched + c.result.blocks_reused,
                    0,
                    "{kind} sharding={sharding}: cold frame {} counted blocks",
                    c.id
                );
            }
            if sharding {
                assert!(
                    warm.completions.iter().all(|c| c.result.shards > 1),
                    "{kind}: frames should shard at threshold 1"
                );
            }
            // Frame 0 is compulsory-cold: full search, nothing spliced.
            let first = &warm.completions[0].result;
            assert!(first.blocks_searched > 0, "{kind} sharding={sharding}");
            assert_eq!(first.blocks_reused, 0, "{kind} sharding={sharding}");
            // Every later frame splices cached fragments — i.e. searches
            // strictly fewer blocks than the cold pass on the same frame.
            for w in &warm.completions[1..] {
                assert!(
                    w.result.blocks_reused > 0,
                    "{kind} sharding={sharding}: warm frame {} reused nothing",
                    w.id
                );
            }
            assert!(warm.reuse_ratio() > 0.0, "{kind} sharding={sharding}");
            assert_eq!(warm.evictions, 0, "{kind} sharding={sharding}");
        }
    }
}

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/kitti")
}

/// Real-data spot check: the KITTI fixture's two (largely disjoint)
/// frames through a warm cache are bit-identical to cold — dirty-block
/// invalidation must stay correct even when almost nothing is reusable.
#[test]
fn kitti_fixture_is_bit_identical_through_a_warm_cache() {
    let extent = Extent3::new(16, 16, 8);
    let net = || NetworkSpec {
        name: "delta-kitti",
        task: TaskKind::Segmentation,
        extent,
        vfe_channels: 4,
        layers: vec![
            LayerSpec::Subm3 { c_in: 4, c_out: 8 },
            LayerSpec::Subm3 { c_in: 8, c_out: 8 },
        ],
    };
    let voxelizer = || Voxelizer::new((16.0, 16.0, 8.0), extent, 8);
    let serve_once = |delta_on: bool| {
        let rc = RunnerConfig {
            delta: DeltaConfig {
                enabled: delta_on,
                ..DeltaConfig::default()
            },
            ..Default::default()
        };
        let srv = StreamServer::new(net(), rc, 2);
        let mut src = KittiSource::open(fixture_dir(), voxelizer()).unwrap();
        srv.serve(8, &mut src, &mut NativeEngine::default()).unwrap()
    };
    let cold = serve_once(false);
    let warm = serve_once(true);
    assert_eq!(cold.completions.len(), 2);
    assert_eq!(warm.completions.len(), 2);
    for (c, w) in cold.completions.iter().zip(&warm.completions) {
        assert_eq!(c.id, w.id);
        assert_eq!(c.result.checksum, w.result.checksum, "frame {}", c.id);
    }
    assert!(warm.blocks_searched > 0);
    assert_eq!(cold.blocks_searched + cold.blocks_reused, 0);
}

/// Muxed serving: two interleaved drift sequences keep separate cache
/// lineages (keys include `FrameMeta::sequence`), so both reuse blocks
/// and both stay bit-identical to the cold muxed run.
#[test]
fn muxed_sequences_reuse_independently_and_stay_bit_identical() {
    const FRAMES: u64 = 3;
    let mux = || {
        SequenceMux::new(
            vec![drift_source(FRAMES, 0xA11CE), drift_source(FRAMES, 0xB0B)],
            MuxPolicy::RoundRobin,
        )
        .unwrap()
    };
    let serve_once = |delta_on: bool| {
        let srv = StreamServer::new(
            stream_net(),
            cfg(SearcherKind::Octree, ShardConfig::default(), delta_on),
            8,
        );
        let mut m = mux();
        srv.serve(2 * FRAMES, &mut m, &mut NativeEngine::default())
            .unwrap()
    };
    let cold = serve_once(false);
    let warm = serve_once(true);
    assert_eq!(cold.completions.len(), 2 * FRAMES as usize);
    assert_eq!(warm.completions.len(), 2 * FRAMES as usize);
    for (c, w) in cold.completions.iter().zip(&warm.completions) {
        assert_eq!((c.sequence, c.id), (w.sequence, w.id));
        assert_eq!(
            c.result.checksum, w.result.checksum,
            "seq {} frame {} diverged warm through the mux",
            c.sequence, c.id
        );
    }
    // Each sequence's frame 0 is cold; every later frame of *both*
    // sequences reuses — the interleaving never cross-contaminates.
    for w in &warm.completions {
        if w.id == 0 {
            assert_eq!(w.result.blocks_reused, 0, "seq {} frame 0", w.sequence);
        } else {
            assert!(
                w.result.blocks_reused > 0,
                "seq {} frame {} reused nothing",
                w.sequence,
                w.id
            );
        }
    }
    assert_eq!(warm.evictions, 0, "two sequences fit the default bound");
}

/// `delta_max_entries = 1` with two alternating sequences: every commit
/// displaces the other lineage, so the cache stays bounded (evictions
/// counted), no frame ever finds a prior, and the bits never change.
#[test]
fn eviction_bound_keeps_memory_capped_and_bits_identical() {
    const FRAMES: u64 = 3;
    let mux = || {
        SequenceMux::new(
            vec![drift_source(FRAMES, 0xE01), drift_source(FRAMES, 0xE02)],
            MuxPolicy::RoundRobin,
        )
        .unwrap()
    };
    let serve_once = |delta_on: bool, max_entries: usize| {
        let rc = RunnerConfig {
            inflight: 1,
            compute_workers: 1,
            seed: 33,
            delta: DeltaConfig {
                enabled: delta_on,
                max_entries,
                ..DeltaConfig::default()
            },
            ..Default::default()
        };
        let srv = StreamServer::new(stream_net(), rc, 8);
        let mut m = mux();
        srv.serve(2 * FRAMES, &mut m, &mut NativeEngine::default())
            .unwrap()
    };
    let cold = serve_once(false, 1);
    let starved = serve_once(true, 1);
    assert!(starved.evictions > 0, "cap 1 must displace the other lineage");
    // Strict round-robin alternation means no key ever survives to its
    // own sequence's next frame: every frame is effectively cold.
    assert_eq!(starved.blocks_reused, 0);
    assert!(starved.blocks_searched > 0);
    for (c, w) in cold.completions.iter().zip(&starved.completions) {
        assert_eq!((c.sequence, c.id), (w.sequence, w.id));
        assert_eq!(
            c.result.checksum, w.result.checksum,
            "seq {} frame {} diverged under eviction pressure",
            c.sequence, c.id
        );
    }
}

/// The cache is strictly opt-in: a default `RunnerConfig` never touches
/// it and reports zero counters.
#[test]
fn delta_cache_is_off_by_default() {
    let rc = RunnerConfig::default();
    assert!(!rc.delta.enabled);
    let srv = StreamServer::new(stream_net(), rc, 4);
    let mut src = drift_source(3, 0x0FF);
    let report = srv
        .serve(3, src.as_mut(), &mut NativeEngine::default())
        .unwrap();
    assert_eq!(report.completions.len(), 3);
    assert_eq!(report.blocks_searched, 0);
    assert_eq!(report.blocks_reused, 0);
    assert_eq!(report.evictions, 0);
    assert_eq!(report.reuse_ratio(), 0.0);
    assert!(report
        .completions
        .iter()
        .all(|c| c.result.blocks_searched == 0 && c.result.blocks_reused == 0));
}
