//! Integration: the temporal delta cache, all three reuse rungs —
//! map-search splicing, compute (psum) reuse, and delta voxelization.
//! Warm stream frames must be bit-identical to a cold full pass across
//! every `SearcherKind`, sharded and unsharded, solo and muxed, and
//! under admission shedding — while searching fewer blocks, gathering
//! fewer rows, and dispatching fewer GEMM waves on temporally coherent
//! frames. The cache is off by default, and its per-sequence memory is
//! bounded by `delta_max_entries` (evictions are counted, never wrong).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use voxel_cim::coordinator::scheduler::RunnerConfig;
use voxel_cim::coordinator::shard::ShardConfig;
use voxel_cim::coordinator::stream::{StreamReport, StreamServer};
use voxel_cim::dataset::{
    ClosureSource, FrameSource, KittiSource, ProfileSource, ScenarioProfile,
};
use voxel_cim::geom::Extent3;
use voxel_cim::mapsearch::{DeltaConfig, SearcherKind};
use voxel_cim::model::layer::{LayerSpec, NetworkSpec, TaskKind};
use voxel_cim::pointcloud::voxelize::Voxelizer;
use voxel_cim::serving::{AdmissionConfig, AdmissionPolicy, MuxPolicy, SequenceMux};
use voxel_cim::sparse::tensor::SparseTensor;
use voxel_cim::spconv::layer::NativeEngine;

const EXTENT: Extent3 = Extent3::new(64, 64, 6);

/// The stream backbone shape: two submanifold layers sharing a rulebook,
/// a downsample, and a fresh submanifold at the coarse scale — both delta
/// slot shapes (fresh full-res, fresh post-downsample) are exercised.
fn stream_net() -> NetworkSpec {
    NetworkSpec {
        name: "delta-stream",
        task: TaskKind::Segmentation,
        extent: EXTENT,
        vfe_channels: 4,
        layers: vec![
            LayerSpec::Subm3 { c_in: 4, c_out: 8 },
            LayerSpec::Subm3 { c_in: 8, c_out: 8 },
            LayerSpec::GConv2 { c_in: 8, c_out: 16 },
            LayerSpec::Subm3 { c_in: 16, c_out: 16 },
        ],
    }
}

fn cfg(kind: SearcherKind, shard: ShardConfig, delta_on: bool) -> RunnerConfig {
    RunnerConfig {
        searcher: kind,
        shard,
        // One frame per window: every warm frame plans against its own
        // predecessor's committed entry.
        inflight: 1,
        compute_workers: 1,
        seed: 33,
        delta: DeltaConfig {
            enabled: delta_on,
            // Compute reuse rides along wherever the cache is on. Drift
            // profiles re-randomize per-voxel features every frame, so
            // on those sources the psum rung must stay bit-identical
            // precisely when nothing is compute-clean.
            compute: delta_on,
            // 4x4-voxel blocks: fine enough that the drift edge and the
            // per-frame dynamic blob leave most of the field clean.
            blocks_x: 16,
            blocks_y: 16,
            ..DeltaConfig::default()
        },
        ..Default::default()
    }
}

/// An ego-motion sequence: world-anchored field drifting one voxel per
/// frame plus a small per-frame dynamic blob — the temporally coherent
/// regime the cache is built for.
fn drift_source(frames: u64, seed: u64) -> Box<dyn FrameSource> {
    Box::new(
        ProfileSource::new(ScenarioProfile::Urban, EXTENT, 0.03, seed)
            .with_drift(1.0)
            .with_frames(frames),
    )
}

fn serve_drift(
    kind: SearcherKind,
    shard: ShardConfig,
    delta_on: bool,
    frames: u64,
    seed: u64,
) -> StreamReport {
    let srv = StreamServer::new(stream_net(), cfg(kind, shard, delta_on), 4);
    let mut src = drift_source(frames, seed);
    srv.serve(frames, src.as_mut(), &mut NativeEngine::default())
        .unwrap()
}

/// The acceptance property: for every searcher kind, sharded and not,
/// warm frames are bit-identical to the cold full search and re-search
/// strictly fewer blocks. A cold pass searches every occupied block of a
/// frame, and occupied = searched + reused on the warm pass, so
/// `blocks_reused > 0` is exactly the strictly-fewer claim.
#[test]
fn warm_serving_is_bit_identical_and_reuses_blocks_for_every_searcher() {
    const FRAMES: u64 = 4;
    let shard_modes = [
        ShardConfig::default(),
        ShardConfig {
            auto_threshold: 1,
            ..ShardConfig::grid(2, 2).unwrap()
        },
    ];
    for kind in SearcherKind::ALL {
        for shard in shard_modes {
            let sharding = shard.num_blocks() > 1;
            let cold = serve_drift(kind, shard, false, FRAMES, 0xD1F7);
            let warm = serve_drift(kind, shard, true, FRAMES, 0xD1F7);
            assert_eq!(cold.completions.len(), FRAMES as usize);
            assert_eq!(warm.completions.len(), FRAMES as usize);
            for (c, w) in cold.completions.iter().zip(&warm.completions) {
                assert_eq!(c.id, w.id);
                assert_eq!(
                    c.result.checksum, w.result.checksum,
                    "{kind} sharding={sharding}: frame {} diverged warm",
                    c.id
                );
                assert_eq!(
                    c.result.total_pairs(),
                    w.result.total_pairs(),
                    "{kind} sharding={sharding}: frame {} pair count",
                    c.id
                );
                assert_eq!(c.result.shards, w.result.shards, "frame {}", c.id);
                // Cold runs never touch the cache or its counters —
                // neither the map-search rung nor the compute rung.
                assert_eq!(
                    c.result.blocks_searched
                        + c.result.blocks_reused
                        + c.result.waves_skipped
                        + c.result.rows_gathered_saved,
                    0,
                    "{kind} sharding={sharding}: cold frame {} counted reuse",
                    c.id
                );
            }
            if sharding {
                assert!(
                    warm.completions.iter().all(|c| c.result.shards > 1),
                    "{kind}: frames should shard at threshold 1"
                );
            }
            // Frame 0 is compulsory-cold: full search, nothing spliced.
            let first = &warm.completions[0].result;
            assert!(first.blocks_searched > 0, "{kind} sharding={sharding}");
            assert_eq!(first.blocks_reused, 0, "{kind} sharding={sharding}");
            // Every later frame splices cached fragments — i.e. searches
            // strictly fewer blocks than the cold pass on the same frame.
            for w in &warm.completions[1..] {
                assert!(
                    w.result.blocks_reused > 0,
                    "{kind} sharding={sharding}: warm frame {} reused nothing",
                    w.id
                );
            }
            assert!(warm.reuse_ratio() > 0.0, "{kind} sharding={sharding}");
            assert_eq!(warm.evictions, 0, "{kind} sharding={sharding}");
        }
    }
}

/// A temporally coherent scene with *stable* features: every voxel's
/// features are a pure function of its coordinate, so a geometrically
/// clean block is psum-clean too. (Drift profiles re-randomize features
/// each frame — correct for them, but it means they never exercise the
/// splice arm.) With `edited`, one spatial neighbourhood around the
/// first voxel is re-weighted; everything else is untouched.
fn coherent_tensor(edited: bool) -> SparseTensor {
    let coords = Voxelizer::synth_clustered(EXTENT, 0.03, 8, 0.3, 0xBA5E).coords();
    let mut t = SparseTensor::from_coords(EXTENT, coords, 4);
    let anchor = t.coords[0];
    for (i, c) in t.coords.iter().enumerate() {
        for ch in 0..4usize {
            let mut v = ((c.x + 3 * c.y + 5 * c.z + 7 * ch as i32) % 15 - 7) as i8;
            if edited && (c.x - anchor.x).abs() <= 4 && (c.y - anchor.y).abs() <= 4 {
                v = v.wrapping_add(3);
            }
            t.features[i * 4 + ch] = v;
        }
    }
    t
}

/// The compute rung's acceptance matrix: on a feature-stable scene the
/// warm pass splices cached psum rows, gathers strictly fewer rows,
/// skips whole GEMM waves, and issues strictly fewer engine dispatches
/// — bit-identically, for every searcher kind, sharded and unsharded.
/// Frame 1 repeats frame 0 (the full-splice path: every prefix layer's
/// output comes from the cache); frame 2 re-weights one neighbourhood
/// (partial invalidation through the accumulated receptive cone);
/// frame 3 repeats the base scene against the edited prior.
#[test]
fn compute_reuse_skips_waves_and_stays_bit_identical_for_every_searcher() {
    const FRAMES: u64 = 4;
    let source = || {
        let base = coherent_tensor(false);
        let edited = coherent_tensor(true);
        ClosureSource::new(move |id| if id == 2 { edited.clone() } else { base.clone() })
    };
    let shard_modes = [
        ShardConfig::default(),
        ShardConfig {
            auto_threshold: 1,
            ..ShardConfig::grid(2, 2).unwrap()
        },
    ];
    for kind in SearcherKind::ALL {
        for shard in shard_modes {
            let sharding = shard.num_blocks() > 1;
            let serve_once = |delta_on: bool, eng: &mut NativeEngine| {
                let srv = StreamServer::new(stream_net(), cfg(kind, shard, delta_on), 4);
                let mut src = source();
                srv.serve(FRAMES, &mut src, eng).unwrap()
            };
            let mut cold_eng = NativeEngine::default();
            let cold = serve_once(false, &mut cold_eng);
            let mut warm_eng = NativeEngine::default();
            let warm = serve_once(true, &mut warm_eng);
            assert_eq!(cold.completions.len(), FRAMES as usize);
            assert_eq!(warm.completions.len(), FRAMES as usize);
            for (c, w) in cold.completions.iter().zip(&warm.completions) {
                assert_eq!(c.id, w.id);
                assert_eq!(
                    c.result.checksum, w.result.checksum,
                    "{kind} sharding={sharding}: frame {} diverged with psum splicing",
                    c.id
                );
                assert_eq!(
                    c.result.total_pairs(),
                    w.result.total_pairs(),
                    "{kind} sharding={sharding}: frame {} pair count",
                    c.id
                );
            }
            // Every warm frame finds psum-clean blocks: frames 1 and 3
            // away from nothing, frame 2 away from the edited
            // neighbourhood's dilated cone.
            for w in &warm.completions[1..] {
                assert!(
                    w.result.rows_gathered_saved > 0,
                    "{kind} sharding={sharding}: warm frame {} saved no gather rows",
                    w.id
                );
            }
            // Frame 1 repeats frame 0 bit-for-bit, so whole waves drop
            // out of the dispatch, not just rows out of the gather.
            assert!(
                warm.completions[1].result.waves_skipped > 0,
                "{kind} sharding={sharding}: full-splice frame skipped no waves"
            );
            // Strictly fewer engine dispatches over the whole warm serve
            // — the claim the CI stream-smoke gate holds the line on.
            assert!(
                warm_eng.calls < cold_eng.calls,
                "{kind} sharding={sharding}: warm {} !< cold {} GEMM dispatches",
                warm_eng.calls,
                cold_eng.calls
            );
        }
    }
}

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/kitti")
}

/// Real-data spot check: the KITTI fixture's two (largely disjoint)
/// frames through a warm cache — with all three rungs on, including
/// delta voxelization on the raw point stream — are bit-identical to
/// cold. Dirty-block invalidation must stay correct even when almost
/// nothing is reusable.
#[test]
fn kitti_fixture_is_bit_identical_through_a_warm_cache() {
    let extent = Extent3::new(16, 16, 8);
    let net = || NetworkSpec {
        name: "delta-kitti",
        task: TaskKind::Segmentation,
        extent,
        vfe_channels: 4,
        layers: vec![
            LayerSpec::Subm3 { c_in: 4, c_out: 8 },
            LayerSpec::Subm3 { c_in: 8, c_out: 8 },
        ],
    };
    let voxelizer = || Voxelizer::new((16.0, 16.0, 8.0), extent, 8);
    let serve_once = |delta_on: bool| {
        let rc = RunnerConfig {
            delta: DeltaConfig {
                enabled: delta_on,
                compute: delta_on,
                voxelize: delta_on,
                ..DeltaConfig::default()
            },
            ..Default::default()
        };
        let srv = StreamServer::new(net(), rc, 2);
        let mut src = KittiSource::open(fixture_dir(), voxelizer()).unwrap();
        if delta_on {
            src = src.with_delta(8, 8);
        }
        srv.serve(8, &mut src, &mut NativeEngine::default()).unwrap()
    };
    let cold = serve_once(false);
    let warm = serve_once(true);
    assert_eq!(cold.completions.len(), 2);
    assert_eq!(warm.completions.len(), 2);
    for (c, w) in cold.completions.iter().zip(&warm.completions) {
        assert_eq!(c.id, w.id);
        assert_eq!(c.result.checksum, w.result.checksum, "frame {}", c.id);
    }
    assert!(warm.blocks_searched > 0);
    assert_eq!(cold.blocks_searched + cold.blocks_reused, 0);
    // Both runs voxelize from raw points: the plain path counts every
    // voxel it bins, the delta path only the dirty blocks' voxels —
    // never more, and identically on the priorless first frame.
    assert!(cold.voxels_rebinned > 0);
    assert!(warm.voxels_rebinned > 0, "frame 0 is compulsorily all-dirty");
    assert!(warm.voxels_rebinned <= cold.voxels_rebinned);
    assert_eq!(
        warm.completions[0].result.voxels_rebinned,
        cold.completions[0].result.voxels_rebinned,
        "first frame has no prior: every block re-bins"
    );
}

/// Muxed serving: two interleaved drift sequences keep separate cache
/// lineages (keys include `FrameMeta::sequence`), so both reuse blocks
/// and both stay bit-identical to the cold muxed run.
#[test]
fn muxed_sequences_reuse_independently_and_stay_bit_identical() {
    const FRAMES: u64 = 3;
    let mux = || {
        SequenceMux::new(
            vec![drift_source(FRAMES, 0xA11CE), drift_source(FRAMES, 0xB0B)],
            MuxPolicy::RoundRobin,
        )
        .unwrap()
    };
    let serve_once = |delta_on: bool| {
        let srv = StreamServer::new(
            stream_net(),
            cfg(SearcherKind::Octree, ShardConfig::default(), delta_on),
            8,
        );
        let mut m = mux();
        srv.serve(2 * FRAMES, &mut m, &mut NativeEngine::default())
            .unwrap()
    };
    let cold = serve_once(false);
    let warm = serve_once(true);
    assert_eq!(cold.completions.len(), 2 * FRAMES as usize);
    assert_eq!(warm.completions.len(), 2 * FRAMES as usize);
    for (c, w) in cold.completions.iter().zip(&warm.completions) {
        assert_eq!((c.sequence, c.id), (w.sequence, w.id));
        assert_eq!(
            c.result.checksum, w.result.checksum,
            "seq {} frame {} diverged warm through the mux",
            c.sequence, c.id
        );
    }
    // Each sequence's frame 0 is cold; every later frame of *both*
    // sequences reuses — the interleaving never cross-contaminates.
    for w in &warm.completions {
        if w.id == 0 {
            assert_eq!(w.result.blocks_reused, 0, "seq {} frame 0", w.sequence);
        } else {
            assert!(
                w.result.blocks_reused > 0,
                "seq {} frame {} reused nothing",
                w.sequence,
                w.id
            );
        }
    }
    assert_eq!(warm.evictions, 0, "two sequences fit the default bound");
}

/// `delta_max_entries = 1` with two alternating sequences: every commit
/// displaces the other lineage, so the cache stays bounded (evictions
/// counted), no frame ever finds a prior, and the bits never change.
#[test]
fn eviction_bound_keeps_memory_capped_and_bits_identical() {
    const FRAMES: u64 = 3;
    let mux = || {
        SequenceMux::new(
            vec![drift_source(FRAMES, 0xE01), drift_source(FRAMES, 0xE02)],
            MuxPolicy::RoundRobin,
        )
        .unwrap()
    };
    let serve_once = |delta_on: bool, max_entries: usize| {
        let rc = RunnerConfig {
            inflight: 1,
            compute_workers: 1,
            seed: 33,
            delta: DeltaConfig {
                enabled: delta_on,
                max_entries,
                ..DeltaConfig::default()
            },
            ..Default::default()
        };
        let srv = StreamServer::new(stream_net(), rc, 8);
        let mut m = mux();
        srv.serve(2 * FRAMES, &mut m, &mut NativeEngine::default())
            .unwrap()
    };
    let cold = serve_once(false, 1);
    let starved = serve_once(true, 1);
    assert!(starved.evictions > 0, "cap 1 must displace the other lineage");
    // Strict round-robin alternation means no key ever survives to its
    // own sequence's next frame: every frame is effectively cold.
    assert_eq!(starved.blocks_reused, 0);
    assert!(starved.blocks_searched > 0);
    for (c, w) in cold.completions.iter().zip(&starved.completions) {
        assert_eq!((c.sequence, c.id), (w.sequence, w.id));
        assert_eq!(
            c.result.checksum, w.result.checksum,
            "seq {} frame {} diverged under eviction pressure",
            c.sequence, c.id
        );
    }
}

/// Frames the admission layer sheds must never commit partial cache
/// state. `DropOldest` and `RejectOverDepth` under a sub-microsecond
/// SLO shed aggressively; every survivor must be bit-identical to the
/// unshedded cold reference (matched by id — the warm cache sees id
/// *gaps*, never adjacency), and reuse must keep working across those
/// gaps by splicing against the last *served* frame.
#[test]
fn shed_frames_never_commit_partial_cache_state() {
    const FRAMES: u64 = 8;
    const SEED: u64 = 0x5AED;
    let reference: HashMap<u64, (u64, u64)> = {
        let srv = StreamServer::new(
            stream_net(),
            cfg(SearcherKind::Octree, ShardConfig::default(), false),
            4,
        );
        let mut src = drift_source(FRAMES, SEED);
        let cold = srv
            .serve(FRAMES, src.as_mut(), &mut NativeEngine::default())
            .unwrap();
        assert_eq!(cold.completions.len(), FRAMES as usize);
        cold.completions
            .iter()
            .map(|c| (c.id, (c.result.checksum, c.result.total_pairs())))
            .collect()
    };
    for policy in [AdmissionPolicy::DropOldest, AdmissionPolicy::RejectOverDepth] {
        let srv = StreamServer::new(
            stream_net(),
            cfg(SearcherKind::Octree, ShardConfig::default(), true),
            4,
        )
        .with_admission(AdmissionConfig {
            policy,
            // Any positive attributed latency trips the policy, so
            // shedding starts right after the first completed window.
            slo_ms: 1e-9,
            ..AdmissionConfig::default()
        });
        let mut src = drift_source(FRAMES, SEED);
        let warm = srv
            .serve(FRAMES, src.as_mut(), &mut NativeEngine::default())
            .unwrap();
        let shed = warm.admission.dropped + warm.admission.rejected;
        assert!(shed > 0, "{policy:?}: a sub-microsecond SLO must shed load");
        assert_eq!(
            warm.completions.len() as u64 + shed,
            FRAMES,
            "{policy:?}: every pulled frame is served or counted shed"
        );
        let mut prev_id = None;
        let mut gap_reuse = false;
        for w in &warm.completions {
            let (checksum, pairs) = reference[&w.id];
            assert_eq!(
                w.result.checksum, checksum,
                "{policy:?}: survivor frame {} diverged after shedding",
                w.id
            );
            assert_eq!(w.result.total_pairs(), pairs, "{policy:?}: frame {}", w.id);
            if prev_id.is_some_and(|p| w.id > p + 1) && w.result.blocks_reused > 0 {
                gap_reuse = true;
            }
            prev_id = Some(w.id);
        }
        assert!(
            gap_reuse,
            "{policy:?}: no survivor reused across a shed gap — the cache must \
             splice against the last served frame, not require adjacency"
        );
    }
}

/// Deferred (reordered) frames: a round-robin mux of a sparse sequence
/// and a dense, sharding sequence under `DeferSharding` and a
/// sub-microsecond SLO. Dense scenes get pushed behind queued sparse
/// frames — the service order changes, nothing is dropped — and each
/// sequence's cache lineage still sees its own frames in order, so both
/// keep reusing and every frame stays bit-identical to the unshedded
/// cold reference.
#[test]
fn deferred_frames_reorder_without_corrupting_the_cache() {
    const FRAMES: u64 = 4;
    let mux = || {
        let sparse = Box::new(
            ProfileSource::new(ScenarioProfile::Urban, EXTENT, 0.01, 0xDEF1)
                .with_drift(1.0)
                .with_frames(FRAMES),
        ) as Box<dyn FrameSource>;
        let dense = Box::new(
            ProfileSource::new(ScenarioProfile::Urban, EXTENT, 0.08, 0xDEF2)
                .with_drift(1.0)
                .with_frames(FRAMES),
        ) as Box<dyn FrameSource>;
        SequenceMux::new(vec![sparse, dense], MuxPolicy::RoundRobin).unwrap()
    };
    // ~0.01 * |extent| ≈ 250 voxels vs ~0.08 * |extent| ≈ 2000: the
    // threshold splits the classes, so exactly the dense frames shard
    // (and therefore defer).
    let shard = ShardConfig {
        auto_threshold: 900,
        ..ShardConfig::grid(2, 2).unwrap()
    };
    let serve_once = |delta_on: bool, defer: bool| {
        let mut srv =
            StreamServer::new(stream_net(), cfg(SearcherKind::Octree, shard, delta_on), 8);
        if defer {
            srv = srv.with_admission(AdmissionConfig {
                policy: AdmissionPolicy::DeferSharding,
                slo_ms: 1e-9,
                ..AdmissionConfig::default()
            });
        }
        let mut m = mux();
        srv.serve(2 * FRAMES, &mut m, &mut NativeEngine::default())
            .unwrap()
    };
    let cold = serve_once(false, false);
    let warm = serve_once(true, true);
    assert_eq!(cold.completions.len(), 2 * FRAMES as usize);
    assert_eq!(
        warm.completions.len(),
        2 * FRAMES as usize,
        "deferral reorders, it never drops"
    );
    assert!(warm.admission.deferred > 0, "dense scenes must be deferred");
    assert_eq!(warm.admission.dropped + warm.admission.rejected, 0);
    let reference: HashMap<(u32, u64), u64> = cold
        .completions
        .iter()
        .map(|c| ((c.sequence, c.id), c.result.checksum))
        .collect();
    for w in &warm.completions {
        assert_eq!(
            w.result.checksum,
            reference[&(w.sequence, w.id)],
            "seq {} frame {} diverged through deferral",
            w.sequence,
            w.id
        );
        if w.id > 0 {
            assert!(
                w.result.blocks_reused > 0,
                "seq {} frame {}: deferral broke its lineage's reuse",
                w.sequence,
                w.id
            );
        }
    }
}

/// The cache is strictly opt-in: a default `RunnerConfig` never touches
/// it and reports zero counters.
#[test]
fn delta_cache_is_off_by_default() {
    let rc = RunnerConfig::default();
    assert!(!rc.delta.enabled);
    let srv = StreamServer::new(stream_net(), rc, 4);
    let mut src = drift_source(3, 0x0FF);
    let report = srv
        .serve(3, src.as_mut(), &mut NativeEngine::default())
        .unwrap();
    assert_eq!(report.completions.len(), 3);
    assert_eq!(report.blocks_searched, 0);
    assert_eq!(report.blocks_reused, 0);
    assert_eq!(report.evictions, 0);
    assert_eq!(report.reuse_ratio(), 0.0);
    // The compute and voxelize rungs are off too: profile sources
    // synthesize voxels directly (nothing to re-bin) and no psum is
    // ever cached or spliced.
    assert_eq!(report.voxels_rebinned, 0);
    assert_eq!(report.waves_skipped, 0);
    assert_eq!(report.rows_gathered_saved, 0);
    assert!(report.completions.iter().all(|c| {
        c.result.blocks_searched == 0
            && c.result.blocks_reused == 0
            && c.result.waves_skipped == 0
            && c.result.rows_gathered_saved == 0
    }));
}
