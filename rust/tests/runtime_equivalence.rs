//! Integration: the PJRT runtime (AOT Pallas kernel artifacts) must be
//! bit-exact with the native rust reference on the request path.
//!
//! Requires `make artifacts`; tests are skipped (with a notice) when the
//! manifest is absent so `cargo test` works on a fresh checkout.

use voxel_cim::runtime::{Runtime, RuntimeConfig};
use voxel_cim::spconv::layer::{GemmEngine, NativeEngine};
use voxel_cim::spconv::quant;
use voxel_cim::util::rng::Pcg64;

fn runtime() -> Option<Runtime> {
    match Runtime::load(&RuntimeConfig::discover()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            None
        }
    }
}

fn rand_i8(rng: &mut Pcg64, n: usize, lo: i8, hi: i8) -> Vec<i8> {
    (0..n).map(|_| rng.next_i8(lo, hi)).collect()
}

#[test]
fn gemm_bit_exact_full_range() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = Pcg64::new(101);
    for &(b, c1, c2) in &[(64usize, 64usize, 64usize), (17, 64, 64), (64, 32, 48), (1, 1, 1)] {
        let acts = rand_i8(&mut rng, b * c1, -128, 127);
        let w = rand_i8(&mut rng, c1 * c2, -128, 127);
        let got = rt.gemm_i8(&acts, &w, b, c1, c2).unwrap();
        let want = quant::cim_gemm_ref(&acts, &w, b, c1, c2, 8, 8);
        assert_eq!(got, want, "mismatch at b={b} c1={c1} c2={c2}");
    }
}

#[test]
fn gemm_matches_native_engine_on_many_shapes() {
    let Some(mut rt) = runtime() else { return };
    let mut native = NativeEngine::default();
    let mut rng = Pcg64::new(102);
    for trial in 0..12 {
        let b = rng.range(1, 300);
        let c1 = rng.range(1, 65);
        let c2 = rng.range(1, 65);
        let acts = rand_i8(&mut rng, b * c1, -128, 127);
        let w = rand_i8(&mut rng, c1 * c2, -128, 127);
        let got = rt.gemm_i8(&acts, &w, b, c1, c2).unwrap();
        let want = native.gemm_i8(&acts, &w, b, c1, c2).unwrap();
        assert_eq!(got, want, "trial {trial}: b={b} c1={c1} c2={c2}");
    }
}

#[test]
fn oversized_batch_chunks_across_largest_artifact() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = Pcg64::new(103);
    let b = 2500; // > the 1024 artifact
    let (c1, c2) = (64, 64);
    let acts = rand_i8(&mut rng, b * c1, -128, 127);
    let w = rand_i8(&mut rng, c1 * c2, -128, 127);
    let got = rt.gemm_i8(&acts, &w, b, c1, c2).unwrap();
    let want = quant::cim_gemm_ref(&acts, &w, b, c1, c2, 8, 8);
    assert_eq!(got, want);
}

#[test]
fn epilogue_bit_exact() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg64::new(104);
    for &(b, c) in &[(64usize, 64usize), (10, 32), (300, 64)] {
        let psum: Vec<i32> = (0..b * c)
            .map(|_| (rng.next_below(1 << 20) as i32) - (1 << 19))
            .collect();
        let scale: Vec<f32> = (0..c).map(|_| rng.uniform(0.001, 0.1) as f32).collect();
        let zero: Vec<f32> = (0..c).map(|_| rng.uniform(-2.0, 2.0) as f32).collect();
        let got = rt.epilogue(&psum, &scale, &zero, b, c).unwrap();
        let want = quant::dequant_relu_quant(&psum, &scale, &zero, c);
        assert_eq!(got, want, "epilogue mismatch at b={b} c={c}");
    }
}

#[test]
fn vfe_mean_matches_reference() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg64::new(105);
    let (v, p, f) = (700usize, 16usize, 4usize); // v > artifact batch 512
    let mut points = vec![0f32; v * p * f];
    let mut counts = vec![0i32; v];
    for i in 0..v {
        let c = rng.range(1, p + 1);
        counts[i] = c as i32;
        for j in 0..c {
            for k in 0..f {
                points[(i * p + j) * f + k] = rng.uniform(-5.0, 5.0) as f32;
            }
        }
    }
    let got = rt.vfe_mean(&points, &counts, v, p, f).unwrap();
    for i in 0..v {
        for k in 0..f {
            let mut s = 0f32;
            for j in 0..p {
                s += points[(i * p + j) * f + k];
            }
            let want = s / counts[i] as f32;
            let g = got[i * f + k];
            assert!(
                (g - want).abs() < 1e-4,
                "voxel {i} ch {k}: {g} vs {want}"
            );
        }
    }
}
