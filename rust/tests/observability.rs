//! Integration: stage-level observability end to end. The recorder must
//! be a pure observer — a disabled run records nothing and an enabled
//! run changes no bit of any result — while an enabled run accounts for
//! every span recorded from every worker thread, attributes engine-side
//! spans to their window/shard/layer, and routes the stream counters
//! through the metrics registry without drift from the report fields.

use std::collections::HashSet;

use voxel_cim::coordinator::executor::WorkerPool;
use voxel_cim::coordinator::scheduler::RunnerConfig;
use voxel_cim::coordinator::shard::ShardConfig;
use voxel_cim::coordinator::stream::{StreamReport, StreamServer};
use voxel_cim::dataset::{FrameSource, ProfileSource, ScenarioProfile};
use voxel_cim::geom::Extent3;
use voxel_cim::mapsearch::{DeltaConfig, SearcherKind};
use voxel_cim::model::layer::{LayerSpec, NetworkSpec, TaskKind};
use voxel_cim::obs::{CostModel, FrameCost, ObsConfig, Recorder, Stage};
use voxel_cim::spconv::layer::NativeEngine;

const EXTENT: Extent3 = Extent3::new(64, 64, 6);
const FRAMES: u64 = 4;

/// Same backbone shape as the temporal-delta suite: two submanifold
/// layers sharing a rulebook, a downsample, and a fresh coarse-scale
/// submanifold — every engine stage (gather / gemm_wave / scatter /
/// requant) fires on every frame.
fn stream_net() -> NetworkSpec {
    NetworkSpec {
        name: "obs-stream",
        task: TaskKind::Segmentation,
        extent: EXTENT,
        vfe_channels: 4,
        layers: vec![
            LayerSpec::Subm3 { c_in: 4, c_out: 8 },
            LayerSpec::Subm3 { c_in: 8, c_out: 8 },
            LayerSpec::GConv2 { c_in: 8, c_out: 16 },
            LayerSpec::Subm3 { c_in: 16, c_out: 16 },
        ],
    }
}

fn cfg(kind: SearcherKind, shard: ShardConfig, delta_on: bool) -> RunnerConfig {
    RunnerConfig {
        searcher: kind,
        shard,
        inflight: 1,
        compute_workers: 2,
        seed: 33,
        delta: DeltaConfig {
            enabled: delta_on,
            compute: delta_on,
            blocks_x: 16,
            blocks_y: 16,
            ..DeltaConfig::default()
        },
        ..Default::default()
    }
}

/// An ego-motion sequence: world-anchored field drifting one voxel per
/// frame plus a per-frame dynamic blob — warm frames reuse cached
/// rulebook fragments, so the delta stages actually run.
fn drift_source(frames: u64, seed: u64) -> Box<dyn FrameSource> {
    Box::new(
        ProfileSource::new(ScenarioProfile::Urban, EXTENT, 0.03, seed)
            .with_drift(1.0)
            .with_frames(frames),
    )
}

fn serve_observed(
    kind: SearcherKind,
    shard: ShardConfig,
    delta_on: bool,
    obs: Recorder,
) -> StreamReport {
    let srv = StreamServer::new(stream_net(), cfg(kind, shard, delta_on), 4).with_observer(obs);
    let mut src = drift_source(FRAMES, 0x0B5);
    srv.serve(FRAMES, src.as_mut(), &mut NativeEngine::default())
        .unwrap()
}

fn tracing_recorder() -> Recorder {
    Recorder::from_config(&ObsConfig {
        trace: true,
        metrics: true,
        ..ObsConfig::default()
    })
}

fn shard_modes() -> [ShardConfig; 2] {
    [
        ShardConfig::default(),
        ShardConfig {
            auto_threshold: 1,
            ..ShardConfig::grid(2, 2).unwrap()
        },
    ]
}

/// The pure-observer property, swept over every searcher kind, sharded
/// and unsharded: a run without an observer records zero spans and
/// leaves the report's stage buckets empty, and attaching a tracing +
/// metrics recorder changes no checksum, pair count, or reuse counter.
#[test]
fn observation_never_perturbs_results_for_any_searcher() {
    for kind in SearcherKind::ALL {
        for shard in shard_modes() {
            let sharding = shard.num_blocks() > 1;
            let plain = serve_observed(kind, shard, true, Recorder::Disabled);
            assert!(
                plain.stage_seconds.iter().all(Vec::is_empty),
                "{kind} sharding={sharding}: disabled run bucketed spans"
            );
            assert!(plain.stage_summary().is_empty());

            let obs = tracing_recorder();
            let seen = serve_observed(kind, shard, true, obs.clone());
            assert!(
                obs.span_count() > 0,
                "{kind} sharding={sharding}: enabled run recorded nothing"
            );

            assert_eq!(plain.completions.len(), FRAMES as usize);
            assert_eq!(seen.completions.len(), FRAMES as usize);
            for (p, s) in plain.completions.iter().zip(&seen.completions) {
                assert_eq!(p.id, s.id);
                assert_eq!(
                    p.result.checksum, s.result.checksum,
                    "{kind} sharding={sharding}: frame {} diverged under observation",
                    p.id
                );
                assert_eq!(p.result.total_pairs(), s.result.total_pairs());
                assert_eq!(p.result.shards, s.result.shards);
            }
            assert_eq!(plain.windows, seen.windows);
            assert_eq!(plain.blocks_searched, seen.blocks_searched);
            assert_eq!(plain.blocks_reused, seen.blocks_reused);
            assert_eq!(plain.waves_skipped, seen.waves_skipped);
            assert_eq!(plain.rows_gathered_saved, seen.rows_gathered_saved);
        }
    }
}

/// Span conservation under the shared-queue worker pool: N jobs each
/// recording M attributed spans from whatever thread picked them up
/// must drain to exactly N*M distinct, well-formed spans — no loss, no
/// duplication, no stripe corruption.
#[test]
fn worker_pool_spans_are_conserved_across_threads() {
    const JOBS: u64 = 32;
    const SPANS_PER_JOB: u32 = 8;
    let obs = tracing_recorder();
    let pool = WorkerPool::new(4);
    let handles: Vec<_> = (0..JOBS)
        .map(|j| {
            let o = obs.clone();
            pool.submit(move || {
                for k in 0..SPANS_PER_JOB {
                    let _g = o.span(Stage::Gather).frame(j).layer(k);
                }
            })
        })
        .collect();
    for h in handles {
        h.join();
    }

    let spans = obs.spans();
    assert_eq!(spans.len(), (JOBS * SPANS_PER_JOB as u64) as usize);
    let mut seen = HashSet::new();
    for s in &spans {
        assert_eq!(s.stage, Stage::Gather);
        assert!(s.start >= 0.0 && s.dur >= 0.0, "negative time in {s:?}");
        assert!(
            seen.insert((s.frame.unwrap(), s.layer.unwrap())),
            "span ({:?}, {:?}) drained twice",
            s.frame,
            s.layer
        );
    }
}

/// An observed sharded delta stream hits every serving-side stage and
/// carries the attribution each site knows: delta plans are tagged with
/// their shard, engine-side work with its window.
#[test]
fn observed_delta_stream_records_expected_stages_with_attribution() {
    let obs = tracing_recorder();
    let shard = ShardConfig {
        auto_threshold: 1,
        ..ShardConfig::grid(2, 2).unwrap()
    };
    let report = serve_observed(SearcherKind::BlockDoms, shard, true, obs.clone());
    assert_eq!(report.completions.len(), FRAMES as usize);

    let spans = obs.spans();
    let has = |st: Stage| spans.iter().any(|s| s.stage == st);
    for st in [
        Stage::MapSearch,
        Stage::DeltaPlan,
        Stage::Gather,
        Stage::GemmWave,
        Stage::Scatter,
        Stage::Requant,
        Stage::Merge,
        Stage::Admission,
        Stage::WindowPack,
    ] {
        assert!(has(st), "no {} span recorded", st.key());
    }
    assert!(
        spans
            .iter()
            .any(|s| s.stage == Stage::DeltaPlan && s.shard.is_some()),
        "sharded delta plans lost their shard attribution"
    );
    assert!(
        spans
            .iter()
            .filter(|s| s.stage == Stage::GemmWave)
            .all(|s| s.window.is_some()),
        "engine-side span missing its ambient window id"
    );

    // The report's summary view agrees with the raw spans.
    let summary = report.stage_summary();
    let keys: Vec<&str> = summary.iter().map(|(k, _)| *k).collect();
    assert!(keys.contains(&"map_search") && keys.contains(&"gemm_wave"));
    for (key, s) in &summary {
        assert!(s.n > 0, "{key}: empty summary bucket survived");
        assert!(s.p95 >= s.p50, "{key}: p95 < p50");
    }
}

/// The registry subsumes the ad-hoc stream counters: after one observed
/// serve on a fresh recorder, every public report field reads back
/// identically from the metrics registry, and the latency histograms
/// saw exactly one observation per completed frame.
#[test]
fn metrics_registry_matches_report_counters_exactly() {
    let obs = tracing_recorder();
    let report =
        serve_observed(SearcherKind::BlockDoms, ShardConfig::default(), true, obs.clone());
    let m = obs.metrics().expect("metrics half enabled");

    assert_eq!(m.counter("stream.windows"), report.windows);
    assert_eq!(m.counter("delta.blocks_searched"), report.blocks_searched);
    assert_eq!(m.counter("delta.blocks_reused"), report.blocks_reused);
    assert_eq!(m.counter("delta.evictions"), report.evictions);
    assert_eq!(m.counter("stream.voxels_rebinned"), report.voxels_rebinned);
    assert_eq!(m.counter("compute.waves_skipped"), report.waves_skipped);
    assert_eq!(
        m.counter("compute.rows_gathered_saved"),
        report.rows_gathered_saved
    );
    assert_eq!(m.counter("admission.admitted"), report.admission.admitted);
    assert_eq!(m.counter("admission.dropped"), report.admission.dropped);
    assert_eq!(m.counter("admission.rejected"), report.admission.rejected);
    assert_eq!(m.counter("admission.deferred"), report.admission.deferred);

    let lat = m.histogram("stream.latency").expect("latency histogram");
    assert_eq!(lat.n, report.completions.len());
    let att = m.histogram("stream.attributed").expect("attributed histogram");
    assert_eq!(att.n, report.completions.len());
    // Warm frames actually reused: the subsumed counters are live, not
    // zero-filled placeholders.
    assert!(m.counter("delta.blocks_reused") > 0);
}

/// A recorder with the cost ledger on (which implies the metrics half)
/// plus tracing, so counter-track points are retained too.
fn cost_recorder() -> Recorder {
    Recorder::from_config(&ObsConfig {
        trace: true,
        cost: true,
        ..ObsConfig::default()
    })
}

/// Conservation: the stream-level cost summary is exactly the sum of
/// the per-frame ledgers, and its per-stage buckets partition the
/// totals — nothing double-counted, nothing dropped.
#[test]
fn cost_summary_conserves_per_frame_ledgers() {
    let report =
        serve_observed(SearcherKind::Doms, ShardConfig::default(), true, Recorder::Disabled);
    let model = CostModel::default();
    let summary = report.cost_summary();
    let mut total = FrameCost::default();
    for c in &report.completions {
        total.add(&model.frame_cost(&c.result));
    }
    assert_eq!(summary.frames, report.completions.len());
    assert_eq!(summary.bytes, total.total_bytes());
    assert_eq!(summary.dram_bytes, total.dram_bytes());
    assert_eq!(summary.buffer_bytes, total.buffer_bytes());
    assert_eq!(summary.dram_bytes + summary.buffer_bytes, summary.bytes);
    assert_eq!(summary.macs, total.macs);
    assert!(summary.bytes > 0 && summary.macs > 0 && summary.joules > 0.0);
    let tol = 1e-12 * summary.joules.max(1.0);
    assert!((summary.joules - total.total_joules()).abs() <= tol);
    // The per-stage buckets partition the totals exactly.
    let stage_bytes: u64 = summary.stages.iter().map(|(_, c)| c.bytes).sum();
    assert_eq!(stage_bytes, summary.bytes, "stage buckets must sum to total bytes");
    let stage_joules: f64 = summary.stages.iter().map(|(_, c)| c.joules).sum();
    assert!((stage_joules - summary.joules).abs() <= tol);
    // Effective efficiency can never beat the dynamic-only array bound.
    assert!(summary.tops_per_watt > 0.0 && summary.tops_per_watt.is_finite());
}

/// The paper's O(N) claim as a live gate: on the same profile scenes,
/// every searcher's per-voxel access volume is positive, finite, and
/// within one constant-factor band — no kind degrades superlinearly.
#[test]
fn normalized_access_stays_within_a_constant_factor_across_searchers() {
    let mut volumes = Vec::new();
    for kind in SearcherKind::ALL {
        let report =
            serve_observed(kind, ShardConfig::default(), false, Recorder::Disabled);
        assert_eq!(report.completions.len(), FRAMES as usize, "{kind}");
        let na = report.cost_summary().normalized_access;
        assert!(na > 0.0 && na.is_finite(), "{kind}: access volume {na}");
        volumes.push((kind, na));
    }
    let min = volumes.iter().map(|&(_, v)| v).fold(f64::INFINITY, f64::min);
    let max = volumes.iter().map(|&(_, v)| v).fold(0.0f64, f64::max);
    assert!(
        max <= 64.0 * min,
        "normalized access spread breaks the constant-factor band: {volumes:?}"
    );
}

/// Cost accounting is a pure observer: enabling the ledger changes no
/// checksum, the live counters agree exactly with the pure summary, and
/// a recorder without the cost flag (or disabled entirely) records no
/// cost at all.
#[test]
fn cost_accounting_is_a_pure_observer() {
    for shard in shard_modes() {
        let sharding = shard.num_blocks() > 1;
        let plain =
            serve_observed(SearcherKind::BlockDoms, shard, true, Recorder::Disabled);
        let obs = cost_recorder();
        let costed = serve_observed(SearcherKind::BlockDoms, shard, true, obs.clone());
        assert_eq!(plain.completions.len(), costed.completions.len());
        for (p, c) in plain.completions.iter().zip(&costed.completions) {
            assert_eq!(p.id, c.id);
            assert_eq!(
                p.result.checksum, c.result.checksum,
                "sharding={sharding}: frame {} diverged under cost accounting",
                p.id
            );
        }

        // The live ledger recorded, and it agrees with the pure summary.
        let s = costed.cost_summary();
        let m = obs.metrics().expect("cost implies the metrics registry");
        assert_eq!(m.counter("cost.dram_bytes"), s.dram_bytes);
        assert_eq!(m.counter("cost.buffer_bytes"), s.buffer_bytes);
        assert_eq!(m.counter("cost.macs"), s.macs);
        assert!(m.counter("cost.energy_nj") > 0);
        let occ = m
            .histogram("cost.wave_occupancy")
            .expect("sharding={sharding}: no wave occupancy recorded");
        assert!(occ.n > 0 && occ.max <= 1.0 + 1e-9 && occ.p50 > 0.0);
        let fb = m.histogram("cost.frame_bytes").expect("per-frame bytes");
        assert_eq!(fb.n, costed.completions.len());
        // Tracing + cost keeps one counter point per completion.
        assert_eq!(obs.cost_points().len(), costed.completions.len());

        // A metrics-only recorder (no cost flag) records no cost.
        let metrics_only = tracing_recorder();
        let _ = serve_observed(SearcherKind::BlockDoms, shard, true, metrics_only.clone());
        let mm = metrics_only.metrics().expect("metrics half on");
        assert_eq!(mm.counter("cost.dram_bytes"), 0);
        assert_eq!(mm.counter("cost.macs"), 0);
        assert!(mm.histogram("cost.wave_occupancy").is_none());
        assert!(metrics_only.cost_points().is_empty());
    }
    // The fully disabled arm keeps no ledger surface at all.
    assert!(Recorder::Disabled.cost().is_none());
    assert!(Recorder::Disabled.cost_points().is_empty());
}

/// The acceptance gate: on a delta-compute drift stream, warm frames
/// move strictly less modeled DRAM than cold frames while still
/// attributing real (nonzero) access — reduced, never absent.
#[test]
fn delta_warm_frames_cost_less_dram_but_never_zero() {
    for shard in shard_modes() {
        let sharding = shard.num_blocks() > 1;
        let report =
            serve_observed(SearcherKind::Doms, shard, true, Recorder::Disabled);
        let s = report.cost_summary();
        assert!(s.warm_frames > 0, "sharding={sharding}: drift stream never warmed");
        assert!(s.cold_frames > 0, "sharding={sharding}: frame 0 must be cold");
        assert!(
            s.warm_dram_per_frame > 0.0,
            "sharding={sharding}: warm frames must show reduced, not absent, traffic"
        );
        assert!(
            s.warm_dram_per_frame < s.cold_dram_per_frame,
            "sharding={sharding}: warm DRAM/frame {} not below cold {}",
            s.warm_dram_per_frame,
            s.cold_dram_per_frame
        );
        assert!(s.normalized_access > 0.0);
        // Per-frame: every warm frame's records carry live access stats
        // (the satellite-1 fix — reuse stamps real reads, not zero).
        for c in report.completions.iter().filter(|c| c.result.blocks_reused > 0) {
            let touched: u64 = c
                .result
                .records
                .iter()
                .map(|r| r.access.voxel_reads + r.access.voxel_writes)
                .sum();
            assert!(
                touched > 0,
                "sharding={sharding}: warm frame {} read as zero-cost",
                c.id
            );
        }
    }
}
