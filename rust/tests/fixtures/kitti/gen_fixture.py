#!/usr/bin/env python3
"""Regenerate the checked-in KITTI fixture and print the golden values.

The fixture is two velodyne frames on a 16 x 16 x 8 grid with 1 m voxels
(range (16, 16, 8)), so quantization is exact: a point at (i + 0.5) lands
in bin i with no float ambiguity. Frame 000000 additionally carries
corrupt returns (non-finite components -> dropped by Point::parse) and
out-of-range returns (negative / beyond-range -> dropped by
Voxelizer::quantize). Labels are SemanticKITTI-style u32 words: semantic
class in the low 16 bits, instance id in the high 16.

Run from this directory:  python3 gen_fixture.py
"""
import struct, os

MASK = (1 << 64) - 1

def fnv1a(data):
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & MASK
    return h

def frame0():
    pts, labels = [], []
    for k in range(60):
        x = (k * 7 + k // 16) % 16
        y = (k * 5 + 3 * (k // 16)) % 16
        z = (k * 3 + k // 16) % 8
        pts.append((x + 0.5, y + 0.5, z + 0.5, (k % 10) / 10.0))
        labels.append((10 + (k % 4) * 10) | ((k % 3) << 16))
    nan, inf = float("nan"), float("inf")
    corrupt = [(nan, 1.5, 1.5, 0.5), (1.5, inf, 1.5, 0.5),
               (1.5, 1.5, -inf, 0.5), (1.5, 1.5, 1.5, nan)]
    out_of_range = [(-0.5, 3.5, 2.5, 0.5), (3.5, -0.25, 1.0, 0.5),
                    (20.5, 1.5, 1.5, 0.5), (1.5, 1.5, 9.5, 0.5)]
    for p in corrupt + out_of_range:
        pts.append(p)
        labels.append(99)
    return pts, labels

def frame1():
    pts, labels = [], []
    for k in range(40):
        x = (3 + k * 11 + k // 8) % 16
        y = (k * 13 + 5 * (k // 8)) % 16
        z = (1 + k * 5) % 8
        pts.append((x + 0.5, y + 0.5, z + 0.5, ((k * 3) % 10) / 10.0))
        labels.append(40 + (k % 2) * 4)
    return pts, labels

def is_finite(v):
    return v == v and v not in (float("inf"), float("-inf"))

def golden(pts):
    survived = [p for p in pts if all(is_finite(c) for c in p)]
    coords = set()
    for x, y, z, _r in survived:
        if x < 0 or y < 0 or z < 0:
            continue
        c = (int(x), int(y), int(z))   # truncation == Rust `as i32` for >= 0
        if c[0] < 16 and c[1] < 16 and c[2] < 8:
            coords.add(c)
    ordered = sorted(coords, key=lambda c: (c[2], c[1], c[0]))  # depth-major
    blob = b"".join(struct.pack("<iii", x, y, z) for x, y, z in ordered)
    return len(survived), len(coords), fnv1a(blob)

here = os.path.dirname(os.path.abspath(__file__))
for name, (pts, labels) in (("000000", frame0()), ("000001", frame1())):
    with open(os.path.join(here, name + ".bin"), "wb") as f:
        for p in pts:
            f.write(struct.pack("<4f", *p))
    with open(os.path.join(here, name + ".label"), "wb") as f:
        for l in labels:
            f.write(struct.pack("<I", l))
    parsed, voxels, csum = golden(pts)
    print(f"{name}: raw={len(pts)} parsed={parsed} dropped={len(pts)-parsed} "
          f"voxels={voxels} coord_fnv=0x{csum:016X}")
