//! Integration: cross-searcher equivalence and ordering properties on
//! scenes larger and more varied than the unit tests use.

use voxel_cim::geom::Extent3;
use voxel_cim::mapsearch::{BlockDoms, Doms, MapSearch, OutputMajor, WeightMajor};
use voxel_cim::pointcloud::voxelize::Voxelizer;
use voxel_cim::sparse::rulebook::ConvKind;
use voxel_cim::sparse::{hash_map_search, SparseTensor};
use voxel_cim::testing::prop::check;

fn searchers() -> Vec<Box<dyn MapSearch>> {
    vec![
        Box::new(WeightMajor::default()),
        Box::new(OutputMajor::default()),
        Box::new(Doms::default()),
        Box::new(BlockDoms::default()),
        Box::new(BlockDoms::with_partition(3, 5).unwrap()),
    ]
}

#[test]
fn all_searchers_equal_oracle_on_urban_frame() {
    // A realistic LiDAR-like frame rather than i.i.d. noise.
    let pts = voxel_cim::pointcloud::scene::SceneConfig::default()
        .with_points(20_000)
        .generate();
    let vx = Voxelizer::new((70.4, 80.0, 4.0), Extent3::new(352, 400, 10), 4);
    let grid = vx.voxelize(&pts);
    let t = SparseTensor::from_coords(grid.extent, grid.coords(), 1);
    let want = hash_map_search(&t, ConvKind::subm3());
    for s in searchers() {
        let (rb, stats) = s.search_subm(&t, 3);
        assert_eq!(rb.pairs, want.pairs, "{} diverged from oracle", s.name());
        assert!(stats.voxel_reads > 0, "{} reported no traffic", s.name());
    }
}

#[test]
fn all_searchers_equal_oracle_prop() {
    check("all searchers == oracle", 8, |g| {
        let e = Extent3::new(g.usize(6, 48), g.usize(6, 48), g.usize(2, 12));
        let n = g.usize(1, 600);
        let grid = Voxelizer::synth_clustered(
            e,
            (n as f64 / e.volume() as f64).min(0.5),
            g.usize(1, 6),
            0.4,
            g.usize(0, 1 << 30) as u64,
        );
        let t = SparseTensor::from_coords(e, grid.coords(), 1);
        let want = hash_map_search(&t, ConvKind::subm3());
        for s in searchers() {
            let (rb, _) = s.search_subm(&t, 3);
            assert_eq!(rb.pairs, want.pairs, "{} diverged", s.name());
        }
    });
}

#[test]
fn access_volume_ordering_holds_in_stress_regime() {
    // The paper's qualitative ordering in the high-res dense regime:
    // block-DOMS <= DOMS << MARS, and PointAcc pays ~K^3.
    let e = Extent3::new(512, 512, 16);
    let n = (512.0f64 * 512.0 * 0.01) as usize; // 2.5D sparsity 0.01
    let grid = Voxelizer::synth_occupancy(e, n as f64 / e.volume() as f64, 77);
    let t = SparseTensor::from_coords(e, grid.coords(), 1);
    let nv = t.len();
    let (_, wm) = WeightMajor::default().search_subm(&t, 3);
    let (_, om) = OutputMajor::default().search_subm(&t, 3);
    let (_, d) = Doms::default().search_subm(&t, 3);
    let (_, bd) = BlockDoms::with_partition(4, 8).unwrap().search_subm(&t, 3);
    let (wm, om, d, bd) = (
        wm.normalized(nv),
        om.normalized(nv),
        d.normalized(nv),
        bd.normalized(nv),
    );
    assert!((wm - 27.0).abs() < 0.5, "weight-major {wm}");
    assert!(om > d, "MARS {om} should exceed DOMS {d} here");
    assert!(d <= 2.3, "DOMS {d}");
    assert!(bd <= d + 0.2, "block-DOMS {bd} vs DOMS {d}");
}

#[test]
fn gconv_and_tconv_geometry_roundtrip() {
    check("gconv/tconv roundtrip via searchers", 6, |g| {
        let e = Extent3::new(16, 16, 8);
        let grid = Voxelizer::synth_occupancy(
            e,
            g.f64(0.01, 0.2),
            g.usize(0, 1 << 30) as u64,
        );
        let t = SparseTensor::from_coords(e, grid.coords(), 1);
        let doms = Doms::default();
        let (down, _) = doms.search(&t, ConvKind::gconv2());
        // Every output of gconv2 comes from at least one input.
        assert!(down.out_coords.len() <= t.len());
        assert!(down.len() >= down.out_coords.len());
        let dt = SparseTensor::from_coords(down.out_extent, down.out_coords.clone(), 1);
        let (up, _) = doms.search(&dt, ConvKind::tconv2());
        // Upsampling recovers at least all original occupied coords that
        // fed the downsample.
        for &c in &t.coords {
            assert!(
                up.out_coords.binary_search(&c).is_ok(),
                "lost {c:?} in down-up roundtrip"
            );
        }
    });
}
