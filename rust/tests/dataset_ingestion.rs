//! Integration tests for the dataset & scenario ingestion subsystem:
//! the KITTI fixture golden files, the `FrameSource` unification of the
//! stream path, prefetched-vs-direct bit-identity across every searcher,
//! scenario profiles through the shard scheduler, and trace replay.

use std::path::{Path, PathBuf};

use voxel_cim::coordinator::scheduler::{NetworkRunner, RunnerConfig};
use voxel_cim::coordinator::shard::ShardConfig;
use voxel_cim::coordinator::stream::StreamServer;
use voxel_cim::dataset::{
    kitti, KittiSource, PrefetchSource, ProfileSource, ScenarioProfile, Trace,
};
use voxel_cim::geom::Extent3;
use voxel_cim::mapsearch::SearcherKind;
use voxel_cim::model::layer::{LayerSpec, NetworkSpec, TaskKind};
use voxel_cim::pointcloud::voxelize::Voxelizer;
use voxel_cim::spconv::layer::NativeEngine;

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/kitti")
}

/// The fixture's voxelizer: 1 m voxels over a 16 x 16 x 8 m box, so
/// quantization is float-exact (see the fixture README).
fn fixture_voxelizer() -> Voxelizer {
    Voxelizer::new((16.0, 16.0, 8.0), Extent3::new(16, 16, 8), 8)
}

fn tiny_net(extent: Extent3) -> NetworkSpec {
    NetworkSpec {
        name: "dataset-tiny",
        task: TaskKind::Segmentation,
        extent,
        vfe_channels: 4,
        layers: vec![
            LayerSpec::Subm3 { c_in: 4, c_out: 8 },
            LayerSpec::Subm3 { c_in: 8, c_out: 8 },
        ],
    }
}

/// FNV-1a over depth-major coordinate triples (x, y, z as i32 LE) — the
/// checksum `gen_fixture.py` prints as `coord_fnv`.
fn coord_checksum(coords: &[voxel_cim::geom::Coord3]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for c in coords {
        for v in [c.x, c.y, c.z] {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100_0000_01b3);
            }
        }
    }
    h
}

#[test]
fn kitti_fixture_matches_golden_counts_and_checksums() {
    // Golden constants from `tests/fixtures/kitti/gen_fixture.py`.
    const GOLD: [(&str, usize, usize, usize, u64); 2] = [
        ("000000.bin", 68, 4, 32, 0x48A2_071F_35B0_0EA5),
        ("000001.bin", 40, 0, 40, 0x3F27_DBF8_F3AD_F285),
    ];
    let vx = fixture_voxelizer();
    for (name, raw, dropped, voxels, checksum) in GOLD {
        let bin = fixture_dir().join(name);
        let frame = kitti::read_frame(&bin, None).unwrap();
        assert_eq!(frame.points.len() + frame.dropped, raw, "{name}");
        assert_eq!(frame.dropped, dropped, "{name}");
        let grid = vx.voxelize(&frame.points);
        assert_eq!(grid.len(), voxels, "{name}");
        assert_eq!(coord_checksum(&grid.coords()), checksum, "{name}");
    }
}

#[test]
fn kitti_labels_pair_and_filter_in_lockstep_with_points() {
    let bin = fixture_dir().join("000000.bin");
    let label = fixture_dir().join("000000.label");
    let raw_labels = kitti::read_labels(&label).unwrap();
    assert_eq!(raw_labels.len(), 68);
    let frame = kitti::read_frame(&bin, Some(&label)).unwrap();
    let labels = frame.labels.unwrap();
    assert_eq!(labels.len(), frame.points.len());
    // The four corrupt returns carried class 99 and were dropped with
    // their points; the four out-of-range returns survive parsing (the
    // voxelizer drops them later), so exactly 4 of the 8 class-99 words
    // remain.
    let nines = labels.iter().filter(|&&l| kitti::semantic_class(l) == 99).count();
    assert_eq!(nines, 4);
    // The generator's class cycle: k % 4 -> 10/20/30/40, 15 each.
    for class in [10u32, 20, 30, 40] {
        let n = labels
            .iter()
            .filter(|&&l| kitti::semantic_class(l) == class)
            .count();
        assert_eq!(n, 15, "class {class}");
    }
    // Majority labels align with the voxel grid.
    let vx = fixture_voxelizer();
    let grid = vx.voxelize(&frame.points);
    let per_voxel = kitti::voxel_majority_labels(&vx, &grid, &frame.points, &labels);
    assert_eq!(per_voxel.len(), grid.len());
    assert!(per_voxel.iter().all(|&l| [10, 20, 30, 40].contains(&l)));
}

#[test]
fn kitti_fixture_serves_end_to_end_and_deterministically() {
    let srv = StreamServer::new(
        tiny_net(Extent3::new(16, 16, 8)),
        RunnerConfig::default(),
        2,
    );
    let serve_once = || {
        let mut src = KittiSource::open(fixture_dir(), fixture_voxelizer()).unwrap();
        assert_eq!(src.len(), 2);
        srv.serve(8, &mut src, &mut NativeEngine::default()).unwrap()
    };
    let a = serve_once();
    // Two frames on disk: the stream ends there even though we asked
    // for 8.
    assert_eq!(a.completions.len(), 2);
    assert_eq!(a.completions[0].id, 0);
    assert_eq!(a.completions[1].id, 1);
    assert!(a.completions.iter().all(|c| c.result.out_voxels > 0));
    let b = serve_once();
    for (x, y) in a.completions.iter().zip(&b.completions) {
        assert_eq!(x.result.checksum, y.result.checksum, "frame {}", x.id);
    }
}

/// The acceptance property: for every `SearcherKind`, serving a profile
/// stream through the double-buffered prefetching loader is bit-identical
/// to direct iteration.
#[test]
fn prefetched_loading_is_bit_identical_to_direct_for_all_searchers() {
    let extent = Extent3::new(24, 24, 8);
    let profile = || {
        ProfileSource::new(ScenarioProfile::Urban, extent, 0.04, 0x5EED).with_frames(4)
    };
    for kind in SearcherKind::ALL {
        let srv = StreamServer::new(
            tiny_net(extent),
            RunnerConfig {
                searcher: kind,
                inflight: 2,
                ..Default::default()
            },
            4,
        );
        let mut direct = profile();
        let direct_report = srv
            .serve(4, &mut direct, &mut NativeEngine::default())
            .unwrap();
        let mut prefetched = PrefetchSource::spawn(Box::new(profile()), 2);
        let prefetched_report = srv
            .serve(4, &mut prefetched, &mut NativeEngine::default())
            .unwrap();
        assert_eq!(direct_report.completions.len(), 4, "{kind}");
        assert_eq!(prefetched_report.completions.len(), 4, "{kind}");
        for (a, b) in direct_report
            .completions
            .iter()
            .zip(&prefetched_report.completions)
        {
            assert_eq!(a.id, b.id, "{kind}");
            assert_eq!(
                a.result.checksum, b.result.checksum,
                "{kind}: frame {} diverged under prefetching",
                a.id
            );
            assert_eq!(a.result.total_pairs(), b.result.total_pairs(), "{kind}");
        }
    }
}

/// Every scenario profile serves end-to-end through `StreamServer::serve`.
#[test]
fn every_profile_serves_through_the_stream_server() {
    let extent = Extent3::new(24, 24, 8);
    let srv = StreamServer::new(
        tiny_net(extent),
        RunnerConfig {
            inflight: 2,
            ..Default::default()
        },
        3,
    );
    for profile in ScenarioProfile::ALL {
        let mut src =
            ProfileSource::new(profile, extent, 0.04, 0x90).with_frames(3);
        let report = srv.serve(3, &mut src, &mut NativeEngine::default()).unwrap();
        assert_eq!(report.completions.len(), 3, "{profile}");
        assert!(
            report.completions.iter().all(|c| c.result.out_voxels > 0),
            "{profile}"
        );
    }
}

/// Every scenario profile runs through the shard scheduler and merges
/// bit-identically to the unsharded path.
#[test]
fn scenario_profiles_run_sharded_bit_identically() {
    let extent = Extent3::new(64, 64, 8);
    let net = tiny_net(extent);
    let plain = NetworkRunner::new(net.clone(), RunnerConfig::default());
    let sharded = NetworkRunner::new(
        net,
        RunnerConfig {
            shard: ShardConfig::grid(2, 2).unwrap(),
            ..Default::default()
        },
    );
    for profile in ScenarioProfile::ALL {
        let frame = ProfileSource::new(profile, extent, 0.03, 0xCAFE).generate(1);
        assert!(!frame.is_empty(), "{profile}");
        let want = plain
            .run_frames(vec![frame.clone()], &mut NativeEngine::default())
            .unwrap()
            .pop()
            .expect("one frame in, one result out");
        let got = sharded
            .run_scenes(vec![frame], &mut NativeEngine::default())
            .unwrap()
            .pop()
            .expect("one scene in, one result out");
        assert_eq!(
            want.checksum, got.checksum,
            "{profile} diverged under shard scheduling"
        );
        assert!(got.shards >= 1, "{profile}");
    }
}

/// Trace record/replay closes the loop: a replayed stream yields the
/// same `FrameResult` checksums as the live source it was recorded from.
#[test]
fn trace_replay_serves_bit_identically_to_the_live_source() {
    let extent = Extent3::new(24, 24, 8);
    let srv = StreamServer::new(tiny_net(extent), RunnerConfig::default(), 2);
    let mut live =
        ProfileSource::new(ScenarioProfile::FarField, extent, 0.04, 0x11).with_frames(3);
    let live_report = srv.serve(3, &mut live, &mut NativeEngine::default()).unwrap();

    let mut fresh =
        ProfileSource::new(ScenarioProfile::FarField, extent, 0.04, 0x11).with_frames(3);
    let trace = Trace::record(&mut fresh, 3);
    let path = std::env::temp_dir().join("voxel-cim-dataset-ingestion.vctr");
    trace.save(&path).unwrap();
    let loaded = Trace::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let mut replay = loaded.replay();
    let replay_report = srv
        .serve(3, &mut replay, &mut NativeEngine::default())
        .unwrap();
    assert_eq!(live_report.completions.len(), replay_report.completions.len());
    for (a, b) in live_report
        .completions
        .iter()
        .zip(&replay_report.completions)
    {
        assert_eq!(a.id, b.id);
        assert_eq!(
            a.result.checksum, b.result.checksum,
            "frame {} diverged under replay",
            a.id
        );
    }
}

#[test]
fn truncated_kitti_files_error_instead_of_silently_truncating() {
    let tmp = std::env::temp_dir().join("voxel-cim-kitti-truncated");
    std::fs::create_dir_all(&tmp).unwrap();
    let bin = tmp.join("000000.bin");
    let bytes = std::fs::read(fixture_dir().join("000000.bin")).unwrap();
    std::fs::write(&bin, &bytes[..bytes.len() - 3]).unwrap();
    assert!(kitti::read_frame(&bin, None).is_err());
    let label = tmp.join("000000.label");
    std::fs::write(&label, [1u8, 2, 3]).unwrap();
    assert!(kitti::read_labels(&label).is_err());
    // Label/point count mismatch is an error too.
    std::fs::write(&bin, &bytes).unwrap();
    std::fs::write(&label, [0u8; 12]).unwrap();
    assert!(kitti::read_frame(&bin, Some(&label)).is_err());
    std::fs::remove_dir_all(&tmp).ok();
}
