//! Integration: the configurable engine layer. Every `SearcherKind` must
//! build bit-identical rulebooks vs the hash oracle across randomized
//! scenes, any searcher must be acceptable on the runner/stream request
//! path, and batched multi-frame GEMM waves must reproduce the
//! single-frame path bit for bit while issuing no more engine dispatches.

use voxel_cim::coordinator::scheduler::{NetworkRunner, RunnerConfig};
use voxel_cim::coordinator::stream::StreamServer;
use voxel_cim::dataset::ClosureSource;
use voxel_cim::geom::Extent3;
use voxel_cim::mapsearch::SearcherKind;
use voxel_cim::model::layer::{LayerSpec, NetworkSpec, TaskKind};
use voxel_cim::pointcloud::voxelize::Voxelizer;
use voxel_cim::sparse::rulebook::ConvKind;
use voxel_cim::sparse::{hash_map_search, SparseTensor};
use voxel_cim::spconv::layer::NativeEngine;
use voxel_cim::testing::prop::check;

#[test]
fn every_searcher_kind_matches_the_hash_oracle_on_random_scenes() {
    check("all SearcherKind == hash oracle", 12, |g| {
        let t = g.sparse_scene(48, 12, 600);
        let want = hash_map_search(&t, ConvKind::subm3());
        for kind in SearcherKind::ALL {
            let s = kind.build();
            let (rb, _) = s.search_subm(&t, 3);
            assert_eq!(
                rb.pairs, want.pairs,
                "{kind} diverged from the oracle on {} voxels at {:?}",
                t.len(),
                t.extent
            );
            assert_eq!(rb.out_coords, want.out_coords, "{kind} output set");
            rb.validate(&t).unwrap_or_else(|e| panic!("{kind}: {e}"));
        }
    });
}

fn tiny_net() -> NetworkSpec {
    NetworkSpec {
        name: "engine-layer-tiny",
        task: TaskKind::Segmentation,
        extent: Extent3::new(32, 32, 8),
        vfe_channels: 4,
        layers: vec![
            LayerSpec::Subm3 { c_in: 4, c_out: 16 },
            LayerSpec::Subm3 { c_in: 16, c_out: 16 },
            LayerSpec::GConv2 { c_in: 16, c_out: 32 },
            LayerSpec::Subm3 { c_in: 32, c_out: 32 },
        ],
    }
}

fn make_frame(id: u64) -> SparseTensor {
    let e = Extent3::new(32, 32, 8);
    let g = Voxelizer::synth_clustered(e, 0.04, 4, 0.35, 900 + id);
    let mut t = SparseTensor::from_coords(e, g.coords(), 4);
    for (i, v) in t.features.iter_mut().enumerate() {
        *v = ((i as u64 + 5 * id) % 9) as i8;
    }
    t
}

#[test]
fn runner_accepts_every_searcher_kind_with_identical_outputs() {
    let mut checksums = Vec::new();
    for kind in SearcherKind::ALL {
        let runner = NetworkRunner::new(
            tiny_net(),
            RunnerConfig {
                searcher: kind,
                seed: 21,
                ..Default::default()
            },
        );
        let res = runner
            .run_frames(vec![make_frame(0)], &mut NativeEngine::default())
            .unwrap()
            .pop()
            .expect("one frame in, one result out");
        assert!(res.total_pairs() > 0);
        // One record per layer; every sparse layer actually searched.
        let net = tiny_net();
        assert_eq!(res.records.len(), net.layers.len());
        for (spec, record) in net.layers.iter().zip(&res.records) {
            assert_eq!(spec.is_sparse(), record.pairs > 0, "{}", record.name);
        }
        checksums.push((kind, res.checksum));
    }
    let want = checksums[0].1;
    for (kind, got) in checksums {
        assert_eq!(got, want, "searcher {kind} changed the frame bits");
    }
}

#[test]
fn batched_waves_are_bit_identical_and_amortize_dispatches() {
    let runner = NetworkRunner::new(
        tiny_net(),
        RunnerConfig {
            batch: 64,
            seed: 22,
            // Serial compute so the NativeEngine dispatch counter sees
            // every GEMM (forked engines keep their own counters).
            compute_workers: 1,
            ..Default::default()
        },
    );
    let frames: Vec<SparseTensor> = (0..4).map(make_frame).collect();

    let mut solo_engine = NativeEngine::default();
    let mut solo = Vec::new();
    for f in &frames {
        solo.push(
            runner
                .run_frames(vec![f.clone()], &mut solo_engine)
                .unwrap()
                .pop()
                .expect("one frame in, one result out"),
        );
    }

    let mut wave_engine = NativeEngine::default();
    let batched = runner
        .run_frames(frames, &mut wave_engine)
        .unwrap();

    assert_eq!(solo.len(), batched.len());
    for (a, b) in solo.iter().zip(&batched) {
        assert_eq!(a.checksum, b.checksum, "frame bits diverged under batching");
        assert_eq!(a.total_pairs(), b.total_pairs());
        assert_eq!(a.out_voxels, b.out_voxels);
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(ra.pairs, rb.pairs, "{}", ra.name);
            assert_eq!(ra.out_voxels, rb.out_voxels, "{}", ra.name);
            assert_eq!(ra.workload, rb.workload, "{}", ra.name);
        }
    }
    assert!(
        wave_engine.calls < solo_engine.calls,
        "shared waves should amortize dispatches: {} vs {}",
        wave_engine.calls,
        solo_engine.calls
    );
}

#[test]
fn stream_server_accepts_configured_searchers() {
    for kind in [SearcherKind::Hash, SearcherKind::BlockDoms, SearcherKind::Octree] {
        let srv = StreamServer::new(
            tiny_net(),
            RunnerConfig {
                searcher: kind,
                inflight: 2,
                ..Default::default()
            },
            4,
        );
        let mut source = ClosureSource::new(make_frame);
        let report = srv
            .serve(4, &mut source, &mut NativeEngine::default())
            .unwrap();
        assert_eq!(report.completions.len(), 4, "{kind}");
        let ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3], "{kind}");
    }
}
