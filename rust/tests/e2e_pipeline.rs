//! Integration: whole-network frames through the coordinator with both
//! engines; PJRT (when artifacts exist) must agree with native exactly,
//! since both implement the same bit-serial CIM semantics.

use voxel_cim::coordinator::scheduler::{NetworkRunner, RunnerConfig};
use voxel_cim::geom::Extent3;
use voxel_cim::model::layer::{LayerSpec, NetworkSpec, TaskKind};
use voxel_cim::pointcloud::voxelize::Voxelizer;
use voxel_cim::runtime::{Runtime, RuntimeConfig};
use voxel_cim::sparse::tensor::SparseTensor;
use voxel_cim::spconv::layer::NativeEngine;
use voxel_cim::util::rng::Pcg64;

fn tiny_net() -> NetworkSpec {
    use LayerSpec::*;
    NetworkSpec {
        name: "tiny",
        task: TaskKind::Detection,
        extent: Extent3::new(24, 24, 8),
        vfe_channels: 4,
        layers: vec![
            Subm3 { c_in: 4, c_out: 16 },
            Subm3 { c_in: 16, c_out: 16 },
            GConv2 { c_in: 16, c_out: 32 },
            Subm3 { c_in: 32, c_out: 32 },
            ToBev,
            Conv2d { c_in: 128, c_out: 32, k: 3, stride: 1 },
            Conv2d { c_in: 32, c_out: 32, k: 3, stride: 2 },
        ],
    }
}

fn frame(extent: Extent3, n: usize, seed: u64) -> SparseTensor {
    let g = Voxelizer::synth_occupancy(extent, n as f64 / extent.volume() as f64, seed);
    let mut t = SparseTensor::from_coords(extent, g.coords(), 4);
    let mut rng = Pcg64::new(seed ^ 0xabc);
    for v in t.features.iter_mut() {
        *v = rng.next_i8(0, 16);
    }
    t
}

/// One frame through the lockstep loop — the non-deprecated spelling of
/// the legacy `run_frame` (facade submissions go through
/// `Pipeline::run(Job::Frame(..))`; see `tests/pipeline_api.rs`).
fn run_one<E: voxel_cim::spconv::layer::GemmEngine>(
    runner: &NetworkRunner,
    t: SparseTensor,
    engine: &mut E,
) -> voxel_cim::coordinator::FrameResult {
    runner
        .run_frames(vec![t], engine)
        .unwrap()
        .pop()
        .expect("one frame in, one result out")
}

#[test]
fn native_run_is_deterministic() {
    let net = tiny_net();
    let input = frame(net.extent, 250, 201);
    let runner = NetworkRunner::new(net, RunnerConfig { batch: 64, workers: 2, seed: 5, ..Default::default() });
    let a = run_one(&runner, input.clone(), &mut NativeEngine::default());
    let b = run_one(&runner, input, &mut NativeEngine::default());
    assert_eq!(a.total_pairs(), b.total_pairs());
    assert_eq!(a.head_shape, b.head_shape);
    let last_a = &a.records.last().unwrap();
    let last_b = &b.records.last().unwrap();
    assert_eq!(last_a.out_voxels, last_b.out_voxels);
}

#[test]
fn pjrt_and_native_agree_end_to_end() {
    let Ok(mut rt) = Runtime::load(&RuntimeConfig::discover()) else {
        eprintln!("SKIP: artifacts not built");
        return;
    };
    let net = tiny_net();
    let input = frame(net.extent, 200, 202);
    let runner = NetworkRunner::new(net, RunnerConfig { batch: 64, workers: 2, seed: 6, ..Default::default() });
    let native = run_one(&runner, input.clone(), &mut NativeEngine::default());
    let pjrt = run_one(&runner, input, &mut rt);
    assert_eq!(native.head_shape, pjrt.head_shape);
    assert_eq!(native.total_pairs(), pjrt.total_pairs());
    // The per-layer output voxel counts and pair counts must agree
    // exactly (the numerics are bit-identical, so coordinates and
    // sparsity patterns match).
    for (a, b) in native.records.iter().zip(&pjrt.records) {
        assert_eq!(a.pairs, b.pairs, "{}", a.name);
        assert_eq!(a.out_voxels, b.out_voxels, "{}", a.name);
    }
    assert!(rt.gemm_dispatches.get() > 0, "PJRT was never dispatched");
}

#[test]
fn batch_size_does_not_change_results() {
    let net = tiny_net();
    let input = frame(net.extent, 220, 203);
    for batch in [16, 64, 1024] {
        let runner = NetworkRunner::new(
            tiny_net(),
            RunnerConfig { batch, workers: 1, seed: 6, ..Default::default() },
        );
        let res = run_one(&runner, input.clone(), &mut NativeEngine::default());
        // Head shape and pair totals are invariant under wave batching.
        // 24x24 voxel grid -> gconv2 -> 12x12 BEV -> stride-2 RPN -> 6x6.
        assert_eq!(res.head_shape, Some((6, 6, 32)));
        assert!(res.total_pairs() > 0);
    }
    let _ = net;
}
