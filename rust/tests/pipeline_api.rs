//! Legacy-compat witness for the pipeline facade: `Pipeline::run(Job::*)`
//! must be checksum-bit-identical to every legacy entry point it
//! replaces — `run_frame`, `run_frame_sharded`, `run_frames`, `serve`,
//! and `serve_closure` — for all six `SearcherKind`s, sharded and
//! unsharded; and builder misconfigurations must surface as typed
//! `PipelineError`s.
//!
//! This file is the ONE place deprecated entry points may still be
//! called (the CI deprecation check builds everything else with
//! `-D deprecated`): the comparisons below are exactly what the shims
//! exist for.
#![allow(deprecated)]

use voxel_cim::coordinator::scheduler::{NetworkRunner, RunnerConfig};
use voxel_cim::coordinator::shard::ShardConfig;
use voxel_cim::coordinator::stream::StreamServer;
use voxel_cim::dataset::ClosureSource;
use voxel_cim::geom::Extent3;
use voxel_cim::mapsearch::SearcherKind;
use voxel_cim::model::layer::{LayerSpec, NetworkSpec, TaskKind};
use voxel_cim::pipeline::{EngineKind, Job, Pipeline, PipelineConfig, PipelineError};
use voxel_cim::pointcloud::voxelize::Voxelizer;
use voxel_cim::sparse::SparseTensor;
use voxel_cim::spconv::layer::NativeEngine;

/// Segmentation net with a downsampling stage: shard plans get a real
/// halo and the merge path real cross-block pairs.
fn seg_net(extent: Extent3) -> NetworkSpec {
    NetworkSpec {
        name: "facade-seg",
        task: TaskKind::Segmentation,
        extent,
        vfe_channels: 4,
        layers: vec![
            LayerSpec::Subm3 { c_in: 4, c_out: 8 },
            LayerSpec::Subm3 { c_in: 8, c_out: 8 },
            LayerSpec::GConv2 { c_in: 8, c_out: 16 },
        ],
    }
}

/// Detection-shaped net: sparse prefix, BEV flatten, one dense RPN layer
/// — exercises the merged-scene dense suffix through the facade.
fn det_net(extent: Extent3) -> NetworkSpec {
    NetworkSpec {
        name: "facade-det",
        task: TaskKind::Detection,
        extent,
        vfe_channels: 4,
        layers: vec![
            LayerSpec::Subm3 { c_in: 4, c_out: 8 },
            LayerSpec::GConv2 { c_in: 8, c_out: 16 },
            LayerSpec::ToBev,
            LayerSpec::Conv2d { c_in: 64, c_out: 16, k: 3, stride: 1 },
        ],
    }
}

fn make_frame(id: u64) -> SparseTensor {
    // Uniform occupancy: every 2x2 shard block is populated, so the
    // sharded comparisons below genuinely split each scene.
    let e = Extent3::new(24, 24, 8);
    let g = Voxelizer::synth_occupancy(e, 0.05, 7100 + id);
    let mut t = SparseTensor::from_coords(e, g.coords(), 4);
    for (i, v) in t.features.iter_mut().enumerate() {
        *v = ((i as u64 + 3 * id) % 9) as i8;
    }
    t
}

/// A facade over `net` with this exact runner config and a fresh native
/// engine — the same stack the legacy entry points are handed.
fn facade(net: NetworkSpec, rc: RunnerConfig) -> Pipeline {
    let cfg = PipelineConfig {
        runner: rc,
        engine: EngineKind::Native,
        ..Default::default()
    };
    Pipeline::builder()
        .config(cfg)
        .network(net)
        .engine(NativeEngine::default())
        .build()
        .expect("facade pipeline")
}

#[test]
fn job_frame_matches_run_frame_and_run_frame_sharded_for_every_searcher() {
    let e = Extent3::new(24, 24, 8);
    for kind in SearcherKind::ALL {
        for (sharded, shard) in [
            (false, ShardConfig::default()),
            (true, ShardConfig::grid(2, 2).unwrap()),
        ] {
            let rc = RunnerConfig {
                searcher: kind,
                shard,
                batch: 64,
                seed: 41,
                ..Default::default()
            };
            let legacy = NetworkRunner::new(seg_net(e), rc);
            let want = if sharded {
                legacy
                    .run_frame_sharded(make_frame(3), &mut NativeEngine::default())
                    .unwrap()
            } else {
                legacy
                    .run_frame(make_frame(3), &mut NativeEngine::default())
                    .unwrap()
            };
            let mut pipe = facade(seg_net(e), rc);
            let got = pipe
                .run(Job::Frame(make_frame(3)))
                .unwrap()
                .into_frame()
                .unwrap();
            assert_eq!(
                want.checksum, got.checksum,
                "{kind} sharded={sharded}: facade diverged from the legacy entry point"
            );
            assert_eq!(want.out_voxels, got.out_voxels, "{kind} sharded={sharded}");
            assert_eq!(want.shards, got.shards, "{kind} sharded={sharded}");
            if sharded {
                assert!(got.shards > 1, "{kind}: scene should actually shard");
            }
        }
    }
}

#[test]
fn job_frame_runs_the_dense_head_like_the_legacy_sharded_path() {
    let e = Extent3::new(32, 32, 8);
    let rc = RunnerConfig {
        shard: ShardConfig::grid(2, 2).unwrap(),
        batch: 64,
        seed: 43,
        ..Default::default()
    };
    let legacy = NetworkRunner::new(det_net(e), rc);
    let want = legacy
        .run_frame_sharded(make_big(e, 9), &mut NativeEngine::default())
        .unwrap();
    let mut pipe = facade(det_net(e), rc);
    let got = pipe
        .run(Job::Frame(make_big(e, 9)))
        .unwrap()
        .into_frame()
        .unwrap();
    assert!(got.shards > 1);
    assert_eq!(want.checksum, got.checksum, "dense-head bits diverged");
    assert_eq!(want.head_shape, got.head_shape);
}

fn make_big(e: Extent3, id: u64) -> SparseTensor {
    let g = Voxelizer::synth_occupancy(e, 0.06, 9200 + id);
    let mut t = SparseTensor::from_coords(e, g.coords(), 4);
    for (i, v) in t.features.iter_mut().enumerate() {
        *v = ((i as u64 + id) % 8) as i8;
    }
    t
}

#[test]
fn job_window_matches_run_frames() {
    let e = Extent3::new(24, 24, 8);
    let rc = RunnerConfig {
        batch: 64,
        seed: 44,
        ..Default::default()
    };
    let inputs: Vec<SparseTensor> = (0..3).map(make_frame).collect();
    let legacy = NetworkRunner::new(seg_net(e), rc);
    let want = legacy
        .run_frames(inputs.clone(), &mut NativeEngine::default())
        .unwrap();
    let mut pipe = facade(seg_net(e), rc);
    let got = pipe
        .run(Job::Window(inputs))
        .unwrap()
        .into_window()
        .unwrap();
    assert_eq!(want.len(), got.len());
    for (i, (a, b)) in want.iter().zip(&got).enumerate() {
        assert_eq!(a.checksum, b.checksum, "window member {i} diverged");
        assert_eq!(a.out_voxels, b.out_voxels, "window member {i}");
    }
}

#[test]
fn job_stream_matches_legacy_serve_and_serve_closure() {
    let e = Extent3::new(24, 24, 8);
    const FRAMES: u64 = 6;
    let rc = RunnerConfig {
        inflight: 2,
        seed: 45,
        ..Default::default()
    };
    // Legacy direct-source serve.
    let srv = StreamServer::new(seg_net(e), rc, 3);
    let want = srv
        .serve(
            FRAMES,
            &mut ClosureSource::new(make_frame),
            &mut NativeEngine::default(),
        )
        .unwrap();
    // Legacy prefetched closure serve.
    let closure = srv
        .serve_closure(FRAMES, make_frame, &mut NativeEngine::default())
        .unwrap();
    // Facade stream job.
    let cfg = PipelineConfig {
        runner: rc,
        dataset: voxel_cim::dataset::DatasetConfig {
            frames: FRAMES,
            ..Default::default()
        },
        ..Default::default()
    };
    let mut pipe = Pipeline::builder()
        .config(cfg)
        .network(seg_net(e))
        .engine(NativeEngine::default())
        .build()
        .unwrap();
    let got = pipe
        .run(Job::stream(ClosureSource::new(make_frame)))
        .unwrap()
        .into_stream()
        .unwrap();
    assert_eq!(want.completions.len(), FRAMES as usize);
    assert_eq!(got.completions.len(), FRAMES as usize);
    assert_eq!(closure.completions.len(), FRAMES as usize);
    for ((a, b), c) in want
        .completions
        .iter()
        .zip(&got.completions)
        .zip(&closure.completions)
    {
        assert_eq!(a.id, b.id);
        assert_eq!(
            a.result.checksum, b.result.checksum,
            "frame {}: facade stream diverged from legacy serve",
            a.id
        );
        assert_eq!(
            a.result.checksum, c.result.checksum,
            "frame {}: serve_closure diverged",
            a.id
        );
    }
    assert!(pipe.dispatches() > 0, "owned engine saw the stream");
}

#[test]
fn facade_owns_the_engine_across_jobs() {
    // No `&mut E` anywhere: consecutive jobs accumulate on the one owned
    // engine, and the caller never touches it.
    let e = Extent3::new(24, 24, 8);
    let mut pipe = facade(seg_net(e), RunnerConfig { seed: 46, ..Default::default() });
    pipe.run(Job::Frame(make_frame(0))).unwrap();
    let after_one = pipe.dispatches();
    pipe.run(Job::Window(vec![make_frame(1), make_frame(2)]))
        .unwrap();
    assert!(after_one > 0);
    assert!(pipe.dispatches() > after_one, "dispatches accumulate");
}

#[test]
fn builder_validation_errors_are_typed_config_errors() {
    use voxel_cim::dataset::DatasetConfig;
    use voxel_cim::serving::{AdmissionConfig, AdmissionPolicy, ServingConfig};

    // Shedding admission policy without an SLO target.
    let cfg = PipelineConfig {
        serving: ServingConfig {
            admission: AdmissionConfig {
                policy: AdmissionPolicy::DropOldest,
                ..Default::default()
            },
            ..Default::default()
        },
        ..Default::default()
    };
    let err = Pipeline::builder().config(cfg).build().unwrap_err();
    match err.downcast_ref::<PipelineError>() {
        Some(PipelineError::InvalidConfig(msg)) => {
            assert!(msg.contains("slo"), "{msg}")
        }
        other => panic!("expected InvalidConfig, got {other:?} ({err:#})"),
    }

    // Path-shaped dataset source that does not exist.
    let cfg = PipelineConfig {
        dataset: DatasetConfig {
            source: "/no/such/kitti/velodyne".into(),
            ..Default::default()
        },
        ..Default::default()
    };
    let err = Pipeline::builder().config(cfg).build().unwrap_err();
    match err.downcast_ref::<PipelineError>() {
        Some(PipelineError::InvalidConfig(msg)) => {
            assert!(msg.contains("/no/such/kitti/velodyne"), "{msg}");
            assert!(msg.contains("does not exist"), "{msg}");
        }
        other => panic!("expected InvalidConfig, got {other:?} ({err:#})"),
    }

    // Unknown profile in the sequence list.
    let cfg = PipelineConfig {
        serving: ServingConfig {
            sequences: vec!["urban".into(), "wormhole".into()],
            ..Default::default()
        },
        ..Default::default()
    };
    let err = Pipeline::builder().config(cfg).build().unwrap_err();
    match err.downcast_ref::<PipelineError>() {
        Some(PipelineError::InvalidConfig(msg)) => {
            assert!(msg.contains("sequence 1"), "{msg}")
        }
        other => panic!("expected InvalidConfig, got {other:?} ({err:#})"),
    }

    // `engine = "pjrt"` that cannot load (feature off, or artifacts
    // missing) errors at engine resolution as EngineUnavailable (an
    // environment problem, not a config typo) — and only when the
    // builder actually resolves from the config; an explicit engine
    // wins.
    #[cfg(not(feature = "pjrt"))]
    {
        let cfg = PipelineConfig {
            engine: EngineKind::Pjrt,
            ..Default::default()
        };
        let err = Pipeline::builder().config(cfg.clone()).build().unwrap_err();
        match err.downcast_ref::<PipelineError>() {
            Some(PipelineError::EngineUnavailable(msg)) => {
                assert!(msg.contains("pjrt"), "{msg}")
            }
            other => panic!("expected EngineUnavailable, got {other:?} ({err:#})"),
        }
        // Same config + caller-supplied engine builds fine.
        Pipeline::builder()
            .config(cfg)
            .engine(NativeEngine::default())
            .build()
            .expect("explicit engine overrides the config's pjrt kind");
    }
}
