//! Integration: the serving scheduler. Serving several muxed sequences
//! through cross-scene lockstep windows must be bit-identical per frame
//! to serving each sequence alone — across every `SearcherKind`, with
//! sharding on and off — and the packer must strictly reduce engine
//! dispatches on mixed workloads at equal frame counts. Admission
//! policies shed load visibly (counted, ordered) and never change the
//! bits of a frame they let through.

use std::collections::HashMap;

use voxel_cim::coordinator::scheduler::{NetworkRunner, RunnerConfig};
use voxel_cim::coordinator::shard::ShardConfig;
use voxel_cim::coordinator::stream::StreamServer;
use voxel_cim::dataset::{ClosureSource, FrameSource, ProfileSource, ScenarioProfile};
use voxel_cim::geom::Extent3;
use voxel_cim::mapsearch::SearcherKind;
use voxel_cim::model::layer::{LayerSpec, NetworkSpec, TaskKind};
use voxel_cim::serving::{
    AdmissionConfig, AdmissionPolicy, MuxPolicy, SequenceMux, WindowPolicy,
};
use voxel_cim::sparse::SparseTensor;
use voxel_cim::spconv::layer::NativeEngine;

const EXTENT: Extent3 = Extent3::new(32, 32, 8);

fn seg_net() -> NetworkSpec {
    NetworkSpec {
        name: "serving-seg",
        task: TaskKind::Segmentation,
        extent: EXTENT,
        vfe_channels: 4,
        layers: vec![
            LayerSpec::Subm3 { c_in: 4, c_out: 8 },
            LayerSpec::Subm3 { c_in: 8, c_out: 8 },
        ],
    }
}

fn cfg_with(kind: SearcherKind, shard: ShardConfig, inflight: usize) -> RunnerConfig {
    RunnerConfig {
        searcher: kind,
        shard,
        inflight,
        // Serial compute so a caller-held NativeEngine sees every
        // dispatch in the dispatch-count tests.
        compute_workers: 1,
        seed: 21,
        ..Default::default()
    }
}

fn sequence(profile: ScenarioProfile, frames: u64, seed: u64) -> Box<dyn FrameSource> {
    Box::new(ProfileSource::new(profile, EXTENT, 0.04, seed).with_frames(frames))
}

/// Per-frame checksums of one sequence served alone: the exclusive,
/// frame-at-a-time baseline keyed by frame id.
fn solo_checksums(profile: ScenarioProfile, frames: u64, seed: u64) -> HashMap<u64, u64> {
    let srv = StreamServer::new(
        seg_net(),
        cfg_with(SearcherKind::Doms, ShardConfig::default(), 1),
        4,
    );
    let mut src = sequence(profile, frames, seed);
    let report = srv
        .serve(frames, src.as_mut(), &mut NativeEngine::default())
        .unwrap();
    assert_eq!(report.completions.len(), frames as usize);
    report
        .completions
        .iter()
        .map(|c| (c.id, c.result.checksum))
        .collect()
}

#[test]
fn muxed_cross_scene_serving_is_bit_identical_for_every_searcher() {
    const FRAMES: u64 = 3;
    let seqs = [
        (ScenarioProfile::Urban, 0xAAA1u64),
        (ScenarioProfile::Highway, 0xBBB2),
    ];
    let want: Vec<HashMap<u64, u64>> = seqs
        .iter()
        .map(|&(p, seed)| solo_checksums(p, FRAMES, seed))
        .collect();
    // Sharding on: threshold 1 so every ~130-voxel profile frame splits
    // on the 2x2 grid; off: the plain grouped path.
    let shard_modes = [
        ShardConfig::default(),
        ShardConfig {
            auto_threshold: 1,
            ..ShardConfig::grid(2, 2).unwrap()
        },
    ];
    for kind in SearcherKind::ALL {
        for shard in shard_modes {
            let sharding = shard.num_blocks() > 1;
            // inflight 8 fits two 2x2-sharded scenes (4 pseudo-frames
            // each) into one cross-scene window.
            let srv = StreamServer::new(seg_net(), cfg_with(kind, shard, 8), 8)
                .with_window(WindowPolicy::CrossScene);
            let mut mux = SequenceMux::new(
                vec![
                    sequence(seqs[0].0, FRAMES, seqs[0].1),
                    sequence(seqs[1].0, FRAMES, seqs[1].1),
                ],
                MuxPolicy::RoundRobin,
            )
            .unwrap();
            let report = srv
                .serve(2 * FRAMES, &mut mux, &mut NativeEngine::default())
                .unwrap();
            assert_eq!(
                report.completions.len(),
                2 * FRAMES as usize,
                "{kind} sharding={sharding}"
            );
            for c in &report.completions {
                let solo = want[c.sequence as usize][&c.id];
                assert_eq!(
                    c.result.checksum, solo,
                    "{kind} sharding={sharding}: seq {} frame {} diverged \
                     through the muxed cross-scene window",
                    c.sequence, c.id
                );
            }
            if sharding {
                assert!(
                    report.completions.iter().all(|c| c.result.shards > 1),
                    "{kind}: frames should shard at threshold 1"
                );
                assert!(
                    report.windows < 2 * FRAMES,
                    "{kind}: sharded scenes should still pack windows \
                     ({} windows for {} frames)",
                    report.windows,
                    2 * FRAMES
                );
            }
            // Per-sequence completion order is the sequence's own order.
            for s in 0..2u32 {
                let ids: Vec<u64> = report
                    .completions
                    .iter()
                    .filter(|c| c.sequence == s)
                    .map(|c| c.id)
                    .collect();
                assert_eq!(ids, vec![0, 1, 2], "{kind} sequence {s} out of order");
            }
        }
    }
}

/// The mixed-density workload of the dispatch and admission tests:
/// even ids are oversized scenes (shard on a 2x2 grid at threshold 300),
/// odd ids are small frames.
fn mixed_frame(id: u64) -> SparseTensor {
    let e = Extent3::new(48, 48, 8);
    let (target, clusters) = if id % 2 == 0 { (600, 6) } else { (80, 2) };
    let g = voxel_cim::pointcloud::voxelize::Voxelizer::synth_clustered(
        e,
        target as f64 / e.volume() as f64,
        clusters,
        0.35,
        4000 + id,
    );
    let mut t = SparseTensor::from_coords(e, g.coords(), 4);
    let mut rng = voxel_cim::util::rng::Pcg64::new(5000 + id);
    for v in t.features.iter_mut() {
        *v = rng.next_i8(0, 8);
    }
    t
}

fn mixed_net() -> NetworkSpec {
    NetworkSpec {
        name: "serving-mixed",
        task: TaskKind::Segmentation,
        extent: Extent3::new(48, 48, 8),
        vfe_channels: 4,
        layers: vec![
            LayerSpec::Subm3 { c_in: 4, c_out: 8 },
            LayerSpec::Subm3 { c_in: 8, c_out: 8 },
        ],
    }
}

fn mixed_cfg(inflight: usize) -> RunnerConfig {
    RunnerConfig {
        shard: ShardConfig {
            auto_threshold: 300,
            ..ShardConfig::grid(2, 2).unwrap()
        },
        inflight,
        compute_workers: 1,
        // One wave per non-empty offset per window: the dispatch count
        // directly measures how many windows each offset was split over.
        batch: 4096,
        seed: 22,
        ..Default::default()
    }
}

#[test]
fn cross_scene_windows_dispatch_strictly_less_than_exclusive() {
    const FRAMES: u64 = 6;
    let exclusive = StreamServer::new(mixed_net(), mixed_cfg(6), 8);
    let packed = StreamServer::new(mixed_net(), mixed_cfg(6), 8)
        .with_window(WindowPolicy::CrossScene);
    let mut excl_engine = NativeEngine::default();
    let a = exclusive
        .serve(FRAMES, &mut ClosureSource::new(mixed_frame), &mut excl_engine)
        .unwrap();
    let mut packed_engine = NativeEngine::default();
    let b = packed
        .serve(FRAMES, &mut ClosureSource::new(mixed_frame), &mut packed_engine)
        .unwrap();
    assert_eq!(a.completions.len(), FRAMES as usize);
    assert_eq!(b.completions.len(), FRAMES as usize);
    for (x, y) in a.completions.iter().zip(&b.completions) {
        assert_eq!(x.id, y.id);
        assert_eq!(
            x.result.checksum, y.result.checksum,
            "frame {} diverged between window policies",
            x.id
        );
        assert_eq!(x.result.shards, y.result.shards, "frame {}", x.id);
    }
    assert!(
        a.completions.iter().any(|c| c.result.shards > 1),
        "mixed workload should contain sharding scenes"
    );
    assert!(
        b.windows < a.windows,
        "cross-scene packing should cut fewer windows ({} vs {})",
        b.windows,
        a.windows
    );
    assert!(
        packed_engine.calls < excl_engine.calls,
        "cross-scene windows must dispatch strictly less at equal frames: \
         {} vs {}",
        packed_engine.calls,
        excl_engine.calls
    );
}

/// Exclusive serving of the mixed stream with no admission: the
/// per-frame checksum oracle for the admission tests.
fn mixed_oracle(frames: u64) -> HashMap<u64, u64> {
    let srv = StreamServer::new(mixed_net(), mixed_cfg(1), 4);
    let report = srv
        .serve(
            frames,
            &mut ClosureSource::new(mixed_frame),
            &mut NativeEngine::default(),
        )
        .unwrap();
    report
        .completions
        .iter()
        .map(|c| (c.id, c.result.checksum))
        .collect()
}

/// Admission config that is over its SLO from the first completion on:
/// any positive latency exceeds the (absurd) target, making the policy
/// deterministic to test without timing games.
fn instant_pressure(policy: AdmissionPolicy, depth: usize) -> AdmissionConfig {
    AdmissionConfig {
        policy,
        slo_ms: 1e-9,
        depth,
        ..Default::default()
    }
}

#[test]
fn drop_oldest_sheds_stale_frames_and_reports_them() {
    const FRAMES: u64 = 8;
    let oracle = mixed_oracle(FRAMES);
    let srv = StreamServer::new(mixed_net(), mixed_cfg(2), 8)
        .with_window(WindowPolicy::CrossScene)
        .with_admission(instant_pressure(AdmissionPolicy::DropOldest, 4));
    let report = srv
        .serve(
            FRAMES,
            &mut ClosureSource::new(mixed_frame),
            &mut NativeEngine::default(),
        )
        .unwrap();
    let adm = report.admission;
    assert!(adm.dropped > 0, "tiny SLO must shed load");
    assert_eq!(
        report.completions.len() as u64 + adm.dropped,
        FRAMES,
        "every pulled frame is either served or counted dropped"
    );
    let ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
    assert!(ids.windows(2).all(|w| w[0] < w[1]), "order kept: {ids:?}");
    assert_eq!(*ids.last().unwrap(), FRAMES - 1, "newest frame survives");
    for c in &report.completions {
        assert_eq!(
            c.result.checksum, oracle[&c.id],
            "dropping must not change surviving frames' bits (frame {})",
            c.id
        );
    }
}

#[test]
fn reject_over_depth_caps_the_backlog_and_reports_it() {
    const FRAMES: u64 = 8;
    let oracle = mixed_oracle(FRAMES);
    let srv = StreamServer::new(mixed_net(), mixed_cfg(2), 8)
        .with_window(WindowPolicy::CrossScene)
        .with_admission(instant_pressure(AdmissionPolicy::RejectOverDepth, 4));
    let report = srv
        .serve(
            FRAMES,
            &mut ClosureSource::new(mixed_frame),
            &mut NativeEngine::default(),
        )
        .unwrap();
    let adm = report.admission;
    assert!(adm.rejected > 0, "tiny SLO must reject load");
    assert_eq!(report.completions.len() as u64 + adm.rejected, FRAMES);
    let ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
    // Rejection sheds at most one frame per refill pass (pressure is
    // re-evaluated each window), so the earliest admitted frames keep
    // their slots and service order is never scrambled.
    assert!(ids.windows(2).all(|w| w[0] < w[1]), "order kept: {ids:?}");
    assert_eq!(ids[0], 0, "earliest admitted frame keeps its slot");
    for c in &report.completions {
        assert_eq!(c.result.checksum, oracle[&c.id], "frame {}", c.id);
    }
}

#[test]
fn defer_sharding_serves_small_frames_first_under_pressure() {
    const FRAMES: u64 = 4;
    // Stream order: small 1, big 0... mixed_frame: even = big. Use an
    // explicit order: id0 small, id1 big, id2 small, id3 small.
    let frame = |id: u64| mixed_frame(match id {
        0 => 1,
        1 => 0,
        2 => 3,
        3 => 5,
        other => 2 * other + 1,
    });
    // Oracle on the same re-ordered stream.
    let oracle: HashMap<u64, u64> = {
        let srv = StreamServer::new(mixed_net(), mixed_cfg(1), 4);
        let report = srv
            .serve(FRAMES, &mut ClosureSource::new(frame), &mut NativeEngine::default())
            .unwrap();
        report
            .completions
            .iter()
            .map(|c| (c.id, c.result.checksum))
            .collect()
    };
    let srv = StreamServer::new(mixed_net(), mixed_cfg(2), 8)
        .with_window(WindowPolicy::CrossScene)
        .with_admission(instant_pressure(AdmissionPolicy::DeferSharding, 4));
    let report = srv
        .serve(FRAMES, &mut ClosureSource::new(frame), &mut NativeEngine::default())
        .unwrap();
    assert_eq!(report.completions.len(), FRAMES as usize, "defer never drops");
    assert!(report.admission.deferred > 0, "the big scene should defer");
    let ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
    assert_eq!(
        ids,
        vec![0, 2, 3, 1],
        "small frames overtake the queued sharding scene under pressure"
    );
    for c in &report.completions {
        assert_eq!(
            c.result.checksum, oracle[&c.id],
            "deferral must not change any frame's bits (frame {})",
            c.id
        );
    }
}

#[test]
fn cross_scene_window_runs_dense_heads_grouped_bit_identically() {
    // One sharding detection scene plus one small one in a single
    // cross-scene window: the sparse prefix runs as one pseudo-frame
    // group, both merged scenes then run the BEV + RPN suffix as a
    // second lockstep group — bit-identical to each scene served alone.
    let e = Extent3::new(48, 48, 8);
    let net = NetworkSpec {
        name: "serving-det",
        task: TaskKind::Detection,
        extent: e,
        vfe_channels: 4,
        layers: vec![
            LayerSpec::Subm3 { c_in: 4, c_out: 8 },
            LayerSpec::GConv2 { c_in: 8, c_out: 16 },
            LayerSpec::ToBev,
            LayerSpec::Conv2d { c_in: 64, c_out: 32, k: 3, stride: 1 },
        ],
    };
    let runner = NetworkRunner::new(net, mixed_cfg(8));
    let big = mixed_frame(0);
    let small = mixed_frame(1);
    let want_big = runner
        .run_scenes(vec![big.clone()], &mut NativeEngine::default())
        .unwrap()
        .pop()
        .expect("one scene in, one result out");
    let want_small = runner
        .run_scenes(vec![small.clone()], &mut NativeEngine::default())
        .unwrap()
        .pop()
        .expect("one scene in, one result out");
    assert!(want_big.shards > 1, "big det scene should shard");
    let got = runner
        .run_scenes(vec![big, small], &mut NativeEngine::default())
        .unwrap();
    assert_eq!(got.len(), 2);
    assert_eq!(got[0].checksum, want_big.checksum, "big scene diverged");
    assert_eq!(got[1].checksum, want_small.checksum, "small scene diverged");
    assert_eq!(got[0].shards, want_big.shards);
    assert_eq!(got[1].shards, 1);
    assert_eq!(got[0].head_shape, want_big.head_shape);
    assert_eq!(got[1].head_shape, want_small.head_shape);
    assert_eq!(got[0].records.len(), want_big.records.len());
}

#[test]
fn shortest_queue_mux_keeps_uneven_sequences_fair() {
    // A 2-frame sequence next to a 6-frame one: fewest-served-first
    // alternates while both live, then drains the long one; everything
    // still completes, in per-sequence order, bit-identical to solo.
    let want0 = solo_checksums(ScenarioProfile::Indoor, 2, 0xC01);
    let want1 = solo_checksums(ScenarioProfile::FarField, 6, 0xC02);
    let srv = StreamServer::new(
        seg_net(),
        cfg_with(SearcherKind::BlockDoms, ShardConfig::default(), 3),
        8,
    )
    .with_window(WindowPolicy::CrossScene);
    let mut mux = SequenceMux::new(
        vec![
            sequence(ScenarioProfile::Indoor, 2, 0xC01),
            sequence(ScenarioProfile::FarField, 6, 0xC02),
        ],
        MuxPolicy::ShortestQueue,
    )
    .unwrap();
    let report = srv
        .serve(8, &mut mux, &mut NativeEngine::default())
        .unwrap();
    assert_eq!(report.completions.len(), 8);
    for c in &report.completions {
        let want = if c.sequence == 0 { &want0 } else { &want1 };
        assert_eq!(c.result.checksum, want[&c.id], "seq {} frame {}", c.sequence, c.id);
        assert!(c.attributed <= c.latency + 1e-6);
    }
    let seq1_ids: Vec<u64> = report
        .completions
        .iter()
        .filter(|c| c.sequence == 1)
        .map(|c| c.id)
        .collect();
    assert_eq!(seq1_ids, (0..6).collect::<Vec<_>>());
}
