//! Integration: shard-level scheduling. A block-sharded run must be
//! bit-identical to the unsharded path — same checksum, head shape, and
//! output voxel count — for every `SearcherKind`, every partition, both
//! task kinds (sparse-only segmentation and dense-head detection), and
//! in composition with W2B-aware wave packing. The halo math is what
//! makes this hold across shard edges; these tests are its witness.

use voxel_cim::coordinator::scheduler::{FrameResult, NetworkRunner, RunnerConfig};
use voxel_cim::coordinator::shard::ShardConfig;
use voxel_cim::geom::Extent3;
use voxel_cim::mapsearch::SearcherKind;
use voxel_cim::model::layer::{LayerSpec, NetworkSpec, TaskKind};
use voxel_cim::model::minkunet;
use voxel_cim::sparse::SparseTensor;
use voxel_cim::spconv::layer::NativeEngine;
use voxel_cim::testing::prop::check;

/// Shallow segmentation net: halo 5 at scale 2, small enough that a
/// shard's halo ring does not swallow the whole scene — real cross-shard
/// boundary pairs get exercised.
fn seg_net(extent: Extent3) -> NetworkSpec {
    NetworkSpec {
        name: "shard-seg",
        task: TaskKind::Segmentation,
        extent,
        vfe_channels: 4,
        layers: vec![
            LayerSpec::Subm3 { c_in: 4, c_out: 8 },
            LayerSpec::Subm3 { c_in: 8, c_out: 8 },
            LayerSpec::GConv2 { c_in: 8, c_out: 16 },
            LayerSpec::Subm3 { c_in: 16, c_out: 16 },
        ],
    }
}

/// Detection-shaped net: sparse prefix, then BEV flatten and a dense RPN
/// layer — exercises the merged-scene suffix run and its weight-seed
/// continuation.
fn det_net(extent: Extent3) -> NetworkSpec {
    NetworkSpec {
        name: "shard-det",
        task: TaskKind::Detection,
        extent,
        vfe_channels: 4,
        layers: vec![
            LayerSpec::Subm3 { c_in: 4, c_out: 8 },
            LayerSpec::GConv2 { c_in: 8, c_out: 16 },
            LayerSpec::ToBev,
            LayerSpec::Conv2d { c_in: 64, c_out: 32, k: 3, stride: 1 },
        ],
    }
}

fn featured(coords_only: SparseTensor, channels: usize, seed: u64) -> SparseTensor {
    let mut t = SparseTensor::from_coords(coords_only.extent, coords_only.coords, channels);
    let mut rng = voxel_cim::util::rng::Pcg64::new(seed);
    for v in t.features.iter_mut() {
        *v = rng.next_i8(0, 8);
    }
    t
}

fn scene(e: Extent3, n: usize, channels: usize, seed: u64) -> SparseTensor {
    let g = voxel_cim::pointcloud::voxelize::Voxelizer::synth_clustered(
        e,
        n as f64 / e.volume() as f64,
        4,
        0.35,
        seed,
    );
    featured(SparseTensor::from_coords(e, g.coords(), 1), channels, seed ^ 0x5eed)
}

/// One frame through the plain lockstep loop (never sharded) — the
/// non-deprecated spelling of the legacy `run_frame`.
fn run_plain(runner: &NetworkRunner, t: SparseTensor) -> FrameResult {
    runner
        .run_frames(vec![t], &mut NativeEngine::default())
        .unwrap()
        .pop()
        .expect("one frame in, one result out")
}

/// One scene through the shard-scheduling window executor — the
/// non-deprecated spelling of the legacy `run_frame_sharded`.
fn run_sharded(runner: &NetworkRunner, t: SparseTensor) -> FrameResult {
    runner
        .run_scenes(vec![t], &mut NativeEngine::default())
        .unwrap()
        .pop()
        .expect("one scene in, one result out")
}

fn runner_with(net: NetworkSpec, shard: ShardConfig, kind: SearcherKind, w2b: u32) -> NetworkRunner {
    NetworkRunner::new(
        net,
        RunnerConfig {
            searcher: kind,
            shard,
            w2b_factor: w2b,
            batch: 64,
            seed: 33,
            ..Default::default()
        },
    )
}

#[test]
fn sharded_runs_are_bit_identical_for_every_searcher_and_partition() {
    check("sharded == unsharded for any searcher/partition", 6, |g| {
        let coords = g.sparse_scene(48, 8, 320);
        let e = coords.extent;
        let t = featured(coords, 4, g.usize(0, 1 << 30) as u64);
        let (bx, by) = (g.usize(1, 5), g.usize(1, 5));
        for kind in SearcherKind::ALL {
            let runner = runner_with(
                seg_net(e),
                ShardConfig::grid(bx, by).unwrap(),
                kind,
                0,
            );
            let want = run_plain(&runner, t.clone());
            let got = run_sharded(&runner, t.clone());
            assert_eq!(
                want.checksum, got.checksum,
                "{kind} diverged at {bx}x{by} on {} voxels at {e:?}",
                t.len()
            );
            assert_eq!(want.out_voxels, got.out_voxels, "{kind} {bx}x{by}");
            assert_eq!(want.head_shape, got.head_shape);
            assert_eq!(want.records.len(), got.records.len());
        }
    });
}

#[test]
fn detection_head_runs_on_the_merged_scene() {
    let e = Extent3::new(48, 48, 8);
    let t = scene(e, 400, 4, 77);
    let runner = runner_with(det_net(e), ShardConfig::grid(2, 2).unwrap(), SearcherKind::Doms, 0);
    let want = run_plain(&runner, t.clone());
    let got = run_sharded(&runner, t);
    assert!(got.shards > 1, "scene should actually shard");
    assert_eq!(want.checksum, got.checksum, "dense head bits diverged");
    assert_eq!(want.head_shape, got.head_shape);
    assert_eq!(want.head_shape.unwrap().2, 32);
    // Full layer stack reported: prefix (aggregated) + suffix.
    assert_eq!(got.records.len(), want.records.len());
}

#[test]
fn minkunet_decoder_shards_bit_identically() {
    // Encoder-decoder with pruned transposed convs: the deepest halo in
    // the repo (each shard records and pops its own skip sets).
    let net = minkunet::minkunet_small();
    let e = net.extent;
    let t = scene(e, 500, 4, 91);
    let runner = runner_with(net, ShardConfig::grid(2, 2).unwrap(), SearcherKind::Doms, 0);
    let want = run_plain(&runner, t.clone());
    let got = run_sharded(&runner, t);
    assert!(got.shards > 1);
    assert_eq!(want.checksum, got.checksum, "UNet bits diverged under sharding");
    assert_eq!(want.out_voxels, got.out_voxels);
}

#[test]
fn empty_blocks_drop_without_losing_bits() {
    // Scene confined to a corner of a wide grid: most blocks plan empty
    // and are dropped; the survivors still reassemble the exact frame.
    let e = Extent3::new(96, 96, 6);
    let corner = voxel_cim::pointcloud::voxelize::Voxelizer::synth_occupancy(
        Extent3::new(24, 96, 6),
        0.08,
        13,
    );
    let t = featured(SparseTensor::from_coords(e, corner.coords(), 1), 4, 14);
    let runner = runner_with(seg_net(e), ShardConfig::grid(4, 2).unwrap(), SearcherKind::Doms, 0);
    let want = run_plain(&runner, t.clone());
    let got = run_sharded(&runner, t);
    assert!(got.shards > 1, "expected several live shards");
    assert!(got.shards < 8, "empty blocks should have been dropped");
    assert_eq!(want.checksum, got.checksum);
}

#[test]
fn auto_threshold_gates_sharding() {
    let e = Extent3::new(32, 32, 6);
    let t = scene(e, 200, 4, 55);
    let gated = ShardConfig {
        auto_threshold: 100_000,
        ..ShardConfig::grid(2, 2).unwrap()
    };
    let runner = runner_with(seg_net(e), gated, SearcherKind::Doms, 0);
    let plain = runner_with(seg_net(e), ShardConfig::default(), SearcherKind::Doms, 0);
    let got = run_sharded(&runner, t.clone());
    let want = run_plain(&plain, t);
    assert_eq!(got.shards, 1, "below-threshold scene must not shard");
    assert_eq!(got.checksum, want.checksum);
}

#[test]
fn w2b_packing_composes_with_sharding_bit_identically() {
    let e = Extent3::new(40, 40, 8);
    let t = scene(e, 350, 4, 66);
    let base = runner_with(seg_net(e), ShardConfig::default(), SearcherKind::Doms, 0);
    let want = run_plain(&base, t.clone());
    // W2B packing alone, then W2B + sharding: both bit-identical.
    let w2b = runner_with(seg_net(e), ShardConfig::default(), SearcherKind::Doms, 2);
    let got = run_plain(&w2b, t.clone());
    assert_eq!(want.checksum, got.checksum, "W2B packing changed the bits");
    let both = runner_with(seg_net(e), ShardConfig::grid(2, 2).unwrap(), SearcherKind::Doms, 2);
    let got = run_sharded(&both, t);
    assert!(got.shards > 1);
    assert_eq!(want.checksum, got.checksum, "W2B + sharding changed the bits");
}
