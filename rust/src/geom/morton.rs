//! Morton (Z-order) encoding.
//!
//! Used by the table-aided / octree-encoding baseline (SpOctA-style): a
//! voxel's Morton code is its position along the octree's space-filling
//! curve, so an octree-encoded table is an array indexed by Morton code
//! prefix. We use it to size the table-aided baseline's storage in
//! `mapsearch::table` and as an alternative sort order in tests.

/// Spread the low 21 bits of `v` so there are two zero bits between each
/// original bit (the classic magic-number dilation).
#[inline]
fn part1by2(v: u32) -> u64 {
    let mut y = (v as u64) & 0x1f_ffff; // 21 bits
    y = (y | (y << 32)) & 0x001f_0000_0000_ffff;
    y = (y | (y << 16)) & 0x001f_0000_ff00_00ff;
    y = (y | (y << 8)) & 0x100f_00f0_0f00_f00f;
    y = (y | (y << 4)) & 0x10c3_0c30_c30c_30c3;
    y = (y | (y << 2)) & 0x1249_2492_4924_9249;
    y
}

/// Inverse of [`part1by2`].
#[inline]
fn compact1by2(x: u64) -> u32 {
    let mut v = x & 0x1249_2492_4924_9249;
    v = (v ^ (v >> 2)) & 0x10c3_0c30_c30c_30c3;
    v = (v ^ (v >> 4)) & 0x100f_00f0_0f00_f00f;
    v = (v ^ (v >> 8)) & 0x001f_0000_ff00_00ff;
    v = (v ^ (v >> 16)) & 0x001f_0000_0000_ffff;
    v = (v ^ (v >> 32)) & 0x1f_ffff;
    v as u32
}

/// Interleave (x, y, z) (each < 2^21) into a 63-bit Morton code.
#[inline]
pub fn encode(x: u32, y: u32, z: u32) -> u64 {
    debug_assert!(x < (1 << 21) && y < (1 << 21) && z < (1 << 21));
    part1by2(x) | (part1by2(y) << 1) | (part1by2(z) << 2)
}

/// Inverse of [`encode`].
#[inline]
pub fn decode(m: u64) -> (u32, u32, u32) {
    (compact1by2(m), compact1by2(m >> 1), compact1by2(m >> 2))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::check;

    #[test]
    fn known_values() {
        assert_eq!(encode(0, 0, 0), 0);
        assert_eq!(encode(1, 0, 0), 0b001);
        assert_eq!(encode(0, 1, 0), 0b010);
        assert_eq!(encode(0, 0, 1), 0b100);
        assert_eq!(encode(1, 1, 1), 0b111);
        assert_eq!(encode(2, 0, 0), 0b001_000);
    }

    #[test]
    fn roundtrip_prop() {
        check("morton roundtrip", 500, |g| {
            let x = g.usize(0, 1 << 21) as u32;
            let y = g.usize(0, 1 << 21) as u32;
            let z = g.usize(0, 1 << 21) as u32;
            assert_eq!(decode(encode(x, y, z)), (x, y, z));
        });
    }

    #[test]
    fn order_locality() {
        // Within one octant, all codes are below the next octant's codes.
        let inside = encode(7, 7, 7);
        let outside = encode(8, 0, 0);
        assert!(inside < outside);
    }
}
