//! Voxel-space geometry: integer coordinates, kernel offset sets, and
//! Morton (Z-order) encoding used by the table-aided baseline.

pub mod coord;
pub mod morton;
pub mod offsets;

pub use coord::{Coord2, Coord3, Extent3};
pub use offsets::{KernelOffsets, Offset3};
