//! Kernel offset sets Δ³(K) and the central-symmetry halving that MARS and
//! DOMS exploit (Fig. 2a): for a centrally-symmetric kernel, if the pair
//! `(P, Q, W_δ)` exists then `(Q, P, W_{-δ})` exists, so only half of the
//! non-center offsets need to be searched.

/// One kernel offset δ ∈ Δ³(K).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct Offset3 {
    pub dz: i8,
    pub dy: i8,
    pub dx: i8,
}

impl Offset3 {
    pub const fn new(dx: i8, dy: i8, dz: i8) -> Self {
        Self { dz, dy, dx }
    }

    pub const ZERO: Offset3 = Offset3::new(0, 0, 0);

    #[inline]
    pub fn negate(self) -> Self {
        Self {
            dx: -self.dx,
            dy: -self.dy,
            dz: -self.dz,
        }
    }

    /// True for the "positive half" of the offset set: the first nonzero
    /// component in (z, y, x) order is positive. The center offset is in
    /// neither half.
    #[inline]
    pub fn is_positive_half(self) -> bool {
        if self.dz != 0 {
            return self.dz > 0;
        }
        if self.dy != 0 {
            return self.dy > 0;
        }
        self.dx > 0
    }
}

/// The full offset set of a K×K×K kernel (odd K, e.g. subm3) or a
/// downsampling kernel (gconv2: offsets `{0, 1}³` relative to the scaled
/// output coordinate).
#[derive(Clone, Debug)]
pub struct KernelOffsets {
    pub k: usize,
    pub offsets: Vec<Offset3>,
}

impl KernelOffsets {
    /// Δ³(K) for odd K, centered: components in `[-(K-1)/2, (K-1)/2]`.
    /// Offsets are enumerated in (dz, dy, dx) lexicographic order, so
    /// `offset_index` is stable and matches the weight sub-matrix layout.
    pub fn centered(k: usize) -> Self {
        assert!(k % 2 == 1, "centered kernel requires odd K");
        let r = (k / 2) as i8;
        let mut offsets = Vec::with_capacity(k * k * k);
        for dz in -r..=r {
            for dy in -r..=r {
                for dx in -r..=r {
                    offsets.push(Offset3::new(dx, dy, dz));
                }
            }
        }
        Self { k, offsets }
    }

    /// Offsets of a stride-s downsampling kernel of size K (gconv2 uses
    /// K = 2): input coordinate = s * output + δ with δ ∈ [0, K)³.
    pub fn downsample(k: usize) -> Self {
        let mut offsets = Vec::with_capacity(k * k * k);
        for dz in 0..k as i8 {
            for dy in 0..k as i8 {
                for dx in 0..k as i8 {
                    offsets.push(Offset3::new(dx, dy, dz));
                }
            }
        }
        Self { k, offsets }
    }

    pub fn len(&self) -> usize {
        self.offsets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.offsets.is_empty()
    }

    /// Index of an offset in the canonical enumeration.
    pub fn index_of(&self, o: Offset3) -> Option<usize> {
        self.offsets.iter().position(|&x| x == o)
    }

    /// The 13 positive-half offsets of a centered kernel (excludes center).
    pub fn positive_half(&self) -> Vec<Offset3> {
        self.offsets
            .iter()
            .copied()
            .filter(|o| o.is_positive_half())
            .collect()
    }

    /// Positive half + center: what an output-major searcher visits per
    /// output (13 + 1 for subm3).
    pub fn search_half(&self) -> Vec<Offset3> {
        let mut v = vec![Offset3::ZERO];
        v.extend(self.positive_half());
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn centered_k3_has_27() {
        let k = KernelOffsets::centered(3);
        assert_eq!(k.len(), 27);
        assert_eq!(k.index_of(Offset3::ZERO), Some(13)); // center is middle
    }

    #[test]
    fn positive_half_is_13_for_k3() {
        let k = KernelOffsets::centered(3);
        let half = k.positive_half();
        assert_eq!(half.len(), 13);
        // Halves partition the non-center offsets under negation.
        for o in &k.offsets {
            if *o == Offset3::ZERO {
                continue;
            }
            assert_ne!(o.is_positive_half(), o.negate().is_positive_half());
        }
    }

    #[test]
    fn search_half_has_14_for_k3() {
        let k = KernelOffsets::centered(3);
        assert_eq!(k.search_half().len(), 14);
    }

    #[test]
    fn positive_half_reaches_only_forward_depths() {
        // DOMS invariant: every positive-half offset has dz in {0, +1} for
        // K=3, and those with dz == 0 have (dy, dx) lexicographically > 0.
        let k = KernelOffsets::centered(3);
        for o in k.positive_half() {
            assert!(o.dz == 0 || o.dz == 1);
            if o.dz == 0 {
                assert!(o.dy > 0 || (o.dy == 0 && o.dx > 0));
            }
        }
    }

    #[test]
    fn downsample_k2_has_8_nonnegative() {
        let k = KernelOffsets::downsample(2);
        assert_eq!(k.len(), 8);
        assert!(k.offsets.iter().all(|o| o.dx >= 0 && o.dy >= 0 && o.dz >= 0));
    }

    #[test]
    fn k5_counts() {
        let k = KernelOffsets::centered(5);
        assert_eq!(k.len(), 125);
        assert_eq!(k.positive_half().len(), 62);
    }

    #[test]
    #[should_panic]
    fn even_centered_panics() {
        let _ = KernelOffsets::centered(2);
    }
}
