//! Quantized voxel coordinates.
//!
//! The paper's map search relies on a *depth-major* total order: voxels are
//! stored sorted by `(z, y, x)` so that one "depth" (all voxels with a given
//! z) is a contiguous run in off-chip memory, addressable via the
//! depth-encoding table. `Ord` on [`Coord3`] implements exactly that order.

use std::fmt;

/// A quantized 3-D voxel coordinate. Ordered depth-major: `(z, y, x)`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Coord3 {
    pub z: i32,
    pub y: i32,
    pub x: i32,
}

impl Coord3 {
    #[inline]
    pub const fn new(x: i32, y: i32, z: i32) -> Self {
        Self { z, y, x }
    }

    /// Component-wise add of a kernel offset.
    #[inline]
    pub fn offset(self, d: super::Offset3) -> Self {
        Self {
            x: self.x + d.dx as i32,
            y: self.y + d.dy as i32,
            z: self.z + d.dz as i32,
        }
    }

    /// True if inside `[0, extent)` on all axes.
    #[inline]
    pub fn in_bounds(self, e: Extent3) -> bool {
        self.x >= 0
            && self.y >= 0
            && self.z >= 0
            && (self.x as usize) < e.x
            && (self.y as usize) < e.y
            && (self.z as usize) < e.z
    }

    /// Flat row-major index (z-major) within `extent`; coordinate must be
    /// in bounds.
    #[inline]
    pub fn flat_index(self, e: Extent3) -> usize {
        debug_assert!(self.in_bounds(e));
        (self.z as usize * e.y + self.y as usize) * e.x + self.x as usize
    }

    /// Downsample by `stride` (floor division, matching gconv2 semantics).
    #[inline]
    pub fn downsample(self, stride: i32) -> Self {
        Self {
            x: self.x.div_euclid(stride),
            y: self.y.div_euclid(stride),
            z: self.z.div_euclid(stride),
        }
    }
}

impl fmt::Debug for Coord3 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "({},{},{})", self.x, self.y, self.z)
    }
}

/// 2-D block coordinate used by block-DOMS.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct Coord2 {
    pub y: i32,
    pub x: i32,
}

impl Coord2 {
    pub const fn new(x: i32, y: i32) -> Self {
        Self { y, x }
    }
}

/// Voxel-space extent `(x, y, z)` — e.g. the paper's low-res KITTI space is
/// `352 x 400 x 10`, the high-res space `1408 x 1600 x 41`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Extent3 {
    pub x: usize,
    pub y: usize,
    pub z: usize,
}

impl Extent3 {
    pub const fn new(x: usize, y: usize, z: usize) -> Self {
        Self { x, y, z }
    }

    pub fn volume(self) -> usize {
        self.x * self.y * self.z
    }

    /// Extent after a stride-`s` downsampling conv (ceil division).
    pub fn downsample(self, s: usize) -> Self {
        Self {
            x: self.x.div_ceil(s),
            y: self.y.div_ceil(s),
            z: self.z.div_ceil(s),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Offset3;

    #[test]
    fn depth_major_order() {
        // (z, y, x) lexicographic: z dominates, then y, then x.
        let a = Coord3::new(9, 0, 0);
        let b = Coord3::new(0, 9, 0);
        let c = Coord3::new(0, 0, 9);
        assert!(a < b && b < c);
        assert!(Coord3::new(5, 3, 1) < Coord3::new(0, 4, 1));
    }

    #[test]
    fn offset_and_bounds() {
        let e = Extent3::new(4, 4, 4);
        let c = Coord3::new(0, 0, 0);
        assert!(c.in_bounds(e));
        let moved = c.offset(Offset3::new(-1, 0, 0));
        assert!(!moved.in_bounds(e));
        assert!(Coord3::new(3, 3, 3).in_bounds(e));
        assert!(!Coord3::new(4, 0, 0).in_bounds(e));
    }

    #[test]
    fn flat_index_bijective_on_small_grid() {
        let e = Extent3::new(3, 4, 5);
        let mut seen = vec![false; e.volume()];
        for z in 0..5 {
            for y in 0..4 {
                for x in 0..3 {
                    let i = Coord3::new(x, y, z).flat_index(e);
                    assert!(!seen[i]);
                    seen[i] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn downsample_floor() {
        assert_eq!(Coord3::new(3, 2, 5).downsample(2), Coord3::new(1, 1, 2));
        assert_eq!(Coord3::new(0, 0, 0).downsample(2), Coord3::new(0, 0, 0));
    }

    #[test]
    fn extent_downsample_ceil() {
        let e = Extent3::new(5, 4, 1);
        assert_eq!(e.downsample(2), Extent3::new(3, 2, 1));
    }
}
