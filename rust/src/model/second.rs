//! SECOND [5] — the KITTI detection benchmark (Table 1, "Det").
//!
//! Voxel grid 1408 x 1600 x 41 (0.05 m x 0.05 m x 0.1 m over x 0..70.4,
//! y ±40, z -3..1), simple VFE, the SpMiddleFHD-style sparse 3D encoder,
//! BEV flatten, and the three-block RPN of §2C.

use crate::geom::Extent3;
use crate::model::layer::{LayerSpec, NetworkSpec, TaskKind};

/// The full-resolution SECOND network.
pub fn second() -> NetworkSpec {
    use LayerSpec::*;
    NetworkSpec {
        name: "SECOND",
        task: TaskKind::Detection,
        extent: Extent3::new(1408, 1600, 41),
        vfe_channels: 4,
        layers: vec![
            // 3D feature encoder (SpMiddleFHD).
            Subm3 { c_in: 4, c_out: 16 },
            Subm3 { c_in: 16, c_out: 16 },
            GConv2 { c_in: 16, c_out: 32 },
            Subm3 { c_in: 32, c_out: 32 },
            Subm3 { c_in: 32, c_out: 32 },
            GConv2 { c_in: 32, c_out: 64 },
            Subm3 { c_in: 64, c_out: 64 },
            Subm3 { c_in: 64, c_out: 64 },
            GConv2 { c_in: 64, c_out: 64 },
            Subm3 { c_in: 64, c_out: 64 },
            Subm3 { c_in: 64, c_out: 64 },
            // Hand-off to the RPN: z (41 -> 6) folds into channels.
            ToBev,
            // RPN block 1 (stride 1 at BEV resolution).
            Conv2d { c_in: 384, c_out: 128, k: 3, stride: 1 },
            Conv2d { c_in: 128, c_out: 128, k: 3, stride: 1 },
            Conv2d { c_in: 128, c_out: 128, k: 3, stride: 1 },
            // RPN block 2 (downsample x2).
            Conv2d { c_in: 128, c_out: 128, k: 3, stride: 2 },
            Conv2d { c_in: 128, c_out: 128, k: 3, stride: 1 },
            Conv2d { c_in: 128, c_out: 128, k: 3, stride: 1 },
            // RPN block 3 (downsample x2).
            Conv2d { c_in: 128, c_out: 256, k: 3, stride: 2 },
            Conv2d { c_in: 256, c_out: 256, k: 3, stride: 1 },
            Conv2d { c_in: 256, c_out: 256, k: 3, stride: 1 },
            // Upsample head chain back to BEV resolution (the paper's RPN
            // upsamples blocks 2/3 and concatenates with block 1; we model
            // the same MAC volume as a sequential trunk — see DESIGN.md).
            Deconv2d { c_in: 256, c_out: 128, k: 3, up: 1 },
            Deconv2d { c_in: 128, c_out: 128, k: 3, up: 2 },
            Deconv2d { c_in: 128, c_out: 128, k: 3, up: 2 },
        ],
    }
}

/// A reduced-extent SECOND used by tests and the quickstart example
/// (identical layer topology, smaller grid so rulebooks build fast).
pub fn second_small() -> NetworkSpec {
    let mut net = second();
    net.name = "SECOND-small";
    net.extent = Extent3::new(176, 200, 10);
    net
}

/// The paper's low-resolution map-search setting (Fig. 9a).
pub const LOW_RES: Extent3 = Extent3::new(352, 400, 10);
/// The paper's high-resolution map-search setting (Fig. 9b).
pub const HIGH_RES: Extent3 = Extent3::new(1408, 1600, 41);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_is_consistent() {
        let net = second();
        net.validate().unwrap();
        assert_eq!(net.task, TaskKind::Detection);
        assert_eq!(net.n_sparse_layers(), 11);
        // subm pairs share searches: (2 subm) (g) (2 subm) (g) (2 subm)
        // (g) (2 subm) -> 1+1+1+1+1+1+1 = 7 map searches.
        assert_eq!(net.n_map_searches(), 7);
    }

    #[test]
    fn small_variant_same_topology() {
        let a = second();
        let b = second_small();
        assert_eq!(a.layers, b.layers);
        assert!(b.extent.volume() < a.extent.volume());
    }
}
