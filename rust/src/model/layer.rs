//! Layer and network specifications.

use crate::geom::Extent3;
use crate::sparse::rulebook::ConvKind;

/// One layer of a voxel-based network (Fig. 1's three stages flattened).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerSpec {
    /// Submanifold Spconv3D, K=3, stride 1.
    Subm3 { c_in: usize, c_out: usize },
    /// Generalized (downsampling) Spconv3D, K=2, stride 2.
    GConv2 { c_in: usize, c_out: usize },
    /// Transposed (upsampling) Spconv3D, K=2, stride 2.
    TConv2 { c_in: usize, c_out: usize },
    /// Flatten the sparse 3D tensor to a dense BEV map (z folded into
    /// channels) — the handoff from the 3D encoder to the RPN.
    ToBev,
    /// Dense 2D convolution (RPN), SAME padding.
    Conv2d {
        c_in: usize,
        c_out: usize,
        k: usize,
        stride: usize,
    },
    /// Dense 2D transposed conv (RPN upsampling head), modeled as a
    /// stride-1 conv at the upsampled resolution.
    Deconv2d {
        c_in: usize,
        c_out: usize,
        k: usize,
        up: usize,
    },
}

impl LayerSpec {
    /// The sparse-conv kind, if this is a Spconv3D layer.
    pub fn conv_kind(&self) -> Option<ConvKind> {
        match *self {
            LayerSpec::Subm3 { .. } => Some(ConvKind::subm3()),
            LayerSpec::GConv2 { .. } => Some(ConvKind::gconv2()),
            LayerSpec::TConv2 { .. } => Some(ConvKind::tconv2()),
            _ => None,
        }
    }

    /// Whether this layer runs on the sparse Spconv3D path (map search +
    /// gather/GEMM/scatter) as opposed to the dense BEV/RPN path.
    pub fn is_sparse(&self) -> bool {
        self.conv_kind().is_some()
    }

    pub fn channels(&self) -> (usize, usize) {
        match *self {
            LayerSpec::Subm3 { c_in, c_out }
            | LayerSpec::GConv2 { c_in, c_out }
            | LayerSpec::TConv2 { c_in, c_out }
            | LayerSpec::Conv2d { c_in, c_out, .. }
            | LayerSpec::Deconv2d { c_in, c_out, .. } => (c_in, c_out),
            LayerSpec::ToBev => (0, 0),
        }
    }

    /// Kernel volume (number of weight sub-matrices).
    pub fn kernel_volume(&self) -> usize {
        match *self {
            LayerSpec::Subm3 { .. } => 27,
            LayerSpec::GConv2 { .. } | LayerSpec::TConv2 { .. } => 8,
            LayerSpec::Conv2d { k, .. } => k * k,
            LayerSpec::Deconv2d { k, .. } => k * k,
            LayerSpec::ToBev => 0,
        }
    }

    /// Multiply-accumulates per IN-OUT pair (or per output pixel for
    /// dense layers).
    pub fn macs_per_pair(&self) -> u64 {
        let (c1, c2) = self.channels();
        (c1 * c2) as u64
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    Detection,
    Segmentation,
}

/// A whole network: the 3D feature encoder plus task head.
#[derive(Clone, Debug)]
pub struct NetworkSpec {
    pub name: &'static str,
    pub task: TaskKind,
    /// Input voxel-grid extent.
    pub extent: Extent3,
    /// VFE output channels (input to the first 3D layer).
    pub vfe_channels: usize,
    pub layers: Vec<LayerSpec>,
}

impl NetworkSpec {
    /// Sanity: channel chain must be consistent across consecutive
    /// compute layers.
    pub fn validate(&self) -> Result<(), String> {
        let mut c = self.vfe_channels;
        let mut bev_mult = 1usize;
        for (i, l) in self.layers.iter().enumerate() {
            match *l {
                LayerSpec::ToBev => {
                    // z folds into channels; the multiplier is decided by
                    // the encoder's final z extent at runtime. Spec-level
                    // validation just remembers a fold happened.
                    bev_mult = 0;
                    continue;
                }
                _ => {
                    let (c_in, c_out) = l.channels();
                    if bev_mult == 0 {
                        // First dense layer after ToBev: c_in is the
                        // folded channel count, checked at runtime.
                        bev_mult = 1;
                    } else if c_in != c {
                        return Err(format!(
                            "layer {i} ({l:?}): expects c_in {c_in}, got {c}"
                        ));
                    }
                    c = c_out;
                }
            }
        }
        Ok(())
    }

    /// Number of Spconv3D layers.
    pub fn n_sparse_layers(&self) -> usize {
        self.layers.iter().filter(|l| l.conv_kind().is_some()).count()
    }

    /// Consecutive subm3 runs share one rulebook (§3.3): the number of
    /// *distinct* map searches the network needs.
    pub fn n_map_searches(&self) -> usize {
        let mut n = 0;
        let mut prev_was_subm = false;
        for l in &self.layers {
            match l.conv_kind() {
                Some(ConvKind::Submanifold { .. }) => {
                    if !prev_was_subm {
                        n += 1;
                    }
                    prev_was_subm = true;
                }
                Some(_) => {
                    n += 1;
                    prev_was_subm = false;
                }
                None => prev_was_subm = false,
            }
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_volumes() {
        assert_eq!(LayerSpec::Subm3 { c_in: 4, c_out: 16 }.kernel_volume(), 27);
        assert_eq!(LayerSpec::GConv2 { c_in: 16, c_out: 32 }.kernel_volume(), 8);
        assert_eq!(
            LayerSpec::Conv2d { c_in: 64, c_out: 128, k: 3, stride: 2 }.kernel_volume(),
            9
        );
    }

    #[test]
    fn validate_catches_channel_break() {
        let bad = NetworkSpec {
            name: "bad",
            task: TaskKind::Detection,
            extent: Extent3::new(8, 8, 8),
            vfe_channels: 4,
            layers: vec![
                LayerSpec::Subm3 { c_in: 4, c_out: 16 },
                LayerSpec::Subm3 { c_in: 32, c_out: 32 },
            ],
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn map_search_sharing() {
        let net = NetworkSpec {
            name: "t",
            task: TaskKind::Segmentation,
            extent: Extent3::new(8, 8, 8),
            vfe_channels: 4,
            layers: vec![
                LayerSpec::Subm3 { c_in: 4, c_out: 16 },
                LayerSpec::Subm3 { c_in: 16, c_out: 16 }, // shared
                LayerSpec::GConv2 { c_in: 16, c_out: 32 },
                LayerSpec::Subm3 { c_in: 32, c_out: 32 },
            ],
        };
        assert_eq!(net.n_sparse_layers(), 4);
        assert_eq!(net.n_map_searches(), 3);
    }
}
