//! Network definitions: the two benchmark models of Table 1 (SECOND for
//! KITTI detection, MinkUNet for SemanticKITTI segmentation) expressed as
//! layer-spec sequences the execution engine and the performance
//! simulator both consume.

pub mod layer;
pub mod minkunet;
pub mod second;

pub use layer::{LayerSpec, NetworkSpec, TaskKind};
