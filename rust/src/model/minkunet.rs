//! MinkUNet [8] — the SemanticKITTI segmentation benchmark (Table 1,
//! "Seg"): a sparse 3D UNet of submanifold blocks with generalized-conv
//! downsampling and transposed-conv upsampling. Segmentation networks are
//! Spconv3D-dominated, which is why the paper runs the W2B study on this
//! model (Fig. 10).

use crate::geom::Extent3;
use crate::model::layer::{LayerSpec, NetworkSpec, TaskKind};

/// MinkUNet14-style topology (channels 32-64-128-256 encoder,
/// 128-96-96 decoder), 4 downsampling stages.
pub fn minkunet() -> NetworkSpec {
    use LayerSpec::*;
    NetworkSpec {
        name: "MinkUNet",
        task: TaskKind::Segmentation,
        // SemanticKITTI at 0.05 m: ~100 m x 100 m x 6.5 m scene.
        extent: Extent3::new(2048, 2048, 128),
        vfe_channels: 4,
        layers: vec![
            // Stem.
            Subm3 { c_in: 4, c_out: 32 },
            Subm3 { c_in: 32, c_out: 32 },
            // Encoder stage 1.
            GConv2 { c_in: 32, c_out: 64 },
            Subm3 { c_in: 64, c_out: 64 },
            Subm3 { c_in: 64, c_out: 64 },
            // Encoder stage 2.
            GConv2 { c_in: 64, c_out: 128 },
            Subm3 { c_in: 128, c_out: 128 },
            Subm3 { c_in: 128, c_out: 128 },
            // Encoder stage 3.
            GConv2 { c_in: 128, c_out: 256 },
            Subm3 { c_in: 256, c_out: 256 },
            Subm3 { c_in: 256, c_out: 256 },
            // Decoder stage 1.
            TConv2 { c_in: 256, c_out: 128 },
            Subm3 { c_in: 128, c_out: 128 },
            Subm3 { c_in: 128, c_out: 128 },
            // Decoder stage 2.
            TConv2 { c_in: 128, c_out: 96 },
            Subm3 { c_in: 96, c_out: 96 },
            Subm3 { c_in: 96, c_out: 96 },
            // Decoder stage 3 (back to input resolution).
            TConv2 { c_in: 96, c_out: 96 },
            Subm3 { c_in: 96, c_out: 96 },
            // Per-voxel classifier head (1x1x1 == subm with K=1, modeled
            // as a subm3 with the same channel change for simplicity of
            // the spec; compute model uses its MACs).
            Subm3 { c_in: 96, c_out: 32 },
        ],
    }
}

/// Reduced extent for tests and the quickstart.
pub fn minkunet_small() -> NetworkSpec {
    let mut net = minkunet();
    net.name = "MinkUNet-small";
    net.extent = Extent3::new(128, 128, 16);
    net
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_is_consistent() {
        let net = minkunet();
        net.validate().unwrap();
        assert_eq!(net.task, TaskKind::Segmentation);
        // Spconv3D-dominated: no dense layers at all.
        assert_eq!(net.n_sparse_layers(), net.layers.len());
    }

    #[test]
    fn unet_is_symmetric_in_downs_and_ups() {
        let net = minkunet();
        let downs = net
            .layers
            .iter()
            .filter(|l| matches!(l, LayerSpec::GConv2 { .. }))
            .count();
        let ups = net
            .layers
            .iter()
            .filter(|l| matches!(l, LayerSpec::TConv2 { .. }))
            .count();
        assert_eq!(downs, ups);
    }
}
