//! # voxel-cim
//!
//! A full-system software reproduction of **"Voxel-CIM: An Efficient
//! Compute-in-Memory Accelerator for Voxel-based Point Cloud Neural
//! Networks"** (Lin, Huang, Jiang — ICCAD 2024).
//!
//! The crate is the L3 coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — synthetic LiDAR scenes, voxelization, VFE, the
//!   paper's map-search core (DOMS / block-DOMS plus the PointAcc and MARS
//!   baselines), the CIM computing-core model (tiles, sub-matrix weight
//!   mapping, W2B workload balancing, a 22 nm energy/latency model), the
//!   sparse-convolution execution engine, SECOND / MinkUNet network
//!   definitions, the hybrid MS-wise / compute-wise pipeline, and the
//!   experiment harness that regenerates every figure and table of the
//!   paper's evaluation.
//! * **L2 (python/compile/model.py, build-time)** — the JAX compute graph.
//! * **L1 (python/compile/kernels/, build-time)** — Pallas kernels for the
//!   CIM PE datapath (bit-serial MAC + ADC clamp + shift-add).
//!
//! Python never runs on the request path: `make artifacts` lowers L2/L1
//! once to HLO text in `artifacts/`, and [`runtime`] loads + executes them
//! through the PJRT CPU client (`xla` crate).
//!
//! See `DESIGN.md` (repo root) for the full module map and experiment
//! index, and `examples/configs/default.toml` for the engine-layer run
//! config (`[runner] searcher = ...`, wave batching, worker counts).
//!
//! **Start at [`pipeline`]**: `Pipeline::builder().config(cfg).build()?`
//! yields the one owned-engine submission surface
//! (`pipeline.run(Job::Frame | Job::Window | Job::Stream)`) that
//! replaces hand-assembling `NetworkRunner` / `StreamServer` / engine
//! per call site.

pub mod cim;
pub mod coordinator;
pub mod dataset;
pub mod experiments;
pub mod geom;
pub mod mapsearch;
pub mod model;
pub mod obs;
pub mod pipeline;
pub mod pointcloud;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod sparse;
pub mod spconv;
pub mod testing;
pub mod util;

pub mod bench_util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Common imports for examples and benches.
pub mod prelude {
    pub use crate::cim::{CimConfig, EnergyModel, W2bAllocation};
    pub use crate::geom::{Coord3, KernelOffsets};
    pub use crate::coordinator::{
        NetworkRunner, RunnerConfig, ShardConfig, ShardPlan, StreamReport, StreamServer,
    };
    pub use crate::dataset::{
        ClosureSource, DatasetConfig, FrameSource, KittiSource, PrefetchSource,
        ProfileSource, ReplaySource, ScenarioProfile, SourcedFrame, Trace,
    };
    pub use crate::mapsearch::{
        AccessStats, BlockDoms, Doms, HashSearch, MapSearch, OctreeSearch, OutputMajor,
        SearcherKind, WeightMajor,
    };
    pub use crate::model::{minkunet, second, LayerSpec, NetworkSpec};
    pub use crate::obs::{MetricsRegistry, ObsConfig, Recorder, Stage};
    pub use crate::pipeline::{
        EngineKind, Job, NetworkKind, Overrides, Pipeline, PipelineConfig, PipelineError,
        RunOutcome,
    };
    pub use crate::pointcloud::{SceneConfig, SceneKind, Voxelizer};
    pub use crate::runtime::{Runtime, RuntimeConfig};
    pub use crate::serving::{
        AdmissionConfig, AdmissionPolicy, AdmissionReport, MuxPolicy, SequenceMux,
        ServingConfig, WindowPolicy,
    };
    pub use crate::sim::{Accelerator, SimReport};
    pub use crate::sparse::{Rulebook, SparseTensor};
    pub use crate::util::rng::Pcg64;
    pub use crate::Result;
}
