//! Depth-encoding tables (§3.1B) and their storage accounting.
//!
//! A depth-encoding table records, for each depth z (and in block-DOMS,
//! for each (block, z)), the start pointer of that depth's voxel run in
//! off-chip memory. With coords stored depth-major + row-major, the start
//! of any *row* (y, z) can then be found with a bounded scan of that depth
//! — the key to loading exactly the 2+3 rows DOMS needs.

use rustc_hash::FxHashMap as HashMap;

use crate::geom::Coord3;
use crate::sparse::tensor::SparseTensor;

/// Bytes per table entry: a 32-bit DRAM pointer.
pub const PTR_BYTES: u64 = 4;

/// Depth-encoding table for a single (non-blocked) voxel space.
#[derive(Clone, Debug)]
pub struct DepthTable {
    /// `starts[z] .. starts[z+1]` is depth z's run in the coord array.
    pub starts: Vec<usize>,
    /// Per-row index within each depth: (z, y) -> (start, len). Built
    /// lazily by the searcher from the depth runs; its storage is *not*
    /// part of the table (it is derived on the fly by the row locator),
    /// but we keep it here for the behavioral model's O(1) lookups.
    row_index: HashMap<(i32, i32), (usize, usize)>,
}

impl DepthTable {
    pub fn build(input: &SparseTensor) -> Self {
        let starts = input.depth_starts();
        let mut row_index = HashMap::default();
        let mut i = 0usize;
        while i < input.coords.len() {
            let c = input.coords[i];
            let mut j = i;
            while j < input.coords.len()
                && input.coords[j].z == c.z
                && input.coords[j].y == c.y
            {
                j += 1;
            }
            row_index.insert((c.z, c.y), (i, j - i));
            i = j;
        }
        Self { starts, row_index }
    }

    /// Table storage in bytes: one pointer per depth.
    pub fn table_bytes(&self) -> u64 {
        (self.starts.len().saturating_sub(1)) as u64 * PTR_BYTES
    }

    /// Number of voxels at depth `z`.
    pub fn depth_len(&self, z: i32) -> usize {
        let z = z as usize;
        if z + 1 >= self.starts.len() {
            return 0;
        }
        self.starts[z + 1] - self.starts[z]
    }

    /// Row (z, y): (start index, length), empty row -> (_, 0).
    pub fn row(&self, z: i32, y: i32) -> (usize, usize) {
        self.row_index.get(&(z, y)).copied().unwrap_or((0, 0))
    }

    /// All distinct y values present at depth z, ascending.
    pub fn rows_at_depth(&self, input: &SparseTensor, z: i32) -> Vec<i32> {
        let zu = z as usize;
        if zu + 1 >= self.starts.len() {
            return Vec::new();
        }
        let mut ys: Vec<i32> = input.coords[self.starts[zu]..self.starts[zu + 1]]
            .iter()
            .map(|c| c.y)
            .collect();
        ys.dedup();
        ys
    }
}

/// Block partition for block-DOMS: a (bx, by) grid over the (x, y) plane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockPartition {
    pub bx: usize,
    pub by: usize,
    /// Voxel-space extent the partition covers.
    pub ext_x: usize,
    pub ext_y: usize,
}

impl BlockPartition {
    pub fn new(bx: usize, by: usize, ext_x: usize, ext_y: usize) -> Self {
        assert!(bx >= 1 && by >= 1);
        Self { bx, by, ext_x, ext_y }
    }

    #[inline]
    pub fn block_w(&self) -> usize {
        self.ext_x.div_ceil(self.bx)
    }

    #[inline]
    pub fn block_h(&self) -> usize {
        self.ext_y.div_ceil(self.by)
    }

    /// Block id (i, j) of a coordinate: i indexes x, j indexes y.
    #[inline]
    pub fn block_of(&self, c: Coord3) -> (usize, usize) {
        (
            (c.x as usize / self.block_w()).min(self.bx - 1),
            (c.y as usize / self.block_h()).min(self.by - 1),
        )
    }

    pub fn num_blocks(&self) -> usize {
        self.bx * self.by
    }

    /// Total depth-encoding table storage for all blocks (Fig. 9c's
    /// x-axis trade-off): one pointer per (block, depth).
    pub fn table_bytes(&self, depths: usize) -> u64 {
        (self.num_blocks() * depths) as u64 * PTR_BYTES
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Extent3;

    fn tensor() -> SparseTensor {
        SparseTensor::from_coords(
            Extent3::new(8, 8, 3),
            vec![
                Coord3::new(0, 0, 0),
                Coord3::new(3, 0, 0),
                Coord3::new(5, 2, 0),
                Coord3::new(1, 1, 2),
            ],
            1,
        )
    }

    #[test]
    fn depth_lens_and_rows() {
        let t = tensor();
        let dt = DepthTable::build(&t);
        assert_eq!(dt.depth_len(0), 3);
        assert_eq!(dt.depth_len(1), 0);
        assert_eq!(dt.depth_len(2), 1);
        assert_eq!(dt.row(0, 0), (0, 2));
        assert_eq!(dt.row(0, 2), (2, 1));
        assert_eq!(dt.row(2, 1), (3, 1));
        assert_eq!(dt.row(1, 0).1, 0);
    }

    #[test]
    fn rows_at_depth_sorted_unique() {
        let t = tensor();
        let dt = DepthTable::build(&t);
        assert_eq!(dt.rows_at_depth(&t, 0), vec![0, 2]);
        assert_eq!(dt.rows_at_depth(&t, 2), vec![1]);
        assert!(dt.rows_at_depth(&t, 1).is_empty());
    }

    #[test]
    fn table_bytes_one_ptr_per_depth() {
        let t = tensor();
        let dt = DepthTable::build(&t);
        assert_eq!(dt.table_bytes(), 3 * PTR_BYTES);
    }

    #[test]
    fn block_partition_geometry() {
        let p = BlockPartition::new(2, 8, 352, 400);
        assert_eq!(p.block_w(), 176);
        assert_eq!(p.block_h(), 50);
        assert_eq!(p.block_of(Coord3::new(0, 0, 0)), (0, 0));
        assert_eq!(p.block_of(Coord3::new(175, 49, 0)), (0, 0));
        assert_eq!(p.block_of(Coord3::new(176, 50, 0)), (1, 1));
        assert_eq!(p.block_of(Coord3::new(351, 399, 0)), (1, 7));
        assert_eq!(p.num_blocks(), 16);
        assert_eq!(p.table_bytes(10), 16 * 10 * PTR_BYTES);
    }
}
