//! The map-search core — the paper's primary contribution (§3.1).
//!
//! Five interchangeable searchers build the same [`Rulebook`] with very
//! different off-chip access behavior:
//!
//! | searcher | paper | off-chip access |
//! |---|---|---|
//! | [`hash`] (oracle, in `sparse::hash_search`) | table-aided | O(N) probes but >100 MB table |
//! | [`WeightMajor`] | PointAcc [13] | O(K³·N) |
//! | [`OutputMajor`] | MARS [14] | O(N) if two depths fit the sorter buffer, blows up otherwise |
//! | [`Doms`] | this paper | stable O(2N), O(N) with a depth-sized FIFO |
//! | [`BlockDoms`] | this paper | stable O(N) + <6% replication |
//!
//! Correctness and cost are deliberately separated: neighbor existence is
//! resolved against the sorted coordinate list (bit-identical rulebooks,
//! property-tested against the hash oracle), while [`AccessStats`] comes
//! from a behavioral model of the FIFO buffers, merge sorter, and
//! depth-encoding tables that each dataflow would exercise.

pub mod block_doms;
pub mod buffer;
pub mod doms;
pub mod octree;
pub mod output_major;
pub mod sorter;
pub mod table;
pub mod weight_major;

pub use block_doms::BlockDoms;
pub use doms::Doms;
pub use octree::OctreeSearch;
pub use output_major::OutputMajor;
pub use weight_major::WeightMajor;

use crate::sparse::rulebook::{ConvKind, Rulebook};
use crate::sparse::tensor::SparseTensor;

/// Off-chip / on-chip activity of one map-search run.
///
/// `voxel_reads` is the paper's "data access volume": the number of voxel
/// coordinates fetched from off-chip memory. Figures 2(d) and 9 plot this
/// normalized by N (the voxel count).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AccessStats {
    /// Voxel coordinates read from DRAM.
    pub voxel_reads: u64,
    /// Voxel coordinates written back to DRAM (re-organization, block
    /// replication).
    pub voxel_writes: u64,
    /// Merge-sorter invocations (fixed-length bitonic passes).
    pub sorter_passes: u64,
    /// Comparator operations inside the sorter (cycle proxy).
    pub sorter_compares: u64,
    /// Bytes of on-chip table state required (depth-encoding tables).
    pub table_bytes: u64,
}

impl AccessStats {
    /// Data access volume normalized by the voxel count — the y-axis of
    /// Fig. 2(d) / Fig. 9.
    pub fn normalized(&self, n_voxels: usize) -> f64 {
        if n_voxels == 0 {
            0.0
        } else {
            (self.voxel_reads + self.voxel_writes) as f64 / n_voxels as f64
        }
    }

    pub fn add(&mut self, other: &AccessStats) {
        self.voxel_reads += other.voxel_reads;
        self.voxel_writes += other.voxel_writes;
        self.sorter_passes += other.sorter_passes;
        self.sorter_compares += other.sorter_compares;
        self.table_bytes = self.table_bytes.max(other.table_bytes);
    }
}

/// A map-search engine: builds the rulebook and reports its access cost.
pub trait MapSearch {
    fn name(&self) -> &'static str;

    /// Search a submanifold (K=3, stride 1) neighborhood — the operation
    /// all four dataflows differ on.
    fn search_subm(&self, input: &SparseTensor, k: usize) -> (Rulebook, AccessStats);

    /// Full dispatch. Generalized / transposed convolutions with K == s
    /// have non-overlapping windows, so every searcher handles them with
    /// the same single linear stream (each input maps to exactly one
    /// output): O(N) reads, no neighborhood search.
    fn search(&self, input: &SparseTensor, kind: ConvKind) -> (Rulebook, AccessStats) {
        match kind {
            ConvKind::Submanifold { k } => self.search_subm(input, k),
            _ => {
                let rb = crate::sparse::hash_map_search(input, kind);
                let stats = AccessStats {
                    voxel_reads: input.len() as u64,
                    voxel_writes: rb.out_coords.len() as u64,
                    ..Default::default()
                };
                (rb, stats)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalized_access() {
        let s = AccessStats {
            voxel_reads: 200,
            voxel_writes: 0,
            ..Default::default()
        };
        assert!((s.normalized(100) - 2.0).abs() < 1e-12);
        assert_eq!(s.normalized(0), 0.0);
    }

    #[test]
    fn add_accumulates_and_maxes_table() {
        let mut a = AccessStats {
            voxel_reads: 10,
            table_bytes: 100,
            ..Default::default()
        };
        a.add(&AccessStats {
            voxel_reads: 5,
            table_bytes: 40,
            ..Default::default()
        });
        assert_eq!(a.voxel_reads, 15);
        assert_eq!(a.table_bytes, 100);
    }
}
