//! The map-search core — the paper's primary contribution (§3.1).
//!
//! Five interchangeable searchers build the same [`Rulebook`] with very
//! different off-chip access behavior:
//!
//! | searcher | paper | off-chip access |
//! |---|---|---|
//! | [`hash`] (oracle, in `sparse::hash_search`) | table-aided | O(N) probes but >100 MB table |
//! | [`WeightMajor`] | PointAcc [13] | O(K³·N) |
//! | [`OutputMajor`] | MARS [14] | O(N) if two depths fit the sorter buffer, blows up otherwise |
//! | [`Doms`] | this paper | stable O(2N), O(N) with a depth-sized FIFO |
//! | [`BlockDoms`] | this paper | stable O(N) + <6% replication |
//!
//! Correctness and cost are deliberately separated: neighbor existence is
//! resolved against the sorted coordinate list (bit-identical rulebooks,
//! property-tested against the hash oracle), while [`AccessStats`] comes
//! from a behavioral model of the FIFO buffers, merge sorter, and
//! depth-encoding tables that each dataflow would exercise.

pub mod block_doms;
pub mod buffer;
pub mod delta;
pub mod doms;
pub mod octree;
pub mod output_major;
pub mod sorter;
pub mod table;
pub mod weight_major;

pub use block_doms::BlockDoms;
pub use delta::{DeltaCache, DeltaConfig, DeltaKey, FrameDelta, SlotSpec};
pub use doms::Doms;
pub use octree::OctreeSearch;
pub use output_major::OutputMajor;
pub use weight_major::WeightMajor;

use crate::sparse::hash_search::{hash_map_search, hash_table_bytes};
use crate::sparse::rulebook::{ConvKind, Rulebook};
use crate::sparse::tensor::SparseTensor;

/// Off-chip / on-chip activity of one map-search run.
///
/// `voxel_reads` is the paper's "data access volume": the number of voxel
/// coordinates fetched from off-chip memory. Figures 2(d) and 9 plot this
/// normalized by N (the voxel count).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AccessStats {
    /// Voxel coordinates read from DRAM.
    pub voxel_reads: u64,
    /// Voxel coordinates written back to DRAM (re-organization, block
    /// replication).
    pub voxel_writes: u64,
    /// Merge-sorter invocations (fixed-length bitonic passes).
    pub sorter_passes: u64,
    /// Comparator operations inside the sorter (cycle proxy).
    pub sorter_compares: u64,
    /// Bytes of on-chip table state required (depth-encoding tables).
    pub table_bytes: u64,
}

impl AccessStats {
    /// Data access volume normalized by the voxel count — the y-axis of
    /// Fig. 2(d) / Fig. 9.
    pub fn normalized(&self, n_voxels: usize) -> f64 {
        if n_voxels == 0 {
            0.0
        } else {
            (self.voxel_reads + self.voxel_writes) as f64 / n_voxels as f64
        }
    }

    pub fn add(&mut self, other: &AccessStats) {
        self.voxel_reads += other.voxel_reads;
        self.voxel_writes += other.voxel_writes;
        self.sorter_passes += other.sorter_passes;
        self.sorter_compares += other.sorter_compares;
        self.table_bytes = self.table_bytes.max(other.table_bytes);
    }
}

/// A map-search engine: builds the rulebook and reports its access cost.
pub trait MapSearch {
    fn name(&self) -> &'static str;

    /// Search a submanifold (K=3, stride 1) neighborhood — the operation
    /// all four dataflows differ on.
    fn search_subm(&self, input: &SparseTensor, k: usize) -> (Rulebook, AccessStats);

    /// Full dispatch. Generalized / transposed convolutions with K == s
    /// have non-overlapping windows, so every searcher handles them with
    /// the same single linear stream (each input maps to exactly one
    /// output): O(N) reads, no neighborhood search.
    fn search(&self, input: &SparseTensor, kind: ConvKind) -> (Rulebook, AccessStats) {
        match kind {
            ConvKind::Submanifold { k } => self.search_subm(input, k),
            _ => {
                let rb = crate::sparse::hash_map_search(input, kind);
                let stats = AccessStats {
                    voxel_reads: input.len() as u64,
                    voxel_writes: rb.out_coords.len() as u64,
                    ..Default::default()
                };
                (rb, stats)
            }
        }
    }
}

/// The table-aided oracle as a [`MapSearch`] engine: O(N) streaming reads
/// against an off-chip-resident hash table sized for the whole grid — the
/// ">100 MB table" baseline of Fig. 2(d). Rulebooks are bit-identical to
/// every other searcher by construction (it *is* the oracle).
#[derive(Clone, Copy, Debug, Default)]
pub struct HashSearch;

impl MapSearch for HashSearch {
    fn name(&self) -> &'static str {
        "hash table-aided (oracle)"
    }

    fn search_subm(&self, input: &SparseTensor, k: usize) -> (Rulebook, AccessStats) {
        let rb = hash_map_search(input, ConvKind::Submanifold { k });
        let stats = AccessStats {
            voxel_reads: input.len() as u64,
            table_bytes: hash_table_bytes(input.extent),
            ..Default::default()
        };
        (rb, stats)
    }
}

/// The configurable searcher selector of the engine layer: every
/// interchangeable map-search dataflow, nameable from a run config or CLI
/// flag and constructible as a boxed [`MapSearch`] trait object.
///
/// This is what `RunnerConfig.searcher` stores and what the coordinator
/// dispatches through — no call site hardcodes a concrete searcher.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SearcherKind {
    /// Table-aided oracle (O(N) access, grid-sized table).
    Hash,
    /// PointAcc-style weight-major (O(K³·N)).
    WeightMajor,
    /// MARS-style output-major (buffer-sensitive).
    OutputMajor,
    /// SpOctA-class octree-encoding table-aided.
    Octree,
    /// The paper's depth-encoding searcher (default).
    #[default]
    Doms,
    /// Block-partitioned DOMS at the paper's (2, 8) partition.
    BlockDoms,
}

impl SearcherKind {
    /// Every selectable searcher, in ablation-table order.
    pub const ALL: [SearcherKind; 6] = [
        SearcherKind::Hash,
        SearcherKind::WeightMajor,
        SearcherKind::OutputMajor,
        SearcherKind::Octree,
        SearcherKind::Doms,
        SearcherKind::BlockDoms,
    ];

    /// The config/CLI spelling (`searcher = "doms"` etc.).
    pub fn key(&self) -> &'static str {
        match self {
            SearcherKind::Hash => "hash",
            SearcherKind::WeightMajor => "weight-major",
            SearcherKind::OutputMajor => "output-major",
            SearcherKind::Octree => "octree",
            SearcherKind::Doms => "doms",
            SearcherKind::BlockDoms => "block-doms",
        }
    }

    /// Parse a config/CLI spelling (accepts `-` and `_` separators).
    pub fn parse(s: &str) -> Option<SearcherKind> {
        let norm = s.trim().to_ascii_lowercase().replace('_', "-");
        Self::ALL.iter().copied().find(|k| k.key() == norm)
    }

    /// Construct the searcher with its paper-default parameters.
    pub fn build(&self) -> Box<dyn MapSearch + Send + Sync> {
        match self {
            SearcherKind::Hash => Box::new(HashSearch),
            SearcherKind::WeightMajor => Box::new(WeightMajor::default()),
            SearcherKind::OutputMajor => Box::new(OutputMajor::default()),
            SearcherKind::Octree => Box::new(OctreeSearch::default()),
            SearcherKind::Doms => Box::new(Doms::default()),
            SearcherKind::BlockDoms => Box::new(BlockDoms::default()),
        }
    }
}

impl std::str::FromStr for SearcherKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Self::parse(s).ok_or_else(|| {
            let names: Vec<&str> = Self::ALL.iter().map(|k| k.key()).collect();
            format!("unknown searcher {s:?} (expected one of {})", names.join(", "))
        })
    }
}

impl std::fmt::Display for SearcherKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.key())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalized_access() {
        let s = AccessStats {
            voxel_reads: 200,
            voxel_writes: 0,
            ..Default::default()
        };
        assert!((s.normalized(100) - 2.0).abs() < 1e-12);
        assert_eq!(s.normalized(0), 0.0);
    }

    #[test]
    fn add_accumulates_and_maxes_table() {
        let mut a = AccessStats {
            voxel_reads: 10,
            table_bytes: 100,
            ..Default::default()
        };
        a.add(&AccessStats {
            voxel_reads: 5,
            table_bytes: 40,
            ..Default::default()
        });
        assert_eq!(a.voxel_reads, 15);
        assert_eq!(a.table_bytes, 100);
    }

    #[test]
    fn kind_roundtrips_through_key() {
        for k in SearcherKind::ALL {
            assert_eq!(SearcherKind::parse(k.key()), Some(k));
            assert_eq!(k.key().parse::<SearcherKind>().unwrap(), k);
        }
        assert_eq!(SearcherKind::parse("BLOCK_DOMS"), Some(SearcherKind::BlockDoms));
        assert_eq!(SearcherKind::parse("nope"), None);
        assert!("nope".parse::<SearcherKind>().is_err());
        assert_eq!(SearcherKind::default(), SearcherKind::Doms);
    }

    #[test]
    fn built_searchers_are_dispatchable_objects() {
        use crate::geom::Extent3;
        use crate::pointcloud::voxelize::Voxelizer;
        let e = Extent3::new(12, 12, 4);
        let g = Voxelizer::synth_occupancy(e, 0.1, 9);
        let t = SparseTensor::from_coords(e, g.coords(), 1);
        let want = hash_map_search(&t, ConvKind::subm3());
        for kind in SearcherKind::ALL {
            let s: Box<dyn MapSearch + Send + Sync> = kind.build();
            let (rb, _) = s.search_subm(&t, 3);
            assert_eq!(rb.pairs, want.pairs, "{kind} diverged from oracle");
        }
    }
}
