//! Table-aided map search with octree (Morton) encoding — the SpOctA [9]
//! class of searchers the paper's introduction contrasts DOMS against.
//!
//! All voxels are encoded along the Z-order curve; an *octree-encoding
//! table* maps Morton-code prefixes (octree nodes at `table_level`) to
//! the start of their run in the Morton-sorted coordinate array. A
//! neighbor probe walks to the candidate's prefix bucket in O(1) and
//! scans the (small) bucket. Searching is O(1)-ish per probe — the
//! paper's point is the *storage*: the table grows with the occupied
//! prefix space and, for dense tables over large grids, "can exceed
//! 100 MB". We model storage both ways:
//!
//! * [`AccessStats::table_bytes`] — the *sparse* table actually built
//!   (one entry per occupied prefix), and
//! * [`OctreeSearch::dense_table_bytes`] — the dense-indexed variant a
//!   fixed-function design would allocate (one slot per possible prefix),
//!   which is the paper's ">100 MB" number at high resolution.
//!
//! Off-chip access is O(N) for streaming the encoded voxels once; probes
//! hit the on-chip table + bucket cache.

use rustc_hash::FxHashMap as HashMap;

use crate::geom::{morton, KernelOffsets};
use crate::mapsearch::{AccessStats, MapSearch};
use crate::sparse::rulebook::{ConvKind, Rulebook, RulePair};
use crate::sparse::tensor::SparseTensor;

#[derive(Clone, Debug)]
pub struct OctreeSearch {
    /// Octree level of the table: prefixes of `3 * table_level` bits are
    /// dropped, i.e. buckets of `8^table_level`-voxel cubes. SpOctA-style
    /// designs use shallow buckets (level 1 = 2x2x2 cubes).
    pub table_level: u32,
}

impl Default for OctreeSearch {
    fn default() -> Self {
        Self { table_level: 1 }
    }
}

impl OctreeSearch {
    /// Storage of the dense-indexed table over the whole grid: one 4-byte
    /// pointer per possible prefix (the paper's ">100 MB" concern).
    pub fn dense_table_bytes(&self, input: &SparseTensor) -> u64 {
        let e = input.extent;
        let side = |n: usize| (n.next_power_of_two().max(1)) as u64;
        let cells = side(e.x) * side(e.y) * side(e.z);
        (cells >> (3 * self.table_level)) * 4
    }
}

impl MapSearch for OctreeSearch {
    fn name(&self) -> &'static str {
        "octree table-aided (SpOctA-class)"
    }

    fn search_subm(&self, input: &SparseTensor, k: usize) -> (Rulebook, AccessStats) {
        let offs = KernelOffsets::centered(k);
        // Build the octree-encoding table: Morton-sort the voxels and
        // record each occupied prefix's run. (The coordinate array itself
        // stays depth-major; `order` is the Morton permutation, which the
        // hardware stores as the encoded copy of the cloud.)
        let mut order: Vec<u32> = (0..input.len() as u32).collect();
        let keys: Vec<u64> = input
            .coords
            .iter()
            .map(|c| morton::encode(c.x as u32, c.y as u32, c.z as u32))
            .collect();
        order.sort_unstable_by_key(|&i| keys[i as usize]);
        let mut table: HashMap<u64, (u32, u32)> = HashMap::default();
        {
            let mut i = 0usize;
            while i < order.len() {
                let p = keys[order[i] as usize] >> (3 * self.table_level);
                let mut j = i;
                while j < order.len()
                    && keys[order[j] as usize] >> (3 * self.table_level) == p
                {
                    j += 1;
                }
                table.insert(p, (i as u32, (j - i) as u32));
                i = j;
            }
        }

        let mut stats = AccessStats {
            // One streaming pass to encode + sort off-chip data.
            voxel_reads: input.len() as u64,
            voxel_writes: input.len() as u64, // write back the encoded copy
            table_bytes: table.len() as u64 * 12, // prefix + ptr + len
            ..Default::default()
        };
        let _ = &mut stats;

        // Probe all positive-half neighbors through the table.
        let mut pairs = Vec::with_capacity(input.len() * 8);
        let center = offs.index_of(crate::geom::Offset3::ZERO).unwrap() as u16;
        for (o, &q) in input.coords.iter().enumerate() {
            pairs.push(RulePair {
                offset: center,
                input: o as u32,
                output: o as u32,
            });
            for &delta in offs.positive_half().iter() {
                let p = q.offset(delta);
                if !p.in_bounds(input.extent) {
                    continue;
                }
                let key = morton::encode(p.x as u32, p.y as u32, p.z as u32);
                let Some(&(start, len)) = table.get(&(key >> (3 * self.table_level)))
                else {
                    continue;
                };
                // Scan the bucket (<= 8^level entries, usually sparse).
                for bi in start..start + len {
                    let idx = order[bi as usize] as usize;
                    if keys[idx] == key {
                        let d = offs.index_of(delta).unwrap() as u16;
                        let dneg = offs.index_of(delta.negate()).unwrap() as u16;
                        pairs.push(RulePair {
                            offset: d,
                            input: idx as u32,
                            output: o as u32,
                        });
                        pairs.push(RulePair {
                            offset: dneg,
                            input: o as u32,
                            output: idx as u32,
                        });
                        break;
                    }
                }
            }
        }

        let mut rb = Rulebook {
            kind: ConvKind::Submanifold { k },
            pairs,
            out_coords: input.coords.clone(),
            out_extent: input.extent,
        };
        rb.canonicalize();
        (rb, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::{Coord3, Extent3};
    use crate::pointcloud::voxelize::Voxelizer;
    use crate::sparse::hash_map_search;
    use crate::testing::prop::check;

    fn tensor(e: Extent3, n: usize, seed: u64) -> SparseTensor {
        let g = Voxelizer::synth_occupancy(e, n as f64 / e.volume() as f64, seed);
        SparseTensor::from_coords(e, g.coords(), 1)
    }

    #[test]
    fn matches_hash_oracle() {
        let t = tensor(Extent3::new(32, 32, 8), 700, 61);
        let (rb, _) = OctreeSearch::default().search_subm(&t, 3);
        let want = hash_map_search(&t, ConvKind::subm3());
        assert_eq!(rb.pairs, want.pairs);
    }

    #[test]
    fn matches_hash_oracle_prop_over_levels() {
        check("octree search == oracle", 12, |g| {
            let e = Extent3::new(g.usize(4, 40), g.usize(4, 40), g.usize(2, 10));
            let t = tensor(e, g.usize(1, 600), g.usize(0, 1 << 30) as u64);
            let s = OctreeSearch {
                table_level: g.usize(0, 4) as u32,
            };
            let (rb, _) = s.search_subm(&t, 3);
            let want = hash_map_search(&t, ConvKind::subm3());
            assert_eq!(rb.pairs, want.pairs);
        });
    }

    #[test]
    fn o_n_streaming_access() {
        let t = tensor(Extent3::new(64, 64, 8), 1500, 62);
        let (_, stats) = OctreeSearch::default().search_subm(&t, 3);
        // One read + one write pass: normalized access = 2.
        assert!((stats.normalized(t.len()) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn dense_table_is_huge_at_high_res() {
        // The paper's ">100 MB" intro claim at the high-res grid.
        let t = SparseTensor::from_coords(
            Extent3::new(1408, 1600, 41),
            vec![Coord3::new(0, 0, 0)],
            1,
        );
        let s = OctreeSearch::default();
        let mb = s.dense_table_bytes(&t) as f64 / (1024.0 * 1024.0);
        assert!(mb > 100.0, "dense table only {mb:.1} MB");
        // While the sparse table actually built is tiny for one voxel.
        let (_, stats) = s.search_subm(&t, 3);
        assert!(stats.table_bytes < 1024);
    }

    #[test]
    fn table_shrinks_with_coarser_level() {
        let t = tensor(Extent3::new(64, 64, 16), 2000, 63);
        let (_, fine) = OctreeSearch { table_level: 0 }.search_subm(&t, 3);
        let (_, coarse) = OctreeSearch { table_level: 3 }.search_subm(&t, 3);
        assert!(coarse.table_bytes < fine.table_bytes);
    }
}
