//! Temporal delta map-search cache: block-level rulebook reuse across
//! streamed frames.
//!
//! Consecutive LiDAR frames of one drive overlap heavily, yet the stream
//! path re-runs map search on every frame from scratch — the per-frame
//! cost PointAcc and SpOctA identify as the dominant overhead of voxel
//! pipelines. This module converts that cost from O(frame) to O(delta):
//!
//! * Each frame's layer-0 voxel set is hashed per block on the block-DOMS
//!   `(bx, by)` grid ([`block_hashes`]). A block is **dirty** when its
//!   hash differs from the prior frame of the same [`DeltaKey`]
//!   (`FrameMeta::sequence` × scene-shard block).
//! * Per map-search slot (one per *fresh* Subm3 run — consecutive Subm3
//!   layers share a rulebook), the prior frame's rulebook is kept as
//!   per-block [`BlockFragment`]s binned by output coordinate.
//! * On a warm frame, only dirty blocks plus a halo ring sized by the
//!   `prefix_halo`-style receptive cone ([`SlotSpec::halo`]) are
//!   re-searched against a sub-tensor; clean blocks splice their cached
//!   pairs back in. After `Rulebook::canonicalize` the merged result is
//!   **bit-identical** to a cold full search, because the canonical
//!   rulebook is a pure function of the coordinate set and the halo rule
//!   covers every layer-0 voxel a clean block's fragment can depend on.
//!
//! Correctness is unconditional — hashing catches any change, and the
//! halo ring covers cross-block influence — so eviction and window
//! ordering only ever affect the hit rate, never the produced rulebook.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::bail;

use crate::geom::{Coord3, Extent3, KernelOffsets};
use crate::mapsearch::table::BlockPartition;
use crate::mapsearch::{AccessStats, MapSearch};
use crate::sparse::rulebook::{ConvKind, RulePair, Rulebook};
use crate::sparse::tensor::SparseTensor;
use crate::util::config::{Config, Value};

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x1000_0000_01b3;

/// `[runner] delta*` keys: the temporal delta cache's knobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DeltaConfig {
    /// Off by default: the cache only pays for itself on coherent
    /// sequences, and cold one-shot jobs should not carry its bookkeeping.
    pub enabled: bool,
    /// Invalidation grid over the layer-0 (x, y) plane.
    pub blocks_x: usize,
    pub blocks_y: usize,
    /// Bound on cached `(sequence, shard-block)` entries; LRU beyond it.
    pub max_entries: usize,
}

impl Default for DeltaConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            blocks_x: 8,
            blocks_y: 8,
            max_entries: 32,
        }
    }
}

impl DeltaConfig {
    /// Parse `[runner]` delta keys with the same strictness contract as
    /// the rest of `RunnerConfig`: missing keys default, present-but-bad
    /// values error.
    pub fn from_config(cfg: &Config) -> crate::Result<Self> {
        let d = Self::default();
        let enabled = match cfg.get("runner.delta") {
            None => d.enabled,
            Some(Value::Bool(b)) => *b,
            Some(v) => bail!("runner.delta must be a boolean, got {v:?}"),
        };
        let blocks_x = cfg.usize_or("runner.delta_blocks_x", d.blocks_x)?;
        let blocks_y = cfg.usize_or("runner.delta_blocks_y", d.blocks_y)?;
        let max_entries = cfg.usize_or("runner.delta_max_entries", d.max_entries)?;
        anyhow::ensure!(
            blocks_x >= 1 && blocks_y >= 1,
            "runner.delta_blocks_x/delta_blocks_y must be >= 1"
        );
        anyhow::ensure!(max_entries >= 1, "runner.delta_max_entries must be >= 1");
        Ok(Self {
            enabled,
            blocks_x,
            blocks_y,
            max_entries,
        })
    }
}

/// One map-search slot of the sparse prefix: the receptive-cone radius
/// (in layer-0 voxels, x/y Chebyshev) through that slot's layer
/// inclusive, and the slot tensor's coordinate scale relative to layer 0.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotSpec {
    pub halo: usize,
    pub scale: usize,
}

/// Fingerprint of a slot-spec chain; a cached entry built under a
/// different network shape must not be spliced.
pub fn specs_sig(specs: &[SlotSpec]) -> u64 {
    let mut h = FNV_OFFSET;
    for s in specs {
        for v in [s.halo as u64, s.scale as u64] {
            for byte in v.to_le_bytes() {
                h = (h ^ byte as u64).wrapping_mul(FNV_PRIME);
            }
        }
    }
    h
}

/// Cache key: one entry per streamed sequence — and per scene-shard block
/// when the window shards, since each pseudo-frame searches its own
/// tensor. Non-muxed serves stamp `FrameMeta::sequence = 0`, so solo
/// streams hit the cache exactly like muxed ones.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct DeltaKey {
    pub sequence: u32,
    pub shard: Option<(usize, usize)>,
}

/// The prior frame's rule pairs for one block, stored positionally:
/// `(offset index, output coordinate)`. The input coordinate is implied
/// (`out + offsets[offset]`), and indices are re-resolved against the
/// *current* frame's tensor at splice time — frame-to-frame index shifts
/// in clean blocks therefore cost two binary searches per pair, not a
/// cache miss.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BlockFragment {
    pub pairs: Vec<(u16, Coord3)>,
}

struct SeqEntry {
    extent: Extent3,
    part: BlockPartition,
    sig: u64,
    hashes: Vec<u64>,
    /// Per slot, per block: the fragment to splice when the block stays
    /// clean.
    slots: Vec<Vec<Arc<BlockFragment>>>,
    tick: u64,
}

/// Per-serve temporal cache, bounded by `max_entries` with LRU eviction.
pub struct DeltaCache {
    cfg: DeltaConfig,
    entries: HashMap<DeltaKey, SeqEntry>,
    tick: u64,
    /// Entries displaced by the `max_entries` bound.
    pub evictions: u64,
}

impl DeltaCache {
    pub fn new(cfg: DeltaConfig) -> Self {
        Self {
            cfg,
            entries: HashMap::new(),
            tick: 0,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Plan one frame's delta work against the cached prior state
    /// (non-mutating: every frame of a lockstep window plans against the
    /// pre-window cache; [`DeltaCache::commit`] lands results in frame
    /// order afterwards). A missing or structurally mismatched entry
    /// (extent, grid, or network shape changed) degrades to a cold plan:
    /// every block dirty, nothing to splice.
    pub fn begin_frame(
        &self,
        key: DeltaKey,
        input: &SparseTensor,
        specs: &Arc<Vec<SlotSpec>>,
    ) -> FrameDelta {
        let part = BlockPartition::new(
            self.cfg.blocks_x,
            self.cfg.blocks_y,
            input.extent.x,
            input.extent.y,
        );
        let sig = specs_sig(specs);
        let hashes = block_hashes(input, &part);
        let prior = self.entries.get(&key).filter(|e| {
            e.extent == input.extent
                && e.part == part
                && e.sig == sig
                && e.slots.len() == specs.len()
                && e.hashes.len() == hashes.len()
        });
        let dirty: Vec<bool> = match prior {
            Some(e) => e.hashes.iter().zip(&hashes).map(|(a, b)| a != b).collect(),
            None => vec![true; part.num_blocks()],
        };
        let (bw, bh) = (part.block_w(), part.block_h());
        let slots = specs
            .iter()
            .enumerate()
            .map(|(s, spec)| {
                // Halo rule: a fragment for block B is valid only if every
                // layer-0 block within the slot's receptive cone of B is
                // clean — so dirtiness dilates by ceil(halo / block side).
                let research = dilate(
                    &dirty,
                    part.bx,
                    part.by,
                    spec.halo.div_ceil(bw),
                    spec.halo.div_ceil(bh),
                );
                Some(SlotTask {
                    index: s,
                    spec: *spec,
                    part,
                    research,
                    prior: prior.map(|e| e.slots[s].clone()),
                })
            })
            .collect();
        FrameDelta {
            key,
            extent: input.extent,
            part,
            sig,
            hashes,
            slots,
            new_slots: vec![None; specs.len()],
            next: 0,
        }
    }

    /// Land a completed frame: its hashes and fresh fragments become the
    /// prior state for the next frame of the same key.
    pub fn commit(&mut self, fd: FrameDelta) {
        // A hole (a slot the runtime never searched) means the static
        // walk and the run disagreed; drop the entry rather than cache a
        // partial frame.
        let mut slots = Vec::with_capacity(fd.new_slots.len());
        for s in fd.new_slots {
            match s {
                Some(f) => slots.push(f),
                None => {
                    self.entries.remove(&fd.key);
                    return;
                }
            }
        }
        self.tick += 1;
        if !self.entries.contains_key(&fd.key) && self.entries.len() >= self.cfg.max_entries {
            if let Some(lru) = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.tick)
                .map(|(k, _)| *k)
            {
                self.entries.remove(&lru);
                self.evictions += 1;
            }
        }
        self.entries.insert(
            fd.key,
            SeqEntry {
                extent: fd.extent,
                part: fd.part,
                sig: fd.sig,
                hashes: fd.hashes,
                slots,
                tick: self.tick,
            },
        );
    }
}

/// One frame's delta plan, threaded through the scheduler: the group
/// runner takes one [`SlotTask`] per fresh Subm3 search (in layer order)
/// and records the resulting fragments back for [`DeltaCache::commit`].
pub struct FrameDelta {
    key: DeltaKey,
    extent: Extent3,
    part: BlockPartition,
    sig: u64,
    hashes: Vec<u64>,
    slots: Vec<Option<SlotTask>>,
    new_slots: Vec<Option<Vec<Arc<BlockFragment>>>>,
    next: usize,
}

impl FrameDelta {
    /// Claim the next slot's task, in map-search order. Returns `None`
    /// once the static slot walk is exhausted — searches past that point
    /// (e.g. after a dense layer) simply bypass the cache.
    pub fn take_slot(&mut self) -> Option<SlotTask> {
        let i = self.next;
        self.next += 1;
        self.slots.get_mut(i)?.take()
    }

    /// Record the fragments produced for slot `index`.
    pub fn record(&mut self, index: usize, frags: Vec<Arc<BlockFragment>>) {
        self.new_slots[index] = Some(frags);
    }

    pub fn key(&self) -> DeltaKey {
        self.key
    }
}

/// The delta work for one map-search slot of one frame.
pub struct SlotTask {
    pub index: usize,
    pub spec: SlotSpec,
    pub part: BlockPartition,
    /// Blocks that must be re-searched this frame (dirty ∪ halo ring).
    pub research: Vec<bool>,
    /// Prior-frame fragments per block; `None` on a cold start.
    pub prior: Option<Vec<Arc<BlockFragment>>>,
}

/// What one delta-managed search produced: next-frame fragments plus the
/// reuse counters `StreamReport` aggregates.
pub struct SlotOutcome {
    pub frags: Vec<Arc<BlockFragment>>,
    /// Occupied blocks that went through the searcher this frame.
    pub searched: u64,
    /// Occupied blocks whose pairs were spliced from the cache.
    pub reused: u64,
}

/// Per-block FNV-1a over the (sorted) coordinate list: the invalidation
/// unit. Any voxel appearing, moving, or vanishing anywhere in a block's
/// (x, y) column changes that block's hash.
pub fn block_hashes(input: &SparseTensor, part: &BlockPartition) -> Vec<u64> {
    let mut hashes = vec![FNV_OFFSET; part.num_blocks()];
    for c in &input.coords {
        let h = &mut hashes[block_at(part, *c, 1)];
        for v in [c.x, c.y, c.z] {
            for byte in v.to_le_bytes() {
                *h = (*h ^ byte as u64).wrapping_mul(FNV_PRIME);
            }
        }
    }
    hashes
}

/// Flat block id of a (possibly downscaled) coordinate on the layer-0
/// partition, via its fine-grid anchor — the same anchoring
/// `ShardPlan::merge` uses to route coarse outputs to blocks.
#[inline]
fn block_at(part: &BlockPartition, c: Coord3, scale: usize) -> usize {
    let (i, j) = part.block_of(Coord3::new(c.x * scale as i32, c.y * scale as i32, c.z));
    j * part.bx + i
}

/// Chebyshev dilation of a block mask by `(rx, ry)` blocks, clamped at
/// the grid border.
fn dilate(mask: &[bool], bx: usize, by: usize, rx: usize, ry: usize) -> Vec<bool> {
    let mut out = vec![false; mask.len()];
    for j in 0..by {
        for i in 0..bx {
            if !mask[j * bx + i] {
                continue;
            }
            for jj in j.saturating_sub(ry)..=(j + ry).min(by - 1) {
                for ii in i.saturating_sub(rx)..=(i + rx).min(bx - 1) {
                    out[jj * bx + ii] = true;
                }
            }
        }
    }
    out
}

/// Run one slot's map search through the delta plan: search only the
/// re-search region (cold plans degenerate to a full search), splice
/// clean blocks from the prior frame's fragments, and canonicalize — the
/// result is bit-identical to `searcher.search_subm(input, k)` for every
/// `SearcherKind`, because all searchers produce the same canonical
/// rulebook and the output-block partition of its pairs is exhaustive
/// and disjoint.
pub fn delta_search(
    searcher: &dyn MapSearch,
    input: &SparseTensor,
    k: usize,
    task: &SlotTask,
) -> (Rulebook, AccessStats, SlotOutcome) {
    let part = &task.part;
    let scale = task.spec.scale;
    let nb = part.num_blocks();

    // Block id per voxel plus occupancy (submanifold outputs == inputs,
    // so this doubles as the output occupancy the counters report).
    let mut occupied = vec![false; nb];
    let blocks: Vec<usize> = input
        .coords
        .iter()
        .map(|c| {
            let b = block_at(part, *c, scale);
            occupied[b] = true;
            b
        })
        .collect();

    let warm = task.prior.is_some() && task.research.iter().any(|r| !r);
    let (rb, stats) = if !warm {
        searcher.search_subm(input, k)
    } else {
        let prior = task.prior.as_ref().expect("warm implies prior");
        let mut pairs: Vec<RulePair> = Vec::new();
        let mut sub_stats = AccessStats::default();
        if task.research.iter().any(|r| *r) {
            // Sub-tensor: coords within kernel reach of the re-search
            // region — every true input of a re-searched output is
            // present, so the searcher cannot miss or invent pairs for
            // those outputs.
            let reach = (k / 2) * scale;
            let gather = dilate(
                &task.research,
                part.bx,
                part.by,
                reach.div_ceil(part.block_w()),
                reach.div_ceil(part.block_h()),
            );
            // Selection preserves sorted order, so the sub-tensor stays
            // canonical and `sel` maps sub indices back to global ones.
            let mut sel: Vec<u32> = Vec::new();
            let mut sub_coords: Vec<Coord3> = Vec::new();
            for (i, c) in input.coords.iter().enumerate() {
                if gather[blocks[i]] {
                    sel.push(i as u32);
                    sub_coords.push(*c);
                }
            }
            let sub = SparseTensor::from_coords(input.extent, sub_coords, 1);
            let (sub_rb, st) = searcher.search_subm(&sub, k);
            sub_stats = st;
            pairs.reserve(sub_rb.pairs.len());
            for p in &sub_rb.pairs {
                let out_global = sel[p.output as usize];
                if task.research[blocks[out_global as usize]] {
                    pairs.push(RulePair {
                        offset: p.offset,
                        input: sel[p.input as usize],
                        output: out_global,
                    });
                }
            }
        }
        // Splice clean blocks from the prior frame. The hash + halo rule
        // guarantees both pair endpoints still exist in this frame; a
        // miss here would mean the invalidation invariant is broken, so
        // fail loudly rather than emit a silently wrong rulebook.
        let offs = KernelOffsets::centered(k).offsets;
        for (b, frag) in prior.iter().enumerate() {
            if task.research[b] {
                continue;
            }
            for &(off, out) in &frag.pairs {
                let pin = out.offset(offs[off as usize]);
                let i = input
                    .find(pin)
                    .expect("delta cache: clean-block input vanished");
                let o = input
                    .find(out)
                    .expect("delta cache: clean-block output vanished");
                pairs.push(RulePair {
                    offset: off,
                    input: i as u32,
                    output: o as u32,
                });
            }
        }
        let mut rb = Rulebook {
            kind: ConvKind::Submanifold { k },
            pairs,
            out_coords: input.coords.clone(),
            out_extent: input.extent,
        };
        rb.canonicalize();
        let mut stats = sub_stats;
        stats.voxel_reads += input.len() as u64; // hash + splice scan
        (rb, stats)
    };

    // Fragments for the next frame, binned by output block. Rebuilt from
    // the merged rulebook every frame — self-correcting by construction,
    // since the merged rulebook *is* the full-search rulebook.
    let binned = rb.pairs_by_output_bin(nb, |c| block_at(part, c, scale));
    let frags = binned
        .into_iter()
        .map(|ps| {
            Arc::new(BlockFragment {
                pairs: ps
                    .into_iter()
                    .map(|p| (p.offset, rb.out_coords[p.output as usize]))
                    .collect(),
            })
        })
        .collect();

    let mut searched = 0u64;
    let mut reused = 0u64;
    for (b, occ) in occupied.iter().enumerate() {
        if !occ {
            continue;
        }
        if !warm || task.research[b] {
            searched += 1;
        } else {
            reused += 1;
        }
    }
    (rb, stats, SlotOutcome { frags, searched, reused })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapsearch::SearcherKind;
    use crate::pointcloud::voxelize::Voxelizer;
    use crate::util::config::Config;

    fn tensor(e: Extent3, sparsity: f64, seed: u64) -> SparseTensor {
        SparseTensor::from_coords(e, Voxelizer::synth_occupancy(e, sparsity, seed).coords(), 1)
    }

    #[test]
    fn block_hashes_localize_changes() {
        let e = Extent3::new(32, 32, 4);
        let part = BlockPartition::new(8, 8, e.x, e.y);
        let a = tensor(e, 0.05, 11);
        let dropped = a.coords[0];
        let coords: Vec<Coord3> = a.coords.iter().copied().filter(|c| *c != dropped).collect();
        let b = SparseTensor::from_coords(e, coords, 1);
        let (ha, hb) = (block_hashes(&a, &part), block_hashes(&b, &part));
        let changed = block_at(&part, dropped, 1);
        for (i, (x, y)) in ha.iter().zip(&hb).enumerate() {
            if i == changed {
                assert_ne!(x, y, "dropped voxel must dirty its block");
            } else {
                assert_eq!(x, y, "block {i} unaffected by the drop");
            }
        }
    }

    #[test]
    fn dilation_clamps_at_borders() {
        let mut m = vec![false; 16]; // 4x4
        m[0] = true; // corner
        let d = dilate(&m, 4, 4, 1, 1);
        let want: Vec<bool> = (0..16).map(|i| matches!(i, 0 | 1 | 4 | 5)).collect();
        assert_eq!(d, want);
        assert_eq!(dilate(&m, 4, 4, 0, 0), m);
    }

    #[test]
    fn warm_delta_search_is_bit_identical_for_every_searcher() {
        let e = Extent3::new(32, 32, 4);
        let a = tensor(e, 0.08, 7);
        // Frame B: one extra voxel in the (0, 0) block — a localized edit.
        let mut coords = a.coords.clone();
        coords.push(Coord3::new(2, 2, 1));
        let b = SparseTensor::from_coords(e, coords, 1);
        let specs = Arc::new(vec![SlotSpec { halo: 1, scale: 1 }]);
        let key = DeltaKey { sequence: 0, shard: None };
        for kind in SearcherKind::ALL {
            let searcher = kind.build();
            let mut cache = DeltaCache::new(DeltaConfig {
                enabled: true,
                ..Default::default()
            });
            // Cold frame A.
            let mut fd = cache.begin_frame(key, &a, &specs);
            let task = fd.take_slot().unwrap();
            let (rb, _, out) = delta_search(searcher.as_ref(), &a, 3, &task);
            let (want, _) = searcher.search_subm(&a, 3);
            assert_eq!(rb.pairs, want.pairs, "{kind}: cold frame diverged");
            assert_eq!(out.reused, 0, "{kind}: nothing to reuse on a cold frame");
            assert!(out.searched > 0);
            fd.record(task.index, out.frags);
            cache.commit(fd);
            // Warm frame B.
            let mut fd = cache.begin_frame(key, &b, &specs);
            let task = fd.take_slot().unwrap();
            assert!(
                task.research.iter().any(|r| !r),
                "a one-voxel edit must leave clean blocks"
            );
            let (rb, _, out) = delta_search(searcher.as_ref(), &b, 3, &task);
            let (want, _) = searcher.search_subm(&b, 3);
            assert_eq!(rb.pairs, want.pairs, "{kind}: warm frame diverged");
            assert_eq!(rb.out_coords, want.out_coords);
            assert!(out.reused > 0, "{kind}: warm frame reused nothing");
            fd.record(task.index, out.frags);
            cache.commit(fd);
            assert_eq!(cache.len(), 1);
        }
    }

    #[test]
    fn structural_mismatch_degrades_to_cold() {
        let e = Extent3::new(32, 32, 4);
        let a = tensor(e, 0.05, 3);
        let specs = Arc::new(vec![SlotSpec { halo: 1, scale: 1 }]);
        let key = DeltaKey { sequence: 0, shard: None };
        let mut cache = DeltaCache::new(DeltaConfig::default());
        let mut fd = cache.begin_frame(key, &a, &specs);
        let task = fd.take_slot().unwrap();
        let (_, _, out) = delta_search(SearcherKind::Doms.build().as_ref(), &a, 3, &task);
        fd.record(task.index, out.frags);
        cache.commit(fd);
        // Different network shape -> cold plan despite identical coords.
        let other = Arc::new(vec![SlotSpec { halo: 3, scale: 2 }]);
        let mut fd = cache.begin_frame(key, &a, &other);
        let task = fd.take_slot().unwrap();
        assert!(task.prior.is_none());
        assert!(task.research.iter().all(|r| *r));
    }

    #[test]
    fn cache_evicts_lru_beyond_bound() {
        let e = Extent3::new(16, 16, 2);
        let t = tensor(e, 0.1, 5);
        let specs = Arc::new(vec![SlotSpec { halo: 1, scale: 1 }]);
        let mut cache = DeltaCache::new(DeltaConfig {
            enabled: true,
            max_entries: 1,
            ..Default::default()
        });
        let s = SearcherKind::Doms.build();
        for seq in 0..3u32 {
            let key = DeltaKey { sequence: seq, shard: None };
            let mut fd = cache.begin_frame(key, &t, &specs);
            let task = fd.take_slot().unwrap();
            let (_, _, out) = delta_search(s.as_ref(), &t, 3, &task);
            fd.record(task.index, out.frags);
            cache.commit(fd);
            assert_eq!(cache.len(), 1, "bound must hold after every commit");
        }
        assert_eq!(cache.evictions, 2);
    }

    #[test]
    fn partial_commit_drops_entry() {
        let e = Extent3::new(16, 16, 2);
        let t = tensor(e, 0.1, 5);
        let specs = Arc::new(vec![SlotSpec { halo: 1, scale: 1 }]);
        let key = DeltaKey { sequence: 9, shard: None };
        let mut cache = DeltaCache::new(DeltaConfig::default());
        let fd = cache.begin_frame(key, &t, &specs); // slot never taken
        cache.commit(fd);
        assert!(cache.is_empty());
    }

    #[test]
    fn config_parses_and_rejects_bad_values() {
        let c = Config::parse(
            "[runner]\ndelta = true\ndelta_blocks_x = 4\ndelta_blocks_y = 2\ndelta_max_entries = 5",
        )
        .unwrap();
        let d = DeltaConfig::from_config(&c).unwrap();
        assert_eq!(
            d,
            DeltaConfig { enabled: true, blocks_x: 4, blocks_y: 2, max_entries: 5 }
        );
        // Missing keys: defaults, disabled.
        let d = DeltaConfig::from_config(&Config::parse("").unwrap()).unwrap();
        assert_eq!(d, DeltaConfig::default());
        assert!(!d.enabled);
        // Present-but-bad values are errors, not silent fallbacks.
        for bad in [
            "[runner]\ndelta = 3",
            "[runner]\ndelta = \"yes\"",
            "[runner]\ndelta_blocks_x = 0",
            "[runner]\ndelta_blocks_y = -1",
            "[runner]\ndelta_max_entries = 0",
        ] {
            let c = Config::parse(bad).unwrap();
            assert!(DeltaConfig::from_config(&c).is_err(), "{bad:?} must be rejected");
        }
    }
}
