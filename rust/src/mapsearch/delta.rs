//! Temporal delta map-search cache: block-level rulebook reuse across
//! streamed frames.
//!
//! Consecutive LiDAR frames of one drive overlap heavily, yet the stream
//! path re-runs map search on every frame from scratch — the per-frame
//! cost PointAcc and SpOctA identify as the dominant overhead of voxel
//! pipelines. This module converts that cost from O(frame) to O(delta):
//!
//! * Each frame's layer-0 voxel set is hashed per block on the block-DOMS
//!   `(bx, by)` grid ([`block_hashes`]). A block is **dirty** when its
//!   hash differs from the prior frame of the same [`DeltaKey`]
//!   (`FrameMeta::sequence` × scene-shard block).
//! * Per map-search slot (one per *fresh* Subm3 run — consecutive Subm3
//!   layers share a rulebook), the prior frame's rulebook is kept as
//!   per-block [`BlockFragment`]s binned by output coordinate.
//! * On a warm frame, only dirty blocks plus a halo ring sized by the
//!   `prefix_halo`-style receptive cone ([`SlotSpec::halo`]) are
//!   re-searched against a sub-tensor; clean blocks splice their cached
//!   pairs back in. After `Rulebook::canonicalize` the merged result is
//!   **bit-identical** to a cold full search, because the canonical
//!   rulebook is a pure function of the coordinate set and the halo rule
//!   covers every layer-0 voxel a clean block's fragment can depend on.
//!
//! Correctness is unconditional — hashing catches any change, and the
//! halo ring covers cross-block influence — so eviction and window
//! ordering only ever affect the hit rate, never the produced rulebook.
//!
//! Two further reuse rungs ride the same hash/halo machinery
//! (`[runner] delta_compute`, off by default):
//!
//! * **Compute-core reuse** — per compute slot (one per sparse-prefix
//!   layer, `shard::delta_compute_specs`), the prior frame's pre-epilogue
//!   psum rows are kept per block ([`BlockRows`]). A block splices its
//!   cached rows when every layer-0 block within the slot's *accumulated*
//!   receptive cone is clean in **coordinates and features**
//!   ([`block_chashes`]): a clean cone fixes the rule pairs and every
//!   input feature feeding the block, weights are deterministic per
//!   layer, so the psums — and through the pure per-row requant epilogue
//!   the output features — are bit-identical. Spliced rows are dropped
//!   from gather/GEMM/scatter packing entirely ([`ComputeTask::splice_plan`]
//!   feeds the skip-aware wave packer), so warm frames dispatch strictly
//!   fewer GEMM waves.
//! * **Delta voxelization** lives with the voxelizer
//!   (`pointcloud::voxelize::DeltaVoxelizer`) but follows the same
//!   per-block hash-and-reuse contract one level earlier, on raw points.

use std::collections::HashMap;
use std::sync::Arc;

use crate::geom::{Coord3, Extent3, KernelOffsets};
use crate::mapsearch::table::BlockPartition;
use crate::mapsearch::{AccessStats, MapSearch};
use crate::sparse::rulebook::{ConvKind, RulePair, Rulebook};
use crate::sparse::tensor::SparseTensor;
use crate::spconv::gather::ComputeSplice;
use crate::util::config::Config;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x1000_0000_01b3;

/// `[runner] delta*` keys: the temporal delta cache's knobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DeltaConfig {
    /// Off by default: the cache only pays for itself on coherent
    /// sequences, and cold one-shot jobs should not carry its bookkeeping.
    pub enabled: bool,
    /// Invalidation grid over the layer-0 (x, y) plane.
    pub blocks_x: usize,
    pub blocks_y: usize,
    /// Bound on cached `(sequence, shard-block)` entries; LRU beyond it.
    pub max_entries: usize,
    /// Compute-core reuse: cache per-block psum rows of the sparse
    /// prefix and skip the GEMM waves of blocks whose accumulated
    /// receptive cone stayed clean. Only meaningful with `enabled`.
    pub compute: bool,
    /// Delta voxelization: re-bin points and re-run VFE only for dirty
    /// blocks of a point-cloud source. Only meaningful with `enabled`.
    pub voxelize: bool,
}

impl Default for DeltaConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            blocks_x: 8,
            blocks_y: 8,
            max_entries: 32,
            compute: false,
            voxelize: false,
        }
    }
}

impl DeltaConfig {
    /// Parse `[runner]` delta keys with the same strictness contract as
    /// the rest of `RunnerConfig`: missing keys default, present-but-bad
    /// values error.
    pub fn from_config(cfg: &Config) -> crate::Result<Self> {
        let d = Self::default();
        let enabled = cfg.opt_bool("runner.delta")?.unwrap_or(d.enabled);
        let blocks_x = cfg.usize_or("runner.delta_blocks_x", d.blocks_x)?;
        let blocks_y = cfg.usize_or("runner.delta_blocks_y", d.blocks_y)?;
        let max_entries = cfg.usize_or("runner.delta_max_entries", d.max_entries)?;
        let compute = cfg.opt_bool("runner.delta_compute")?.unwrap_or(d.compute);
        let voxelize = cfg.opt_bool("runner.delta_voxelize")?.unwrap_or(d.voxelize);
        anyhow::ensure!(
            blocks_x >= 1 && blocks_y >= 1,
            "runner.delta_blocks_x/delta_blocks_y must be >= 1"
        );
        anyhow::ensure!(max_entries >= 1, "runner.delta_max_entries must be >= 1");
        Ok(Self {
            enabled,
            blocks_x,
            blocks_y,
            max_entries,
            compute,
            voxelize,
        })
    }
}

/// One map-search slot of the sparse prefix: the receptive-cone radius
/// (in layer-0 voxels, x/y Chebyshev) through that slot's layer
/// inclusive, and the slot tensor's coordinate scale relative to layer 0.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotSpec {
    pub halo: usize,
    pub scale: usize,
}

/// Fingerprint of a slot-spec chain; a cached entry built under a
/// different network shape must not be spliced.
pub fn specs_sig(specs: &[SlotSpec]) -> u64 {
    let mut h = FNV_OFFSET;
    for s in specs {
        for v in [s.halo as u64, s.scale as u64] {
            for byte in v.to_le_bytes() {
                h = (h ^ byte as u64).wrapping_mul(FNV_PRIME);
            }
        }
    }
    h
}

/// Cache key: one entry per streamed sequence — and per scene-shard block
/// when the window shards, since each pseudo-frame searches its own
/// tensor. Non-muxed serves stamp `FrameMeta::sequence = 0`, so solo
/// streams hit the cache exactly like muxed ones.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct DeltaKey {
    pub sequence: u32,
    pub shard: Option<(usize, usize)>,
}

/// The prior frame's rule pairs for one block, stored positionally:
/// `(offset index, output coordinate)`. The input coordinate is implied
/// (`out + offsets[offset]`), and indices are re-resolved against the
/// *current* frame's tensor at splice time — frame-to-frame index shifts
/// in clean blocks therefore cost two binary searches per pair, not a
/// cache miss.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BlockFragment {
    pub pairs: Vec<(u16, Coord3)>,
}

/// The prior frame's compute rows for one block of one compute slot:
/// `(output coordinate, pre-epilogue psum row)` in coordinate order. The
/// psum row is spliced into the zero-initialized accumulation buffer
/// *before* the requant epilogue, so the output features fall out
/// bit-identically without re-running gather/GEMM/scatter for the row.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BlockRows {
    pub rows: Vec<(Coord3, Vec<i32>)>,
}

struct SeqEntry {
    extent: Extent3,
    part: BlockPartition,
    sig: u64,
    hashes: Vec<u64>,
    /// Per-block hash over coordinates *and* features — the stricter
    /// invalidation unit compute reuse needs (a feature edit with
    /// unchanged geometry keeps the rulebook but changes every psum
    /// downstream).
    chashes: Vec<u64>,
    /// Per slot, per block: the fragment to splice when the block stays
    /// clean.
    slots: Vec<Vec<Arc<BlockFragment>>>,
    /// Per compute slot (layer of the sparse prefix), per block: the psum
    /// rows to splice when the block's accumulated cone stays clean.
    compute: Vec<Vec<Arc<BlockRows>>>,
    tick: u64,
}

/// Per-serve temporal cache, bounded by `max_entries` with LRU eviction.
pub struct DeltaCache {
    cfg: DeltaConfig,
    entries: HashMap<DeltaKey, SeqEntry>,
    tick: u64,
    /// Entries displaced by the `max_entries` bound.
    pub evictions: u64,
}

impl DeltaCache {
    pub fn new(cfg: DeltaConfig) -> Self {
        Self {
            cfg,
            entries: HashMap::new(),
            tick: 0,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Plan one frame's delta work against the cached prior state
    /// (non-mutating: every frame of a lockstep window plans against the
    /// pre-window cache; [`DeltaCache::commit`] lands results in frame
    /// order afterwards). A missing or structurally mismatched entry
    /// (extent, grid, or network shape changed) degrades to a cold plan:
    /// every block dirty, nothing to splice.
    ///
    /// `specs` are the map-search slots (`shard::delta_slot_specs`);
    /// `cspecs` the compute slots (`shard::delta_compute_specs`, empty
    /// when compute reuse is off). Both chains are part of the entry
    /// signature, so flipping either feature starts cold, never wrong.
    pub fn begin_frame(
        &self,
        key: DeltaKey,
        input: &SparseTensor,
        specs: &Arc<Vec<SlotSpec>>,
        cspecs: &Arc<Vec<SlotSpec>>,
    ) -> FrameDelta {
        let part = BlockPartition::new(
            self.cfg.blocks_x,
            self.cfg.blocks_y,
            input.extent.x,
            input.extent.y,
        );
        let sig = specs_sig(specs) ^ specs_sig(cspecs).wrapping_mul(FNV_PRIME);
        let hashes = block_hashes(input, &part);
        let chashes = if cspecs.is_empty() {
            Vec::new()
        } else {
            block_chashes(input, &part)
        };
        let prior = self.entries.get(&key).filter(|e| {
            e.extent == input.extent
                && e.part == part
                && e.sig == sig
                && e.slots.len() == specs.len()
                && e.hashes.len() == hashes.len()
                && e.compute.len() == cspecs.len()
                && e.chashes.len() == chashes.len()
        });
        let dirty: Vec<bool> = match prior {
            Some(e) => e.hashes.iter().zip(&hashes).map(|(a, b)| a != b).collect(),
            None => vec![true; part.num_blocks()],
        };
        // Compute dirtiness is strictly stronger: features count too.
        let cdirty: Vec<bool> = match prior {
            Some(e) if !cspecs.is_empty() => {
                e.chashes.iter().zip(&chashes).map(|(a, b)| a != b).collect()
            }
            _ => vec![true; part.num_blocks()],
        };
        let (bw, bh) = (part.block_w(), part.block_h());
        let slots = specs
            .iter()
            .enumerate()
            .map(|(s, spec)| {
                // Halo rule: a fragment for block B is valid only if every
                // layer-0 block within the slot's receptive cone of B is
                // clean — so dirtiness dilates by ceil(halo / block side).
                let research = dilate(
                    &dirty,
                    part.bx,
                    part.by,
                    spec.halo.div_ceil(bw),
                    spec.halo.div_ceil(bh),
                );
                Some(SlotTask {
                    index: s,
                    spec: *spec,
                    part,
                    research,
                    prior: prior.map(|e| e.slots[s].clone()),
                })
            })
            .collect();
        let compute = cspecs
            .iter()
            .enumerate()
            .map(|(s, spec)| {
                let research = dilate(
                    &cdirty,
                    part.bx,
                    part.by,
                    spec.halo.div_ceil(bw),
                    spec.halo.div_ceil(bh),
                );
                Some(ComputeTask {
                    index: s,
                    spec: *spec,
                    part,
                    research,
                    prior: prior.map(|e| e.compute[s].clone()),
                })
            })
            .collect();
        FrameDelta {
            key,
            extent: input.extent,
            part,
            sig,
            hashes,
            chashes,
            slots,
            new_slots: vec![None; specs.len()],
            compute,
            new_compute: vec![None; cspecs.len()],
            next: 0,
        }
    }

    /// Land a completed frame: its hashes and fresh fragments become the
    /// prior state for the next frame of the same key.
    pub fn commit(&mut self, fd: FrameDelta) {
        // A hole (a slot the runtime never searched) means the static
        // walk and the run disagreed; drop the entry rather than cache a
        // partial frame. Compute slots obey the same rule.
        let mut slots = Vec::with_capacity(fd.new_slots.len());
        for s in fd.new_slots {
            match s {
                Some(f) => slots.push(f),
                None => {
                    self.entries.remove(&fd.key);
                    return;
                }
            }
        }
        let mut compute = Vec::with_capacity(fd.new_compute.len());
        for s in fd.new_compute {
            match s {
                Some(r) => compute.push(r),
                None => {
                    self.entries.remove(&fd.key);
                    return;
                }
            }
        }
        self.tick += 1;
        if !self.entries.contains_key(&fd.key) && self.entries.len() >= self.cfg.max_entries {
            // vcim:allow(determinism) unique argmin over the (tick, key) total order — hash-iteration order cannot affect which entry is evicted
            let lru = self.entries.iter().min_by_key(|(k, e)| (e.tick, **k)).map(|(k, _)| *k);
            if let Some(lru) = lru {
                self.entries.remove(&lru);
                self.evictions += 1;
            }
        }
        self.entries.insert(
            fd.key,
            SeqEntry {
                extent: fd.extent,
                part: fd.part,
                sig: fd.sig,
                hashes: fd.hashes,
                chashes: fd.chashes,
                slots,
                compute,
                tick: self.tick,
            },
        );
    }
}

/// One frame's delta plan, threaded through the scheduler: the group
/// runner takes one [`SlotTask`] per fresh Subm3 search (in layer order)
/// and records the resulting fragments back for [`DeltaCache::commit`].
pub struct FrameDelta {
    key: DeltaKey,
    extent: Extent3,
    part: BlockPartition,
    sig: u64,
    hashes: Vec<u64>,
    chashes: Vec<u64>,
    slots: Vec<Option<SlotTask>>,
    new_slots: Vec<Option<Vec<Arc<BlockFragment>>>>,
    compute: Vec<Option<ComputeTask>>,
    new_compute: Vec<Option<Vec<Arc<BlockRows>>>>,
    next: usize,
}

impl FrameDelta {
    /// Claim the next slot's task, in map-search order. Returns `None`
    /// once the static slot walk is exhausted — searches past that point
    /// (e.g. after a dense layer) simply bypass the cache.
    pub fn take_slot(&mut self) -> Option<SlotTask> {
        let i = self.next;
        self.next += 1;
        self.slots.get_mut(i)?.take()
    }

    /// Record the fragments produced for slot `index`.
    pub fn record(&mut self, index: usize, frags: Vec<Arc<BlockFragment>>) {
        self.new_slots[index] = Some(frags);
    }

    /// Claim the compute task for sparse-prefix layer `layer`. Unlike
    /// [`FrameDelta::take_slot`] this claims by index, not sequentially:
    /// compute slots are one-per-layer (contiguous from layer 0, both in
    /// the whole net and in a sharded prefix group), so the group runner
    /// addresses them by its local layer index directly.
    pub fn take_compute(&mut self, layer: usize) -> Option<ComputeTask> {
        self.compute.get_mut(layer)?.take()
    }

    /// Record the psum rows produced for compute slot `index`.
    pub fn record_compute(&mut self, index: usize, rows: Vec<Arc<BlockRows>>) {
        self.new_compute[index] = Some(rows);
    }

    pub fn key(&self) -> DeltaKey {
        self.key
    }
}

/// The delta work for one map-search slot of one frame.
pub struct SlotTask {
    pub index: usize,
    pub spec: SlotSpec,
    pub part: BlockPartition,
    /// Blocks that must be re-searched this frame (dirty ∪ halo ring).
    pub research: Vec<bool>,
    /// Prior-frame fragments per block; `None` on a cold start.
    pub prior: Option<Vec<Arc<BlockFragment>>>,
}

/// What one delta-managed search produced: next-frame fragments plus the
/// reuse counters `StreamReport` aggregates.
pub struct SlotOutcome {
    pub frags: Vec<Arc<BlockFragment>>,
    /// Occupied blocks that went through the searcher this frame.
    pub searched: u64,
    /// Occupied blocks whose pairs were spliced from the cache.
    pub reused: u64,
}

/// The delta compute work for one sparse-prefix layer of one frame.
pub struct ComputeTask {
    pub index: usize,
    pub spec: SlotSpec,
    pub part: BlockPartition,
    /// Blocks whose psums must be recomputed this frame: compute-dirty
    /// (coords *or* features changed) dilated by the layer's accumulated
    /// receptive cone.
    pub research: Vec<bool>,
    /// Prior-frame psum rows per block; `None` on a cold start.
    pub prior: Option<Vec<Arc<BlockRows>>>,
}

impl ComputeTask {
    /// Build the splice plan against this frame's output coordinates:
    /// which output rows can skip gather/GEMM/scatter entirely, and the
    /// cached psum rows to write in their place. `None` means nothing to
    /// splice (cold start, or every block inside the re-compute region) —
    /// the layer then runs the plain packing with zero overhead.
    ///
    /// The skip mask is derived *from the cache*: only rows present in a
    /// clean block's cached entry are skipped, so any output the cache
    /// does not know about is computed normally. The converse — a cached
    /// clean-block row whose coordinate no longer exists — would mean the
    /// hash/halo invariant is broken, and fails loudly.
    pub fn splice_plan(&self, out_coords: &[Coord3]) -> Option<ComputeSplice> {
        let prior = self.prior.as_ref()?;
        if self.research.iter().all(|r| *r) {
            return None;
        }
        let mut skip = vec![false; out_coords.len()];
        let mut rows: Vec<(u32, Vec<i32>)> = Vec::new();
        for (b, br) in prior.iter().enumerate() {
            if self.research[b] {
                continue;
            }
            for (c, psums) in &br.rows {
                let o = out_coords
                    .binary_search(c)
                    .expect("delta compute: clean-block output vanished");
                skip[o] = true;
                rows.push((o as u32, psums.clone()));
            }
        }
        if rows.is_empty() {
            return None;
        }
        Some(ComputeSplice { skip, rows })
    }
}

/// Bin one layer's pre-epilogue psums into per-block [`BlockRows`] for
/// the next frame. Re-computed blocks are rebuilt from the psum buffer;
/// clean blocks keep the prior frame's `Arc` (the spliced rows *are* in
/// the buffer too, so either source is bit-identical — the clone is
/// free).
pub fn bin_compute_rows(
    task: &ComputeTask,
    out_coords: &[Coord3],
    psums: &[i32],
    c_out: usize,
) -> Vec<Arc<BlockRows>> {
    let nb = task.part.num_blocks();
    let warm = task.prior.is_some();
    let mut fresh: Vec<Vec<(Coord3, Vec<i32>)>> = vec![Vec::new(); nb];
    for (o, c) in out_coords.iter().enumerate() {
        let b = block_at(&task.part, *c, task.spec.scale);
        if !warm || task.research[b] {
            fresh[b].push((*c, psums[o * c_out..(o + 1) * c_out].to_vec()));
        }
    }
    (0..nb)
        .map(|b| {
            if warm && !task.research[b] {
                task.prior.as_ref().expect("warm implies prior")[b].clone()
            } else {
                Arc::new(BlockRows {
                    rows: std::mem::take(&mut fresh[b]),
                })
            }
        })
        .collect()
}

/// Per-block FNV-1a over the (sorted) coordinate list: the invalidation
/// unit. Any voxel appearing, moving, or vanishing anywhere in a block's
/// (x, y) column changes that block's hash.
pub fn block_hashes(input: &SparseTensor, part: &BlockPartition) -> Vec<u64> {
    let mut hashes = vec![FNV_OFFSET; part.num_blocks()];
    for c in &input.coords {
        let h = &mut hashes[block_at(part, *c, 1)];
        for v in [c.x, c.y, c.z] {
            for byte in v.to_le_bytes() {
                *h = (*h ^ byte as u64).wrapping_mul(FNV_PRIME);
            }
        }
    }
    hashes
}

/// Per-block FNV-1a over coordinates *and* i8 feature rows: the stricter
/// invalidation unit compute reuse needs. Geometry-only hashing
/// ([`block_hashes`]) keeps a rulebook valid when features drift, but a
/// single changed activation changes every psum downstream of it.
pub fn block_chashes(input: &SparseTensor, part: &BlockPartition) -> Vec<u64> {
    let mut hashes = vec![FNV_OFFSET; part.num_blocks()];
    for (i, c) in input.coords.iter().enumerate() {
        let h = &mut hashes[block_at(part, *c, 1)];
        for v in [c.x, c.y, c.z] {
            for byte in v.to_le_bytes() {
                *h = (*h ^ byte as u64).wrapping_mul(FNV_PRIME);
            }
        }
        for &f in input.feature(i) {
            *h = (*h ^ (f as u8) as u64).wrapping_mul(FNV_PRIME);
        }
    }
    hashes
}

/// Flat block id of a (possibly downscaled) coordinate on the layer-0
/// partition, via its fine-grid anchor — the same anchoring
/// `ShardPlan::merge` uses to route coarse outputs to blocks.
#[inline]
fn block_at(part: &BlockPartition, c: Coord3, scale: usize) -> usize {
    let (i, j) = part.block_of(Coord3::new(c.x * scale as i32, c.y * scale as i32, c.z));
    j * part.bx + i
}

/// Chebyshev dilation of a block mask by `(rx, ry)` blocks, clamped at
/// the grid border.
fn dilate(mask: &[bool], bx: usize, by: usize, rx: usize, ry: usize) -> Vec<bool> {
    let mut out = vec![false; mask.len()];
    for j in 0..by {
        for i in 0..bx {
            if !mask[j * bx + i] {
                continue;
            }
            for jj in j.saturating_sub(ry)..=(j + ry).min(by - 1) {
                for ii in i.saturating_sub(rx)..=(i + rx).min(bx - 1) {
                    out[jj * bx + ii] = true;
                }
            }
        }
    }
    out
}

/// Run one slot's map search through the delta plan: search only the
/// re-search region (cold plans degenerate to a full search), splice
/// clean blocks from the prior frame's fragments, and canonicalize — the
/// result is bit-identical to `searcher.search_subm(input, k)` for every
/// `SearcherKind`, because all searchers produce the same canonical
/// rulebook and the output-block partition of its pairs is exhaustive
/// and disjoint.
pub fn delta_search(
    searcher: &dyn MapSearch,
    input: &SparseTensor,
    k: usize,
    task: &SlotTask,
) -> (Rulebook, AccessStats, SlotOutcome) {
    let part = &task.part;
    let scale = task.spec.scale;
    let nb = part.num_blocks();

    // Block id per voxel plus occupancy (submanifold outputs == inputs,
    // so this doubles as the output occupancy the counters report).
    let mut occupied = vec![false; nb];
    let blocks: Vec<usize> = input
        .coords
        .iter()
        .map(|c| {
            let b = block_at(part, *c, scale);
            occupied[b] = true;
            b
        })
        .collect();

    let warm = task.prior.is_some() && task.research.iter().any(|r| !r);
    let (rb, stats) = if !warm {
        searcher.search_subm(input, k)
    } else {
        let prior = task.prior.as_ref().expect("warm implies prior");
        let mut pairs: Vec<RulePair> = Vec::new();
        let mut sub_stats = AccessStats::default();
        if task.research.iter().any(|r| *r) {
            // Sub-tensor: coords within kernel reach of the re-search
            // region — every true input of a re-searched output is
            // present, so the searcher cannot miss or invent pairs for
            // those outputs.
            let reach = (k / 2) * scale;
            let gather = dilate(
                &task.research,
                part.bx,
                part.by,
                reach.div_ceil(part.block_w()),
                reach.div_ceil(part.block_h()),
            );
            // Selection preserves sorted order, so the sub-tensor stays
            // canonical and `sel` maps sub indices back to global ones.
            let mut sel: Vec<u32> = Vec::new();
            let mut sub_coords: Vec<Coord3> = Vec::new();
            for (i, c) in input.coords.iter().enumerate() {
                if gather[blocks[i]] {
                    sel.push(i as u32);
                    sub_coords.push(*c);
                }
            }
            let sub = SparseTensor::from_coords(input.extent, sub_coords, 1);
            let (sub_rb, st) = searcher.search_subm(&sub, k);
            sub_stats = st;
            pairs.reserve(sub_rb.pairs.len());
            for p in &sub_rb.pairs {
                let out_global = sel[p.output as usize];
                if task.research[blocks[out_global as usize]] {
                    pairs.push(RulePair {
                        offset: p.offset,
                        input: sel[p.input as usize],
                        output: out_global,
                    });
                }
            }
        }
        // Splice clean blocks from the prior frame. The hash + halo rule
        // guarantees both pair endpoints still exist in this frame; a
        // miss here would mean the invalidation invariant is broken, so
        // fail loudly rather than emit a silently wrong rulebook.
        let offs = KernelOffsets::centered(k).offsets;
        for (b, frag) in prior.iter().enumerate() {
            if task.research[b] {
                continue;
            }
            for &(off, out) in &frag.pairs {
                let pin = out.offset(offs[off as usize]);
                let i = input
                    .find(pin)
                    .expect("delta cache: clean-block input vanished");
                let o = input
                    .find(out)
                    .expect("delta cache: clean-block output vanished");
                pairs.push(RulePair {
                    offset: off,
                    input: i as u32,
                    output: o as u32,
                });
            }
        }
        let mut rb = Rulebook {
            kind: ConvKind::Submanifold { k },
            pairs,
            out_coords: input.coords.clone(),
            out_extent: input.extent,
        };
        rb.canonicalize();
        let mut stats = sub_stats;
        stats.voxel_reads += input.len() as u64; // hash + splice scan
        (rb, stats)
    };

    // Fragments for the next frame, binned by output block. On a warm
    // frame only the re-searched blocks' fragments are rebuilt; a clean
    // block keeps the prior frame's `Arc`. The clone is exact: a clean
    // block's pair set is identical across the two frames (that is what
    // splicing relied on), and per-block pair order is the canonical
    // (offset, input, output) order in both builds — input/output index
    // order tracks coordinate order, which the clean block shares with
    // the prior frame. Cold frames rebuild everything.
    let frags: Vec<Arc<BlockFragment>> = if warm {
        let prior = task.prior.as_ref().expect("warm implies prior");
        let mut fresh: Vec<Vec<(u16, Coord3)>> = vec![Vec::new(); nb];
        for p in &rb.pairs {
            let out = rb.out_coords[p.output as usize];
            let b = block_at(part, out, scale);
            if task.research[b] {
                fresh[b].push((p.offset, out));
            }
        }
        (0..nb)
            .map(|b| {
                if task.research[b] {
                    Arc::new(BlockFragment {
                        pairs: std::mem::take(&mut fresh[b]),
                    })
                } else {
                    prior[b].clone()
                }
            })
            .collect()
    } else {
        rb.pairs_by_output_bin(nb, |c| block_at(part, c, scale))
            .into_iter()
            .map(|ps| {
                Arc::new(BlockFragment {
                    pairs: ps
                        .into_iter()
                        .map(|p| (p.offset, rb.out_coords[p.output as usize]))
                        .collect(),
                })
            })
            .collect()
    };

    let mut searched = 0u64;
    let mut reused = 0u64;
    for (b, occ) in occupied.iter().enumerate() {
        if !occ {
            continue;
        }
        if !warm || task.research[b] {
            searched += 1;
        } else {
            reused += 1;
        }
    }
    (rb, stats, SlotOutcome { frags, searched, reused })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapsearch::SearcherKind;
    use crate::pointcloud::voxelize::Voxelizer;
    use crate::util::config::Config;

    fn tensor(e: Extent3, sparsity: f64, seed: u64) -> SparseTensor {
        SparseTensor::from_coords(e, Voxelizer::synth_occupancy(e, sparsity, seed).coords(), 1)
    }

    /// Compute reuse off: the common case for the map-search-only tests.
    fn no_compute() -> Arc<Vec<SlotSpec>> {
        Arc::new(Vec::new())
    }

    #[test]
    fn block_hashes_localize_changes() {
        let e = Extent3::new(32, 32, 4);
        let part = BlockPartition::new(8, 8, e.x, e.y);
        let a = tensor(e, 0.05, 11);
        let dropped = a.coords[0];
        let coords: Vec<Coord3> = a.coords.iter().copied().filter(|c| *c != dropped).collect();
        let b = SparseTensor::from_coords(e, coords, 1);
        let (ha, hb) = (block_hashes(&a, &part), block_hashes(&b, &part));
        let changed = block_at(&part, dropped, 1);
        for (i, (x, y)) in ha.iter().zip(&hb).enumerate() {
            if i == changed {
                assert_ne!(x, y, "dropped voxel must dirty its block");
            } else {
                assert_eq!(x, y, "block {i} unaffected by the drop");
            }
        }
    }

    #[test]
    fn dilation_clamps_at_borders() {
        let mut m = vec![false; 16]; // 4x4
        m[0] = true; // corner
        let d = dilate(&m, 4, 4, 1, 1);
        let want: Vec<bool> = (0..16).map(|i| matches!(i, 0 | 1 | 4 | 5)).collect();
        assert_eq!(d, want);
        assert_eq!(dilate(&m, 4, 4, 0, 0), m);
    }

    #[test]
    fn warm_delta_search_is_bit_identical_for_every_searcher() {
        let e = Extent3::new(32, 32, 4);
        let a = tensor(e, 0.08, 7);
        // Frame B: one extra voxel in the (0, 0) block — a localized edit.
        let mut coords = a.coords.clone();
        coords.push(Coord3::new(2, 2, 1));
        let b = SparseTensor::from_coords(e, coords, 1);
        let specs = Arc::new(vec![SlotSpec { halo: 1, scale: 1 }]);
        let key = DeltaKey { sequence: 0, shard: None };
        for kind in SearcherKind::ALL {
            let searcher = kind.build();
            let mut cache = DeltaCache::new(DeltaConfig {
                enabled: true,
                ..Default::default()
            });
            // Cold frame A.
            let mut fd = cache.begin_frame(key, &a, &specs, &no_compute());
            let task = fd.take_slot().unwrap();
            let (rb, _, out) = delta_search(searcher.as_ref(), &a, 3, &task);
            let (want, _) = searcher.search_subm(&a, 3);
            assert_eq!(rb.pairs, want.pairs, "{kind}: cold frame diverged");
            assert_eq!(out.reused, 0, "{kind}: nothing to reuse on a cold frame");
            assert!(out.searched > 0);
            fd.record(task.index, out.frags);
            cache.commit(fd);
            // Warm frame B.
            let mut fd = cache.begin_frame(key, &b, &specs, &no_compute());
            let task = fd.take_slot().unwrap();
            assert!(
                task.research.iter().any(|r| !r),
                "a one-voxel edit must leave clean blocks"
            );
            let (rb, _, out) = delta_search(searcher.as_ref(), &b, 3, &task);
            let (want, _) = searcher.search_subm(&b, 3);
            assert_eq!(rb.pairs, want.pairs, "{kind}: warm frame diverged");
            assert_eq!(rb.out_coords, want.out_coords);
            assert!(out.reused > 0, "{kind}: warm frame reused nothing");
            fd.record(task.index, out.frags);
            cache.commit(fd);
            assert_eq!(cache.len(), 1);
            // Frame C, warm against B's *incrementally* built fragments
            // (clean blocks of B carry A's Arcs): a far-corner edit.
            let mut coords = b.coords.clone();
            coords.push(Coord3::new(29, 29, 2));
            let c = SparseTensor::from_coords(e, coords, 1);
            let mut fd = cache.begin_frame(key, &c, &specs, &no_compute());
            let task = fd.take_slot().unwrap();
            let (rb, _, out) = delta_search(searcher.as_ref(), &c, 3, &task);
            let (want, _) = searcher.search_subm(&c, 3);
            assert_eq!(rb.pairs, want.pairs, "{kind}: chained warm frame diverged");
            assert!(out.reused > 0);
            fd.record(task.index, out.frags);
            cache.commit(fd);
        }
    }

    #[test]
    fn structural_mismatch_degrades_to_cold() {
        let e = Extent3::new(32, 32, 4);
        let a = tensor(e, 0.05, 3);
        let specs = Arc::new(vec![SlotSpec { halo: 1, scale: 1 }]);
        let key = DeltaKey { sequence: 0, shard: None };
        let mut cache = DeltaCache::new(DeltaConfig::default());
        let mut fd = cache.begin_frame(key, &a, &specs, &no_compute());
        let task = fd.take_slot().unwrap();
        let (_, _, out) = delta_search(SearcherKind::Doms.build().as_ref(), &a, 3, &task);
        fd.record(task.index, out.frags);
        cache.commit(fd);
        // Different network shape -> cold plan despite identical coords.
        let other = Arc::new(vec![SlotSpec { halo: 3, scale: 2 }]);
        let mut fd = cache.begin_frame(key, &a, &other, &no_compute());
        let task = fd.take_slot().unwrap();
        assert!(task.prior.is_none());
        assert!(task.research.iter().all(|r| *r));
        // Turning compute reuse on also changes the signature -> cold.
        let mut fd = cache.begin_frame(key, &a, &specs, &specs);
        let task = fd.take_slot().unwrap();
        assert!(task.prior.is_none());
    }

    #[test]
    fn cache_evicts_lru_beyond_bound() {
        let e = Extent3::new(16, 16, 2);
        let t = tensor(e, 0.1, 5);
        let specs = Arc::new(vec![SlotSpec { halo: 1, scale: 1 }]);
        let mut cache = DeltaCache::new(DeltaConfig {
            enabled: true,
            max_entries: 1,
            ..Default::default()
        });
        let s = SearcherKind::Doms.build();
        for seq in 0..3u32 {
            let key = DeltaKey { sequence: seq, shard: None };
            let mut fd = cache.begin_frame(key, &t, &specs, &no_compute());
            let task = fd.take_slot().unwrap();
            let (_, _, out) = delta_search(s.as_ref(), &t, 3, &task);
            fd.record(task.index, out.frags);
            cache.commit(fd);
            assert_eq!(cache.len(), 1, "bound must hold after every commit");
        }
        assert_eq!(cache.evictions, 2);
    }

    #[test]
    fn partial_commit_drops_entry() {
        let e = Extent3::new(16, 16, 2);
        let t = tensor(e, 0.1, 5);
        let specs = Arc::new(vec![SlotSpec { halo: 1, scale: 1 }]);
        let key = DeltaKey { sequence: 9, shard: None };
        let mut cache = DeltaCache::new(DeltaConfig::default());
        let fd = cache.begin_frame(key, &t, &specs, &no_compute()); // slot never taken
        cache.commit(fd);
        assert!(cache.is_empty());
    }

    #[test]
    fn config_parses_and_rejects_bad_values() {
        let c = Config::parse(
            "[runner]\ndelta = true\ndelta_blocks_x = 4\ndelta_blocks_y = 2\ndelta_max_entries = 5\ndelta_compute = true\ndelta_voxelize = true",
        )
        .unwrap();
        let d = DeltaConfig::from_config(&c).unwrap();
        assert_eq!(
            d,
            DeltaConfig {
                enabled: true,
                blocks_x: 4,
                blocks_y: 2,
                max_entries: 5,
                compute: true,
                voxelize: true,
            }
        );
        // Missing keys: defaults, disabled.
        let d = DeltaConfig::from_config(&Config::parse("").unwrap()).unwrap();
        assert_eq!(d, DeltaConfig::default());
        assert!(!d.enabled && !d.compute && !d.voxelize);
        // Present-but-bad values are errors, not silent fallbacks.
        for bad in [
            "[runner]\ndelta = 3",
            "[runner]\ndelta = \"yes\"",
            "[runner]\ndelta_blocks_x = 0",
            "[runner]\ndelta_blocks_y = -1",
            "[runner]\ndelta_max_entries = 0",
            "[runner]\ndelta_compute = 1",
            "[runner]\ndelta_voxelize = \"on\"",
        ] {
            let c = Config::parse(bad).unwrap();
            assert!(DeltaConfig::from_config(&c).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn compute_rows_splice_only_clean_cone_blocks() {
        let e = Extent3::new(32, 32, 4);
        let a = tensor(e, 0.08, 7);
        let mut coords = a.coords.clone();
        coords.push(Coord3::new(2, 2, 1));
        let b = SparseTensor::from_coords(e, coords, 1);
        let specs = Arc::new(vec![SlotSpec { halo: 1, scale: 1 }]);
        let cspecs = Arc::new(vec![SlotSpec { halo: 1, scale: 1 }]);
        let key = DeltaKey { sequence: 0, shard: None };
        let mut cache = DeltaCache::new(DeltaConfig {
            enabled: true,
            compute: true,
            ..Default::default()
        });
        let c_out = 2usize;
        // Cold frame A: psum row o = [o, -o].
        let mut fd = cache.begin_frame(key, &a, &specs, &cspecs);
        let task = fd.take_slot().unwrap();
        let (_, _, out) = delta_search(SearcherKind::Doms.build().as_ref(), &a, 3, &task);
        fd.record(task.index, out.frags);
        let ct = fd.take_compute(0).unwrap();
        assert!(ct.prior.is_none());
        assert!(ct.splice_plan(&a.coords).is_none(), "cold frame has nothing to splice");
        let psums: Vec<i32> = (0..a.len() as i32).flat_map(|o| [o, -o]).collect();
        let rows = bin_compute_rows(&ct, &a.coords, &psums, c_out);
        let total: usize = rows.iter().map(|r| r.rows.len()).sum();
        assert_eq!(total, a.len(), "cold frame bins every output row");
        fd.record_compute(ct.index, rows);
        cache.commit(fd);
        // Warm frame B: the (2, 2) edit dirties one block; its cone ring
        // recomputes, everything else splices A's rows.
        let mut fd = cache.begin_frame(key, &b, &specs, &cspecs);
        let ct = fd.take_compute(0).unwrap();
        assert!(ct.prior.is_some());
        assert!(ct.research.iter().any(|r| *r) && ct.research.iter().any(|r| !r));
        let plan = ct.splice_plan(&b.coords).expect("clean blocks must yield a plan");
        assert_eq!(plan.skip.len(), b.len());
        assert!(plan.skip.iter().any(|s| *s));
        for &(o, ref row) in &plan.rows {
            assert!(plan.skip[o as usize]);
            // The spliced row is A's row for the same coordinate.
            let c = b.coords[o as usize];
            let ao = a.coords.binary_search(&c).expect("clean row exists in A");
            assert_eq!(row, &vec![ao as i32, -(ao as i32)]);
            // And it lives outside the re-compute region.
            let blk = block_at(&ct.part, c, 1);
            assert!(!ct.research[blk]);
        }
        // Skipped rows are exactly the cached clean-block rows.
        let skipped = plan.skip.iter().filter(|s| **s).count();
        assert_eq!(skipped, plan.rows.len());
    }

    #[test]
    fn feature_change_dirties_compute_but_not_map_search() {
        let e = Extent3::new(32, 32, 4);
        let a = tensor(e, 0.08, 19);
        // Same geometry, one feature flipped: the rulebook is reusable
        // everywhere, but psums near the edit are not.
        let mut b = a.clone();
        b.feature_mut(0)[0] = 7;
        let specs = Arc::new(vec![SlotSpec { halo: 1, scale: 1 }]);
        let cspecs = Arc::new(vec![SlotSpec { halo: 1, scale: 1 }]);
        let key = DeltaKey { sequence: 0, shard: None };
        let mut cache = DeltaCache::new(DeltaConfig {
            enabled: true,
            compute: true,
            ..Default::default()
        });
        let mut fd = cache.begin_frame(key, &a, &specs, &cspecs);
        let task = fd.take_slot().unwrap();
        let (_, _, out) = delta_search(SearcherKind::Doms.build().as_ref(), &a, 3, &task);
        fd.record(task.index, out.frags);
        let ct = fd.take_compute(0).unwrap();
        let psums = vec![0i32; a.len()];
        let rows = bin_compute_rows(&ct, &a.coords, &psums, 1);
        fd.record_compute(ct.index, rows);
        cache.commit(fd);
        let mut fd = cache.begin_frame(key, &b, &specs, &cspecs);
        let task = fd.take_slot().unwrap();
        assert!(
            task.research.iter().all(|r| !r),
            "identical geometry: no map-search work at all"
        );
        let ct = fd.take_compute(0).unwrap();
        let dirty_blk = block_at(&task.part, b.coords[0], 1);
        assert!(ct.research[dirty_blk], "feature edit must dirty its block's compute");
        assert!(ct.research.iter().any(|r| !r), "far blocks keep their psums");
    }

    #[test]
    fn partial_compute_commit_drops_entry() {
        let e = Extent3::new(16, 16, 2);
        let t = tensor(e, 0.1, 5);
        let specs = Arc::new(vec![SlotSpec { halo: 1, scale: 1 }]);
        let cspecs = Arc::new(vec![SlotSpec { halo: 1, scale: 1 }]);
        let key = DeltaKey { sequence: 9, shard: None };
        let mut cache = DeltaCache::new(DeltaConfig::default());
        let mut fd = cache.begin_frame(key, &t, &specs, &cspecs);
        let task = fd.take_slot().unwrap();
        let (_, _, out) = delta_search(SearcherKind::Doms.build().as_ref(), &t, 3, &task);
        fd.record(task.index, out.frags);
        // Map-search slot recorded, compute slot never recorded.
        cache.commit(fd);
        assert!(cache.is_empty(), "a compute hole must drop the entry");
    }
}
