//! Output-major map search — the MARS [14] baseline.
//!
//! Concentrates on each output voxel and exploits kernel symmetry (only
//! the 13 positive-half offsets + center are searched; the reverse pair is
//! inferred, Fig. 2a). To search one output exhaustively in a single load
//! the sorter buffer must hold the voxels of **two whole consecutive
//! depths**. When it does, each depth is loaded once → O(N). When the two
//! depths outgrow the buffer (high resolution / dense regions, Fig. 2c-d),
//! every group of outputs must re-stream the whole two-depth window from
//! DRAM, and the access volume deteriorates rapidly — the behavior this
//! model reproduces and Fig. 9(b) quantifies.

use crate::geom::KernelOffsets;
use crate::mapsearch::table::DepthTable;
use crate::mapsearch::{AccessStats, MapSearch};
use crate::sparse::rulebook::{ConvKind, Rulebook, RulePair};
use crate::sparse::tensor::SparseTensor;

#[derive(Clone, Debug)]
pub struct OutputMajor {
    /// Sorter buffer capacity in voxels. The paper's stress setting sizes
    /// it to the merge-sorter length (64).
    pub buffer_voxels: usize,
    /// Merge-sorter length.
    pub sorter_len: usize,
}

impl Default for OutputMajor {
    fn default() -> Self {
        Self {
            buffer_voxels: 64,
            sorter_len: 64,
        }
    }
}

impl OutputMajor {
    /// Queries per output: 13 positive-half positions + center.
    fn queries_per_output(k: usize) -> usize {
        let offs = KernelOffsets::centered(k);
        offs.search_half().len()
    }
}

/// Emit the pairs for output index `o` by probing the positive half, and
/// infer the symmetric reverse pairs. The straightforward
/// binary-search-per-offset formulation — kept as the reference that
/// [`emit_output_pairs_rows`] (the optimized version all searchers use)
/// is property-tested against.
#[cfg_attr(not(test), allow(dead_code))]
pub(crate) fn emit_output_pairs(
    input: &SparseTensor,
    offs: &KernelOffsets,
    o: usize,
    pairs: &mut Vec<RulePair>,
) {
    let q = input.coords[o];
    // Center: submanifold outputs are the inputs, pair with itself.
    let center = offs.index_of(crate::geom::Offset3::ZERO).unwrap() as u16;
    pairs.push(RulePair {
        offset: center,
        input: o as u32,
        output: o as u32,
    });
    for &delta in offs.positive_half().iter() {
        let p = q.offset(delta);
        if !p.in_bounds(input.extent) {
            continue;
        }
        if let Some(i) = input.find(p) {
            let d = offs.index_of(delta).unwrap() as u16;
            // (P=Q+δ, Q, W_δ): input i contributes to output o via δ.
            pairs.push(RulePair {
                offset: d,
                input: i as u32,
                output: o as u32,
            });
            // Symmetric reverse pair (Fig. 2a): output at P takes input Q
            // via -δ.
            let dneg = offs.index_of(delta.negate()).unwrap() as u16;
            pairs.push(RulePair {
                offset: dneg,
                input: o as u32,
                output: i as u32,
            });
        }
    }
}

/// K=3 offset index in the canonical (dz, dy, dx) enumeration.
#[inline]
pub(crate) fn offset_index3(dx: i32, dy: i32, dz: i32) -> u16 {
    ((dz + 1) * 9 + (dy + 1) * 3 + (dx + 1)) as u16
}

/// Fast K=3 variant of [`emit_output_pairs`]: instead of 13 binary
/// searches over the whole coordinate array, probe the (at most 5)
/// affected row spans from the depth table and scan the 1-3 candidate x
/// positions inside each — the same lookups the hardware's merge sorter
/// performs against its row-window, and ~10x faster on the host
/// (EXPERIMENTS.md §Perf L3 iteration 1).
pub(crate) fn emit_output_pairs_rows(
    input: &SparseTensor,
    dt: &DepthTable,
    o: usize,
    pairs: &mut Vec<RulePair>,
) {
    let q = input.coords[o];
    let o32 = o as u32;
    pairs.push(RulePair {
        offset: offset_index3(0, 0, 0),
        input: o32,
        output: o32,
    });
    // Probe x0+dx within row span (start, len) for dx in [x_lo..=1].
    let probe_row = |y: i32, z: i32, dx_lo: i32, pairs: &mut Vec<RulePair>| {
        let (start, len) = dt.row(z, y);
        if len == 0 {
            return;
        }
        let row = &input.coords[start..start + len];
        // Rows are short; find the lower bound of x0-1 then scan.
        let x_lo = q.x + dx_lo;
        let x_hi = q.x + 1;
        let mut i = row.partition_point(|c| c.x < x_lo);
        while i < len && row[i].x <= x_hi {
            let p = row[i];
            let (dx, dy, dz) = (p.x - q.x, y - q.y, z - q.z);
            // Skip the center (handled above) and non-window positions.
            if !(dx == 0 && dy == 0 && dz == 0) {
                let d = offset_index3(dx, dy, dz);
                let dneg = offset_index3(-dx, -dy, -dz);
                let i32idx = (start + i) as u32;
                pairs.push(RulePair {
                    offset: d,
                    input: i32idx,
                    output: o32,
                });
                pairs.push(RulePair {
                    offset: dneg,
                    input: o32,
                    output: i32idx,
                });
            }
            i += 1;
        }
    };
    // Positive half for K=3: same depth — (dx=1, dy=0) and row y0+1 with
    // dx in {-1,0,1}; next depth — rows y0-1..y0+1, dx in {-1,0,1}.
    probe_row(q.y, q.z, 1, pairs);
    probe_row(q.y + 1, q.z, -1, pairs);
    for dy in -1..=1 {
        probe_row(q.y + dy, q.z + 1, -1, pairs);
    }
}

impl MapSearch for OutputMajor {
    fn name(&self) -> &'static str {
        "output-major (MARS)"
    }

    fn search_subm(&self, input: &SparseTensor, k: usize) -> (Rulebook, AccessStats) {
        assert_eq!(k, 3, "output-major model is calibrated for subm3");
        let dt = DepthTable::build(input);
        let qpo = Self::queries_per_output(k);
        let mut pairs = Vec::with_capacity(input.len() * 8);
        let mut stats = AccessStats::default();

        let depths = input.extent.z;
        let mut prev_window_resident = false;
        for z in 0..depths as i32 {
            let len_z = dt.depth_len(z);
            if len_z == 0 {
                prev_window_resident = false;
                continue;
            }
            let len_next = if (z as usize) + 1 < depths {
                dt.depth_len(z + 1)
            } else {
                0
            };
            let window = len_z + len_next;

            if window <= self.buffer_voxels {
                // Window fits: depth z is already resident iff the
                // previous window (z-1, z) fit too; depth z+1 must be
                // loaded fresh.
                if prev_window_resident {
                    stats.voxel_reads += len_next as u64;
                } else {
                    stats.voxel_reads += window as u64;
                }
                prev_window_resident = true;
                // Sorter: outputs grouped so window + queries fit a pass.
                let free = self.sorter_len.saturating_sub(window).max(1);
                let group = (free / qpo).max(1);
                stats.sorter_passes += len_z.div_ceil(group) as u64;
            } else {
                // Window exceeds the buffer: each output group must
                // re-stream the entire two-depth window from DRAM in
                // sorter-sized chunks (the "multiple loading" regime).
                // Outputs are batched through a query FIFO so a quarter
                // of the buffer's worth of outputs share one window
                // stream.
                let group = (self.buffer_voxels / 4).max(1);
                let groups = len_z.div_ceil(group) as u64;
                stats.voxel_reads += groups * window as u64;
                let chunks = window.div_ceil((self.sorter_len / 2).max(1)) as u64;
                stats.sorter_passes += groups * chunks;
                prev_window_resident = false;
            }

            // Functional result (identical across searchers).
            let (start, _) = (dt.starts[z as usize], ());
            let end = dt.starts[z as usize + 1];
            for o in start..end {
                emit_output_pairs_rows(input, &dt, o, &mut pairs);
            }
        }

        // Comparator count proxy: full network per pass.
        let l = self.sorter_len;
        stats.sorter_compares =
            stats.sorter_passes * (l / 2 * (l.ilog2() as usize * (l.ilog2() as usize + 1) / 2)) as u64;

        let mut rb = Rulebook {
            kind: ConvKind::Submanifold { k },
            pairs,
            out_coords: input.coords.clone(),
            out_extent: input.extent,
        };
        rb.canonicalize();
        (rb, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Extent3;
    use crate::pointcloud::voxelize::Voxelizer;
    use crate::sparse::hash_map_search;
    use crate::testing::prop::check;

    fn tensor(e: Extent3, sparsity: f64, seed: u64) -> SparseTensor {
        let g = Voxelizer::synth_occupancy(e, sparsity, seed);
        SparseTensor::from_coords(e, g.coords(), 1)
    }

    #[test]
    fn matches_hash_oracle() {
        let t = tensor(Extent3::new(24, 24, 8), 0.04, 21);
        let (rb, _) = OutputMajor::default().search_subm(&t, 3);
        let want = hash_map_search(&t, ConvKind::subm3());
        assert_eq!(rb.pairs, want.pairs);
    }

    #[test]
    fn matches_hash_oracle_prop() {
        check("output-major == hash oracle", 15, |g| {
            let e = Extent3::new(g.usize(4, 20), g.usize(4, 20), g.usize(2, 10));
            let t = tensor(e, g.f64(0.01, 0.3), g.usize(0, 1 << 30) as u64);
            let (rb, _) = OutputMajor::default().search_subm(&t, 3);
            let want = hash_map_search(&t, ConvKind::subm3());
            assert_eq!(rb.pairs, want.pairs);
        });
    }

    #[test]
    fn fast_emit_equals_reference_emit() {
        check("row emit == binary-search emit", 25, |g| {
            let e = Extent3::new(g.usize(3, 24), g.usize(3, 24), g.usize(2, 8));
            let t = tensor(e, g.f64(0.02, 0.4), g.usize(0, 1 << 30) as u64);
            if t.is_empty() {
                return;
            }
            let dt = crate::mapsearch::table::DepthTable::build(&t);
            let offs = KernelOffsets::centered(3);
            let o = g.usize(0, t.len());
            let mut a = Vec::new();
            emit_output_pairs(&t, &offs, o, &mut a);
            let mut b = Vec::new();
            emit_output_pairs_rows(&t, &dt, o, &mut b);
            a.sort();
            b.sort();
            assert_eq!(a, b);
        });
    }

    #[test]
    fn sparse_case_is_o_n() {
        // Low resolution, very sparse: every two-depth window fits in 64.
        let t = tensor(Extent3::new(32, 32, 8), 0.002, 22);
        let (_, stats) = OutputMajor::default().search_subm(&t, 3);
        let norm = stats.normalized(t.len());
        assert!(norm <= 2.0, "expected ~O(N), got {norm}x");
    }

    #[test]
    fn dense_case_blows_up() {
        // Dense: two-depth windows far exceed 64 voxels.
        let t = tensor(Extent3::new(64, 64, 8), 0.10, 23);
        let (_, stats) = OutputMajor::default().search_subm(&t, 3);
        let norm = stats.normalized(t.len());
        assert!(norm > 27.0, "expected blow-up beyond weight-major, got {norm}x");
    }

    #[test]
    fn bigger_buffer_restores_o_n() {
        let t = tensor(Extent3::new(64, 64, 8), 0.10, 23);
        let big = OutputMajor {
            buffer_voxels: 4096,
            sorter_len: 4096,
        };
        let (_, stats) = big.search_subm(&t, 3);
        assert!(stats.normalized(t.len()) <= 2.0);
    }
}
