//! Row-granular FIFO voxel-buffer model (buffers I and II of Fig. 7).
//!
//! The map-search core stores voxel *rows* (all voxels sharing (y, z)) in
//! two FIFO buffers. The model tracks which rows are resident and charges
//! a DRAM read for each voxel of a row that has to be (re)loaded — this is
//! what produces O(N) vs O(2N) vs blow-up behavior across the searchers.

use std::collections::VecDeque;

use rustc_hash::FxHashSet as HashSet;

/// Identifier of a voxel row: (z, y). Block-DOMS additionally scopes rows
/// by block id packed into the high bits of `y` by the caller.
pub type RowId = (i32, i64);

/// A FIFO of voxel rows with a capacity in *voxels* (the paper sizes the
/// buffer to the merge-sorter length, 64).
///
/// Membership is tracked in a side `HashSet`: with ~1-voxel rows at high
/// resolution the FIFO holds up to `capacity` rows, and a linear scan per
/// `ensure` dominated the DOMS hot loop (EXPERIMENTS.md §Perf L3
/// iteration 3).
#[derive(Clone, Debug)]
pub struct RowFifo {
    pub capacity: usize,
    resident: VecDeque<(RowId, usize)>,
    members: HashSet<RowId>,
    occupied: usize,
    /// Total voxels loaded from DRAM into this buffer.
    pub loads: u64,
}

impl RowFifo {
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity,
            resident: VecDeque::new(),
            members: HashSet::default(),
            occupied: 0,
            loads: 0,
        }
    }

    pub fn contains(&self, row: RowId) -> bool {
        self.members.contains(&row)
    }

    /// Ensure `row` (with `size` voxels) is resident; returns the number
    /// of voxels read from DRAM (0 if already resident). Rows larger than
    /// the whole buffer are streamed through: they are charged fully and
    /// marked non-resident (they can never be reused).
    pub fn ensure(&mut self, row: RowId, size: usize) -> u64 {
        if size == 0 {
            return 0;
        }
        if self.contains(row) {
            return 0;
        }
        self.loads += size as u64;
        if size > self.capacity {
            // Streamed, not retained.
            return size as u64;
        }
        while self.occupied + size > self.capacity {
            let (evicted, s) = self.resident.pop_front().expect("occupied>0");
            self.members.remove(&evicted);
            self.occupied -= s;
        }
        self.resident.push_back((row, size));
        self.members.insert(row);
        self.occupied += size;
        size as u64
    }

    /// Drop a specific row (Fig. 3 step 4: first row released after use).
    pub fn release(&mut self, row: RowId) {
        if let Some(pos) = self.resident.iter().position(|(r, _)| *r == row) {
            let (_, s) = self.resident.remove(pos).unwrap();
            self.members.remove(&row);
            self.occupied -= s;
        }
    }

    /// Drop everything (depth advance).
    pub fn clear(&mut self) {
        self.resident.clear();
        self.members.clear();
        self.occupied = 0;
    }

    pub fn occupied(&self) -> usize {
        self.occupied
    }

    /// Adopt the contents of another FIFO (the DOMS O(N) optimization:
    /// when a whole depth fits, buffer II's rows become buffer I's rows on
    /// depth advance without touching DRAM).
    pub fn adopt(&mut self, other: &mut RowFifo) {
        self.clear();
        std::mem::swap(&mut self.resident, &mut other.resident);
        std::mem::swap(&mut self.members, &mut other.members);
        self.occupied = other.occupied;
        other.occupied = 0;
        other.members.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_load_charged_reuse_free() {
        let mut f = RowFifo::new(16);
        assert_eq!(f.ensure((0, 0), 4), 4);
        assert_eq!(f.ensure((0, 0), 4), 0);
        assert_eq!(f.loads, 4);
    }

    #[test]
    fn eviction_fifo_order() {
        let mut f = RowFifo::new(8);
        f.ensure((0, 0), 4);
        f.ensure((0, 1), 4);
        f.ensure((0, 2), 4); // evicts (0,0)
        assert!(!f.contains((0, 0)));
        assert!(f.contains((0, 1)));
        assert!(f.contains((0, 2)));
        // Reloading the evicted row costs again.
        assert_eq!(f.ensure((0, 0), 4), 4);
    }

    #[test]
    fn oversized_row_streams_without_residency() {
        let mut f = RowFifo::new(8);
        assert_eq!(f.ensure((0, 0), 20), 20);
        assert!(!f.contains((0, 0)));
        assert_eq!(f.occupied(), 0);
        // And it did not evict anything resident.
        f.ensure((0, 1), 8);
        assert_eq!(f.ensure((0, 2), 30), 30);
        assert!(f.contains((0, 1)));
    }

    #[test]
    fn release_frees_space() {
        let mut f = RowFifo::new(8);
        f.ensure((0, 0), 4);
        f.ensure((0, 1), 4);
        f.release((0, 0));
        assert_eq!(f.occupied(), 4);
        assert_eq!(f.ensure((0, 2), 4), 4);
        assert!(f.contains((0, 1)) && f.contains((0, 2)));
    }

    #[test]
    fn adopt_moves_rows_without_dram_traffic() {
        let mut a = RowFifo::new(8);
        let mut b = RowFifo::new(8);
        b.ensure((1, 0), 4);
        b.ensure((1, 1), 2);
        let loads_before = a.loads;
        a.adopt(&mut b);
        assert_eq!(a.loads, loads_before);
        assert!(a.contains((1, 0)) && a.contains((1, 1)));
        assert_eq!(a.occupied(), 6);
        assert_eq!(b.occupied(), 0);
    }

    #[test]
    fn zero_size_row_free() {
        let mut f = RowFifo::new(4);
        assert_eq!(f.ensure((0, 5), 0), 0);
        assert_eq!(f.loads, 0);
    }
}
