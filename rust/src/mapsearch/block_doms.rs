//! Block-DOMS (§3.1D, Fig. 4, Alg. 1).
//!
//! DOMS still pays O(2N) when a whole depth outgrows the FIFO (each depth
//! is read once into buffer II serving depth z-1, and again into buffer I
//! serving depth z). Block-DOMS divides the (x, y) plane into a `bx x by`
//! grid **to downsize each depth**: per-block depths fit the FIFO, buffer
//! II is adopted on the depth advance, and access drops to a stable O(N).
//!
//! Cross-block neighbor access (Alg. 1):
//! * `y∓` direction — the needed rows sit at the beginning/end of the
//!   neighbor block's depth run, located directly via that block's
//!   depth-encoding table (loaded into the backup FIFO);
//! * `x⁺` direction — the neighbor block's first column is **replicated**
//!   into this block at re-organization time (<6% of voxels, counted as
//!   `voxel_writes`); `x⁻` needs nothing by kernel symmetry.
//!
//! The trade-off (Fig. 9c): more blocks → smaller per-block depths (less
//! access) but one depth table per block (more SRAM) and more replicated
//! voxels.

use rustc_hash::FxHashMap as HashMap;

use crate::geom::KernelOffsets;
use crate::mapsearch::buffer::RowFifo;
use crate::mapsearch::output_major::emit_output_pairs_rows;
use crate::mapsearch::table::{BlockPartition, DepthTable};
use crate::mapsearch::{AccessStats, MapSearch};
use crate::sparse::rulebook::{ConvKind, Rulebook};
use crate::sparse::tensor::SparseTensor;

#[derive(Clone, Debug)]
pub struct BlockDoms {
    pub bx: usize,
    pub by: usize,
    /// Row-FIFO capacity in voxels (paper: 64).
    pub fifo_voxels: usize,
    pub sorter_len: usize,
}

impl Default for BlockDoms {
    fn default() -> Self {
        // The paper's chosen partition for the high-resolution case.
        Self {
            bx: 2,
            by: 8,
            fifo_voxels: 64,
            sorter_len: 64,
        }
    }
}

/// Per-block reorganized data: depth-major sorted voxel list plus row
/// index, including the replicated x⁺ margin column.
struct BlockData {
    /// (z, y) -> number of voxels in that row of this block (own +
    /// replicated).
    rows: HashMap<(i32, i32), usize>,
    /// (z) -> total voxels of this block at that depth.
    depth_len: HashMap<i32, usize>,
    /// Output voxels (global indices) owned by this block, depth-major.
    outputs: Vec<usize>,
    /// Number of voxels replicated into this block from its x⁺ neighbor.
    replicated: usize,
}

impl BlockDoms {
    /// Build a block-DOMS searcher over a `bx x by` partition. A zero-
    /// sized grid is a configuration error (it would denote an empty
    /// partition with no blocks to search), reported instead of asserted
    /// so config-driven callers (`[shard]`, partition sweeps) surface it
    /// to the user.
    pub fn with_partition(bx: usize, by: usize) -> crate::Result<Self> {
        anyhow::ensure!(
            bx >= 1 && by >= 1,
            "block partition must be at least 1x1, got {bx}x{by}"
        );
        Ok(Self {
            bx,
            by,
            ..Default::default()
        })
    }

    pub fn partition_for(&self, input: &SparseTensor) -> BlockPartition {
        BlockPartition::new(self.bx, self.by, input.extent.x, input.extent.y)
    }

    /// Reorganize the tensor into per-block structures, performing the
    /// x⁺ margin replication.
    fn reorganize(&self, input: &SparseTensor, part: &BlockPartition) -> Vec<BlockData> {
        let nb = part.num_blocks();
        let mut blocks: Vec<BlockData> = (0..nb)
            .map(|_| BlockData {
                rows: HashMap::default(),
                depth_len: HashMap::default(),
                outputs: Vec::new(),
                replicated: 0,
            })
            .collect();
        let bw = part.block_w();
        for (idx, &c) in input.coords.iter().enumerate() {
            let (bi, bj) = part.block_of(c);
            let b = &mut blocks[bj * part.bx + bi];
            *b.rows.entry((c.z, c.y)).or_insert(0) += 1;
            *b.depth_len.entry(c.z).or_insert(0) += 1;
            b.outputs.push(idx);
            // Replication: a voxel on the first column of block bi (> 0)
            // is copied into block bi-1 (same j).
            if bi > 0 && (c.x as usize) % bw == 0 {
                let nb = &mut blocks[bj * part.bx + (bi - 1)];
                *nb.rows.entry((c.z, c.y)).or_insert(0) += 1;
                *nb.depth_len.entry(c.z).or_insert(0) += 1;
                nb.replicated += 1;
            }
        }
        blocks
    }
}

impl MapSearch for BlockDoms {
    fn name(&self) -> &'static str {
        "block-DOMS"
    }

    fn search_subm(&self, input: &SparseTensor, k: usize) -> (Rulebook, AccessStats) {
        assert_eq!(k, 3, "block-DOMS row-window model is calibrated for subm3");
        let offs = KernelOffsets::centered(k);
        let part = self.partition_for(input);
        let blocks = self.reorganize(input, &part);
        // Global depth table for pair emission (per-block tables drive
        // the cost model; emission only needs fast row lookup).
        let dt = DepthTable::build(input);
        let qpo = offs.search_half().len();
        let mut stats = AccessStats {
            table_bytes: part.table_bytes(input.extent.z),
            ..Default::default()
        };
        let mut pairs = Vec::with_capacity(input.len() * 8);

        let bh = part.block_h() as i32;
        for (bid, b) in blocks.iter().enumerate() {
            // Replicated voxels were written back to DRAM during
            // re-organization.
            stats.voxel_writes += b.replicated as u64;
            let bj = (bid / part.bx) as i32;
            let y_lo = bj * bh;
            let y_hi = ((bj + 1) * bh).min(input.extent.y as i32) - 1;

            let mut buf_i = RowFifo::new(self.fifo_voxels);
            let mut buf_ii = RowFifo::new(self.fifo_voxels);
            // Backup FIFO for cross-block rows (Fig. 7). Keyed by the
            // neighbor block id packed into the row id.
            let mut backup = RowFifo::new(self.fifo_voxels);

            let mut prev_z = i32::MIN;
            let mut i = 0usize;
            while i < b.outputs.len() {
                let o = b.outputs[i];
                let (z, y0) = (input.coords[o].z, input.coords[o].y);
                // Depth advance within the block.
                if z != prev_z {
                    if b.depth_len.get(&z).copied().unwrap_or(0) <= self.fifo_voxels {
                        buf_i.adopt(&mut buf_ii);
                    } else {
                        buf_i.clear();
                        buf_ii.clear();
                    }
                    prev_z = z;
                }
                // All outputs of this (z, y0) row within the block share
                // the window.
                let row_end = {
                    let mut j = i;
                    while j < b.outputs.len() {
                        let c = input.coords[b.outputs[j]];
                        if c.z != z || c.y != y0 {
                            break;
                        }
                        j += 1;
                    }
                    j
                };

                let row_id = |bb: usize, zz: i32, yy: i32| -> (i32, i64) {
                    (zz, ((bb as i64) << 32) | (yy as i64 & 0xffff_ffff))
                };
                let mut window = 0usize;
                // In-block rows y0..y0+1 @ z (clamped to block range).
                for dy in 0..=1 {
                    let y = y0 + dy;
                    if y > y_hi {
                        continue;
                    }
                    let rl = b.rows.get(&(z, y)).copied().unwrap_or(0);
                    stats.voxel_reads += buf_i.ensure(row_id(bid, z, y), rl);
                    window += rl;
                }
                // In-block rows y0-1..y0+1 @ z+1.
                for dy in -1..=1 {
                    let y = y0 + dy;
                    if y < y_lo || y > y_hi {
                        continue;
                    }
                    let rl = b.rows.get(&(z + 1, y)).copied().unwrap_or(0);
                    stats.voxel_reads += buf_ii.ensure(row_id(bid, z + 1, y), rl);
                    window += rl;
                }
                // Cross-block rows (Alg. 1): y0-1 below the block or y0+1
                // above it live in the j∓1 neighbor blocks, located via
                // their depth-encoding tables and staged in the backup
                // FIFO. Δx ∈ {-1, 0, +1} column spill is covered because
                // we charge the neighbor's whole (short) row.
                let mut cross = |yy: i32, zz: i32, stats: &mut AccessStats, window: &mut usize| {
                    if yy < 0 || yy >= input.extent.y as i32 {
                        return;
                    }
                    let nbj = yy / bh;
                    if nbj == bj {
                        return;
                    }
                    let nbid = (nbj as usize) * part.bx + (bid % part.bx);
                    let rl = blocks[nbid].rows.get(&(zz, yy)).copied().unwrap_or(0);
                    stats.voxel_reads += backup.ensure(row_id(nbid, zz, yy), rl);
                    *window += rl;
                };
                cross(y0 - 1, z + 1, &mut stats, &mut window);
                cross(y0 + 1, z, &mut stats, &mut window);
                cross(y0 + 1, z + 1, &mut stats, &mut window);

                for &oi in &b.outputs[i..row_end] {
                    let payload = window + qpo;
                    stats.sorter_passes +=
                        payload.div_ceil(self.sorter_len).max(1) as u64;
                    emit_output_pairs_rows(input, &dt, oi, &mut pairs);
                }
                i = row_end;
            }
        }

        let l = self.sorter_len;
        stats.sorter_compares = stats.sorter_passes
            * (l / 2 * (l.ilog2() as usize * (l.ilog2() as usize + 1) / 2)) as u64;

        let mut rb = Rulebook {
            kind: ConvKind::Submanifold { k },
            pairs,
            out_coords: input.coords.clone(),
            out_extent: input.extent,
        };
        rb.canonicalize();
        (rb, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Extent3;
    use crate::mapsearch::Doms;
    use crate::pointcloud::voxelize::Voxelizer;
    use crate::sparse::hash_map_search;
    use crate::testing::prop::check;

    fn tensor(e: Extent3, n: usize, seed: u64) -> SparseTensor {
        let s = n as f64 / e.volume() as f64;
        let g = Voxelizer::synth_occupancy(e, s, seed);
        SparseTensor::from_coords(e, g.coords(), 1)
    }

    #[test]
    fn matches_hash_oracle() {
        let t = tensor(Extent3::new(32, 32, 8), 600, 41);
        let (rb, _) = BlockDoms::default().search_subm(&t, 3);
        let want = hash_map_search(&t, ConvKind::subm3());
        assert_eq!(rb.pairs, want.pairs);
    }

    #[test]
    fn matches_hash_oracle_prop_over_partitions() {
        check("block-DOMS == hash oracle for any partition", 12, |g| {
            let e = Extent3::new(g.usize(8, 40), g.usize(8, 40), g.usize(2, 8));
            let t = tensor(e, g.usize(10, 800), g.usize(0, 1 << 30) as u64);
            let bd = BlockDoms::with_partition(g.usize(1, 5), g.usize(1, 5)).unwrap();
            let (rb, _) = bd.search_subm(&t, 3);
            let want = hash_map_search(&t, ConvKind::subm3());
            assert_eq!(rb.pairs, want.pairs);
        });
    }

    #[test]
    fn reaches_o_n_where_doms_pays_2n() {
        // Depth of ~300 voxels: far beyond the 64-voxel FIFO for DOMS,
        // but a 4x8 partition brings per-block depths under 64.
        let e = Extent3::new(128, 128, 8);
        let t = tensor(e, 2400, 42);
        let (_, doms) = Doms::default().search_subm(&t, 3);
        let (_, bdoms) = BlockDoms::with_partition(4, 8).unwrap().search_subm(&t, 3);
        let dn = doms.normalized(t.len());
        let bn = bdoms.normalized(t.len());
        assert!(dn > 1.7, "DOMS should be ~2N here, got {dn}");
        assert!(bn < 1.4, "block-DOMS should be ~N here, got {bn}");
    }

    #[test]
    fn replication_fraction_small() {
        let e = Extent3::new(352, 400, 10);
        let t = tensor(e, 7000, 43);
        let bd = BlockDoms::with_partition(2, 8).unwrap();
        let (_, stats) = bd.search_subm(&t, 3);
        let frac = stats.voxel_writes as f64 / t.len() as f64;
        assert!(frac < 0.06, "replicated fraction {frac} >= 6%");
    }

    #[test]
    fn zero_partition_is_a_config_error() {
        assert!(BlockDoms::with_partition(0, 4).is_err());
        assert!(BlockDoms::with_partition(4, 0).is_err());
        assert!(BlockDoms::with_partition(0, 0).is_err());
        assert!(BlockDoms::with_partition(1, 1).is_ok());
    }

    #[test]
    fn table_grows_with_blocks() {
        let e = Extent3::new(64, 64, 10);
        let t = tensor(e, 500, 44);
        let (_, s1) = BlockDoms::with_partition(1, 1).unwrap().search_subm(&t, 3);
        let (_, s2) = BlockDoms::with_partition(4, 8).unwrap().search_subm(&t, 3);
        assert_eq!(s1.table_bytes, 10 * 4);
        assert_eq!(s2.table_bytes, 32 * 10 * 4);
    }
}
