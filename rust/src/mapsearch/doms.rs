//! DOMS — Depth-encoding-based Output Major Search (§3.1B-C, Fig. 3).
//!
//! The insight: an output voxel Q = (x₀, y₀, z₀) never needs two whole
//! depths — its positive-half search space is exactly
//!
//! * rows `y₀ .. y₀+1` at depth `z₀` (same-depth forward offsets), and
//! * rows `y₀-1 .. y₀+1` at depth `z₀+1` (next-depth offsets),
//!
//! and with a **depth-encoding table** holding each depth's start pointer,
//! those rows can be fetched directly from DRAM. Two row-FIFO buffers
//! (I: current depth, II: next depth) slide down the rows of a depth;
//! margin rows are reused between consecutive outputs, so each voxel is
//! loaded at most twice (once serving outputs of depth z-1, once serving
//! depth z) → stable O(2N). If a FIFO can hold an entire depth, buffer II
//! is *adopted* as buffer I on the depth advance and access drops to O(N).
//!
//! This module is a behavioral simulation of that exact schedule: the
//! reads counted are the reads the Fig. 7 map-search core would issue.

use crate::geom::KernelOffsets;
use crate::mapsearch::buffer::RowFifo;
use crate::mapsearch::output_major::emit_output_pairs_rows;
use crate::mapsearch::table::DepthTable;
use crate::mapsearch::{AccessStats, MapSearch};
use crate::sparse::rulebook::{ConvKind, Rulebook};
use crate::sparse::tensor::SparseTensor;

#[derive(Clone, Debug)]
pub struct Doms {
    /// Capacity of each row-FIFO buffer, in voxels (paper: 64, matching
    /// the merge-sorter length).
    pub fifo_voxels: usize,
    /// Merge-sorter length.
    pub sorter_len: usize,
}

impl Default for Doms {
    fn default() -> Self {
        Self {
            fifo_voxels: 64,
            sorter_len: 64,
        }
    }
}

impl Doms {
    /// Sorter passes for one output: its 5-row window streams through the
    /// fixed network alongside the 14 query positions.
    fn sorter_passes_for(&self, window: usize, queries: usize) -> u64 {
        let payload = window + queries;
        payload.div_ceil(self.sorter_len).max(1) as u64
    }
}

impl MapSearch for Doms {
    fn name(&self) -> &'static str {
        "DOMS"
    }

    fn search_subm(&self, input: &SparseTensor, k: usize) -> (Rulebook, AccessStats) {
        assert_eq!(k, 3, "DOMS row-window model is calibrated for subm3");
        let offs = KernelOffsets::centered(k);
        let dt = DepthTable::build(input);
        let qpo = offs.search_half().len(); // 14
        let mut stats = AccessStats {
            table_bytes: dt.table_bytes(),
            ..Default::default()
        };
        let mut buf_i = RowFifo::new(self.fifo_voxels); // depth z rows
        let mut buf_ii = RowFifo::new(self.fifo_voxels); // depth z+1 rows
        // Subm3 on LiDAR-like data averages ~4-8 pairs/voxel; presizing
        // avoids repeated reallocation of the dominant output vector.
        let mut pairs = Vec::with_capacity(input.len() * 8);

        let depths = input.extent.z as i32;
        for z in 0..depths {
            let len_z = dt.depth_len(z);
            if len_z == 0 {
                buf_i.clear();
                buf_ii.clear();
                continue;
            }
            // Depth advance (Fig. 3 end): buffer II's rows (depth z) become
            // buffer I's working set without re-reading DRAM — the O(N)
            // optimization — as long as the depth can fit at all.
            if len_z <= self.fifo_voxels {
                buf_i.adopt(&mut buf_ii);
            } else {
                buf_i.clear();
                buf_ii.clear();
            }

            // Outputs advance row-major within the depth (Step 2-4).
            let start = dt.starts[z as usize];
            let end = dt.starts[z as usize + 1];
            let mut o = start;
            while o < end {
                let y0 = input.coords[o].y;
                // All outputs of row (z, y0) share the same 5-row window;
                // process the row as one scheduling step.
                let row_end = {
                    let mut j = o;
                    while j < end && input.coords[j].y == y0 {
                        j += 1;
                    }
                    j
                };
                // Rows y0, y0+1 at depth z into buffer I.
                let mut window = 0usize;
                for dy in 0..=1 {
                    let (_, rl) = dt.row(z, y0 + dy);
                    stats.voxel_reads += buf_i.ensure((z, (y0 + dy) as i64), rl);
                    window += rl;
                }
                // Rows y0-1 .. y0+1 at depth z+1 into buffer II (located
                // via the depth-encoding table).
                if z + 1 < depths {
                    for dy in -1..=1 {
                        let (_, rl) = dt.row(z + 1, y0 + dy);
                        stats.voxel_reads += buf_ii.ensure((z + 1, (y0 + dy) as i64), rl);
                        window += rl;
                    }
                }
                // One sorter schedule per output in this row.
                for o_i in o..row_end {
                    stats.sorter_passes += self.sorter_passes_for(window, qpo);
                    emit_output_pairs_rows(input, &dt, o_i, &mut pairs);
                }
                o = row_end;
            }
        }

        let l = self.sorter_len;
        stats.sorter_compares = stats.sorter_passes
            * (l / 2 * (l.ilog2() as usize * (l.ilog2() as usize + 1) / 2)) as u64;

        let mut rb = Rulebook {
            kind: ConvKind::Submanifold { k },
            pairs,
            out_coords: input.coords.clone(),
            out_extent: input.extent,
        };
        rb.canonicalize();
        (rb, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Extent3;
    use crate::mapsearch::OutputMajor;
    use crate::pointcloud::voxelize::Voxelizer;
    use crate::sparse::hash_map_search;
    use crate::testing::prop::check;

    fn tensor(e: Extent3, sparsity: f64, seed: u64) -> SparseTensor {
        let g = Voxelizer::synth_occupancy(e, sparsity, seed);
        SparseTensor::from_coords(e, g.coords(), 1)
    }

    #[test]
    fn matches_hash_oracle() {
        let t = tensor(Extent3::new(24, 24, 8), 0.05, 31);
        let (rb, _) = Doms::default().search_subm(&t, 3);
        let want = hash_map_search(&t, ConvKind::subm3());
        assert_eq!(rb.pairs, want.pairs);
    }

    #[test]
    fn matches_hash_oracle_prop() {
        check("DOMS == hash oracle", 15, |g| {
            let e = Extent3::new(g.usize(4, 24), g.usize(4, 24), g.usize(2, 10));
            let t = tensor(e, g.f64(0.01, 0.35), g.usize(0, 1 << 30) as u64);
            let (rb, _) = Doms::default().search_subm(&t, 3);
            let want = hash_map_search(&t, ConvKind::subm3());
            assert_eq!(rb.pairs, want.pairs);
        });
    }

    #[test]
    fn access_bounded_by_2n_when_rows_fit() {
        // Dense case that breaks MARS (two-depth windows >> 64) but whose
        // individual rows fit the FIFOs: DOMS stays at <= ~2N.
        let t = tensor(Extent3::new(64, 64, 8), 0.10, 32);
        let (_, doms) = Doms::default().search_subm(&t, 3);
        let norm = doms.normalized(t.len());
        assert!(norm <= 2.2, "DOMS should be ~O(2N), got {norm}x");
        let (_, mars) = OutputMajor::default().search_subm(&t, 3);
        assert!(
            mars.normalized(t.len()) > 5.0 * norm,
            "MARS should deteriorate far beyond DOMS here"
        );
    }

    #[test]
    fn whole_depth_fifo_gives_o_n() {
        let t = tensor(Extent3::new(32, 32, 8), 0.02, 33);
        // FIFO big enough for any whole depth.
        let big = Doms {
            fifo_voxels: 100_000,
            sorter_len: 64,
        };
        let (_, stats) = big.search_subm(&t, 3);
        let norm = stats.normalized(t.len());
        assert!(norm <= 1.05, "expected O(N), got {norm}x");
    }

    #[test]
    fn table_bytes_one_pointer_per_depth() {
        let t = tensor(Extent3::new(16, 16, 10), 0.05, 34);
        let (_, stats) = Doms::default().search_subm(&t, 3);
        assert_eq!(stats.table_bytes, 10 * 4);
    }

    #[test]
    fn stable_across_density_prop() {
        // The paper's headline claim: normalized access stays O(2N)-ish
        // regardless of sparsity, as long as single rows fit the FIFO.
        check("DOMS stable O(2N)", 8, |g| {
            let e = Extent3::new(48, 48, 8);
            let t = tensor(e, g.f64(0.005, 0.12), g.usize(0, 1 << 30) as u64);
            let (_, stats) = Doms::default().search_subm(&t, 3);
            let norm = stats.normalized(t.len());
            assert!(norm <= 2.5, "sparsity broke DOMS: {norm}x");
        });
    }
}
