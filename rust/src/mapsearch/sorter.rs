//! Bitonic merge-sorter model (Fig. 7, map search core).
//!
//! The hardware is a fixed-length (64) bitonic sorting network followed by
//! a comparator-based intersection detector that compares the three
//! coordinates of adjacent items in parallel. We implement the actual
//! bitonic network (so the comparator count is the real O(L·log²L) cost,
//! not a formula) and count invocations + comparator ops; these feed the
//! map-search latency model.

use crate::geom::Coord3;

/// Fixed-length bitonic merge sorter.
#[derive(Clone, Debug)]
pub struct MergeSorter {
    /// Network length (power of two). The paper's design uses 64.
    pub length: usize,
    pub passes: u64,
    pub compares: u64,
}

/// Tag distinguishing "input voxel" items from "output adjacent position"
/// items inside the sorter stream.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Item {
    /// An input voxel coordinate, carrying its index in the tensor.
    Input(Coord3, u32),
    /// A candidate adjacent position of output `out`, for offset index
    /// `offset`.
    Query(Coord3, u32, u16),
}

impl Item {
    #[inline]
    fn key(&self) -> (Coord3, u8) {
        // Inputs sort before queries at equal coordinates so the
        // intersection detector sees Input immediately followed by its
        // matching Query items.
        match self {
            Item::Input(c, _) => (*c, 0),
            Item::Query(c, _, _) => (*c, 1),
        }
    }

    #[inline]
    pub fn coord(&self) -> Coord3 {
        match self {
            Item::Input(c, _) | Item::Query(c, _, _) => *c,
        }
    }
}

/// One detected intersection: (input index, output index, offset index).
pub type Match = (u32, u32, u16);

impl MergeSorter {
    pub fn new(length: usize) -> Self {
        assert!(length.is_power_of_two(), "bitonic network needs 2^k length");
        Self {
            length,
            passes: 0,
            compares: 0,
        }
    }

    /// The paper's configuration.
    pub fn paper_default() -> Self {
        Self::new(64)
    }

    /// Sort up to `length` items with the bitonic network (shorter inputs
    /// are padded with sentinels, as real fixed networks do) and return
    /// all Input/Query coordinate matches.
    pub fn sort_and_detect(&mut self, items: &[Item]) -> Vec<Match> {
        assert!(
            items.len() <= self.length,
            "stream of {} exceeds sorter length {}",
            items.len(),
            self.length
        );
        self.passes += 1;
        // Pad to the fixed network length with +inf sentinels.
        let sentinel = Item::Input(Coord3::new(i32::MAX, i32::MAX, i32::MAX), u32::MAX);
        let mut buf: Vec<Item> = Vec::with_capacity(self.length);
        buf.extend_from_slice(items);
        buf.resize(self.length, sentinel);
        self.bitonic_sort(&mut buf);
        // Intersection detector: a run of equal coordinates contains at
        // most one Input (coords are unique) followed by its Queries.
        let mut matches = Vec::new();
        let mut i = 0;
        while i < buf.len() {
            let c = buf[i].coord();
            if c.x == i32::MAX {
                break; // sentinels
            }
            let mut j = i;
            let mut input_idx: Option<u32> = None;
            while j < buf.len() && buf[j].coord() == c {
                if let Item::Input(_, idx) = buf[j] {
                    input_idx = Some(idx);
                }
                j += 1;
            }
            if let Some(idx) = input_idx {
                for item in &buf[i..j] {
                    if let Item::Query(_, out, off) = *item {
                        matches.push((idx, out, off));
                    }
                }
            }
            i = j;
        }
        matches
    }

    /// In-place bitonic sort, counting comparator operations.
    fn bitonic_sort(&mut self, buf: &mut [Item]) {
        let n = buf.len();
        let mut k = 2;
        while k <= n {
            let mut j = k / 2;
            while j > 0 {
                for i in 0..n {
                    let l = i ^ j;
                    if l > i {
                        self.compares += 1;
                        let up = (i & k) == 0;
                        if (buf[i].key() > buf[l].key()) == up {
                            buf.swap(i, l);
                        }
                    }
                }
                j /= 2;
            }
            k *= 2;
        }
    }

    pub fn reset_counters(&mut self) {
        self.passes = 0;
        self.compares = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::check;
    use crate::util::rng::Pcg64;

    #[test]
    fn sorts_and_detects_simple_match() {
        let mut s = MergeSorter::new(8);
        let items = vec![
            Item::Query(Coord3::new(1, 1, 1), 7, 3),
            Item::Input(Coord3::new(2, 2, 2), 0),
            Item::Input(Coord3::new(1, 1, 1), 5),
            Item::Query(Coord3::new(9, 9, 9), 7, 4),
        ];
        let m = s.sort_and_detect(&items);
        assert_eq!(m, vec![(5, 7, 3)]);
        assert_eq!(s.passes, 1);
    }

    #[test]
    fn comparator_count_is_network_size() {
        // Bitonic network on n elements: n/2 * log2(n) * (log2(n)+1) / 2
        // comparators per pass.
        let mut s = MergeSorter::new(64);
        let _ = s.sort_and_detect(&[]);
        let want = 64 / 2 * (6 * 7 / 2);
        assert_eq!(s.compares, want as u64);
    }

    #[test]
    #[should_panic]
    fn overlong_stream_panics() {
        let mut s = MergeSorter::new(4);
        let items = vec![Item::Input(Coord3::new(0, 0, 0), 0); 5];
        let _ = s.sort_and_detect(&items);
    }

    #[test]
    fn detect_matches_prop() {
        check("sorter detects exactly the coordinate matches", 50, |g| {
            let mut s = MergeSorter::new(64);
            let mut rng = Pcg64::new(g.usize(0, 1 << 30) as u64);
            // Unique input coords.
            let mut inputs = std::collections::HashSet::new();
            let n_in = g.usize(0, 20);
            while inputs.len() < n_in {
                inputs.insert(Coord3::new(
                    rng.range(0, 6) as i32,
                    rng.range(0, 6) as i32,
                    rng.range(0, 6) as i32,
                ));
            }
            let inputs: Vec<Coord3> = inputs.into_iter().collect();
            let n_q = g.usize(0, 30);
            let mut items: Vec<Item> = inputs
                .iter()
                .enumerate()
                .map(|(i, &c)| Item::Input(c, i as u32))
                .collect();
            let mut queries = Vec::new();
            for qi in 0..n_q {
                let c = Coord3::new(
                    rng.range(0, 6) as i32,
                    rng.range(0, 6) as i32,
                    rng.range(0, 6) as i32,
                );
                items.push(Item::Query(c, qi as u32, 0));
                queries.push(c);
            }
            let got = {
                let mut m = s.sort_and_detect(&items);
                m.sort();
                m
            };
            let mut want: Vec<Match> = Vec::new();
            for (qi, qc) in queries.iter().enumerate() {
                if let Some(ii) = inputs.iter().position(|c| c == qc) {
                    want.push((ii as u32, qi as u32, 0));
                }
            }
            want.sort();
            assert_eq!(got, want);
        });
    }
}
