//! Weight-major map search — the PointAcc [13] baseline.
//!
//! For every kernel offset δ, the whole input coordinate stream is loaded
//! from DRAM, shifted by δ, and merge-intersected against the output
//! coordinates. The on-chip buffer cannot hold all voxels, so each of the
//! K³ weights pays a full O(N) stream: O(K³·N) off-chip access — the
//! paper's challenge (1).

use crate::mapsearch::sorter::MergeSorter;
use crate::mapsearch::{AccessStats, MapSearch};
use crate::sparse::rulebook::{ConvKind, Rulebook, RulePair};
use crate::sparse::tensor::SparseTensor;

#[derive(Clone, Debug)]
pub struct WeightMajor {
    /// Merge-sorter length (both streams pass through it in chunks).
    pub sorter_len: usize,
}

impl Default for WeightMajor {
    fn default() -> Self {
        Self { sorter_len: 64 }
    }
}

impl MapSearch for WeightMajor {
    fn name(&self) -> &'static str {
        "weight-major (PointAcc)"
    }

    fn search_subm(&self, input: &SparseTensor, k: usize) -> (Rulebook, AccessStats) {
        let offs = crate::geom::KernelOffsets::centered(k);
        let n = input.len() as u64;
        let mut pairs = Vec::new();
        let mut sorter = MergeSorter::new(self.sorter_len);
        let mut stats = AccessStats::default();

        for (d, &delta) in offs.offsets.iter().enumerate() {
            // One full DRAM pass of the input coordinates per weight. The
            // output list is identical to the input list for submanifold
            // conv and is streamed from on-chip storage built during this
            // pass in PointAcc; we follow the paper's O(K³N) accounting
            // and charge the input stream only.
            stats.voxel_reads += n;
            // Functional intersection: output Q pairs with input P = Q + δ.
            for (o, &q) in input.coords.iter().enumerate() {
                let p = q.offset(delta);
                if !p.in_bounds(input.extent) {
                    continue;
                }
                if let Some(i) = input.find(p) {
                    pairs.push(RulePair {
                        offset: d as u16,
                        input: i as u32,
                        output: o as u32,
                    });
                }
            }
            // Sorter cost: both streams (shifted inputs + outputs) pass
            // through the fixed-length network in chunks of L/2 + L/2.
            let chunk = (self.sorter_len / 2).max(1);
            let passes = (input.len() + chunk - 1) / chunk.max(1);
            for _ in 0..passes {
                sorter.passes += 1;
                sorter.compares += (self.sorter_len / 2
                    * (self.sorter_len.ilog2() as usize
                        * (self.sorter_len.ilog2() as usize + 1)
                        / 2)) as u64;
            }
        }
        stats.sorter_passes = sorter.passes;
        stats.sorter_compares = sorter.compares;

        let mut rb = Rulebook {
            kind: ConvKind::Submanifold { k },
            pairs,
            out_coords: input.coords.clone(),
            out_extent: input.extent,
        };
        rb.canonicalize();
        (rb, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Extent3;
    use crate::pointcloud::voxelize::Voxelizer;
    use crate::sparse::hash_map_search;

    fn tensor(e: Extent3, sparsity: f64, seed: u64) -> SparseTensor {
        let g = Voxelizer::synth_occupancy(e, sparsity, seed);
        SparseTensor::from_coords(e, g.coords(), 1)
    }

    #[test]
    fn matches_hash_oracle() {
        let t = tensor(Extent3::new(20, 20, 6), 0.05, 11);
        let (rb, _) = WeightMajor::default().search_subm(&t, 3);
        let want = hash_map_search(&t, ConvKind::subm3());
        assert_eq!(rb.pairs, want.pairs);
        assert_eq!(rb.out_coords, want.out_coords);
    }

    #[test]
    fn access_is_k3_times_n() {
        let t = tensor(Extent3::new(16, 16, 8), 0.03, 12);
        let (_, stats) = WeightMajor::default().search_subm(&t, 3);
        assert_eq!(stats.voxel_reads, 27 * t.len() as u64);
        assert!((stats.normalized(t.len()) - 27.0).abs() < 1e-9);
    }

    #[test]
    fn no_table_storage() {
        let t = tensor(Extent3::new(8, 8, 4), 0.1, 13);
        let (_, stats) = WeightMajor::default().search_subm(&t, 3);
        assert_eq!(stats.table_bytes, 0);
    }
}
