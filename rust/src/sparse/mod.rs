//! Sparse-tensor substrate: coordinates + features, the IN-OUT map
//! ("rulebook") that drives sparse convolution, and a hash-table map
//! search that serves as the golden oracle for every searcher in
//! [`crate::mapsearch`].

pub mod hash_search;
pub mod rulebook;
pub mod tensor;

pub use hash_search::hash_map_search;
pub use rulebook::{ConvKind, Rulebook, RulePair};
pub use tensor::SparseTensor;
