//! Sparse tensor: the paper's Eq. (1) — a coordinate list `P` (depth-major
//! sorted) plus a dense feature matrix `F` of shape `[N, C]` (int8 on the
//! request path).

use crate::geom::{Coord3, Extent3};

/// A sparse voxel tensor. Coordinates are unique and sorted depth-major
/// (z, y, x); `features` is row-major `[len, channels]`.
#[derive(Clone, Debug)]
pub struct SparseTensor {
    pub extent: Extent3,
    pub coords: Vec<Coord3>,
    pub features: Vec<i8>,
    pub channels: usize,
}

impl SparseTensor {
    /// Build from unsorted, possibly duplicated coordinates. Duplicate
    /// coordinates keep the first occurrence's features.
    pub fn new(
        extent: Extent3,
        mut pairs: Vec<(Coord3, Vec<i8>)>,
        channels: usize,
    ) -> Self {
        pairs.sort_by_key(|(c, _)| *c);
        pairs.dedup_by_key(|(c, _)| *c);
        let mut coords = Vec::with_capacity(pairs.len());
        let mut features = Vec::with_capacity(pairs.len() * channels);
        for (c, f) in pairs {
            assert_eq!(f.len(), channels, "feature width mismatch at {c:?}");
            coords.push(c);
            features.extend_from_slice(&f);
        }
        Self {
            extent,
            coords,
            features,
            channels,
        }
    }

    /// Coordinates-only constructor (features zeroed) — used by map-search
    /// sweeps where only geometry matters.
    pub fn from_coords(extent: Extent3, mut coords: Vec<Coord3>, channels: usize) -> Self {
        coords.sort();
        coords.dedup();
        let features = vec![0i8; coords.len() * channels];
        Self {
            extent,
            coords,
            features,
            channels,
        }
    }

    pub fn len(&self) -> usize {
        self.coords.len()
    }

    pub fn is_empty(&self) -> bool {
        self.coords.is_empty()
    }

    /// Feature row of voxel `i`.
    #[inline]
    pub fn feature(&self, i: usize) -> &[i8] {
        &self.features[i * self.channels..(i + 1) * self.channels]
    }

    #[inline]
    pub fn feature_mut(&mut self, i: usize) -> &mut [i8] {
        &mut self.features[i * self.channels..(i + 1) * self.channels]
    }

    /// Binary search for a coordinate (valid because coords are sorted).
    #[inline]
    pub fn find(&self, c: Coord3) -> Option<usize> {
        self.coords.binary_search(&c).ok()
    }

    /// Start index of each depth (z value) in `coords` — the off-chip
    /// layout the DOMS depth-encoding table points into. Returned vec has
    /// `extent.z + 1` entries; depth z occupies `coords[v[z]..v[z+1]]`.
    pub fn depth_starts(&self) -> Vec<usize> {
        let mut starts = vec![0usize; self.extent.z + 1];
        let mut zi = 0usize;
        for (i, c) in self.coords.iter().enumerate() {
            while zi <= c.z as usize {
                starts[zi] = i;
                zi += 1;
            }
        }
        while zi <= self.extent.z {
            starts[zi] = self.coords.len();
            zi += 1;
        }
        starts
    }

    /// Verify sortedness/uniqueness (used by tests and debug assertions).
    pub fn check_canonical(&self) -> bool {
        self.coords.windows(2).all(|w| w[0] < w[1])
            && self.features.len() == self.coords.len() * self.channels
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::check;

    #[test]
    fn new_sorts_and_dedups() {
        let e = Extent3::new(4, 4, 4);
        let t = SparseTensor::new(
            e,
            vec![
                (Coord3::new(3, 3, 3), vec![1, 1]),
                (Coord3::new(0, 0, 0), vec![2, 2]),
                (Coord3::new(3, 3, 3), vec![9, 9]),
            ],
            2,
        );
        assert_eq!(t.len(), 2);
        assert!(t.check_canonical());
        assert_eq!(t.coords[0], Coord3::new(0, 0, 0));
        assert_eq!(t.feature(1), &[1, 1]); // first occurrence wins
    }

    #[test]
    fn find_works() {
        let e = Extent3::new(8, 8, 8);
        let t = SparseTensor::from_coords(
            e,
            vec![Coord3::new(1, 2, 3), Coord3::new(4, 5, 6)],
            1,
        );
        assert_eq!(t.find(Coord3::new(1, 2, 3)), Some(0));
        assert_eq!(t.find(Coord3::new(4, 5, 6)), Some(1));
        assert_eq!(t.find(Coord3::new(0, 0, 0)), None);
    }

    #[test]
    fn depth_starts_partition() {
        let e = Extent3::new(4, 4, 3);
        let t = SparseTensor::from_coords(
            e,
            vec![
                Coord3::new(0, 0, 0),
                Coord3::new(1, 0, 0),
                Coord3::new(0, 0, 2),
            ],
            1,
        );
        let s = t.depth_starts();
        assert_eq!(s, vec![0, 2, 2, 3]);
        // depth 0 -> [0,2), depth 1 -> [2,2) empty, depth 2 -> [2,3)
    }

    #[test]
    fn depth_starts_prop() {
        check("depth starts partition coords", 50, |g| {
            let e = Extent3::new(8, 8, g.usize(1, 8));
            let coords = g.vec(0, 64, |g| {
                Coord3::new(
                    g.i32(0, 8),
                    g.i32(0, 8),
                    g.i32(0, e.z as i32),
                )
            });
            let t = SparseTensor::from_coords(e, coords, 1);
            let s = t.depth_starts();
            assert_eq!(s.len(), e.z + 1);
            assert_eq!(*s.last().unwrap(), t.len());
            for z in 0..e.z {
                for i in s[z]..s[z + 1] {
                    assert_eq!(t.coords[i].z as usize, z);
                }
            }
        });
    }
}
