//! The IN-OUT map ("rulebook"): the paper's `M(j) = {(P_i, Q_j, W_δ)}`.
//!
//! Every map-search implementation produces a [`Rulebook`]; canonical form
//! (sorted pairs) makes cross-implementation equality testable, and the
//! per-offset grouping is exactly what the weight-stationary CIM dataflow
//! consumes (gather all inputs of offset δ, MAC against sub-matrix W_δ,
//! scatter to outputs).

use crate::geom::{Coord3, Extent3, KernelOffsets, Offset3};
use crate::sparse::tensor::SparseTensor;

/// One IN-OUT pair: input voxel index, output voxel index, offset index.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub struct RulePair {
    pub offset: u16,
    pub input: u32,
    pub output: u32,
}

/// Which of the three Spconv3D flavors a rulebook describes (§2B).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ConvKind {
    /// Submanifold: outputs = inputs (subm3: K=3, stride 1).
    Submanifold { k: usize },
    /// Generalized: output valid if any input in kernel range (gconv2:
    /// K=2, stride 2).
    Generalized { k: usize, stride: usize },
    /// Transposed: reverse of generalized (upsampling).
    Transposed { k: usize, stride: usize },
}

impl ConvKind {
    pub fn subm3() -> Self {
        ConvKind::Submanifold { k: 3 }
    }
    pub fn gconv2() -> Self {
        ConvKind::Generalized { k: 2, stride: 2 }
    }
    pub fn tconv2() -> Self {
        ConvKind::Transposed { k: 2, stride: 2 }
    }

    pub fn kernel_volume(&self) -> usize {
        match self {
            ConvKind::Submanifold { k } => k * k * k,
            ConvKind::Generalized { k, .. } | ConvKind::Transposed { k, .. } => k * k * k,
        }
    }
}

/// The rulebook plus the output coordinate set it maps onto.
#[derive(Clone, Debug)]
pub struct Rulebook {
    pub kind: ConvKind,
    pub pairs: Vec<RulePair>,
    /// Output coordinates, sorted depth-major; `RulePair::output` indexes
    /// into this.
    pub out_coords: Vec<Coord3>,
    pub out_extent: Extent3,
}

impl Rulebook {
    /// Canonicalize: sort pairs (offset-major, then output, then input).
    pub fn canonicalize(&mut self) {
        // Unstable sort: RulePair is Copy and duplicates are removed, so
        // stability buys nothing; this is on the map-search hot path.
        self.pairs.sort_unstable();
        self.pairs.dedup();
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    /// Pair count per offset index — the W2B workload histogram
    /// (Fig. 6a).
    pub fn workload_per_offset(&self) -> Vec<u64> {
        let mut w = vec![0u64; self.kind.kernel_volume()];
        for p in &self.pairs {
            w[p.offset as usize] += 1;
        }
        w
    }

    /// Element-wise sum of several rulebooks' per-offset workloads — the
    /// group-level histogram the scheduler feeds to W2B allocation when
    /// in-flight frames (or scene shards) share one GEMM wave schedule.
    pub fn combined_workload<'a>(rbs: impl IntoIterator<Item = &'a Rulebook>) -> Vec<u64> {
        let mut acc: Vec<u64> = Vec::new();
        for rb in rbs {
            let w = rb.workload_per_offset();
            if acc.is_empty() {
                acc = w;
            } else {
                debug_assert_eq!(acc.len(), w.len(), "mixed kernels in one group");
                for (a, b) in acc.iter_mut().zip(w) {
                    *a += b;
                }
            }
        }
        acc
    }

    /// Group pair indices by offset (weight-stationary gather order).
    pub fn pairs_by_offset(&self) -> Vec<Vec<RulePair>> {
        let mut groups = vec![Vec::new(); self.kind.kernel_volume()];
        for p in &self.pairs {
            groups[p.offset as usize].push(*p);
        }
        groups
    }

    /// Group pairs by a caller-defined bin of their *output* coordinate
    /// (e.g. a block id) — how the temporal delta cache extracts
    /// per-block rulebook fragments. Bins preserve canonical pair order,
    /// so re-concatenating all bins and canonicalizing reproduces
    /// `self.pairs` exactly.
    pub fn pairs_by_output_bin(
        &self,
        nbins: usize,
        bin: impl Fn(Coord3) -> usize,
    ) -> Vec<Vec<RulePair>> {
        let mut groups = vec![Vec::new(); nbins];
        for p in &self.pairs {
            let b = bin(self.out_coords[p.output as usize]);
            debug_assert!(b < nbins, "output bin {b} out of range");
            groups[b].push(*p);
        }
        groups
    }

    /// Check structural invariants against the input tensor (used by the
    /// property tests): indices in range, offsets consistent with the
    /// geometry.
    pub fn validate(&self, input: &SparseTensor) -> Result<(), String> {
        let offs = match self.kind {
            ConvKind::Submanifold { k } => KernelOffsets::centered(k).offsets,
            ConvKind::Generalized { k, .. } | ConvKind::Transposed { k, .. } => {
                KernelOffsets::downsample(k).offsets
            }
        };
        for p in &self.pairs {
            let (i, o, d) = (p.input as usize, p.output as usize, p.offset as usize);
            if i >= input.len() {
                return Err(format!("input index {i} out of range"));
            }
            if o >= self.out_coords.len() {
                return Err(format!("output index {o} out of range"));
            }
            if d >= offs.len() {
                return Err(format!("offset index {d} out of range"));
            }
            let pin = input.coords[i];
            let qout = self.out_coords[o];
            let delta: Offset3 = offs[d];
            let ok = match self.kind {
                // Submanifold: P = Q + δ.
                ConvKind::Submanifold { .. } => qout.offset(delta) == pin,
                // Generalized stride-s: P = s*Q + δ.
                ConvKind::Generalized { stride, .. } => {
                    Coord3::new(
                        qout.x * stride as i32 + delta.dx as i32,
                        qout.y * stride as i32 + delta.dy as i32,
                        qout.z * stride as i32 + delta.dz as i32,
                    ) == pin
                }
                // Transposed stride-s: Q = s*P + δ ... reversed roles.
                ConvKind::Transposed { stride, .. } => {
                    Coord3::new(
                        pin.x * stride as i32 + delta.dx as i32,
                        pin.y * stride as i32 + delta.dy as i32,
                        pin.z * stride as i32 + delta.dz as i32,
                    ) == qout
                }
            };
            if !ok {
                return Err(format!(
                    "geometry violated: in={pin:?} out={qout:?} δ={delta:?} kind={:?}",
                    self.kind
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_histogram_counts() {
        let rb = Rulebook {
            kind: ConvKind::subm3(),
            pairs: vec![
                RulePair { offset: 13, input: 0, output: 0 },
                RulePair { offset: 13, input: 1, output: 1 },
                RulePair { offset: 0, input: 1, output: 0 },
            ],
            out_coords: vec![Coord3::new(0, 0, 0), Coord3::new(1, 0, 0)],
            out_extent: Extent3::new(2, 1, 1),
        };
        let w = rb.workload_per_offset();
        assert_eq!(w.len(), 27);
        assert_eq!(w[13], 2);
        assert_eq!(w[0], 1);
        assert_eq!(w.iter().sum::<u64>(), 3);
    }

    #[test]
    fn combined_workload_sums_across_frames() {
        let rb = |n: u32| Rulebook {
            kind: ConvKind::subm3(),
            pairs: (0..n)
                .map(|i| RulePair { offset: 13, input: i, output: i })
                .collect(),
            out_coords: (0..n as i32).map(|i| Coord3::new(i, 0, 0)).collect(),
            out_extent: Extent3::new(64, 1, 1),
        };
        let (a, b) = (rb(3), rb(5));
        let w = Rulebook::combined_workload([&a, &b]);
        assert_eq!(w.len(), 27);
        assert_eq!(w[13], 8);
        assert_eq!(w.iter().sum::<u64>(), 8);
        assert!(Rulebook::combined_workload(std::iter::empty::<&Rulebook>()).is_empty());
    }

    #[test]
    fn canonicalize_sorts_and_dedups() {
        let mut rb = Rulebook {
            kind: ConvKind::subm3(),
            pairs: vec![
                RulePair { offset: 5, input: 1, output: 1 },
                RulePair { offset: 1, input: 0, output: 0 },
                RulePair { offset: 5, input: 1, output: 1 },
            ],
            out_coords: vec![Coord3::new(0, 0, 0), Coord3::new(1, 0, 0)],
            out_extent: Extent3::new(2, 1, 1),
        };
        rb.canonicalize();
        assert_eq!(rb.len(), 2);
        assert!(rb.pairs[0] < rb.pairs[1]);
    }

    #[test]
    fn output_bins_partition_canonical_pairs() {
        let mut rb = Rulebook {
            kind: ConvKind::subm3(),
            pairs: vec![
                RulePair { offset: 13, input: 0, output: 0 },
                RulePair { offset: 13, input: 1, output: 1 },
                RulePair { offset: 0, input: 1, output: 0 },
            ],
            out_coords: vec![Coord3::new(0, 0, 0), Coord3::new(3, 0, 0)],
            out_extent: Extent3::new(4, 1, 1),
        };
        rb.canonicalize();
        // Bin by x-half: output 0 -> bin 0, output 1 -> bin 1.
        let bins = rb.pairs_by_output_bin(2, |c| (c.x >= 2) as usize);
        assert_eq!(bins.len(), 2);
        assert_eq!(bins[0].len(), 2);
        assert_eq!(bins[1].len(), 1);
        // Re-concatenating and canonicalizing reproduces the rulebook.
        let mut merged: Vec<RulePair> = bins.into_iter().flatten().collect();
        merged.sort_unstable();
        assert_eq!(merged, rb.pairs);
    }

    #[test]
    fn validate_catches_bad_geometry() {
        let e = Extent3::new(4, 4, 4);
        let t = SparseTensor::from_coords(
            e,
            vec![Coord3::new(0, 0, 0), Coord3::new(1, 0, 0)],
            1,
        );
        let rb = Rulebook {
            kind: ConvKind::subm3(),
            // offset index 13 is the center: requires in == out coord.
            pairs: vec![RulePair { offset: 13, input: 0, output: 1 }],
            out_coords: t.coords.clone(),
            out_extent: e,
        };
        assert!(rb.validate(&t).is_err());
    }
}
