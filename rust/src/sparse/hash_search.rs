//! Table-aided (hash) map search — the golden oracle.
//!
//! This is the classic GPU/table-aided strategy (§1 challenge 1): build a
//! hash table over all input coordinates, then for each output probe its
//! K³ neighbors. O(1) per probe but the table itself is large — the cost
//! the paper's table-free DOMS avoids. Every `mapsearch::*` implementation
//! is property-tested to produce exactly this rulebook.

use std::collections::HashMap;

use crate::geom::{Coord3, Extent3, KernelOffsets};
use crate::sparse::rulebook::{ConvKind, Rulebook, RulePair};
use crate::sparse::tensor::SparseTensor;

/// Build the rulebook for `kind` over `input` with a hash table.
pub fn hash_map_search(input: &SparseTensor, kind: ConvKind) -> Rulebook {
    match kind {
        ConvKind::Submanifold { k } => subm(input, k),
        ConvKind::Generalized { k, stride } => gconv(input, k, stride),
        ConvKind::Transposed { k, stride } => tconv(input, k, stride),
    }
}

fn index_table(coords: &[Coord3]) -> HashMap<Coord3, u32> {
    coords
        .iter()
        .enumerate()
        .map(|(i, &c)| (c, i as u32))
        .collect()
}

fn subm(input: &SparseTensor, k: usize) -> Rulebook {
    let table = index_table(&input.coords);
    let offs = KernelOffsets::centered(k);
    let mut pairs = Vec::new();
    // Submanifold: outputs are exactly the inputs.
    for (o, &q) in input.coords.iter().enumerate() {
        for (d, &delta) in offs.offsets.iter().enumerate() {
            let p = q.offset(delta);
            if !p.in_bounds(input.extent) {
                continue;
            }
            if let Some(&i) = table.get(&p) {
                pairs.push(RulePair {
                    offset: d as u16,
                    input: i,
                    output: o as u32,
                });
            }
        }
    }
    let mut rb = Rulebook {
        kind: ConvKind::Submanifold { k },
        pairs,
        out_coords: input.coords.clone(),
        out_extent: input.extent,
    };
    rb.canonicalize();
    rb
}

fn gconv(input: &SparseTensor, k: usize, stride: usize) -> Rulebook {
    let offs = KernelOffsets::downsample(k);
    let out_extent = Extent3::new(
        input.extent.x.div_ceil(stride),
        input.extent.y.div_ceil(stride),
        input.extent.z.div_ceil(stride),
    );
    // Output active iff any input within its receptive field: for each
    // input P, the output Q = floor(P / s) when K == s (non-overlapping
    // windows); general K >= s handled by iterating candidate Qs.
    let mut out_set: Vec<Coord3> = input
        .coords
        .iter()
        .map(|&p| p.downsample(stride as i32))
        .collect();
    out_set.sort();
    out_set.dedup();
    let out_index: HashMap<Coord3, u32> = out_set
        .iter()
        .enumerate()
        .map(|(i, &c)| (c, i as u32))
        .collect();
    let in_table = index_table(&input.coords);
    let mut pairs = Vec::new();
    for (&q, &o) in &out_index {
        for (d, &delta) in offs.offsets.iter().enumerate() {
            let p = Coord3::new(
                q.x * stride as i32 + delta.dx as i32,
                q.y * stride as i32 + delta.dy as i32,
                q.z * stride as i32 + delta.dz as i32,
            );
            if !p.in_bounds(input.extent) {
                continue;
            }
            if let Some(&i) = in_table.get(&p) {
                pairs.push(RulePair {
                    offset: d as u16,
                    input: i,
                    output: o,
                });
            }
        }
    }
    let mut rb = Rulebook {
        kind: ConvKind::Generalized { k, stride },
        pairs,
        out_coords: out_set,
        out_extent,
    };
    rb.canonicalize();
    rb
}

fn tconv(input: &SparseTensor, k: usize, stride: usize) -> Rulebook {
    let offs = KernelOffsets::downsample(k);
    let out_extent = Extent3::new(
        input.extent.x * stride,
        input.extent.y * stride,
        input.extent.z * stride,
    );
    // Transposed: every input spawns K³ candidate outputs Q = s*P + δ.
    let mut out_set: Vec<Coord3> = Vec::with_capacity(input.len() * offs.len());
    for &p in &input.coords {
        for &delta in &offs.offsets {
            let q = Coord3::new(
                p.x * stride as i32 + delta.dx as i32,
                p.y * stride as i32 + delta.dy as i32,
                p.z * stride as i32 + delta.dz as i32,
            );
            if q.in_bounds(out_extent) {
                out_set.push(q);
            }
        }
    }
    out_set.sort();
    out_set.dedup();
    let out_index: HashMap<Coord3, u32> = out_set
        .iter()
        .enumerate()
        .map(|(i, &c)| (c, i as u32))
        .collect();
    let mut pairs = Vec::new();
    for (i, &p) in input.coords.iter().enumerate() {
        for (d, &delta) in offs.offsets.iter().enumerate() {
            let q = Coord3::new(
                p.x * stride as i32 + delta.dx as i32,
                p.y * stride as i32 + delta.dy as i32,
                p.z * stride as i32 + delta.dz as i32,
            );
            if let Some(&o) = out_index.get(&q) {
                pairs.push(RulePair {
                    offset: d as u16,
                    input: i as u32,
                    output: o,
                });
            }
        }
    }
    let mut rb = Rulebook {
        kind: ConvKind::Transposed { k, stride },
        pairs,
        out_coords: out_set,
        out_extent,
    };
    rb.canonicalize();
    rb
}

/// Transposed conv with UNet skip-connection pruning: outputs are
/// restricted to `target` (the matching encoder stage's coordinate set),
/// exactly how MinkUNet's decoder works — without pruning the coordinate
/// set would dilate 8x per upsampling stage.
pub fn tconv_pruned(
    input: &SparseTensor,
    k: usize,
    stride: usize,
    out_extent: Extent3,
    target: &[Coord3],
) -> Rulebook {
    debug_assert!(target.windows(2).all(|w| w[0] < w[1]), "target must be sorted");
    let offs = KernelOffsets::downsample(k);
    let mut pairs = Vec::new();
    for (i, &p) in input.coords.iter().enumerate() {
        for (d, &delta) in offs.offsets.iter().enumerate() {
            let q = Coord3::new(
                p.x * stride as i32 + delta.dx as i32,
                p.y * stride as i32 + delta.dy as i32,
                p.z * stride as i32 + delta.dz as i32,
            );
            if let Ok(o) = target.binary_search(&q) {
                pairs.push(RulePair {
                    offset: d as u16,
                    input: i as u32,
                    output: o as u32,
                });
            }
        }
    }
    let mut rb = Rulebook {
        kind: ConvKind::Transposed { k, stride },
        pairs,
        out_coords: target.to_vec(),
        out_extent,
    };
    rb.canonicalize();
    rb
}

/// Storage cost of the table-aided approach in bytes (the ">100 MB" the
/// paper's intro cites): a dense bucket array over the voxel space with a
/// 4-byte index per cell.
pub fn hash_table_bytes(extent: Extent3) -> u64 {
    extent.volume() as u64 * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pointcloud::voxelize::Voxelizer;
    use crate::testing::prop::check;

    fn tensor(extent: Extent3, sparsity: f64, seed: u64) -> SparseTensor {
        let g = Voxelizer::synth_occupancy(extent, sparsity, seed);
        SparseTensor::from_coords(extent, g.coords(), 1)
    }

    #[test]
    fn subm_center_pairs_everyone() {
        let t = tensor(Extent3::new(16, 16, 4), 0.05, 1);
        let rb = hash_map_search(&t, ConvKind::subm3());
        rb.validate(&t).unwrap();
        // Center offset (index 13) pairs each voxel with itself.
        let center: Vec<_> = rb.pairs.iter().filter(|p| p.offset == 13).collect();
        assert_eq!(center.len(), t.len());
        assert!(center.iter().all(|p| p.input == p.output));
    }

    #[test]
    fn subm_symmetry() {
        // If (i, o, δ) exists then (o, i, -δ) exists (Fig. 2a).
        let t = tensor(Extent3::new(12, 12, 6), 0.08, 2);
        let rb = hash_map_search(&t, ConvKind::subm3());
        let offs = KernelOffsets::centered(3);
        let set: std::collections::HashSet<(u16, u32, u32)> =
            rb.pairs.iter().map(|p| (p.offset, p.input, p.output)).collect();
        for p in &rb.pairs {
            let neg = offs.offsets[p.offset as usize].negate();
            let nd = offs.index_of(neg).unwrap() as u16;
            assert!(
                set.contains(&(nd, p.output, p.input)),
                "missing reverse of {p:?}"
            );
        }
    }

    #[test]
    fn isolated_voxel_only_center() {
        let e = Extent3::new(9, 9, 9);
        let t = SparseTensor::from_coords(e, vec![Coord3::new(4, 4, 4)], 1);
        let rb = hash_map_search(&t, ConvKind::subm3());
        assert_eq!(rb.len(), 1);
        assert_eq!(rb.pairs[0].offset, 13);
    }

    #[test]
    fn gconv_downsamples() {
        let e = Extent3::new(8, 8, 8);
        let t = SparseTensor::from_coords(
            e,
            vec![
                Coord3::new(0, 0, 0),
                Coord3::new(1, 1, 1), // same 2x2x2 window
                Coord3::new(6, 6, 6),
            ],
            1,
        );
        let rb = hash_map_search(&t, ConvKind::gconv2());
        rb.validate(&t).unwrap();
        assert_eq!(rb.out_coords.len(), 2);
        assert_eq!(rb.len(), 3); // every input pairs exactly once for K=s=2
    }

    #[test]
    fn tconv_reverses_gconv_pairs() {
        let e = Extent3::new(8, 8, 8);
        let t = tensor(e, 0.05, 3);
        let g = hash_map_search(&t, ConvKind::gconv2());
        // Take the downsampled outputs as a new tensor and transpose-conv.
        let down = SparseTensor::from_coords(
            Extent3::new(4, 4, 4),
            g.out_coords.clone(),
            1,
        );
        let up = hash_map_search(&down, ConvKind::tconv2());
        up.validate(&down).unwrap();
        // Every gconv pair (i_fine, o_coarse, δ) has a mirror tconv pair
        // (o_coarse, q_fine=coords[i_fine], δ).
        for p in &g.pairs {
            let fine = t.coords[p.input as usize];
            let coarse = g.out_coords[p.output as usize];
            let ci = down.find(coarse).unwrap() as u32;
            let qo = up.out_coords.binary_search(&fine);
            assert!(qo.is_ok(), "fine coord {fine:?} missing from tconv outputs");
            let qo = qo.unwrap() as u32;
            assert!(
                up.pairs
                    .iter()
                    .any(|u| u.input == ci && u.output == qo && u.offset == p.offset),
                "missing mirror of {p:?}"
            );
        }
    }

    #[test]
    fn tconv_pruned_is_tconv_restricted_to_target() {
        let e = Extent3::new(8, 8, 8);
        let t = tensor(e, 0.08, 5);
        let full = hash_map_search(&t, ConvKind::tconv2());
        // Prune to every other output of the full tconv.
        let target: Vec<Coord3> = full
            .out_coords
            .iter()
            .copied()
            .step_by(2)
            .collect();
        let pruned = tconv_pruned(&t, 2, 2, full.out_extent, &target);
        pruned
            .validate(&t)
            .unwrap();
        assert_eq!(pruned.out_coords, target);
        // Every pruned pair exists in the full rulebook (modulo output
        // re-indexing), and pair count matches the restriction.
        let full_set: std::collections::HashSet<(u16, u32, Coord3)> = full
            .pairs
            .iter()
            .map(|p| (p.offset, p.input, full.out_coords[p.output as usize]))
            .collect();
        for p in &pruned.pairs {
            assert!(full_set
                .contains(&(p.offset, p.input, pruned.out_coords[p.output as usize])));
        }
        let want = full
            .pairs
            .iter()
            .filter(|p| target.binary_search(&full.out_coords[p.output as usize]).is_ok())
            .count();
        assert_eq!(pruned.len(), want);
    }

    #[test]
    fn pair_count_prop_matches_brute_force() {
        check("hash search matches brute force subm3", 10, |g| {
            let e = Extent3::new(g.usize(3, 10), g.usize(3, 10), g.usize(3, 6));
            let t = tensor(e, g.f64(0.02, 0.3), g.usize(0, 1 << 30) as u64);
            let rb = hash_map_search(&t, ConvKind::subm3());
            rb.validate(&t).unwrap();
            // Brute force count.
            let offs = KernelOffsets::centered(3);
            let mut want = 0usize;
            for &q in &t.coords {
                for &d in &offs.offsets {
                    let p = q.offset(d);
                    if p.in_bounds(e) && t.find(p).is_some() {
                        want += 1;
                    }
                }
            }
            assert_eq!(rb.len(), want);
        });
    }
}
