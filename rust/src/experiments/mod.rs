//! Experiment harness: one module per figure/table of the paper's
//! evaluation (§4). Each `run_*` returns printable rows; the `voxel-cim
//! exp <id>` CLI and the bench binaries call these, and EXPERIMENTS.md
//! records paper-vs-measured for each.

pub mod ablations;
pub mod fig11;
pub mod fig2d;
pub mod fig9;
pub mod table2;
pub mod w2b_fig10;

use crate::geom::Extent3;
use crate::pointcloud::voxelize::Voxelizer;
use crate::sparse::tensor::SparseTensor;

/// The paper's two map-search resolutions (Fig. 2d / Fig. 9).
pub const LOW_RES: Extent3 = Extent3::new(352, 400, 10);
pub const HIGH_RES: Extent3 = Extent3::new(1408, 1600, 41);

/// Map-search sweep "sparsity": the paper sweeps the occupancy of LiDAR
/// frames, which are 2.5-D (≈ one return per occupied (x, y) column). We
/// therefore define N = x·y·s occupied voxels spread over the volume —
/// the interpretation under which every published curve (MARS degrading
/// at high resolution, DOMS ~O(2N), block-DOMS@(2,8) ~O(N)) is
/// self-consistent. See EXPERIMENTS.md §Setup.
pub fn sweep_tensor(extent: Extent3, sparsity: f64, seed: u64) -> SparseTensor {
    let n = ((extent.x * extent.y) as f64 * sparsity).round() as usize;
    let vol_sparsity = n as f64 / extent.volume() as f64;
    let g = Voxelizer::synth_occupancy(extent, vol_sparsity, seed);
    SparseTensor::from_coords(extent, g.coords(), 1)
}

/// Clustered variant (Fig. 2b's "dense distributions in partial regions").
pub fn sweep_tensor_clustered(extent: Extent3, sparsity: f64, seed: u64) -> SparseTensor {
    let n = ((extent.x * extent.y) as f64 * sparsity).round() as usize;
    let vol_sparsity = n as f64 / extent.volume() as f64;
    let g = Voxelizer::synth_clustered(extent, vol_sparsity, 8, 0.3, seed);
    SparseTensor::from_coords(extent, g.coords(), 1)
}

/// Simple fixed-width table printer shared by the experiment CLIs.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let line = |cells: Vec<String>| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        println!("{}", s.trim_end());
    };
    line(header.iter().map(|s| s.to_string()).collect());
    line(widths.iter().map(|w| "-".repeat(*w)).collect());
    for row in rows {
        line(row.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_tensor_is_2p5d_scaled() {
        let t = sweep_tensor(LOW_RES, 0.005, 1);
        let expect = (352.0f64 * 400.0 * 0.005).round() as usize;
        assert!((t.len() as i64 - expect as i64).unsigned_abs() < 10);
    }

    #[test]
    fn clustered_same_budget() {
        let a = sweep_tensor(LOW_RES, 0.005, 2);
        let b = sweep_tensor_clustered(LOW_RES, 0.005, 2);
        // Same voxel budget within 20% (cluster rejection sampling).
        let ratio = b.len() as f64 / a.len() as f64;
        assert!(ratio > 0.8 && ratio < 1.2, "ratio {ratio}");
    }
}
