//! E7 — Fig. 11: normalized speedup of Voxel-CIM over the baseline
//! accelerators and GPUs for detection (SECOND/KITTI) and segmentation
//! (MinkUNet/SemanticKITTI). Baseline FPS are the published numbers
//! (sim::baselines); Voxel-CIM's FPS comes from our simulator.

use crate::experiments::print_table;
use crate::mapsearch::Doms;
use crate::model::{minkunet, second};
use crate::pointcloud::voxelize::Voxelizer;
use crate::sim::accelerator::{Accelerator, SimOptions};
use crate::sim::baselines::{BASELINES, GPU_DET_FPS, GPU_SEG_FPS, VOXEL_CIM_PUBLISHED};
use crate::sparse::tensor::SparseTensor;

#[derive(Clone, Debug)]
pub struct Fig11Row {
    pub baseline: String,
    pub task: &'static str,
    pub baseline_fps: f64,
    pub voxelcim_fps: f64,
    pub speedup: f64,
    /// The speedup using the paper's own published Voxel-CIM FPS (shape
    /// check column).
    pub paper_speedup: f64,
}

pub struct Fig11Result {
    pub det_fps: f64,
    pub seg_fps: f64,
    pub rows: Vec<Fig11Row>,
}

pub fn run(seed: u64) -> Fig11Result {
    let acc = Accelerator::default();
    let doms = Doms::default();
    // Detection frame: KITTI-like high-res occupancy.
    let det_net = second::second();
    let gd = Voxelizer::synth_clustered(det_net.extent, 6.0e-4, 10, 0.35, seed);
    let det_in = SparseTensor::from_coords(det_net.extent, gd.coords(), 1);
    // Preprocessing (voxelize + VFE) measured on this CPU by table2; a
    // fixed 1.5 ms is the measured order of magnitude.
    let opts = SimOptions {
        preprocess_seconds: 1.5e-3,
        ..Default::default()
    };
    let det = acc.simulate(&det_net, &det_in, &doms, &opts);

    let seg_net = minkunet::minkunet();
    let gs = Voxelizer::synth_clustered(seg_net.extent, 2.3e-4, 14, 0.3, seed ^ 1);
    let seg_in = SparseTensor::from_coords(seg_net.extent, gs.coords(), 1);
    let seg = acc.simulate(&seg_net, &seg_in, &doms, &opts);

    let mut rows = Vec::new();
    let pub_det = VOXEL_CIM_PUBLISHED.det_fps.unwrap();
    let pub_seg = VOXEL_CIM_PUBLISHED.seg_fps.unwrap();
    for b in BASELINES {
        if let Some(f) = b.det_fps {
            rows.push(Fig11Row {
                baseline: b.name.into(),
                task: "Det",
                baseline_fps: f,
                voxelcim_fps: det.fps(),
                speedup: det.fps() / f,
                paper_speedup: pub_det / f,
            });
        }
        if let Some(f) = b.seg_fps {
            rows.push(Fig11Row {
                baseline: b.name.into(),
                task: "Seg",
                baseline_fps: f,
                voxelcim_fps: seg.fps(),
                speedup: seg.fps() / f,
                paper_speedup: pub_seg / f,
            });
        }
    }
    rows.push(Fig11Row {
        baseline: "GPU 3090Ti".into(),
        task: "Det",
        baseline_fps: GPU_DET_FPS,
        voxelcim_fps: det.fps(),
        speedup: det.fps() / GPU_DET_FPS,
        paper_speedup: pub_det / GPU_DET_FPS,
    });
    rows.push(Fig11Row {
        baseline: "GPU 2080Ti".into(),
        task: "Seg",
        baseline_fps: GPU_SEG_FPS,
        voxelcim_fps: seg.fps(),
        speedup: seg.fps() / GPU_SEG_FPS,
        paper_speedup: pub_seg / GPU_SEG_FPS,
    });
    Fig11Result {
        det_fps: det.fps(),
        seg_fps: seg.fps(),
        rows,
    }
}

pub fn print(r: &Fig11Result) {
    print_table(
        "Fig. 11 — normalized speedup (measured sim vs published baselines)",
        &["baseline", "task", "baseline fps", "Voxel-CIM fps", "speedup", "paper"],
        &r.rows
            .iter()
            .map(|row| {
                vec![
                    row.baseline.clone(),
                    row.task.into(),
                    format!("{:.1}", row.baseline_fps),
                    format!("{:.1}", row.voxelcim_fps),
                    format!("{:.2}x", row.speedup),
                    format!("{:.2}x", row.paper_speedup),
                ]
            })
            .collect::<Vec<_>>(),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_shape() {
        let r = run(31);
        // Detection: Voxel-CIM must beat every detection baseline (the
        // paper's 2.4~5.4x band; we accept winning by >1.2x).
        for row in r.rows.iter().filter(|r| r.task == "Det") {
            assert!(
                row.speedup > 1.2,
                "{}: det speedup {:.2}",
                row.baseline,
                row.speedup
            );
        }
        // Segmentation: beats the GPU and PointAcc/MARS, loses to SpOctA
        // in FPS (the paper concedes exactly this).
        let spocta = r
            .rows
            .iter()
            .find(|x| x.baseline == "SpOctA" && x.task == "Seg")
            .unwrap();
        assert!(spocta.speedup < 1.0, "should lose to SpOctA in seg fps");
        let gpu = r
            .rows
            .iter()
            .find(|x| x.baseline == "GPU 2080Ti")
            .unwrap();
        assert!(gpu.speedup > 2.0, "seg vs GPU speedup {:.2}", gpu.speedup);
    }
}
