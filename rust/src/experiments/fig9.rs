//! E2/E3/E4 — Fig. 9: map-search comparison.
//!
//! (a) low resolution (352x400x10), sparsity sweep: PointAcc / MARS /
//!     DOMS / block-DOMS normalized access volume;
//! (b) high resolution (1408x1600x41): MARS deteriorates, DOMS ~O(2N),
//!     block-DOMS@(2,8) stays ~O(N);
//! (c) the table-size vs access-volume trade-off across block partition
//!     factors at fixed sparsity 0.005.

use crate::experiments::{print_table, sweep_tensor, HIGH_RES, LOW_RES};
use crate::geom::Extent3;
use crate::mapsearch::{BlockDoms, Doms, MapSearch, OutputMajor, WeightMajor};

#[derive(Clone, Debug)]
pub struct Fig9Row {
    pub sparsity: f64,
    pub n_voxels: usize,
    pub pointacc: f64,
    pub mars: f64,
    pub doms: f64,
    pub block_doms: f64,
}

/// Shared sweep for (a) and (b).
pub fn run_sweep(extent: Extent3, sparsities: &[f64], seed: u64) -> Vec<Fig9Row> {
    let wm = WeightMajor::default();
    let om = OutputMajor::default();
    let doms = Doms::default();
    let bd = BlockDoms::default(); // (2, 8), the paper's pick
    sparsities
        .iter()
        .map(|&s| {
            let t = sweep_tensor(extent, s, seed ^ (s * 1e6) as u64);
            let n = t.len();
            let (_, a) = wm.search_subm(&t, 3);
            let (_, b) = om.search_subm(&t, 3);
            let (_, c) = doms.search_subm(&t, 3);
            let (_, d) = bd.search_subm(&t, 3);
            Fig9Row {
                sparsity: s,
                n_voxels: n,
                pointacc: a.normalized(n),
                mars: b.normalized(n),
                doms: c.normalized(n),
                block_doms: d.normalized(n),
            }
        })
        .collect()
}

pub const SPARSITIES: &[f64] = &[0.001, 0.002, 0.005, 0.01, 0.02];

pub fn run_a(seed: u64) -> Vec<Fig9Row> {
    run_sweep(LOW_RES, SPARSITIES, seed)
}

pub fn run_b(seed: u64) -> Vec<Fig9Row> {
    run_sweep(HIGH_RES, SPARSITIES, seed)
}

#[derive(Clone, Debug)]
pub struct Fig9cRow {
    pub partition: (usize, usize),
    pub table_kb: f64,
    pub normalized_access: f64,
    pub replicated_fraction: f64,
}

/// (c): block-partition trade-off at sparsity 0.005, high resolution.
pub fn run_c(seed: u64) -> Vec<Fig9cRow> {
    let t = sweep_tensor(HIGH_RES, 0.005, seed);
    let n = t.len();
    let partitions = [
        (1, 1),
        (1, 2),
        (2, 2),
        (2, 4),
        (2, 8),
        (4, 8),
        (8, 8),
        (8, 16),
        (16, 16),
        (32, 32),
    ];
    partitions
        .iter()
        .map(|&(bx, by)| {
            let bd = BlockDoms::with_partition(bx, by).expect("valid partition");
            let (_, st) = bd.search_subm(&t, 3);
            Fig9cRow {
                partition: (bx, by),
                table_kb: st.table_bytes as f64 / 1024.0,
                normalized_access: st.normalized(n),
                replicated_fraction: st.voxel_writes as f64 / n as f64,
            }
        })
        .collect()
}

pub fn print_sweep(title: &str, rows: &[Fig9Row]) {
    print_table(
        title,
        &["sparsity", "N", "PointAcc", "MARS", "DOMS", "block-DOMS(2,8)"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    format!("{}", r.sparsity),
                    r.n_voxels.to_string(),
                    format!("{:.1}x", r.pointacc),
                    format!("{:.2}x", r.mars),
                    format!("{:.2}x", r.doms),
                    format!("{:.2}x", r.block_doms),
                ]
            })
            .collect::<Vec<_>>(),
    );
}

pub fn print_c(rows: &[Fig9cRow]) {
    print_table(
        "Fig. 9(c) — block partition trade-off @ sparsity 0.005, high res",
        &["partition", "table (KiB)", "access", "replicated"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    format!("({},{})", r.partition.0, r.partition.1),
                    format!("{:.2}", r.table_kb),
                    format!("{:.2}x", r.normalized_access),
                    format!("{:.2}%", r.replicated_fraction * 100.0),
                ]
            })
            .collect::<Vec<_>>(),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9a_low_res_shape() {
        let rows = run_sweep(LOW_RES, &[0.001, 0.01], 11);
        for r in &rows {
            // PointAcc pays ~27N regardless.
            assert!((r.pointacc - 27.0).abs() < 0.5);
            // DOMS and block-DOMS beat PointAcc by an order of magnitude.
            assert!(r.doms < 3.0);
            assert!(r.block_doms < 2.0);
        }
        // MARS: fine when sparse, worse when dense.
        assert!(rows[0].mars < 2.5);
        assert!(rows[1].mars > rows[0].mars);
    }

    #[test]
    fn fig9b_high_res_shape() {
        let rows = run_sweep(HIGH_RES, &[0.005], 12);
        let r = &rows[0];
        // The paper's headline: MARS blows up, DOMS stays in the
        // O(N..2N) band (depths no longer fit the FIFO, so forward rows
        // are double-loaded), block-DOMS @(2,8) recovers ~O(N).
        assert!(r.mars > 5.0, "MARS {:.2}", r.mars);
        assert!(r.doms > 1.2 && r.doms < 2.5, "DOMS {:.2}", r.doms);
        assert!(r.block_doms < 1.25, "block-DOMS {:.2}", r.block_doms);
        assert!(
            r.doms > r.block_doms + 0.15,
            "DOMS {:.2} should exceed block-DOMS {:.2}",
            r.doms,
            r.block_doms
        );
    }

    #[test]
    fn fig9c_tradeoff_shape() {
        let rows = run_c(13);
        // Table size grows monotonically with the block count.
        for w in rows.windows(2) {
            assert!(w[1].table_kb >= w[0].table_kb);
        }
        // Access volume improves from (1,1) to the paper's (2,8)...
        let a11 = rows.iter().find(|r| r.partition == (1, 1)).unwrap();
        let a28 = rows.iter().find(|r| r.partition == (2, 8)).unwrap();
        assert!(a28.normalized_access < a11.normalized_access);
        // ...and replication grows with block count in x.
        let a3232 = rows.iter().find(|r| r.partition == (32, 32)).unwrap();
        assert!(a3232.replicated_fraction > a28.replicated_fraction);
    }
}
