//! E5/E6 — Fig. 6 (workload histograms + copy factors) and Fig. 10
//! (MinkUNet FPS / energy with and without W2B).

use crate::cim::w2b::{w2b_allocate, W2bAllocation};
use crate::experiments::print_table;
use crate::geom::Extent3;
use crate::mapsearch::Doms;
use crate::model::minkunet;
use crate::model::second;
use crate::pointcloud::voxelize::Voxelizer;
use crate::sim::accelerator::{Accelerator, SimOptions, SimReport};
use crate::sparse::rulebook::ConvKind;
use crate::sparse::tensor::SparseTensor;
use crate::sparse::hash_map_search;

/// Fig. 6: the workload histogram of SECOND's first subm3 layer, before
/// and after W2B, plus the copy factors (the paper's Fig. 6c).
pub struct Fig6Result {
    pub workload: Vec<u64>,
    pub alloc: W2bAllocation,
}

pub fn run_fig6(seed: u64) -> Fig6Result {
    // SECOND layer 1 on a LiDAR-like clustered frame at the detection
    // resolution — the skew source is the scene structure itself.
    let extent = Extent3::new(1408, 1600, 41);
    let n = ((extent.x * extent.y) as f64 * 0.005) as usize;
    let g = Voxelizer::synth_clustered(extent, n as f64 / extent.volume() as f64, 10, 0.35, seed);
    let t = SparseTensor::from_coords(extent, g.coords(), 1);
    let rb = hash_map_search(&t, ConvKind::subm3());
    let workload = rb.workload_per_offset();
    let alloc = w2b_allocate(&workload, 54); // 2x the kernel volume
    Fig6Result { workload, alloc }
}

pub fn print_fig6(r: &Fig6Result) {
    let norm = r.alloc.normalized_workload(&r.workload);
    let rows: Vec<Vec<String>> = r
        .workload
        .iter()
        .enumerate()
        .map(|(k, &w)| {
            vec![
                format!("δ[{k}]"),
                w.to_string(),
                r.alloc.copies[k].to_string(),
                format!("{:.0}", norm[k]),
            ]
        })
        .collect();
    print_table(
        "Fig. 6 — per-offset workload, copies (W2B @ 54), normalized workload",
        &["offset", "pairs", "copies", "pairs/copies"],
        &rows,
    );
    let max_w = *r.workload.iter().max().unwrap() as f64;
    let min_w = r.workload.iter().copied().filter(|&w| w > 0).min().unwrap() as f64;
    println!(
        "imbalance before: {:.1}x (max/min) | makespan {} -> {} | speedup {:.2}x",
        max_w / min_w,
        r.alloc.makespan_before,
        r.alloc.makespan_after,
        r.alloc.speedup()
    );
}

/// Fig. 10: MinkUNet with/without W2B — FPS and energy per frame.
pub struct Fig10Result {
    pub with_w2b: SimReport,
    pub without_w2b: SimReport,
}

impl Fig10Result {
    pub fn speedup(&self) -> f64 {
        self.without_w2b.seconds / self.with_w2b.seconds
    }
    pub fn energy_reduction(&self) -> f64 {
        1.0 - self.with_w2b.energy_joules / self.without_w2b.energy_joules
    }
}

pub fn run_fig10(seed: u64) -> Fig10Result {
    let net = minkunet::minkunet();
    // Clustered SemanticKITTI-like occupancy (~120k voxels).
    let g = Voxelizer::synth_clustered(net.extent, 2.3e-4, 14, 0.3, seed);
    let input = SparseTensor::from_coords(net.extent, g.coords(), 1);
    let acc = Accelerator::default();
    let doms = Doms::default();
    let with_w2b = acc.simulate(&net, &input, &doms, &SimOptions::default());
    let without_w2b = acc.simulate(
        &net,
        &input,
        &doms,
        &SimOptions {
            w2b: false,
            ..Default::default()
        },
    );
    Fig10Result {
        with_w2b,
        without_w2b,
    }
}

pub fn print_fig10(r: &Fig10Result) {
    print_table(
        "Fig. 10 — W2B ablation on MinkUNet (segmentation)",
        &["config", "fps", "energy/frame (mJ)"],
        &[
            vec![
                "baseline (no W2B)".into(),
                format!("{:.1}", r.without_w2b.fps()),
                format!("{:.2}", r.without_w2b.energy_joules * 1e3),
            ],
            vec![
                "with W2B".into(),
                format!("{:.1}", r.with_w2b.fps()),
                format!("{:.2}", r.with_w2b.energy_joules * 1e3),
            ],
        ],
    );
    println!(
        "W2B speedup: {:.2}x (paper: 2.3x) | energy reduction: {:.1}% (paper: 6%)",
        r.speedup(),
        r.energy_reduction() * 100.0
    );
}

/// Fig. 6(c) companion: the detection-layer copy factors the paper
/// tabulates, for SECOND L1 specifically.
pub fn second_l1_copy_factors(seed: u64) -> Vec<u32> {
    let _ = second::second();
    run_fig6(seed).alloc.copies
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_shows_large_imbalance_then_flat() {
        let r = run_fig6(21);
        let max_w = *r.workload.iter().max().unwrap();
        let nonzero_min = r.workload.iter().copied().filter(|&w| w > 0).min().unwrap();
        // The paper reports the central/peripheral gap "can even be more
        // than 40x"; a clustered LiDAR-like frame shows a strong skew.
        assert!(
            max_w as f64 / nonzero_min as f64 > 3.0,
            "imbalance too small: {max_w}/{nonzero_min}"
        );
        // After W2B, normalized workload spread is much tighter.
        let norm = r.alloc.normalized_workload(&r.workload);
        let nz: Vec<f64> = norm.iter().copied().filter(|&x| x > 0.0).collect();
        let max_n = nz.iter().cloned().fold(0.0, f64::max);
        let min_n = nz.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(
            max_n / min_n < max_w as f64 / nonzero_min as f64,
            "W2B did not flatten the histogram"
        );
        // Center offset is the most replicated.
        let center_copies = r.alloc.copies[13];
        assert_eq!(
            center_copies,
            *r.alloc.copies.iter().max().unwrap(),
            "center should get the most copies"
        );
    }

    #[test]
    fn fig10_speedup_band() {
        let r = run_fig10(22);
        let s = r.speedup();
        // Paper: 2.3x. Our synthetic SemanticKITTI stand-in has a
        // somewhat stronger center-offset skew than real scans, so we
        // accept a 1.5x..5.5x band; EXPERIMENTS.md records the measured
        // value against the paper's.
        assert!(s > 1.5 && s < 5.5, "W2B speedup {s:.2} out of band");
        let e = r.energy_reduction();
        assert!(e > 0.0 && e < 0.25, "energy reduction {e:.3} out of band");
    }
}
