//! Design-choice ablations beyond the paper's published figures
//! (DESIGN.md §8): FIFO capacity, W2B copy budget, hybrid-pipeline
//! on/off, and the octree table-aided alternative — each isolating one
//! knob of the Voxel-CIM design.

use crate::experiments::{print_table, sweep_tensor, HIGH_RES, LOW_RES};
use crate::cim::w2b::{copies_for_factor, w2b_allocate};
use crate::mapsearch::{Doms, MapSearch, OctreeSearch, SearcherKind};
use crate::model::{minkunet, second};
use crate::pointcloud::voxelize::Voxelizer;
use crate::sim::accelerator::{Accelerator, SimOptions};
use crate::sparse::rulebook::ConvKind;
use crate::sparse::tensor::SparseTensor;
use crate::spconv::gather::{gather_batches_multi_w2b, tile_makespan_rows};

/// Ablation A: DOMS FIFO capacity vs access volume (how much buffer does
/// stability actually need?).
pub fn fifo_sweep(seed: u64) -> Vec<(usize, f64)> {
    let t = sweep_tensor(HIGH_RES, 0.005, seed);
    [16usize, 32, 64, 128, 256, 512, 1024]
        .iter()
        .map(|&cap| {
            let d = Doms {
                fifo_voxels: cap,
                sorter_len: 64,
            };
            let (_, st) = d.search_subm(&t, 3);
            (cap, st.normalized(t.len()))
        })
        .collect()
}

/// Ablation B: W2B copy budget vs achieved speedup on a SECOND L1-like
/// workload (diminishing returns past ~3x the kernel volume).
pub fn w2b_budget_sweep(seed: u64) -> Vec<(u32, f64)> {
    let extent = crate::geom::Extent3::new(1408, 1600, 41);
    let n = ((extent.x * extent.y) as f64 * 0.005) as usize;
    let g = Voxelizer::synth_clustered(extent, n as f64 / extent.volume() as f64, 10, 0.35, seed);
    let t = SparseTensor::from_coords(extent, g.coords(), 1);
    let rb = crate::sparse::hash_map_search(&t, crate::sparse::rulebook::ConvKind::subm3());
    let w = rb.workload_per_offset();
    [27u32, 40, 54, 81, 108, 162, 216]
        .iter()
        .map(|&budget| (budget, w2b_allocate(&w, budget).speedup()))
        .collect()
}

/// Ablation C: hybrid pipeline vs serial scheduling, both networks.
pub fn pipeline_ablation(seed: u64) -> Vec<(&'static str, f64, f64, f64)> {
    let acc = Accelerator::default();
    let doms = Doms::default();
    let opts = SimOptions::default();
    let mut rows = Vec::new();
    let det = second::second();
    let gd = Voxelizer::synth_clustered(det.extent, 6.0e-4, 10, 0.35, seed);
    let din = SparseTensor::from_coords(det.extent, gd.coords(), 1);
    let r = acc.simulate(&det, &din, &doms, &opts);
    rows.push(("SECOND", r.serial_seconds * 1e3, r.seconds * 1e3, r.serial_seconds / r.seconds));
    let seg = minkunet::minkunet();
    let gs = Voxelizer::synth_clustered(seg.extent, 2.3e-4, 14, 0.3, seed ^ 1);
    let sin = SparseTensor::from_coords(seg.extent, gs.coords(), 1);
    let r = acc.simulate(&seg, &sin, &doms, &opts);
    rows.push(("MinkUNet", r.serial_seconds * 1e3, r.seconds * 1e3, r.serial_seconds / r.seconds));
    rows
}

/// Ablation D: table-aided octree search vs DOMS — access volume and
/// table storage (the paper's §1 trade-off, quantified).
pub fn octree_vs_doms(seed: u64) -> Vec<(String, f64, u64, u64)> {
    let t = sweep_tensor(HIGH_RES, 0.005, seed);
    let n = t.len();
    let mut rows = Vec::new();
    let doms = Doms::default();
    let (_, st) = doms.search_subm(&t, 3);
    rows.push((doms.name().to_string(), st.normalized(n), st.table_bytes, 0));
    for level in [0u32, 1, 2] {
        let oc = OctreeSearch { table_level: level };
        let (_, st) = oc.search_subm(&t, 3);
        rows.push((
            format!("octree level {level}"),
            st.normalized(n),
            st.table_bytes,
            oc.dense_table_bytes(&t),
        ));
    }
    rows
}

/// Ablation E: every searcher the engine layer can serve with, at both
/// paper resolutions — normalized access volume and table state, all
/// through the same [`SearcherKind`] dispatch the request path uses.
/// (The rulebooks are bit-identical by the engine-layer property test;
/// this sweep quantifies what the *choice* costs.)
pub fn searcher_sweep(seed: u64) -> Vec<(SearcherKind, f64, f64, u64)> {
    let low = sweep_tensor(LOW_RES, 0.005, seed);
    let high = sweep_tensor(HIGH_RES, 0.005, seed);
    SearcherKind::ALL
        .iter()
        .map(|&kind| {
            let s = kind.build();
            let (_, sl) = s.search_subm(&low, 3);
            let (_, sh) = s.search_subm(&high, 3);
            (
                kind,
                sl.normalized(low.len()),
                sh.normalized(high.len()),
                sh.table_bytes,
            )
        })
        .collect()
}

/// Ablation F: W2B-aware wave packing on the *real* schedule — replica
/// copies from `w2b_allocate` fed into `gather_batches_multi_w2b`,
/// measuring the busiest `(offset, replica)` tile (the layer's makespan
/// in rows) and how many replica tiles the hottest offset's waves
/// actually land on. Row: `(factor, makespan_rows, hottest_offset_tiles,
/// total_waves)`.
pub fn w2b_packing_sweep(seed: u64) -> Vec<(u32, u64, usize, usize)> {
    let t = sweep_tensor(LOW_RES, 0.005, seed);
    let rb = crate::sparse::hash_map_search(&t, ConvKind::subm3());
    let workload = rb.workload_per_offset();
    let hottest = workload
        .iter()
        .enumerate()
        .max_by_key(|(_, &w)| w)
        .map(|(d, _)| d as u16)
        .unwrap_or(0);
    [1u32, 2, 4, 8]
        .iter()
        .map(|&factor| {
            let copies = copies_for_factor(&workload, factor);
            let waves = gather_batches_multi_w2b(&[&rb], 256, &copies);
            let replicas: std::collections::HashSet<u16> = waves
                .iter()
                .filter(|w| w.offset == hottest)
                .map(|w| w.replica)
                .collect();
            (factor, tile_makespan_rows(&waves), replicas.len(), waves.len())
        })
        .collect()
}

pub fn print_all(seed: u64) {
    print_table(
        "Ablation A — DOMS FIFO capacity (high res, s=0.005)",
        &["fifo voxels", "access"],
        &fifo_sweep(seed)
            .iter()
            .map(|(c, a)| vec![c.to_string(), format!("{a:.2}x")])
            .collect::<Vec<_>>(),
    );
    print_table(
        "Ablation B — W2B copy budget (SECOND L1 workload)",
        &["budget", "speedup"],
        &w2b_budget_sweep(seed)
            .iter()
            .map(|(b, s)| vec![b.to_string(), format!("{s:.2}x")])
            .collect::<Vec<_>>(),
    );
    print_table(
        "Ablation C — hybrid pipeline vs serial (Fig. 8 model)",
        &["network", "serial (ms)", "pipelined (ms)", "gain"],
        &pipeline_ablation(seed)
            .iter()
            .map(|(n, s, p, g)| {
                vec![n.to_string(), format!("{s:.2}"), format!("{p:.2}"), format!("{g:.2}x")]
            })
            .collect::<Vec<_>>(),
    );
    print_table(
        "Ablation D — table-aided octree vs DOMS (high res, s=0.005)",
        &["searcher", "access", "table built", "dense table"],
        &octree_vs_doms(seed)
            .iter()
            .map(|(n, a, t, d)| {
                vec![
                    n.clone(),
                    format!("{a:.2}x"),
                    crate::util::human_bytes(*t),
                    if *d == 0 {
                        "-".into()
                    } else {
                        crate::util::human_bytes(*d)
                    },
                ]
            })
            .collect::<Vec<_>>(),
    );
    print_table(
        "Ablation E — engine-layer searcher sweep (s=0.005)",
        &["searcher", "low-res access", "high-res access", "table built"],
        &searcher_sweep(seed)
            .iter()
            .map(|(k, lo, hi, t)| {
                vec![
                    k.key().to_string(),
                    format!("{lo:.2}x"),
                    format!("{hi:.2}x"),
                    crate::util::human_bytes(*t),
                ]
            })
            .collect::<Vec<_>>(),
    );
    print_table(
        "Ablation F — W2B-aware wave packing (low res, batch 256)",
        &["factor", "makespan rows", "hot-offset tiles", "waves"],
        &w2b_packing_sweep(seed)
            .iter()
            .map(|(f, m, r, w)| {
                vec![format!("{f}x"), m.to_string(), r.to_string(), w.to_string()]
            })
            .collect::<Vec<_>>(),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_sweep_monotone_down() {
        let rows = fifo_sweep(71);
        for w in rows.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-9, "access grew with bigger FIFO: {rows:?}");
        }
        // A depth-sized FIFO reaches O(N).
        assert!(rows.last().unwrap().1 < 1.1);
    }

    #[test]
    fn w2b_budget_monotone_up_with_diminishing_returns() {
        let rows = w2b_budget_sweep(72);
        assert!((rows[0].1 - 1.0).abs() < 1e-9); // budget = K is identity
        for w in rows.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-9);
        }
        // Diminishing: the last doubling gains less than the first.
        let first_gain = rows[2].1 / rows[0].1;
        let last_gain = rows.last().unwrap().1 / rows[rows.len() - 3].1;
        assert!(first_gain > last_gain);
    }

    #[test]
    fn pipeline_always_gains() {
        for (net, serial, pipelined, gain) in pipeline_ablation(73) {
            assert!(pipelined <= serial + 1e-9, "{net}");
            assert!(gain >= 1.0);
        }
    }

    #[test]
    fn w2b_packing_splits_the_hottest_offset_across_replica_tiles() {
        let rows = w2b_packing_sweep(76);
        assert_eq!(rows.len(), 4);
        // Factor 1 = identity allocation: one tile per offset.
        assert_eq!(rows[0].0, 1);
        assert_eq!(rows[0].2, 1);
        // Replication never worsens the busiest tile.
        for w in rows.windows(2) {
            assert!(w[1].1 <= w[0].1, "makespan grew with budget: {rows:?}");
        }
        // The paper's 2x setting demonstrably splits the hottest offset's
        // waves across >= 2 replica tiles and shrinks the makespan.
        let f2 = rows.iter().find(|r| r.0 == 2).unwrap();
        assert!(f2.2 >= 2, "hottest offset stayed on one tile: {rows:?}");
        assert!(f2.1 < rows[0].1, "2x replication did not flatten: {rows:?}");
    }

    #[test]
    fn searcher_sweep_reproduces_the_paper_ordering() {
        let rows = searcher_sweep(75);
        assert_eq!(rows.len(), SearcherKind::ALL.len());
        let get = |k: SearcherKind| rows.iter().find(|r| r.0 == k).unwrap();
        let wm = get(SearcherKind::WeightMajor);
        let om = get(SearcherKind::OutputMajor);
        let doms = get(SearcherKind::Doms);
        // PointAcc pays ~K^3 at both resolutions; MARS deteriorates at
        // high resolution while DOMS stays stable O(2N).
        assert!((wm.1 - 27.0).abs() < 0.5 && (wm.2 - 27.0).abs() < 0.5);
        assert!(om.2 > doms.2, "MARS {:.2} should exceed DOMS {:.2}", om.2, doms.2);
        assert!(doms.2 <= 2.3);
    }

    #[test]
    fn octree_trades_storage_for_access() {
        let rows = octree_vs_doms(74);
        let doms = &rows[0];
        let oct = &rows[1];
        // Octree streams twice (read + encoded write-back) vs DOMS <= 2N;
        // its *dense* table is orders of magnitude bigger than DOMS'.
        assert!(oct.1 <= 2.01);
        assert!(oct.3 > doms.2 * 1000);
    }
}
