//! E1 — Fig. 2(d): normalized off-chip data access volume of the
//! output-major search (MARS) across the four (resolution x distribution)
//! corners, buffer = merge-sorter length = 64, versus DOMS. The paper's
//! point: output-major is optimal only in the sparse/low-res corner and
//! deteriorates rapidly with high resolution or dense local
//! distributions; DOMS stays ~O(N..2N) everywhere.

use crate::experiments::{print_table, sweep_tensor, sweep_tensor_clustered, HIGH_RES, LOW_RES};
use crate::mapsearch::{Doms, MapSearch, OutputMajor};

/// One measured corner.
#[derive(Clone, Debug)]
pub struct Fig2dRow {
    pub case: &'static str,
    pub n_voxels: usize,
    pub mars_norm: f64,
    pub doms_norm: f64,
}

pub fn run(seed: u64) -> Vec<Fig2dRow> {
    // "Sparse" must leave two-depth windows well inside the 64-voxel
    // sorter buffer at low resolution (the corner where MARS is optimal);
    // "dense" is an order of magnitude past it.
    let sparsity_low = 0.001;
    let sparsity_high = 0.02;
    let cases = [
        ("low-res / sparse", LOW_RES, sparsity_low, false),
        ("low-res / dense-cluster", LOW_RES, sparsity_high, true),
        ("high-res / sparse", HIGH_RES, sparsity_low, false),
        ("high-res / dense-cluster", HIGH_RES, sparsity_high, true),
    ];
    let mars = OutputMajor::default();
    let doms = Doms::default();
    cases
        .iter()
        .map(|&(case, extent, s, clustered)| {
            let t = if clustered {
                sweep_tensor_clustered(extent, s, seed)
            } else {
                sweep_tensor(extent, s, seed)
            };
            let (_, sm) = mars.search_subm(&t, 3);
            let (_, sd) = doms.search_subm(&t, 3);
            Fig2dRow {
                case,
                n_voxels: t.len(),
                mars_norm: sm.normalized(t.len()),
                doms_norm: sd.normalized(t.len()),
            }
        })
        .collect()
}

pub fn print(rows: &[Fig2dRow]) {
    print_table(
        "Fig. 2(d) — normalized off-chip access volume (buffer = 64)",
        &["case", "N", "output-major (MARS)", "DOMS"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.case.to_string(),
                    r.n_voxels.to_string(),
                    format!("{:.2}x", r.mars_norm),
                    format!("{:.2}x", r.doms_norm),
                ]
            })
            .collect::<Vec<_>>(),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_paper() {
        let rows = run(7);
        assert_eq!(rows.len(), 4);
        // Corner 1: MARS near-optimal (the paper's "optimal O(N)").
        assert!(rows[0].mars_norm < 2.5, "sparse low-res MARS {:.2}", rows[0].mars_norm);
        // Dense / high-res corners: MARS deteriorates by large factors...
        assert!(rows[1].mars_norm > 4.0 * rows[0].mars_norm);
        assert!(rows[3].mars_norm > 4.0 * rows[0].mars_norm);
        // ...while DOMS stays in the O(N..2N) band everywhere.
        for r in &rows {
            assert!(r.doms_norm <= 2.6, "{}: DOMS {:.2}", r.case, r.doms_norm);
        }
    }
}
