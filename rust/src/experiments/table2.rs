//! E8 — Table 2: the chip-comparison table, with Voxel-CIM's column
//! produced by our models (peak throughput and efficiency from the CIM
//! config + energy model; Det/Seg fps from the simulator) next to the
//! published baselines. Also measures the CPU-side preprocessing cost
//! (voxelization + VFE) the paper evaluates on a Xeon.

use std::time::Instant;

use crate::cim::energy::EnergyModel;
use crate::cim::tile::CimConfig;
use crate::experiments::print_table;
use crate::mapsearch::Doms;
use crate::model::{minkunet, second};
use crate::pointcloud::scene::SceneConfig;
use crate::pointcloud::vfe::{Vfe, VfeKind};
use crate::pointcloud::voxelize::Voxelizer;
use crate::sim::accelerator::{Accelerator, SimOptions};
use crate::sim::baselines::{BaselineChip, BASELINES, VOXEL_CIM_PUBLISHED};
use crate::sparse::tensor::SparseTensor;

pub struct Table2Result {
    pub measured: BaselineChip,
    pub preprocess_ms: f64,
}

/// Measure voxelization + VFE on this machine's CPU (the paper's Xeon
/// role) over a realistic urban frame.
pub fn measure_preprocess_seconds() -> f64 {
    let scene = SceneConfig::default().with_points(20_000);
    let pts = scene.generate();
    let vx = Voxelizer::kitti_high((70.4, 80.0, 4.0));
    let vfe = Vfe::new(VfeKind::Simple);
    // Warm once, then time a few iterations.
    let grid = vx.voxelize(&pts);
    let _ = vfe.extract_i8(&grid);
    let t = Instant::now();
    let iters = 5;
    for _ in 0..iters {
        let grid = vx.voxelize(&pts);
        let _ = vfe.extract_i8(&grid);
    }
    t.elapsed().as_secs_f64() / iters as f64
}

pub fn run(seed: u64) -> Table2Result {
    let cim = CimConfig::default();
    let em = EnergyModel::default();
    let acc = Accelerator::default();
    let doms = Doms::default();
    let preprocess = measure_preprocess_seconds();
    let opts = SimOptions {
        preprocess_seconds: preprocess,
        ..Default::default()
    };

    let det_net = second::second();
    let gd = Voxelizer::synth_clustered(det_net.extent, 6.0e-4, 10, 0.35, seed);
    let det_in = SparseTensor::from_coords(det_net.extent, gd.coords(), 1);
    let det = acc.simulate(&det_net, &det_in, &doms, &opts);

    let seg_net = minkunet::minkunet();
    let gs = Voxelizer::synth_clustered(seg_net.extent, 2.3e-4, 14, 0.3, seed ^ 1);
    let seg_in = SparseTensor::from_coords(seg_net.extent, gs.coords(), 1);
    let seg = acc.simulate(&seg_net, &seg_in, &doms, &opts);

    let measured = BaselineChip {
        name: "Voxel-CIM (this repo)",
        tech_nm: 22,
        freq_mhz: 1000,
        buffer_kb: 776.0,
        dram: "HBM2 250GB/s",
        peak_gops: cim.peak_tops() * 1000.0,
        tops_per_watt: Some(em.peak_tops_per_watt(&cim)),
        det_fps: Some(det.fps()),
        seg_fps: Some(seg.fps()),
    };
    Table2Result {
        measured,
        preprocess_ms: preprocess * 1e3,
    }
}

pub fn print(r: &Table2Result) {
    let fmt_chip = |c: &BaselineChip| -> Vec<String> {
        vec![
            c.name.to_string(),
            format!("{}", c.tech_nm),
            format!("{}", c.freq_mhz),
            format!("{}", c.buffer_kb),
            c.dram.to_string(),
            format!("{:.0}", c.peak_gops),
            c.tops_per_watt
                .map(|x| format!("{x:.2}"))
                .unwrap_or_else(|| "-".into()),
            c.det_fps
                .map(|x| format!("{x:.1}"))
                .unwrap_or_else(|| "-".into()),
            c.seg_fps
                .map(|x| format!("{x:.1}"))
                .unwrap_or_else(|| "-".into()),
        ]
    };
    let mut rows: Vec<Vec<String>> = BASELINES.iter().map(fmt_chip).collect();
    rows.push(fmt_chip(&VOXEL_CIM_PUBLISHED));
    rows.push(fmt_chip(&r.measured));
    print_table(
        "Table 2 — comparison with other works",
        &[
            "chip", "nm", "MHz", "buf KB", "DRAM", "GOPS", "TOPS/W", "Det fps", "Seg fps",
        ],
        &rows,
    );
    println!("CPU preprocessing (voxelize + VFE): {:.2} ms/frame", r.preprocess_ms);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measured_column_matches_published_operating_points() {
        let r = run(41);
        let m = &r.measured;
        // Peak GOPS and TOPS/W are calibrated quantities: within 5%.
        assert!((m.peak_gops - 27822.0).abs() / 27822.0 < 0.05);
        assert!((m.tops_per_watt.unwrap() - 10.8).abs() / 10.8 < 0.06);
        // FPS: simulated end-to-end; the shape requirement is the right
        // order of magnitude and both tasks real-time-capable.
        let det = m.det_fps.unwrap();
        let seg = m.seg_fps.unwrap();
        assert!(det > 40.0 && det < 400.0, "det fps {det}");
        assert!(seg > 40.0 && seg < 400.0, "seg fps {seg}");
    }

    #[test]
    fn preprocess_measured_not_zero() {
        let ms = measure_preprocess_seconds() * 1e3;
        assert!(ms > 0.05 && ms < 1000.0, "preprocess {ms} ms");
    }
}
