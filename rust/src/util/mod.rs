//! Small self-contained substrates the offline build environment forces us
//! to provide ourselves (no clap/serde/rand in the vendored registry — see
//! DESIGN.md §3 "Offline-crate substitutions").

pub mod cli;
pub mod config;
pub mod json;
pub mod rng;
pub mod stats;

/// Integer ceiling division.
#[inline]
pub fn div_ceil(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Round `n` up to the next multiple of `m`.
#[inline]
pub fn round_up(n: usize, m: usize) -> usize {
    div_ceil(n, m) * m
}

/// Human-readable byte size (KiB/MiB with one decimal).
pub fn human_bytes(bytes: u64) -> String {
    const KIB: f64 = 1024.0;
    let b = bytes as f64;
    if b < KIB {
        format!("{bytes} B")
    } else if b < KIB * KIB {
        format!("{:.1} KiB", b / KIB)
    } else if b < KIB * KIB * KIB {
        format!("{:.1} MiB", b / (KIB * KIB))
    } else {
        format!("{:.2} GiB", b / (KIB * KIB * KIB))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn div_ceil_basics() {
        assert_eq!(div_ceil(0, 4), 0);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(4, 4), 1);
        assert_eq!(div_ceil(5, 4), 2);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 64), 0);
        assert_eq!(round_up(1, 64), 64);
        assert_eq!(round_up(64, 64), 64);
        assert_eq!(round_up(65, 64), 128);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.0 MiB");
    }
}
