//! A minimal hand-rolled JSON writer (the vendored registry has no
//! serde) shared by the bench's `--json` report and the observability
//! exporters (`obs::Recorder::write_chrome_trace` /
//! `write_metrics_json`).
//!
//! Only *writing* is supported — the repo never parses JSON — so the
//! surface is a small value tree plus an escaping-correct renderer.
//! Object keys keep insertion order (exporters sort where determinism
//! matters).

use std::fmt::Write as _;

/// A JSON value tree. Build it with the enum constructors (or the
/// [`Json::obj`] / [`Json::arr`] helpers) and render with
/// [`Json::render`].
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Signed integer — rendered without a decimal point.
    Int(i64),
    /// Unsigned integer — rendered without a decimal point.
    UInt(u64),
    /// Finite floats render via `f64`'s shortest-roundtrip `Display`
    /// (never exponent notation, so always valid JSON); non-finite
    /// values render as `0` — JSON has no NaN/Infinity literal.
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Ordered key/value pairs (insertion order is preserved).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Convenience: an object from `(&str, Json)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Self {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Convenience: an array.
    pub fn arr(items: Vec<Json>) -> Self {
        Json::Arr(items)
    }

    /// Convenience: a string value.
    pub fn str(s: &str) -> Self {
        Json::Str(s.to_string())
    }

    /// Render to a compact JSON string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    /// Render appending to `out`.
    pub fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::UInt(u) => {
                let _ = write!(out, "{u}");
            }
            Json::Num(f) => {
                if f.is_finite() {
                    let _ = write!(out, "{f}");
                } else {
                    out.push('0');
                }
            }
            Json::Str(s) => escape_into(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(out, k);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Append `s` to `out` as a quoted, escaped JSON string: `"` and `\`
/// are backslash-escaped, the common control characters get their short
/// forms (`\n`, `\r`, `\t`), and every other control char (U+0000 —
/// U+001F) becomes a `\u00XX` escape. Everything else — including
/// non-ASCII — passes through as UTF-8, which JSON permits.
pub fn escape_into(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Quote + escape a string (allocating convenience over [`escape_into`]).
pub fn escape(s: &str) -> String {
    let mut out = String::new();
    escape_into(&mut out, s);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escapes_quotes_backslashes_and_control_chars() {
        assert_eq!(escape("plain"), "\"plain\"");
        assert_eq!(escape("a\"b"), "\"a\\\"b\"");
        assert_eq!(escape("a\\b"), "\"a\\\\b\"");
        assert_eq!(escape("line\nbreak"), "\"line\\nbreak\"");
        assert_eq!(escape("cr\rtab\t"), "\"cr\\rtab\\t\"");
        assert_eq!(escape("nul\u{0}bel\u{7}"), "\"nul\\u0000bel\\u0007\"");
        // Non-ASCII passes through as UTF-8 (valid JSON).
        assert_eq!(escape("voxel-μ"), "\"voxel-μ\"");
    }

    #[test]
    fn renders_scalars() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::Bool(true).render(), "true");
        assert_eq!(Json::Int(-3).render(), "-3");
        assert_eq!(Json::UInt(u64::MAX).render(), "18446744073709551615");
        assert_eq!(Json::Num(1.5).render(), "1.5");
        // `Display` for f64 never emits exponent notation.
        assert!(!Json::Num(1e-7).render().contains('e'));
        // Non-finite floats must stay valid JSON.
        assert_eq!(Json::Num(f64::NAN).render(), "0");
        assert_eq!(Json::Num(f64::INFINITY).render(), "0");
    }

    #[test]
    fn renders_nested_structures() {
        let doc = Json::obj(vec![
            ("name", Json::str("a\"b")),
            ("xs", Json::arr(vec![Json::Int(1), Json::Int(2)])),
            ("inner", Json::obj(vec![("ok", Json::Bool(false))])),
        ]);
        assert_eq!(
            doc.render(),
            "{\"name\":\"a\\\"b\",\"xs\":[1,2],\"inner\":{\"ok\":false}}"
        );
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::Arr(vec![]).render(), "[]");
        assert_eq!(Json::Obj(vec![]).render(), "{}");
    }
}
