//! Minimal TOML-subset config parser (no serde/toml in the vendored
//! registry). Supports exactly what run configs need:
//!
//! ```toml
//! # comment
//! [section]
//! key = "string"
//! count = 42
//! ratio = 0.5
//! flag = true
//! dims = [352, 400, 10]
//! ```
//!
//! Values are stored as typed [`Value`]s keyed by `"section.key"`.

use std::collections::BTreeMap;

use anyhow::{bail, Context};

/// A parsed config value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    IntList(Vec<i64>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_int_list(&self) -> Option<&[i64]> {
        match self {
            Value::IntList(v) => Some(v),
            _ => None,
        }
    }
}

/// Flat `section.key -> Value` config map.
#[derive(Clone, Debug, Default)]
pub struct Config {
    map: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> crate::Result<Self> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected `key = value`", lineno + 1))?;
            let full_key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            let value = parse_value(val.trim())
                .with_context(|| format!("line {}: bad value {val:?}", lineno + 1))?;
            if map.insert(full_key.clone(), value).is_some() {
                bail!("duplicate key {full_key:?}");
            }
        }
        Ok(Self { map })
    }

    pub fn load(path: &str) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(String::as_str)
    }

    /// Strict non-negative integer key: missing yields `default`, but a
    /// present value that is negative or not an integer is an error —
    /// the contract config-driven counts (`[runner]`, `[shard]`) rely on
    /// instead of silently falling back.
    pub fn usize_or(&self, key: &str, default: usize) -> crate::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(Value::Int(i)) => {
                anyhow::ensure!(*i >= 0, "{key} must be >= 0, got {i}");
                Ok(*i as usize)
            }
            Some(v) => bail!("{key} must be an integer, got {v:?}"),
        }
    }

    /// Parse a string-valued key into any `FromStr` type (enum-valued
    /// config keys like the engine layer's `[runner] searcher`). Missing
    /// key yields `default`; a present-but-invalid value (unparseable
    /// string or non-string) is an error rather than a silent fallback.
    pub fn parsed_or<T: std::str::FromStr>(&self, key: &str, default: T) -> crate::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(Value::Str(s)) => {
                s.parse().map_err(|e| anyhow::anyhow!("{key}: {e}"))
            }
            Some(v) => bail!("{key} must be a quoted string, got {v:?}"),
        }
    }

    /// Strict optional string: missing yields `Ok(None)`, a present
    /// non-string is an error. The `opt_*` family exists for keys whose
    /// *absence* is meaningful (policy off, no override) — unlike the
    /// `*_or` scalar helpers there is no default to hide a typo'd type
    /// behind, and the strict-config lint rule expects raw `get` reads
    /// to migrate here.
    pub fn opt_str(&self, key: &str) -> crate::Result<Option<&str>> {
        match self.get(key) {
            None => Ok(None),
            Some(Value::Str(s)) => Ok(Some(s)),
            Some(v) => bail!("{key} must be a quoted string, got {v:?}"),
        }
    }

    /// Strict optional bool: missing yields `Ok(None)`, a present
    /// non-bool is an error.
    pub fn opt_bool(&self, key: &str) -> crate::Result<Option<bool>> {
        match self.get(key) {
            None => Ok(None),
            Some(Value::Bool(b)) => Ok(Some(*b)),
            Some(v) => bail!("{key} must be true/false, got {v:?}"),
        }
    }

    /// Strict optional float: missing yields `Ok(None)`; integers
    /// promote (matching [`Value::as_float`]); anything else is an
    /// error.
    pub fn opt_float(&self, key: &str) -> crate::Result<Option<f64>> {
        match self.get(key) {
            None => Ok(None),
            Some(Value::Float(f)) => Ok(Some(*f)),
            Some(Value::Int(i)) => Ok(Some(*i as f64)),
            Some(v) => bail!("{key} must be a number, got {v:?}"),
        }
    }

    /// Strict optional integer list: missing yields `Ok(None)`, a
    /// present non-list is an error.
    pub fn opt_int_list(&self, key: &str) -> crate::Result<Option<&[i64]>> {
        match self.get(key) {
            None => Ok(None),
            Some(Value::IntList(v)) => Ok(Some(v)),
            Some(v) => bail!("{key} must be an integer list like [1, 2, 3], got {v:?}"),
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> crate::Result<Value> {
    if let Some(inner) = s.strip_prefix('"').and_then(|t| t.strip_suffix('"')) {
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|t| t.strip_suffix(']')) {
        let items: Result<Vec<i64>, _> = inner
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(str::parse)
            .collect();
        return Ok(Value::IntList(items?));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("unparseable value {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# global
seed = 42
[scene]
kind = "urban"     # synthetic scene type
sparsity = 0.005
dims = [352, 400, 10]
dense = false
"#;

    #[test]
    fn parses_all_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.int_or("seed", 0), 42);
        assert_eq!(c.str_or("scene.kind", ""), "urban");
        assert!((c.float_or("scene.sparsity", 0.0) - 0.005).abs() < 1e-12);
        assert!(!c.bool_or("scene.dense", true));
        assert_eq!(
            c.get("scene.dims").unwrap().as_int_list().unwrap(),
            &[352, 400, 10]
        );
    }

    #[test]
    fn defaults_for_missing() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.int_or("nope", 7), 7);
        assert_eq!(c.str_or("nope", "d"), "d");
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(Config::parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn comment_inside_string_kept() {
        let c = Config::parse("s = \"a#b\"").unwrap();
        assert_eq!(c.str_or("s", ""), "a#b");
    }

    #[test]
    fn bad_line_errors() {
        assert!(Config::parse("not a kv line").is_err());
        assert!(Config::parse("x = @@").is_err());
    }

    #[test]
    fn parsed_keys_have_strict_errors() {
        use crate::mapsearch::SearcherKind;
        let c = Config::parse("[runner]\nsearcher = \"block-doms\"").unwrap();
        assert_eq!(
            c.parsed_or("runner.searcher", SearcherKind::Doms).unwrap(),
            SearcherKind::BlockDoms
        );
        assert_eq!(
            c.parsed_or("runner.missing", SearcherKind::Doms).unwrap(),
            SearcherKind::Doms
        );
        let bad = Config::parse("[runner]\nsearcher = \"bogus\"").unwrap();
        assert!(bad.parsed_or("runner.searcher", SearcherKind::Doms).is_err());
        // Present but not a string is an error, not a silent default.
        let not_str = Config::parse("[runner]\nsearcher = 3").unwrap();
        assert!(not_str.parsed_or("runner.searcher", SearcherKind::Doms).is_err());
    }

    #[test]
    fn usize_or_is_strict() {
        let c = Config::parse("[shard]\nblocks_x = 2\nbad = -1\nkind = \"x\"").unwrap();
        assert_eq!(c.usize_or("shard.blocks_x", 1).unwrap(), 2);
        assert_eq!(c.usize_or("shard.missing", 7).unwrap(), 7);
        assert!(c.usize_or("shard.bad", 1).is_err());
        assert!(c.usize_or("shard.kind", 1).is_err());
    }

    #[test]
    fn opt_helpers_are_strict_about_present_types() {
        let c = Config::parse(
            "[s]\nname = \"x\"\nflag = true\nratio = 0.5\nn = 3\ndims = [1, 2]",
        )
        .unwrap();
        // Missing keys are None, not errors.
        assert_eq!(c.opt_str("s.missing").unwrap(), None);
        assert_eq!(c.opt_bool("s.missing").unwrap(), None);
        assert_eq!(c.opt_float("s.missing").unwrap(), None);
        assert_eq!(c.opt_int_list("s.missing").unwrap(), None);
        // Present, right type.
        assert_eq!(c.opt_str("s.name").unwrap(), Some("x"));
        assert_eq!(c.opt_bool("s.flag").unwrap(), Some(true));
        assert_eq!(c.opt_float("s.ratio").unwrap(), Some(0.5));
        // Ints promote to float (matching as_float).
        assert_eq!(c.opt_float("s.n").unwrap(), Some(3.0));
        assert_eq!(c.opt_int_list("s.dims").unwrap(), Some(&[1i64, 2][..]));
        // Present, wrong type: an error — never a silent None.
        assert!(c.opt_str("s.flag").is_err());
        assert!(c.opt_bool("s.ratio").is_err());
        assert!(c.opt_float("s.name").is_err());
        assert!(c.opt_int_list("s.n").is_err());
    }

    #[test]
    fn int_vs_float() {
        let c = Config::parse("i = 3\nf = 3.5").unwrap();
        assert_eq!(c.get("i").unwrap().as_int(), Some(3));
        assert_eq!(c.get("f").unwrap().as_int(), None);
        assert_eq!(c.get("i").unwrap().as_float(), Some(3.0));
    }
}
