//! Minimal declarative CLI flag parser (the vendored registry has no clap).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and auto-generated `--help`. Enough for the `voxel-cim`
//! binary, the examples, and the bench harness.

use std::collections::BTreeMap;

/// One declared option.
#[derive(Clone, Debug)]
struct OptSpec {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_bool: bool,
}

/// Declarative argument parser.
#[derive(Debug, Default)]
pub struct Args {
    bin: String,
    about: &'static str,
    specs: Vec<OptSpec>,
    values: BTreeMap<&'static str, String>,
    bools: BTreeMap<&'static str, bool>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(about: &'static str) -> Self {
        Self {
            about,
            ..Default::default()
        }
    }

    /// Declare a string/number option with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.specs.push(OptSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_bool: false,
        });
        self
    }

    /// Declare a boolean switch (off by default).
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec {
            name,
            help,
            default: None,
            is_bool: true,
        });
        self
    }

    /// Parse from an iterator (first element = argv[0] is NOT expected).
    pub fn parse_from<I: IntoIterator<Item = String>>(
        mut self,
        args: I,
    ) -> Result<Self, String> {
        for spec in &self.specs {
            if spec.is_bool {
                self.bools.insert(spec.name, false);
            } else if let Some(d) = &spec.default {
                self.values.insert(spec.name, d.clone());
            }
        }
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}\n{}", self.usage()))?
                    .clone();
                if spec.is_bool {
                    if inline_val.is_some() {
                        return Err(format!("--{name} takes no value"));
                    }
                    self.bools.insert(spec.name, true);
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{name} requires a value"))?,
                    };
                    self.values.insert(spec.name, v);
                }
            } else {
                self.positional.push(arg);
            }
        }
        Ok(self)
    }

    /// Parse from `std::env::args()` and exit(2) with usage on error.
    pub fn parse(mut self) -> Self {
        let mut env = std::env::args();
        self.bin = env.next().unwrap_or_else(|| "voxel-cim".into());
        match self.parse_from(env) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{}\n\nOptions:\n", self.about);
        for spec in &self.specs {
            let d = match (&spec.default, spec.is_bool) {
                (_, true) => " (switch)".to_string(),
                (Some(d), _) => format!(" (default: {d})"),
                _ => String::new(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", spec.name, spec.help, d));
        }
        s
    }

    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|e| panic!("--{name}: not an integer ({e})"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|e| panic!("--{name}: not an integer ({e})"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|e| panic!("--{name}: not a number ({e})"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        *self
            .bools
            .get(name)
            .unwrap_or_else(|| panic!("switch --{name} not declared"))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Parse a `BXxBY` grid spec (`"2x8"`), accepting a bare `N` as the
/// square grid `NxN` — the `--shards` flag's value format.
pub fn parse_grid(s: &str) -> Result<(usize, usize), String> {
    let s = s.trim();
    let parse_dim = |d: &str| -> Result<usize, String> {
        d.trim()
            .parse::<usize>()
            .map_err(|e| format!("bad grid dimension {d:?}: {e}"))
    };
    match s.split_once(['x', 'X']) {
        Some((a, b)) => Ok((parse_dim(a)?, parse_dim(b)?)),
        None => {
            let n = parse_dim(s)?;
            Ok((n, n))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = Args::new("t")
            .opt("n", "5", "count")
            .switch("verbose", "talk")
            .parse_from(argv(""))
            .unwrap();
        assert_eq!(a.get_usize("n"), 5);
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = Args::new("t")
            .opt("n", "5", "count")
            .opt("name", "x", "label")
            .parse_from(argv("--n 9 --name=abc"))
            .unwrap();
        assert_eq!(a.get_usize("n"), 9);
        assert_eq!(a.get("name"), "abc");
    }

    #[test]
    fn switches_and_positionals() {
        let a = Args::new("t")
            .switch("fast", "go fast")
            .parse_from(argv("--fast cmd arg1"))
            .unwrap();
        assert!(a.get_bool("fast"));
        assert_eq!(a.positional(), &["cmd".to_string(), "arg1".to_string()]);
    }

    #[test]
    fn unknown_flag_errors() {
        let r = Args::new("t").parse_from(argv("--bogus 1"));
        assert!(r.is_err());
    }

    #[test]
    fn missing_value_errors() {
        let r = Args::new("t").opt("n", "5", "count").parse_from(argv("--n"));
        assert!(r.is_err());
    }

    #[test]
    fn grid_specs_parse() {
        assert_eq!(parse_grid("2x8"), Ok((2, 8)));
        assert_eq!(parse_grid(" 4X4 "), Ok((4, 4)));
        assert_eq!(parse_grid("3"), Ok((3, 3)));
        assert!(parse_grid("x2").is_err());
        assert!(parse_grid("2x").is_err());
        assert!(parse_grid("axb").is_err());
        assert!(parse_grid("").is_err());
    }

    #[test]
    fn help_yields_usage() {
        let r = Args::new("about-text")
            .opt("n", "5", "count")
            .parse_from(argv("--help"));
        let msg = r.unwrap_err();
        assert!(msg.contains("about-text"));
        assert!(msg.contains("--n"));
    }
}
