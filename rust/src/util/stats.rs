//! Tiny descriptive-statistics helpers used by the bench harness and the
//! experiment reports.

/// Online accumulator for mean/min/max/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Accumulator {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Accumulator {
    pub fn new() -> Self {
        Self {
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

/// Percentile of a sample (nearest-rank on a sorted copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    assert!((0.0..=100.0).contains(&p));
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    nearest_rank(&s, p)
}

/// The one nearest-rank rule: [`percentile`] and [`LatencySummary`]
/// both resolve ranks here, so they can never disagree on what "p95"
/// means.
fn nearest_rank(sorted: &[f64], p: f64) -> f64 {
    let rank = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank]
}

/// One latency distribution, summarized the way every serving report
/// prints it — the shared helper behind the stream benches' p50/p95
/// lines and the admission controller's rolling estimator, so the two
/// never disagree on what "p95" means (nearest-rank, like
/// [`percentile`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LatencySummary {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl LatencySummary {
    /// Summarize a sample of latencies in seconds. `None` when empty —
    /// an empty stream has no percentiles, and callers must say so
    /// instead of printing NaNs.
    pub fn of(xs: &[f64]) -> Option<Self> {
        if xs.is_empty() {
            return None;
        }
        let mut s: Vec<f64> = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(Self {
            n: xs.len(),
            mean: xs.iter().sum::<f64>() / xs.len() as f64,
            p50: nearest_rank(&s, 50.0),
            p95: nearest_rank(&s, 95.0),
            max: *s.last().unwrap(),
        })
    }

    /// The bench-report rendering: `p50 1.23 ms | p95 4.56 ms`.
    pub fn format_ms(&self) -> String {
        format!(
            "p50 {:.2} ms | p95 {:.2} ms",
            self.p50 * 1e3,
            self.p95 * 1e3
        )
    }
}

/// Geometric mean (for normalized speedup summaries, as in Fig. 11).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulator_moments() {
        let mut a = Accumulator::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            a.push(x);
        }
        assert_eq!(a.count(), 8);
        assert!((a.mean() - 5.0).abs() < 1e-12);
        assert!((a.std() - 2.138089935).abs() < 1e-6);
        assert_eq!(a.min(), 2.0);
        assert_eq!(a.max(), 9.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn latency_summary_matches_percentile_and_handles_empty() {
        assert_eq!(LatencySummary::of(&[]), None);
        let xs = [0.004, 0.001, 0.002, 0.005, 0.003];
        let s = LatencySummary::of(&xs).unwrap();
        assert_eq!(s.n, 5);
        assert_eq!(s.p50, percentile(&xs, 50.0));
        assert_eq!(s.p95, percentile(&xs, 95.0));
        assert_eq!(s.max, 0.005);
        assert!((s.mean - 0.003).abs() < 1e-12);
        let line = s.format_ms();
        assert!(line.contains("p50 3.00 ms"), "{line}");
        assert!(line.contains("p95 5.00 ms"), "{line}");
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }
}
