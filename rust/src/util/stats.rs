//! Tiny descriptive-statistics helpers used by the bench harness and the
//! experiment reports.

/// Online accumulator for mean/min/max/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Accumulator {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Accumulator {
    pub fn new() -> Self {
        Self {
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

/// Percentile of a sample (nearest-rank on a sorted copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    assert!((0.0..=100.0).contains(&p));
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
    s[rank]
}

/// Geometric mean (for normalized speedup summaries, as in Fig. 11).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulator_moments() {
        let mut a = Accumulator::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            a.push(x);
        }
        assert_eq!(a.count(), 8);
        assert!((a.mean() - 5.0).abs() < 1e-12);
        assert!((a.std() - 2.138089935).abs() < 1e-6);
        assert_eq!(a.min(), 2.0);
        assert_eq!(a.max(), 9.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }
}
