//! Deterministic pseudo-random number generation.
//!
//! The vendored registry has no `rand` crate, so we implement PCG64
//! (O'Neill's PCG XSL RR 128/64) plus SplitMix64 for seeding. Every
//! stochastic component of the simulator takes an explicit seed so all
//! experiments are reproducible run-to-run.

/// SplitMix64 — used to expand a single `u64` seed into PCG state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG XSL RR 128/64 — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Construct from a 64-bit seed (stream selected by `seed` too, so two
    /// different seeds give statistically independent sequences).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s0 = (sm.next_u64() as u128) << 64 | sm.next_u64() as u128;
        let s1 = (sm.next_u64() as u128) << 64 | sm.next_u64() as u128;
        let mut rng = Self {
            state: 0,
            inc: (s1 << 1) | 1,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(s0);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift with rejection.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (n.wrapping_neg() % n) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (cached spare not kept: simplicity
    /// beats the 2x for our workloads).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Random int8 in `[lo, hi)` (for weight generation).
    pub fn next_i8(&mut self, lo: i8, hi: i8) -> i8 {
        debug_assert!(lo < hi);
        let span = (hi as i16 - lo as i16) as u64;
        (lo as i16 + self.next_below(span) as i16) as i8
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = Pcg64::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Pcg64::new(4);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments_sane() {
        let mut r = Pcg64::new(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(6);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(8);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 30);
    }

    #[test]
    fn next_i8_bounds() {
        let mut r = Pcg64::new(9);
        for _ in 0..1000 {
            let v = r.next_i8(-128, 127);
            assert!((-128..127).contains(&(v as i16 as i32)));
        }
    }
}
