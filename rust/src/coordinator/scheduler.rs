//! The network scheduler: drives frames through the full request path —
//! map search (on the worker pool, MS-wise pipelined, through whichever
//! [`SearcherKind`] the config selects) → gather / GEMM / scatter via a
//! [`GemmEngine`] → BEV flatten → RPN — and reports per-layer statistics.
//!
//! Frames run in *lockstep*: [`NetworkRunner::run_frames`] advances every
//! in-flight frame through the same layer together, searching all frames'
//! rulebooks in parallel on the pool and packing their rule pairs into
//! shared GEMM waves (`SpconvLayer::execute_batch`), so PJRT dispatch
//! overhead amortizes across the stream. A single frame takes the same
//! path with pooled per-offset gather/GEMM/scatter instead.
//!
//! This is the leader loop of the system: pure rust, artifacts already
//! compiled, no python anywhere.

use std::sync::Arc;

use crate::cim::w2b::copies_for_factor;
use crate::coordinator::executor::WorkerPool;
use crate::coordinator::shard::{delta_slot_specs, ShardConfig, ShardPlan};
use crate::geom::{Coord3, Extent3};
use crate::mapsearch::delta::{self, DeltaCache, DeltaConfig, DeltaKey, FrameDelta, SlotSpec};
use crate::mapsearch::{AccessStats, MapSearch, SearcherKind};
use crate::model::layer::{LayerSpec, NetworkSpec};
use crate::obs::{Recorder, Stage, stopwatch};
use crate::sparse::rulebook::{ConvKind, Rulebook};
use crate::sparse::tensor::SparseTensor;
use crate::spconv::conv2d::{conv2d_im2col, DenseMap};
use crate::spconv::gather::ComputeSplice;
use crate::spconv::layer::{GemmEngine, LayerWeights, SpconvLayer, SpconvOutput};
use crate::spconv::quant;
use crate::util::config::Config;

/// Scheduler configuration — the knobs of the engine layer.
#[derive(Clone, Copy, Debug)]
pub struct RunnerConfig {
    /// GEMM wave batch size.
    pub batch: usize,
    /// Worker threads for map search.
    pub workers: usize,
    /// Worker threads for the compute core's gather/GEMM/scatter (1 =
    /// serial; only engines that can fork shard — see
    /// [`GemmEngine::fork`]).
    pub compute_workers: usize,
    /// Frames the stream server keeps in flight and packs into shared
    /// GEMM waves (1 = classic frame-at-a-time serving).
    pub inflight: usize,
    /// Which map-search dataflow builds the rulebooks.
    pub searcher: SearcherKind,
    /// W2B replication budget as a multiple of the kernel volume, fed to
    /// the wave packer: hot offsets get extra sub-matrix copies and their
    /// waves split across the replica tiles (0 = first-come-first-served
    /// packing; the paper's detection setting is 2). Numerics never
    /// change — only wave→tile placement.
    pub w2b_factor: u32,
    /// Block-shard scheduling of oversized scenes (`1x1` grid = off);
    /// see [`crate::coordinator::shard`].
    pub shard: ShardConfig,
    /// Temporal delta map-search cache for streamed sequences (off by
    /// default); see [`crate::mapsearch::delta`].
    pub delta: DeltaConfig,
    /// Weight seed (weights are random — hardware cost is value-free).
    pub seed: u64,
}

impl Default for RunnerConfig {
    fn default() -> Self {
        Self {
            batch: 256,
            workers: 2,
            compute_workers: 2,
            inflight: 1,
            searcher: SearcherKind::Doms,
            w2b_factor: 0,
            shard: ShardConfig::default(),
            delta: DeltaConfig::default(),
            seed: 0x5EC0,
        }
    }
}

impl RunnerConfig {
    /// Read the `[runner]` and `[shard]` sections of a run config,
    /// falling back to the defaults for missing keys. Unknown searcher
    /// names, zero-sized shard grids, and negative counts are errors
    /// rather than silent wraparound.
    pub fn from_config(cfg: &Config) -> crate::Result<Self> {
        let d = Self::default();
        let batch = cfg.usize_or("runner.batch", d.batch)?;
        anyhow::ensure!(batch >= 1, "runner.batch must be >= 1, got {batch}");
        Ok(Self {
            batch,
            workers: cfg.usize_or("runner.workers", d.workers)?,
            compute_workers: cfg.usize_or("runner.compute_workers", d.compute_workers)?,
            inflight: cfg.usize_or("runner.inflight", d.inflight)?,
            searcher: cfg.parsed_or("runner.searcher", d.searcher)?,
            w2b_factor: u32::try_from(cfg.usize_or("runner.w2b_factor", d.w2b_factor as usize)?)
                .map_err(|_| anyhow::anyhow!("runner.w2b_factor out of u32 range"))?,
            shard: ShardConfig::from_config(cfg)?,
            delta: DeltaConfig::from_config(cfg)?,
            seed: cfg.int_or("runner.seed", d.seed as i64) as u64,
        })
    }
}

/// Per-layer record.
#[derive(Clone, Debug)]
pub struct LayerRecord {
    pub name: String,
    pub pairs: u64,
    pub out_voxels: u64,
    pub gemm_calls: u64,
    pub ms_seconds: f64,
    pub compute_seconds: f64,
    pub access: AccessStats,
    /// Per-offset workload (for W2B studies).
    pub workload: Vec<u64>,
    /// Layer channel shape, for cost accounting: the layer's MAC count
    /// is `pairs * c_in * c_out` (dense 2D layers count `pairs` as
    /// output positions × k², so the same product holds).
    pub c_in: u64,
    pub c_out: u64,
    /// Activation rows actually gathered into GEMM waves — equals
    /// `pairs` on a cold frame, strictly less when compute-core reuse
    /// spliced cached psum rows (`rows_gathered_saved`). Dense 2D
    /// layers count their im2col rows here.
    pub gathered_rows: u64,
}

/// Result of one frame.
#[derive(Debug)]
pub struct FrameResult {
    pub records: Vec<LayerRecord>,
    /// Segmentation: per-voxel logits tensor. Detection: BEV head output.
    pub out_voxels: u64,
    /// Dense head output (detection): (h, w, c).
    pub head_shape: Option<(usize, usize, usize)>,
    /// FNV-1a over the final output features (head map for detection,
    /// voxel features for segmentation) — the bit-identity witness the
    /// engine-layer tests compare across searcher kinds, wave batching,
    /// compute pooling, and shard scheduling.
    pub checksum: u64,
    /// Pseudo-frames this frame was executed as: 1 on the plain path,
    /// the shard count when [`NetworkRunner::run_frame_sharded`] split
    /// the scene.
    pub shards: u32,
    /// Wall-clock of the run that produced this frame. In a lockstep
    /// [`NetworkRunner::run_frames`] group the frames complete together,
    /// so every frame of the group reports the *group's* makespan — do
    /// not sum this across a group; per-frame compute attribution lives
    /// in `records[..].compute_seconds`.
    pub total_seconds: f64,
    /// Blocks map-searched for this frame by the temporal delta cache
    /// (dirty + halo ring on warm frames, all occupied blocks on cold
    /// ones). Zero when the cache is disabled.
    pub blocks_searched: u64,
    /// Blocks whose rulebook fragments were spliced from the cache
    /// instead of searched. Zero when the cache is disabled.
    pub blocks_reused: u64,
    /// Voxels re-binned by delta voxelization (all of them on a cold or
    /// non-delta frame). Stamped by the stream server from `FrameMeta`;
    /// zero on non-streamed runs.
    pub voxels_rebinned: u64,
    /// Shared GEMM waves this frame skipped via compute-core reuse,
    /// summed over the sparse prefix. Zero when `delta_compute` is off.
    pub waves_skipped: u64,
    /// Gather rows (rule pairs) compute-core reuse removed from wave
    /// packing. Zero when `delta_compute` is off.
    pub rows_gathered_saved: u64,
    /// Input voxel count of the scene — the N of the paper's
    /// normalized access volume (Fig. 2d / Fig. 9), used by the cost
    /// ledger (`obs::cost`).
    pub in_voxels: u64,
}

impl FrameResult {
    pub fn total_pairs(&self) -> u64 {
        self.records.iter().map(|r| r.pairs).sum()
    }
    pub fn ms_seconds(&self) -> f64 {
        self.records.iter().map(|r| r.ms_seconds).sum()
    }
    pub fn compute_seconds(&self) -> f64 {
        self.records.iter().map(|r| r.compute_seconds).sum()
    }
}

/// FNV-1a over raw bytes — the frame checksum.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn i8_bytes(v: &[i8]) -> &[u8] {
    // SAFETY: i8 and u8 have identical size and alignment, the pointer
    // and length come from a live slice borrow, and the returned slice
    // inherits that borrow's lifetime. The checksum only needs bytes.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len()) }
}

/// The [`FrameResult::checksum`] function over a feature buffer —
/// public so shard merges (and tests) can witness bit-identity against
/// a tensor they assembled themselves.
pub fn checksum_features(features: &[i8]) -> u64 {
    fnv1a(i8_bytes(features))
}

/// Rolling state of one in-flight frame while the lockstep loop advances
/// the whole group layer by layer. The tensor sits behind an `Arc` so
/// pooled layer execution shares it with worker threads without copying.
struct FrameState {
    cur: Arc<SparseTensor>,
    bev: Option<DenseMap>,
    /// Rulebook shared by consecutive subm3 layers on the same geometry.
    shared_rb: Option<Arc<Rulebook>>,
    /// UNet skip connections: gconv2 pushes its input coordinate set;
    /// tconv2 pops it and prunes its outputs to that set (MinkUNet's
    /// decoder semantics — without this, coordinates dilate 8x per
    /// upsampling stage).
    skip_stack: Vec<(Extent3, Vec<Coord3>)>,
    records: Vec<LayerRecord>,
    /// Temporal delta plan for this frame (dirty blocks + cached
    /// fragments per fresh-subm3 slot); `None` when the cache is off.
    delta: Option<FrameDelta>,
    /// Delta-cache counters accumulated across this frame's slots.
    searched: u64,
    reused: u64,
    /// Compute-core reuse counters accumulated across the prefix layers.
    waves_skipped: u64,
    rows_saved: u64,
}

/// One frame's rolling output from a [`NetworkRunner::run_group`] pass:
/// per-layer records plus whatever the last executed layer produced.
struct GroupRun {
    records: Vec<LayerRecord>,
    cur: Arc<SparseTensor>,
    bev: Option<DenseMap>,
    /// Finished delta plan, carrying the fragments to commit back to
    /// the cache once the whole window has planned against prior state.
    delta: Option<FrameDelta>,
    searched: u64,
    reused: u64,
    waves_skipped: u64,
    rows_saved: u64,
}

/// How one frame obtains its rulebook for a sparse layer.
enum RbPlan {
    /// Reuse the previous subm3 search (zero MS time).
    Reuse(Arc<Rulebook>),
    /// Computed inline (pruned transposed conv), with stats and seconds.
    Inline(Arc<Rulebook>, AccessStats, f64),
    /// Searched on the worker pool; resolved after the join.
    Pooled,
}

/// The network runner.
pub struct NetworkRunner {
    pub net: NetworkSpec,
    pub cfg: RunnerConfig,
    searcher: Arc<dyn MapSearch + Send + Sync>,
    pool: WorkerPool,
    compute_pool: Option<WorkerPool>,
    /// Stage-span recorder (see [`Self::set_observer`]); `Disabled`
    /// keeps every hot path allocation- and lock-free.
    obs: Recorder,
}

impl NetworkRunner {
    /// Build a runner with the searcher named by `cfg.searcher`.
    pub fn new(net: NetworkSpec, cfg: RunnerConfig) -> Self {
        let searcher: Arc<dyn MapSearch + Send + Sync> = Arc::from(cfg.searcher.build());
        Self::with_searcher(net, cfg, searcher)
    }

    /// Build a runner around a custom searcher instance (non-default
    /// FIFO/partition parameters, experimental dataflows, ...). The
    /// `cfg.searcher` kind is ignored in favor of the instance.
    pub fn with_searcher(
        net: NetworkSpec,
        cfg: RunnerConfig,
        searcher: Arc<dyn MapSearch + Send + Sync>,
    ) -> Self {
        let pool = WorkerPool::new(cfg.workers.max(1));
        let compute_pool = if cfg.compute_workers >= 2 {
            Some(WorkerPool::new(cfg.compute_workers))
        } else {
            None
        };
        Self {
            net,
            cfg,
            searcher,
            pool,
            compute_pool,
            obs: Recorder::Disabled,
        }
    }

    /// The active map-search engine.
    pub fn searcher(&self) -> &dyn MapSearch {
        self.searcher.as_ref()
    }

    /// Attach a stage-span recorder: map-search / delta-plan / merge /
    /// dense-head spans record in the scheduler (worker closures clone
    /// the recorder), and every executed `SpconvLayer` inherits it for
    /// gather / GEMM-wave / scatter / requant spans.
    pub fn set_observer(&mut self, obs: Recorder) {
        self.obs = obs;
    }

    /// The attached recorder (`Disabled` unless [`Self::set_observer`]).
    pub fn observer(&self) -> &Recorder {
        &self.obs
    }

    /// Run one frame through the network (never block-sharded).
    ///
    /// Legacy shim: submit through the facade instead —
    /// `Pipeline::run(Job::Frame(..))` routes through [`Self::run_scenes`]
    /// and is checksum-bit-identical (`tests/pipeline_api.rs`).
    #[deprecated(
        since = "0.2.0",
        note = "submit through `pipeline::Pipeline::run(Job::Frame(..))`; \
                the facade owns the engine and routes through `run_scenes`"
    )]
    pub fn run_frame<E: GemmEngine>(
        &self,
        input: SparseTensor,
        engine: &mut E,
    ) -> crate::Result<FrameResult> {
        self.run_frames(vec![input], engine)?
            .pop()
            .ok_or_else(|| anyhow::anyhow!("one frame in, one result out"))
    }

    /// Run a group of in-flight frames through the network in lockstep,
    /// packing every sparse layer's rule pairs from all frames into
    /// shared GEMM waves. Per-frame results are bit-identical to running
    /// each frame alone (GEMM rows are independent, scatter-adds
    /// commute); only dispatch counts and wall-clock change.
    pub fn run_frames<E: GemmEngine>(
        &self,
        inputs: Vec<SparseTensor>,
        engine: &mut E,
    ) -> crate::Result<Vec<FrameResult>> {
        let t0 = stopwatch();
        let in_lens: Vec<u64> = inputs.iter().map(|t| t.len() as u64).collect();
        let runs = self.run_group(&self.net.layers, inputs, Vec::new(), engine, self.cfg.seed)?;
        let total = t0.elapsed().as_secs_f64();
        Ok(runs
            .into_iter()
            .zip(in_lens)
            .map(|(r, n)| finalize_frame(r, 1, total, n))
            .collect())
    }

    /// The lockstep layer loop over an explicit layer slice, starting
    /// the per-layer weight seed sequence at `seed0`. `run_frames` runs
    /// the whole network from `cfg.seed`; the shard path runs the sparse
    /// prefix on shard pseudo-frames and then the dense suffix on the
    /// merged scene with `seed0` advanced past the prefix's weights, so
    /// every layer sees exactly the weights the unsharded run would.
    ///
    /// `deltas` carries one optional temporal delta plan per frame
    /// (empty = cache off for the whole group): each fresh subm3 search
    /// claims the frame's next slot and runs [`delta::delta_search`]
    /// instead of a full search. Slot order is safe by construction —
    /// [`delta_slot_specs`] mirrors this loop's rulebook-sharing rule,
    /// and exhausted slots simply fall back to the plain search.
    fn run_group<E: GemmEngine>(
        &self,
        layers: &[LayerSpec],
        inputs: Vec<SparseTensor>,
        deltas: Vec<Option<FrameDelta>>,
        engine: &mut E,
        seed0: u64,
    ) -> crate::Result<Vec<GroupRun>> {
        let nf = inputs.len();
        if nf == 0 {
            return Ok(Vec::new());
        }
        debug_assert!(
            deltas.is_empty() || deltas.len() == nf,
            "one delta plan per frame when the cache is on"
        );
        let mut deltas = deltas.into_iter().chain(std::iter::repeat_with(|| None));
        let mut frames: Vec<FrameState> = inputs
            .into_iter()
            .map(|cur| FrameState {
                cur: Arc::new(cur),
                bev: None,
                shared_rb: None,
                skip_stack: Vec::new(),
                records: Vec::new(),
                delta: deltas.next().flatten(),
                searched: 0,
                reused: 0,
                waves_skipped: 0,
                rows_saved: 0,
            })
            .collect();
        let mut weight_seed = seed0;

        for (li, &spec) in layers.iter().enumerate() {
            match spec {
                LayerSpec::Subm3 { .. } | LayerSpec::GConv2 { .. } | LayerSpec::TConv2 { .. } => {
                    // vcim:allow(panic-freedom) the match arm admits exactly the three sparse-conv specs, for which conv_kind() is Some by definition
                    let kind = spec.conv_kind().unwrap();
                    let (c_in_decl, c_out) = spec.channels();
                    // Per-frame map search: resolve reuse / pruned-tconv
                    // inline, fan fresh searches out over the pool (the
                    // MS-wise side of the Fig. 8 pipeline, now across
                    // frames as well as layers).
                    let mut plans: Vec<RbPlan> = Vec::with_capacity(nf);
                    let mut handles = Vec::new();
                    for f in frames.iter_mut() {
                        let c_in = f.cur.channels;
                        debug_assert!(
                            c_in == c_in_decl || li == 0,
                            "channel drift at layer {li}: {c_in} vs {c_in_decl}"
                        );
                        if matches!(kind, ConvKind::Generalized { .. }) {
                            f.skip_stack.push((f.cur.extent, f.cur.coords.clone()));
                        }
                        let reuse_rb = if matches!(kind, ConvKind::Submanifold { .. }) {
                            f.shared_rb
                                .as_ref()
                                .filter(|rb| rb.out_coords == f.cur.coords)
                                .cloned()
                        } else {
                            None
                        };
                        let skip_target = match kind {
                            ConvKind::Transposed { .. } => f.skip_stack.pop(),
                            _ => None,
                        };
                        if let Some(rb) = reuse_rb {
                            plans.push(RbPlan::Reuse(rb));
                        } else if let (
                            ConvKind::Transposed { k, stride },
                            Some((ext, target)),
                        ) = (kind, skip_target)
                        {
                            // Pruned transposed conv (UNet decoder):
                            // outputs restricted to the matching encoder
                            // stage. Geometry comes from the skip target,
                            // so this path is searcher-independent.
                            let t = stopwatch();
                            let _g = self.obs.span(Stage::MapSearch).layer(li as u32);
                            let rb = crate::sparse::hash_search::tconv_pruned(
                                &f.cur, k, stride, ext, &target,
                            );
                            drop(_g);
                            let access = AccessStats {
                                voxel_reads: f.cur.len() as u64 + target.len() as u64,
                                ..Default::default()
                            };
                            f.shared_rb = None;
                            plans.push(RbPlan::Inline(
                                Arc::new(rb),
                                access,
                                t.elapsed().as_secs_f64(),
                            ));
                        } else {
                            let coords_tensor = SparseTensor::from_coords(
                                f.cur.extent,
                                f.cur.coords.clone(),
                                1,
                            );
                            let searcher = Arc::clone(&self.searcher);
                            // A fresh subm3 search claims the frame's
                            // next delta slot (if any); other kinds and
                            // slots past the static walk take the plain
                            // full search.
                            let slot = match kind {
                                ConvKind::Submanifold { k } => f
                                    .delta
                                    .as_mut()
                                    .and_then(FrameDelta::take_slot)
                                    .map(|task| (k, task)),
                                _ => None,
                            };
                            let obs = self.obs.clone();
                            handles.push((plans.len(), self.pool.submit(move || {
                                let _g = obs.span(Stage::MapSearch).layer(li as u32);
                                let t = stopwatch();
                                let (rb, st, outcome) = match slot {
                                    Some((k, task)) => {
                                        let (rb, st, out) = delta::delta_search(
                                            searcher.as_ref(),
                                            &coords_tensor,
                                            k,
                                            &task,
                                        );
                                        (rb, st, Some((task.index, out)))
                                    }
                                    None => {
                                        let (rb, st) =
                                            searcher.search(&coords_tensor, kind);
                                        (rb, st, None)
                                    }
                                };
                                (rb, st, t.elapsed().as_secs_f64(), outcome)
                            })));
                            plans.push(RbPlan::Pooled);
                        }
                    }
                    let mut searched = handles
                        .into_iter()
                        .map(|(idx, h)| (idx, h.join()))
                        .collect::<Vec<_>>()
                        .into_iter();

                    // Resolve plans into per-frame (rulebook, stats, ms).
                    let mut rbs: Vec<(Arc<Rulebook>, AccessStats, f64)> =
                        Vec::with_capacity(nf);
                    for (fi, plan) in plans.into_iter().enumerate() {
                        match plan {
                            RbPlan::Reuse(rb) => {
                                // No search ran, but replaying the
                                // resident rulebook still re-reads one
                                // coordinate entry per output voxel —
                                // reuse is reduced access, not free.
                                let access = AccessStats {
                                    voxel_reads: rb.out_coords.len() as u64,
                                    ..Default::default()
                                };
                                rbs.push((rb, access, 0.0));
                            }
                            RbPlan::Inline(rb, st, secs) => rbs.push((rb, st, secs)),
                            RbPlan::Pooled => {
                                // vcim:allow(panic-freedom) pooled plans and pool results are built in lockstep from the same frame loop; the debug_assert below checks the pairing
                                let hit = searched.next().expect("one search per pooled plan");
                                let (idx, (rb, st, secs, outcome)) = hit;
                                debug_assert_eq!(idx, fi);
                                if let Some((slot, out)) = outcome {
                                    let f = &mut frames[fi];
                                    f.searched += out.searched;
                                    f.reused += out.reused;
                                    if let Some(d) = f.delta.as_mut() {
                                        d.record(slot, out.frags);
                                    }
                                }
                                let rb = Arc::new(rb);
                                frames[fi].shared_rb =
                                    matches!(kind, ConvKind::Submanifold { .. })
                                        .then(|| rb.clone());
                                rbs.push((rb, st, secs));
                            }
                        }
                    }

                    let c_in = frames[0].cur.channels;
                    let weights =
                        LayerWeights::random(spec.kernel_volume(), c_in, c_out, weight_seed);
                    weight_seed = weight_seed.wrapping_add(1);
                    let mut layer = SpconvLayer::new(weights, self.cfg.batch)
                        .with_observer(self.obs.clone(), li as u32);
                    if self.cfg.w2b_factor > 0 {
                        // W2B-aware wave packing: replica copies from the
                        // group's combined per-offset workload, so hot
                        // offsets' waves split across parallel tiles
                        // (numerics unchanged; placement only).
                        let workload = Rulebook::combined_workload(
                            rbs.iter().map(|(rb, _, _)| rb.as_ref()),
                        );
                        if !workload.is_empty() {
                            layer = layer
                                .with_w2b(copies_for_factor(&workload, self.cfg.w2b_factor));
                        }
                    }
                    let tc = stopwatch();
                    // Compute-core reuse: each frame's compute slot for
                    // this layer (claimed by layer index — compute specs
                    // are one-per-layer, contiguous from 0 both in the
                    // whole net and in the sharded prefix group). A task
                    // with clean-cone blocks yields a splice plan: its
                    // cached psum rows bypass gather/GEMM/scatter.
                    let mut ctasks: Vec<Option<delta::ComputeTask>> = frames
                        .iter_mut()
                        .map(|f| f.delta.as_mut().and_then(|d| d.take_compute(li)))
                        .collect();
                    let splices: Vec<Option<ComputeSplice>> = ctasks
                        .iter()
                        .zip(&rbs)
                        .map(|(t, (rb, _, _))| {
                            t.as_ref().and_then(|t| t.splice_plan(&rb.out_coords))
                        })
                        .collect();
                    // Single frames and lockstep groups share one path:
                    // shared GEMM waves, sharded over the compute pool
                    // when the engine can fork.
                    let group: Vec<(Arc<SparseTensor>, Arc<Rulebook>)> = frames
                        .iter()
                        .zip(&rbs)
                        .map(|(f, (rb, _, _))| (Arc::clone(&f.cur), Arc::clone(rb)))
                        .collect();
                    let (outs, dstats): (Vec<SpconvOutput>, _) = layer.execute_batch_delta(
                        &group,
                        engine,
                        self.compute_pool.as_ref(),
                        &splices,
                    )?;
                    let layer_secs = tc.elapsed().as_secs_f64();
                    // Attribute the shared compute wall time to frames in
                    // proportion to their pair counts.
                    let total_pairs: u64 =
                        rbs.iter().map(|(rb, _, _)| rb.len() as u64).sum();
                    for (fi, ((f, (rb, access, ms_secs)), out)) in
                        frames.iter_mut().zip(rbs).zip(outs).enumerate()
                    {
                        let share = if total_pairs == 0 {
                            layer_secs / nf as f64
                        } else {
                            layer_secs * rb.len() as f64 / total_pairs as f64
                        };
                        f.waves_skipped += dstats.waves_skipped[fi];
                        f.rows_saved += dstats.rows_saved[fi];
                        // The layer's psum rows become next frame's cache
                        // for this compute slot (clean blocks keep their
                        // prior Arc).
                        if let Some(task) = ctasks[fi].take() {
                            let rows = delta::bin_compute_rows(
                                &task,
                                &rb.out_coords,
                                &out.psums,
                                c_out,
                            );
                            if let Some(d) = f.delta.as_mut() {
                                d.record_compute(task.index, rows);
                            }
                        }
                        f.records.push(LayerRecord {
                            name: format!("{spec:?}"),
                            pairs: rb.len() as u64,
                            out_voxels: rb.out_coords.len() as u64,
                            gemm_calls: out.gemm_calls,
                            ms_seconds: ms_secs,
                            compute_seconds: share,
                            access,
                            workload: rb.workload_per_offset(),
                            c_in: c_in as u64,
                            c_out: c_out as u64,
                            gathered_rows: out.gathered_rows,
                        });
                        f.cur = Arc::new(out.tensor);
                    }
                }
                LayerSpec::ToBev => {
                    for f in frames.iter_mut() {
                        let _g = self.obs.span(Stage::DenseHead).layer(li as u32);
                        f.bev = Some(to_bev(&f.cur));
                        drop(_g);
                        // The BEV flatten reads every sparse voxel's
                        // coordinate and writes it into the dense
                        // plane — real data movement, not zero-cost.
                        let n = f.cur.len() as u64;
                        f.records.push(LayerRecord {
                            name: "ToBev".into(),
                            pairs: 0,
                            out_voxels: n,
                            gemm_calls: 0,
                            ms_seconds: 0.0,
                            compute_seconds: 0.0,
                            access: AccessStats {
                                voxel_reads: n,
                                voxel_writes: n,
                                ..Default::default()
                            },
                            workload: Vec::new(),
                            c_in: f.cur.channels as u64,
                            c_out: 0,
                            gathered_rows: 0,
                        });
                    }
                }
                LayerSpec::Conv2d { c_out, k, stride, .. } => {
                    let c_in0 = frames[0]
                        .bev
                        .as_ref()
                        .ok_or_else(|| anyhow::anyhow!("layer {li}: Conv2d before ToBev"))?
                        .c;
                    let w = conv2d_weights(c_in0, c_out, k, weight_seed);
                    weight_seed = weight_seed.wrapping_add(1);
                    for f in frames.iter_mut() {
                        let x = f
                            .bev
                            .take()
                            .ok_or_else(|| anyhow::anyhow!("layer {li}: Conv2d before ToBev"))?;
                        let c_in = x.c as u64;
                        let _g = self.obs.span(Stage::DenseHead).layer(li as u32);
                        let (y, secs) = run_conv2d(&x, &w, c_out, k, stride, 1, engine)?;
                        drop(_g);
                        let pairs = (y.h * y.w) as u64 * (k * k) as u64;
                        f.records.push(LayerRecord {
                            name: format!("{spec:?}"),
                            pairs,
                            out_voxels: (y.h * y.w) as u64,
                            gemm_calls: 0,
                            ms_seconds: 0.0,
                            compute_seconds: secs,
                            // Im2col reads one plane position per rule
                            // pair and writes each output position.
                            access: AccessStats {
                                voxel_reads: pairs,
                                voxel_writes: (y.h * y.w) as u64,
                                ..Default::default()
                            },
                            workload: Vec::new(),
                            c_in,
                            c_out: c_out as u64,
                            gathered_rows: pairs,
                        });
                        f.bev = Some(y);
                    }
                }
                LayerSpec::Deconv2d { c_out, k, up, .. } => {
                    let c_in0 = frames[0]
                        .bev
                        .as_ref()
                        .ok_or_else(|| anyhow::anyhow!("layer {li}: Deconv2d before ToBev"))?
                        .c;
                    let w = conv2d_weights(c_in0, c_out, k, weight_seed);
                    weight_seed = weight_seed.wrapping_add(1);
                    for f in frames.iter_mut() {
                        let x = f
                            .bev
                            .take()
                            .ok_or_else(|| anyhow::anyhow!("layer {li}: Deconv2d before ToBev"))?;
                        let c_in = x.c as u64;
                        let _g = self.obs.span(Stage::DenseHead).layer(li as u32);
                        let (y, secs) = run_conv2d(&x, &w, c_out, k, 1, up, engine)?;
                        drop(_g);
                        let pairs = (y.h * y.w) as u64 * (k * k) as u64;
                        f.records.push(LayerRecord {
                            name: format!("{spec:?}"),
                            pairs,
                            out_voxels: (y.h * y.w) as u64,
                            gemm_calls: 0,
                            ms_seconds: 0.0,
                            compute_seconds: secs,
                            // Upsample + im2col read one position per
                            // pair; each output position is written.
                            access: AccessStats {
                                voxel_reads: pairs,
                                voxel_writes: (y.h * y.w) as u64,
                                ..Default::default()
                            },
                            workload: Vec::new(),
                            c_in,
                            c_out: c_out as u64,
                            gathered_rows: pairs,
                        });
                        f.bev = Some(y);
                    }
                }
            }
        }

        Ok(frames
            .into_iter()
            .map(|f| GroupRun {
                records: f.records,
                cur: f.cur,
                bev: f.bev,
                delta: f.delta,
                searched: f.searched,
                reused: f.reused,
                waves_skipped: f.waves_skipped,
                rows_saved: f.rows_saved,
            })
            .collect())
    }

    /// Run one frame with shard-level scheduling: the single-scene
    /// window of [`Self::run_scenes`].
    ///
    /// Legacy shim: submit through the facade instead —
    /// `Pipeline::run(Job::Frame(..))` takes exactly this path and is
    /// checksum-bit-identical (`tests/pipeline_api.rs`).
    #[deprecated(
        since = "0.2.0",
        note = "submit through `pipeline::Pipeline::run(Job::Frame(..))`; \
                the facade owns the engine and routes through `run_scenes`"
    )]
    pub fn run_frame_sharded<E: GemmEngine>(
        &self,
        input: SparseTensor,
        engine: &mut E,
    ) -> crate::Result<FrameResult> {
        self.run_scenes(vec![input], engine)?
            .pop()
            .ok_or_else(|| anyhow::anyhow!("one scene in, one result out"))
    }

    /// Run a *window* of scenes in cross-scene lockstep — the serving
    /// scheduler's window executor. Every scene that `cfg.shard` splits
    /// contributes its halo-padded block shards as pseudo-frames; every
    /// other scene contributes itself; and all pseudo-frames, across
    /// scene boundaries, run through the sparse prefix as **one**
    /// lockstep group sharing GEMM waves. Sharded scenes then merge back
    /// by block ownership, and the dense suffix (if any) runs as a
    /// second lockstep group over the merged scenes with the weight-seed
    /// sequence continued exactly where the prefix left off (the prefix
    /// is all weight-bearing sparse layers, so `seed + prefix.len()` is
    /// the seed the single-pass run would reach).
    ///
    /// Per-scene results are bit-identical to running each scene alone:
    /// the halo covers the prefix's receptive field, so every owned
    /// output's dependency cone — including rule pairs that cross shard
    /// edges — is complete inside its shard, and lockstep grouping never
    /// changes a frame's bits (GEMM rows are independent, scatter-adds
    /// commute). Checksum-verified across all six `SearcherKind`s in
    /// `tests/serving_scheduler.rs`. Per-layer records aggregate across
    /// a scene's shards; halo voxels are processed by every shard whose
    /// ring they fall in, so summed pairs exceed the unsharded run's —
    /// that surplus is the replication cost of sharding, reported rather
    /// than hidden. `FrameResult::total_seconds` is the *window*
    /// makespan for every scene of the window (like
    /// [`Self::run_frames`]); per-scene attribution lives in the
    /// records.
    ///
    /// Falls back to a single lockstep group over the whole network
    /// (the [`Self::run_frames`] shape) when no scene shards — sharding
    /// off, scenes below the auto threshold, plans collapsing to one
    /// non-empty shard, or an empty sparse prefix.
    pub fn run_scenes<E: GemmEngine>(
        &self,
        inputs: Vec<SparseTensor>,
        engine: &mut E,
    ) -> crate::Result<Vec<FrameResult>> {
        self.run_scenes_delta(inputs, None, engine)
    }

    /// [`Self::run_scenes`] with an optional temporal delta cache: one
    /// sequence id per scene (window order) plus the serve-scoped
    /// [`DeltaCache`]. Warm frames re-search only dirty blocks plus the
    /// receptive-cone halo ring and splice the rest of the rulebook from
    /// the cache — bit-identical to the cold path by construction (hash
    /// invalidation, canonical rulebooks); only the blocks-searched /
    /// blocks-reused counters and the search cost change. Plans are made
    /// against pre-window cache state for every scene of the window and
    /// committed in window order afterwards, so lockstep grouping never
    /// sees mid-window cache mutation.
    pub fn run_scenes_delta<E: GemmEngine>(
        &self,
        inputs: Vec<SparseTensor>,
        mut delta: Option<(&[u32], &mut DeltaCache)>,
        engine: &mut E,
    ) -> crate::Result<Vec<FrameResult>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        if let Some((seqs, _)) = &delta {
            anyhow::ensure!(
                seqs.len() == inputs.len(),
                "one sequence id per scene ({} vs {} scenes)",
                seqs.len(),
                inputs.len()
            );
        }
        let sc = self.cfg.shard;
        let n_layers = self.net.layers.len();
        let split = self.net.layers.iter().position(|l| !l.is_sparse()).unwrap_or(n_layers);
        let (prefix, suffix) = self.net.layers.split_at(split);
        // The slot walk stops at the first non-subm3-compatible layer,
        // so it is identical whether the group runs the whole network
        // (fallback) or just the sparse prefix (sharded path).
        let specs: Arc<Vec<SlotSpec>> = Arc::new(if delta.is_some() {
            delta_slot_specs(&self.net.layers)
        } else {
            Vec::new()
        });
        // Compute slots mirror the same static walk, one per prefix
        // layer; empty (== feature off) unless `delta_compute` is set.
        let cspecs: Arc<Vec<SlotSpec>> = Arc::new(
            if delta.is_some() && self.cfg.delta.compute {
                crate::coordinator::shard::delta_compute_specs(&self.net.layers)
            } else {
                Vec::new()
            },
        );
        let t0 = stopwatch();
        let in_lens: Vec<u64> = inputs.iter().map(|t| t.len() as u64).collect();
        let mut plans: Vec<Option<ShardPlan>> = Vec::with_capacity(inputs.len());
        for t in &inputs {
            let plan = if !prefix.is_empty() && sc.active_for(t.len()) {
                let p = ShardPlan::plan(prefix, t, sc.blocks_x, sc.blocks_y)?;
                (p.shards.len() > 1).then_some(p)
            } else {
                None
            };
            plans.push(plan);
        }
        if plans.iter().all(Option::is_none) {
            // No scene shards: one lockstep group over the whole
            // network, each scene planned against its (sequence, whole
            // scene) cache entry.
            let frame_deltas: Vec<Option<FrameDelta>> = match &delta {
                Some((seqs, cache)) => inputs
                    .iter()
                    .zip(seqs.iter())
                    .map(|(t, &sequence)| {
                        let _g = self.obs.span(Stage::DeltaPlan).sequence(sequence);
                        Some(cache.begin_frame(
                            DeltaKey { sequence, shard: None },
                            t,
                            &specs,
                            &cspecs,
                        ))
                    })
                    .collect(),
                None => Vec::new(),
            };
            let mut runs =
                self.run_group(&self.net.layers, inputs, frame_deltas, engine, self.cfg.seed)?;
            if let Some((_, cache)) = delta.as_mut() {
                for r in &mut runs {
                    if let Some(fd) = r.delta.take() {
                        cache.commit(fd);
                    }
                }
            }
            let total = t0.elapsed().as_secs_f64();
            return Ok(runs
                .into_iter()
                .zip(in_lens)
                .map(|(r, n)| finalize_frame(r, 1, total, n))
                .collect());
        }
        // The cross-scene pseudo-frame group, in scene order: a planned
        // scene expands into its shards (cached per (sequence, block)),
        // a plain scene stays whole.
        let mut pseudo: Vec<SparseTensor> = Vec::new();
        let mut frame_deltas: Vec<Option<FrameDelta>> = Vec::new();
        for (i, (input, plan)) in inputs.into_iter().zip(&plans).enumerate() {
            match plan {
                Some(p) => {
                    for (si, s) in p.shards.iter().enumerate() {
                        if let Some((seqs, cache)) = &delta {
                            let _g = self
                                .obs
                                .span(Stage::DeltaPlan)
                                .sequence(seqs[i])
                                .shard(si as u32);
                            frame_deltas.push(Some(cache.begin_frame(
                                DeltaKey { sequence: seqs[i], shard: Some(s.block) },
                                &s.tensor,
                                &specs,
                                &cspecs,
                            )));
                        }
                        pseudo.push(s.tensor.clone());
                    }
                }
                None => {
                    if let Some((seqs, cache)) = &delta {
                        let _g = self.obs.span(Stage::DeltaPlan).sequence(seqs[i]);
                        frame_deltas.push(Some(cache.begin_frame(
                            DeltaKey { sequence: seqs[i], shard: None },
                            &input,
                            &specs,
                            &cspecs,
                        )));
                    }
                    pseudo.push(input);
                }
            }
        }
        let mut runs = self.run_group(prefix, pseudo, frame_deltas, engine, self.cfg.seed)?;
        if let Some((_, cache)) = delta.as_mut() {
            for r in &mut runs {
                if let Some(fd) = r.delta.take() {
                    cache.commit(fd);
                }
            }
        }
        // Collapse pseudo-frame runs back to per-scene prefix outputs.
        let mut runs = runs.into_iter();
        let mut records_per: Vec<Vec<LayerRecord>> = Vec::with_capacity(plans.len());
        let mut counters_per: Vec<(u64, u64, u64, u64)> = Vec::with_capacity(plans.len());
        let mut merged: Vec<SparseTensor> = Vec::with_capacity(plans.len());
        let mut shard_counts: Vec<u32> = Vec::with_capacity(plans.len());
        for plan in &plans {
            match plan {
                Some(p) => {
                    let _g = self.obs.span(Stage::Merge);
                    let scene_runs: Vec<GroupRun> =
                        runs.by_ref().take(p.shards.len()).collect();
                    debug_assert_eq!(scene_runs.len(), p.shards.len());
                    records_per.push(merge_records(scene_runs.iter().map(|r| &r.records)));
                    let mut searched = 0;
                    let mut reused = 0;
                    let mut waves_skipped = 0;
                    let mut rows_saved = 0;
                    for r in &scene_runs {
                        searched += r.searched;
                        reused += r.reused;
                        waves_skipped += r.waves_skipped;
                        rows_saved += r.rows_saved;
                    }
                    counters_per.push((searched, reused, waves_skipped, rows_saved));
                    merged.push(p.merge(scene_runs.iter().map(|r| r.cur.as_ref()))?);
                    shard_counts.push(p.shards.len() as u32);
                }
                None => {
                    let r = runs
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("one run per plain scene"))?;
                    records_per.push(r.records);
                    counters_per.push((r.searched, r.reused, r.waves_skipped, r.rows_saved));
                    merged.push(
                        Arc::try_unwrap(r.cur).unwrap_or_else(|arc| (*arc).clone()),
                    );
                    shard_counts.push(1);
                }
            }
        }
        let finished: Vec<GroupRun> = if suffix.is_empty() {
            merged
                .into_iter()
                .zip(records_per)
                .zip(&counters_per)
                .map(|((cur, records), &(searched, reused, waves_skipped, rows_saved))| GroupRun {
                    records,
                    cur: Arc::new(cur),
                    bev: None,
                    delta: None,
                    searched,
                    reused,
                    waves_skipped,
                    rows_saved,
                })
                .collect()
        } else {
            // Dense heads run as their own lockstep group over the
            // merged scenes; the weight-seed sequence continues exactly
            // where the prefix left off. The suffix never map-searches
            // submanifold layers, so the delta counters are the
            // prefix's.
            let seed = self.cfg.seed.wrapping_add(prefix.len() as u64);
            let tails = self.run_group(suffix, merged, Vec::new(), engine, seed)?;
            tails
                .into_iter()
                .zip(records_per)
                .zip(&counters_per)
                .map(|((t, mut records), &(searched, reused, waves_skipped, rows_saved))| {
                    records.extend(t.records);
                    GroupRun {
                        records,
                        cur: t.cur,
                        bev: t.bev,
                        delta: None,
                        searched,
                        reused,
                        waves_skipped,
                        rows_saved,
                    }
                })
                .collect()
        };
        let total = t0.elapsed().as_secs_f64();
        Ok(finished
            .into_iter()
            .zip(shard_counts)
            .zip(in_lens)
            .map(|((run, shards), n)| finalize_frame(run, shards, total, n))
            .collect())
    }

    /// Pseudo-frames a scene of `n_voxels` will occupy in a lockstep
    /// window: the shard-grid size when sharding triggers, else 1. The
    /// stream server's queue accounting charges sharded scenes a whole
    /// window with this.
    pub fn planned_shards(&self, n_voxels: usize) -> usize {
        if self.cfg.shard.active_for(n_voxels) {
            self.cfg.shard.num_blocks()
        } else {
            1
        }
    }
}

/// Assemble a [`FrameResult`] from a finished [`GroupRun`].
fn finalize_frame(run: GroupRun, shards: u32, total_seconds: f64, in_voxels: u64) -> FrameResult {
    let head_shape = run.bev.as_ref().map(|b| (b.h, b.w, b.c));
    let checksum = match &run.bev {
        Some(b) => checksum_features(&b.data),
        None => checksum_features(&run.cur.features),
    };
    FrameResult {
        out_voxels: run.cur.len() as u64,
        records: run.records,
        head_shape,
        checksum,
        shards,
        total_seconds,
        blocks_searched: run.searched,
        blocks_reused: run.reused,
        voxels_rebinned: 0,
        waves_skipped: run.waves_skipped,
        rows_gathered_saved: run.rows_saved,
        in_voxels,
    }
}

/// Element-wise aggregation of per-shard layer records (same layer
/// stack): counts and times sum, access stats accumulate, per-offset
/// workloads add up.
fn merge_records<'a>(mut shards: impl Iterator<Item = &'a Vec<LayerRecord>>) -> Vec<LayerRecord> {
    let Some(first) = shards.next() else {
        return Vec::new();
    };
    let mut acc = first.clone();
    for recs in shards {
        debug_assert_eq!(acc.len(), recs.len(), "shards ran different layer stacks");
        for (a, r) in acc.iter_mut().zip(recs) {
            a.pairs += r.pairs;
            a.out_voxels += r.out_voxels;
            a.gemm_calls += r.gemm_calls;
            a.gathered_rows += r.gathered_rows;
            a.ms_seconds += r.ms_seconds;
            a.compute_seconds += r.compute_seconds;
            a.access.add(&r.access);
            if a.workload.len() == r.workload.len() {
                for (x, y) in a.workload.iter_mut().zip(&r.workload) {
                    *x += y;
                }
            }
        }
    }
    acc
}

/// Flatten a sparse 3D tensor to a dense BEV map: z folds into channels.
pub fn to_bev(t: &SparseTensor) -> DenseMap {
    let Extent3 { x, y, z } = t.extent;
    let c_bev = t.channels * z;
    let mut m = DenseMap::zeros(y, x, c_bev);
    for (i, &c) in t.coords.iter().enumerate() {
        let px = m.pixel_mut(c.y as usize, c.x as usize);
        let base = c.z as usize * t.channels;
        px[base..base + t.channels].copy_from_slice(t.feature(i));
    }
    m
}

/// Nearest-neighbor upsample (for the deconv head model).
fn upsample(x: &DenseMap, up: usize) -> DenseMap {
    if up <= 1 {
        return x.clone();
    }
    let mut y = DenseMap::zeros(x.h * up, x.w * up, x.c);
    for oy in 0..y.h {
        for ox in 0..y.w {
            let src = x.pixel(oy / up, ox / up).to_vec();
            y.pixel_mut(oy, ox).copy_from_slice(&src);
        }
    }
    y
}

/// RPN weights for one dense layer, generated once per layer and shared
/// by every in-flight frame (matching the single-frame seed sequence).
fn conv2d_weights(c_in: usize, c_out: usize, k: usize, seed: u64) -> Vec<i8> {
    let mut rng = crate::util::rng::Pcg64::new(seed);
    (0..k * k * c_in * c_out).map(|_| rng.next_i8(-16, 16)).collect()
}

fn run_conv2d<E: GemmEngine>(
    x: &DenseMap,
    w: &[i8],
    c_out: usize,
    k: usize,
    stride: usize,
    up: usize,
    engine: &mut E,
) -> crate::Result<(DenseMap, f64)> {
    let t = stopwatch();
    let x = upsample(x, up);
    let (psums, ho, wo) = conv2d_im2col(&x, w, k, stride, c_out, engine)?;
    let scale = vec![0.03f32; c_out];
    let zero = vec![0f32; c_out];
    let feats = quant::dequant_relu_quant(&psums, &scale, &zero, c_out);
    Ok((
        DenseMap {
            h: ho,
            w: wo,
            c: c_out,
            data: feats,
        },
        t.elapsed().as_secs_f64(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Coord3;
    use crate::model::{minkunet, second};
    use crate::pointcloud::voxelize::Voxelizer;
    use crate::spconv::layer::NativeEngine;

    fn frame(extent: Extent3, n: usize, c: usize, seed: u64) -> SparseTensor {
        let g = Voxelizer::synth_occupancy(extent, n as f64 / extent.volume() as f64, seed);
        let mut t = SparseTensor::from_coords(extent, g.coords(), c);
        let mut rng = crate::util::rng::Pcg64::new(seed);
        for v in t.features.iter_mut() {
            *v = rng.next_i8(0, 8);
        }
        t
    }

    /// One frame through the lockstep loop (the non-deprecated spelling
    /// of the old `run_frame`).
    fn run_one(runner: &NetworkRunner, t: SparseTensor) -> FrameResult {
        runner
            .run_frames(vec![t], &mut NativeEngine::default())
            .unwrap()
            .pop()
            .expect("one frame in, one result out")
    }

    #[test]
    fn to_bev_roundtrip_values() {
        let e = Extent3::new(4, 3, 2);
        let mut t = SparseTensor::from_coords(
            e,
            vec![Coord3::new(1, 2, 0), Coord3::new(3, 0, 1)],
            2,
        );
        t.feature_mut(0).copy_from_slice(&[5, 6]);
        t.feature_mut(1).copy_from_slice(&[7, 8]);
        let m = to_bev(&t);
        assert_eq!((m.h, m.w, m.c), (3, 4, 4));
        assert_eq!(&m.pixel(2, 1)[0..2], &[5, 6]); // z=0 slot
        assert_eq!(&m.pixel(0, 3)[2..4], &[7, 8]); // z=1 slot
    }

    #[test]
    fn second_small_frame_end_to_end() {
        let net = second::second_small();
        let runner = NetworkRunner::new(net, RunnerConfig {
            batch: 128,
            workers: 2,
            seed: 7,
            ..Default::default()
        });
        let input = frame(Extent3::new(176, 200, 10), 1500, 4, 71);
        let res = run_one(&runner, input);
        // Detection path ends in a dense head.
        let (h, w, c) = res.head_shape.expect("detection head");
        assert_eq!(c, 128);
        assert!(h > 0 && w > 0);
        assert!(res.total_pairs() > 0);
        // Consecutive subm3 layers shared searches: some records have
        // zero MS time.
        let shared = res
            .records
            .iter()
            .filter(|r| r.name.contains("Subm3") && r.ms_seconds == 0.0)
            .count();
        assert!(shared >= 3, "expected shared subm searches, got {shared}");
    }

    #[test]
    fn minkunet_small_frame_end_to_end() {
        let net = minkunet::minkunet_small();
        let runner = NetworkRunner::new(net, RunnerConfig {
            batch: 128,
            workers: 2,
            seed: 8,
            ..Default::default()
        });
        let input = frame(Extent3::new(128, 128, 16), 1200, 4, 72);
        let res = run_one(&runner, input);
        assert!(res.head_shape.is_none());
        assert!(res.out_voxels > 0);
        // UNet output voxel count >= input (upsampled back + dilation).
        assert!(res.records.last().unwrap().out_voxels >= 1000);
    }

    #[test]
    fn lockstep_group_matches_single_frame_results() {
        let net = second::second_small();
        let cfg = RunnerConfig {
            batch: 96,
            workers: 2,
            seed: 9,
            ..Default::default()
        };
        let runner = NetworkRunner::new(net, cfg);
        let inputs: Vec<SparseTensor> = (0..3)
            .map(|i| frame(Extent3::new(176, 200, 10), 900 + 150 * i, 4, 80 + i as u64))
            .collect();
        let batched = runner
            .run_frames(inputs.clone(), &mut NativeEngine::default())
            .unwrap();
        for (input, got) in inputs.into_iter().zip(&batched) {
            let want = run_one(&runner, input);
            assert_eq!(want.checksum, got.checksum, "frame outputs diverged");
            assert_eq!(want.head_shape, got.head_shape);
            assert_eq!(want.total_pairs(), got.total_pairs());
            for (a, b) in want.records.iter().zip(&got.records) {
                assert_eq!(a.pairs, b.pairs, "{}", a.name);
                assert_eq!(a.out_voxels, b.out_voxels, "{}", a.name);
            }
        }
    }

    #[test]
    fn every_searcher_kind_yields_identical_frame_checksums() {
        let net = minkunet::minkunet_small();
        let input = frame(Extent3::new(128, 128, 16), 800, 4, 91);
        let mut checksums = Vec::new();
        for kind in SearcherKind::ALL {
            let runner = NetworkRunner::new(
                net.clone(),
                RunnerConfig {
                    searcher: kind,
                    seed: 10,
                    ..Default::default()
                },
            );
            let res = run_one(&runner, input.clone());
            checksums.push((kind, res.checksum));
        }
        let want = checksums[0].1;
        for (kind, got) in checksums {
            assert_eq!(got, want, "{kind} changed the frame output");
        }
    }

    #[test]
    fn runner_config_parses_from_run_config() {
        let cfg = Config::parse(
            "[runner]\nbatch = 128\nworkers = 3\ncompute_workers = 4\ninflight = 2\nsearcher = \"octree\"\nseed = 99",
        )
        .unwrap();
        let rc = RunnerConfig::from_config(&cfg).unwrap();
        assert_eq!(rc.batch, 128);
        assert_eq!(rc.workers, 3);
        assert_eq!(rc.compute_workers, 4);
        assert_eq!(rc.inflight, 2);
        assert_eq!(rc.searcher, SearcherKind::Octree);
        assert_eq!(rc.seed, 99);
        // Missing section -> defaults (delta cache off).
        let rc = RunnerConfig::from_config(&Config::parse("").unwrap()).unwrap();
        assert_eq!(rc.searcher, SearcherKind::Doms);
        assert_eq!(rc.batch, 256);
        assert_eq!(rc.w2b_factor, 0);
        assert_eq!(rc.shard, ShardConfig::default());
        assert_eq!(rc.delta, DeltaConfig::default());
        assert!(!rc.delta.enabled);
    }

    #[test]
    fn delta_config_keys_parse_strictly() {
        let cfg = Config::parse(
            "[runner]\ndelta = true\ndelta_blocks_x = 4\ndelta_blocks_y = 16\ndelta_max_entries = 3",
        )
        .unwrap();
        let rc = RunnerConfig::from_config(&cfg).unwrap();
        assert!(rc.delta.enabled);
        assert_eq!(rc.delta.blocks_x, 4);
        assert_eq!(rc.delta.blocks_y, 16);
        assert_eq!(rc.delta.max_entries, 3);
        for bad in [
            "[runner]\ndelta = 3",
            "[runner]\ndelta = \"yes\"",
            "[runner]\ndelta_blocks_x = 0",
            "[runner]\ndelta_blocks_y = -1",
            "[runner]\ndelta_max_entries = 0",
        ] {
            let cfg = Config::parse(bad).unwrap();
            assert!(RunnerConfig::from_config(&cfg).is_err(), "{bad}");
        }
    }

    #[test]
    fn shard_and_w2b_config_keys_parse_strictly() {
        let cfg = Config::parse(
            "[runner]\nw2b_factor = 2\n[shard]\nblocks_x = 2\nblocks_y = 8\nauto_threshold = 5000",
        )
        .unwrap();
        let rc = RunnerConfig::from_config(&cfg).unwrap();
        assert_eq!(rc.w2b_factor, 2);
        assert_eq!(
            rc.shard,
            ShardConfig {
                blocks_x: 2,
                blocks_y: 8,
                auto_threshold: 5000
            }
        );
        // Strict `[shard]` keys: zero-sized grids, negative counts, and
        // non-integer values are config errors, never silent fallbacks.
        for bad in [
            "[shard]\nblocks_x = 0",
            "[shard]\nblocks_y = 0",
            "[shard]\nblocks_x = \"two\"",
            "[shard]\nauto_threshold = -1",
            "[runner]\nw2b_factor = -2",
        ] {
            let cfg = Config::parse(bad).unwrap();
            assert!(RunnerConfig::from_config(&cfg).is_err(), "{bad}");
        }
    }
}
