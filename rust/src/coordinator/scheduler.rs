//! The network scheduler: drives one frame through the full request path
//! — map search (on the worker pool, MS-wise pipelined) → gather / GEMM /
//! scatter via a [`GemmEngine`] → BEV flatten → RPN — and reports
//! per-layer statistics.
//!
//! This is the leader loop of the system: pure rust, artifacts already
//! compiled, no python anywhere.

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::executor::WorkerPool;
use crate::geom::Extent3;
use crate::mapsearch::{AccessStats, Doms, MapSearch};
use crate::model::layer::{LayerSpec, NetworkSpec};
use crate::sparse::rulebook::{ConvKind, Rulebook};
use crate::sparse::tensor::SparseTensor;
use crate::spconv::conv2d::{conv2d_im2col, DenseMap};
use crate::spconv::layer::{GemmEngine, LayerWeights, SpconvLayer};
use crate::spconv::quant;

/// Scheduler configuration.
#[derive(Clone, Debug)]
pub struct RunnerConfig {
    /// GEMM wave batch size.
    pub batch: usize,
    /// Worker threads for map search.
    pub workers: usize,
    /// Weight seed (weights are random — hardware cost is value-free).
    pub seed: u64,
}

impl Default for RunnerConfig {
    fn default() -> Self {
        Self {
            batch: 256,
            workers: 2,
            seed: 0x5EC0,
        }
    }
}

/// Per-layer record.
#[derive(Clone, Debug)]
pub struct LayerRecord {
    pub name: String,
    pub pairs: u64,
    pub out_voxels: u64,
    pub gemm_calls: u64,
    pub ms_seconds: f64,
    pub compute_seconds: f64,
    pub access: AccessStats,
    /// Per-offset workload (for W2B studies).
    pub workload: Vec<u64>,
}

/// Result of one frame.
#[derive(Debug)]
pub struct FrameResult {
    pub records: Vec<LayerRecord>,
    /// Segmentation: per-voxel logits tensor. Detection: BEV head output.
    pub out_voxels: u64,
    /// Dense head output (detection): (h, w, c).
    pub head_shape: Option<(usize, usize, usize)>,
    pub total_seconds: f64,
}

impl FrameResult {
    pub fn total_pairs(&self) -> u64 {
        self.records.iter().map(|r| r.pairs).sum()
    }
    pub fn ms_seconds(&self) -> f64 {
        self.records.iter().map(|r| r.ms_seconds).sum()
    }
    pub fn compute_seconds(&self) -> f64 {
        self.records.iter().map(|r| r.compute_seconds).sum()
    }
}

/// The network runner.
pub struct NetworkRunner {
    pub net: NetworkSpec,
    pub cfg: RunnerConfig,
    pool: WorkerPool,
}

impl NetworkRunner {
    pub fn new(net: NetworkSpec, cfg: RunnerConfig) -> Self {
        let pool = WorkerPool::new(cfg.workers.max(1));
        Self { net, cfg, pool }
    }

    /// Run one frame through the network.
    pub fn run_frame<E: GemmEngine>(
        &self,
        input: SparseTensor,
        engine: &mut E,
    ) -> crate::Result<FrameResult> {
        let t0 = Instant::now();
        let mut records = Vec::new();
        let mut cur = input;
        let mut bev: Option<DenseMap> = None;
        let mut weight_seed = self.cfg.seed;

        // MS-wise pipelining: the *next* sparse layer's map search runs on
        // the worker pool while the current layer computes. `pending`
        // holds the handle for the upcoming layer when its geometry is
        // already determined (consecutive subm3 share geometry).
        let mut shared_rb: Option<Arc<Rulebook>> = None;
        // UNet skip connections: gconv2 pushes its input coordinate set;
        // tconv2 pops it and prunes its outputs to that set (MinkUNet's
        // decoder semantics — without this, coordinates dilate 8x per
        // upsampling stage).
        let mut skip_stack: Vec<(Extent3, Vec<crate::geom::Coord3>)> = Vec::new();

        let mut i = 0usize;
        let layers = self.net.layers.clone();
        while i < layers.len() {
            let spec = layers[i];
            match spec {
                LayerSpec::Subm3 { .. } | LayerSpec::GConv2 { .. } | LayerSpec::TConv2 { .. } => {
                    let kind = spec.conv_kind().unwrap();
                    let (c_in_decl, c_out) = spec.channels();
                    let c_in = cur.channels;
                    debug_assert!(
                        c_in == c_in_decl || i == 0,
                        "channel drift at layer {i}: {c_in} vs {c_in_decl}"
                    );
                    // Map search (shared for consecutive subm3).
                    if matches!(kind, ConvKind::Generalized { .. }) {
                        skip_stack.push((cur.extent, cur.coords.clone()));
                    }
                    let reuse = matches!(kind, ConvKind::Submanifold { .. })
                        && shared_rb
                            .as_ref()
                            .map(|rb| rb.out_coords == cur.coords)
                            .unwrap_or(false);
                    let skip_target = match kind {
                        ConvKind::Transposed { .. } => skip_stack.pop(),
                        _ => None,
                    };
                    let (rb, access, ms_secs) = if reuse {
                        (shared_rb.clone().unwrap(), AccessStats::default(), 0.0)
                    } else if let (ConvKind::Transposed { k, stride }, Some((ext, target))) =
                        (kind, skip_target)
                    {
                        // Pruned transposed conv (UNet decoder): outputs
                        // restricted to the matching encoder stage.
                        let t = Instant::now();
                        let rb = crate::sparse::hash_search::tconv_pruned(
                            &cur, k, stride, ext, &target,
                        );
                        let access = AccessStats {
                            voxel_reads: cur.len() as u64 + target.len() as u64,
                            ..Default::default()
                        };
                        shared_rb = None;
                        (Arc::new(rb), access, t.elapsed().as_secs_f64())
                    } else {
                        let coords_tensor =
                            SparseTensor::from_coords(cur.extent, cur.coords.clone(), 1);
                        let handle = self.pool.submit(move || {
                            let t = Instant::now();
                            let (rb, st) = Doms::default().search(&coords_tensor, kind);
                            (rb, st, t.elapsed().as_secs_f64())
                        });
                        let (rb, st, secs) = handle.join();
                        let rb = Arc::new(rb);
                        if matches!(kind, ConvKind::Submanifold { .. }) {
                            shared_rb = Some(rb.clone());
                        } else {
                            shared_rb = None;
                        }
                        (rb, st, secs)
                    };

                    let weights =
                        LayerWeights::random(spec.kernel_volume(), c_in, c_out, weight_seed);
                    weight_seed = weight_seed.wrapping_add(1);
                    let layer = SpconvLayer::new(weights, self.cfg.batch);
                    let tc = Instant::now();
                    let out = layer.execute(&cur, &rb, engine)?;
                    let compute_seconds = tc.elapsed().as_secs_f64();
                    records.push(LayerRecord {
                        name: format!("{spec:?}"),
                        pairs: rb.len() as u64,
                        out_voxels: rb.out_coords.len() as u64,
                        gemm_calls: out.gemm_calls,
                        ms_seconds: ms_secs,
                        compute_seconds,
                        access,
                        workload: rb.workload_per_offset(),
                    });
                    cur = out.tensor;
                }
                LayerSpec::ToBev => {
                    bev = Some(to_bev(&cur));
                    records.push(LayerRecord {
                        name: "ToBev".into(),
                        pairs: 0,
                        out_voxels: cur.len() as u64,
                        gemm_calls: 0,
                        ms_seconds: 0.0,
                        compute_seconds: 0.0,
                        access: AccessStats::default(),
                        workload: Vec::new(),
                    });
                }
                LayerSpec::Conv2d { c_out, k, stride, .. } => {
                    let x = bev.take().expect("Conv2d before ToBev");
                    let tc = Instant::now();
                    let (y, secs) =
                        run_conv2d(&x, c_out, k, stride, 1, weight_seed, engine)?;
                    weight_seed = weight_seed.wrapping_add(1);
                    let _ = tc;
                    records.push(LayerRecord {
                        name: format!("{spec:?}"),
                        pairs: (y.h * y.w) as u64 * (k * k) as u64,
                        out_voxels: (y.h * y.w) as u64,
                        gemm_calls: 0,
                        ms_seconds: 0.0,
                        compute_seconds: secs,
                        access: AccessStats::default(),
                        workload: Vec::new(),
                    });
                    bev = Some(y);
                }
                LayerSpec::Deconv2d { c_out, k, up, .. } => {
                    let x = bev.take().expect("Deconv2d before ToBev");
                    let (y, secs) = run_conv2d(&x, c_out, k, 1, up, weight_seed, engine)?;
                    weight_seed = weight_seed.wrapping_add(1);
                    records.push(LayerRecord {
                        name: format!("{spec:?}"),
                        pairs: (y.h * y.w) as u64 * (k * k) as u64,
                        out_voxels: (y.h * y.w) as u64,
                        gemm_calls: 0,
                        ms_seconds: 0.0,
                        compute_seconds: secs,
                        access: AccessStats::default(),
                        workload: Vec::new(),
                    });
                    bev = Some(y);
                }
            }
            i += 1;
        }

        let head_shape = bev.as_ref().map(|b| (b.h, b.w, b.c));
        Ok(FrameResult {
            out_voxels: cur.len() as u64,
            records,
            head_shape,
            total_seconds: t0.elapsed().as_secs_f64(),
        })
    }
}

/// Flatten a sparse 3D tensor to a dense BEV map: z folds into channels.
pub fn to_bev(t: &SparseTensor) -> DenseMap {
    let Extent3 { x, y, z } = t.extent;
    let c_bev = t.channels * z;
    let mut m = DenseMap::zeros(y, x, c_bev);
    for (i, &c) in t.coords.iter().enumerate() {
        let px = m.pixel_mut(c.y as usize, c.x as usize);
        let base = c.z as usize * t.channels;
        px[base..base + t.channels].copy_from_slice(t.feature(i));
    }
    m
}

/// Nearest-neighbor upsample (for the deconv head model).
fn upsample(x: &DenseMap, up: usize) -> DenseMap {
    if up <= 1 {
        return x.clone();
    }
    let mut y = DenseMap::zeros(x.h * up, x.w * up, x.c);
    for oy in 0..y.h {
        for ox in 0..y.w {
            let src = x.pixel(oy / up, ox / up).to_vec();
            y.pixel_mut(oy, ox).copy_from_slice(&src);
        }
    }
    y
}

fn run_conv2d<E: GemmEngine>(
    x: &DenseMap,
    c_out: usize,
    k: usize,
    stride: usize,
    up: usize,
    seed: u64,
    engine: &mut E,
) -> crate::Result<(DenseMap, f64)> {
    let t = Instant::now();
    let x = upsample(x, up);
    let mut rng = crate::util::rng::Pcg64::new(seed);
    let w: Vec<i8> = (0..k * k * x.c * c_out).map(|_| rng.next_i8(-16, 16)).collect();
    let (psums, ho, wo) = conv2d_im2col(&x, &w, k, stride, c_out, engine)?;
    let scale = vec![0.03f32; c_out];
    let zero = vec![0f32; c_out];
    let feats = quant::dequant_relu_quant(&psums, &scale, &zero, c_out);
    Ok((
        DenseMap {
            h: ho,
            w: wo,
            c: c_out,
            data: feats,
        },
        t.elapsed().as_secs_f64(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Coord3;
    use crate::model::{minkunet, second};
    use crate::pointcloud::voxelize::Voxelizer;
    use crate::spconv::layer::NativeEngine;

    fn frame(extent: Extent3, n: usize, c: usize, seed: u64) -> SparseTensor {
        let g = Voxelizer::synth_occupancy(extent, n as f64 / extent.volume() as f64, seed);
        let mut t = SparseTensor::from_coords(extent, g.coords(), c);
        let mut rng = crate::util::rng::Pcg64::new(seed);
        for v in t.features.iter_mut() {
            *v = rng.next_i8(0, 8);
        }
        t
    }

    #[test]
    fn to_bev_roundtrip_values() {
        let e = Extent3::new(4, 3, 2);
        let mut t = SparseTensor::from_coords(
            e,
            vec![Coord3::new(1, 2, 0), Coord3::new(3, 0, 1)],
            2,
        );
        t.feature_mut(0).copy_from_slice(&[5, 6]);
        t.feature_mut(1).copy_from_slice(&[7, 8]);
        let m = to_bev(&t);
        assert_eq!((m.h, m.w, m.c), (3, 4, 4));
        assert_eq!(&m.pixel(2, 1)[0..2], &[5, 6]); // z=0 slot
        assert_eq!(&m.pixel(0, 3)[2..4], &[7, 8]); // z=1 slot
    }

    #[test]
    fn second_small_frame_end_to_end() {
        let net = second::second_small();
        let runner = NetworkRunner::new(net, RunnerConfig {
            batch: 128,
            workers: 2,
            seed: 7,
        });
        let input = frame(Extent3::new(176, 200, 10), 1500, 4, 71);
        let res = runner.run_frame(input, &mut NativeEngine::default()).unwrap();
        // Detection path ends in a dense head.
        let (h, w, c) = res.head_shape.expect("detection head");
        assert_eq!(c, 128);
        assert!(h > 0 && w > 0);
        assert!(res.total_pairs() > 0);
        // Consecutive subm3 layers shared searches: some records have
        // zero MS time.
        let shared = res
            .records
            .iter()
            .filter(|r| r.name.contains("Subm3") && r.ms_seconds == 0.0)
            .count();
        assert!(shared >= 3, "expected shared subm searches, got {shared}");
    }

    #[test]
    fn minkunet_small_frame_end_to_end() {
        let net = minkunet::minkunet_small();
        let runner = NetworkRunner::new(net, RunnerConfig {
            batch: 128,
            workers: 2,
            seed: 8,
        });
        let input = frame(Extent3::new(128, 128, 16), 1200, 4, 72);
        let res = runner.run_frame(input, &mut NativeEngine::default()).unwrap();
        assert!(res.head_shape.is_none());
        assert!(res.out_voxels > 0);
        // UNet output voxel count >= input (upsampled back + dilation).
        assert!(res.records.last().unwrap().out_voxels >= 1000);
    }
}
