//! A small fixed worker pool over `std::thread` + `mpsc` (the vendored
//! registry has no tokio; map-search jobs are CPU-bound anyway, so a
//! thread pool is the right substrate).
//!
//! Jobs are `FnOnce` closures; `submit` returns a [`JobHandle`] whose
//! `join` blocks for the result. The scheduler uses this to run the next
//! layer's map search concurrently with the current layer's compute (the
//! MS-wise pipeline of Fig. 8).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool.
pub struct WorkerPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

/// Handle to a submitted job's result.
pub struct JobHandle<T> {
    rx: mpsc::Receiver<T>,
}

impl<T> JobHandle<T> {
    /// Block until the job finishes.
    pub fn join(self) -> T {
        // vcim:allow(panic-freedom) a closed result channel means the job itself panicked; propagating that panic to the joiner is the documented contract
        self.rx.recv().expect("worker dropped result channel")
    }

    /// Non-blocking poll.
    pub fn try_join(&self) -> Option<T> {
        self.rx.try_recv().ok()
    }
}

impl WorkerPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads >= 1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("voxel-cim-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            // vcim:allow(panic-freedom) the mutex guards only `recv()`, which cannot panic, so the lock is never poisoned
                            let guard = rx.lock().expect("poisoned job queue");
                            guard.recv()
                        };
                        match job {
                            // Contain job panics to the job: the worker
                            // survives and the job's result channel simply
                            // closes (join() then panics in the caller).
                            Ok(job) => {
                                let _ = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(job),
                                );
                            }
                            Err(_) => break, // pool dropped
                        }
                    })
                    // vcim:allow(panic-freedom) thread spawn fails only on OS resource exhaustion at pool construction; no typed-error path exists from new()
                    .expect("spawning worker")
            })
            .collect();
        Self {
            tx: Some(tx),
            workers,
        }
    }

    /// Number of worker threads (used by callers to size work chunks).
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job; the closure runs on a worker thread.
    pub fn submit<T, F>(&self, f: F) -> JobHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (rtx, rrx) = mpsc::channel();
        let job: Job = Box::new(move || {
            let out = f();
            let _ = rtx.send(out); // receiver may have been dropped
        });
        self.tx
            .as_ref()
            // vcim:allow(panic-freedom) tx is Some for the pool's whole lifetime; it is taken only in Drop, after which submit() is unreachable
            .expect("pool shut down")
            .send(job)
            // vcim:allow(panic-freedom) workers only exit after the sender drops, so a send on a live pool cannot fail
            .expect("workers alive");
        JobHandle { rx: rrx }
    }

    /// Map a function over items in parallel, preserving order.
    pub fn map<T, U, F>(&self, items: Vec<T>, f: F) -> Vec<U>
    where
        T: Send + 'static,
        U: Send + 'static,
        F: Fn(T) -> U + Send + Sync + Clone + 'static,
    {
        let handles: Vec<JobHandle<U>> = items
            .into_iter()
            .map(|it| {
                let f = f.clone();
                self.submit(move || f(it))
            })
            .collect();
        handles.into_iter().map(JobHandle::join).collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn submit_and_join() {
        let pool = WorkerPool::new(2);
        let h = pool.submit(|| 40 + 2);
        assert_eq!(h.join(), 42);
    }

    #[test]
    fn map_preserves_order() {
        let pool = WorkerPool::new(4);
        let out = pool.map((0..32).collect(), |x: i32| x * x);
        assert_eq!(out, (0..32).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn jobs_actually_run_concurrently() {
        let pool = WorkerPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                    // Busy-wait until all four jobs are in flight (proves
                    // >1 worker) with a timeout escape.
                    let start = std::time::Instant::now();
                    while c.load(Ordering::SeqCst) < 4 {
                        if start.elapsed().as_secs() > 5 {
                            return false;
                        }
                        std::hint::spin_loop();
                    }
                    true
                })
            })
            .collect();
        assert!(handles.into_iter().all(|h| h.join()));
    }

    #[test]
    fn drop_joins_workers_cleanly() {
        let pool = WorkerPool::new(2);
        let _ = pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn panicking_job_does_not_kill_pool_consumers() {
        let pool = WorkerPool::new(1);
        // A panicking job poisons nothing outside its closure: the result
        // channel just closes.
        let h = pool.submit(|| -> i32 { panic!("job failure") });
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| h.join()));
        assert!(r.is_err());
    }
}
