//! Frame-stream serving: the leader loop that keeps the map-search core
//! and the computing core busy across *consecutive frames*, extending the
//! Fig. 8 hybrid pipeline from layers to the frame stream.
//!
//! Frames come from any [`FrameSource`] — KITTI sequences, scenario
//! profiles, trace replay, closure adapters, or several sequences striped
//! through a [`SequenceMux`](crate::serving::SequenceMux) — optionally
//! behind a prefetching buffer (backpressure: a buffered producer blocks
//! when the accelerator falls behind). The server admits frames into a
//! bounded pending queue, cuts *lockstep windows* from its front, and
//! runs each window through the engine layer: all window members' map
//! searches fan out over the worker pool and their rule pairs pack into
//! shared GEMM waves, amortizing engine dispatch overhead across the
//! stream without changing any frame's bits.
//!
//! Window packing is policy-driven ([`WindowPolicy`]): the historical
//! `Exclusive` accounting gives a sharding scene a window of its own,
//! while `CrossScene` packs pseudo-frames of *different* queued scenes
//! into one window under an `inflight`-slot budget
//! ([`NetworkRunner::run_scenes`]). Either way each completion carries
//! both its end-to-end latency and a per-scene *attributed* latency
//! (queue wait + the scene's own share of its window), which is what the
//! SLO-aware [`AdmissionController`](crate::serving::AdmissionController)
//! estimates p95 over when shedding load.

use std::collections::VecDeque;
use std::time::Instant;

use crate::coordinator::pipeline::{HybridPipeline, PhaseTiming};
use crate::coordinator::scheduler::{FrameResult, NetworkRunner, RunnerConfig};
use crate::dataset::{ClosureSource, FramePoll, FrameSource, PrefetchSource, SourcedFrame};
use crate::model::layer::NetworkSpec;
use crate::obs::cost::{CostModel, CostSummary, FrameCost};
use crate::obs::{Recorder, Stage, stopwatch};
use crate::serving::{AdmissionConfig, AdmissionController, AdmissionReport, WindowPolicy};
use crate::sparse::tensor::SparseTensor;
use crate::spconv::layer::GemmEngine;
use crate::util::stats::{percentile, LatencySummary};

/// Completion record for one frame. The pseudo-frame count of a
/// block-sharded scene is carried by `result.shards`.
#[derive(Debug)]
pub struct FrameCompletion {
    pub id: u64,
    /// Muxed sequence the frame came from (0 on single-sequence
    /// streams); frame identity on a muxed stream is `(sequence, id)`.
    pub sequence: u32,
    pub result: FrameResult,
    /// End-to-end wall latency, seconds: production → window completion.
    /// Frames of one lockstep window complete together, so this includes
    /// the *whole window's* makespan for every member.
    pub latency: f64,
    /// Per-scene attributed latency, seconds: queue wait plus this
    /// scene's *own* map-search and compute share of its window (the
    /// records' pair-proportional attribution), clamped to `latency` —
    /// a sharded scene's concurrent shard searches sum past the wall
    /// otherwise. The scene's end-to-end cost rather than the window's:
    /// a small frame packed next to a monopolizing scene reports its
    /// own cost here. The SLO admission estimator consumes exactly this
    /// signal.
    pub attributed: f64,
}

/// Stream-level statistics.
#[derive(Debug)]
pub struct StreamReport {
    pub completions: Vec<FrameCompletion>,
    pub wall_seconds: f64,
    /// Lockstep windows the server cut (engine entry count — the
    /// cross-scene packer's win shows up as fewer windows at equal
    /// frames).
    pub windows: u64,
    /// Admission actions taken while serving (all zero without an
    /// active policy).
    pub admission: AdmissionReport,
    /// Blocks map-searched across the stream by the temporal delta
    /// cache (dirty + halo on warm frames, every occupied block on cold
    /// ones). Zero when `RunnerConfig::delta` is off.
    pub blocks_searched: u64,
    /// Blocks whose rulebook fragments were spliced from the cache
    /// instead of searched. Zero when the cache is off.
    pub blocks_reused: u64,
    /// Cache entries displaced by the `delta_max_entries` bound.
    pub evictions: u64,
    /// Voxels actually re-binned by the sources across the stream: with
    /// delta voxelization only the dirty blocks' voxels, otherwise every
    /// occupied voxel of every KITTI frame (zero for synthetic sources,
    /// which have no voxelization stage).
    pub voxels_rebinned: u64,
    /// Shared GEMM waves skipped across the stream by compute-core reuse
    /// (zero unless `delta_compute` is on).
    pub waves_skipped: u64,
    /// Gather rows (rule pairs) compute-core reuse dropped from wave
    /// packing across the stream (zero unless `delta_compute` is on).
    pub rows_gathered_saved: u64,
    /// Per-stage span durations (seconds) recorded while this stream was
    /// served, indexed by [`Stage::index`] — always [`Stage::COUNT`]
    /// buckets, all empty when observability is off.
    pub stage_seconds: Vec<Vec<f64>>,
}

impl StreamReport {
    /// Frames per wall second; 0 for an empty stream (never NaN).
    pub fn throughput_fps(&self) -> f64 {
        if self.completions.is_empty() || self.wall_seconds <= 0.0 {
            return 0.0;
        }
        self.completions.len() as f64 / self.wall_seconds
    }
    /// Median end-to-end latency, seconds; 0 for an empty stream.
    pub fn latency_p50(&self) -> f64 {
        let xs = self.latencies();
        if xs.is_empty() {
            0.0
        } else {
            percentile(&xs, 50.0)
        }
    }
    /// p95 end-to-end latency, seconds; 0 for an empty stream.
    pub fn latency_p95(&self) -> f64 {
        let xs = self.latencies();
        if xs.is_empty() {
            0.0
        } else {
            percentile(&xs, 95.0)
        }
    }
    fn latencies(&self) -> Vec<f64> {
        self.completions.iter().map(|c| c.latency).collect()
    }

    /// Per-stage latency summaries over the spans recorded during this
    /// serve, in dataflow order (keys match [`Stage::key`]). Empty when
    /// observability is off or no spans were recorded.
    pub fn stage_summary(&self) -> Vec<(&'static str, LatencySummary)> {
        Stage::ALL
            .iter()
            .filter_map(|s| {
                self.stage_seconds
                    .get(s.index())
                    .and_then(|durs| LatencySummary::of(durs))
                    .map(|sum| (s.key(), sum))
            })
            .collect()
    }

    /// Modeled data-movement and energy roll-up of the served frames
    /// (see [`CostModel`]): total/DRAM/buffer bytes, joules, effective
    /// TOPS/W, the Fig. 2d / Fig. 9 normalized access volume, and the
    /// warm-vs-cold delta-cache DRAM split. Pure over the completions —
    /// available whether or not observability was on during the serve.
    pub fn cost_summary(&self) -> CostSummary {
        CostModel::default().summarize(self.completions.iter().map(|c| &c.result))
    }

    /// Fraction of occupied blocks served from the temporal delta cache
    /// instead of map-searched; 0 when the cache is off (or nothing ran).
    pub fn reuse_ratio(&self) -> f64 {
        let total = self.blocks_searched + self.blocks_reused;
        if total == 0 {
            0.0
        } else {
            self.blocks_reused as f64 / total as f64
        }
    }

    /// Summary of end-to-end latencies; `None` for an empty stream.
    pub fn latency_summary(&self) -> Option<LatencySummary> {
        LatencySummary::of(&self.latencies())
    }

    /// Summary of per-scene *attributed* latencies (see
    /// [`FrameCompletion::attributed`]); `None` for an empty stream.
    pub fn attributed_summary(&self) -> Option<LatencySummary> {
        let xs: Vec<f64> = self.completions.iter().map(|c| c.attributed).collect();
        LatencySummary::of(&xs)
    }

    /// Project the measured per-layer phase timings of every served frame
    /// through the Fig. 8 hybrid pipeline chained across frame boundaries
    /// — the accelerator-side latency this stream would see if the MS and
    /// compute cores double-buffered consecutive frames. Returns the
    /// modeled stream makespan in seconds.
    pub fn modeled_pipeline_seconds(&self, pipe: &HybridPipeline) -> f64 {
        let frames: Vec<Vec<PhaseTiming>> = self
            .completions
            .iter()
            .map(|c| {
                c.result
                    .records
                    .iter()
                    .map(|r| PhaseTiming {
                        ms: r.ms_seconds,
                        compute: r.compute_seconds,
                    })
                    .collect()
            })
            .collect();
        pipe.schedule_stream(&frames).total
    }
}

/// Streaming server over a [`NetworkRunner`].
pub struct StreamServer {
    runner: NetworkRunner,
    /// Bounded queue depth (backpressure threshold).
    pub queue_depth: usize,
    /// Lockstep-window packing policy.
    window: WindowPolicy,
    /// SLO-aware admission (policy `None` by default: every offered
    /// frame is admitted and the pending bound is plain backpressure).
    admission: AdmissionConfig,
    /// Stage-span / metrics recorder ([`Recorder::Disabled`] by default:
    /// every hot path stays allocation- and lock-free).
    obs: Recorder,
}

impl StreamServer {
    pub fn new(net: NetworkSpec, cfg: RunnerConfig, queue_depth: usize) -> Self {
        assert!(queue_depth >= 1);
        Self {
            runner: NetworkRunner::new(net, cfg),
            queue_depth,
            window: WindowPolicy::Exclusive,
            admission: AdmissionConfig::default(),
            obs: Recorder::Disabled,
        }
    }

    /// Select the lockstep-window packing policy (default
    /// [`WindowPolicy::Exclusive`], the historical accounting).
    pub fn with_window(mut self, window: WindowPolicy) -> Self {
        self.window = window;
        self
    }

    /// The engine-layer runner this server drives. The pipeline facade
    /// routes `Job::Frame` / `Job::Window` submissions through it
    /// (`run_scenes`), so frame and stream jobs share one executor.
    pub fn runner(&self) -> &NetworkRunner {
        &self.runner
    }

    /// Attach an SLO-aware admission config (default: no policy).
    pub fn with_admission(mut self, admission: AdmissionConfig) -> Self {
        self.admission = admission;
        self
    }

    /// Attach a stage-span / metrics recorder. The engine-layer runner
    /// shares the same recorder, so map-search / gather / GEMM / scatter
    /// spans and the serving spans (admission, window packing) land in
    /// one trace.
    pub fn with_observer(mut self, obs: Recorder) -> Self {
        self.runner.set_observer(obs.clone());
        self.obs = obs;
        self
    }

    /// The attached recorder ([`Recorder::Disabled`] by default).
    pub fn observer(&self) -> &Recorder {
        &self.obs
    }

    /// Serve up to `n_frames` frames from any [`FrameSource`] — a KITTI
    /// sequence, a scenario profile, a trace replay, a sequence mux, a
    /// prefetched wrapper, or a [`ClosureSource`] adapter. The stream
    /// ends early if the source is exhausted. Processing runs on the
    /// caller thread with the engine; production overlaps when the
    /// source buffers (wrap it in a [`PrefetchSource`], or use
    /// [`Self::serve_closure`]).
    ///
    /// Each iteration admits ready frames into a bounded pending queue
    /// (blocking only when the queue is empty — latency is never traded
    /// for batch size), lets the admission policy act on the backlog,
    /// and cuts one lockstep window from the front per [`WindowPolicy`]:
    ///
    /// * `Exclusive` — a scene that `cfg.shard` splits occupies a whole
    ///   window by itself; plain frames group up to
    ///   `RunnerConfig::inflight`.
    /// * `CrossScene` — scenes are charged their pseudo-frame count
    ///   against an `inflight`-slot budget, so shards of different
    ///   queued scenes share one window
    ///   ([`NetworkRunner::run_scenes`]).
    ///
    /// Per-frame results are bit-identical across policies, window
    /// compositions, and admission reorderings (never across drops:
    /// a dropped frame has no result at all).
    pub fn serve<E: GemmEngine>(
        &self,
        n_frames: u64,
        source: &mut dyn FrameSource,
        engine: &mut E,
    ) -> crate::Result<StreamReport> {
        let inflight = self.runner.cfg.inflight.max(1);
        let depth = self.admission.effective_depth(inflight);
        let t0 = stopwatch();
        let mut admission = AdmissionController::new(self.admission);
        let mut completions = Vec::with_capacity(n_frames as usize);
        let mut windows: u64 = 0;
        // Temporal delta cache, scoped to this serve: entries key on
        // (FrameMeta::sequence, shard block), so muxed sequences never
        // cross-invalidate and solo streams (sequence 0) reuse across
        // consecutive frames.
        let mut cache = if self.runner.cfg.delta.enabled {
            Some(crate::mapsearch::DeltaCache::new(self.runner.cfg.delta))
        } else {
            None
        };
        let mut blocks_searched: u64 = 0;
        let mut blocks_reused: u64 = 0;
        let mut voxels_rebinned: u64 = 0;
        let mut waves_skipped: u64 = 0;
        let mut rows_gathered_saved: u64 = 0;
        // Admitted frames waiting for a window slot, in arrival order.
        let mut pending: VecDeque<SourcedFrame> = VecDeque::new();
        // Frames pulled from the source so far (bounds total pulls at
        // `n_frames` even over endless sources).
        let mut pulled: u64 = 0;
        let mut exhausted = false;
        // Spans committed before this serve (a reused recorder carries
        // prior streams' spans): this report buckets only what follows.
        let span_base = self.obs.span_count();
        while (completions.len() as u64) < n_frames {
            // Refill: block for one frame when nothing is queued, then
            // top up opportunistically ([`FrameSource::poll_frame`] —
            // never waiting for a frame that has not been produced yet).
            let planned = |n: usize| self.runner.planned_shards(n);
            if pending.is_empty() && !exhausted && pulled < n_frames {
                match source.next_frame() {
                    Some(f) => {
                        pulled += 1;
                        let _g = self
                            .obs
                            .span(Stage::Admission)
                            .frame(f.meta.id)
                            .sequence(f.meta.sequence);
                        admission.offer(&mut pending, f, inflight, planned);
                    }
                    None => exhausted = true,
                }
            }
            while !exhausted && pulled < n_frames && pending.len() < depth {
                match source.poll_frame() {
                    FramePoll::Ready(Some(f)) => {
                        pulled += 1;
                        let g = self
                            .obs
                            .span(Stage::Admission)
                            .frame(f.meta.id)
                            .sequence(f.meta.sequence);
                        let shed = admission.offer(&mut pending, f, inflight, planned);
                        drop(g);
                        if shed {
                            // The offer shed load: pause this refill
                            // pass so pressure is re-evaluated against
                            // the next window's completions instead of
                            // shedding the whole remaining stream on
                            // one stale p95.
                            break;
                        }
                    }
                    FramePoll::Ready(None) => exhausted = true,
                    FramePoll::Pending => break,
                }
            }
            if pending.is_empty() {
                // Source exhausted or the pull budget is spent; any
                // shortfall against `n_frames` is recorded admission
                // shedding, not silence.
                break;
            }
            // SLO pressure: defer-sharding reorders the backlog before
            // the window is cut. The ambient window id is set first so
            // every span recorded from here through the engine inherits
            // it without plumbing.
            self.obs.set_window(windows);
            {
                let _g = self.obs.span(Stage::Admission);
                admission.reorder(&mut pending, planned);
            }
            let window = {
                let _g = self.obs.span(Stage::WindowPack);
                self.take_window(&mut pending, inflight)
            };
            windows += 1;
            let started = stopwatch();
            let metas: Vec<(u64, u32, Instant, u64)> = window
                .iter()
                .map(|f| {
                    (f.meta.id, f.meta.sequence, f.produced, f.meta.voxels_rebinned)
                })
                .collect();
            let tensors: Vec<SparseTensor> =
                window.into_iter().map(|f| f.tensor).collect();
            // Both policies execute through the one window executor —
            // the policy only shaped the window's *composition*. An
            // Exclusive multi-frame window holds no sharding scene
            // (take_window guarantees it), so run_scenes plans nothing
            // and falls back to the plain lockstep group; a lone
            // sharding scene takes exactly the run_frame_sharded path.
            let results = match cache.as_mut() {
                Some(c) => {
                    let seqs: Vec<u32> = metas.iter().map(|m| m.1).collect();
                    self.runner.run_scenes_delta(tensors, Some((&seqs, c)), engine)?
                }
                None => self.runner.run_scenes(tensors, engine)?,
            };
            for ((id, sequence, produced, rebinned), mut result) in
                metas.into_iter().zip(results)
            {
                // The runner never sees the voxelization stage; stamp
                // the source-side counter onto the frame's result here.
                result.voxels_rebinned = rebinned;
                blocks_searched += result.blocks_searched;
                blocks_reused += result.blocks_reused;
                voxels_rebinned += result.voxels_rebinned;
                waves_skipped += result.waves_skipped;
                rows_gathered_saved += result.rows_gathered_saved;
                let latency = produced.elapsed().as_secs_f64();
                let wait = started.saturating_duration_since(produced).as_secs_f64();
                // A sharded scene's per-shard map searches run
                // concurrently on the pool, so their summed ms can
                // exceed the window wall — clamp so "own cost" never
                // exceeds the frame's end-to-end latency.
                let attributed = (wait + result.ms_seconds() + result.compute_seconds())
                    .min(latency);
                admission.record(attributed);
                if self.obs.costing() {
                    // Per-completion counter samples for the trace's
                    // bytes/energy tracks, stamped at completion time
                    // (dropped internally unless tracing is also on).
                    let fc = CostModel::default().frame_cost(&result);
                    self.obs.record_cost_point(id, fc.total_bytes(), fc.total_joules());
                }
                completions.push(FrameCompletion {
                    id,
                    sequence,
                    latency,
                    attributed,
                    result,
                });
            }
            // Window commit: sweep every stripe's buffered spans into
            // the ordered log while the workers are quiescent.
            self.obs.drain();
        }
        self.obs.clear_window();
        let mut stage_seconds = vec![Vec::new(); Stage::COUNT];
        for s in self.obs.spans().iter().skip(span_base) {
            stage_seconds[s.stage.index()].push(s.dur);
        }
        let mut evictions = cache.as_ref().map_or(0, |c| c.evictions);
        let mut admission_report = admission.report;
        if let Some(m) = self.obs.metrics() {
            // One counter surface: route the ad-hoc counters through the
            // registry and read the report fields back out of it. The
            // before/after delta keeps repeated serves on one recorder
            // value-identical to the metrics-off path.
            let routed = |name: &str, v: u64| {
                let before = m.counter(name);
                m.add(name, v);
                m.counter(name) - before
            };
            windows = routed("stream.windows", windows);
            blocks_searched = routed("delta.blocks_searched", blocks_searched);
            blocks_reused = routed("delta.blocks_reused", blocks_reused);
            evictions = routed("delta.evictions", evictions);
            voxels_rebinned = routed("stream.voxels_rebinned", voxels_rebinned);
            waves_skipped = routed("compute.waves_skipped", waves_skipped);
            rows_gathered_saved =
                routed("compute.rows_gathered_saved", rows_gathered_saved);
            admission_report.admitted =
                routed("admission.admitted", admission_report.admitted);
            admission_report.dropped =
                routed("admission.dropped", admission_report.dropped);
            admission_report.rejected =
                routed("admission.rejected", admission_report.rejected);
            admission_report.deferred =
                routed("admission.deferred", admission_report.deferred);
            for c in &completions {
                m.observe("stream.latency", c.latency);
                m.observe("stream.attributed", c.attributed);
            }
        }
        if let Some(m) = self.obs.cost() {
            // Cost ledger roll-up: plain adds (nothing reads these back
            // into report fields — `cost_summary()` is pure over the
            // completions) plus per-frame distributions. Per-stage byte
            // counters give the metrics snapshot the same breakdown the
            // summary carries.
            let model = CostModel::default();
            let mut total = FrameCost::default();
            for c in &completions {
                let fc = model.frame_cost(&c.result);
                m.observe("cost.frame_bytes", fc.total_bytes() as f64);
                m.observe("cost.frame_joules", fc.total_joules());
                total.add(&fc);
            }
            m.add("cost.dram_bytes", total.dram_bytes());
            m.add("cost.buffer_bytes", total.buffer_bytes());
            m.add("cost.macs", total.macs);
            m.add("cost.energy_nj", (total.total_joules() * 1e9).round() as u64);
            for (key, sc) in total.buckets() {
                m.add(&format!("cost.stage.{key}.bytes"), sc.bytes);
            }
        }
        Ok(StreamReport {
            completions,
            wall_seconds: t0.elapsed().as_secs_f64(),
            windows,
            admission: admission_report,
            blocks_searched,
            blocks_reused,
            evictions,
            voxels_rebinned,
            waves_skipped,
            rows_gathered_saved,
            stage_seconds,
        })
    }

    /// Cut one lockstep window from the front of the pending queue (see
    /// [`Self::serve`] for the two policies). FIFO in both modes: the
    /// packer never skips past a scene that does not fit, so admitted
    /// arrival order is the service order.
    fn take_window(
        &self,
        pending: &mut VecDeque<SourcedFrame>,
        inflight: usize,
    ) -> Vec<SourcedFrame> {
        let Some(first) = pending.pop_front() else {
            // The serve loop only cuts windows while frames are queued;
            // an empty queue yields an empty window rather than a panic.
            return Vec::new();
        };
        let cost = |f: &SourcedFrame| self.runner.planned_shards(f.tensor.len());
        match self.window {
            WindowPolicy::Exclusive => {
                if cost(&first) > 1 {
                    return vec![first];
                }
                let mut window = vec![first];
                while window.len() < inflight
                    && pending.front().is_some_and(|f| cost(f) == 1)
                {
                    if let Some(f) = pending.pop_front() {
                        window.push(f);
                    }
                }
                window
            }
            WindowPolicy::CrossScene => {
                // Slot budget: the first scene always boards (an
                // oversized scene still gets served); following scenes
                // board while their pseudo-frame count fits.
                let mut budget = inflight.saturating_sub(cost(&first));
                let mut window = vec![first];
                while let Some(f) = pending.front() {
                    let c = cost(f);
                    if c > budget {
                        break;
                    }
                    budget -= c;
                    if let Some(f) = pending.pop_front() {
                        window.push(f);
                    }
                }
                window
            }
        }
    }

    /// The historical closure API: `producer` runs on a background
    /// prefetch thread feeding a bounded buffer of `queue_depth` frames
    /// (backpressure: the producer blocks when the accelerator falls
    /// behind), exactly the producer/consumer split `serve` used to
    /// hard-code.
    ///
    /// Legacy shim: submit through the facade instead —
    /// `Pipeline::run(Job::stream(PrefetchSource::spawn(..)))` is the
    /// same producer/consumer split with the engine owned by the
    /// pipeline (`tests/pipeline_api.rs` witnesses bit-identity).
    #[deprecated(
        since = "0.2.0",
        note = "submit through `pipeline::Pipeline::run(Job::Stream(..))` with a \
                `PrefetchSource`-wrapped `ClosureSource`"
    )]
    pub fn serve_closure<E, P>(
        &self,
        n_frames: u64,
        producer: P,
        engine: &mut E,
    ) -> crate::Result<StreamReport>
    where
        E: GemmEngine,
        P: Fn(u64) -> SparseTensor + Send + 'static,
    {
        let mut source =
            PrefetchSource::spawn(Box::new(ClosureSource::new(producer)), self.queue_depth);
        self.serve(n_frames, &mut source, engine)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::shard::ShardConfig;
    use crate::geom::Extent3;
    use crate::model::layer::{LayerSpec, TaskKind};
    use crate::pointcloud::voxelize::Voxelizer;
    use crate::spconv::layer::NativeEngine;

    fn tiny_net() -> NetworkSpec {
        NetworkSpec {
            name: "stream-tiny",
            task: TaskKind::Segmentation,
            extent: Extent3::new(16, 16, 8),
            vfe_channels: 4,
            layers: vec![
                LayerSpec::Subm3 { c_in: 4, c_out: 8 },
                LayerSpec::Subm3 { c_in: 8, c_out: 8 },
            ],
        }
    }

    fn make_frame(id: u64) -> SparseTensor {
        let e = Extent3::new(16, 16, 8);
        let g = Voxelizer::synth_occupancy(e, 0.05, 1000 + id);
        let mut t = SparseTensor::from_coords(e, g.coords(), 4);
        for (i, v) in t.features.iter_mut().enumerate() {
            *v = ((i as u64 + id) % 7) as i8;
        }
        t
    }

    /// The old `serve_closure` producer/consumer split, spelled with the
    /// non-deprecated source API: a prefetch thread over a closure
    /// source, bounded by the server's `queue_depth`.
    fn serve_prefetched<P>(srv: &StreamServer, n: u64, producer: P) -> StreamReport
    where
        P: Fn(u64) -> SparseTensor + Send + 'static,
    {
        let mut source =
            PrefetchSource::spawn(Box::new(ClosureSource::new(producer)), srv.queue_depth);
        srv.serve(n, &mut source, &mut NativeEngine::default()).unwrap()
    }

    #[test]
    fn serves_all_frames_in_order() {
        let srv = StreamServer::new(tiny_net(), RunnerConfig::default(), 2);
        let report = serve_prefetched(&srv, 8, make_frame);
        assert_eq!(report.completions.len(), 8);
        let ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        assert!(report.throughput_fps() > 0.0);
        assert!(report.latency_p95() >= report.latency_p50());
        assert_eq!(report.admission, crate::serving::AdmissionReport {
            admitted: 8,
            ..Default::default()
        });
        assert!(report.windows >= 1);
    }

    #[test]
    fn direct_source_matches_prefetched_closure_path() {
        let srv = StreamServer::new(
            tiny_net(),
            RunnerConfig {
                inflight: 3,
                ..Default::default()
            },
            4,
        );
        let prefetched = serve_prefetched(&srv, 6, make_frame);
        let mut direct = ClosureSource::new(make_frame);
        let direct = srv
            .serve(6, &mut direct, &mut NativeEngine::default())
            .unwrap();
        assert_eq!(prefetched.completions.len(), direct.completions.len());
        for (a, b) in prefetched.completions.iter().zip(&direct.completions) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.result.checksum, b.result.checksum, "frame {}", a.id);
        }
    }

    #[test]
    fn finite_source_ends_the_stream_early() {
        use crate::dataset::{ProfileSource, ScenarioProfile};
        let srv = StreamServer::new(
            tiny_net(),
            RunnerConfig {
                inflight: 2,
                ..Default::default()
            },
            4,
        );
        let mut src = ProfileSource::new(
            ScenarioProfile::Urban,
            Extent3::new(16, 16, 8),
            0.05,
            3,
        )
        .with_frames(3);
        // Ask for more frames than the source holds: serve returns what
        // the source produced instead of hanging.
        let report = srv.serve(10, &mut src, &mut NativeEngine::default()).unwrap();
        assert_eq!(report.completions.len(), 3);
        let ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn queue_depth_one_still_completes() {
        let srv = StreamServer::new(tiny_net(), RunnerConfig::default(), 1);
        let report = serve_prefetched(&srv, 4, make_frame);
        assert_eq!(report.completions.len(), 4);
    }

    #[test]
    fn deterministic_results_across_streams() {
        let srv = StreamServer::new(tiny_net(), RunnerConfig::default(), 3);
        let a = serve_prefetched(&srv, 3, make_frame);
        let b = serve_prefetched(&srv, 3, make_frame);
        for (x, y) in a.completions.iter().zip(&b.completions) {
            assert_eq!(x.result.total_pairs(), y.result.total_pairs());
            assert_eq!(x.result.out_voxels, y.result.out_voxels);
            assert_eq!(x.result.checksum, y.result.checksum);
        }
    }

    #[test]
    fn inflight_batching_preserves_every_frame_bit_for_bit() {
        let unbatched = StreamServer::new(tiny_net(), RunnerConfig::default(), 8);
        let batched = StreamServer::new(
            tiny_net(),
            RunnerConfig {
                inflight: 4,
                ..Default::default()
            },
            8,
        );
        let a = serve_prefetched(&unbatched, 8, make_frame);
        let b = serve_prefetched(&batched, 8, make_frame);
        assert_eq!(a.completions.len(), b.completions.len());
        for (x, y) in a.completions.iter().zip(&b.completions) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.result.checksum, y.result.checksum, "frame {}", x.id);
            assert_eq!(x.result.total_pairs(), y.result.total_pairs());
        }
    }

    #[test]
    fn sharded_stream_serves_bit_identical_frames_in_their_own_windows() {
        let plain = StreamServer::new(tiny_net(), RunnerConfig::default(), 8);
        let sharded = StreamServer::new(
            tiny_net(),
            RunnerConfig {
                shard: ShardConfig::grid(2, 2).unwrap(),
                inflight: 3,
                ..Default::default()
            },
            8,
        );
        let a = serve_prefetched(&plain, 6, make_frame);
        let b = serve_prefetched(&sharded, 6, make_frame);
        assert_eq!(a.completions.len(), b.completions.len());
        for (x, y) in a.completions.iter().zip(&b.completions) {
            assert_eq!(x.id, y.id);
            assert_eq!(
                x.result.checksum, y.result.checksum,
                "frame {} diverged under shard scheduling",
                x.id
            );
            assert_eq!(x.result.shards, 1);
            assert!(y.result.shards >= 1);
        }
        assert!(
            b.completions.iter().any(|c| c.result.shards > 1),
            "no frame actually sharded"
        );
    }

    #[test]
    fn cross_scene_windows_pack_shards_with_other_frames_bit_identically() {
        // Every frame shards under the 2x2 grid with threshold 0; with
        // inflight 8 > 2 * shards, the cross-scene packer fits two
        // sharded scenes (4 pseudo-frames each) into one window, which
        // the exclusive policy never does.
        let cfg = RunnerConfig {
            shard: ShardConfig::grid(2, 2).unwrap(),
            inflight: 8,
            ..Default::default()
        };
        let exclusive = StreamServer::new(tiny_net(), cfg, 8);
        let packed = StreamServer::new(tiny_net(), cfg, 8)
            .with_window(WindowPolicy::CrossScene);
        // Direct (synchronous) sources so the window compositions are
        // deterministic: every poll is Ready, no prefetch-thread races.
        let a = exclusive
            .serve(6, &mut ClosureSource::new(make_frame), &mut NativeEngine::default())
            .unwrap();
        let b = packed
            .serve(6, &mut ClosureSource::new(make_frame), &mut NativeEngine::default())
            .unwrap();
        assert_eq!(a.completions.len(), b.completions.len());
        for (x, y) in a.completions.iter().zip(&b.completions) {
            assert_eq!(x.id, y.id);
            assert_eq!(
                x.result.checksum, y.result.checksum,
                "frame {} diverged under cross-scene packing",
                x.id
            );
            assert_eq!(x.result.shards, y.result.shards);
        }
        assert!(
            b.windows < a.windows,
            "cross-scene packing must cut fewer windows ({} vs {})",
            b.windows,
            a.windows
        );
    }

    #[test]
    fn attributed_latency_is_bounded_by_end_to_end_latency() {
        // Sharding on: the clamp path matters exactly when a scene's
        // concurrent shard searches would sum past the window wall.
        let srv = StreamServer::new(
            tiny_net(),
            RunnerConfig {
                inflight: 8,
                shard: ShardConfig::grid(2, 2).unwrap(),
                ..Default::default()
            },
            8,
        )
        .with_window(WindowPolicy::CrossScene);
        let report = serve_prefetched(&srv, 8, make_frame);
        for c in &report.completions {
            assert!(c.attributed >= 0.0);
            assert!(
                c.attributed <= c.latency + 1e-6,
                "frame {}: attributed {} vs latency {}",
                c.id,
                c.attributed,
                c.latency
            );
        }
        let att = report.attributed_summary().unwrap();
        let e2e = report.latency_summary().unwrap();
        assert_eq!(att.n, e2e.n);
        assert!(att.p95 <= e2e.p95 + 1e-6);
        assert_eq!(e2e.p95, report.latency_p95());
    }

    #[test]
    fn delta_cache_stream_is_bit_identical_and_reuses_blocks() {
        let cold = StreamServer::new(tiny_net(), RunnerConfig::default(), 4);
        let warm = StreamServer::new(
            tiny_net(),
            RunnerConfig {
                delta: crate::mapsearch::DeltaConfig {
                    enabled: true,
                    compute: true,
                    ..Default::default()
                },
                ..Default::default()
            },
            4,
        );
        // A static scene: every block stays clean after frame 0, so the
        // warm server must splice everything and search nothing new.
        let frame = |_: u64| make_frame(3);
        let a = cold
            .serve(4, &mut ClosureSource::new(frame), &mut NativeEngine::default())
            .unwrap();
        let b = warm
            .serve(4, &mut ClosureSource::new(frame), &mut NativeEngine::default())
            .unwrap();
        assert_eq!(a.completions.len(), b.completions.len());
        for (x, y) in a.completions.iter().zip(&b.completions) {
            assert_eq!(x.result.checksum, y.result.checksum, "frame {}", x.id);
            assert_eq!(x.result.total_pairs(), y.result.total_pairs());
        }
        // Off by default: the cold server reports no delta activity.
        assert_eq!(a.blocks_searched + a.blocks_reused, 0);
        assert_eq!(a.reuse_ratio(), 0.0);
        assert!(b.blocks_reused > 0, "static stream reused no blocks");
        assert!(b.reuse_ratio() > 0.0);
        assert_eq!(b.evictions, 0);
        // Compute-core reuse: a fully static scene splices every psum
        // row after frame 0, so warm frames shed gather rows and whole
        // GEMM waves — while staying bit-identical (checked above).
        assert_eq!(a.waves_skipped + a.rows_gathered_saved, 0);
        assert!(b.rows_gathered_saved > 0, "static stream saved no gather rows");
        assert!(b.waves_skipped > 0, "static stream skipped no waves");
    }

    #[test]
    fn empty_stream_report_returns_zeroes_not_nan() {
        let srv = StreamServer::new(tiny_net(), RunnerConfig::default(), 2);
        let report = srv
            .serve(0, &mut ClosureSource::new(make_frame), &mut NativeEngine::default())
            .unwrap();
        assert!(report.completions.is_empty());
        // Every ratio / percentile degrades to 0, never NaN or a panic.
        assert_eq!(report.throughput_fps(), 0.0);
        assert_eq!(report.latency_p50(), 0.0);
        assert_eq!(report.latency_p95(), 0.0);
        assert_eq!(report.reuse_ratio(), 0.0);
        assert!(report.latency_summary().is_none());
        assert!(report.attributed_summary().is_none());
        assert!(report.stage_summary().is_empty());
        assert_eq!(report.stage_seconds.len(), Stage::COUNT);
    }

    #[test]
    fn observed_stream_reports_stage_summaries() {
        use crate::obs::ObsConfig;
        let obs = Recorder::from_config(&ObsConfig {
            trace: true,
            ..ObsConfig::default()
        });
        let srv = StreamServer::new(tiny_net(), RunnerConfig::default(), 2)
            .with_observer(obs);
        let report = srv
            .serve(4, &mut ClosureSource::new(make_frame), &mut NativeEngine::default())
            .unwrap();
        assert_eq!(report.completions.len(), 4);
        let summary = report.stage_summary();
        let keys: Vec<&str> = summary.iter().map(|(k, _)| *k).collect();
        for want in ["map_search", "gather", "gemm_wave", "scatter", "requant",
            "admission", "window_pack"]
        {
            assert!(keys.contains(&want), "missing stage {want:?} in {keys:?}");
        }
        for (k, s) in &summary {
            assert!(s.n >= 1 && s.p95 >= s.p50 && s.p50 >= 0.0, "stage {k}");
        }
        // The observer also kept the recorded spans for export.
        assert!(srv.observer().span_count() > 0);
    }

    #[test]
    fn unobserved_stream_records_no_spans_and_identical_bits() {
        use crate::obs::ObsConfig;
        let plain = StreamServer::new(tiny_net(), RunnerConfig::default(), 2);
        let observed = StreamServer::new(tiny_net(), RunnerConfig::default(), 2)
            .with_observer(Recorder::from_config(&ObsConfig {
                trace: true,
                metrics: true,
                ..ObsConfig::default()
            }));
        let a = plain
            .serve(4, &mut ClosureSource::new(make_frame), &mut NativeEngine::default())
            .unwrap();
        let b = observed
            .serve(4, &mut ClosureSource::new(make_frame), &mut NativeEngine::default())
            .unwrap();
        assert!(!plain.observer().enabled());
        assert!(a.stage_seconds.iter().all(Vec::is_empty));
        assert!(a.stage_summary().is_empty());
        for (x, y) in a.completions.iter().zip(&b.completions) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.result.checksum, y.result.checksum, "frame {}", x.id);
        }
        // Metrics routing read the counters back bit-identically.
        assert_eq!(a.windows, b.windows);
        assert_eq!(a.admission, b.admission);
        let m = observed.observer().metrics().expect("metrics half on");
        assert_eq!(m.counter("stream.windows"), b.windows);
        assert_eq!(m.counter("admission.admitted"), b.admission.admitted);
    }

    #[test]
    fn modeled_stream_pipeline_is_bounded_by_serial_sum() {
        let srv = StreamServer::new(tiny_net(), RunnerConfig::default(), 4);
        let report = serve_prefetched(&srv, 4, make_frame);
        let pipe = HybridPipeline::default();
        let modeled = report.modeled_pipeline_seconds(&pipe);
        let serial: f64 = report
            .completions
            .iter()
            .map(|c| c.result.ms_seconds() + c.result.compute_seconds())
            .sum();
        assert!(modeled <= serial + 1e-9, "modeled {modeled} vs serial {serial}");
        assert!(modeled >= 0.0);
    }
}
