//! Frame-stream serving: the leader loop that keeps the map-search core
//! and the computing core busy across *consecutive frames*, extending the
//! Fig. 8 hybrid pipeline from layers to the frame stream.
//!
//! Frames come from any [`FrameSource`] — KITTI sequences, scenario
//! profiles, trace replay, or closure adapters, optionally behind a
//! prefetching buffer (backpressure: a buffered producer blocks when
//! the accelerator falls behind). The server pulls up to
//! `RunnerConfig::inflight` ready frames at a time and runs them in
//! lockstep through [`NetworkRunner::run_frames`]: all in-flight frames'
//! map searches fan out over the worker pool and their rule pairs pack
//! into shared GEMM waves, amortizing engine dispatch overhead across
//! the stream without changing any frame's bits. Latency/throughput
//! percentiles are reported per stream — the serving-style measurement
//! the e2e benches record.

use std::time::Instant;

use crate::coordinator::pipeline::{HybridPipeline, PhaseTiming};
use crate::coordinator::scheduler::{FrameResult, NetworkRunner, RunnerConfig};
use crate::dataset::{ClosureSource, FramePoll, FrameSource, PrefetchSource, SourcedFrame};
use crate::model::layer::NetworkSpec;
use crate::sparse::tensor::SparseTensor;
use crate::spconv::layer::GemmEngine;
use crate::util::stats::percentile;

/// Completion record for one frame. The pseudo-frame count of a
/// block-sharded scene is carried by `result.shards`.
#[derive(Debug)]
pub struct FrameCompletion {
    pub id: u64,
    pub result: FrameResult,
    /// Queue wait + processing, seconds.
    pub latency: f64,
}

/// Stream-level statistics.
#[derive(Debug)]
pub struct StreamReport {
    pub completions: Vec<FrameCompletion>,
    pub wall_seconds: f64,
}

impl StreamReport {
    pub fn throughput_fps(&self) -> f64 {
        self.completions.len() as f64 / self.wall_seconds
    }
    pub fn latency_p50(&self) -> f64 {
        percentile(&self.latencies(), 50.0)
    }
    pub fn latency_p95(&self) -> f64 {
        percentile(&self.latencies(), 95.0)
    }
    fn latencies(&self) -> Vec<f64> {
        self.completions.iter().map(|c| c.latency).collect()
    }

    /// Project the measured per-layer phase timings of every served frame
    /// through the Fig. 8 hybrid pipeline chained across frame boundaries
    /// — the accelerator-side latency this stream would see if the MS and
    /// compute cores double-buffered consecutive frames. Returns the
    /// modeled stream makespan in seconds.
    pub fn modeled_pipeline_seconds(&self, pipe: &HybridPipeline) -> f64 {
        let frames: Vec<Vec<PhaseTiming>> = self
            .completions
            .iter()
            .map(|c| {
                c.result
                    .records
                    .iter()
                    .map(|r| PhaseTiming {
                        ms: r.ms_seconds,
                        compute: r.compute_seconds,
                    })
                    .collect()
            })
            .collect();
        pipe.schedule_stream(&frames).total
    }
}

/// Streaming server over a [`NetworkRunner`].
pub struct StreamServer {
    runner: NetworkRunner,
    /// Bounded queue depth (backpressure threshold).
    pub queue_depth: usize,
}

impl StreamServer {
    pub fn new(net: NetworkSpec, cfg: RunnerConfig, queue_depth: usize) -> Self {
        assert!(queue_depth >= 1);
        Self {
            runner: NetworkRunner::new(net, cfg),
            queue_depth,
        }
    }

    /// Serve up to `n_frames` frames from any [`FrameSource`] — a KITTI
    /// sequence, a scenario profile, a trace replay, a prefetched
    /// wrapper, or a [`ClosureSource`] adapter. The stream ends early if
    /// the source is exhausted. Processing runs on the caller thread
    /// with the engine; production overlaps when the source buffers
    /// (wrap it in a [`PrefetchSource`], or use [`Self::serve_closure`]).
    ///
    /// When `RunnerConfig::inflight > 1` the server opportunistically
    /// pulls up to that many *ready* frames per iteration
    /// ([`FrameSource::poll_frame`] — never waiting for a frame that has
    /// not been produced yet, so latency is not traded for batch size)
    /// and runs them as one lockstep wave group. Per-frame results are
    /// bit-identical either way.
    ///
    /// Queue accounting is shard-aware: a scene that `cfg.shard` splits
    /// occupies a whole lockstep window by itself — its block shards are
    /// the window's pseudo-frames — so it is never packed together with
    /// other queued frames, and a frame pulled while filling a window is
    /// carried over to the next iteration instead of being dropped.
    pub fn serve<E: GemmEngine>(
        &self,
        n_frames: u64,
        source: &mut dyn FrameSource,
        engine: &mut E,
    ) -> crate::Result<StreamReport> {
        let inflight = self.runner.cfg.inflight.max(1);
        let t0 = Instant::now();
        let mut completions = Vec::with_capacity(n_frames as usize);
        // Frames pulled from the source so far (bounds total pulls at
        // `n_frames` even over endless sources).
        let mut pulled: u64 = 0;
        // A frame pulled while filling a lockstep window but too big to
        // join it (it shards into its own window) waits here.
        let mut carry: Option<SourcedFrame> = None;
        while (completions.len() as u64) < n_frames {
            let first = match carry.take() {
                Some(frame) => frame,
                None => match source.next_frame() {
                    Some(frame) => {
                        pulled += 1;
                        frame
                    }
                    None => break, // source exhausted
                },
            };
            // Shard-aware queue accounting: a scene that shards fills
            // its whole window with its own pseudo-frames.
            if self.runner.planned_shards(first.tensor.len()) > 1 {
                let (id, produced) = (first.meta.id, first.produced);
                let result = self.runner.run_frame_sharded(first.tensor, engine)?;
                completions.push(FrameCompletion {
                    id,
                    latency: produced.elapsed().as_secs_f64(),
                    result,
                });
                continue;
            }
            let mut group = vec![first];
            let mut exhausted = false;
            while group.len() < inflight && pulled < n_frames && !exhausted {
                match source.poll_frame() {
                    FramePoll::Ready(Some(frame)) => {
                        pulled += 1;
                        if self.runner.planned_shards(frame.tensor.len()) > 1 {
                            carry = Some(frame);
                            break;
                        }
                        group.push(frame);
                    }
                    FramePoll::Ready(None) => exhausted = true,
                    FramePoll::Pending => break,
                }
            }
            let metas: Vec<(u64, Instant)> =
                group.iter().map(|f| (f.meta.id, f.produced)).collect();
            let tensors: Vec<SparseTensor> =
                group.into_iter().map(|f| f.tensor).collect();
            let results = self.runner.run_frames(tensors, engine)?;
            for ((id, produced), result) in metas.into_iter().zip(results) {
                completions.push(FrameCompletion {
                    id,
                    latency: produced.elapsed().as_secs_f64(),
                    result,
                });
            }
        }
        Ok(StreamReport {
            completions,
            wall_seconds: t0.elapsed().as_secs_f64(),
        })
    }

    /// The historical closure API: `producer` runs on a background
    /// prefetch thread feeding a bounded buffer of `queue_depth` frames
    /// (backpressure: the producer blocks when the accelerator falls
    /// behind), exactly the producer/consumer split `serve` used to
    /// hard-code. Kept as the convenience path for synthetic streams.
    pub fn serve_closure<E, P>(
        &self,
        n_frames: u64,
        producer: P,
        engine: &mut E,
    ) -> crate::Result<StreamReport>
    where
        E: GemmEngine,
        P: Fn(u64) -> SparseTensor + Send + 'static,
    {
        let mut source =
            PrefetchSource::spawn(Box::new(ClosureSource::new(producer)), self.queue_depth);
        self.serve(n_frames, &mut source, engine)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Extent3;
    use crate::model::layer::{LayerSpec, TaskKind};
    use crate::pointcloud::voxelize::Voxelizer;
    use crate::spconv::layer::NativeEngine;

    fn tiny_net() -> NetworkSpec {
        NetworkSpec {
            name: "stream-tiny",
            task: TaskKind::Segmentation,
            extent: Extent3::new(16, 16, 8),
            vfe_channels: 4,
            layers: vec![
                LayerSpec::Subm3 { c_in: 4, c_out: 8 },
                LayerSpec::Subm3 { c_in: 8, c_out: 8 },
            ],
        }
    }

    fn make_frame(id: u64) -> SparseTensor {
        let e = Extent3::new(16, 16, 8);
        let g = Voxelizer::synth_occupancy(e, 0.05, 1000 + id);
        let mut t = SparseTensor::from_coords(e, g.coords(), 4);
        for (i, v) in t.features.iter_mut().enumerate() {
            *v = ((i as u64 + id) % 7) as i8;
        }
        t
    }

    #[test]
    fn serves_all_frames_in_order() {
        let srv = StreamServer::new(tiny_net(), RunnerConfig::default(), 2);
        let report = srv
            .serve_closure(8, make_frame, &mut NativeEngine::default())
            .unwrap();
        assert_eq!(report.completions.len(), 8);
        let ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        assert!(report.throughput_fps() > 0.0);
        assert!(report.latency_p95() >= report.latency_p50());
    }

    #[test]
    fn direct_source_matches_prefetched_closure_path() {
        let srv = StreamServer::new(
            tiny_net(),
            RunnerConfig {
                inflight: 3,
                ..Default::default()
            },
            4,
        );
        let prefetched = srv
            .serve_closure(6, make_frame, &mut NativeEngine::default())
            .unwrap();
        let mut direct = ClosureSource::new(make_frame);
        let direct = srv
            .serve(6, &mut direct, &mut NativeEngine::default())
            .unwrap();
        assert_eq!(prefetched.completions.len(), direct.completions.len());
        for (a, b) in prefetched.completions.iter().zip(&direct.completions) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.result.checksum, b.result.checksum, "frame {}", a.id);
        }
    }

    #[test]
    fn finite_source_ends_the_stream_early() {
        use crate::dataset::{ProfileSource, ScenarioProfile};
        let srv = StreamServer::new(
            tiny_net(),
            RunnerConfig {
                inflight: 2,
                ..Default::default()
            },
            4,
        );
        let mut src = ProfileSource::new(
            ScenarioProfile::Urban,
            Extent3::new(16, 16, 8),
            0.05,
            3,
        )
        .with_frames(3);
        // Ask for more frames than the source holds: serve returns what
        // the source produced instead of hanging.
        let report = srv.serve(10, &mut src, &mut NativeEngine::default()).unwrap();
        assert_eq!(report.completions.len(), 3);
        let ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn queue_depth_one_still_completes() {
        let srv = StreamServer::new(tiny_net(), RunnerConfig::default(), 1);
        let report = srv
            .serve_closure(4, make_frame, &mut NativeEngine::default())
            .unwrap();
        assert_eq!(report.completions.len(), 4);
    }

    #[test]
    fn deterministic_results_across_streams() {
        let srv = StreamServer::new(tiny_net(), RunnerConfig::default(), 3);
        let a = srv.serve_closure(3, make_frame, &mut NativeEngine::default()).unwrap();
        let b = srv.serve_closure(3, make_frame, &mut NativeEngine::default()).unwrap();
        for (x, y) in a.completions.iter().zip(&b.completions) {
            assert_eq!(x.result.total_pairs(), y.result.total_pairs());
            assert_eq!(x.result.out_voxels, y.result.out_voxels);
            assert_eq!(x.result.checksum, y.result.checksum);
        }
    }

    #[test]
    fn inflight_batching_preserves_every_frame_bit_for_bit() {
        let unbatched = StreamServer::new(tiny_net(), RunnerConfig::default(), 8);
        let batched = StreamServer::new(
            tiny_net(),
            RunnerConfig {
                inflight: 4,
                ..Default::default()
            },
            8,
        );
        let a = unbatched
            .serve_closure(8, make_frame, &mut NativeEngine::default())
            .unwrap();
        let b = batched
            .serve_closure(8, make_frame, &mut NativeEngine::default())
            .unwrap();
        assert_eq!(a.completions.len(), b.completions.len());
        for (x, y) in a.completions.iter().zip(&b.completions) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.result.checksum, y.result.checksum, "frame {}", x.id);
            assert_eq!(x.result.total_pairs(), y.result.total_pairs());
        }
    }

    #[test]
    fn sharded_stream_serves_bit_identical_frames_in_their_own_windows() {
        use crate::coordinator::shard::ShardConfig;
        let plain = StreamServer::new(tiny_net(), RunnerConfig::default(), 8);
        let sharded = StreamServer::new(
            tiny_net(),
            RunnerConfig {
                shard: ShardConfig::grid(2, 2).unwrap(),
                inflight: 3,
                ..Default::default()
            },
            8,
        );
        let a = plain
            .serve_closure(6, make_frame, &mut NativeEngine::default())
            .unwrap();
        let b = sharded
            .serve_closure(6, make_frame, &mut NativeEngine::default())
            .unwrap();
        assert_eq!(a.completions.len(), b.completions.len());
        for (x, y) in a.completions.iter().zip(&b.completions) {
            assert_eq!(x.id, y.id);
            assert_eq!(
                x.result.checksum, y.result.checksum,
                "frame {} diverged under shard scheduling",
                x.id
            );
            assert_eq!(x.result.shards, 1);
            assert!(y.result.shards >= 1);
        }
        assert!(
            b.completions.iter().any(|c| c.result.shards > 1),
            "no frame actually sharded"
        );
    }

    #[test]
    fn modeled_stream_pipeline_is_bounded_by_serial_sum() {
        let srv = StreamServer::new(tiny_net(), RunnerConfig::default(), 4);
        let report = srv
            .serve_closure(4, make_frame, &mut NativeEngine::default())
            .unwrap();
        let pipe = HybridPipeline::default();
        let modeled = report.modeled_pipeline_seconds(&pipe);
        let serial: f64 = report
            .completions
            .iter()
            .map(|c| c.result.ms_seconds() + c.result.compute_seconds())
            .sum();
        assert!(modeled <= serial + 1e-9, "modeled {modeled} vs serial {serial}");
        assert!(modeled >= 0.0);
    }
}
