//! Frame-stream serving: the leader loop that keeps the map-search core
//! and the computing core busy across *consecutive frames*, extending the
//! Fig. 8 hybrid pipeline from layers to the frame stream.
//!
//! Frames arrive on a bounded queue (backpressure: the producer blocks
//! when the accelerator falls behind). The server drains up to
//! `RunnerConfig::inflight` queued frames at a time and runs them in
//! lockstep through [`NetworkRunner::run_frames`]: all in-flight frames'
//! map searches fan out over the worker pool and their rule pairs pack
//! into shared GEMM waves, amortizing engine dispatch overhead across
//! the stream without changing any frame's bits. Latency/throughput
//! percentiles are reported per stream — the serving-style measurement
//! the e2e benches record.

use std::sync::mpsc;
use std::time::Instant;

use crate::coordinator::executor::WorkerPool;
use crate::coordinator::pipeline::{HybridPipeline, PhaseTiming};
use crate::coordinator::scheduler::{FrameResult, NetworkRunner, RunnerConfig};
use crate::model::layer::NetworkSpec;
use crate::sparse::tensor::SparseTensor;
use crate::spconv::layer::GemmEngine;
use crate::util::stats::percentile;

/// One frame queued for processing.
pub struct FrameRequest {
    pub id: u64,
    pub tensor: SparseTensor,
    pub enqueued: Instant,
}

/// Completion record for one frame. The pseudo-frame count of a
/// block-sharded scene is carried by `result.shards`.
#[derive(Debug)]
pub struct FrameCompletion {
    pub id: u64,
    pub result: FrameResult,
    /// Queue wait + processing, seconds.
    pub latency: f64,
}

/// Stream-level statistics.
#[derive(Debug)]
pub struct StreamReport {
    pub completions: Vec<FrameCompletion>,
    pub wall_seconds: f64,
}

impl StreamReport {
    pub fn throughput_fps(&self) -> f64 {
        self.completions.len() as f64 / self.wall_seconds
    }
    pub fn latency_p50(&self) -> f64 {
        percentile(&self.latencies(), 50.0)
    }
    pub fn latency_p95(&self) -> f64 {
        percentile(&self.latencies(), 95.0)
    }
    fn latencies(&self) -> Vec<f64> {
        self.completions.iter().map(|c| c.latency).collect()
    }

    /// Project the measured per-layer phase timings of every served frame
    /// through the Fig. 8 hybrid pipeline chained across frame boundaries
    /// — the accelerator-side latency this stream would see if the MS and
    /// compute cores double-buffered consecutive frames. Returns the
    /// modeled stream makespan in seconds.
    pub fn modeled_pipeline_seconds(&self, pipe: &HybridPipeline) -> f64 {
        let frames: Vec<Vec<PhaseTiming>> = self
            .completions
            .iter()
            .map(|c| {
                c.result
                    .records
                    .iter()
                    .map(|r| PhaseTiming {
                        ms: r.ms_seconds,
                        compute: r.compute_seconds,
                    })
                    .collect()
            })
            .collect();
        pipe.schedule_stream(&frames).total
    }
}

/// Streaming server over a [`NetworkRunner`].
pub struct StreamServer {
    runner: NetworkRunner,
    /// Bounded queue depth (backpressure threshold).
    pub queue_depth: usize,
}

impl StreamServer {
    pub fn new(net: NetworkSpec, cfg: RunnerConfig, queue_depth: usize) -> Self {
        assert!(queue_depth >= 1);
        Self {
            runner: NetworkRunner::new(net, cfg),
            queue_depth,
        }
    }

    /// Serve a finite stream of frames produced by `producer` (called
    /// `n_frames` times on a worker thread, simulating the sensor).
    /// Processing runs on the caller thread with the engine; production
    /// overlaps via the bounded channel.
    ///
    /// When `RunnerConfig::inflight > 1` the server opportunistically
    /// drains up to that many already-queued frames per iteration and
    /// runs them as one lockstep wave group (never waiting for frames
    /// that have not arrived — latency is not traded for batch size).
    /// Per-frame results are bit-identical either way.
    ///
    /// Queue accounting is shard-aware: a scene that `cfg.shard` splits
    /// occupies a whole lockstep window by itself — its block shards are
    /// the window's pseudo-frames — so it is never packed together with
    /// other queued frames, and a frame pulled while filling a window is
    /// carried over to the next iteration instead of being dropped.
    pub fn serve<E, P>(
        &self,
        n_frames: u64,
        producer: P,
        engine: &mut E,
    ) -> crate::Result<StreamReport>
    where
        E: GemmEngine,
        P: Fn(u64) -> SparseTensor + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel::<FrameRequest>(self.queue_depth);
        let pool = WorkerPool::new(1);
        let _producer_handle = pool.submit(move || {
            for id in 0..n_frames {
                let tensor = producer(id);
                let req = FrameRequest {
                    id,
                    tensor,
                    enqueued: Instant::now(),
                };
                if tx.send(req).is_err() {
                    break; // consumer dropped
                }
            }
        });

        let inflight = self.runner.cfg.inflight.max(1);
        let t0 = Instant::now();
        let mut completions = Vec::with_capacity(n_frames as usize);
        // A frame pulled while filling a lockstep window but too big to
        // join it (it shards into its own window) waits here.
        let mut carry: Option<FrameRequest> = None;
        while (completions.len() as u64) < n_frames {
            let first = match carry.take() {
                Some(req) => req,
                None => match rx.recv() {
                    Ok(req) => req,
                    Err(_) => break,
                },
            };
            // Shard-aware queue accounting: a scene that shards fills
            // its whole window with its own pseudo-frames.
            if self.runner.planned_shards(first.tensor.len()) > 1 {
                let (id, enqueued) = (first.id, first.enqueued);
                let result = self.runner.run_frame_sharded(first.tensor, engine)?;
                completions.push(FrameCompletion {
                    id,
                    latency: enqueued.elapsed().as_secs_f64(),
                    result,
                });
                continue;
            }
            let mut group = vec![first];
            while group.len() < inflight {
                match rx.try_recv() {
                    Ok(req) if self.runner.planned_shards(req.tensor.len()) > 1 => {
                        carry = Some(req);
                        break;
                    }
                    Ok(req) => group.push(req),
                    Err(_) => break,
                }
            }
            let metas: Vec<(u64, Instant)> =
                group.iter().map(|r| (r.id, r.enqueued)).collect();
            let tensors: Vec<SparseTensor> =
                group.into_iter().map(|r| r.tensor).collect();
            let results = self.runner.run_frames(tensors, engine)?;
            for ((id, enqueued), result) in metas.into_iter().zip(results) {
                completions.push(FrameCompletion {
                    id,
                    latency: enqueued.elapsed().as_secs_f64(),
                    result,
                });
            }
        }
        Ok(StreamReport {
            completions,
            wall_seconds: t0.elapsed().as_secs_f64(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Extent3;
    use crate::model::layer::{LayerSpec, TaskKind};
    use crate::pointcloud::voxelize::Voxelizer;
    use crate::spconv::layer::NativeEngine;

    fn tiny_net() -> NetworkSpec {
        NetworkSpec {
            name: "stream-tiny",
            task: TaskKind::Segmentation,
            extent: Extent3::new(16, 16, 8),
            vfe_channels: 4,
            layers: vec![
                LayerSpec::Subm3 { c_in: 4, c_out: 8 },
                LayerSpec::Subm3 { c_in: 8, c_out: 8 },
            ],
        }
    }

    fn make_frame(id: u64) -> SparseTensor {
        let e = Extent3::new(16, 16, 8);
        let g = Voxelizer::synth_occupancy(e, 0.05, 1000 + id);
        let mut t = SparseTensor::from_coords(e, g.coords(), 4);
        for (i, v) in t.features.iter_mut().enumerate() {
            *v = ((i as u64 + id) % 7) as i8;
        }
        t
    }

    #[test]
    fn serves_all_frames_in_order() {
        let srv = StreamServer::new(tiny_net(), RunnerConfig::default(), 2);
        let report = srv
            .serve(8, make_frame, &mut NativeEngine::default())
            .unwrap();
        assert_eq!(report.completions.len(), 8);
        let ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        assert!(report.throughput_fps() > 0.0);
        assert!(report.latency_p95() >= report.latency_p50());
    }

    #[test]
    fn queue_depth_one_still_completes() {
        let srv = StreamServer::new(tiny_net(), RunnerConfig::default(), 1);
        let report = srv
            .serve(4, make_frame, &mut NativeEngine::default())
            .unwrap();
        assert_eq!(report.completions.len(), 4);
    }

    #[test]
    fn deterministic_results_across_streams() {
        let srv = StreamServer::new(tiny_net(), RunnerConfig::default(), 3);
        let a = srv.serve(3, make_frame, &mut NativeEngine::default()).unwrap();
        let b = srv.serve(3, make_frame, &mut NativeEngine::default()).unwrap();
        for (x, y) in a.completions.iter().zip(&b.completions) {
            assert_eq!(x.result.total_pairs(), y.result.total_pairs());
            assert_eq!(x.result.out_voxels, y.result.out_voxels);
            assert_eq!(x.result.checksum, y.result.checksum);
        }
    }

    #[test]
    fn inflight_batching_preserves_every_frame_bit_for_bit() {
        let unbatched = StreamServer::new(tiny_net(), RunnerConfig::default(), 8);
        let batched = StreamServer::new(
            tiny_net(),
            RunnerConfig {
                inflight: 4,
                ..Default::default()
            },
            8,
        );
        let a = unbatched
            .serve(8, make_frame, &mut NativeEngine::default())
            .unwrap();
        let b = batched
            .serve(8, make_frame, &mut NativeEngine::default())
            .unwrap();
        assert_eq!(a.completions.len(), b.completions.len());
        for (x, y) in a.completions.iter().zip(&b.completions) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.result.checksum, y.result.checksum, "frame {}", x.id);
            assert_eq!(x.result.total_pairs(), y.result.total_pairs());
        }
    }

    #[test]
    fn sharded_stream_serves_bit_identical_frames_in_their_own_windows() {
        use crate::coordinator::shard::ShardConfig;
        let plain = StreamServer::new(tiny_net(), RunnerConfig::default(), 8);
        let sharded = StreamServer::new(
            tiny_net(),
            RunnerConfig {
                shard: ShardConfig::grid(2, 2).unwrap(),
                inflight: 3,
                ..Default::default()
            },
            8,
        );
        let a = plain
            .serve(6, make_frame, &mut NativeEngine::default())
            .unwrap();
        let b = sharded
            .serve(6, make_frame, &mut NativeEngine::default())
            .unwrap();
        assert_eq!(a.completions.len(), b.completions.len());
        for (x, y) in a.completions.iter().zip(&b.completions) {
            assert_eq!(x.id, y.id);
            assert_eq!(
                x.result.checksum, y.result.checksum,
                "frame {} diverged under shard scheduling",
                x.id
            );
            assert_eq!(x.result.shards, 1);
            assert!(y.result.shards >= 1);
        }
        assert!(
            b.completions.iter().any(|c| c.result.shards > 1),
            "no frame actually sharded"
        );
    }

    #[test]
    fn modeled_stream_pipeline_is_bounded_by_serial_sum() {
        let srv = StreamServer::new(tiny_net(), RunnerConfig::default(), 4);
        let report = srv
            .serve(4, make_frame, &mut NativeEngine::default())
            .unwrap();
        let pipe = HybridPipeline::default();
        let modeled = report.modeled_pipeline_seconds(&pipe);
        let serial: f64 = report
            .completions
            .iter()
            .map(|c| c.result.ms_seconds() + c.result.compute_seconds())
            .sum();
        assert!(modeled <= serial + 1e-9, "modeled {modeled} vs serial {serial}");
        assert!(modeled >= 0.0);
    }
}
