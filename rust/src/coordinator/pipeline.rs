//! The hybrid pipeline timing model of Fig. 8.
//!
//! * **MS-wise pipeline** — map search for layer i+1 does not depend on
//!   layer i's convolution, only on layer i's map search (the output
//!   coordinate set is known from the search alone); MS(i+1) starts when
//!   MS(i) ends.
//! * **Compute-wise pipeline** — layer i's convolution starts once a
//!   fill-threshold fraction of its IN-OUT pairs is available (it does
//!   not wait for its map search to finish), but must wait for layer
//!   i-1's convolution.
//! * Consecutive subm3 layers share one map search (zero MS time for the
//!   second).
//!
//! Inputs are per-layer (ms_time, compute_time) pairs in seconds; the
//! output is the pipelined end-to-end latency, vs the serial sum.

/// Per-layer phase durations (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTiming {
    pub ms: f64,
    pub compute: f64,
}

/// Hybrid pipeline evaluator.
#[derive(Clone, Debug)]
pub struct HybridPipeline {
    /// Fraction of a layer's map search that must complete before its
    /// compute may start (Fig. 8 shows compute trailing MS closely; we
    /// default to 10%).
    pub fill_threshold: f64,
}

impl Default for HybridPipeline {
    fn default() -> Self {
        Self {
            fill_threshold: 0.1,
        }
    }
}

/// Result of scheduling one frame.
#[derive(Clone, Debug, Default)]
pub struct PipelineSchedule {
    /// (ms_start, ms_end, compute_start, compute_end) per layer.
    pub spans: Vec<(f64, f64, f64, f64)>,
    pub total: f64,
    pub serial_total: f64,
}

impl PipelineSchedule {
    pub fn speedup_vs_serial(&self) -> f64 {
        if self.total == 0.0 {
            1.0
        } else {
            self.serial_total / self.total
        }
    }
}

/// Rolling occupancy of the two cores while scheduling.
#[derive(Clone, Copy, Debug, Default)]
struct CoreState {
    /// When the MS core is next available.
    ms_free: f64,
    /// When the compute core is free.
    compute_free: f64,
}

impl HybridPipeline {
    fn step(&self, state: &mut CoreState, l: &PhaseTiming) -> (f64, f64, f64, f64) {
        let ms_start = state.ms_free;
        let ms_end = ms_start + l.ms;
        state.ms_free = ms_end;
        // Compute may start once the fill threshold of *this* layer's
        // search is done and the compute core is free.
        let gate = ms_start + l.ms * self.fill_threshold.clamp(0.0, 1.0);
        let compute_start = gate.max(state.compute_free);
        // A layer's compute cannot finish before its own MS finishes
        // delivering pairs; model: compute runs at full rate but its
        // completion is at least ms_end (pairs arrive throughout MS).
        let compute_end = (compute_start + l.compute).max(ms_end);
        state.compute_free = compute_end;
        (ms_start, ms_end, compute_start, compute_end)
    }

    /// Schedule a frame. `layers[i]` is the timing of layer i; a layer
    /// with `ms == 0` shares the previous search (consecutive subm3).
    pub fn schedule(&self, layers: &[PhaseTiming]) -> PipelineSchedule {
        self.schedule_stream(std::slice::from_ref(&layers))
    }

    /// Schedule a stream of consecutive frames through the same two
    /// cores: frame i+1's first map search starts as soon as the MS core
    /// drains frame i, while frame i still computes — the Fig. 8 pipeline
    /// extended across frame boundaries, which is what [`StreamServer`]
    /// realizes with its in-flight frame window.
    ///
    /// [`StreamServer`]: crate::coordinator::stream::StreamServer
    pub fn schedule_stream<L: AsRef<[PhaseTiming]>>(&self, frames: &[L]) -> PipelineSchedule {
        let mut spans = Vec::new();
        let mut state = CoreState::default();
        let mut serial = 0.0f64;
        for frame in frames {
            for l in frame.as_ref() {
                serial += l.ms + l.compute;
                spans.push(self.step(&mut state, l));
            }
        }
        let total = spans.iter().map(|s| s.3).fold(0.0f64, f64::max);
        PipelineSchedule {
            spans,
            total,
            serial_total: serial,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::check;

    #[test]
    fn empty_schedule() {
        let s = HybridPipeline::default().schedule(&[]);
        assert_eq!(s.total, 0.0);
    }

    #[test]
    fn single_layer_overlaps_ms_and_compute() {
        let s = HybridPipeline::default().schedule(&[PhaseTiming { ms: 1.0, compute: 1.0 }]);
        // compute starts at 0.1, ends at 1.1 (not 2.0 serial).
        assert!((s.total - 1.1).abs() < 1e-9);
        assert!(s.speedup_vs_serial() > 1.8);
    }

    #[test]
    fn ms_wise_pipeline_runs_ahead() {
        // Layer 2's MS starts when layer 1's MS ends, not when layer 1's
        // compute ends.
        let s = HybridPipeline::default().schedule(&[
            PhaseTiming { ms: 1.0, compute: 5.0 },
            PhaseTiming { ms: 1.0, compute: 1.0 },
        ]);
        let (ms2_start, ..) = s.spans[1];
        assert!((ms2_start - 1.0).abs() < 1e-9);
        // Layer 2 compute waits for layer 1 compute (5.1) then runs.
        assert!((s.total - 6.1).abs() < 1e-9);
    }

    #[test]
    fn shared_subm_search_is_free() {
        let s = HybridPipeline::default().schedule(&[
            PhaseTiming { ms: 1.0, compute: 2.0 },
            PhaseTiming { ms: 0.0, compute: 2.0 }, // shares rulebook
        ]);
        assert!((s.total - 4.1).abs() < 1e-9, "total {}", s.total);
    }

    #[test]
    fn stream_schedule_overlaps_frames_on_both_cores() {
        let frame = vec![
            PhaseTiming { ms: 1.0, compute: 1.0 },
            PhaseTiming { ms: 1.0, compute: 1.0 },
        ];
        let pipe = HybridPipeline::default();
        let one = pipe.schedule(&frame);
        let four = pipe.schedule_stream(&[frame.clone(), frame.clone(), frame.clone(), frame]);
        // Back-to-back frames keep both cores busy: the stream finishes
        // well before 4x a single frame's pipelined latency.
        assert!(four.total < 4.0 * one.total - 1e-9, "{} vs {}", four.total, one.total);
        // ...but never beats the busy-core lower bound (8 units of MS).
        assert!(four.total >= 8.0 - 1e-9);
        assert_eq!(four.spans.len(), 8);
    }

    #[test]
    fn stream_of_one_equals_schedule() {
        let frame = vec![
            PhaseTiming { ms: 0.7, compute: 1.3 },
            PhaseTiming { ms: 0.0, compute: 0.4 },
        ];
        let pipe = HybridPipeline::default();
        let a = pipe.schedule(&frame);
        let b = pipe.schedule_stream(std::slice::from_ref(&frame));
        assert_eq!(a.spans, b.spans);
        assert!((a.total - b.total).abs() < 1e-12);
    }

    #[test]
    fn pipeline_never_beats_critical_path_prop() {
        check("pipeline bounds", 50, |g| {
            let layers: Vec<PhaseTiming> = g.vec(1, 10, |g| PhaseTiming {
                ms: g.f64(0.0, 3.0),
                compute: g.f64(0.0, 3.0),
            });
            let s = HybridPipeline::default().schedule(&layers);
            let ms_sum: f64 = layers.iter().map(|l| l.ms).sum();
            let c_sum: f64 = layers.iter().map(|l| l.compute).sum();
            // Lower bound: both resources are serial pipelines.
            assert!(s.total >= ms_sum - 1e-9);
            assert!(s.total >= c_sum - 1e-9);
            // Upper bound: serial execution.
            assert!(s.total <= s.serial_total + 1e-9);
            // Spans are internally consistent.
            for w in s.spans.windows(2) {
                assert!(w[1].0 >= w[0].0 - 1e-12); // MS order
                assert!(w[1].3 >= w[0].3 - 1e-12); // compute order
            }
        });
    }
}
