//! L3 coordination: the worker pool, the hybrid MS-wise / compute-wise
//! pipeline model (Fig. 8), and the network scheduler that drives whole
//! frames through map search → gather/GEMM/scatter → RPN on the request
//! path.

pub mod executor;
pub mod pipeline;
pub mod scheduler;
pub mod shard;
pub mod stream;

pub use executor::WorkerPool;
pub use pipeline::{HybridPipeline, PhaseTiming};
pub use scheduler::{FrameResult, NetworkRunner, RunnerConfig};
pub use shard::{ShardConfig, ShardPlan};
pub use stream::{StreamReport, StreamServer};
