//! Shard-level scheduling: one oversized scene becomes a lockstep group
//! of block-partitioned *pseudo-frames*.
//!
//! The engine layer already runs any group of in-flight frames through
//! [`crate::coordinator::NetworkRunner::run_frames`] with shared GEMM
//! waves; this module makes a single huge scene *be* such a group. The
//! scene is split along the block-DOMS `(bx, by)` grid
//! ([`BlockDoms::partition_for`] — the same partition §3.1D uses to
//! downsize depths), each shard is padded with a halo wide enough to
//! cover the sparse prefix's receptive field, and per-shard outputs are
//! merged back by block ownership.
//!
//! ```text
//!            scene                     pseudo-frames (lockstep group)
//!   ┌───────────┬───────────┐      ┌────────────┐┌────────────┐
//!   │  block    │  block    │      │ (0,0)+halo ││ (1,0)+halo │ ...
//!   │  (0,0)    │  (1,0)    │  →   └────────────┘└────────────┘
//!   ├───────────┼───────────┤            │  run_frames (shared waves)
//!   │  (0,1)    │  (1,1)    │            ▼
//!   └───────────┴───────────┘      merge by block ownership → one frame
//! ```
//!
//! Because the halo closes every owned output's dependency cone, the
//! merged result is bit-identical to the unsharded run: rule pairs that
//! cross a shard edge are recovered inside the neighbors' halos — the
//! cross-block story of Alg. 1 lifted from map search to the whole
//! schedule (checksum-verified in `tests/shard_scheduler.rs`).

use crate::geom::Coord3;
use crate::mapsearch::table::BlockPartition;
use crate::mapsearch::BlockDoms;
use crate::model::layer::LayerSpec;
use crate::sparse::tensor::SparseTensor;
use crate::util::config::Config;

/// The `[shard]` section of a run config: block-shard scheduling of
/// oversized scenes (`1x1` = off).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardConfig {
    pub blocks_x: usize,
    pub blocks_y: usize,
    /// Scenes below this voxel count run unsharded (0 = always shard
    /// when the grid is larger than 1x1).
    pub auto_threshold: usize,
}

impl Default for ShardConfig {
    fn default() -> Self {
        Self {
            blocks_x: 1,
            blocks_y: 1,
            auto_threshold: 0,
        }
    }
}

impl ShardConfig {
    /// A validated `bx x by` grid (auto threshold 0: always shard).
    pub fn grid(bx: usize, by: usize) -> crate::Result<Self> {
        // Zero-sized grids are config errors, reported through the same
        // validation the block-DOMS searcher applies.
        BlockDoms::with_partition(bx, by)?;
        Ok(Self {
            blocks_x: bx,
            blocks_y: by,
            auto_threshold: 0,
        })
    }

    /// Read the `[shard]` keys of a run config. Strict: zero-sized grids
    /// and non-integer / negative values are errors, never silent
    /// fallbacks.
    pub fn from_config(cfg: &Config) -> crate::Result<Self> {
        let d = Self::default();
        let s = Self {
            blocks_x: cfg.usize_or("shard.blocks_x", d.blocks_x)?,
            blocks_y: cfg.usize_or("shard.blocks_y", d.blocks_y)?,
            auto_threshold: cfg.usize_or("shard.auto_threshold", d.auto_threshold)?,
        };
        BlockDoms::with_partition(s.blocks_x, s.blocks_y)?;
        Ok(s)
    }

    pub fn num_blocks(&self) -> usize {
        self.blocks_x * self.blocks_y
    }

    /// Whether a scene of `n_voxels` gets sharded under this config.
    pub fn active_for(&self, n_voxels: usize) -> bool {
        self.num_blocks() > 1 && n_voxels >= self.auto_threshold
    }
}

/// Halo width (in input voxels, x/y Chebyshev distance) and final
/// coordinate scale of a sparse prefix.
///
/// Every output coordinate `c` of the prefix has a fine-grid *anchor*
/// `c * scale` (scale = cumulative stride). Walking the layers forward,
/// each layer's kernel support moves the anchor of a dependency by at
/// most one voxel at that layer's input resolution, so the sum of those
/// step sizes bounds the whole receptive cone: every input voxel an
/// output depends on (transitively, including coordinate *existence* for
/// downsampling layers) lies within `halo` of its anchor. A shard that
/// carries `halo` extra voxels around its owned block therefore computes
/// its owned outputs bit-identically to the full scene.
pub fn prefix_halo(layers: &[LayerSpec]) -> crate::Result<(usize, usize)> {
    let mut halo = 0usize;
    let mut scale = 1usize;
    for l in layers {
        match l {
            // subm3: inputs at c ± 1 (same resolution).
            LayerSpec::Subm3 { .. } => halo += scale,
            // gconv2: inputs at 2c + {0, 1}, one step at the *input*
            // resolution, then the anchor scale doubles.
            LayerSpec::GConv2 { .. } => {
                halo += scale;
                scale *= 2;
            }
            // tconv2 (k = s = 2): the unique parent is floor(c / 2) — at
            // most one step at the *output* resolution.
            LayerSpec::TConv2 { .. } => {
                anyhow::ensure!(
                    scale >= 2,
                    "shard scheduling needs every TConv2 preceded by a matching \
                     GConv2 (the net would upsample past input resolution)"
                );
                scale /= 2;
                halo += scale;
            }
            other => anyhow::bail!("dense layer {other:?} inside the sparse prefix"),
        }
    }
    Ok((halo, scale))
}

/// Static map-search slot walk for the temporal delta cache: one
/// [`SlotSpec`] per *fresh* Subm3 search of the sparse prefix — a Subm3
/// not immediately preceded by another Subm3, mirroring the scheduler's
/// rulebook sharing (`NetworkSpec::n_map_searches`). Each spec records
/// the [`prefix_halo`]-style receptive-cone radius *through that slot's
/// layer inclusive* and the slot tensor's coordinate scale: a cached
/// block fragment stays valid exactly when every layer-0 block within
/// that halo is clean.
///
/// Unlike [`prefix_halo`] this walk never errors: it stops at the first
/// layer the sparse prefix cannot absorb (a dense layer, or a TConv2
/// below input resolution) and returns the specs gathered so far —
/// runtime searches past that point simply bypass the cache, which keeps
/// the walk a *prefix* of the runtime search sequence.
pub fn delta_slot_specs(layers: &[LayerSpec]) -> Vec<crate::mapsearch::SlotSpec> {
    let mut specs = Vec::new();
    let (mut halo, mut scale) = (0usize, 1usize);
    let mut prev_subm = false;
    for l in layers {
        match l {
            LayerSpec::Subm3 { .. } => {
                halo += scale;
                if !prev_subm {
                    specs.push(crate::mapsearch::SlotSpec { halo, scale });
                }
                prev_subm = true;
            }
            LayerSpec::GConv2 { .. } => {
                halo += scale;
                scale *= 2;
                prev_subm = false;
            }
            LayerSpec::TConv2 { .. } => {
                if scale < 2 {
                    break;
                }
                scale /= 2;
                halo += scale;
                prev_subm = false;
            }
            _ => break,
        }
    }
    specs
}

/// Static compute-slot walk for the temporal delta cache's compute-core
/// reuse: one [`SlotSpec`] per *layer* of the sparse prefix (slot index
/// == layer index), recording the accumulated receptive-cone radius of
/// that layer's **output** in layer-0 voxels plus the output coordinate
/// scale. A cached block of output rows (psums) is valid exactly when
/// every layer-0 block within `ceil(halo / block_w)` Chebyshev blocks is
/// clean in both coordinates *and* features — clean cone ⇒ identical
/// rule pairs and identical input features ⇒ identical psums (weights
/// are deterministic per layer) ⇒ identical features through the pure
/// per-row requant epilogue.
///
/// Unlike [`delta_slot_specs`] (one slot per fresh search) this walk is
/// dense over the layer prefix, because *every* layer's GEMM waves are
/// re-dispatched per frame even when its rulebook was spliced. It stops
/// at the first layer it cannot absorb (dense, or TConv2 — decoder
/// reuse would need union-cones across the skip connection), keeping the
/// specs a safe prefix: layers past the stop simply bypass compute
/// reuse.
pub fn delta_compute_specs(layers: &[LayerSpec]) -> Vec<crate::mapsearch::SlotSpec> {
    let mut specs = Vec::new();
    let (mut halo, mut scale) = (0usize, 1usize);
    for l in layers {
        match l {
            LayerSpec::Subm3 { .. } => {
                halo += scale;
                specs.push(crate::mapsearch::SlotSpec { halo, scale });
            }
            LayerSpec::GConv2 { .. } => {
                halo += scale;
                scale *= 2;
                // The *output* scale: cached rows are binned to layer-0
                // blocks through the anchor `c * scale`.
                specs.push(crate::mapsearch::SlotSpec { halo, scale });
            }
            _ => break,
        }
    }
    specs
}

/// One pseudo-frame: a block's owned voxels plus its halo ring, at the
/// scene's global coordinates and full extent. Geometry is untouched —
/// only membership shrinks — so every searcher treats a shard exactly
/// like a small frame.
#[derive(Clone, Debug)]
pub struct Shard {
    /// Block id `(i, j)` in the partition grid.
    pub block: (usize, usize),
    pub tensor: SparseTensor,
    /// Voxels this shard owns (the merge keeps only their outputs).
    pub owned: usize,
}

/// A planned sharding of one scene.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    pub part: BlockPartition,
    /// Halo width in input voxels (see [`prefix_halo`]).
    pub halo: usize,
    /// Cumulative stride of the prefix output — the merge's ownership
    /// anchor scale.
    pub scale: usize,
    /// Non-empty shards. Blocks whose halo-padded region holds no voxels
    /// are dropped: with an empty region there is no input inside any
    /// owned output's receptive cone, so such a block cannot own outputs.
    pub shards: Vec<Shard>,
}

impl ShardPlan {
    /// Split `input` into halo-padded block shards for the given sparse
    /// prefix (every layer before the first dense layer). Single pass
    /// over the scene: each voxel is routed to the handful of blocks
    /// whose halo-padded region covers it (at most
    /// `(2*halo/block_w + 1) * (2*halo/block_h + 1)`), not rescanned per
    /// block — this planner runs exactly on the oversized scenes the
    /// shard path exists for.
    pub fn plan(
        prefix: &[LayerSpec],
        input: &SparseTensor,
        bx: usize,
        by: usize,
    ) -> crate::Result<ShardPlan> {
        let part = BlockDoms::with_partition(bx, by)?.partition_for(input);
        let (halo, scale) = prefix_halo(prefix)?;
        let (bw, bh) = (part.block_w(), part.block_h());
        // Does `v` fall in block `b`'s halo-padded region along one axis?
        // Blocks past the extent (trailing blocks of a non-dividing grid)
        // have an empty owned rect and accept nothing.
        let in_region = |b: usize, bs: usize, ext: usize, v: usize| -> bool {
            let lo = (b * bs).saturating_sub(halo);
            let hi = (((b + 1) * bs).min(ext) + halo).min(ext);
            b * bs < ext && v >= lo && v < hi
        };
        // Candidate window [v-halo, v+halo] in block units; every block
        // whose region covers `v` lies inside it (checked precisely by
        // `in_region`).
        let window = |v: usize, bs: usize, n: usize| -> (usize, usize) {
            (v.saturating_sub(halo) / bs, ((v + halo) / bs).min(n - 1))
        };
        let mut members: Vec<Vec<u32>> = vec![Vec::new(); part.num_blocks()];
        let mut owned_counts = vec![0usize; part.num_blocks()];
        for (vi, &c) in input.coords.iter().enumerate() {
            let owner = part.block_of(c);
            let (ix_lo, ix_hi) = window(c.x as usize, bw, bx);
            let (iy_lo, iy_hi) = window(c.y as usize, bh, by);
            for j in iy_lo..=iy_hi {
                for i in ix_lo..=ix_hi {
                    if in_region(i, bw, input.extent.x, c.x as usize)
                        && in_region(j, bh, input.extent.y, c.y as usize)
                    {
                        members[j * bx + i].push(vi as u32);
                        if (i, j) == owner {
                            owned_counts[j * bx + i] += 1;
                        }
                    }
                }
            }
        }
        let mut shards = Vec::with_capacity(part.num_blocks());
        for j in 0..by {
            for i in 0..bx {
                let m = &members[j * bx + i];
                if m.is_empty() {
                    continue;
                }
                let pairs: Vec<(Coord3, Vec<i8>)> = m
                    .iter()
                    .map(|&vi| (input.coords[vi as usize], input.feature(vi as usize).to_vec()))
                    .collect();
                shards.push(Shard {
                    block: (i, j),
                    tensor: SparseTensor::new(input.extent, pairs, input.channels),
                    owned: owned_counts[j * bx + i],
                });
            }
        }
        Ok(ShardPlan {
            part,
            halo,
            scale,
            shards,
        })
    }

    /// Merge per-shard prefix outputs into one scene tensor: each shard
    /// keeps exactly the coordinates whose fine anchor `(c.x * scale,
    /// c.y * scale)` falls in its own block. Ownership is a function of
    /// the coordinate, so the kept sets partition the output set — the
    /// union is complete and duplicate-free, and the features are the
    /// unsharded run's bit for bit (the halo closed every owned cone).
    /// `outs` must arrive in `self.shards` order.
    pub fn merge<'a>(
        &self,
        outs: impl ExactSizeIterator<Item = &'a SparseTensor>,
    ) -> crate::Result<SparseTensor> {
        anyhow::ensure!(!self.shards.is_empty(), "merge of an empty shard plan");
        anyhow::ensure!(
            outs.len() == self.shards.len(),
            "one output tensor per shard"
        );
        let s = self.scale as i32;
        let mut pairs: Vec<(Coord3, Vec<i8>)> = Vec::new();
        let mut channels = 0usize;
        let mut extent = None;
        for (shard, t) in self.shards.iter().zip(outs) {
            channels = t.channels;
            match extent {
                None => extent = Some(t.extent),
                Some(e) => anyhow::ensure!(e == t.extent, "shard output extents diverged"),
            }
            for (i, &c) in t.coords.iter().enumerate() {
                let anchor = Coord3::new(c.x * s, c.y * s, c.z);
                if self.part.block_of(anchor) == shard.block {
                    pairs.push((c, t.feature(i).to_vec()));
                }
            }
        }
        let extent =
            extent.ok_or_else(|| anyhow::anyhow!("merge called with zero shard outputs"))?;
        Ok(SparseTensor::new(extent, pairs, channels.max(1)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Extent3;
    use crate::pointcloud::voxelize::Voxelizer;

    fn scene(e: Extent3, n: usize, seed: u64) -> SparseTensor {
        let g = Voxelizer::synth_occupancy(e, n as f64 / e.volume() as f64, seed);
        let mut t = SparseTensor::from_coords(e, g.coords(), 2);
        let mut rng = crate::util::rng::Pcg64::new(seed ^ 0xabc);
        for v in t.features.iter_mut() {
            *v = rng.next_i8(-5, 6);
        }
        t
    }

    #[test]
    fn halo_tracks_receptive_field_and_scale() {
        use LayerSpec::*;
        // Two subm3: radius 2 at scale 1.
        let (h, s) = prefix_halo(&[
            Subm3 { c_in: 4, c_out: 8 },
            Subm3 { c_in: 8, c_out: 8 },
        ])
        .unwrap();
        assert_eq!((h, s), (2, 1));
        // subm3, gconv2, subm3: 1 + 1, then one coarse step = 2 fine.
        let (h, s) = prefix_halo(&[
            Subm3 { c_in: 4, c_out: 8 },
            GConv2 { c_in: 8, c_out: 16 },
            Subm3 { c_in: 16, c_out: 16 },
        ])
        .unwrap();
        assert_eq!((h, s), (4, 2));
        // Encoder-decoder returns to scale 1.
        let (h, s) = prefix_halo(&[
            GConv2 { c_in: 4, c_out: 8 },
            TConv2 { c_in: 8, c_out: 8 },
        ])
        .unwrap();
        assert_eq!(s, 1);
        assert_eq!(h, 2);
        // Upsampling past input resolution is unsupported.
        assert!(prefix_halo(&[TConv2 { c_in: 4, c_out: 4 }]).is_err());
        // Dense layers never belong to a sparse prefix.
        assert!(prefix_halo(&[ToBev]).is_err());
    }

    #[test]
    fn slot_specs_follow_rulebook_sharing() {
        use crate::mapsearch::SlotSpec;
        use LayerSpec::*;
        // Stream-backbone shape: two slots — the consecutive Subm3 pair
        // shares the first search; the post-GConv2 Subm3 is the second.
        let specs = delta_slot_specs(&[
            Subm3 { c_in: 4, c_out: 16 },
            Subm3 { c_in: 16, c_out: 16 },
            GConv2 { c_in: 16, c_out: 32 },
            Subm3 { c_in: 32, c_out: 32 },
        ]);
        assert_eq!(
            specs,
            vec![SlotSpec { halo: 1, scale: 1 }, SlotSpec { halo: 5, scale: 2 }]
        );
        // The walk stops at the first dense layer instead of erroring.
        let specs = delta_slot_specs(&[
            Subm3 { c_in: 4, c_out: 8 },
            ToBev,
            Subm3 { c_in: 8, c_out: 8 },
        ]);
        assert_eq!(specs, vec![SlotSpec { halo: 1, scale: 1 }]);
        // Encoder-decoder: the decoder-side Subm3 gets the full cone.
        let specs = delta_slot_specs(&[
            GConv2 { c_in: 4, c_out: 8 },
            TConv2 { c_in: 8, c_out: 8 },
            Subm3 { c_in: 8, c_out: 8 },
        ]);
        assert_eq!(specs, vec![SlotSpec { halo: 3, scale: 1 }]);
        // Upsampling past input resolution stops the walk.
        assert!(delta_slot_specs(&[TConv2 { c_in: 4, c_out: 4 }]).is_empty());
    }

    #[test]
    fn compute_specs_cover_every_prefix_layer() {
        use crate::mapsearch::SlotSpec;
        use LayerSpec::*;
        // Stream-backbone shape: one slot per layer, cones accumulating
        // exactly like prefix_halo, GConv2 slots at the *output* scale.
        let specs = delta_compute_specs(&[
            Subm3 { c_in: 4, c_out: 16 },
            Subm3 { c_in: 16, c_out: 16 },
            GConv2 { c_in: 16, c_out: 32 },
            Subm3 { c_in: 32, c_out: 32 },
        ]);
        assert_eq!(
            specs,
            vec![
                SlotSpec { halo: 1, scale: 1 },
                SlotSpec { halo: 2, scale: 1 },
                SlotSpec { halo: 3, scale: 2 },
                SlotSpec { halo: 5, scale: 2 },
            ]
        );
        // The final slot's cone matches the shard planner's whole-prefix
        // halo: both walks bound the same dependency cone.
        let net = [
            Subm3 { c_in: 4, c_out: 16 },
            Subm3 { c_in: 16, c_out: 16 },
            GConv2 { c_in: 16, c_out: 32 },
            Subm3 { c_in: 32, c_out: 32 },
        ];
        let (h, s) = prefix_halo(&net).unwrap();
        let last = *delta_compute_specs(&net).last().unwrap();
        assert_eq!((last.halo, last.scale), (h, s));
        // Safe prefix: the walk stops at dense layers and TConv2.
        let specs = delta_compute_specs(&[
            Subm3 { c_in: 4, c_out: 8 },
            ToBev,
            Subm3 { c_in: 8, c_out: 8 },
        ]);
        assert_eq!(specs.len(), 1);
        let specs = delta_compute_specs(&[
            GConv2 { c_in: 4, c_out: 8 },
            TConv2 { c_in: 8, c_out: 8 },
            Subm3 { c_in: 8, c_out: 8 },
        ]);
        assert_eq!(specs, vec![SlotSpec { halo: 1, scale: 2 }]);
        assert!(delta_compute_specs(&[ToBev]).is_empty());
    }

    #[test]
    fn plan_with_zero_halo_partitions_the_scene() {
        let t = scene(Extent3::new(32, 24, 6), 260, 9);
        let plan = ShardPlan::plan(&[], &t, 4, 3).unwrap();
        assert_eq!(plan.halo, 0);
        assert_eq!(plan.scale, 1);
        let owned_total: usize = plan.shards.iter().map(|s| s.owned).sum();
        assert_eq!(owned_total, t.len());
        // Zero halo => every shard tensor is exactly its owned set, and
        // the merge reassembles the scene bit for bit.
        let tensors: Vec<&SparseTensor> = plan.shards.iter().map(|s| &s.tensor).collect();
        let merged = plan.merge(tensors.into_iter()).unwrap();
        assert_eq!(merged.coords, t.coords);
        assert_eq!(merged.features, t.features);
    }

    #[test]
    fn halo_voxels_are_shared_between_neighbor_shards() {
        let t = scene(Extent3::new(40, 40, 4), 400, 11);
        let prefix = [LayerSpec::Subm3 { c_in: 2, c_out: 2 }];
        let plan = ShardPlan::plan(&prefix, &t, 2, 2).unwrap();
        assert_eq!(plan.halo, 1);
        let shard_total: usize = plan.shards.iter().map(|s| s.tensor.len()).sum();
        let owned_total: usize = plan.shards.iter().map(|s| s.owned).sum();
        assert_eq!(owned_total, t.len());
        assert!(
            shard_total > t.len(),
            "boundary voxels should be replicated into neighbor halos"
        );
        // Still a partition after merge.
        let tensors: Vec<&SparseTensor> = plan.shards.iter().map(|s| &s.tensor).collect();
        let merged = plan.merge(tensors.into_iter()).unwrap();
        assert_eq!(merged.coords, t.coords);
    }

    #[test]
    fn empty_blocks_are_dropped() {
        // All voxels in the left half: the right-hand blocks (beyond
        // halo reach) plan no shards.
        let e = Extent3::new(64, 16, 4);
        let coords: Vec<Coord3> = (0..12)
            .map(|i| Coord3::new(i % 8, (i / 2) % 16, (i % 4) as i32))
            .collect();
        let t = SparseTensor::from_coords(e, coords, 1);
        let plan = ShardPlan::plan(&[LayerSpec::Subm3 { c_in: 1, c_out: 1 }], &t, 8, 1).unwrap();
        assert!(!plan.shards.is_empty());
        assert!(plan.shards.len() < 8, "empty blocks must be dropped");
        assert!(plan.shards.iter().all(|s| !s.tensor.is_empty()));
    }

    #[test]
    fn shard_config_validation() {
        assert!(ShardConfig::grid(0, 2).is_err());
        assert!(ShardConfig::grid(2, 0).is_err());
        let sc = ShardConfig::grid(2, 8).unwrap();
        assert_eq!(sc.num_blocks(), 16);
        assert!(sc.active_for(0));
        assert!(!ShardConfig::default().active_for(1_000_000));
        let gated = ShardConfig {
            auto_threshold: 500,
            ..ShardConfig::grid(2, 2).unwrap()
        };
        assert!(!gated.active_for(499));
        assert!(gated.active_for(500));
    }
}
