//! `voxel-cim` — the leader binary.
//!
//! ```text
//! voxel-cim exp <fig2d|fig9a|fig9b|fig9c|fig6|fig10|fig11|table2|all>
//! voxel-cim run-det [--points N] [--native]    end-to-end SECOND frame
//! voxel-cim run-seg [--points N] [--native]    end-to-end MinkUNet frame
//! voxel-cim info                               config + artifact status
//! ```

use voxel_cim::coordinator::scheduler::{NetworkRunner, RunnerConfig};
use voxel_cim::experiments as exp;
use voxel_cim::model::{minkunet, second};
use voxel_cim::pointcloud::scene::SceneConfig;
use voxel_cim::pointcloud::vfe::{Vfe, VfeKind};
use voxel_cim::pointcloud::voxelize::Voxelizer;
use voxel_cim::runtime::{Runtime, RuntimeConfig};
use voxel_cim::sparse::tensor::SparseTensor;
use voxel_cim::spconv::layer::{GemmEngine, NativeEngine};
use voxel_cim::util::cli::Args;

fn main() -> voxel_cim::Result<()> {
    let args = Args::new(
        "voxel-cim — Compute-in-Memory accelerator for voxel-based point cloud networks \
         (ICCAD'24 reproduction)\n\nUsage: voxel-cim <exp|run-det|run-seg|info> [flags]",
    )
    .opt("seed", "42", "experiment seed")
    .opt("points", "20000", "LiDAR points per synthetic frame")
    .opt("extent", "small", "grid for run-*: small|full")
    .opt("config", "", "TOML run config (see examples/configs/)")
    .opt(
        "searcher",
        "",
        "map-search engine: hash|weight-major|output-major|octree|doms|block-doms \
         (overrides the config; default doms)",
    )
    .opt(
        "shards",
        "",
        "block-shard the scene into a BXxBY grid of lockstep pseudo-frames \
         (e.g. 2x2, or N for NxN; overrides the [shard] config; bit-identical output)",
    )
    .opt(
        "w2b",
        "",
        "W2B replication budget as a multiple of the kernel volume for wave \
         packing (overrides [runner] w2b_factor; 0 = off)",
    )
    .switch("native", "use the native GEMM engine instead of PJRT artifacts")
    .parse();

    let seed = args.get_u64("seed");
    let pos = args.positional();
    match pos.first().map(String::as_str) {
        Some("exp") => run_experiments(pos.get(1).map(String::as_str).unwrap_or("all"), seed),
        Some("run-det") => run_net(true, &args),
        Some("run-seg") => run_net(false, &args),
        Some("info") => info(),
        other => {
            eprintln!("unknown command {other:?}\n\n{}", args.usage());
            std::process::exit(2);
        }
    }
}

fn run_experiments(which: &str, seed: u64) -> voxel_cim::Result<()> {
    let all = which == "all";
    if all || which == "fig2d" {
        exp::fig2d::print(&exp::fig2d::run(seed));
    }
    if all || which == "fig9a" {
        exp::fig9::print_sweep(
            "Fig. 9(a) — low resolution (352x400x10)",
            &exp::fig9::run_a(seed),
        );
    }
    if all || which == "fig9b" {
        exp::fig9::print_sweep(
            "Fig. 9(b) — high resolution (1408x1600x41)",
            &exp::fig9::run_b(seed),
        );
    }
    if all || which == "fig9c" {
        exp::fig9::print_c(&exp::fig9::run_c(seed));
    }
    if all || which == "fig6" {
        exp::w2b_fig10::print_fig6(&exp::w2b_fig10::run_fig6(seed));
    }
    if all || which == "fig10" {
        exp::w2b_fig10::print_fig10(&exp::w2b_fig10::run_fig10(seed));
    }
    if all || which == "fig11" {
        exp::fig11::print(&exp::fig11::run(seed));
    }
    if all || which == "table2" {
        exp::table2::print(&exp::table2::run(seed));
    }
    if all || which == "ablations" {
        exp::ablations::print_all(seed);
    }
    Ok(())
}

fn run_net(detection: bool, args: &Args) -> voxel_cim::Result<()> {
    // Optional TOML config overrides the CLI defaults.
    let cfg = match args.get("config") {
        "" => voxel_cim::util::config::Config::default(),
        path => voxel_cim::util::config::Config::load(path)?,
    };
    let full = args.get("extent") == "full";
    let net = match (detection, full) {
        (true, true) => second::second(),
        (true, false) => second::second_small(),
        (false, true) => minkunet::minkunet(),
        (false, false) => minkunet::minkunet_small(),
    };
    println!("network: {} | extent {:?}", net.name, net.extent);

    // Synthetic frame -> voxelize -> VFE (the preprocessing path).
    let mut scene = SceneConfig::default()
        .with_points(cfg.int_or("scene.points", args.get_usize("points") as i64) as usize)
        .with_seed(cfg.int_or("seed", args.get_u64("seed") as i64) as u64);
    if let Some(kind) =
        voxel_cim::pointcloud::scene::SceneKind::parse(cfg.str_or("scene.kind", "urban"))
    {
        scene.kind = kind;
    }
    let scene = scene;
    let pts = scene.generate();
    let e = net.extent;
    let vx = Voxelizer::new((70.4, 80.0, 4.0), e, 32);
    let grid = vx.voxelize(&pts);
    let vfe = Vfe::new(VfeKind::Simple);
    let (feats, scale) = vfe.extract_i8(&grid);
    println!(
        "frame: {} points -> {} voxels (sparsity {:.5}, vfe scale {:.4})",
        pts.len(),
        grid.len(),
        grid.sparsity(),
        scale
    );
    let input = SparseTensor::new(
        e,
        grid.voxels
            .iter()
            .enumerate()
            .map(|(i, v)| (v.coord, feats[i * 4..(i + 1) * 4].to_vec()))
            .collect(),
        4,
    );

    let mut runner_cfg = RunnerConfig::from_config(&cfg)?;
    match args.get("searcher") {
        "" => {}
        s => runner_cfg.searcher = s.parse().map_err(anyhow::Error::msg)?,
    }
    match args.get("shards") {
        "" => {}
        s => {
            let (bx, by) = voxel_cim::util::cli::parse_grid(s).map_err(anyhow::Error::msg)?;
            runner_cfg.shard = voxel_cim::coordinator::shard::ShardConfig::grid(bx, by)?;
        }
    }
    match args.get("w2b") {
        "" => {}
        s => {
            runner_cfg.w2b_factor = s
                .parse()
                .map_err(|e| anyhow::anyhow!("--w2b: not an integer ({e})"))?
        }
    }
    println!(
        "engine layer: searcher={} batch={} workers={} compute_workers={} w2b={} shards={}x{}",
        runner_cfg.searcher,
        runner_cfg.batch,
        runner_cfg.workers,
        runner_cfg.compute_workers,
        runner_cfg.w2b_factor,
        runner_cfg.shard.blocks_x,
        runner_cfg.shard.blocks_y,
    );
    let runner = NetworkRunner::new(net, runner_cfg);
    let res = if args.get_bool("native") {
        let mut engine = NativeEngine::default();
        runner.run_frame_sharded(input, &mut engine)?
    } else {
        let mut engine = Runtime::load(&RuntimeConfig::discover())?;
        println!("runtime: PJRT CPU, batches {:?}", engine.gemm_batches());
        let r = runner.run_frame_sharded(input, &mut engine)?;
        println!("PJRT dispatches: {}", engine.dispatches());
        r
    };
    if res.shards > 1 {
        println!("shard scheduler: scene served as {} lockstep pseudo-frames", res.shards);
    }

    println!("\nper-layer:");
    for r in &res.records {
        println!(
            "  {:<38} pairs {:>9}  out {:>8}  ms {:>9.3?}ms  compute {:>9.3}ms",
            r.name,
            r.pairs,
            r.out_voxels,
            r.ms_seconds * 1e3,
            r.compute_seconds * 1e3
        );
    }
    println!(
        "\ntotal: {:.1} ms ({} pairs, map-search {:.1} ms, compute {:.1} ms)",
        res.total_seconds * 1e3,
        res.total_pairs(),
        res.ms_seconds() * 1e3,
        res.compute_seconds() * 1e3
    );
    if let Some((h, w, c)) = res.head_shape {
        println!("detection head: {h}x{w}x{c}");
    } else {
        println!("segmentation output voxels: {}", res.out_voxels);
    }
    Ok(())
}

fn info() -> voxel_cim::Result<()> {
    use voxel_cim::cim::{CimConfig, EnergyModel};
    let cim = CimConfig::default();
    let em = EnergyModel::default();
    println!("Voxel-CIM configuration");
    println!("  tiles: {} x {}x{} cells", cim.tiles, cim.tile_rows, cim.tile_cols);
    println!("  weight capacity: {} int8", cim.weight_capacity());
    println!("  peak throughput: {:.1} TOPS @ {:.0} MHz", cim.peak_tops(), cim.freq_hz / 1e6);
    println!("  peak efficiency: {:.2} TOPS/W", em.peak_tops_per_watt(&cim));
    let searchers: Vec<&str> = voxel_cim::mapsearch::SearcherKind::ALL
        .iter()
        .map(|k| k.key())
        .collect();
    println!("  searchers: {}", searchers.join(", "));
    match Runtime::load(&RuntimeConfig::discover()) {
        Ok(rt) => println!("  artifacts: loaded (GEMM batches {:?})", rt.gemm_batches()),
        Err(e) => println!("  artifacts: NOT loaded ({e:#}) — run `make artifacts`"),
    }
    Ok(())
}
