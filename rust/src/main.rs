//! `voxel-cim` — the leader binary.
//!
//! ```text
//! voxel-cim exp <fig2d|fig9a|fig9b|fig9c|fig6|fig10|fig11|table2|all>
//! voxel-cim run-det [--points N] [--native]    end-to-end SECOND frame
//! voxel-cim run-seg [--points N] [--native]    end-to-end MinkUNet frame
//! voxel-cim stream [--dataset D] [--frames N]  serve a frame stream
//!                  [--sequences A,B] [--admission P] [--slo MS] [--delta]
//!                  multi-sequence muxing + SLO-aware admission
//!                  [--trace] [--trace-out T.json] [--metrics-out M.json]
//!                  stage-span tracing + metrics export
//!                  [--cost]  modeled data-movement / energy footer
//! voxel-cim info                               config + artifact status
//! ```
//!
//! Every command goes through the pipeline facade: one
//! [`PipelineConfig`] load (all config sections in a single strict
//! pass), one [`Overrides`] application (the CLI flags), one
//! [`Pipeline::builder`] — then a single [`Pipeline::run`] submission
//! (`Job::Frame` for `run-det` / `run-seg`, `Job::Stream` for `stream`).
//! The engine (PJRT artifacts or the native fallback) is owned by the
//! pipeline; no command threads `&mut E` by hand anymore.

use voxel_cim::dataset::FrameSource;
use voxel_cim::experiments as exp;
use voxel_cim::model::{minkunet, second};
use voxel_cim::pipeline::{Job, Overrides, Pipeline, PipelineConfig};
use voxel_cim::pointcloud::scene::SceneConfig;
use voxel_cim::pointcloud::vfe::{Vfe, VfeKind};
use voxel_cim::pointcloud::voxelize::Voxelizer;
use voxel_cim::runtime::{Runtime, RuntimeConfig};
use voxel_cim::sparse::tensor::SparseTensor;
use voxel_cim::util::cli::Args;
use voxel_cim::util::config::Config;

fn main() -> voxel_cim::Result<()> {
    let args = Args::new(
        "voxel-cim — Compute-in-Memory accelerator for voxel-based point cloud networks \
         (ICCAD'24 reproduction)\n\nUsage: voxel-cim <exp|run-det|run-seg|stream|info> [flags]",
    )
    .opt("seed", "42", "experiment seed")
    .opt("points", "20000", "LiDAR points per synthetic frame")
    .opt("extent", "small", "grid for run-*: small|full")
    .opt("config", "", "TOML run config (see examples/configs/)")
    .opt(
        "searcher",
        "",
        "map-search engine: hash|weight-major|output-major|octree|doms|block-doms \
         (overrides the config; default doms)",
    )
    .opt(
        "shards",
        "",
        "block-shard the scene into a BXxBY grid of lockstep pseudo-frames \
         (e.g. 2x2, or N for NxN; overrides the [shard] config; bit-identical output)",
    )
    .opt(
        "w2b",
        "",
        "W2B replication budget as a multiple of the kernel volume for wave \
         packing (overrides [runner] w2b_factor; 0 = off)",
    )
    .opt(
        "dataset",
        "",
        "frame source: a KITTI velodyne directory or a scenario profile \
         (urban|highway|indoor|far-field); overrides [dataset] source",
    )
    .opt(
        "frames",
        "",
        "frames to serve with the `stream` command (overrides [dataset] frames)",
    )
    .opt(
        "sequences",
        "",
        "comma-separated frame sources muxed into one stream (profiles or \
         KITTI dirs, e.g. urban,far-field); overrides [serving] sequences",
    )
    .opt(
        "admission",
        "",
        "SLO admission policy: none|drop-oldest|defer-sharding|reject-over-depth \
         (overrides [serving] admission)",
    )
    .opt(
        "slo",
        "",
        "p95 latency target in ms driving the admission policy \
         (overrides [serving] slo_ms; 0 = off)",
    )
    .switch("native", "use the native GEMM engine instead of PJRT artifacts")
    .switch(
        "delta",
        "enable the temporal delta map-search cache: warm stream frames re-search \
         only dirty blocks and splice the rest (overrides [runner] delta; bit-identical)",
    )
    .switch(
        "delta-compute",
        "extend the delta cache through the GEMM core: clean-cone blocks splice \
         cached psum rows and skip their gather rows and waves (implies --delta; \
         bit-identical)",
    )
    .switch(
        "delta-voxelize",
        "extend the delta cache through voxelization: KITTI sources re-bin only \
         dirty blocks' points (implies --delta; bit-identical)",
    )
    .switch(
        "trace",
        "record stage spans (voxelize/map_search/gemm_wave/...) and print the \
         per-stage breakdown in the stream footer (overrides [observability] trace)",
    )
    .opt(
        "trace-out",
        "",
        "write the recorded spans as Chrome trace-event JSON to this path \
         (loads in Perfetto / chrome://tracing; implies --trace)",
    )
    .opt(
        "metrics-out",
        "",
        "write a JSON snapshot of the metrics registry (counters, gauges, \
         per-stage histograms) to this path",
    )
    .switch(
        "cost",
        "account modeled data movement (bytes) and energy (joules) for the served \
         stream — cost.* counters, per-wave occupancy, and a cost footer \
         (overrides [observability] cost; implies the metrics registry)",
    )
    .parse();

    let seed = args.get_u64("seed");
    let pos = args.positional();
    match pos.first().map(String::as_str) {
        Some("exp") => run_experiments(pos.get(1).map(String::as_str).unwrap_or("all"), seed),
        Some("run-det") => run_net(true, &args),
        Some("run-seg") => run_net(false, &args),
        Some("stream") => run_stream(&args),
        Some("info") => info(),
        other => {
            eprintln!("unknown command {other:?}\n\n{}", args.usage());
            std::process::exit(2);
        }
    }
}

fn run_experiments(which: &str, seed: u64) -> voxel_cim::Result<()> {
    let all = which == "all";
    if all || which == "fig2d" {
        exp::fig2d::print(&exp::fig2d::run(seed));
    }
    if all || which == "fig9a" {
        exp::fig9::print_sweep(
            "Fig. 9(a) — low resolution (352x400x10)",
            &exp::fig9::run_a(seed),
        );
    }
    if all || which == "fig9b" {
        exp::fig9::print_sweep(
            "Fig. 9(b) — high resolution (1408x1600x41)",
            &exp::fig9::run_b(seed),
        );
    }
    if all || which == "fig9c" {
        exp::fig9::print_c(&exp::fig9::run_c(seed));
    }
    if all || which == "fig6" {
        exp::w2b_fig10::print_fig6(&exp::w2b_fig10::run_fig6(seed));
    }
    if all || which == "fig10" {
        exp::w2b_fig10::print_fig10(&exp::w2b_fig10::run_fig10(seed));
    }
    if all || which == "fig11" {
        exp::fig11::print(&exp::fig11::run(seed));
    }
    if all || which == "table2" {
        exp::table2::print(&exp::table2::run(seed));
    }
    if all || which == "ablations" {
        exp::ablations::print_all(seed);
    }
    Ok(())
}

/// The one config path of every command: load the (optional) TOML run
/// config, parse every section strictly, apply the CLI overrides. The
/// raw [`Config`] is returned too for the synthetic-scene keys
/// (`[scene]`) that only `run-det` / `run-seg` read.
fn load_config(args: &Args) -> voxel_cim::Result<(PipelineConfig, Config)> {
    let raw = match args.get("config") {
        "" => Config::default(),
        path => Config::load(path)?,
    };
    let mut cfg = PipelineConfig::from_config(&raw)?;
    cfg.apply(&Overrides::from_args(args))?;
    Ok((cfg, raw))
}

fn run_net(detection: bool, args: &Args) -> voxel_cim::Result<()> {
    let (cfg, raw) = load_config(args)?;
    let full = args.get("extent") == "full";
    let net = match (detection, full) {
        (true, true) => second::second(),
        (true, false) => second::second_small(),
        (false, true) => minkunet::minkunet(),
        (false, false) => minkunet::minkunet_small(),
    };
    println!("network: {} | extent {:?}", net.name, net.extent);
    let e = net.extent;

    // Frame input: the `[dataset]` / `--dataset` ingestion subsystem when
    // configured, else the classic synthetic scene -> voxelize -> VFE path.
    let input = match cfg.dataset.build(e)? {
        Some(mut source) => {
            let frame = source
                .next_frame()
                .ok_or_else(|| anyhow::anyhow!("dataset {:?} produced no frames", source.label()))?;
            println!(
                "frame (from {}): id {} | {} points -> {} voxels",
                source.label(),
                frame.meta.id,
                frame.meta.points,
                frame.tensor.len(),
            );
            anyhow::ensure!(
                frame.meta.extent == e,
                "dataset frame extent {:?} does not match network extent {e:?} \
                 (set [dataset] dims to the network grid)",
                frame.meta.extent
            );
            frame.tensor
        }
        None => {
            let mut scene = SceneConfig::default()
                .with_points(raw.int_or("scene.points", args.get_usize("points") as i64) as usize)
                .with_seed(raw.int_or("seed", args.get_u64("seed") as i64) as u64);
            if let Some(kind) =
                voxel_cim::pointcloud::scene::SceneKind::parse(raw.str_or("scene.kind", "urban"))
            {
                scene.kind = kind;
            }
            let pts = scene.generate();
            let vx = Voxelizer::new((70.4, 80.0, 4.0), e, 32);
            let grid = vx.voxelize(&pts);
            let vfe = Vfe::new(VfeKind::Simple);
            let (feats, scale) = vfe.extract_i8(&grid);
            println!(
                "frame: {} points -> {} voxels (sparsity {:.5}, vfe scale {:.4})",
                pts.len(),
                grid.len(),
                grid.sparsity(),
                scale
            );
            SparseTensor::new(
                e,
                grid.voxels
                    .iter()
                    .enumerate()
                    .map(|(i, v)| (v.coord, feats[i * 4..(i + 1) * 4].to_vec()))
                    .collect(),
                4,
            )
        }
    };

    let rc = cfg.runner;
    println!(
        "engine layer: searcher={} batch={} workers={} compute_workers={} w2b={} shards={}x{}",
        rc.searcher,
        rc.batch,
        rc.workers,
        rc.compute_workers,
        rc.w2b_factor,
        rc.shard.blocks_x,
        rc.shard.blocks_y,
    );
    let mut pipe = Pipeline::builder().config(cfg).network(net).build()?;
    println!("engine: {}", pipe.engine_desc());
    let res = pipe.run(Job::Frame(input))?.into_frame()?;
    println!("engine dispatches: {}", pipe.dispatches());
    if res.shards > 1 {
        println!("shard scheduler: scene served as {} lockstep pseudo-frames", res.shards);
    }

    println!("\nper-layer:");
    for r in &res.records {
        println!(
            "  {:<38} pairs {:>9}  out {:>8}  ms {:>9.3?}ms  compute {:>9.3}ms",
            r.name,
            r.pairs,
            r.out_voxels,
            r.ms_seconds * 1e3,
            r.compute_seconds * 1e3
        );
    }
    println!(
        "\ntotal: {:.1} ms ({} pairs, map-search {:.1} ms, compute {:.1} ms)",
        res.total_seconds * 1e3,
        res.total_pairs(),
        res.ms_seconds() * 1e3,
        res.compute_seconds() * 1e3
    );
    if let Some((h, w, c)) = res.head_shape {
        println!("detection head: {h}x{w}x{c}");
    } else {
        println!("segmentation output voxels: {}", res.out_voxels);
    }
    Ok(())
}

/// `voxel-cim stream` — serve a frame stream from the configured dataset
/// source (a KITTI directory or a scenario profile), or several of them
/// muxed (`--sequences`), through the serving scheduler and report
/// serving-style latency/throughput plus admission actions. (Trace
/// replay is a library-level source: `Trace::load(..).replay()`.)
fn run_stream(args: &Args) -> voxel_cim::Result<()> {
    let (mut cfg, _) = load_config(args)?;
    if cfg.dataset.source.is_empty() {
        cfg.dataset.source = "urban".into();
    }
    let muxed = !cfg.serving.sequences.is_empty();
    let mut pipe = Pipeline::builder().config(cfg).build()?;
    let source: Box<dyn FrameSource> = pipe.open_source()?;
    let cfg = pipe.config();
    println!(
        "stream: {} frames from {} | inflight {} | searcher {} | shards {}x{}{} | \
         window {} | admission {}{}",
        cfg.dataset.frames,
        source.label(),
        cfg.runner.inflight,
        cfg.runner.searcher,
        cfg.runner.shard.blocks_x,
        cfg.runner.shard.blocks_y,
        match (
            cfg.runner.delta.enabled,
            cfg.runner.delta.compute,
            cfg.runner.delta.voxelize,
        ) {
            (false, _, _) => "",
            (true, false, false) => " | delta on",
            (true, true, false) => " | delta on (+compute)",
            (true, false, true) => " | delta on (+voxelize)",
            (true, true, true) => " | delta on (+compute +voxelize)",
        },
        pipe.window(),
        cfg.serving.admission.policy,
        if cfg.serving.admission.slo_ms > 0.0 {
            format!(" (slo {} ms)", cfg.serving.admission.slo_ms)
        } else {
            String::new()
        },
    );
    println!("engine: {}", pipe.engine_desc());
    let delta_voxelize = cfg.runner.delta.enabled && cfg.runner.delta.voxelize;
    let cost_enabled = cfg.observability.cost;
    let trace_out = cfg.observability.trace_out.clone();
    let metrics_out = cfg.observability.metrics_out.clone();
    let report = pipe.run(Job::Stream(source))?.into_stream()?;
    for c in &report.completions {
        println!(
            "  {}frame {:>4}: {:>8} out voxels | latency {:>7.2} ms | own {:>7.2} ms{}",
            if muxed {
                format!("seq {} ", c.sequence)
            } else {
                String::new()
            },
            c.id,
            c.result.out_voxels,
            c.latency * 1e3,
            c.attributed * 1e3,
            if c.result.shards > 1 {
                format!(" | {} pseudo-frames", c.result.shards)
            } else {
                String::new()
            }
        );
    }
    // LatencySummary handles the empty stream (an exhausted or fully
    // shed source) instead of panicking on an empty percentile.
    let latency_line = report
        .latency_summary()
        .map(|s| s.format_ms())
        .unwrap_or_else(|| "no completions".into());
    println!(
        "\nserved {} frames in {:.1} ms over {} windows: {:.2} fps | {}",
        report.completions.len(),
        report.wall_seconds * 1e3,
        report.windows,
        report.throughput_fps(),
        latency_line,
    );
    if let Some(att) = report.attributed_summary() {
        println!("attributed (own-cost) latency: {}", att.format_ms());
    }
    if report.blocks_searched + report.blocks_reused > 0 {
        println!(
            "delta cache: {} blocks searched | {} reused ({:.1}% reuse) | {} evictions",
            report.blocks_searched,
            report.blocks_reused,
            report.reuse_ratio() * 100.0,
            report.evictions,
        );
    }
    if report.waves_skipped + report.rows_gathered_saved > 0 {
        println!(
            "delta compute: {} GEMM waves skipped | {} gather rows saved",
            report.waves_skipped, report.rows_gathered_saved,
        );
    }
    if delta_voxelize {
        println!("delta voxelize: {} voxels re-binned", report.voxels_rebinned);
    }
    let adm = report.admission;
    if adm.dropped + adm.rejected + adm.deferred > 0 {
        println!(
            "admission: {} admitted | {} dropped | {} rejected | {} deferrals",
            adm.admitted, adm.dropped, adm.rejected, adm.deferred
        );
    }
    let stages = report.stage_summary();
    if !stages.is_empty() {
        println!("\nper-stage breakdown (recorded spans):");
        for (name, s) in &stages {
            println!(
                "  {:<12} n {:>6} | p50 {:>8.3} ms | p95 {:>8.3} ms | max {:>8.3} ms",
                name,
                s.n,
                s.p50 * 1e3,
                s.p95 * 1e3,
                s.max * 1e3,
            );
        }
    }
    if cost_enabled {
        let cs = report.cost_summary();
        println!(
            "\ncost model (calibrated EnergyModel/DramModel constants):\n  \
             {:.3} MB moved ({:.3} MB DRAM, {:.3} MB buffers) | {:.2} uJ | \
             {:.1} MMACs | effective {:.2} TOPS/W",
            cs.bytes as f64 / 1e6,
            cs.dram_bytes as f64 / 1e6,
            cs.buffer_bytes as f64 / 1e6,
            cs.joules * 1e6,
            cs.macs as f64 / 1e6,
            cs.tops_per_watt,
        );
        println!(
            "  map-search access volume: {:.2} per input voxel (Fig. 2d/9 normalization)",
            cs.normalized_access,
        );
        if cs.warm_frames > 0 {
            println!(
                "  delta savings: {} warm frames at {:.1} KB DRAM/frame vs {} cold at {:.1} KB",
                cs.warm_frames,
                cs.warm_dram_per_frame / 1e3,
                cs.cold_frames,
                cs.cold_dram_per_frame / 1e3,
            );
        }
        for (name, sc) in &cs.stages {
            println!(
                "  {:<12} {:>12} B | {:>10.3} uJ",
                name,
                sc.bytes,
                sc.joules * 1e6,
            );
        }
    }
    if !trace_out.is_empty() {
        pipe.observer()
            .write_chrome_trace(std::path::Path::new(&trace_out))?;
        println!("trace written to {trace_out} (load in Perfetto / chrome://tracing)");
    }
    if !metrics_out.is_empty() {
        pipe.observer()
            .write_metrics_json(std::path::Path::new(&metrics_out))?;
        println!("metrics snapshot written to {metrics_out}");
    }
    Ok(())
}

fn info() -> voxel_cim::Result<()> {
    use voxel_cim::cim::{CimConfig, EnergyModel};
    let cim = CimConfig::default();
    let em = EnergyModel::default();
    println!("Voxel-CIM configuration");
    println!("  tiles: {} x {}x{} cells", cim.tiles, cim.tile_rows, cim.tile_cols);
    println!("  weight capacity: {} int8", cim.weight_capacity());
    println!("  peak throughput: {:.1} TOPS @ {:.0} MHz", cim.peak_tops(), cim.freq_hz / 1e6);
    println!("  peak efficiency: {:.2} TOPS/W", em.peak_tops_per_watt(&cim));
    let searchers: Vec<&str> = voxel_cim::mapsearch::SearcherKind::ALL
        .iter()
        .map(|k| k.key())
        .collect();
    println!("  searchers: {}", searchers.join(", "));
    match Runtime::load(&RuntimeConfig::discover()) {
        Ok(rt) => println!("  artifacts: loaded (GEMM batches {:?})", rt.gemm_batches()),
        Err(e) => println!("  artifacts: NOT loaded ({e:#}) — run `make artifacts`"),
    }
    Ok(())
}
