//! Stage-level observability: timed spans, a unified metrics registry,
//! and Chrome-trace / metrics-JSON exporters — zero dependencies, wired
//! through the [`crate::pipeline`] facade only.
//!
//! Voxel-CIM's claims are *per-stage* claims (O(N) map-search access,
//! fewer GEMM dispatches from W2B packing, delta-cache reuse), so the
//! pipeline records where frame time actually goes: every stage of the
//! voxelize → map-search → gather → GEMM → scatter → requant path (plus
//! the serving stages around it) can open a [`SpanGuard`] carrying
//! frame / window / sequence / shard / layer attribution.
//!
//! Design constraints, in order:
//!
//! 1. **Off by default and provably cheap.** [`Recorder`] is a two-arm
//!    enum; the `Disabled` arm makes [`Recorder::span`] return an inert
//!    guard — no allocation, no clock read, no lock. Bit-identity tests
//!    run against both arms (`rust/tests/observability.rs`).
//! 2. **Worker threads log without contention.** Spans land in striped
//!    per-thread buffers (a thread-local slot index picks the stripe),
//!    drained into one ordered vector at window commit
//!    ([`Recorder::drain`]) — the `WorkerPool` fork paths in
//!    `coordinator::executor` / `spconv::layer` never share a hot lock.
//! 3. **One counter surface.** [`MetricsRegistry`] subsumes the ad-hoc
//!    report counters (blocks searched/reused, waves skipped, rows
//!    saved, admission drops/defers/rejects, engine dispatches): the
//!    public `StreamReport` fields stay, but with `metrics` enabled the
//!    serve loop routes them through the registry and reads them back.
//!
//! Exporter formats: [`Recorder::write_chrome_trace`] emits the Chrome
//! trace-event JSON array (`ph: "X"` complete events, microsecond
//! timestamps) that loads directly in Perfetto / `chrome://tracing`;
//! [`Recorder::write_metrics_json`] emits a flat snapshot of counters,
//! gauges, and histogram summaries. Both share the escaping-correct
//! writer in [`crate::util::json`] with the stream bench.

pub mod cost;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Context;

pub use cost::{CostModel, CostSummary, FrameCost, StageCost};

use crate::dataset::{FramePoll, FrameSource, SourcedFrame};
use crate::util::config::Config;
use crate::util::json::Json;
use crate::util::stats::LatencySummary;

/// The instrumented pipeline stages, in dataflow order.
///
/// `voxelize` covers frame acquisition (source production + prefetch
/// wait, timed at the consumer); `admission` and `window_pack` are the
/// serving stages around the engine; everything else is the engine
/// layer itself. `dense_head` covers the BEV suffix (ToBev / Conv2d /
/// Deconv2d layers).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Stage {
    Voxelize,
    MapSearch,
    DeltaPlan,
    Gather,
    GemmWave,
    Scatter,
    Requant,
    Merge,
    DenseHead,
    Admission,
    WindowPack,
}

impl Stage {
    /// Number of stages (array-index domain of [`Stage::index`]).
    pub const COUNT: usize = 11;

    /// Every stage, in dataflow order (`index()` order).
    pub const ALL: [Stage; Stage::COUNT] = [
        Stage::Voxelize,
        Stage::MapSearch,
        Stage::DeltaPlan,
        Stage::Gather,
        Stage::GemmWave,
        Stage::Scatter,
        Stage::Requant,
        Stage::Merge,
        Stage::DenseHead,
        Stage::Admission,
        Stage::WindowPack,
    ];

    /// Stable snake_case name (trace-event `name`, metrics key suffix).
    pub fn key(self) -> &'static str {
        match self {
            Stage::Voxelize => "voxelize",
            Stage::MapSearch => "map_search",
            Stage::DeltaPlan => "delta_plan",
            Stage::Gather => "gather",
            Stage::GemmWave => "gemm_wave",
            Stage::Scatter => "scatter",
            Stage::Requant => "requant",
            Stage::Merge => "merge",
            Stage::DenseHead => "dense_head",
            Stage::Admission => "admission",
            Stage::WindowPack => "window_pack",
        }
    }

    /// Dense index into per-stage arrays (`Stage::ALL[s.index()] == s`).
    pub fn index(self) -> usize {
        match self {
            Stage::Voxelize => 0,
            Stage::MapSearch => 1,
            Stage::DeltaPlan => 2,
            Stage::Gather => 3,
            Stage::GemmWave => 4,
            Stage::Scatter => 5,
            Stage::Requant => 6,
            Stage::Merge => 7,
            Stage::DenseHead => 8,
            Stage::Admission => 9,
            Stage::WindowPack => 10,
        }
    }
}

/// The one sanctioned wall-clock entry point outside `obs` itself.
///
/// Engine code (coordinator, dataset, serving) that needs an interval —
/// pacing deadlines, span timing, latency estimates — takes its
/// `Instant` from here instead of calling `Instant::now()` directly, so
/// every clock read in the tree funnels through the observability
/// layer. The `determinism` and `observer-purity` lint rules
/// (`tools/vcim-lint`) enforce exactly this: a raw `Instant::now()`
/// outside `obs/` and the measurement harnesses is a finding.
#[inline]
pub fn stopwatch() -> Instant {
    Instant::now()
}

/// One recorded span: a stage interval with whatever attribution the
/// recording site knew. Times are seconds relative to the recorder's
/// construction instant.
#[derive(Clone, Debug)]
pub struct Span {
    pub stage: Stage,
    /// Start offset from the recorder epoch, seconds.
    pub start: f64,
    /// Duration, seconds.
    pub dur: f64,
    /// Recording thread's slot id (stable per thread, process-wide).
    pub tid: u32,
    pub frame: Option<u64>,
    pub sequence: Option<u32>,
    pub window: Option<u64>,
    pub shard: Option<u32>,
    pub layer: Option<u32>,
}

/// `[observability]` config section (strict parse, every key optional):
///
/// ```toml
/// [observability]
/// trace = true            # record stage spans
/// trace_out = "t.json"    # Chrome trace-event output path (implies trace)
/// metrics = true          # route report counters through the registry
/// metrics_out = "m.json"  # metrics-snapshot output path (implies metrics)
/// cost = true             # modeled bytes/energy accounting (implies metrics)
/// sample_every = 1        # record every Nth span per stage (>= 1)
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct ObsConfig {
    /// Record stage spans (the tracing half of the subsystem).
    pub trace: bool,
    /// Chrome trace-event output path; empty = no file. Non-empty
    /// implies `trace`.
    pub trace_out: String,
    /// Enable the metrics registry (counters / gauges / histograms).
    pub metrics: bool,
    /// Metrics-snapshot output path; empty = no file. Non-empty implies
    /// `metrics`.
    pub metrics_out: String,
    /// Enable cost accounting: `cost.*` counters, per-wave occupancy
    /// histograms, and Chrome-trace counter tracks from the modeled
    /// data-movement/energy ledger ([`cost::CostModel`]). Implies
    /// `metrics` (the ledger publishes through the registry).
    pub cost: bool,
    /// Record every Nth span per stage (1 = all). Lossy by design: a
    /// sampled trace keeps the shape of a long stream affordable.
    pub sample_every: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        Self {
            trace: false,
            trace_out: String::new(),
            metrics: false,
            metrics_out: String::new(),
            cost: false,
            sample_every: 1,
        }
    }
}

impl ObsConfig {
    /// Parse the `[observability]` section with the same strictness
    /// contract as the rest of the pipeline config: missing keys
    /// default, present-but-mistyped values error.
    pub fn from_config(cfg: &Config) -> crate::Result<Self> {
        let d = Self::default();
        let trace = cfg.opt_bool("observability.trace")?.unwrap_or(d.trace);
        let trace_out = cfg
            .opt_str("observability.trace_out")?
            .map_or(d.trace_out.clone(), str::to_string);
        let metrics = cfg.opt_bool("observability.metrics")?.unwrap_or(d.metrics);
        let metrics_out = cfg
            .opt_str("observability.metrics_out")?
            .map_or(d.metrics_out.clone(), str::to_string);
        let cost = cfg.opt_bool("observability.cost")?.unwrap_or(d.cost);
        let sample_every = cfg.usize_or("observability.sample_every", d.sample_every)?;
        anyhow::ensure!(sample_every >= 1, "observability.sample_every must be >= 1");
        Ok(Self {
            // An output path is an unambiguous request to trace.
            trace: trace || !trace_out.is_empty(),
            trace_out,
            // Same rule for the metrics half: a snapshot path (or cost
            // accounting, which publishes through the registry) switches
            // the registry on.
            metrics: metrics || !metrics_out.is_empty() || cost,
            metrics_out,
            cost,
            sample_every,
        })
    }

    /// Whether any half of the subsystem is on.
    pub fn enabled(&self) -> bool {
        self.trace || self.metrics || self.cost
    }
}

/// How many span stripes the recorder shards its buffers over. Threads
/// map to stripes by a process-wide slot counter, so any realistic
/// worker-pool size gets a private stripe.
const STRIPES: usize = 64;

static NEXT_THREAD_SLOT: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static THREAD_SLOT: usize = NEXT_THREAD_SLOT.fetch_add(1, Ordering::Relaxed);
}

fn thread_slot() -> usize {
    THREAD_SLOT.with(|s| *s)
}

/// The span/metrics recorder handed through the facade. Cheap to clone
/// (`Disabled` is a unit arm; `Enabled` clones an `Arc`), so every
/// worker closure can own one.
#[derive(Clone, Debug, Default)]
pub enum Recorder {
    /// The no-op arm: `span()` returns an inert guard — no clock read,
    /// no allocation, no lock — and every other method is a no-op.
    #[default]
    Disabled,
    Enabled(Arc<RecorderInner>),
}

/// Shared state behind an enabled [`Recorder`].
#[derive(Debug)]
pub struct RecorderInner {
    epoch: Instant,
    trace: bool,
    sample_every: u64,
    /// Per-stage creation counters driving `sample_every`.
    sampled: [AtomicU64; Stage::COUNT],
    /// Ambient window id (stored +1; 0 = outside any window). The serve
    /// loop sets it before packing each window so spans recorded deep in
    /// the engine inherit window attribution without plumbing.
    window: AtomicU64,
    /// Striped span buffers: a thread writes only its own stripe.
    stripes: Vec<Mutex<Vec<Span>>>,
    /// Committed spans, appended stripe-by-stripe at each `drain()`.
    drained: Mutex<Vec<Span>>,
    metrics: Option<MetricsRegistry>,
    /// Cost accounting on: `cost.*` counters flow into the registry and
    /// per-frame [`CostPoint`]s are kept for the trace counter tracks.
    cost: bool,
    /// Per-frame cost points (serve loop, once per completed frame —
    /// cold path, so one mutex is fine).
    cost_points: Mutex<Vec<CostPoint>>,
}

/// One per-frame cost observation, timestamped for the Chrome-trace
/// counter tracks (`ph: "C"` events).
#[derive(Clone, Copy, Debug)]
pub struct CostPoint {
    /// Seconds since the recorder epoch.
    pub t: f64,
    pub frame: u64,
    /// Total modeled bytes moved for the frame.
    pub bytes: u64,
    /// Total modeled joules spent for the frame.
    pub joules: f64,
}

impl Recorder {
    /// Build from the `[observability]` section; `Disabled` unless a
    /// half of the subsystem is switched on.
    pub fn from_config(cfg: &ObsConfig) -> Self {
        if !cfg.enabled() {
            return Recorder::Disabled;
        }
        Recorder::Enabled(Arc::new(RecorderInner {
            epoch: Instant::now(),
            trace: cfg.trace,
            sample_every: cfg.sample_every.max(1) as u64,
            sampled: std::array::from_fn(|_| AtomicU64::new(0)),
            window: AtomicU64::new(0),
            stripes: (0..STRIPES).map(|_| Mutex::new(Vec::new())).collect(),
            drained: Mutex::new(Vec::new()),
            metrics: (cfg.metrics || cfg.cost).then(MetricsRegistry::default),
            cost: cfg.cost,
            cost_points: Mutex::new(Vec::new()),
        }))
    }

    /// Whether the recorder records anything at all.
    pub fn enabled(&self) -> bool {
        matches!(self, Recorder::Enabled(_))
    }

    /// Whether spans are being recorded (the `trace` half).
    pub fn tracing(&self) -> bool {
        matches!(self, Recorder::Enabled(i) if i.trace)
    }

    /// The metrics registry, when the `metrics` half is on.
    pub fn metrics(&self) -> Option<&MetricsRegistry> {
        match self {
            Recorder::Disabled => None,
            Recorder::Enabled(i) => i.metrics.as_ref(),
        }
    }

    /// The metrics registry, but only when *cost accounting* is on —
    /// the gate every `cost.*` recording site checks, so a plain
    /// metrics/trace run records no cost and a disabled recorder costs
    /// one enum match.
    pub fn cost(&self) -> Option<&MetricsRegistry> {
        match self {
            Recorder::Enabled(i) if i.cost => i.metrics.as_ref(),
            _ => None,
        }
    }

    /// Whether cost accounting is on.
    pub fn costing(&self) -> bool {
        matches!(self, Recorder::Enabled(i) if i.cost)
    }

    /// Record one per-frame cost point for the Chrome-trace counter
    /// tracks. No-op unless both `cost` and `trace` are on (the point
    /// only feeds the trace exporter; counters go through
    /// [`Self::cost`]).
    pub fn record_cost_point(&self, frame: u64, bytes: u64, joules: f64) {
        if let Recorder::Enabled(i) = self {
            if i.cost && i.trace {
                let t = i.epoch.elapsed().as_secs_f64();
                i.cost_points
                    .lock()
                    .expect("cost point lock")
                    .push(CostPoint { t, frame, bytes, joules });
            }
        }
    }

    /// All recorded per-frame cost points (empty unless cost + trace).
    pub fn cost_points(&self) -> Vec<CostPoint> {
        match self {
            Recorder::Disabled => Vec::new(),
            Recorder::Enabled(i) => i.cost_points.lock().expect("cost point lock").clone(),
        }
    }

    /// Open a span: the guard records `stage` from now until drop.
    /// Attribution is attached on the guard (builder or `set_*`). On
    /// the `Disabled` arm (or a sampled-out span) the guard is inert.
    pub fn span(&self, stage: Stage) -> SpanGuard<'_> {
        let inner = match self {
            Recorder::Disabled => return SpanGuard { state: None },
            Recorder::Enabled(i) => i,
        };
        if !inner.trace {
            return SpanGuard { state: None };
        }
        if inner.sample_every > 1 {
            let n = inner.sampled[stage.index()].fetch_add(1, Ordering::Relaxed);
            if n % inner.sample_every != 0 {
                return SpanGuard { state: None };
            }
        }
        SpanGuard {
            state: Some((
                inner.as_ref(),
                PendingSpan {
                    stage,
                    t0: Instant::now(),
                    frame: None,
                    sequence: None,
                    shard: None,
                    layer: None,
                },
            )),
        }
    }

    /// Set the ambient window id inherited by subsequently recorded
    /// spans (serve loop: once per packed window).
    pub fn set_window(&self, window: u64) {
        if let Recorder::Enabled(i) = self {
            i.window.store(window + 1, Ordering::Relaxed);
        }
    }

    /// Clear the ambient window id (outside the serve loop).
    pub fn clear_window(&self) {
        if let Recorder::Enabled(i) = self {
            i.window.store(0, Ordering::Relaxed);
        }
    }

    /// Commit every stripe's buffered spans into the drained log (and,
    /// with metrics on, feed the per-stage duration histograms). The
    /// serve loop calls this at each window commit; worker threads are
    /// quiescent between windows, so nothing races the sweep.
    pub fn drain(&self) {
        let inner = match self {
            Recorder::Disabled => return,
            Recorder::Enabled(i) => i,
        };
        let mut drained = inner.drained.lock().expect("span log lock");
        for stripe in &inner.stripes {
            let mut buf = stripe.lock().expect("span stripe lock");
            if let Some(m) = inner.metrics.as_ref() {
                for s in buf.iter() {
                    m.observe(&format!("stage.{}", s.stage.key()), s.dur);
                }
            }
            drained.append(&mut buf);
        }
    }

    /// All committed spans (drains first). `Disabled` → empty.
    pub fn spans(&self) -> Vec<Span> {
        self.drain();
        match self {
            Recorder::Disabled => Vec::new(),
            Recorder::Enabled(i) => i.drained.lock().expect("span log lock").clone(),
        }
    }

    /// Number of committed spans (drains first).
    pub fn span_count(&self) -> usize {
        self.drain();
        match self {
            Recorder::Disabled => 0,
            Recorder::Enabled(i) => i.drained.lock().expect("span log lock").len(),
        }
    }

    /// Per-stage span durations, indexed by [`Stage::index`] (always
    /// `Stage::COUNT` buckets; all empty when disabled or span-free).
    pub fn stage_seconds(&self) -> Vec<Vec<f64>> {
        let mut out = vec![Vec::new(); Stage::COUNT];
        for s in self.spans() {
            out[s.stage.index()].push(s.dur);
        }
        out
    }

    /// Write every committed span as a Chrome trace-event JSON array
    /// (complete `"ph": "X"` events, microsecond timestamps), plus —
    /// with cost accounting on — per-frame `"ph": "C"` counter events
    /// that Perfetto renders as bytes/energy tracks. The file loads
    /// directly in Perfetto / `chrome://tracing`.
    pub fn write_chrome_trace(&self, path: &Path) -> crate::Result<()> {
        let spans = self.spans();
        let points = self.cost_points();
        let mut events = Vec::with_capacity(spans.len() + 2 * points.len());
        for s in &spans {
            let mut args = Vec::new();
            if let Some(f) = s.frame {
                args.push(("frame".to_string(), Json::UInt(f)));
            }
            if let Some(q) = s.sequence {
                args.push(("sequence".to_string(), Json::UInt(q as u64)));
            }
            if let Some(w) = s.window {
                args.push(("window".to_string(), Json::UInt(w)));
            }
            if let Some(h) = s.shard {
                args.push(("shard".to_string(), Json::UInt(h as u64)));
            }
            if let Some(l) = s.layer {
                args.push(("layer".to_string(), Json::UInt(l as u64)));
            }
            let mut ev = vec![
                ("name".to_string(), Json::str(s.stage.key())),
                ("cat".to_string(), Json::str("stage")),
                ("ph".to_string(), Json::str("X")),
                ("ts".to_string(), Json::Num(s.start * 1e6)),
                ("dur".to_string(), Json::Num(s.dur * 1e6)),
                ("pid".to_string(), Json::UInt(0)),
                ("tid".to_string(), Json::UInt(s.tid as u64)),
            ];
            if !args.is_empty() {
                ev.push(("args".to_string(), Json::Obj(args)));
            }
            events.push(Json::Obj(ev));
        }
        for p in &points {
            let counter = |name: &str, value: Json| {
                Json::obj(vec![
                    ("name", Json::str(name)),
                    ("cat", Json::str("cost")),
                    ("ph", Json::str("C")),
                    ("ts", Json::Num(p.t * 1e6)),
                    ("pid", Json::UInt(0)),
                    ("args", Json::obj(vec![("value", value)])),
                ])
            };
            events.push(counter("cost.bytes", Json::UInt(p.bytes)));
            events.push(counter("cost.energy_uj", Json::Num(p.joules * 1e6)));
        }
        std::fs::write(path, Json::Arr(events).render())
            .with_context(|| format!("writing Chrome trace to {}", path.display()))
    }

    /// Write a flat JSON snapshot: registry counters / gauges /
    /// histogram summaries plus per-stage span summaries.
    pub fn write_metrics_json(&self, path: &Path) -> crate::Result<()> {
        // Commit buffered spans first so the stage-duration histograms
        // below see everything recorded so far.
        self.drain();
        let mut counters = Vec::new();
        let mut gauges = Vec::new();
        let mut hists = Vec::new();
        if let Some(m) = self.metrics() {
            for (k, v) in m.counters() {
                counters.push((k, Json::UInt(v)));
            }
            for (k, v) in m.gauges() {
                gauges.push((k, Json::Num(v)));
            }
            for (k, s) in m.histograms() {
                hists.push((k, summary_json(&s)));
            }
        }
        let mut stages = Vec::new();
        for (i, durs) in self.stage_seconds().iter().enumerate() {
            if let Some(s) = LatencySummary::of(durs) {
                stages.push((Stage::ALL[i].key().to_string(), summary_json(&s)));
            }
        }
        let doc = Json::obj(vec![
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("histograms", Json::Obj(hists)),
            ("stages", Json::Obj(stages)),
        ]);
        std::fs::write(path, doc.render())
            .with_context(|| format!("writing metrics snapshot to {}", path.display()))
    }
}

fn summary_json(s: &LatencySummary) -> Json {
    Json::obj(vec![
        ("n", Json::UInt(s.n as u64)),
        ("mean_ms", Json::Num(s.mean * 1e3)),
        ("p50_ms", Json::Num(s.p50 * 1e3)),
        ("p95_ms", Json::Num(s.p95 * 1e3)),
        ("max_ms", Json::Num(s.max * 1e3)),
    ])
}

#[derive(Debug)]
struct PendingSpan {
    stage: Stage,
    t0: Instant,
    frame: Option<u64>,
    sequence: Option<u32>,
    shard: Option<u32>,
    layer: Option<u32>,
}

/// RAII span: records `[creation, drop)` of its stage into the
/// recording thread's stripe. Inert (a `None` state) when the recorder
/// is disabled or the span was sampled out — every method is then free.
#[must_use = "a span guard records until dropped; binding it to _ drops immediately"]
#[derive(Debug)]
pub struct SpanGuard<'a> {
    state: Option<(&'a RecorderInner, PendingSpan)>,
}

impl SpanGuard<'_> {
    /// Attach a frame id (builder form).
    pub fn frame(mut self, id: u64) -> Self {
        self.set_frame(id);
        self
    }

    /// Attach a sequence id (builder form).
    pub fn sequence(mut self, seq: u32) -> Self {
        self.set_sequence(seq);
        self
    }

    /// Attach a shard index (builder form).
    pub fn shard(mut self, shard: u32) -> Self {
        self.set_shard(shard);
        self
    }

    /// Attach a layer index (builder form).
    pub fn layer(mut self, layer: u32) -> Self {
        self.set_layer(layer);
        self
    }

    /// Attach a frame id after creation (e.g. once the frame arrived).
    pub fn set_frame(&mut self, id: u64) {
        if let Some((_, p)) = self.state.as_mut() {
            p.frame = Some(id);
        }
    }

    /// Attach a sequence id after creation.
    pub fn set_sequence(&mut self, seq: u32) {
        if let Some((_, p)) = self.state.as_mut() {
            p.sequence = Some(seq);
        }
    }

    /// Attach a shard index after creation.
    pub fn set_shard(&mut self, shard: u32) {
        if let Some((_, p)) = self.state.as_mut() {
            p.shard = Some(shard);
        }
    }

    /// Attach a layer index after creation.
    pub fn set_layer(&mut self, layer: u32) {
        if let Some((_, p)) = self.state.as_mut() {
            p.layer = Some(layer);
        }
    }

    /// Drop without recording (e.g. a poll that returned `Pending`).
    pub fn cancel(mut self) {
        self.state = None;
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        let (inner, p) = match self.state.take() {
            None => return,
            Some(s) => s,
        };
        let dur = p.t0.elapsed().as_secs_f64();
        let start = p.t0.saturating_duration_since(inner.epoch).as_secs_f64();
        let slot = thread_slot();
        let span = Span {
            stage: p.stage,
            start,
            dur,
            tid: slot as u32,
            frame: p.frame,
            sequence: p.sequence,
            window: inner.window.load(Ordering::Relaxed).checked_sub(1),
            shard: p.shard,
            layer: p.layer,
        };
        inner.stripes[slot % STRIPES]
            .lock()
            .expect("span stripe lock")
            .push(span);
    }
}

/// Named counters / gauges / duration histograms behind one lock. The
/// registry is cold-path only (the serve loop publishes accumulated
/// totals once per stream, not per frame), so a single mutex is fine.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    inner: Mutex<RegistryInner>,
}

#[derive(Debug, Default)]
struct RegistryInner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Vec<f64>>,
}

impl MetricsRegistry {
    /// Add to a named monotonic counter (created at 0 on first use).
    pub fn add(&self, name: &str, v: u64) {
        let mut i = self.inner.lock().expect("metrics lock");
        *i.counters.entry(name.to_string()).or_insert(0) += v;
    }

    /// Read a counter (0 when never written).
    pub fn counter(&self, name: &str) -> u64 {
        let i = self.inner.lock().expect("metrics lock");
        i.counters.get(name).copied().unwrap_or(0)
    }

    /// Set a named gauge to its latest value.
    pub fn set_gauge(&self, name: &str, v: f64) {
        let mut i = self.inner.lock().expect("metrics lock");
        i.gauges.insert(name.to_string(), v);
    }

    /// Read a gauge.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        let i = self.inner.lock().expect("metrics lock");
        i.gauges.get(name).copied()
    }

    /// Record one observation into a named histogram.
    pub fn observe(&self, name: &str, v: f64) {
        let mut i = self.inner.lock().expect("metrics lock");
        i.histograms.entry(name.to_string()).or_default().push(v);
    }

    /// Summarize a histogram (`None` when absent or empty).
    pub fn histogram(&self, name: &str) -> Option<LatencySummary> {
        let i = self.inner.lock().expect("metrics lock");
        i.histograms.get(name).and_then(|xs| LatencySummary::of(xs))
    }

    /// All counters, name-sorted.
    pub fn counters(&self) -> Vec<(String, u64)> {
        let i = self.inner.lock().expect("metrics lock");
        i.counters.iter().map(|(k, v)| (k.clone(), *v)).collect()
    }

    /// All gauges, name-sorted.
    pub fn gauges(&self) -> Vec<(String, f64)> {
        let i = self.inner.lock().expect("metrics lock");
        i.gauges.iter().map(|(k, v)| (k.clone(), *v)).collect()
    }

    /// All non-empty histograms as summaries, name-sorted.
    pub fn histograms(&self) -> Vec<(String, LatencySummary)> {
        let i = self.inner.lock().expect("metrics lock");
        i.histograms
            .iter()
            .filter_map(|(k, xs)| LatencySummary::of(xs).map(|s| (k.clone(), s)))
            .collect()
    }
}

/// A [`FrameSource`] adapter that times frame acquisition as `voxelize`
/// spans: each successful `next_frame` / `poll_frame` records how long
/// the serve loop waited for the frame (source production + prefetch
/// handoff), attributed to the frame it yielded. Pending polls and
/// end-of-stream record nothing. Frame *content* passes through
/// untouched, so streams are bit-identical under observation.
pub struct ObservedSource {
    inner: Box<dyn FrameSource>,
    obs: Recorder,
}

impl ObservedSource {
    pub fn new(inner: Box<dyn FrameSource>, obs: Recorder) -> Self {
        Self { inner, obs }
    }
}

impl FrameSource for ObservedSource {
    fn next_frame(&mut self) -> Option<SourcedFrame> {
        let mut g = self.obs.span(Stage::Voxelize);
        match self.inner.next_frame() {
            Some(f) => {
                g.set_frame(f.meta.id);
                g.set_sequence(f.meta.sequence);
                Some(f)
            }
            None => {
                g.cancel();
                None
            }
        }
    }

    fn poll_frame(&mut self) -> FramePoll {
        let mut g = self.obs.span(Stage::Voxelize);
        match self.inner.poll_frame() {
            FramePoll::Ready(Some(f)) => {
                g.set_frame(f.meta.id);
                g.set_sequence(f.meta.sequence);
                FramePoll::Ready(Some(f))
            }
            other => {
                g.cancel();
                other
            }
        }
    }

    fn label(&self) -> String {
        self.inner.label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::config::Config;

    fn tracing_recorder() -> Recorder {
        Recorder::from_config(&ObsConfig {
            trace: true,
            ..ObsConfig::default()
        })
    }

    #[test]
    fn disabled_recorder_is_inert() {
        let r = Recorder::Disabled;
        assert!(!r.enabled());
        {
            let g = r.span(Stage::MapSearch).frame(7).layer(2);
            drop(g);
        }
        r.set_window(3);
        r.drain();
        assert_eq!(r.span_count(), 0);
        assert!(r.metrics().is_none());
        assert!(r.cost().is_none());
        assert!(!r.costing());
        r.record_cost_point(0, 100, 1.0);
        assert!(r.cost_points().is_empty());
        assert!(r.stage_seconds().iter().all(Vec::is_empty));
    }

    #[test]
    fn spans_carry_attribution_and_ambient_window() {
        let r = tracing_recorder();
        r.set_window(4);
        {
            let _g = r.span(Stage::GemmWave).frame(9).sequence(1).shard(2).layer(3);
        }
        r.clear_window();
        {
            let _g = r.span(Stage::Admission);
        }
        let spans = r.spans();
        assert_eq!(spans.len(), 2);
        let g = &spans[0];
        assert_eq!(g.stage, Stage::GemmWave);
        assert_eq!(g.frame, Some(9));
        assert_eq!(g.sequence, Some(1));
        assert_eq!(g.shard, Some(2));
        assert_eq!(g.layer, Some(3));
        assert_eq!(g.window, Some(4));
        assert!(g.dur >= 0.0 && g.start >= 0.0);
        assert_eq!(spans[1].window, None);
    }

    #[test]
    fn cancel_records_nothing() {
        let r = tracing_recorder();
        r.span(Stage::Voxelize).cancel();
        assert_eq!(r.span_count(), 0);
    }

    #[test]
    fn sampling_keeps_every_nth_span() {
        let r = Recorder::from_config(&ObsConfig {
            trace: true,
            sample_every: 4,
            ..ObsConfig::default()
        });
        for _ in 0..16 {
            let _g = r.span(Stage::Scatter);
        }
        // Per-stage counters: an unrelated stage is not starved.
        let _g = r.span(Stage::Gather);
        drop(_g);
        let spans = r.spans();
        let scat = spans.iter().filter(|s| s.stage == Stage::Scatter).count();
        let gath = spans.iter().filter(|s| s.stage == Stage::Gather).count();
        assert_eq!(scat, 4, "16 spans at sample_every = 4");
        assert_eq!(gath, 1, "first span of a stage always records");
    }

    #[test]
    fn registry_counts_gauges_and_histograms() {
        let m = MetricsRegistry::default();
        m.add("delta.blocks_reused", 3);
        m.add("delta.blocks_reused", 4);
        assert_eq!(m.counter("delta.blocks_reused"), 7);
        assert_eq!(m.counter("absent"), 0);
        m.set_gauge("engine.dispatches", 12.0);
        assert_eq!(m.gauge("engine.dispatches"), Some(12.0));
        for v in [1.0, 2.0, 3.0, 4.0] {
            m.observe("stage.gather", v);
        }
        let h = m.histogram("stage.gather").expect("4 samples");
        assert_eq!(h.n, 4);
        assert!(m.histogram("absent").is_none());
        assert_eq!(m.counters().len(), 1);
        assert_eq!(m.histograms().len(), 1);
    }

    #[test]
    fn obs_config_parses_strictly() {
        let good = Config::parse(
            "[observability]\ntrace = true\ntrace_out = \"t.json\"\n\
             metrics = true\nmetrics_out = \"m.json\"\ncost = true\nsample_every = 8\n",
        )
        .unwrap();
        let c = ObsConfig::from_config(&good).unwrap();
        assert!(c.trace && c.metrics && c.cost);
        assert_eq!(c.trace_out, "t.json");
        assert_eq!(c.metrics_out, "m.json");
        assert_eq!(c.sample_every, 8);

        // trace_out alone implies trace.
        let implied =
            Config::parse("[observability]\ntrace_out = \"t.json\"\n").unwrap();
        assert!(ObsConfig::from_config(&implied).unwrap().trace);

        // metrics_out alone implies metrics — same rule as trace_out.
        let implied =
            Config::parse("[observability]\nmetrics_out = \"m.json\"\n").unwrap();
        let c = ObsConfig::from_config(&implied).unwrap();
        assert!(c.metrics && !c.trace && !c.cost);
        assert_eq!(c.metrics_out, "m.json");

        // cost alone implies metrics (the ledger publishes through the
        // registry) but not tracing.
        let implied = Config::parse("[observability]\ncost = true\n").unwrap();
        let c = ObsConfig::from_config(&implied).unwrap();
        assert!(c.cost && c.metrics && !c.trace);

        // Missing section = defaults (off).
        let empty = Config::parse("").unwrap();
        let d = ObsConfig::from_config(&empty).unwrap();
        assert_eq!(d, ObsConfig::default());
        assert!(!d.enabled());

        for bad in [
            "[observability]\ntrace = 1\n",
            "[observability]\ntrace = \"yes\"\n",
            "[observability]\ntrace_out = 3\n",
            "[observability]\nmetrics = \"on\"\n",
            "[observability]\nmetrics_out = 7\n",
            "[observability]\ncost = \"yes\"\n",
            "[observability]\nsample_every = true\n",
            "[observability]\nsample_every = 0\n",
        ] {
            let cfg = Config::parse(bad).unwrap();
            assert!(ObsConfig::from_config(&cfg).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn cost_gate_requires_the_cost_flag() {
        // Metrics alone: registry on, cost gate closed, points dropped.
        let m_only = Recorder::from_config(&ObsConfig {
            metrics: true,
            ..ObsConfig::default()
        });
        assert!(m_only.metrics().is_some());
        assert!(m_only.cost().is_none() && !m_only.costing());
        m_only.record_cost_point(0, 64, 1e-6);
        assert!(m_only.cost_points().is_empty());

        // Cost on: gate open (and the registry exists even without
        // `metrics`, since cost implies it at Recorder construction).
        let c = Recorder::from_config(&ObsConfig {
            cost: true,
            ..ObsConfig::default()
        });
        assert!(c.costing() && c.cost().is_some());
        c.cost().unwrap().add("cost.dram_bytes", 96);
        assert_eq!(c.metrics().unwrap().counter("cost.dram_bytes"), 96);
        // Counter points need the trace half too (they only feed the
        // trace exporter).
        c.record_cost_point(1, 64, 1e-6);
        assert!(c.cost_points().is_empty());

        let ct = Recorder::from_config(&ObsConfig {
            cost: true,
            trace: true,
            ..ObsConfig::default()
        });
        ct.record_cost_point(1, 64, 1e-6);
        let pts = ct.cost_points();
        assert_eq!(pts.len(), 1);
        assert_eq!(pts[0].frame, 1);
        assert_eq!(pts[0].bytes, 64);
    }

    #[test]
    fn chrome_trace_includes_cost_counter_tracks() {
        let r = Recorder::from_config(&ObsConfig {
            trace: true,
            cost: true,
            ..ObsConfig::default()
        });
        {
            let _g = r.span(Stage::GemmWave).frame(0);
        }
        r.record_cost_point(0, 4096, 2.5e-6);
        let path = std::env::temp_dir().join(format!(
            "voxel-cim-obs-cost-trace-{}.json",
            std::process::id()
        ));
        r.write_chrome_trace(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(body.contains("\"ph\":\"X\""));
        assert!(body.contains("\"ph\":\"C\""));
        assert!(body.contains("\"name\":\"cost.bytes\""));
        assert!(body.contains("\"name\":\"cost.energy_uj\""));
        assert!(body.contains("\"value\":4096"));
    }

    #[test]
    fn chrome_trace_export_is_wellformed() {
        let r = tracing_recorder();
        r.set_window(0);
        {
            let _g = r.span(Stage::MapSearch).frame(1).layer(0);
        }
        {
            let _g = r.span(Stage::GemmWave).frame(1);
        }
        let path = std::env::temp_dir().join(format!(
            "voxel-cim-obs-test-{}.json",
            std::process::id()
        ));
        r.write_chrome_trace(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(body.starts_with('[') && body.trim_end().ends_with(']'));
        assert!(body.contains("\"name\":\"map_search\""));
        assert!(body.contains("\"name\":\"gemm_wave\""));
        assert!(body.contains("\"ph\":\"X\""));
        assert!(body.contains("\"window\":0"));
    }

    #[test]
    fn metrics_export_includes_stage_summaries() {
        let r = Recorder::from_config(&ObsConfig {
            trace: true,
            metrics: true,
            ..ObsConfig::default()
        });
        {
            let _g = r.span(Stage::Requant);
        }
        r.metrics().unwrap().add("stream.windows", 2);
        let path = std::env::temp_dir().join(format!(
            "voxel-cim-obs-metrics-{}.json",
            std::process::id()
        ));
        r.write_metrics_json(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(body.contains("\"counters\""));
        assert!(body.contains("\"stream.windows\":2"));
        assert!(body.contains("\"requant\""));
        // Drained spans also fed the duration histogram.
        assert!(body.contains("\"stage.requant\""));
    }

    #[test]
    fn stage_index_matches_all_order() {
        for (i, s) in Stage::ALL.iter().enumerate() {
            assert_eq!(s.index(), i);
        }
        let keys: std::collections::BTreeSet<_> =
            Stage::ALL.iter().map(|s| s.key()).collect();
        assert_eq!(keys.len(), Stage::COUNT, "stage keys must be distinct");
    }
}
