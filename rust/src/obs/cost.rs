//! Cost accounting: modeled data movement (bytes) and energy (joules)
//! for what the serving pipeline *actually executed*, derived from the
//! per-frame execution counts the scheduler already records.
//!
//! The paper's headline claims are cost claims — O(N) map-search data
//! access volume (Fig. 2d / Fig. 9), 10.8 TOPS/W (Table 2), balanced
//! waves under irregular sparsity — and until now those numbers lived
//! only in the offline `sim::Accelerator` world. [`CostModel`] closes
//! the loop: it reuses the *same calibrated constants*
//! ([`EnergyModel`], [`DramModel`], [`CimConfig`]) and applies them to
//! the live counts in [`FrameResult`] / [`LayerRecord`]:
//!
//! * **map search** — `AccessStats` voxel reads + writes become coord
//!   DRAM traffic at [`COORD_BYTES`] per coordinate (the Fig. 2d
//!   x-axis quantity), charged at `e_dram_byte`;
//! * **voxelize** — re-binned voxels stream their coordinate plus an
//!   int8 VFE feature row from DRAM (delta voxelization shrinks this
//!   on warm frames);
//! * **gather** — every gathered rule-pair row moves `c_in` int8
//!   activations through the on-chip buffers;
//! * **GEMM** — `pairs × c_in × c_out` MACs at the calibrated
//!   [`EnergyModel::energy_per_mac`] (dynamic energy; leakage is a
//!   whole-core runtime term and is deliberately excluded so per-frame
//!   costs sum exactly — see DESIGN.md §Cost accounting);
//! * **scatter** — each gathered row accumulates `c_out` int32
//!   partial sums into the psum buffer;
//! * **requant** — the epilogue reads `out × c_out` int32 psums and
//!   writes `out × c_out` int8 features.
//!
//! Everything here is a *pure function of counts already collected*:
//! computing a cost never touches an execution path, so the PR 8
//! pure-observer invariant holds trivially — disabled observability
//! records nothing, and enabling cost accounting cannot change a bit.

use crate::cim::energy::EnergyModel;
use crate::cim::tile::CimConfig;
use crate::coordinator::scheduler::{FrameResult, LayerRecord};
use crate::mapsearch::AccessStats;
use crate::sim::dram::{DramModel, COORD_BYTES};

/// One accounting bucket: bytes moved and joules spent.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StageCost {
    pub bytes: u64,
    pub joules: f64,
}

impl StageCost {
    pub fn add(&mut self, other: &StageCost) {
        self.bytes += other.bytes;
        self.joules += other.joules;
    }
}

/// Modeled cost of one frame, bucketed by pipeline stage. Buckets are
/// disjoint and exhaustive, so per-stage entries sum exactly to the
/// totals (the conservation property gated in `tests/observability.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FrameCost {
    /// Coordinate DRAM traffic of map search (incl. delta re-search).
    pub map_search: StageCost,
    /// Point→voxel DRAM traffic (re-binned voxels × (coord + features)).
    pub voxelize: StageCost,
    /// Activation rows through the on-chip buffers.
    pub gather: StageCost,
    /// MAC energy in the CIM array (no data movement: weights resident).
    pub gemm: StageCost,
    /// Int32 partial-sum accumulation into the psum buffer.
    pub scatter: StageCost,
    /// Epilogue: psum reads + int8 feature writes.
    pub requant: StageCost,
    /// Useful multiply-accumulates (2 ops each).
    pub macs: u64,
}

impl FrameCost {
    /// Stage buckets in dataflow order, with their stable keys.
    pub fn buckets(&self) -> [(&'static str, StageCost); 6] {
        [
            ("voxelize", self.voxelize),
            ("map_search", self.map_search),
            ("gather", self.gather),
            ("gemm_wave", self.gemm),
            ("scatter", self.scatter),
            ("requant", self.requant),
        ]
    }

    /// Off-chip traffic: the buckets charged at DRAM energy.
    pub fn dram_bytes(&self) -> u64 {
        self.map_search.bytes + self.voxelize.bytes
    }

    /// On-chip buffer traffic.
    pub fn buffer_bytes(&self) -> u64 {
        self.gather.bytes + self.scatter.bytes + self.requant.bytes
    }

    pub fn total_bytes(&self) -> u64 {
        self.buckets().iter().map(|(_, c)| c.bytes).sum()
    }

    pub fn total_joules(&self) -> f64 {
        self.buckets().iter().map(|(_, c)| c.joules).sum()
    }

    pub fn add(&mut self, other: &FrameCost) {
        self.map_search.add(&other.map_search);
        self.voxelize.add(&other.voxelize);
        self.gather.add(&other.gather);
        self.gemm.add(&other.gemm);
        self.scatter.add(&other.scatter);
        self.requant.add(&other.requant);
        self.macs += other.macs;
    }
}

/// Stream-level roll-up of per-frame costs — what
/// `StreamReport::cost_summary()` and the `--cost` CLI footer print.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CostSummary {
    pub frames: usize,
    /// Total modeled traffic (DRAM + buffers).
    pub bytes: u64,
    pub dram_bytes: u64,
    pub buffer_bytes: u64,
    /// Total modeled energy.
    pub joules: f64,
    pub macs: u64,
    /// Effective efficiency of what actually ran: `2·MACs / joules`,
    /// in TOPS/W. Bounded above by `EnergyModel::peak_tops_per_watt`
    /// (10.8); DRAM-heavy streams land well below it.
    pub tops_per_watt: f64,
    /// Mean per-frame map-search access volume normalized by the
    /// frame's input voxel count — the Fig. 2d / Fig. 9 y-axis.
    pub normalized_access: f64,
    /// Frames that spliced at least one cached block (delta-warm).
    pub warm_frames: usize,
    pub cold_frames: usize,
    /// Mean DRAM bytes per warm frame (0.0 when no warm frames): the
    /// delta-cache saving is `cold_dram_per_frame - warm_dram_per_frame`.
    pub warm_dram_per_frame: f64,
    pub cold_dram_per_frame: f64,
    /// Per-stage totals in dataflow order (stable keys).
    pub stages: Vec<(&'static str, StageCost)>,
}

/// Converts live execution counts into modeled bytes and joules with
/// the calibrated constants of the `cim` / `sim` layers. Stateless; a
/// ledger is produced per frame and summed, never mutated in place by
/// execution paths.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    pub cim: CimConfig,
    pub energy: EnergyModel,
    pub dram: DramModel,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            cim: CimConfig::default(),
            energy: EnergyModel::default(),
            dram: DramModel::default(),
        }
    }
}

impl CostModel {
    /// Coordinate DRAM traffic of one map-search access profile.
    pub fn search_cost(&self, access: &AccessStats) -> StageCost {
        let bytes = (access.voxel_reads + access.voxel_writes) * COORD_BYTES;
        StageCost {
            bytes,
            joules: self.energy.dram_energy(bytes),
        }
    }

    /// Point→voxel DRAM traffic: each re-binned voxel streams its
    /// coordinate plus `vfe_channels` int8 features.
    pub fn voxelize_cost(&self, voxels: u64, vfe_channels: u64) -> StageCost {
        let bytes = voxels * (COORD_BYTES + vfe_channels);
        StageCost {
            bytes,
            joules: self.energy.dram_energy(bytes),
        }
    }

    /// Cost of one executed layer from its record.
    pub fn layer_cost(&self, r: &LayerRecord) -> FrameCost {
        let mut c = FrameCost {
            map_search: self.search_cost(&r.access),
            ..FrameCost::default()
        };
        c.macs = r.pairs * r.c_in * r.c_out;
        c.gemm = StageCost {
            bytes: 0,
            joules: c.macs as f64 * self.energy.energy_per_mac(&self.cim),
        };
        let gather_bytes = r.gathered_rows * r.c_in;
        c.gather = StageCost {
            bytes: gather_bytes,
            joules: self.energy.buffer_energy(gather_bytes),
        };
        let scatter_bytes = r.gathered_rows * r.c_out * 4;
        c.scatter = StageCost {
            bytes: scatter_bytes,
            joules: self.energy.buffer_energy(scatter_bytes),
        };
        let requant_bytes = r.out_voxels * r.c_out * (4 + 1);
        c.requant = StageCost {
            bytes: requant_bytes,
            joules: self.energy.buffer_energy(requant_bytes),
        };
        c
    }

    /// Whole-frame cost: the sum over layer records plus the frame's
    /// voxelize traffic (`voxels_rebinned` × coord + layer-0 features).
    pub fn frame_cost(&self, fr: &FrameResult) -> FrameCost {
        let mut c = FrameCost::default();
        for r in &fr.records {
            c.add(&self.layer_cost(r));
        }
        let vfe = fr.records.first().map(|r| r.c_in).unwrap_or(0);
        c.voxelize = self.voxelize_cost(fr.voxels_rebinned, vfe);
        c
    }

    /// Roll a stream's frame results up into a [`CostSummary`]. Pure
    /// over the results — no recorder needed, so the summary is
    /// available even on unobserved streams.
    pub fn summarize<'a>(&self, frames: impl Iterator<Item = &'a FrameResult>) -> CostSummary {
        let mut total = FrameCost::default();
        let mut s = CostSummary::default();
        let mut norm_sum = 0.0;
        let mut warm_dram = 0u64;
        let mut cold_dram = 0u64;
        for fr in frames {
            let c = self.frame_cost(fr);
            total.add(&c);
            s.frames += 1;
            let mut access = AccessStats::default();
            for r in &fr.records {
                access.add(&r.access);
            }
            norm_sum += access.normalized(fr.in_voxels as usize);
            if fr.blocks_reused > 0 {
                s.warm_frames += 1;
                warm_dram += c.dram_bytes();
            } else {
                s.cold_frames += 1;
                cold_dram += c.dram_bytes();
            }
        }
        s.bytes = total.total_bytes();
        s.dram_bytes = total.dram_bytes();
        s.buffer_bytes = total.buffer_bytes();
        s.joules = total.total_joules();
        s.macs = total.macs;
        s.tops_per_watt = if s.joules > 0.0 {
            2.0 * s.macs as f64 / s.joules / 1e12
        } else {
            0.0
        };
        s.normalized_access = if s.frames > 0 {
            norm_sum / s.frames as f64
        } else {
            0.0
        };
        s.warm_dram_per_frame = if s.warm_frames > 0 {
            warm_dram as f64 / s.warm_frames as f64
        } else {
            0.0
        };
        s.cold_dram_per_frame = if s.cold_frames > 0 {
            cold_dram as f64 / s.cold_frames as f64
        } else {
            0.0
        };
        s.stages = total
            .buckets()
            .iter()
            .filter(|(_, c)| c.bytes > 0 || c.joules > 0.0)
            .copied()
            .collect();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(pairs: u64, out: u64, c_in: u64, c_out: u64, reads: u64) -> LayerRecord {
        LayerRecord {
            name: "test".into(),
            pairs,
            out_voxels: out,
            gemm_calls: 1,
            ms_seconds: 0.0,
            compute_seconds: 0.0,
            access: AccessStats {
                voxel_reads: reads,
                ..Default::default()
            },
            workload: Vec::new(),
            c_in,
            c_out,
            gathered_rows: pairs,
        }
    }

    #[test]
    fn layer_cost_counts_every_bucket() {
        let m = CostModel::default();
        let c = m.layer_cost(&record(100, 40, 8, 16, 120));
        assert_eq!(c.macs, 100 * 8 * 16);
        assert_eq!(c.map_search.bytes, 120 * COORD_BYTES);
        assert_eq!(c.gather.bytes, 100 * 8);
        assert_eq!(c.scatter.bytes, 100 * 16 * 4);
        assert_eq!(c.requant.bytes, 40 * 16 * 5);
        assert!(c.gemm.joules > 0.0 && c.gemm.bytes == 0);
        // Totals are exactly the sum of the buckets.
        assert_eq!(
            c.total_bytes(),
            c.dram_bytes() + c.buffer_bytes(),
            "dram + buffer must partition total bytes"
        );
        let sum: f64 = c.buckets().iter().map(|(_, b)| b.joules).sum();
        assert!((c.total_joules() - sum).abs() < 1e-18);
    }

    #[test]
    fn per_mac_energy_is_consistent_with_peak_efficiency() {
        // 2 ops per MAC at energy_per_mac joules each cannot beat the
        // dynamic-only efficiency bound, and must be within 2x of the
        // Table 2 headline (leakage + DRAM account for the gap).
        let m = CostModel::default();
        let per_mac = m.energy.energy_per_mac(&m.cim);
        let tops_per_watt = 2.0 / per_mac / 1e12;
        assert!(
            tops_per_watt > 10.8 && tops_per_watt < 2.0 * 10.8,
            "dynamic-only efficiency {tops_per_watt} implausible vs 10.8"
        );
    }

    #[test]
    fn dram_charged_per_coordinate() {
        let m = CostModel::default();
        let a = AccessStats {
            voxel_reads: 1000,
            voxel_writes: 500,
            ..Default::default()
        };
        let c = m.search_cost(&a);
        assert_eq!(c.bytes, 1500 * COORD_BYTES);
        assert!((c.joules - m.energy.dram_energy(c.bytes)).abs() < 1e-18);
    }

    #[test]
    fn summary_conserves_frame_costs() {
        let m = CostModel::default();
        let frame = |reads: u64, reused: u64| FrameResult {
            records: vec![record(200, 80, 4, 8, reads), record(150, 60, 8, 8, 0)],
            out_voxels: 60,
            head_shape: None,
            checksum: 0,
            shards: 1,
            total_seconds: 0.0,
            blocks_searched: 4,
            blocks_reused: reused,
            voxels_rebinned: 100,
            waves_skipped: 0,
            rows_gathered_saved: 0,
            in_voxels: 100,
        };
        let frames = [frame(400, 0), frame(100, 3)];
        let s = m.summarize(frames.iter());
        assert_eq!(s.frames, 2);
        assert_eq!(s.warm_frames, 1);
        assert_eq!(s.cold_frames, 1);
        let mut total = FrameCost::default();
        for f in &frames {
            total.add(&m.frame_cost(f));
        }
        assert_eq!(s.bytes, total.total_bytes());
        assert_eq!(s.dram_bytes, total.dram_bytes());
        assert_eq!(s.macs, total.macs);
        assert!((s.joules - total.total_joules()).abs() < 1e-15);
        let stage_bytes: u64 = s.stages.iter().map(|(_, c)| c.bytes).sum();
        assert_eq!(stage_bytes, s.bytes, "stage buckets must sum to total");
        // Warm frame searched fewer coords: its DRAM mean undercuts cold.
        assert!(s.warm_dram_per_frame < s.cold_dram_per_frame);
        assert!(s.normalized_access > 0.0);
        assert!(s.tops_per_watt > 0.0 && s.tops_per_watt < 10.8);
    }

    #[test]
    fn empty_summary_is_zero_not_nan() {
        let s = CostModel::default().summarize(std::iter::empty());
        assert_eq!(s.frames, 0);
        assert_eq!(s.bytes, 0);
        assert_eq!(s.tops_per_watt, 0.0);
        assert_eq!(s.normalized_access, 0.0);
        assert!(s.stages.is_empty());
    }
}
