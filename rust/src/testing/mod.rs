//! Test-support substrates.

pub mod prop;
