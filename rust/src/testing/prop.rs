//! Mini property-testing harness (the vendored registry has no proptest).
//!
//! Usage (`no_run`: doctest binaries don't inherit the xla rpath flags):
//! ```no_run
//! use voxel_cim::testing::prop::{check, Gen};
//! check("sum is commutative", 100, |g| {
//!     let a = g.usize(0, 1000);
//!     let b = g.usize(0, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Each case gets a deterministic per-case seed derived from the property
//! name, so failures are reproducible and reported with the case index +
//! seed. On failure the panic message of the failing case is re-raised
//! with that context attached.

use crate::util::rng::Pcg64;

/// Per-case value generator (a thin convenience wrapper over [`Pcg64`]).
pub struct Gen {
    rng: Pcg64,
    /// Log of generated values for failure reports.
    trace: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self {
            rng: Pcg64::new(seed),
            trace: Vec::new(),
        }
    }

    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        let v = self.rng.range(lo, hi);
        self.trace.push(format!("usize[{lo},{hi})={v}"));
        v
    }

    pub fn i32(&mut self, lo: i32, hi: i32) -> i32 {
        let v = lo + self.rng.next_below((hi - lo) as u64) as i32;
        self.trace.push(format!("i32[{lo},{hi})={v}"));
        v
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.uniform(lo, hi);
        self.trace.push(format!("f64[{lo},{hi})={v:.6}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.chance(0.5);
        self.trace.push(format!("bool={v}"));
        v
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.range(0, xs.len());
        self.trace.push(format!("choose#{i}"));
        &xs[i]
    }

    /// A vector of values from `f`, length in `[min_len, max_len)`.
    pub fn vec<T>(
        &mut self,
        min_len: usize,
        max_len: usize,
        mut f: impl FnMut(&mut Self) -> T,
    ) -> Vec<T> {
        let n = self.usize(min_len, max_len);
        (0..n).map(|_| f(self)).collect()
    }

    /// A randomized sparse voxel scene: random extent up to
    /// `max_side`×`max_side`×`max_depth`, up to `max_n` occupied voxels,
    /// drawn from either the i.i.d. or the clustered (LiDAR-like)
    /// distribution — the scene generator the engine-layer equivalence
    /// properties sweep over.
    pub fn sparse_scene(
        &mut self,
        max_side: usize,
        max_depth: usize,
        max_n: usize,
    ) -> crate::sparse::SparseTensor {
        use crate::geom::Extent3;
        use crate::pointcloud::voxelize::Voxelizer;
        let e = Extent3::new(
            self.usize(4, max_side.max(5)),
            self.usize(4, max_side.max(5)),
            self.usize(2, max_depth.max(3)),
        );
        let n = self.usize(1, max_n.max(2));
        let sparsity = (n as f64 / e.volume() as f64).min(0.5);
        let seed = self.usize(0, 1 << 30) as u64;
        let grid = if self.bool() {
            Voxelizer::synth_clustered(e, sparsity, self.usize(1, 6), 0.4, seed)
        } else {
            Voxelizer::synth_occupancy(e, sparsity, seed)
        };
        crate::sparse::SparseTensor::from_coords(e, grid.coords(), 1)
    }
}

fn name_seed(name: &str) -> u64 {
    // FNV-1a over the property name: stable per-property base seed.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Run `cases` deterministic cases of `property`; panic (with case seed and
/// generated-value trace) on the first failure.
pub fn check(name: &str, cases: u64, property: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let base = name_seed(name);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            property(&mut g);
            g.trace
        });
        if let Err(panic) = result {
            let msg = panic
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            // Re-run to capture the trace (deterministic).
            let mut g = Gen::new(seed);
            let trace = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                property(&mut g);
            }))
            .err()
            .map(|_| g.trace.join(", "))
            .unwrap_or_default();
            panic!(
                "property {name:?} failed at case {case}/{cases} (seed {seed:#x})\n  \
                 values: [{trace}]\n  cause: {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("add commutes", 50, |g| {
            let a = g.usize(0, 100);
            let b = g.usize(0, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_seed_and_trace() {
        let r = std::panic::catch_unwind(|| {
            check("always fails above 5", 100, |g| {
                let v = g.usize(0, 100);
                assert!(v <= 5, "v too big: {v}");
            });
        });
        let msg = match r {
            Err(p) => p
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("usize[0,100)"), "{msg}");
    }

    #[test]
    fn sparse_scene_is_canonical_and_bounded() {
        check("sparse_scene generator invariants", 20, |g| {
            let t = g.sparse_scene(32, 8, 300);
            assert!(t.extent.x >= 4 && t.extent.x < 32);
            assert!(t.extent.z >= 2 && t.extent.z < 8);
            assert!(t.check_canonical(), "non-canonical scene");
            for c in &t.coords {
                assert!((c.x as usize) < t.extent.x);
                assert!((c.y as usize) < t.extent.y);
                assert!((c.z as usize) < t.extent.z);
            }
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first: Vec<usize> = Vec::new();
        check("collect once", 5, |g| {
            let _ = g.usize(0, 1_000_000);
        });
        // Re-derive the same values manually.
        let base = name_seed("collect once");
        for case in 0..5 {
            let seed = base.wrapping_add((case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let mut g = Gen::new(seed);
            first.push(g.usize(0, 1_000_000));
        }
        let mut second: Vec<usize> = Vec::new();
        for case in 0..5 {
            let seed = base.wrapping_add((case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let mut g = Gen::new(seed);
            second.push(g.usize(0, 1_000_000));
        }
        assert_eq!(first, second);
    }
}
