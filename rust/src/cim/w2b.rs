//! W2B — Weight Workload Balanced mapping (§3.2B, Fig. 6).
//!
//! Different kernel offsets carry wildly different pair counts (the
//! central weight of a subm3 layer can exceed a peripheral weight by
//! >40x, Fig. 6a). With one sub-matrix per offset, the layer's makespan
//! is the central weight's workload while peripheral PEs idle. W2B gives
//! heavily-loaded offsets extra sub-matrix copies: minimize
//! `max_k workload_k / copies_k` subject to `sum_k copies_k <= budget`
//! (and the core's weight capacity).
//!
//! The allocator is exact: binary search on the achievable makespan, with
//! the classic feasibility check `sum_k ceil(w_k / T) <= budget`, then
//! leftover copies greedily to the current argmax (matching the paper's
//! "extra copies to central weights, peripheral replicated less or not at
//! all").

use crate::cim::tile::CimConfig;

/// Result of a W2B allocation.
#[derive(Clone, Debug)]
pub struct W2bAllocation {
    pub copies: Vec<u32>,
    /// Makespan in pairs before balancing (copies all 1).
    pub makespan_before: u64,
    /// Makespan in pairs after balancing.
    pub makespan_after: u64,
}

impl W2bAllocation {
    pub fn speedup(&self) -> f64 {
        if self.makespan_after == 0 {
            1.0
        } else {
            self.makespan_before as f64 / self.makespan_after as f64
        }
    }

    /// Normalized workload per offset (workload / copies), the quantity
    /// Fig. 6(b) shows flattening.
    pub fn normalized_workload(&self, workload: &[u64]) -> Vec<f64> {
        workload
            .iter()
            .zip(&self.copies)
            .map(|(&w, &c)| w as f64 / c as f64)
            .collect()
    }
}

/// Allocate sub-matrix copies for a layer.
///
/// * `workload` — pairs per offset (from `Rulebook::workload_per_offset`).
/// * `budget` — total sub-matrix instances available (>= number of
///   offsets with nonzero workload; the paper's detection setting is 2x
///   the kernel volume).
pub fn w2b_allocate(workload: &[u64], budget: u32) -> W2bAllocation {
    let k = workload.len() as u32;
    assert!(budget >= k, "budget {budget} below one copy per offset ({k})");
    let before = workload.iter().copied().max().unwrap_or(0);
    if before == 0 {
        return W2bAllocation {
            copies: vec![1; workload.len()],
            makespan_before: 0,
            makespan_after: 0,
        };
    }

    // Feasibility: can makespan T be met within budget?
    let copies_for = |t: u64| -> u64 {
        workload
            .iter()
            .map(|&w| if w == 0 { 1 } else { w.div_ceil(t) })
            .sum()
    };
    let (mut lo, mut hi) = (1u64, before);
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if copies_for(mid) <= budget as u64 {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    let t = lo;
    let mut copies: Vec<u32> = workload
        .iter()
        .map(|&w| if w == 0 { 1 } else { w.div_ceil(t) as u32 })
        .collect();
    // Spend leftover budget on the current bottleneck.
    let mut used: u32 = copies.iter().sum();
    while used < budget {
        let (arg, _) = workload
            .iter()
            .zip(&copies)
            .enumerate()
            .map(|(i, (&w, &c))| (i, w as f64 / c as f64))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        copies[arg] += 1;
        used += 1;
    }
    let after = workload
        .iter()
        .zip(&copies)
        .map(|(&w, &c)| w.div_ceil(c as u64))
        .max()
        .unwrap_or(0);
    W2bAllocation {
        copies,
        makespan_before: before,
        makespan_after: after,
    }
}

/// Copies per offset at a replication budget of `factor` x the kernel
/// volume — the paper's "2x" detection setting, generalized. This is the
/// vector the scheduler feeds to the W2B-aware wave packer
/// (`spconv::gather::gather_batches_multi_w2b`); `factor <= 1` yields
/// the identity allocation (one copy per offset, FCFS-equivalent).
pub fn copies_for_factor(workload: &[u64], factor: u32) -> Vec<u32> {
    let k = workload.len() as u32;
    w2b_allocate(workload, k.saturating_mul(factor.max(1))).copies
}

/// Budget from the core's capacity for a given sub-matrix size, capped at
/// `max_factor` copies of the kernel volume (the paper replicates
/// centrally-loaded weights a few times, not unboundedly).
pub fn capacity_budget(cfg: &CimConfig, c1: usize, c2: usize, k_volume: usize, max_factor: u32) -> u32 {
    let slots = cfg.submatrix_slots(c1, c2).min(u64::from(u32::MAX)) as u32;
    slots.min(k_volume as u32 * max_factor).max(k_volume as u32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::check;

    #[test]
    fn balances_skewed_workload() {
        // Central weight 40x the edges (the Fig. 6a situation).
        let mut w = vec![10u64; 27];
        w[13] = 400;
        let alloc = w2b_allocate(&w, 54);
        assert_eq!(alloc.makespan_before, 400);
        assert!(alloc.speedup() > 2.0, "speedup {}", alloc.speedup());
        // Central offset got the lion's share of copies.
        assert!(alloc.copies[13] > 10);
        assert_eq!(alloc.copies.iter().sum::<u32>(), 54);
    }

    #[test]
    fn uniform_workload_gains_little() {
        let w = vec![100u64; 27];
        let alloc = w2b_allocate(&w, 54);
        assert!(alloc.speedup() <= 2.0 + 1e-9);
    }

    #[test]
    fn zero_workload_offsets_keep_one_copy() {
        let mut w = vec![0u64; 27];
        w[13] = 100;
        let alloc = w2b_allocate(&w, 30);
        assert!(alloc.copies.iter().all(|&c| c >= 1));
        assert_eq!(alloc.copies[13], 4);
        assert_eq!(alloc.makespan_after, 25);
    }

    #[test]
    fn budget_equal_k_is_identity() {
        let w: Vec<u64> = (1..=27).collect();
        let alloc = w2b_allocate(&w, 27);
        assert_eq!(alloc.copies, vec![1; 27]);
        assert!((alloc.speedup() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn optimality_prop() {
        // The binary-search makespan is optimal: no allocation within the
        // budget achieves a strictly smaller makespan (checked against
        // the feasibility function itself) and the speedup is monotone in
        // budget.
        check("w2b optimal + monotone", 30, |g| {
            let n = g.usize(2, 40);
            let w: Vec<u64> = (0..n).map(|_| g.usize(0, 500) as u64).collect();
            let b1 = (n + g.usize(0, 2 * n)) as u32;
            let b2 = b1 + g.usize(0, 20) as u32;
            let a1 = w2b_allocate(&w, b1);
            let a2 = w2b_allocate(&w, b2);
            assert!(a2.makespan_after <= a1.makespan_after);
            // Feasibility check at T-1 must exceed the budget.
            if a1.makespan_after > 1 {
                let t = a1.makespan_after - 1;
                let need: u64 = w
                    .iter()
                    .map(|&x| if x == 0 { 1 } else { x.div_ceil(t) })
                    .sum();
                assert!(
                    need > b1 as u64,
                    "T={} was feasible with budget {}",
                    t,
                    b1
                );
            }
            // All copies >= 1, total == budget.
            assert!(a1.copies.iter().all(|&c| c >= 1));
            assert_eq!(a1.copies.iter().sum::<u32>(), b1);
        });
    }

    #[test]
    fn copies_for_factor_scales_the_kernel_volume() {
        let mut w = vec![5u64; 27];
        w[13] = 200;
        assert_eq!(copies_for_factor(&w, 1), vec![1u32; 27]);
        assert_eq!(copies_for_factor(&w, 0), vec![1u32; 27]); // clamped to identity
        let c2 = copies_for_factor(&w, 2);
        assert_eq!(c2.iter().sum::<u32>(), 54);
        assert!(c2[13] >= 2, "hot center not replicated: {c2:?}");
    }

    #[test]
    fn capacity_budget_caps() {
        let cfg = CimConfig::default();
        let b = capacity_budget(&cfg, 64, 64, 27, 2);
        assert_eq!(b, 54); // capacity (256) doesn't bind at 2x27
        let b2 = capacity_budget(&cfg, 256, 256, 27, 8);
        assert_eq!(b2, 27); // capacity binds below 27, floor at k
    }
}
