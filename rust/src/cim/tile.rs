//! CIM tile geometry and the macro-level throughput model.
//!
//! "The CIM unit is composed of tiles, where each tile contains 1024x1024
//! memory cells. Each cell can store 1 bit" (§3.3). Table 2 gives the
//! operating points we calibrate to: 27.8 TOPS peak at 1 GHz / 22 nm and
//! 10.8 TOPS/W at 0.85 V.

use crate::cim::pe::PeConfig;

/// Whole computing-core configuration.
#[derive(Clone, Copy, Debug)]
pub struct CimConfig {
    pub pe: PeConfig,
    /// Number of 1024x1024 tiles.
    pub tiles: usize,
    /// Tile edge in cells.
    pub tile_rows: usize,
    pub tile_cols: usize,
    /// Clock frequency in Hz (Table 2: 1000 MHz).
    pub freq_hz: f64,
    /// Fraction of ideal array throughput delivered at peak (peripheral
    /// and pipeline overheads). Calibrated so `peak_tops()` reproduces
    /// Table 2's 27.8 TOPS.
    pub array_efficiency: f64,
}

impl Default for CimConfig {
    fn default() -> Self {
        Self {
            pe: PeConfig::default(),
            tiles: 8,
            tile_rows: 1024,
            tile_cols: 1024,
            freq_hz: 1.0e9,
            array_efficiency: 0.849,
        }
    }
}

impl CimConfig {
    /// Total bit-cells.
    pub fn total_cells(&self) -> u64 {
        (self.tiles * self.tile_rows * self.tile_cols) as u64
    }

    /// Int8 weights the core can hold resident.
    pub fn weight_capacity(&self) -> u64 {
        self.total_cells() / self.pe.cells_per_weight()
    }

    /// MACs per cycle at full activation: every row driven, `cols/mux`
    /// bit-columns read per cycle, one full int8xint8 MAC per
    /// `weight_bits` bit-columns per `input_bits` bit-serial waves.
    pub fn macs_per_cycle(&self) -> f64 {
        let bitcol_reads =
            self.tiles as f64 * self.tile_rows as f64 * self.tile_cols as f64
                / self.pe.col_mux as f64;
        bitcol_reads / (self.pe.weight_bits as f64 * self.pe.input_bits as f64)
    }

    /// Peak throughput in TOPS (2 ops per MAC), including the calibrated
    /// array efficiency.
    pub fn peak_tops(&self) -> f64 {
        self.macs_per_cycle() * 2.0 * self.freq_hz * self.array_efficiency / 1e12
    }

    /// Sub-matrix slots: how many `c1 x c2` int8 sub-matrices fit the
    /// core (the W2B copy budget is capped by this).
    pub fn submatrix_slots(&self, c1: usize, c2: usize) -> u64 {
        self.weight_capacity() / (c1 as u64 * c2 as u64)
    }

    /// Cycles to stream `pairs` input vectors through one sub-matrix
    /// instance (no replication).
    pub fn cycles_for_pairs(&self, pairs: u64) -> u64 {
        pairs * self.pe.cycles_per_pair()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity() {
        let c = CimConfig::default();
        assert_eq!(c.total_cells(), 8 * 1024 * 1024);
        assert_eq!(c.weight_capacity(), 1024 * 1024);
    }

    #[test]
    fn peak_matches_table2() {
        // Table 2: 27822 GOPS peak. Calibrated within 1%.
        let tops = CimConfig::default().peak_tops();
        assert!(
            (tops - 27.822).abs() / 27.822 < 0.01,
            "peak {tops} TOPS vs Table 2's 27.822"
        );
    }

    #[test]
    fn submatrix_slots_for_tile_c() {
        let c = CimConfig::default();
        // 64x64 int8 sub-matrix = 4096 weights: 256 slots.
        assert_eq!(c.submatrix_slots(64, 64), 256);
        // SECOND L1 (16 ch): tiny sub-matrices, huge budget.
        assert!(c.submatrix_slots(4, 16) > 10_000);
    }

    #[test]
    fn cycle_model_scales_linearly() {
        let c = CimConfig::default();
        assert_eq!(c.cycles_for_pairs(10), 640);
    }
}
