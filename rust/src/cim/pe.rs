//! PE (processing element) datapath parameters.
//!
//! Each PE owns a region of the tile plus "all necessary resources to
//! perform MAC operations, such as MUXs, ADCs, Shift-Adders" (Fig. 7).
//! The numeric semantics (bit-serial input, per-bit-plane ADC clamp,
//! shift-add) are implemented by the L1 Pallas kernel / `quant::cim_gemm_ref`;
//! this struct carries the *timing* parameters.

/// PE configuration. Defaults match the L1 kernel constants
/// (`python/compile/kernels/ref.py`) and a typical 22 nm SRAM-CIM macro.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PeConfig {
    /// Activation bit width (bit-serial cycles per input wave).
    pub input_bits: u32,
    /// Weight bit width (bit-columns per logical weight column).
    pub weight_bits: u32,
    /// ADC resolution; MUST match the AOT kernel's `adc_bits`.
    pub adc_bits: u32,
    /// Columns sharing one ADC (time-multiplexed reads).
    pub col_mux: u32,
}

impl Default for PeConfig {
    fn default() -> Self {
        Self {
            input_bits: 8,
            weight_bits: 8,
            adc_bits: 8,
            col_mux: 8,
        }
    }
}

impl PeConfig {
    /// Cycles for one input vector against one resident sub-matrix
    /// (regardless of its column count — all columns of the sub-matrix
    /// region are read through their own ADCs in `col_mux` rounds):
    /// bit-serial input × column multiplexing.
    pub fn cycles_per_pair(&self) -> u64 {
        (self.input_bits * self.col_mux) as u64
    }

    /// Bit-cells per logical int8 weight.
    pub fn cells_per_weight(&self) -> u64 {
        self.weight_bits as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_kernel_constants() {
        let pe = PeConfig::default();
        // These two must stay in lock-step with python/compile/kernels/ref.py.
        assert_eq!(pe.input_bits, 8);
        assert_eq!(pe.adc_bits, 8);
    }

    #[test]
    fn pair_cycles() {
        assert_eq!(PeConfig::default().cycles_per_pair(), 64);
        let fast = PeConfig {
            input_bits: 4,
            col_mux: 4,
            ..Default::default()
        };
        assert_eq!(fast.cycles_per_pair(), 16);
    }
}
