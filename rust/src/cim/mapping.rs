//! Weight-mapping strategies (§3.2A, Fig. 5).
//!
//! * **Traditional** (Fig. 5a): each output channel's whole `C1·K³` kernel
//!   column is unrolled into one array column. Fine for dense Conv2D,
//!   wasteful for Spconv3D: with output-stationary dataflow only the rows
//!   whose inputs exist are driven (utilization = the output's pair count
//!   over K³), and with weight-stationary the psums of one column belong
//!   to different outputs and cannot be accumulated in-array.
//! * **Sub-matrix** (Fig. 5b/c): each kernel offset's `C1 x C2` slice is
//!   an independently-activated sub-matrix; the gather unit feeds each
//!   offset its own input batch (weight-stationary), and the scatter unit
//!   accumulates digitally.
//!
//! The plan computed here is consumed by the latency model and by
//! [`crate::cim::w2b`] for replication.

use crate::cim::tile::CimConfig;
use crate::sparse::rulebook::Rulebook;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MappingStrategy {
    Traditional,
    SubMatrix,
}

/// A placed layer: how many sub-matrix instances exist per kernel offset
/// and what the resulting makespan is.
#[derive(Clone, Debug)]
pub struct SubMatrixPlan {
    pub c1: usize,
    pub c2: usize,
    pub k_volume: usize,
    /// Copies per offset (all 1 without W2B).
    pub copies: Vec<u32>,
    /// Per-offset workload (pair count).
    pub workload: Vec<u64>,
}

impl SubMatrixPlan {
    /// Plan a layer without replication.
    pub fn new(c1: usize, c2: usize, rb: &Rulebook) -> Self {
        let workload = rb.workload_per_offset();
        Self {
            c1,
            c2,
            k_volume: workload.len(),
            copies: vec![1; workload.len()],
            workload,
        }
    }

    /// Weights stored (including replication), in int8 units.
    pub fn weights_stored(&self) -> u64 {
        let per = (self.c1 * self.c2) as u64;
        self.copies.iter().map(|&c| c as u64 * per).sum()
    }

    /// Does the plan fit the core?
    pub fn fits(&self, cfg: &CimConfig) -> bool {
        self.weights_stored() <= cfg.weight_capacity()
    }

    /// Makespan in *pair-slots*: all sub-matrices operate in parallel, so
    /// the layer finishes when its most-loaded instance finishes.
    pub fn makespan_pairs(&self) -> u64 {
        self.workload
            .iter()
            .zip(&self.copies)
            .map(|(&w, &c)| w.div_ceil(c as u64))
            .max()
            .unwrap_or(0)
    }

    /// Layer compute cycles under this plan.
    pub fn cycles(&self, cfg: &CimConfig) -> u64 {
        cfg.cycles_for_pairs(self.makespan_pairs())
    }

    /// Resource utilization: useful pair-slots over allocated pair-slots.
    pub fn utilization(&self) -> f64 {
        let total: u64 = self.workload.iter().sum();
        let slots: u64 = self.makespan_pairs() * self.copies.iter().map(|&c| c as u64).sum::<u64>();
        if slots == 0 {
            0.0
        } else {
            total as f64 / slots as f64
        }
    }
}

/// Cycle estimate for the *traditional* mapping running the same rulebook
/// with an output-stationary dataflow: each output is processed as one
/// array activation in which only its valid rows are driven — K³·C1 rows
/// allocated, `pairs(o)·C1` useful. Cycles = outputs × (bit-serial ·
/// mux) as every output needs a full wave regardless of fill.
pub fn traditional_cycles(rb: &Rulebook, cfg: &CimConfig) -> u64 {
    rb.out_coords.len() as u64 * cfg.pe.cycles_per_pair()
}

/// Utilization of the traditional mapping on a sparse rulebook: average
/// fraction of driven rows that carry real inputs.
pub fn traditional_utilization(rb: &Rulebook) -> f64 {
    let k3 = rb.kind.kernel_volume() as f64;
    if rb.out_coords.is_empty() {
        return 0.0;
    }
    rb.len() as f64 / (rb.out_coords.len() as f64 * k3)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Extent3;
    use crate::pointcloud::voxelize::Voxelizer;
    use crate::sparse::rulebook::ConvKind;
    use crate::sparse::{hash_map_search, SparseTensor};

    fn rulebook(n: usize, seed: u64) -> Rulebook {
        let e = Extent3::new(32, 32, 8);
        let g = Voxelizer::synth_occupancy(e, n as f64 / e.volume() as f64, seed);
        let t = SparseTensor::from_coords(e, g.coords(), 4);
        hash_map_search(&t, ConvKind::subm3())
    }

    #[test]
    fn plan_fits_and_measures() {
        let rb = rulebook(800, 81);
        let plan = SubMatrixPlan::new(64, 64, &rb);
        let cfg = CimConfig::default();
        assert!(plan.fits(&cfg));
        assert_eq!(plan.weights_stored(), 27 * 64 * 64);
        // Center offset dominates the makespan.
        let w = rb.workload_per_offset();
        assert_eq!(plan.makespan_pairs(), *w.iter().max().unwrap());
    }

    #[test]
    fn submatrix_beats_traditional_on_sparse_data() {
        // Without replication, sub-matrix weight-stationary and
        // traditional output-stationary both bottleneck on the center
        // offset (= one wave per output); the sub-matrix mapping's win is
        // that it *admits* W2B replication, which traditional mapping
        // cannot (its column psums belong to one output).
        let rb = rulebook(500, 82);
        let cfg = CimConfig::default();
        let mut plan = SubMatrixPlan::new(16, 16, &rb);
        assert!(traditional_utilization(&rb) < 0.5);
        // Identical bottleneck before W2B:
        assert_eq!(plan.cycles(&cfg), traditional_cycles(&rb, &cfg));
        // With W2B the sub-matrix plan pulls ahead.
        let alloc = crate::cim::w2b::w2b_allocate(&plan.workload, 54);
        plan.copies = alloc.copies.clone();
        assert!(plan.fits(&cfg));
        assert!(plan.cycles(&cfg) < traditional_cycles(&rb, &cfg));
        assert!(plan.utilization() > traditional_utilization(&rb));
    }

    #[test]
    fn utilization_bounds() {
        let rb = rulebook(300, 83);
        let plan = SubMatrixPlan::new(16, 16, &rb);
        let u = plan.utilization();
        assert!(u > 0.0 && u <= 1.0);
    }

    #[test]
    fn oversized_replication_does_not_fit() {
        let rb = rulebook(300, 84);
        let mut plan = SubMatrixPlan::new(256, 256, &rb);
        // 27 x 256x256 = 1.77M weights > 1M capacity.
        assert!(!plan.fits(&CimConfig::default()));
        plan.copies = vec![1; 27];
        assert_eq!(plan.weights_stored(), 27 * 256 * 256);
    }
}
