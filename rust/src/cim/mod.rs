//! The CIM computing core (§3.2, Fig. 7 right): SRAM tiles partitioned
//! into PEs, the sub-matrix weight-mapping strategies for Spconv3D /
//! Conv2D, the W2B workload balancer, and the 22 nm energy/latency model
//! calibrated to the paper's Table 2 operating points.

pub mod energy;
pub mod mapping;
pub mod pe;
pub mod tile;
pub mod w2b;

pub use energy::EnergyModel;
pub use mapping::{MappingStrategy, SubMatrixPlan};
pub use pe::PeConfig;
pub use tile::CimConfig;
pub use w2b::{copies_for_factor, w2b_allocate, W2bAllocation};
