//! 22 nm energy model, NeuroSim-style decomposition (array read + ADC +
//! digital + buffers + DRAM), with macro constants calibrated so the
//! whole core reproduces Table 2's operating points:
//!
//! * peak throughput 27.8 TOPS @ 1 GHz ([`crate::cim::CimConfig::peak_tops`]),
//! * peak energy efficiency **10.8 TOPS/W @ 0.85 V**.
//!
//! The paper's numbers are produced by DNN+NeuroSim v2.0 [29]; we keep
//! NeuroSim's *structure* (what scales with rows/columns/conversions) and
//! fit the three leading coefficients to the published operating point —
//! see DESIGN.md §3 for why this preserves every downstream ratio.

use crate::cim::tile::CimConfig;

/// Energy coefficients (joules).
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    /// Per bit-cell read (wordline + bitline + sense share), J.
    pub e_cell_read: f64,
    /// Per ADC conversion (8-bit SAR at 0.85 V, 22 nm), J.
    pub e_adc: f64,
    /// Digital per active-cycle per tile (shift-adders, accumulators,
    /// control), J.
    pub e_digital_tile_cycle: f64,
    /// On-chip buffer access per byte, J.
    pub e_buffer_byte: f64,
    /// Off-chip DRAM access per byte (HBM2), J.
    pub e_dram_byte: f64,
    /// Static/leakage power of the whole core, W.
    pub p_leak: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self {
            // Calibrated triple: with the default CimConfig these yield
            // 10.8 TOPS/W at peak (see `peak_tops_per_watt` test).
            e_cell_read: 0.60e-15,
            e_adc: 1.48e-12,
            e_digital_tile_cycle: 45.0e-12,
            e_buffer_byte: 1.2e-12,
            e_dram_byte: 31.2e-12, // ~3.9 pJ/bit, HBM2-class
            p_leak: 0.08,
        }
    }
}

impl EnergyModel {
    /// Dynamic energy of one fully-active core cycle: all rows driven,
    /// `cols / mux` bit-columns read and converted per tile, plus the
    /// digital pipeline.
    pub fn energy_per_cycle(&self, cfg: &CimConfig) -> f64 {
        let cells_read = cfg.total_cells() as f64 / cfg.pe.col_mux as f64;
        cells_read * self.e_cell_read
            + self.adc_energy_per_cycle(cfg)
            + cfg.tiles as f64 * self.e_digital_tile_cycle
    }

    /// ADC energy per fully-active cycle: one conversion per resident ADC
    /// (`cols / mux` ADCs per tile).
    fn adc_energy_per_cycle(&self, cfg: &CimConfig) -> f64 {
        let adcs = cfg.tiles as f64 * cfg.tile_cols as f64 / cfg.pe.col_mux as f64;
        adcs * self.e_adc
    }

    /// Peak power (W) at full activity.
    pub fn peak_power(&self, cfg: &CimConfig) -> f64 {
        self.energy_per_cycle(cfg) * cfg.freq_hz + self.p_leak
    }

    /// Peak efficiency in TOPS/W — Table 2's headline 10.8.
    pub fn peak_tops_per_watt(&self, cfg: &CimConfig) -> f64 {
        cfg.peak_tops() / self.peak_power(cfg)
    }

    /// Energy of a compute phase of `cycles` cycles with an `activity`
    /// fraction of the array busy.
    pub fn compute_energy(&self, cfg: &CimConfig, cycles: u64, activity: f64) -> f64 {
        self.energy_per_cycle(cfg) * cycles as f64 * activity.clamp(0.0, 1.0)
            + self.p_leak * cycles as f64 / cfg.freq_hz
    }

    /// Dynamic energy of one useful MAC. Independent of replication: W2B
    /// spreads the same MACs over more sub-matrices in fewer cycles, so
    /// per-MAC energy is the invariant quantity (idle PEs are
    /// clock-gated); only leakage scales with runtime — which is exactly
    /// why the paper's Fig. 10 shows a large speedup but only a ~6%
    /// energy reduction.
    pub fn energy_per_mac(&self, cfg: &CimConfig) -> f64 {
        self.energy_per_cycle(cfg) / (cfg.macs_per_cycle() * cfg.array_efficiency)
    }

    /// Energy of moving `bytes` through the on-chip buffers.
    pub fn buffer_energy(&self, bytes: u64) -> f64 {
        bytes as f64 * self.e_buffer_byte
    }

    /// Energy of `bytes` of DRAM traffic.
    pub fn dram_energy(&self, bytes: u64) -> f64 {
        bytes as f64 * self.e_dram_byte
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_efficiency_matches_table2() {
        let cfg = CimConfig::default();
        let em = EnergyModel::default();
        let eff = em.peak_tops_per_watt(&cfg);
        assert!(
            (eff - 10.8).abs() / 10.8 < 0.05,
            "peak efficiency {eff} TOPS/W vs Table 2's 10.8"
        );
    }

    #[test]
    fn power_budget_is_watts_scale() {
        let p = EnergyModel::default().peak_power(&CimConfig::default());
        assert!(p > 1.0 && p < 5.0, "peak power {p} W implausible");
    }

    #[test]
    fn adc_dominates_array_read() {
        // Sanity on the decomposition: ADC is the biggest dynamic term in
        // SRAM CIM at 8-bit resolution (the standard NeuroSim finding).
        let cfg = CimConfig::default();
        let em = EnergyModel::default();
        let adc = em.adc_energy_per_cycle(&cfg);
        let cells = cfg.total_cells() as f64 / cfg.pe.col_mux as f64 * em.e_cell_read;
        assert!(adc > cells);
    }

    #[test]
    fn compute_energy_scales_with_activity() {
        let cfg = CimConfig::default();
        let em = EnergyModel::default();
        let full = em.compute_energy(&cfg, 1000, 1.0);
        let half = em.compute_energy(&cfg, 1000, 0.5);
        assert!(half < full && half > 0.4 * full);
    }

    #[test]
    fn dram_energy_dwarfs_buffer_energy_per_byte() {
        let em = EnergyModel::default();
        assert!(em.e_dram_byte > 10.0 * em.e_buffer_byte);
    }
}
