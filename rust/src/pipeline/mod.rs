//! The pipeline facade: one owned-engine front door for the whole stack.
//!
//! ```text
//!   dataset (FrameSource / SequenceMux)
//!        │
//!   serving (admission · window packing)
//!        │
//!   Pipeline ── owns Runtime-or-NativeEngine, NetworkRunner, StreamServer
//!        │
//!   engine layer (run_scenes → lockstep GEMM waves)
//! ```
//!
//! Four PRs of layer growth left the public API as a sprawl:
//! `NetworkRunner::{run_frame, run_frames, run_frame_sharded, run_scenes}`,
//! `StreamServer::{serve, serve_closure}`, and five config structs that
//! every caller assembled by hand while threading `&mut E: GemmEngine`
//! through each call. This module replaces that with a single submission
//! surface:
//!
//! ```no_run
//! use voxel_cim::pipeline::{Job, Pipeline, PipelineConfig};
//!
//! # fn main() -> voxel_cim::Result<()> {
//! let cfg = PipelineConfig::load("examples/configs/default.toml")?;
//! let mut pipe = Pipeline::builder().config(cfg).build()?;
//! let source = pipe.open_source()?;
//! let report = pipe.run(Job::Stream(source))?.into_stream()?;
//! println!("{:.1} fps", report.throughput_fps());
//! # Ok(())
//! # }
//! ```
//!
//! [`Pipeline::run`] routes every [`Job`] through the same internals the
//! legacy entry points used — `NetworkRunner::run_scenes` for frames and
//! windows, `StreamServer::serve` for streams — so results are
//! checksum-bit-identical to `run_frame` / `run_frame_sharded` /
//! `run_frames` / `serve` for every `SearcherKind`, sharded or not
//! (witnessed in `tests/pipeline_api.rs`). The engine is *owned*: the
//! facade resolves it once ([`EngineKind`]) and no `&mut E` parameter
//! appears on the public surface — the prerequisite for the ROADMAP's
//! forkable per-worker PJRT executable.

mod config;

pub use config::{EngineKind, NetworkKind, Overrides, PipelineConfig, PipelineError};

use crate::coordinator::scheduler::FrameResult;
use crate::coordinator::stream::{StreamReport, StreamServer};
use crate::dataset::FrameSource;
use crate::model::layer::NetworkSpec;
use crate::obs::{ObservedSource, Recorder};
use crate::runtime::Runtime;
use crate::serving::WindowPolicy;
use crate::sparse::tensor::SparseTensor;
use crate::spconv::layer::{GemmEngine, NativeEngine};

/// One unit of work submitted to [`Pipeline::run`].
pub enum Job {
    /// One scene through the network (block-sharded into lockstep
    /// pseudo-frames when the configured `[shard]` grid triggers).
    Frame(SparseTensor),
    /// An explicit lockstep window of scenes: all of them advance
    /// through the network together sharing GEMM waves, bit-identical
    /// per scene to running each alone.
    Window(Vec<SparseTensor>),
    /// Serve `[dataset] frames` frames from a source through the serving
    /// scheduler (admission, window packing, latency attribution). Build
    /// the configured source with [`Pipeline::open_source`], or pass any
    /// [`FrameSource`] of your own.
    Stream(Box<dyn FrameSource>),
}

impl Job {
    /// Box any [`FrameSource`] into a stream job.
    pub fn stream(source: impl FrameSource + 'static) -> Self {
        Self::Stream(Box::new(source))
    }
}

/// What a [`Job`] produced — one variant per job kind.
#[derive(Debug)]
pub enum RunOutcome {
    /// Result of a [`Job::Frame`].
    Frame(FrameResult),
    /// Per-scene results of a [`Job::Window`], in submission order.
    Window(Vec<FrameResult>),
    /// Report of a [`Job::Stream`].
    Stream(StreamReport),
}

impl RunOutcome {
    /// Unwrap a [`Job::Frame`] outcome.
    pub fn into_frame(self) -> crate::Result<FrameResult> {
        match self {
            Self::Frame(r) => Ok(r),
            other => Err(PipelineError::WrongOutcome(other.kind()).into()),
        }
    }

    /// Unwrap a [`Job::Window`] outcome.
    pub fn into_window(self) -> crate::Result<Vec<FrameResult>> {
        match self {
            Self::Window(r) => Ok(r),
            other => Err(PipelineError::WrongOutcome(other.kind()).into()),
        }
    }

    /// Unwrap a [`Job::Stream`] outcome.
    pub fn into_stream(self) -> crate::Result<StreamReport> {
        match self {
            Self::Stream(r) => Ok(r),
            other => Err(PipelineError::WrongOutcome(other.kind()).into()),
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            Self::Frame(_) => "frame",
            Self::Window(_) => "window",
            Self::Stream(_) => "stream",
        }
    }
}

/// Builder for [`Pipeline`] — `Pipeline::builder().config(cfg).build()?`.
///
/// Everything is optional: the config defaults to
/// [`PipelineConfig::default`], the network to the config's
/// `[pipeline] network`, and the engine to the config's
/// `[pipeline] engine` resolution (PJRT artifacts with native fallback).
pub struct PipelineBuilder {
    cfg: PipelineConfig,
    network: Option<NetworkSpec>,
    engine: Option<(Box<dyn GemmEngine>, String)>,
}

impl PipelineBuilder {
    /// Use this unified run config.
    pub fn config(mut self, cfg: PipelineConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Drive this network instead of the config's `[pipeline] network`.
    pub fn network(mut self, net: NetworkSpec) -> Self {
        self.network = Some(net);
        self
    }

    /// Hand the pipeline this engine instead of resolving one from the
    /// config (tests and benches pass a fresh `NativeEngine` here).
    pub fn engine<E: GemmEngine + 'static>(mut self, engine: E) -> Self {
        self.engine = Some((Box::new(engine), "caller-supplied".into()));
        self
    }

    /// Validate the config and assemble the owned stack. Configuration
    /// inconsistencies surface as typed
    /// [`PipelineError::InvalidConfig`] errors.
    pub fn build(self) -> crate::Result<Pipeline> {
        let cfg = self.cfg;
        cfg.validate()?;
        let net = self
            .network
            .unwrap_or_else(|| cfg.network.build(cfg.stream_extent()));
        let (engine, engine_desc) = match self.engine {
            Some(e) => e,
            None => build_engine(&cfg)?,
        };
        let window = cfg.serving.resolved_window(cfg.serving.sequences.len());
        let obs = Recorder::from_config(&cfg.observability);
        // The server's queue_depth only sizes the deprecated
        // serve_closure prefetch buffer, which the facade never calls;
        // stream jobs' pending-queue bound is `[serving] depth`
        // (`AdmissionConfig::effective_depth`).
        let server = StreamServer::new(net, cfg.runner, 2)
            .with_window(window)
            .with_admission(cfg.serving.admission)
            .with_observer(obs.clone());
        Ok(Pipeline {
            cfg,
            server,
            engine,
            engine_desc,
            window,
            obs,
        })
    }
}

/// Resolve the owned engine named by `[pipeline] engine`.
fn build_engine(cfg: &PipelineConfig) -> crate::Result<(Box<dyn GemmEngine>, String)> {
    let native = || -> (Box<dyn GemmEngine>, String) {
        (
            Box::new(NativeEngine::default()),
            "native (bit-exact CIM reference)".into(),
        )
    };
    let pjrt = || -> crate::Result<(Box<dyn GemmEngine>, String)> {
        let rt = Runtime::load(&cfg.runtime_config())?;
        let desc = format!("PJRT CPU (GEMM batches {:?})", rt.gemm_batches());
        Ok((Box::new(rt), desc))
    };
    match cfg.engine {
        EngineKind::Native => Ok(native()),
        EngineKind::Pjrt => pjrt().map_err(|e| {
            // A valid config whose runtime pieces are missing — typed
            // apart from InvalidConfig so "run make artifacts" is not
            // mistaken for a config typo.
            PipelineError::EngineUnavailable(format!("pipeline.engine = \"pjrt\": {e:#}"))
                .into()
        }),
        EngineKind::Auto => match pjrt() {
            Ok(resolved) => Ok(resolved),
            Err(e) => {
                let (engine, base) = native();
                Ok((engine, format!("{base}; PJRT unavailable: {e:#}")))
            }
        },
    }
}

/// The facade: owns the run config, the network runner, the serving
/// scheduler, and the GEMM engine. Submit work with [`Self::run`].
pub struct Pipeline {
    cfg: PipelineConfig,
    server: StreamServer,
    engine: Box<dyn GemmEngine>,
    engine_desc: String,
    window: WindowPolicy,
    obs: Recorder,
}

impl Pipeline {
    /// Start building a pipeline.
    pub fn builder() -> PipelineBuilder {
        PipelineBuilder {
            cfg: PipelineConfig::default(),
            network: None,
            engine: None,
        }
    }

    /// The unified config this pipeline was built from.
    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// The network being driven.
    pub fn network(&self) -> &NetworkSpec {
        &self.server.runner().net
    }

    /// Human-readable description of the owned engine (resolution +
    /// artifact batches for PJRT).
    pub fn engine_desc(&self) -> &str {
        &self.engine_desc
    }

    /// The resolved lockstep-window packing policy of stream jobs.
    pub fn window(&self) -> WindowPolicy {
        self.window
    }

    /// GEMM dispatches the owned engine has issued so far (cumulative
    /// across jobs; forked worker engines keep their own counters).
    pub fn dispatches(&self) -> u64 {
        self.engine.dispatches()
    }

    /// The stage-span / metrics recorder built from `[observability]`
    /// ([`Recorder::Disabled`] when the section is off — every method is
    /// then a no-op). Use it to export traces after a run:
    /// `pipe.observer().write_chrome_trace(path)?`.
    pub fn observer(&self) -> &Recorder {
        &self.obs
    }

    /// Build the frame source the config names (`[dataset] source`, or a
    /// [`SequenceMux`](crate::serving::SequenceMux) over `[serving]
    /// sequences`), sized to the network extent. A configuration with no
    /// source is a typed [`PipelineError::NoSource`] error.
    pub fn open_source(&self) -> crate::Result<Box<dyn FrameSource>> {
        self.cfg.build_source(self.network().extent)?.ok_or_else(|| {
            PipelineError::NoSource(
                "no dataset source configured: set [dataset] source / --dataset \
                 or [serving] sequences / --sequences"
                    .into(),
            )
            .into()
        })
    }

    /// Submit one job. Every kind routes through the same internals —
    /// `run_scenes` for frames and windows, `serve` for streams — so
    /// results are bit-identical to the legacy per-entry-point API.
    pub fn run(&mut self, job: Job) -> crate::Result<RunOutcome> {
        let outcome = match job {
            Job::Frame(tensor) => {
                let result = self
                    .server
                    .runner()
                    .run_scenes(vec![tensor], &mut self.engine)?
                    .pop()
                    .ok_or_else(|| anyhow::anyhow!("one scene in, one result out"))?;
                RunOutcome::Frame(result)
            }
            Job::Window(tensors) => RunOutcome::Window(
                self.server.runner().run_scenes(tensors, &mut self.engine)?,
            ),
            Job::Stream(mut source) => {
                // Observed streams also time frame acquisition, as
                // `voxelize` spans — frame content is untouched either
                // way, so results stay bit-identical.
                let report = if self.obs.enabled() {
                    let mut observed = ObservedSource::new(source, self.obs.clone());
                    self.server.serve(
                        self.cfg.dataset.frames,
                        &mut observed,
                        &mut self.engine,
                    )?
                } else {
                    self.server.serve(
                        self.cfg.dataset.frames,
                        source.as_mut(),
                        &mut self.engine,
                    )?
                };
                RunOutcome::Stream(report)
            }
        };
        // Frame/window jobs commit their buffered spans here (stream
        // jobs drained at each window already, but a trailing sweep is
        // idempotent); the dispatch gauge tracks the owned engine.
        self.obs.drain();
        if let Some(m) = self.obs.metrics() {
            m.set_gauge("engine.dispatches", self.engine.dispatches() as f64);
        }
        Ok(outcome)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::ClosureSource;
    use crate::geom::Extent3;
    use crate::pointcloud::voxelize::Voxelizer;

    fn tiny_cfg() -> PipelineConfig {
        PipelineConfig {
            dataset: crate::dataset::DatasetConfig {
                extent: Some(Extent3::new(16, 16, 8)),
                ..Default::default()
            },
            engine: EngineKind::Native,
            ..Default::default()
        }
    }

    fn make_frame(id: u64) -> SparseTensor {
        let e = Extent3::new(16, 16, 8);
        let g = Voxelizer::synth_occupancy(e, 0.05, 400 + id);
        let mut t = SparseTensor::from_coords(e, g.coords(), 4);
        for (i, v) in t.features.iter_mut().enumerate() {
            *v = ((i as u64 + id) % 7) as i8;
        }
        t
    }

    #[test]
    fn frame_window_and_stream_jobs_run_through_one_pipeline() {
        let mut pipe = Pipeline::builder().config(tiny_cfg()).build().unwrap();
        assert_eq!(pipe.network().name, "stream");
        let frame = pipe.run(Job::Frame(make_frame(0))).unwrap();
        let frame = frame.into_frame().unwrap();
        assert!(frame.out_voxels > 0);
        let window = pipe
            .run(Job::Window(vec![make_frame(1), make_frame(2)]))
            .unwrap()
            .into_window()
            .unwrap();
        assert_eq!(window.len(), 2);
        let mut cfg = tiny_cfg();
        cfg.dataset.frames = 3;
        let mut pipe = Pipeline::builder().config(cfg).build().unwrap();
        let report = pipe
            .run(Job::stream(ClosureSource::new(make_frame)))
            .unwrap()
            .into_stream()
            .unwrap();
        assert_eq!(report.completions.len(), 3);
        assert!(pipe.dispatches() > 0, "owned engine counts dispatches");
    }

    #[test]
    fn observed_pipeline_records_spans_through_the_facade() {
        let mut cfg = tiny_cfg();
        cfg.dataset.frames = 3;
        cfg.observability.trace = true;
        cfg.observability.metrics = true;
        let mut pipe = Pipeline::builder().config(cfg).build().unwrap();
        let report = pipe
            .run(Job::stream(ClosureSource::new(make_frame)))
            .unwrap()
            .into_stream()
            .unwrap();
        assert_eq!(report.completions.len(), 3);
        let spans = pipe.observer().spans();
        assert!(!spans.is_empty(), "tracing pipeline recorded no spans");
        // Frame acquisition was observed via the source wrapper, with
        // frame attribution.
        assert!(spans
            .iter()
            .any(|s| s.stage == crate::obs::Stage::Voxelize && s.frame.is_some()));
        assert!(!report.stage_summary().is_empty());
        // The dispatch gauge mirrors the owned engine's counter.
        let m = pipe.observer().metrics().expect("metrics half on");
        assert_eq!(m.gauge("engine.dispatches"), Some(pipe.dispatches() as f64));
        assert_eq!(m.counter("stream.windows"), report.windows);
    }

    #[test]
    fn wrong_outcome_unwraps_are_typed_errors() {
        let mut pipe = Pipeline::builder().config(tiny_cfg()).build().unwrap();
        let out = pipe.run(Job::Frame(make_frame(7))).unwrap();
        let err = out.into_stream().unwrap_err();
        assert!(matches!(
            err.downcast_ref::<PipelineError>(),
            Some(PipelineError::WrongOutcome("frame"))
        ));
    }

    #[test]
    fn open_source_without_config_is_a_typed_error() {
        let pipe = Pipeline::builder().config(tiny_cfg()).build().unwrap();
        let err = pipe.open_source().unwrap_err();
        assert!(matches!(
            err.downcast_ref::<PipelineError>(),
            Some(PipelineError::NoSource(_))
        ));
    }

    #[test]
    fn builder_rejects_invalid_config_before_construction() {
        let mut cfg = tiny_cfg();
        cfg.serving.admission.policy = crate::serving::AdmissionPolicy::RejectOverDepth;
        let err = Pipeline::builder().config(cfg).build().unwrap_err();
        assert!(err.downcast_ref::<PipelineError>().is_some(), "{err:#}");
    }
}
