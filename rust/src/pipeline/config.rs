//! The unified run configuration of the pipeline facade.
//!
//! [`PipelineConfig`] is the one strict-keys load over every config
//! section the stack grew across PRs — `[runner]` + `[shard]` (engine
//! layer), `[dataset]` (ingestion), `[serving]` (scheduler), and the new
//! `[pipeline]` section (network / engine / artifacts dir) — plus the
//! one place CLI overrides apply ([`Overrides`]): the
//! `apply_engine_overrides`-style helpers `main.rs` used to duplicate
//! per command collapse into [`PipelineConfig::apply`].
//!
//! Validation is centralized too: [`PipelineConfig::validate`] surfaces
//! inconsistent configurations (a shedding admission policy without an
//! SLO target, a path-shaped dataset source that does not exist, a
//! sequence list naming an unknown profile) as typed
//! [`PipelineError::InvalidConfig`] errors before anything is built.

use std::path::PathBuf;

use crate::coordinator::scheduler::RunnerConfig;
use crate::coordinator::shard::ShardConfig;
use crate::dataset::{DatasetConfig, FrameSource};
use crate::geom::Extent3;
use crate::model::layer::{LayerSpec, NetworkSpec, TaskKind};
use crate::model::{minkunet, second};
use crate::obs::ObsConfig;
use crate::runtime::RuntimeConfig;
use crate::serving::{SequenceMux, ServingConfig};
use crate::util::cli::Args;
use crate::util::config::Config;

/// Typed error of the pipeline facade: what went wrong building or
/// submitting to a [`Pipeline`](crate::pipeline::Pipeline). Carried
/// inside the crate-wide `anyhow` result so callers that care can
/// `downcast_ref::<PipelineError>()` while everyone else just prints it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PipelineError {
    /// The configuration is inconsistent or invalid; the message names
    /// the offending keys in config-file terms.
    InvalidConfig(String),
    /// The configured engine cannot be brought up in this environment —
    /// a valid config, missing runtime pieces (the `pjrt` cargo feature,
    /// or `make artifacts` not run). Distinct from
    /// [`Self::InvalidConfig`] so callers can route "fix the config"
    /// and "fix the environment" remediation differently.
    EngineUnavailable(String),
    /// A stream job needs a frame source but none is configured.
    NoSource(String),
    /// A job outcome was unwrapped as the wrong variant.
    WrongOutcome(&'static str),
}

impl std::fmt::Display for PipelineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::InvalidConfig(msg) => write!(f, "invalid pipeline config: {msg}"),
            Self::EngineUnavailable(msg) => write!(f, "engine unavailable: {msg}"),
            Self::NoSource(msg) => write!(f, "no frame source: {msg}"),
            Self::WrongOutcome(msg) => write!(f, "wrong job outcome: {msg}"),
        }
    }
}

impl std::error::Error for PipelineError {}

/// Which network the pipeline drives (`[pipeline] network`). The CLI's
/// `run-det` / `run-seg` commands pass an explicit
/// [`NetworkSpec`] to the builder instead; this enum is how a config
/// file alone can name the model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum NetworkKind {
    /// Full-resolution SECOND detection backbone + RPN.
    Second,
    /// Reduced-grid SECOND (the host-scale default of `run-det`).
    SecondSmall,
    /// Full-resolution MinkUNet segmentation UNet.
    MinkUNet,
    /// Reduced-grid MinkUNet (the host-scale default of `run-seg`).
    MinkUNetSmall,
    /// The compact segmentation backbone the `stream` command serves,
    /// sized to the dataset extent (`[dataset] dims`, default 64x64x12).
    #[default]
    StreamBackbone,
}

impl NetworkKind {
    /// Canonical config-file name.
    pub fn key(&self) -> &'static str {
        match self {
            Self::Second => "second",
            Self::SecondSmall => "second-small",
            Self::MinkUNet => "minkunet",
            Self::MinkUNetSmall => "minkunet-small",
            Self::StreamBackbone => "stream",
        }
    }

    /// Build the named [`NetworkSpec`]. `stream_extent` sizes only the
    /// stream backbone; the named models carry their own grids.
    pub fn build(&self, stream_extent: Extent3) -> NetworkSpec {
        match self {
            Self::Second => second::second(),
            Self::SecondSmall => second::second_small(),
            Self::MinkUNet => minkunet::minkunet(),
            Self::MinkUNetSmall => minkunet::minkunet_small(),
            Self::StreamBackbone => NetworkSpec {
                name: "stream",
                task: TaskKind::Segmentation,
                extent: stream_extent,
                vfe_channels: 4,
                layers: vec![
                    LayerSpec::Subm3 { c_in: 4, c_out: 16 },
                    LayerSpec::Subm3 { c_in: 16, c_out: 16 },
                    LayerSpec::GConv2 { c_in: 16, c_out: 32 },
                    LayerSpec::Subm3 { c_in: 32, c_out: 32 },
                ],
            },
        }
    }
}

impl std::str::FromStr for NetworkKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "second" => Ok(Self::Second),
            "second-small" => Ok(Self::SecondSmall),
            "minkunet" => Ok(Self::MinkUNet),
            "minkunet-small" => Ok(Self::MinkUNetSmall),
            "stream" => Ok(Self::StreamBackbone),
            other => Err(format!(
                "unknown network {other:?} (expected one of: second, second-small, \
                 minkunet, minkunet-small, stream)"
            )),
        }
    }
}

impl std::fmt::Display for NetworkKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.key())
    }
}

/// How the pipeline resolves its owned GEMM engine (`[pipeline] engine`)
/// when the builder is not handed one explicitly.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// Compiled PJRT artifacts when they load, native fallback otherwise
    /// (with the load error kept in the engine description). Note: the
    /// pre-facade CLI hard-failed `run-det`/`run-seg`/`stream` when
    /// artifacts were missing; under `auto` they now fall back — pin
    /// `pjrt` to get the hard error back.
    #[default]
    Auto,
    /// The bit-exact native reference engine (no artifacts needed).
    Native,
    /// Compiled PJRT artifacts, and a hard error when they cannot load.
    Pjrt,
}

impl EngineKind {
    /// Canonical config-file name.
    pub fn key(&self) -> &'static str {
        match self {
            Self::Auto => "auto",
            Self::Native => "native",
            Self::Pjrt => "pjrt",
        }
    }
}

impl std::str::FromStr for EngineKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "auto" => Ok(Self::Auto),
            "native" => Ok(Self::Native),
            "pjrt" => Ok(Self::Pjrt),
            other => Err(format!(
                "unknown engine {other:?} (expected one of: auto, native, pjrt)"
            )),
        }
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.key())
    }
}

/// CLI overrides for a [`PipelineConfig`]: every flag the `voxel-cim`
/// binary layers on top of a config file, as optional raw strings. One
/// struct replaces the `apply_engine_overrides` / `dataset_config` /
/// `serving_config` helper trio `main.rs` used to duplicate between the
/// `run` and `stream` commands; parsing (and its error messages) lives
/// in [`PipelineConfig::apply`].
#[derive(Clone, Debug, Default)]
pub struct Overrides {
    /// `--searcher`: map-search engine name.
    pub searcher: Option<String>,
    /// `--shards`: `BXxBY` (or bare `N` = `NxN`) block-shard grid.
    pub shards: Option<String>,
    /// `--w2b`: W2B replication budget (multiple of the kernel volume).
    pub w2b: Option<String>,
    /// `--dataset`: frame source (KITTI dir or scenario profile).
    pub dataset: Option<String>,
    /// `--frames`: frames to serve on the stream path.
    pub frames: Option<String>,
    /// `--sequences`: comma-separated muxed frame sources.
    pub sequences: Option<String>,
    /// `--admission`: SLO admission policy name.
    pub admission: Option<String>,
    /// `--slo`: p95 latency target in milliseconds.
    pub slo: Option<String>,
    /// `--native`: pin the engine to the native reference.
    pub native: bool,
    /// `--delta`: enable the temporal delta map-search cache.
    pub delta: bool,
    /// `--delta-compute`: extend the delta cache through the GEMM core
    /// (implies `--delta`).
    pub delta_compute: bool,
    /// `--delta-voxelize`: extend the delta cache through voxelization
    /// (implies `--delta`).
    pub delta_voxelize: bool,
    /// `--trace`: record stage spans (no file output unless
    /// `--trace-out` names one).
    pub trace: bool,
    /// `--trace-out`: Chrome trace-event output path (implies
    /// `--trace`).
    pub trace_out: Option<String>,
    /// `--metrics-out`: metrics-snapshot output path (implies the
    /// metrics registry).
    pub metrics_out: Option<String>,
    /// `--cost`: enable the cost ledger — modeled bytes/joules counters
    /// plus the stream `--cost` footer (implies the metrics registry).
    pub cost: bool,
}

impl Overrides {
    /// Collect the standard `voxel-cim` flag set from parsed [`Args`].
    /// Requires all ten flags to be declared (the binary declares them
    /// once for every command); examples with a narrower flag set fill
    /// the fields they declare directly.
    pub fn from_args(args: &Args) -> Self {
        let opt = |name: &str| match args.get(name) {
            "" => None,
            s => Some(s.to_string()),
        };
        Self {
            searcher: opt("searcher"),
            shards: opt("shards"),
            w2b: opt("w2b"),
            dataset: opt("dataset"),
            frames: opt("frames"),
            sequences: opt("sequences"),
            admission: opt("admission"),
            slo: opt("slo"),
            native: args.get_bool("native"),
            delta: args.get_bool("delta"),
            delta_compute: args.get_bool("delta-compute"),
            delta_voxelize: args.get_bool("delta-voxelize"),
            trace: args.get_bool("trace"),
            trace_out: opt("trace-out"),
            metrics_out: opt("metrics-out"),
            cost: args.get_bool("cost"),
        }
    }
}

/// The unified run configuration: every section of a run config parsed
/// in one strict pass, one override surface, one validation pass. The
/// [`Pipeline`](crate::pipeline::Pipeline) builder consumes it whole.
#[derive(Clone, Debug, Default)]
pub struct PipelineConfig {
    /// Engine layer: `[runner]` + `[shard]`.
    pub runner: RunnerConfig,
    /// Ingestion: `[dataset]`.
    pub dataset: DatasetConfig,
    /// Serving scheduler: `[serving]`.
    pub serving: ServingConfig,
    /// Which network a config-only build drives (`[pipeline] network`);
    /// an explicit builder network wins.
    pub network: NetworkKind,
    /// Owned-engine resolution (`[pipeline] engine`); an explicit
    /// builder engine wins.
    pub engine: EngineKind,
    /// PJRT artifacts directory (`[pipeline] artifacts`); `None`
    /// discovers `artifacts/manifest.txt` upward from the cwd.
    pub artifacts: Option<PathBuf>,
    /// Stage-span tracing / metrics registry: `[observability]` (off by
    /// default — the built pipeline then carries a no-op recorder).
    pub observability: ObsConfig,
}

impl PipelineConfig {
    /// Parse every section of a run config in one strict pass (unknown
    /// enum names, negative counts, and malformed values are errors in
    /// whichever section they appear).
    pub fn from_config(cfg: &Config) -> crate::Result<Self> {
        let artifacts = match cfg.str_or("pipeline.artifacts", "") {
            "" => None,
            dir => Some(PathBuf::from(dir)),
        };
        Ok(Self {
            runner: RunnerConfig::from_config(cfg)?,
            dataset: DatasetConfig::from_config(cfg)?,
            serving: ServingConfig::from_config(cfg)?,
            network: cfg.parsed_or("pipeline.network", NetworkKind::default())?,
            engine: cfg.parsed_or("pipeline.engine", EngineKind::default())?,
            artifacts,
            observability: ObsConfig::from_config(cfg)?,
        })
    }

    /// Load a TOML run config from `path`; `""` yields the defaults
    /// (the behavior of every CLI command's optional `--config`).
    pub fn load(path: &str) -> crate::Result<Self> {
        match path {
            "" => Self::from_config(&Config::default()),
            p => Self::from_config(&Config::load(p)?),
        }
    }

    /// Apply CLI overrides on top of the parsed config. Parse failures
    /// carry the flag name (`--shards: ...`), not just the value.
    pub fn apply(&mut self, ov: &Overrides) -> crate::Result<()> {
        if let Some(s) = &ov.searcher {
            self.runner.searcher = s.parse().map_err(anyhow::Error::msg)?;
        }
        if let Some(s) = &ov.shards {
            let (bx, by) = crate::util::cli::parse_grid(s).map_err(anyhow::Error::msg)?;
            self.runner.shard = ShardConfig::grid(bx, by)?;
        }
        if let Some(s) = &ov.w2b {
            self.runner.w2b_factor = s
                .parse()
                .map_err(|e| anyhow::anyhow!("--w2b: not an integer ({e})"))?;
        }
        if let Some(s) = &ov.dataset {
            self.dataset.source = s.clone();
        }
        if let Some(s) = &ov.frames {
            self.dataset.frames = s
                .parse()
                .map_err(|e| anyhow::anyhow!("--frames: not an integer ({e})"))?;
        }
        if let Some(s) = &ov.sequences {
            self.serving.sequences = crate::serving::parse_sequences(s)?;
        }
        if let Some(s) = &ov.admission {
            self.serving.admission.policy = s.parse().map_err(anyhow::Error::msg)?;
        }
        if let Some(s) = &ov.slo {
            let ms: f64 = s
                .parse()
                .map_err(|e| anyhow::anyhow!("--slo: not a number ({e})"))?;
            anyhow::ensure!(
                ms >= 0.0 && ms.is_finite(),
                "--slo must be a finite value >= 0, got {ms}"
            );
            self.serving.admission.slo_ms = ms;
        }
        if ov.native {
            self.engine = EngineKind::Native;
        }
        if ov.delta || ov.delta_compute || ov.delta_voxelize {
            self.runner.delta.enabled = true;
        }
        if ov.delta_compute {
            self.runner.delta.compute = true;
        }
        if ov.delta_voxelize {
            self.runner.delta.voxelize = true;
        }
        if ov.trace {
            self.observability.trace = true;
        }
        if let Some(p) = &ov.trace_out {
            self.observability.trace = true;
            self.observability.trace_out = p.clone();
        }
        if let Some(p) = &ov.metrics_out {
            self.observability.metrics = true;
            self.observability.metrics_out = p.clone();
        }
        if ov.cost {
            self.observability.cost = true;
            self.observability.metrics = true;
        }
        Ok(())
    }

    /// Check cross-section consistency, surfacing every failure as a
    /// typed [`PipelineError::InvalidConfig`]. The builder runs this
    /// before constructing anything — deliberately including the
    /// stream-only `[serving]` keys even when the pipeline will only
    /// ever see frame jobs: a config that names a shedding policy with
    /// no SLO, or a sequence that cannot resolve, is wrong *as a
    /// config*, and failing at build keeps the error next to the typo
    /// instead of deferring it to the first stream submission.
    pub fn validate(&self) -> crate::Result<()> {
        let invalid =
            |msg: String| -> anyhow::Error { PipelineError::InvalidConfig(msg).into() };
        self.serving
            .validate()
            .map_err(|e| invalid(format!("{e:#}")))?;
        self.dataset
            .validate()
            .map_err(|e| invalid(format!("{e:#}")))?;
        for (i, seq) in self.serving.sequences.iter().enumerate() {
            crate::dataset::validate_source(seq)
                .map_err(|e| invalid(format!("serving sequence {i}: {e:#}")))?;
        }
        // `engine = "pjrt"` without the feature (or without artifacts) is
        // NOT checked here: an explicit builder engine overrides the
        // config's resolution, so the check lives in `build_engine`, the
        // only place the kind is consumed.
        Ok(())
    }

    /// The voxel-grid extent of the stream backbone / profile sources:
    /// `[dataset] dims` when set, the historical 64x64x12 otherwise.
    pub fn stream_extent(&self) -> Extent3 {
        self.dataset.extent.unwrap_or(Extent3::new(64, 64, 12))
    }

    /// The [`RuntimeConfig`] this pipeline loads PJRT artifacts with.
    pub fn runtime_config(&self) -> RuntimeConfig {
        match &self.artifacts {
            Some(dir) => RuntimeConfig {
                artifacts_dir: dir.clone(),
            },
            None => RuntimeConfig::discover(),
        }
    }

    /// Resolve the configured frame source(s) for a stream job, sized to
    /// `extent`: a [`SequenceMux`] striping `[serving] sequences` when
    /// more than zero are configured (each sequence with its own
    /// prefetch buffer and a distinct derived seed, so two sequences of
    /// the same profile are different streams), the single `[dataset]`
    /// source otherwise, `Ok(None)` when neither is configured.
    pub fn build_source(
        &self,
        extent: Extent3,
    ) -> crate::Result<Option<Box<dyn FrameSource>>> {
        // Delta voxelization rides the runner's delta block grid: KITTI
        // sources re-voxelize only dirty blocks (each muxed sequence gets
        // its own [`DeltaVoxelizer`] state, so streams never cross-talk).
        let delta_blocks = (self.runner.delta.enabled && self.runner.delta.voxelize)
            .then(|| (self.runner.delta.blocks_x, self.runner.delta.blocks_y));
        if self.serving.sequences.is_empty() {
            return self.dataset.build_delta(extent, delta_blocks);
        }
        let mut sources = Vec::with_capacity(self.serving.sequences.len());
        for (i, spec) in self.serving.sequences.iter().enumerate() {
            let ds_i = DatasetConfig {
                source: spec.clone(),
                seed: self.dataset.seed.wrapping_add(0x9E37 * i as u64),
                ..self.dataset.clone()
            };
            let src = ds_i.build_delta(extent, delta_blocks)?.ok_or_else(|| {
                anyhow::anyhow!("sequence {i} ({spec:?}) resolved to no source")
            })?;
            sources.push(src);
        }
        Ok(Some(Box::new(SequenceMux::new(sources, self.serving.mux)?)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapsearch::SearcherKind;
    use crate::serving::AdmissionPolicy;

    #[test]
    fn one_strict_pass_over_every_section() {
        let cfg = Config::parse(
            "[runner]\nsearcher = \"octree\"\ninflight = 3\nw2b_factor = 2\n\
             [shard]\nblocks_x = 2\nblocks_y = 2\n\
             [dataset]\nsource = \"highway\"\nframes = 5\n\
             [serving]\nsequences = \"urban, far-field\"\nadmission = \"drop-oldest\"\nslo_ms = 25.0\n\
             [pipeline]\nnetwork = \"minkunet-small\"\nengine = \"native\"\n\
             [observability]\ntrace = true\nsample_every = 2\n\
             metrics_out = \"m.json\"\ncost = true\n",
        )
        .unwrap();
        let pc = PipelineConfig::from_config(&cfg).unwrap();
        assert!(pc.observability.trace && pc.observability.metrics);
        assert!(pc.observability.cost);
        assert_eq!(pc.observability.metrics_out, "m.json");
        assert_eq!(pc.observability.sample_every, 2);
        assert_eq!(pc.runner.searcher, SearcherKind::Octree);
        assert_eq!(pc.runner.inflight, 3);
        assert_eq!(pc.runner.w2b_factor, 2);
        assert_eq!((pc.runner.shard.blocks_x, pc.runner.shard.blocks_y), (2, 2));
        assert_eq!(pc.dataset.source, "highway");
        assert_eq!(pc.dataset.frames, 5);
        assert_eq!(pc.serving.sequences.len(), 2);
        assert_eq!(pc.serving.admission.policy, AdmissionPolicy::DropOldest);
        assert_eq!(pc.network, NetworkKind::MinkUNetSmall);
        assert_eq!(pc.engine, EngineKind::Native);
        pc.validate().unwrap();
        // A bad key in *any* section fails the one load.
        for bad in [
            "[runner]\nsearcher = \"bogus\"",
            "[shard]\nblocks_x = 0",
            "[dataset]\nframes = -1",
            "[serving]\nmux = \"fifo\"",
            "[pipeline]\nnetwork = \"resnet\"",
            "[pipeline]\nengine = \"gpu\"",
            "[observability]\ntrace = \"yes\"",
            "[observability]\nsample_every = 0",
            "[observability]\nmetrics_out = 7",
            "[observability]\ncost = \"yes\"",
        ] {
            let cfg = Config::parse(bad).unwrap();
            assert!(PipelineConfig::from_config(&cfg).is_err(), "{bad}");
        }
    }

    #[test]
    fn overrides_apply_and_parse_strictly() {
        let mut pc = PipelineConfig::default();
        pc.apply(&Overrides {
            searcher: Some("block-doms".into()),
            shards: Some("2x4".into()),
            w2b: Some("2".into()),
            dataset: Some("indoor".into()),
            frames: Some("9".into()),
            sequences: Some("urban,highway".into()),
            admission: Some("defer-sharding".into()),
            slo: Some("12.5".into()),
            native: true,
            delta: false,
            delta_compute: true,
            delta_voxelize: true,
            trace: false,
            trace_out: Some("trace.json".into()),
            metrics_out: Some("metrics.json".into()),
            cost: true,
        })
        .unwrap();
        assert_eq!(pc.runner.searcher, SearcherKind::BlockDoms);
        assert_eq!((pc.runner.shard.blocks_x, pc.runner.shard.blocks_y), (2, 4));
        assert_eq!(pc.runner.w2b_factor, 2);
        assert_eq!(pc.dataset.source, "indoor");
        assert_eq!(pc.dataset.frames, 9);
        assert_eq!(pc.serving.sequences, vec!["urban", "highway"]);
        assert_eq!(pc.serving.admission.policy, AdmissionPolicy::DeferSharding);
        assert!((pc.serving.admission.slo_ms - 12.5).abs() < 1e-12);
        assert_eq!(pc.engine, EngineKind::Native);
        // Either extension flag implies the base cache.
        assert!(pc.runner.delta.enabled);
        assert!(pc.runner.delta.compute);
        assert!(pc.runner.delta.voxelize);
        // Output paths imply their half of the observability subsystem,
        // and --cost turns the ledger on alongside the registry.
        assert!(pc.observability.trace && pc.observability.metrics);
        assert!(pc.observability.cost);
        assert_eq!(pc.observability.trace_out, "trace.json");
        assert_eq!(pc.observability.metrics_out, "metrics.json");
        pc.validate().unwrap();
        for bad in [
            Overrides {
                searcher: Some("bogus".into()),
                ..Default::default()
            },
            Overrides {
                shards: Some("0x2".into()),
                ..Default::default()
            },
            Overrides {
                w2b: Some("two".into()),
                ..Default::default()
            },
            Overrides {
                frames: Some("-3".into()),
                ..Default::default()
            },
            Overrides {
                slo: Some("NaN".into()),
                ..Default::default()
            },
            Overrides {
                sequences: Some("urban,,highway".into()),
                ..Default::default()
            },
        ] {
            assert!(PipelineConfig::default().apply(&bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn validate_surfaces_typed_config_errors() {
        use crate::serving::AdmissionConfig;
        // Shedding policy without an SLO target.
        let pc = PipelineConfig {
            serving: ServingConfig {
                admission: AdmissionConfig {
                    policy: AdmissionPolicy::DropOldest,
                    ..Default::default()
                },
                ..Default::default()
            },
            ..Default::default()
        };
        let err = pc.validate().unwrap_err();
        let typed = err.downcast_ref::<PipelineError>().expect("typed error");
        assert!(matches!(typed, PipelineError::InvalidConfig(m) if m.contains("slo")));
        // Path-shaped missing dataset source.
        let pc = PipelineConfig {
            dataset: DatasetConfig {
                source: "/no/such/kitti/velodyne".into(),
                ..Default::default()
            },
            ..Default::default()
        };
        let err = pc.validate().unwrap_err();
        assert!(err.downcast_ref::<PipelineError>().is_some(), "{err:#}");
        // Unknown profile inside the sequence list.
        let pc = PipelineConfig {
            serving: ServingConfig {
                sequences: vec!["urban".into(), "nebula".into()],
                ..Default::default()
            },
            ..Default::default()
        };
        let err = pc.validate().unwrap_err();
        let typed = err.downcast_ref::<PipelineError>().expect("typed error");
        assert!(matches!(typed, PipelineError::InvalidConfig(m) if m.contains("sequence 1")));
    }

    #[test]
    fn network_and_engine_kinds_round_trip() {
        for k in [
            NetworkKind::Second,
            NetworkKind::SecondSmall,
            NetworkKind::MinkUNet,
            NetworkKind::MinkUNetSmall,
            NetworkKind::StreamBackbone,
        ] {
            assert_eq!(k.key().parse::<NetworkKind>().unwrap(), k);
        }
        for k in [EngineKind::Auto, EngineKind::Native, EngineKind::Pjrt] {
            assert_eq!(k.key().parse::<EngineKind>().unwrap(), k);
        }
        let e = Extent3::new(32, 32, 8);
        assert_eq!(NetworkKind::StreamBackbone.build(e).extent, e);
        assert_eq!(NetworkKind::SecondSmall.build(e).name, "SECOND-small");
    }

    #[test]
    fn build_source_muxes_sequences_with_derived_seeds() {
        let mut pc = PipelineConfig {
            dataset: DatasetConfig {
                prefetch: 0,
                ..Default::default()
            },
            ..Default::default()
        };
        // No source configured at all.
        assert!(pc.build_source(Extent3::new(16, 16, 8)).unwrap().is_none());
        // Two same-profile sequences must still be distinct streams.
        pc.serving.sequences = vec!["urban".into(), "urban".into()];
        let mut src = pc
            .build_source(Extent3::new(16, 16, 8))
            .unwrap()
            .expect("mux source");
        let a = src.next_frame().expect("frame from sequence 0");
        let b = src.next_frame().expect("frame from sequence 1");
        assert_ne!(a.meta.sequence, b.meta.sequence);
        assert_ne!(
            (a.tensor.coords.clone(), a.tensor.features.clone()),
            (b.tensor.coords.clone(), b.tensor.features.clone()),
            "derived seeds must differ"
        );
    }
}
