//! The Voxel-CIM whole-chip estimator: propagates real frame geometry
//! through a network spec, runs the behavioral map-search model per
//! layer, plans CIM sub-matrix execution (with or without W2B), and
//! combines everything through the hybrid pipeline into FPS + energy.
//!
//! This is the simulator the paper's §4A describes ("the behavior of
//! searching methods will be modeled...; hardware performance ... with
//! NeuroSim"), rebuilt as one consistent rust model.

use crate::cim::energy::EnergyModel;
use crate::cim::tile::CimConfig;
use crate::cim::w2b::{capacity_budget, w2b_allocate};
use crate::coordinator::pipeline::{HybridPipeline, PhaseTiming};
use crate::mapsearch::{AccessStats, MapSearch};
use crate::model::layer::{LayerSpec, NetworkSpec, TaskKind};
use crate::sim::dram::{DramModel, COORD_BYTES};
use crate::sparse::rulebook::ConvKind;
use crate::sparse::tensor::SparseTensor;
use crate::sparse::hash_map_search;

/// Simulation options.
#[derive(Clone, Debug)]
pub struct SimOptions {
    /// Apply W2B replication (Fig. 10 ablates this).
    pub w2b: bool,
    /// Max W2B copy factor relative to kernel volume.
    pub w2b_factor: u32,
    /// Host-side preprocessing (voxelization + VFE) seconds per frame —
    /// measured on this machine's CPU by `experiments::table2`.
    pub preprocess_seconds: f64,
}

impl Default for SimOptions {
    fn default() -> Self {
        Self {
            w2b: true,
            w2b_factor: 2,
            preprocess_seconds: 0.0,
        }
    }
}

/// Per-layer simulation record.
#[derive(Clone, Debug)]
pub struct LayerSim {
    pub name: String,
    pub pairs: u64,
    pub macs: u64,
    pub ms_seconds: f64,
    pub compute_seconds: f64,
    pub compute_cycles: u64,
    pub utilization: f64,
    pub access: AccessStats,
    pub shared_search: bool,
}

/// Whole-frame simulation result.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub network: &'static str,
    pub task: TaskKind,
    pub n_input_voxels: usize,
    pub layers: Vec<LayerSim>,
    /// End-to-end seconds (hybrid pipeline + preprocessing).
    pub seconds: f64,
    /// Serial (unpipelined) seconds, for the pipeline ablation.
    pub serial_seconds: f64,
    pub energy_joules: f64,
}

impl SimReport {
    pub fn fps(&self) -> f64 {
        1.0 / self.seconds
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    /// Achieved efficiency in TOPS/W over the frame.
    pub fn tops_per_watt(&self) -> f64 {
        let ops = self.total_macs() as f64 * 2.0;
        let watts = self.energy_joules / self.seconds;
        ops / self.seconds / 1e12 / watts
    }
}

/// The estimator.
#[derive(Clone, Debug, Default)]
pub struct Accelerator {
    pub cim: CimConfig,
    pub energy: EnergyModel,
    pub dram: DramModel,
    pub pipeline: HybridPipeline,
}

impl Accelerator {
    /// Effective parallel instances for a `c1 x c2` slice layer with
    /// `k_volume` offsets: capacity-constrained sub-matrix slots divided
    /// by the tiles one logical slice needs.
    fn slice_tiles(c: usize) -> u64 {
        (c as u64).div_ceil(64)
    }

    /// Compute cycles + utilization for one sparse layer.
    fn sparse_layer_cycles(
        &self,
        workload: &[u64],
        c1: usize,
        c2: usize,
        w2b: bool,
        w2b_factor: u32,
    ) -> (u64, f64, u64) {
        let k_volume = workload.len();
        let tiles_per_slice = Self::slice_tiles(c1) * Self::slice_tiles(c2);
        let slots = self.cim.submatrix_slots(64.min(c1), 64.min(c2));
        let parallel_slices = (slots / tiles_per_slice).max(1);
        let total_pairs: u64 = workload.iter().sum();
        if parallel_slices < k_volume as u64 {
            // Capacity-bound: offsets time-share the array; weights are
            // re-staged between passes.
            let cycles = (total_pairs.div_ceil(parallel_slices))
                * self.cim.pe.cycles_per_pair();
            return (cycles, 1.0, total_pairs);
        }
        let budget = if w2b {
            capacity_budget(&self.cim, c1.min(64), c2.min(64), k_volume, w2b_factor)
                .min((parallel_slices) as u32)
        } else {
            k_volume as u32
        };
        let alloc = w2b_allocate(workload, budget.max(k_volume as u32));
        let makespan = alloc.makespan_after;
        let cycles = makespan * self.cim.pe.cycles_per_pair();
        let copies_total: u64 = alloc.copies.iter().map(|&c| c as u64).sum();
        let util = if makespan == 0 {
            0.0
        } else {
            total_pairs as f64 / (makespan * copies_total) as f64
        };
        (cycles, util, total_pairs)
    }

    /// Dense conv layer cycles: output pixels stream through k²
    /// sub-matrix groups; spare capacity replicates the whole group.
    fn dense_layer_cycles(&self, out_pixels: u64, c1: usize, c2: usize, k: usize) -> (u64, f64) {
        let tiles_per_slice = Self::slice_tiles(c1) * Self::slice_tiles(c2);
        let slots = self.cim.submatrix_slots(64.min(c1), 64.min(c2));
        let group = (k * k) as u64 * tiles_per_slice;
        let copies = (slots / group).max(1);
        let cycles = out_pixels.div_ceil(copies) * self.cim.pe.cycles_per_pair();
        (cycles, 0.9)
    }

    /// Simulate one frame of `net` on `input` (channels ignored; geometry
    /// only). Uses the hash oracle for functional geometry propagation
    /// and the DOMS behavioral model for map-search cost.
    pub fn simulate(
        &self,
        net: &NetworkSpec,
        input: &SparseTensor,
        searcher: &dyn MapSearch,
        opts: &SimOptions,
    ) -> SimReport {
        let mut layers = Vec::new();
        let mut cur = SparseTensor::from_coords(input.extent, input.coords.clone(), 1);
        let mut bev_pixels: u64 = 0;
        let mut bev_done = false;
        let mut prev_subm: Option<Vec<u64>> = None; // workload of shared search
        let mut timings = Vec::new();
        let mut energy = 0.0f64;
        // UNet skip stack: tconv2 outputs prune to the matching encoder
        // stage (see scheduler.rs).
        let mut skip_stack: Vec<(crate::geom::Extent3, Vec<crate::geom::Coord3>)> = Vec::new();

        for spec in &net.layers {
            match *spec {
                LayerSpec::Subm3 { c_in, c_out }
                | LayerSpec::GConv2 { c_in, c_out }
                | LayerSpec::TConv2 { c_in, c_out } => {
                    let kind = spec.conv_kind().unwrap();
                    if matches!(kind, ConvKind::Generalized { .. }) {
                        skip_stack.push((cur.extent, cur.coords.clone()));
                    }
                    let skip_target = match kind {
                        ConvKind::Transposed { .. } => skip_stack.pop(),
                        _ => None,
                    };
                    let shared = matches!(kind, ConvKind::Submanifold { .. })
                        && prev_subm.is_some();
                    let (workload, access, ms_seconds, next) = if shared {
                        (prev_subm.clone().unwrap(), AccessStats::default(), 0.0, None)
                    } else if let (ConvKind::Transposed { k, stride }, Some((ext, target))) =
                        (kind, skip_target)
                    {
                        let rb = crate::sparse::hash_search::tconv_pruned(
                            &cur, k, stride, ext, &target,
                        );
                        let access = AccessStats {
                            voxel_reads: cur.len() as u64 + target.len() as u64,
                            ..Default::default()
                        };
                        let ms = self.dram.seconds(
                            access.voxel_reads * COORD_BYTES,
                        );
                        let w = rb.workload_per_offset();
                        let next =
                            SparseTensor::from_coords(rb.out_extent, rb.out_coords.clone(), 1);
                        (w, access, ms, Some(next))
                    } else {
                        let (rb, st) = searcher.search(&cur, kind);
                        // MS time: DRAM streaming vs sorter throughput
                        // (one pass per cycle, pipelined).
                        let dram_t = self
                            .dram
                            .seconds(st.voxel_reads * COORD_BYTES + st.voxel_writes * COORD_BYTES);
                        let sorter_t = st.sorter_passes as f64 / self.cim.freq_hz * 1.0;
                        let w = rb.workload_per_offset();
                        let next = SparseTensor::from_coords(rb.out_extent, rb.out_coords.clone(), 1);
                        (w, st, dram_t.max(sorter_t), Some(next))
                    };
                    let (cycles, util, pairs) = self.sparse_layer_cycles(
                        &workload,
                        c_in,
                        c_out,
                        opts.w2b,
                        opts.w2b_factor,
                    );
                    let macs = pairs * (c_in * c_out) as u64;
                    let compute_seconds = cycles as f64 / self.cim.freq_hz;
                    // Energy: useful MAC work (replication-invariant; see
                    // EnergyModel::energy_per_mac) + DRAM/buffer traffic.
                    // Leakage is charged once over the pipelined frame
                    // time below.
                    let e_mac = macs as f64 * self.energy.energy_per_mac(&self.cim);
                    let feat_bytes = pairs * c_in as u64 + pairs * 4 * c_out as u64 / 8;
                    let e_dram = self.energy.dram_energy(
                        access.voxel_reads * COORD_BYTES + feat_bytes,
                    ) + self.energy.buffer_energy(feat_bytes);
                    energy += e_mac + e_dram;
                    timings.push(PhaseTiming {
                        ms: ms_seconds,
                        compute: compute_seconds,
                    });
                    layers.push(LayerSim {
                        name: format!("{spec:?}"),
                        pairs,
                        macs,
                        ms_seconds,
                        compute_seconds,
                        compute_cycles: cycles,
                        utilization: util,
                        access,
                        shared_search: shared,
                    });
                    if matches!(kind, ConvKind::Submanifold { .. }) {
                        prev_subm = Some(workload);
                    } else {
                        prev_subm = None;
                    }
                    if let Some(next) = next {
                        cur = next;
                    }
                }
                LayerSpec::ToBev => {
                    bev_pixels = {
                        // BEV grid at the encoder's final resolution.
                        (cur.extent.x * cur.extent.y) as u64
                    };
                    bev_done = true;
                    prev_subm = None;
                }
                LayerSpec::Conv2d { c_in, c_out, k, stride } => {
                    assert!(bev_done, "Conv2d before ToBev in {}", net.name);
                    let out_pixels = bev_pixels / (stride * stride) as u64;
                    let (cycles, util) = self.dense_layer_cycles(out_pixels, c_in, c_out, k);
                    let macs = out_pixels * (k * k * c_in * c_out) as u64;
                    let secs = cycles as f64 / self.cim.freq_hz;
                    energy += macs as f64 * self.energy.energy_per_mac(&self.cim);
                    timings.push(PhaseTiming { ms: 0.0, compute: secs });
                    layers.push(LayerSim {
                        name: format!("{spec:?}"),
                        pairs: out_pixels * (k * k) as u64,
                        macs,
                        ms_seconds: 0.0,
                        compute_seconds: secs,
                        compute_cycles: cycles,
                        utilization: util,
                        access: AccessStats::default(),
                        shared_search: false,
                    });
                    bev_pixels = out_pixels;
                }
                LayerSpec::Deconv2d { c_in, c_out, k, up } => {
                    assert!(bev_done, "Deconv2d before ToBev in {}", net.name);
                    let out_pixels = bev_pixels * (up * up) as u64;
                    let (cycles, util) = self.dense_layer_cycles(out_pixels, c_in, c_out, k);
                    let macs = out_pixels * (k * k * c_in * c_out) as u64;
                    let secs = cycles as f64 / self.cim.freq_hz;
                    energy += macs as f64 * self.energy.energy_per_mac(&self.cim);
                    timings.push(PhaseTiming { ms: 0.0, compute: secs });
                    layers.push(LayerSim {
                        name: format!("{spec:?}"),
                        pairs: out_pixels * (k * k) as u64,
                        macs,
                        ms_seconds: 0.0,
                        compute_seconds: secs,
                        compute_cycles: cycles,
                        utilization: util,
                        access: AccessStats::default(),
                        shared_search: false,
                    });
                    // Deconv heads fan out from saved block outputs; keep
                    // pixel count of the main trunk.
                }
            }
        }

        let sched = self.pipeline.schedule(&timings);
        // Static/leakage power burns for the whole (pipelined) frame —
        // the only energy term W2B's shorter runtime saves (Fig. 10's
        // ~6% at a 2.3x speedup).
        energy += self.energy.p_leak * (sched.total + opts.preprocess_seconds);
        SimReport {
            network: net.name,
            task: net.task,
            n_input_voxels: input.len(),
            layers,
            seconds: sched.total + opts.preprocess_seconds,
            serial_seconds: sched.serial_total + opts.preprocess_seconds,
            energy_joules: energy,
        }
    }
}

/// Propagate geometry only (used by experiments that need layer-wise
/// voxel counts without timing).
pub fn propagate_geometry(net: &NetworkSpec, input: &SparseTensor) -> Vec<usize> {
    let mut cur = SparseTensor::from_coords(input.extent, input.coords.clone(), 1);
    let mut counts = vec![cur.len()];
    let mut skip_stack: Vec<(crate::geom::Extent3, Vec<crate::geom::Coord3>)> = Vec::new();
    for spec in &net.layers {
        if let Some(kind) = spec.conv_kind() {
            if matches!(kind, ConvKind::Submanifold { .. }) {
                counts.push(cur.len());
                continue;
            }
            if matches!(kind, ConvKind::Generalized { .. }) {
                skip_stack.push((cur.extent, cur.coords.clone()));
            }
            let rb = match (kind, skip_stack.is_empty()) {
                (ConvKind::Transposed { k, stride }, false) => {
                    let (ext, target) = skip_stack.pop().unwrap();
                    crate::sparse::hash_search::tconv_pruned(&cur, k, stride, ext, &target)
                }
                _ => hash_map_search(&cur, kind),
            };
            cur = SparseTensor::from_coords(rb.out_extent, rb.out_coords.clone(), 1);
            counts.push(cur.len());
        }
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Extent3;
    use crate::mapsearch::Doms;
    use crate::model::{minkunet, second};
    use crate::pointcloud::voxelize::Voxelizer;

    fn lidar_frame(extent: Extent3, n: usize, seed: u64) -> SparseTensor {
        let g = Voxelizer::synth_occupancy(extent, n as f64 / extent.volume() as f64, seed);
        SparseTensor::from_coords(extent, g.coords(), 1)
    }

    #[test]
    fn detection_sim_produces_plausible_fps() {
        let net = second::second();
        let input = lidar_frame(net.extent, 60_000, 90);
        let acc = Accelerator::default();
        let rep = acc.simulate(&net, &input, &Doms::default(), &SimOptions::default());
        let fps = rep.fps();
        assert!(fps > 30.0 && fps < 500.0, "detection fps {fps}");
        assert!(rep.energy_joules > 0.0);
        // Pipeline must beat serial execution.
        assert!(rep.seconds < rep.serial_seconds);
    }

    #[test]
    fn segmentation_sim_w2b_speedup() {
        let net = minkunet::minkunet();
        // Clustered occupancy: the workload skew W2B exists to fix.
        let g = Voxelizer::synth_clustered(net.extent, 1.5e-4, 12, 0.3, 91);
        let input = SparseTensor::from_coords(net.extent, g.coords(), 1);
        let acc = Accelerator::default();
        let with = acc.simulate(&net, &input, &Doms::default(), &SimOptions::default());
        let without = acc.simulate(
            &net,
            &input,
            &Doms::default(),
            &SimOptions { w2b: false, ..Default::default() },
        );
        let speedup = without.seconds / with.seconds;
        assert!(speedup > 1.3, "W2B speedup only {speedup:.2}x");
        // Energy decreases but by far less than the speedup (Fig. 10).
        assert!(with.energy_joules <= without.energy_joules * 1.02);
    }

    #[test]
    fn geometry_propagation_monotone_downsampling() {
        let net = second::second();
        let input = lidar_frame(net.extent, 30_000, 92);
        let counts = propagate_geometry(&net, &input);
        assert_eq!(counts[0], input.len());
        // gconv2 outputs are never more numerous than inputs.
        for w in counts.windows(2) {
            assert!(w[1] <= w[0] * 27, "implausible growth {w:?}");
        }
    }

    #[test]
    fn tops_per_watt_below_peak() {
        let net = second::second();
        let input = lidar_frame(net.extent, 50_000, 93);
        let acc = Accelerator::default();
        let rep = acc.simulate(&net, &input, &Doms::default(), &SimOptions::default());
        let eff = rep.tops_per_watt();
        let peak = acc.energy.peak_tops_per_watt(&acc.cim);
        assert!(eff > 0.0 && eff <= peak * 1.05, "eff {eff} vs peak {peak}");
    }
}
